//! Design-space exploration with the resource-aware methodology (§V):
//! run Algorithm 1 + Algorithm 2 for every zoo network across a range of
//! FPGA-like budgets, demonstrating the scalability claim of Fig 12/15.
//!
//! ```sh
//! cargo run --release --offline --example allocate_design
//! ```

use repro::alloc::{self, Granularity};
use repro::{nets, zc706};

fn main() {
    // (name, SRAM bytes, DSP budget) — small/edge, ZC706, and a larger
    // mid-range part.
    let budgets: [(&str, u64, usize); 3] = [
        ("edge (0.9MB, 220 DSP)", 900 * 1024, 220),
        ("ZC706 (1.8MB, 855 DSP)", zc706::SRAM_BYTES, zc706::DSP_BUDGET),
        ("mid (4MB, 2520 DSP)", 4 * 1024 * 1024, 2520),
    ];

    for net in nets::all_networks() {
        println!("=== {} ({:.0}M MACs) ===", net.name, net.total_macs() as f64 / 1e6);
        println!(
            "{:24} {:>8} {:>7} {:>7} {:>9} {:>9} {:>8} {:>8}",
            "platform", "boundary", "PEs", "DSPs", "SRAM MB", "DRAM MB", "FPS", "eff"
        );
        for (label, sram, dsp) in budgets {
            let d = alloc::design_point(&net, sram, dsp, Granularity::Fgpm);
            println!(
                "{:24} {:>8} {:>7} {:>7} {:>9.2} {:>9.2} {:>8.1} {:>7.2}%",
                label,
                d.memory.boundary,
                d.parallelism.pes,
                d.parallelism.dsps,
                d.sram_bytes as f64 / 1048576.0,
                d.dram_bytes as f64 / 1048576.0,
                d.performance.fps,
                d.performance.mac_efficiency * 100.0
            );
        }
        println!();
    }
    println!("(larger SRAM pushes the FRCE/WRCE boundary deeper -> less DRAM traffic;");
    println!(" more DSPs raise FPS near-linearly thanks to FGPM — the Fig 12/15 behaviours)");
}
