//! Design-space exploration with the resource-aware methodology (§V):
//! compile a [`Design`] for every zoo network across a range of
//! [`Platform`] budgets, demonstrating the scalability claim of Fig 12/15.
//! With the façade a multi-platform sweep is a one-liner per cell.
//!
//! For whole-matrix sweeps (the catalog x the zoo, with JSON output and
//! per-cell artifacts) see the "Design-space sweeps" example,
//! `examples/platform_sweep.rs`, and the `repro sweep` subcommand.
//!
//! ```sh
//! cargo run --release --offline --example allocate_design
//! ```

use repro::{nets, Design, Platform};

fn main() {
    // Small/edge, the paper's ZC706, and a larger mid-range part — all
    // expressed as named Platform budgets.
    let platforms = [
        Platform::custom("edge (0.9MB, 220 DSP)", 900 * 1024, 220),
        Platform::zc706(),
        Platform::custom("mid (4MB, 2520 DSP)", 4 * 1024 * 1024, 2520),
    ];

    for net in nets::all_networks() {
        println!("=== {} ({:.0}M MACs) ===", net.name, net.total_macs() as f64 / 1e6);
        println!(
            "{:24} {:>8} {:>7} {:>7} {:>9} {:>9} {:>8} {:>8}",
            "platform", "boundary", "PEs", "DSPs", "SRAM MB", "DRAM MB", "FPS", "eff"
        );
        for platform in &platforms {
            let d = Design::builder(&net).platform(platform.clone()).build();
            println!(
                "{:24} {:>8} {:>7} {:>7} {:>9.2} {:>9.2} {:>8.1} {:>7.2}%",
                platform.name,
                d.ce_plan().boundary,
                d.parallelism().pes,
                d.parallelism().dsps,
                d.sram_bytes() as f64 / 1048576.0,
                d.dram_bytes() as f64 / 1048576.0,
                d.predicted().fps,
                d.predicted().mac_efficiency * 100.0
            );
        }
        println!();
    }
    println!("(larger SRAM pushes the FRCE/WRCE boundary deeper -> less DRAM traffic;");
    println!(" more DSPs raise FPS near-linearly thanks to FGPM — the Fig 12/15 behaviours)");
}
