//! # Design-space sweeps
//!
//! The whole platform catalog x the whole network zoo in one call: a
//! [`SweepSpec`] names the matrix axes, [`SweepSpec::run`] compiles a
//! `Design` per cell (Algorithm 1 boundary, Algorithm 2 parallelism,
//! clock-aware Eq-14 prediction at each platform's own MHz), and the
//! report renders as an aligned table ([`report::sweep_matrix`]) or the
//! stable sorted-key JSON that BENCH trajectories record.
//!
//! Cells are evaluated in parallel (`SweepSpec::jobs`, the CLI's
//! `--jobs`) on the scoped-thread pool in `util::pool`; the output is
//! byte-identical to the serial path for any job count.
//!
//! The CLI twin of this example is:
//!
//! ```sh
//! repro sweep --nets mobilenet_v2,shufflenet_v2 \
//!             --platforms zc706,zcu102,edge --jobs 4 --json
//! ```
//!
//! Repeated runs memoize per-cell results in a content-keyed cache
//! (`SweepSpec::cache_dir`, the CLI's `--cache`/`--cache-dir`): the
//! second invocation of this example reports a 100% hit rate and
//! re-derives nothing, with byte-identical output. The directory — and
//! the clock axis, which is part of each cell's content key — is shared
//! with the `pareto_frontier` example, so running either one warms the
//! other: this example's 24 cells are exactly the 24 cells that
//! example's Pareto analyses re-read.
//!
//! Pass a directory argument to also persist one `Design` artifact per
//! cell (the same artifact format committed as golden baselines under
//! `rust/tests/baselines/`):
//!
//! ```sh
//! cargo run --release --offline --example platform_sweep [save-dir]
//! ```

use repro::alloc::Granularity;
use repro::sweep::SweepSpec;
use repro::{report, Platform};

fn main() {
    // Default axes: all four zoo networks x the whole catalog. Add the
    // factorized baseline as a second granularity so every cell pair
    // shows the FGPM gain platform by platform, and fan the 24 cells out
    // over the machine's cores on the work-stealing pool — the report is
    // byte-identical either way. Cells are memoized across runs of this
    // example AND the `pareto_frontier` example: both use the shared
    // directory and the same clock axis (the axis is part of the content
    // key), so whichever runs second is fully warm.
    let cache_dir = std::env::temp_dir().join("repro_examples_sweep_cache");
    let spec = SweepSpec {
        granularities: vec![Granularity::Fgpm, Granularity::Factorized],
        jobs: repro::util::pool::default_jobs(),
        clocks_hz: SweepSpec::parse_clocks_csv("100,150,200,250,300").expect("clock axis"),
        cache_dir: Some(cache_dir.clone()),
        ..SweepSpec::default()
    };
    println!(
        "sweeping {} cells ({} networks x {} platforms x {} granularities) on {} jobs",
        spec.cell_count(),
        spec.nets.len(),
        spec.platforms.len(),
        spec.granularities.len(),
        spec.jobs
    );
    for p in Platform::list() {
        println!(
            "  {:8} {:>5} DSPs (budget {:>4}), {:>5.2} MB SRAM, {:>3.0} MHz",
            p.name,
            p.dsp_total,
            p.dsp_budget,
            p.sram_bytes as f64 / 1048576.0,
            p.clock_hz / 1e6
        );
    }

    let sweep_report = spec.run();
    println!("{}", report::sweep_matrix(&sweep_report));

    if let Some(stats) = &sweep_report.cache {
        // First run: 24 misses. Re-run this example — or run the
        // pareto_frontier example, which shares the directory and clock
        // axis — and it reports 24 hits, 100% rate, zero Alg 1/Alg 2
        // re-derivation, with identical output bytes.
        println!("{}", stats.summary(&cache_dir));
    }

    let json = sweep_report.to_json();
    println!("JSON document: {} bytes, stable sorted keys (`repro sweep --json`)", json.len());

    if let Some(dir) = std::env::args().nth(1) {
        let paths = sweep_report.save_designs(std::path::Path::new(&dir)).expect("save designs");
        println!("saved {} design artifacts to {dir}", paths.len());
    }
}
