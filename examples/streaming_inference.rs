//! END-TO-END DRIVER (DESIGN.md experiment "E2E"; recorded in
//! EXPERIMENTS.md): serve a batch of frames through the full three-layer
//! system for both implemented networks and report the paper's headline
//! metrics.
//!
//! The request path is Rust-only: per-stage HLO executables (compiled once
//! by python/compile/aot.py from the JAX+Pallas stage graphs) are loaded
//! via PJRT and chained by the threaded streaming coordinator — FRCE
//! stages carry their weights as on-chip constants, WRCE stages receive
//! their weights from the host-memory "DRAM" on every frame. Every output
//! frame is checked against the golden logits. The projected hardware
//! numbers come from the same [`Design`] artifact that drives the
//! coordinator (`coordinator::run_streaming_design`).
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example streaming_inference
//! ```

use repro::{coordinator, nets, runtime, Design, Platform};

fn main() -> anyhow::Result<()> {
    let dir = runtime::artifacts_dir();
    let frames = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(12u64);
    let workers = 4usize;

    for (short, net) in [("mbv2", nets::mobilenet_v2()), ("snv2", nets::shufflenet_v2())] {
        if !dir.join(format!("{short}_manifest.json")).exists() {
            println!("{short}: artifacts missing — run `make artifacts`");
            continue;
        }
        // One Design per network: it names the artifacts to stream AND the
        // accelerator configuration whose performance we project.
        let design = Design::builder(&net).platform(Platform::zc706()).build();
        println!("=== {} : streaming {} frames through {} CE groups ===", net.name, frames, workers);
        let r = coordinator::run_streaming_design(&design, dir.clone(), frames, workers)?;
        println!(
            "functional: {:.2} FPS (XLA-CPU substrate), mean latency {:.1} ms, max |logits err| {:.2e}",
            r.fps,
            r.latency * 1e3,
            r.max_abs_err
        );
        assert!(r.max_abs_err < 1e-3, "golden check failed");
        println!(
            "DRAM weight stream {:.2} MB/frame (8-bit model), coordinator overhead {:.1}%",
            r.dram_weight_bytes_8bit as f64 / 1048576.0,
            r.coordinator_overhead() * 100.0
        );
        for g in &r.groups {
            println!("  CE group {:?}: busy {:.2}s", g.stages, g.busy);
        }

        // Projected hardware performance of the same workload: the paper's
        // headline metric comes from the cycle-level simulator at 200 MHz.
        let clock = design.platform().clock_hz;
        let stats = design.simulate(10).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "projected accelerator: {:.1} FPS @200MHz, MAC efficiency {:.2}% \
             (paper: {:.1} FPS / {:.2}%)\n",
            stats.fps(clock),
            stats.mac_efficiency() * 100.0,
            if short == "mbv2" { 985.8 } else { 2092.4 },
            if short == "mbv2" { 94.35 } else { 94.58 },
        );
    }
    Ok(())
}
