//! Quickstart: the five-minute tour of the library.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! 1. Load a network description from the zoo.
//! 2. Run the paper's resource-aware methodology (Algorithm 1 + 2) for the
//!    ZC706 budget.
//! 3. Cycle-simulate the resulting accelerator and compare actual vs
//!    theoretical MAC efficiency.
//! 4. If `make artifacts` has been run, execute one real inference through
//!    the AOT-compiled PJRT pipeline and check it against the golden.

use repro::alloc::{self, Granularity};
use repro::model::memory::CePlan;
use repro::sim::{self, SimOptions};
use repro::{nets, runtime, zc706, CLOCK_HZ};

fn main() -> anyhow::Result<()> {
    // 1. A network from the zoo.
    let net = nets::mobilenet_v2();
    println!(
        "{}: {} layers, {:.1}M MACs, {:.2}M weight bytes (8-bit), {} SCBs",
        net.name,
        net.layers.len(),
        net.total_macs() as f64 / 1e6,
        net.total_weight_bytes() as f64 / 1e6,
        net.scbs.len()
    );

    // 2. Resource-aware allocation for the ZC706 budget.
    let d = alloc::design_point(&net, zc706::SRAM_BYTES, zc706::DSP_BUDGET, Granularity::Fgpm);
    println!(
        "design point: boundary={} ({} FRCEs / {} WRCEs), {} PEs on {} DSPs, \
         SRAM {:.2} MB, DRAM {:.2} MB/frame",
        d.memory.boundary,
        d.memory.boundary,
        net.layers.len() - d.memory.boundary,
        d.parallelism.pes,
        d.parallelism.dsps,
        d.sram_bytes as f64 / 1048576.0,
        d.dram_bytes as f64 / 1048576.0,
    );
    println!(
        "theoretical: {:.1} FPS @200MHz, MAC efficiency {:.2}%",
        d.performance.fps,
        d.performance.mac_efficiency * 100.0
    );

    // 3. Cycle-level simulation of the streaming pipeline.
    let plan = CePlan { boundary: d.memory.boundary };
    let stats = sim::simulate(&net, &d.parallelism.allocs, &plan, &SimOptions::optimized(), 10)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "simulated:   {:.1} FPS @200MHz, actual MAC efficiency {:.2}%, latency {:.2} ms",
        stats.fps(CLOCK_HZ),
        stats.mac_efficiency() * 100.0,
        stats.latency_ms(CLOCK_HZ)
    );

    // 4. Real numerics through the AOT artifacts (optional).
    let dir = runtime::artifacts_dir();
    if dir.join("mbv2_manifest.json").exists() {
        let engine = runtime::Engine::load(&dir, "mbv2")?;
        let input = engine.manifest.read_f32(&engine.manifest.golden_input)?;
        let golden = engine.manifest.read_f32(&engine.manifest.golden_logits)?;
        let logits = engine.infer(&input)?;
        let err = logits.iter().zip(&golden).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        println!("PJRT inference: {} logits, max |err| vs golden = {err:.2e}", logits.len());
    } else {
        println!("(run `make artifacts` to enable the PJRT inference step)");
    }
    Ok(())
}
