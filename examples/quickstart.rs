//! Quickstart: the five-minute tour of the library, organized around the
//! `Design`/`Platform` façade.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! 1. Load a network description from the zoo.
//! 2. Compile a [`Design`] for the ZC706 [`Platform`] — one builder call
//!    runs the paper's whole resource-aware methodology (Algorithm 1
//!    places the FRCE/WRCE boundary, Algorithm 2 tunes parallelism).
//! 3. Cycle-simulate the design (`design.simulate`) and compare actual vs
//!    theoretical MAC efficiency.
//! 4. Round-trip the design through its stable JSON form — the artifact
//!    benches and CI persist and diff.
//! 5. If `make artifacts` has been run, execute one real inference through
//!    the AOT-compiled PJRT pipeline and check it against the golden.

use repro::{nets, runtime, Design, Platform};

fn main() -> anyhow::Result<()> {
    // 1. A network from the zoo.
    let net = nets::mobilenet_v2();
    println!(
        "{}: {} layers, {:.1}M MACs, {:.2}M weight bytes (8-bit), {} SCBs",
        net.name,
        net.layers.len(),
        net.total_macs() as f64 / 1e6,
        net.total_weight_bytes() as f64 / 1e6,
        net.scbs.len()
    );

    // 2. One builder call = the whole resource-aware methodology.
    let design = Design::builder(&net).platform(Platform::zc706()).build();
    println!(
        "design point: boundary={} ({} FRCEs / {} WRCEs), {} PEs on {} DSPs, \
         SRAM {:.2} MB, DRAM {:.2} MB/frame",
        design.ce_plan().boundary,
        design.ce_plan().boundary,
        net.layers.len() - design.ce_plan().boundary,
        design.parallelism().pes,
        design.parallelism().dsps,
        design.sram_bytes() as f64 / 1048576.0,
        design.dram_bytes() as f64 / 1048576.0,
    );
    println!(
        "theoretical: {:.1} FPS @200MHz, MAC efficiency {:.2}%",
        design.predicted().fps,
        design.predicted().mac_efficiency * 100.0
    );

    // 3. Cycle-level simulation of the streaming pipeline.
    let clock = design.platform().clock_hz;
    let stats = design.simulate(10).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "simulated:   {:.1} FPS @200MHz, actual MAC efficiency {:.2}%, latency {:.2} ms",
        stats.fps(clock),
        stats.mac_efficiency() * 100.0,
        stats.latency_ms(clock)
    );

    // 4. Designs persist as stable one-line JSON and reload bit-identically.
    let json = design.to_json();
    let reloaded = Design::from_json(&json).map_err(|e| anyhow::anyhow!(e))?;
    assert_eq!(json, reloaded.to_json());
    println!("design JSON round-trip OK ({} bytes)", json.len());

    // 5. Real numerics through the AOT artifacts (optional).
    let dir = runtime::artifacts_dir();
    if dir.join("mbv2_manifest.json").exists() {
        let engine = runtime::Engine::load_for(&design, &dir)?;
        let input = engine.manifest.read_f32(&engine.manifest.golden_input)?;
        let golden = engine.manifest.read_f32(&engine.manifest.golden_logits)?;
        let logits = engine.infer(&input)?;
        let err = logits.iter().zip(&golden).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        println!("PJRT inference: {} logits, max |err| vs golden = {err:.2e}", logits.len());
    } else {
        println!("(run `make artifacts` to enable the PJRT inference step)");
    }
    Ok(())
}
