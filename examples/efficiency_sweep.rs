//! The Fig 15/16 scalability experiment as a standalone example: sweep MAC
//! budgets 60..4000 for every zoo network, compare FGPM against the
//! factorized-granularity baseline, and print the staircase effect that
//! motivates §IV-A.
//!
//! The FRCE/WRCE boundary each sweep runs under is the ZC706
//! [`Platform`]'s Algorithm-1 placement (Algorithm 2 is what the sweep
//! itself varies, so no full `Design` build is needed here).
//!
//! ```sh
//! cargo run --release --offline --example efficiency_sweep [net]
//! ```

use repro::{nets, report, Platform};

fn main() {
    let filter = std::env::args().nth(1);
    let budgets = report::fig15_budgets();
    for net in nets::all_networks() {
        if let Some(f) = &filter {
            let alias = nets::by_name(f).map(|n| n.name);
            if !net.name.contains(f.as_str()) && alias.as_deref() != Some(&net.name) {
                continue;
            }
        }
        // The same boundary fig15_sweep runs under (one source of truth).
        let boundary = report::zc706_boundary(&net);
        println!("=== {} (FRCE/WRCE boundary {} @ {}) ===", net.name, boundary, Platform::zc706().name);
        let pts = report::fig15_sweep(&net, &budgets);
        println!(
            "{:>6} {:>10} {:>10} {:>11} {:>11} {:>12}",
            "MACs", "eff FGPM", "eff fact", "GOPS FGPM", "GOPS fact", "staircase"
        );
        let mut prev_fact_gops = 0.0f64;
        for p in &pts {
            // The "staircase" marker: budget grew but the factorized
            // baseline's throughput did not (wasted PEs, Fig 10(a)/15).
            let stair = if p.gops_fact <= prev_fact_gops * 1.001 && prev_fact_gops > 0.0 { "  <- flat" } else { "" };
            prev_fact_gops = p.gops_fact;
            println!(
                "{:>6} {:>9.2}% {:>9.2}% {:>11.1} {:>11.1}{}",
                p.pes,
                p.eff_fgpm * 100.0,
                p.eff_fact * 100.0,
                p.gops_fgpm,
                p.gops_fact,
                stair
            );
        }
        println!();
    }
}
