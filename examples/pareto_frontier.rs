//! # Pareto frontiers and clock-scaling curves
//!
//! The design-space sweep's two analyses in one run:
//!
//! * `sweep::pareto` — per network, the non-dominated set over
//!   {on-chip SRAM, predicted FPS, off-chip DRAM bytes/frame}. This is
//!   the trade-off the balanced-dataflow methodology navigates: the
//!   FRCE/WRCE boundary *buys* throughput-stable on-chip memory at the
//!   price of off-chip weight traffic, so a bigger part (zcu102) and a
//!   smaller one (edge) land on the same frontier at different corners,
//!   and a factorized-granularity cell is typically *dominated* by its
//!   FGPM twin — same memory, less throughput.
//!
//! * `SweepSpec::clocks_hz` — every cell's Eq-14 prediction re-evaluated
//!   along a `--clocks`-style MHz axis (FPS/GOPS scale linearly; the
//!   allocation, bottleneck CE and MAC efficiency do not move).
//!
//! * `sweep::pareto_clocks` — the same clock axis promoted to a fourth
//!   Pareto dimension: every (cell, clock point) pair competes over
//!   {SRAM ↓, FPS ↑, DRAM ↓, clock ↓}, so "run the mid-size part at
//!   150 MHz" can beat "run the big part at 300 MHz" on everything but
//!   raw FPS and still sit on the frontier.
//!
//! The CLI twin of this example is:
//!
//! ```sh
//! repro sweep --granularities fgpm,factorized --cache-dir DIR \
//!             --jobs 4 --clocks 100,150,200,250,300 --pareto --pareto-clocks
//! ```
//!
//! The underlying matrix is the `platform_sweep` example's, cell for
//! cell, so the two share one cache directory (and one clock axis — the
//! axis is part of each cell's content key): run `platform_sweep` first
//! and this example starts 100% warm, spending its time only on the
//! Pareto analyses, which are derived from cells and never cached.

use repro::alloc::Granularity;
use repro::sweep::{self, SweepSpec};
use repro::{report, util};

fn main() {
    // Same axes + same shared cache directory as examples/platform_sweep
    // — whichever example runs second gets every cell from disk.
    let cache_dir = std::env::temp_dir().join("repro_examples_sweep_cache");
    let spec = SweepSpec {
        granularities: vec![Granularity::Fgpm, Granularity::Factorized],
        jobs: util::pool::default_jobs(),
        clocks_hz: SweepSpec::parse_clocks_csv("100,150,200,250,300").expect("clock axis"),
        cache_dir: Some(cache_dir.clone()),
        ..SweepSpec::default()
    };
    println!("evaluating {} cells on {} jobs", spec.cell_count(), spec.jobs);
    let matrix = spec.run();
    if let Some(stats) = &matrix.cache {
        // 100% hit rate whenever platform_sweep (or this example) ran
        // before; the analyses below see byte-identical cells either way.
        println!("{}", stats.summary(&cache_dir));
    }

    let analysis = sweep::pareto(&matrix);
    println!("{}", report::pareto_table(&matrix, &analysis));
    for front in &analysis.fronts {
        println!(
            "{}: {} of {} cells on the frontier, {} dominated",
            front.network,
            front.frontier.len(),
            front.frontier.len() + front.dominated.len(),
            front.dominated.len()
        );
    }

    println!("{}", report::clock_curves(&matrix));

    // Clock frequency as a fourth Pareto axis: every (cell, clock point)
    // candidate competes, so the frontier names the slowest clock that
    // still earns its place — not just the fastest platform.
    let clock_analysis = sweep::pareto_clocks(&matrix);
    println!("{}", report::pareto_clocks_table(&matrix, &clock_analysis));
    for front in &clock_analysis.fronts {
        println!(
            "{}: {} of {} (cell, clock) candidates on the 4-D frontier",
            front.network,
            front.frontier.len(),
            front.frontier.len() + front.dominated.len(),
        );
    }

    // The machine-readable twin: `repro sweep --pareto --pareto-clocks
    // --json` embeds both analyses under top-level "pareto" /
    // "pareto_clocks" keys.
    let json = matrix.to_json_full(Some(&analysis), Some(&clock_analysis));
    println!("JSON document with embedded pareto analyses: {} bytes", json.len());
}
