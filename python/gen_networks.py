#!/usr/bin/env python3
"""Generate the committed ``networks/*.json`` catalog.

This script is the Python twin of the Rust IR front-end (``rust/src/ir``):
it mirrors ``GraphBuilder`` node-for-node and emits the exact byte format
of ``ir::to_json`` — fixed key order, one node per line, integral numbers
— so the committed files diff cleanly and the guard test in
``rust/tests/ir.rs`` can assert byte-equality between the two writers for
every zoo network.

Why a Python generator at all: the zoo graphs live in Rust, but the
catalog also carries networks the zoo does *not* build (MobileNetV2-0.5x
below), and those need a reproducible, reviewable source rather than a
hand-typed JSON blob. Regenerate with:

    python3 python/gen_networks.py

which rewrites every file under ``networks/``. The Rust loader
(``repro net <file>``, ``repro sweep --net-file``) validates each one —
CI runs that over the whole directory.
"""

from __future__ import annotations

import os

SCHEMA_FORMAT = "repro-net"
SCHEMA_VERSION = 1


def window_out(in_size: int, k: int, stride: int, pad: int) -> int:
    """Windowed-op output size; integer division exactly as the Rust IR."""
    return (in_size + 2 * pad - k) // stride + 1


class GraphBuilder:
    """Line-for-line mirror of ``rust/src/ir/mod.rs``'s ``GraphBuilder``.

    Nodes are stored as ``(name, block, op, inputs, fields)`` where
    ``fields`` is the ordered list of op-specific (key, value) pairs in
    the exact order ``ir::to_json`` writes them.
    """

    def __init__(self, name: str, input_size: int, input_ch: int) -> None:
        self.name = name
        self.input_size = input_size
        self.input_ch = input_ch
        self.nodes: list[tuple[str, str, str, list[int], list[tuple[str, int]]]] = []
        self.shapes: list[tuple[int, int]] = []  # (size, ch) per node
        self._block = ""
        self.cur: int | None = None

    def block(self, name: str) -> None:
        self._block = name

    def cursor(self) -> int | None:
        return self.cur

    def set_cursor(self, at: int | None) -> None:
        self.cur = at

    def _shape_at(self, at: int | None) -> tuple[int, int]:
        if at is None:
            return (self.input_size, self.input_ch)
        return self.shapes[at]

    def cur_ch(self) -> int:
        return self._shape_at(self.cur)[1]

    def cur_size(self) -> int:
        return self._shape_at(self.cur)[0]

    def _push(
        self,
        op: str,
        fields: list[tuple[str, int]],
        inputs: list[int],
        out: tuple[int, int],
    ) -> int:
        idx = len(self.nodes)
        self.nodes.append((f"{self._block}_{idx}", self._block, op, inputs, fields))
        self.shapes.append(out)
        self.cur = idx
        return idx

    def _push_linear(self, op: str, fields: list[tuple[str, int]], out: tuple[int, int]) -> int:
        inputs = [] if self.cur is None else [self.cur]
        return self._push(op, fields, inputs, out)

    def conv(self, out_ch: int, k: int, stride: int, pad: int) -> int:
        size = window_out(self.cur_size(), k, stride, pad)
        fields = [("out_ch", out_ch), ("k", k), ("stride", stride), ("pad", pad)]
        return self._push_linear("conv", fields, (size, out_ch))

    def dwconv(self, k: int, stride: int, pad: int) -> int:
        size, ch = self._shape_at(self.cur)
        fields = [("k", k), ("stride", stride), ("pad", pad)]
        return self._push_linear("dwconv", fields, (window_out(size, k, stride, pad), ch))

    def pwconv(self, out_ch: int) -> int:
        return self.gpwconv(out_ch, 1)

    def gpwconv(self, out_ch: int, groups: int) -> int:
        size = self.cur_size()
        return self._push_linear("pwconv", [("out_ch", out_ch), ("groups", groups)], (size, out_ch))

    def maxpool(self, k: int, stride: int, pad: int) -> int:
        size, ch = self._shape_at(self.cur)
        fields = [("k", k), ("stride", stride), ("pad", pad)]
        return self._push_linear("maxpool", fields, (window_out(size, k, stride, pad), ch))

    def avgpool(self, k: int, stride: int, pad: int) -> int:
        size, ch = self._shape_at(self.cur)
        fields = [("k", k), ("stride", stride), ("pad", pad)]
        return self._push_linear("avgpool", fields, (window_out(size, k, stride, pad), ch))

    def global_avgpool(self) -> int:
        return self._push_linear("global_avgpool", [], (1, self.cur_ch()))

    def fc(self, out_ch: int) -> int:
        return self._push_linear("fc", [("out_ch", out_ch)], (1, out_ch))

    def shuffle(self) -> int:
        return self._push_linear("shuffle", [], self._shape_at(self.cur))

    def split(self, keep: int) -> int:
        return self._push_linear("split", [("keep", keep)], (self.cur_size(), keep))

    def add_from(self, shortcut: int) -> int:
        through = self.cur
        assert through is not None, "add_from needs a through branch at the cursor"
        return self._push("add", [], [through, shortcut], self.shapes[through])

    def concat_from(self, shortcut: int) -> int:
        through = self.cur
        assert through is not None, "concat_from needs a through branch at the cursor"
        t_size, t_ch = self.shapes[through]
        s_ch = self.shapes[shortcut][1]
        return self._push("concat", [], [through, shortcut], (t_size, t_ch + s_ch))

    def to_json(self) -> str:
        """The exact byte format of ``ir::to_json`` (guard-tested)."""
        out = ["{"]
        out.append(f'  "format": "{SCHEMA_FORMAT}",')
        out.append(f'  "version": {SCHEMA_VERSION},')
        out.append(f'  "name": "{self.name}",')
        out.append(f'  "input": {{"size": {self.input_size}, "channels": {self.input_ch}}},')
        out.append('  "nodes": [')
        for i, (name, block, op, inputs, fields) in enumerate(self.nodes):
            joined = ", ".join(str(j) for j in inputs)
            line = f'    {{"name": "{name}", "block": "{block}", "op": "{op}", "inputs": [{joined}]'
            for key, val in fields:
                line += f', "{key}": {val}'
            line += "}"
            if i + 1 < len(self.nodes):
                line += ","
            out.append(line)
        out.append("  ]")
        out.append("}")
        return "\n".join(out) + "\n"


# --- Zoo graphs: transliterations of rust/src/nets/*.rs ----------------------


def mobilenet_v1() -> GraphBuilder:
    b = GraphBuilder("mobilenet_v1", 224, 3)
    b.block("stem")
    b.conv(32, 3, 2, 1)
    pairs = [
        (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2), (512, 1),
        (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
    ]
    for i, (out, s) in enumerate(pairs):
        b.block(f"dsc{i + 1}")
        b.dwconv(3, s, 1)
        b.pwconv(out)
    b.block("head")
    b.global_avgpool()
    b.fc(1000)
    return b


#: Inverted-residual settings (t, c, n, s) from Table 2 of the MobileNetV2
#: paper; ``c`` is scaled by the width multiplier below.
BOTTLENECKS = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def make_divisible(v: float, divisor: int = 8) -> int:
    """torchvision's ``_make_divisible``: round channels to the divisor,
    never dropping more than 10% below the unrounded value."""
    new_v = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


def mobilenet_v2(name: str = "mobilenet_v2", width: float = 1.0) -> GraphBuilder:
    """MobileNetV2 at a width multiplier. ``width=1.0`` reproduces the zoo
    graph byte-for-byte (``make_divisible`` is the identity on the stock
    channel counts); ``width=0.5`` is the catalog's non-zoo LWCNN."""
    b = GraphBuilder(name, 224, 3)
    b.block("stem")
    b.conv(make_divisible(32 * width), 3, 2, 1)
    stage = 0
    for t, c, n, s in BOTTLENECKS:
        stage += 1
        c = make_divisible(c * width)
        for rep in range(n):
            b.block(f"bneck{stage}_{rep + 1}")
            stride = s if rep == 0 else 1
            in_ch = b.cur_ch()
            residual = stride == 1 and in_ch == c
            unit_input = b.cursor()
            if t != 1:
                b.pwconv(in_ch * t)
            b.dwconv(3, stride, 1)
            b.pwconv(c)
            if residual:
                b.add_from(unit_input)
    b.block("head")
    b.pwconv(make_divisible(1280 * max(1.0, width)))
    b.global_avgpool()
    b.fc(1000)
    return b


def shufflenet_v1() -> GraphBuilder:
    groups = 3
    stages = [(240, 4), (480, 8), (960, 4)]
    b = GraphBuilder("shufflenet_v1", 224, 3)
    b.block("stem")
    b.conv(24, 3, 2, 1)
    b.maxpool(3, 2, 1)
    for stage_idx, (out_ch, repeats) in enumerate(stages):
        stage = stage_idx + 2
        for rep in range(repeats):
            b.block(f"stage{stage}_{rep + 1}")
            in_ch = b.cur_ch()
            mid = out_ch // 4
            unit_input = b.cursor()
            if rep == 0:
                g1 = 1 if stage == 2 else groups
                b.gpwconv(mid, g1)
                b.shuffle()
                b.dwconv(3, 2, 1)
                main_out = b.gpwconv(out_ch - in_ch, groups)
                b.set_cursor(unit_input)
                b.avgpool(3, 2, 1)
                b.concat_from(main_out)
            else:
                b.gpwconv(mid, groups)
                b.shuffle()
                b.dwconv(3, 1, 1)
                b.gpwconv(out_ch, groups)
                b.add_from(unit_input)
    b.block("head")
    b.global_avgpool()
    b.fc(1000)
    return b


def shufflenet_v2() -> GraphBuilder:
    stages = [(116, 4), (232, 8), (464, 4)]
    b = GraphBuilder("shufflenet_v2", 224, 3)
    b.block("stem")
    b.conv(24, 3, 2, 1)
    b.maxpool(3, 2, 1)
    for stage_idx, (out_ch, repeats) in enumerate(stages):
        stage = stage_idx + 2
        half = out_ch // 2
        for rep in range(repeats):
            b.block(f"stage{stage}_{rep + 1}")
            if rep == 0:
                unit_input = b.cursor()
                b.dwconv(3, 2, 1)
                a_out = b.pwconv(half)
                b.set_cursor(unit_input)
                b.pwconv(half)
                b.dwconv(3, 2, 1)
                b.pwconv(half)
                b.concat_from(a_out)
                b.shuffle()
            else:
                split = b.split(half)
                b.pwconv(half)
                b.dwconv(3, 1, 1)
                b.pwconv(half)
                b.concat_from(split)
                b.shuffle()
    b.block("head")
    b.pwconv(1024)
    b.global_avgpool()
    b.fc(1000)
    return b


def catalog() -> list[GraphBuilder]:
    return [
        mobilenet_v1(),
        mobilenet_v2(),
        shufflenet_v1(),
        shufflenet_v2(),
        # The non-zoo member: MobileNetV2 at a 0.5x width multiplier
        # (channels 8/16/16/32/48/80/160, stem 16, head 1280) — exercises
        # the --net-file path end-to-end without a Rust builder.
        mobilenet_v2("mobilenet_v2_050", 0.5),
    ]


def main() -> None:
    out_dir = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "networks"))
    os.makedirs(out_dir, exist_ok=True)
    for g in catalog():
        path = os.path.join(out_dir, f"{g.name}.json")
        with open(path, "w", encoding="ascii", newline="\n") as f:
            f.write(g.to_json())
        print(f"wrote {path} ({len(g.nodes)} nodes)")


if __name__ == "__main__":
    main()
