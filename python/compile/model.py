"""Layer-2 JAX models: MobileNetV2 and ShuffleNetV2 as *stage graphs*.

A stage == one network block == one AOT-compiled HLO artifact == one CE
group of the Rust streaming coordinator. Each stage is a pure function
``(params, x) -> y`` over ``(H, W, C)`` activations, built from the
Layer-1 Pallas kernels (PWC/DWC/STC/SCB-add); pooling and channel
plumbing are plain jnp.

The FRCE/WRCE assignment of a stage decides two things downstream:

* ``aot.py`` closes FRCE stages over their weights (HLO constants — the
  on-chip weight ROM) and leaves WRCE weights as runtime parameters (the
  coordinator streams them from "DRAM" each frame);
* the PWC kernels inside the stage use the matching Pallas reuse schedule
  (``reuse="fm"`` for FRCE, ``reuse="weight"`` for WRCE).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import quant
from .kernels import conv, ref

#: Static activation-quantization scales (fold into the HLO): ReLU6
#: activations live in [0, 6]; linear-bottleneck outputs are clipped to ~8.
ACT_SCALE = 6.0 / 127.0
LIN_SCALE = 8.0 / 127.0


def aq(x: jnp.ndarray) -> jnp.ndarray:
    """ReLU6 + activation fake-quant (the paper's 8-bit activations)."""
    return quant.fake_quant(ref.relu6(x), ACT_SCALE)


def lq(x: jnp.ndarray) -> jnp.ndarray:
    """Linear-output fake-quant."""
    return quant.fake_quant(x, LIN_SCALE)


@dataclasses.dataclass
class Stage:
    """One compiled unit of the streaming pipeline."""

    name: str
    fn: Callable  # (params: dict[str, jnp.ndarray], x) -> y
    param_shapes: dict  # name -> tuple
    in_shape: tuple  # (H, W, C)
    out_shape: tuple
    #: 8-bit weight bytes (the paper's memory unit) — drives the
    #: FRCE/WRCE stage split in aot.py.
    weight_bytes: int
    #: Output feature-map bytes at 8-bit.
    fm_bytes: int


def _shapes_bytes(shapes: dict) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for s in shapes.values())


def init_params(shapes: dict, key: jax.Array, fan_scale: float = 1.0) -> dict:
    """Deterministic fake-quantized weight init (shared with the golden)."""
    params = {}
    for i, (name, shape) in enumerate(sorted(shapes.items())):
        k = jax.random.fold_in(key, i)
        fan_in = max(1, int(jnp.prod(jnp.array(shape[:-1]))))
        w = jax.random.normal(k, shape, jnp.float32) * (fan_scale / jnp.sqrt(fan_in))
        params[name] = quant.quantize_static(w)
    return params


# ---------------------------------------------------------------------------
# MobileNetV2
# ---------------------------------------------------------------------------

MBV2_BOTTLENECKS = [
    # (expansion t, out channels c, repeats n, stride s)
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def _bottleneck_fn(t: int, c_out: int, stride: int, c_in: int, reuse: str):
    residual = stride == 1 and c_in == c_out

    def fn(params, x):
        h = x
        if t != 1:
            h = aq(conv.pwc(h, params["expand"], reuse=reuse))
        h = aq(conv.dwc(h, params["dw"], stride=stride, pad=1))
        h = lq(conv.pwc(h, params["project"], reuse=reuse))
        if residual:
            h = lq(conv.scb_add(h, x))
        return h

    shapes = {}
    mid = c_in * t
    if t != 1:
        shapes["expand"] = (c_in, mid)
    shapes["dw"] = (3, 3, mid)
    shapes["project"] = (mid, c_out)
    return fn, shapes


def mobilenet_v2_stages(input_size: int = 224, reuse_for: Callable[[int], str] = lambda i: "weight") -> list:
    """Build MobileNetV2 as a stage list. ``reuse_for(stage_idx)`` picks the
    Pallas PWC reuse schedule per stage (FRCE stages pass ``"fm"``)."""
    stages = []
    size = input_size // 2
    c_in = 32

    def stem_fn(params, x):
        return aq(conv.stc(x, params["w"], stride=2, pad=1))

    stages.append(
        Stage(
            "stem",
            stem_fn,
            {"w": (3, 3, 3, 32)},
            (input_size, input_size, 3),
            (size, size, 32),
            weight_bytes=3 * 3 * 3 * 32,
            fm_bytes=size * size * 32,
        )
    )

    idx = 0
    for t, c, n, s in MBV2_BOTTLENECKS:
        for rep in range(n):
            idx += 1
            stride = s if rep == 0 else 1
            fn, shapes = _bottleneck_fn(t, c, stride, c_in, reuse_for(idx))
            in_shape = (size, size, c_in)
            size = size // stride
            stages.append(
                Stage(
                    f"bneck{idx:02d}",
                    fn,
                    shapes,
                    in_shape,
                    (size, size, c),
                    weight_bytes=_shapes_bytes(shapes),
                    fm_bytes=size * size * c,
                )
            )
            c_in = c

    def head_fn(params, x):
        h = aq(conv.pwc(x, params["head"], reuse="weight"))
        h = ref.avgpool_global(h)
        return conv.pwc(h, params["fc"], reuse="weight")

    stages.append(
        Stage(
            "head",
            head_fn,
            {"head": (320, 1280), "fc": (1280, 1000)},
            (size, size, 320),
            (1, 1, 1000),
            weight_bytes=320 * 1280 + 1280 * 1000,
            fm_bytes=1000,
        )
    )
    return stages


# ---------------------------------------------------------------------------
# ShuffleNetV2 (1.0x)
# ---------------------------------------------------------------------------

SNV2_STAGES = [(116, 4), (232, 8), (464, 4)]


def _snv2_unit_fn(c_out: int, stride: int, c_in: int, reuse: str):
    half = c_out // 2

    def fn(params, x):
        if stride == 1:
            left, right = x[:, :, :half], x[:, :, half:]
            r = aq(conv.pwc(right, params["pw1"], reuse=reuse))
            r = conv.dwc(r, params["dw"], stride=1, pad=1)
            r = aq(conv.pwc(r, params["pw2"], reuse=reuse))
            out = jnp.concatenate([left, r], axis=2)
        else:
            l = conv.dwc(x, params["ldw"], stride=2, pad=1)
            l = aq(conv.pwc(l, params["lpw"], reuse=reuse))
            r = aq(conv.pwc(x, params["pw1"], reuse=reuse))
            r = conv.dwc(r, params["dw"], stride=2, pad=1)
            r = aq(conv.pwc(r, params["pw2"], reuse=reuse))
            out = jnp.concatenate([l, r], axis=2)
        return ref.channel_shuffle(out, 2)

    if stride == 1:
        shapes = {"pw1": (half, half), "dw": (3, 3, half), "pw2": (half, half)}
    else:
        shapes = {
            "ldw": (3, 3, c_in),
            "lpw": (c_in, half),
            "pw1": (c_in, half),
            "dw": (3, 3, half),
            "pw2": (half, half),
        }
    return fn, shapes


def shufflenet_v2_stages(input_size: int = 224, reuse_for: Callable[[int], str] = lambda i: "weight") -> list:
    stages = []
    size = input_size // 2

    def stem_fn(params, x):
        h = aq(conv.stc(x, params["w"], stride=2, pad=1))
        return ref.maxpool(h, 3, 2, 1)

    stages.append(
        Stage(
            "stem",
            stem_fn,
            {"w": (3, 3, 3, 24)},
            (input_size, input_size, 3),
            (size // 2, size // 2, 24),
            weight_bytes=3 * 3 * 3 * 24,
            fm_bytes=(size // 2) ** 2 * 24,
        )
    )
    size //= 2
    c_in = 24

    idx = 0
    for c_out, repeats in SNV2_STAGES:
        for rep in range(repeats):
            idx += 1
            stride = 2 if rep == 0 else 1
            fn, shapes = _snv2_unit_fn(c_out, stride, c_in, reuse_for(idx))
            in_shape = (size, size, c_in)
            size = size // stride
            stages.append(
                Stage(
                    f"unit{idx:02d}",
                    fn,
                    shapes,
                    in_shape,
                    (size, size, c_out),
                    weight_bytes=_shapes_bytes(shapes),
                    fm_bytes=size * size * c_out,
                )
            )
            c_in = c_out

    def head_fn(params, x):
        h = aq(conv.pwc(x, params["head"], reuse="weight"))
        h = ref.avgpool_global(h)
        return conv.pwc(h, params["fc"], reuse="weight")

    stages.append(
        Stage(
            "head",
            head_fn,
            {"head": (464, 1024), "fc": (1024, 1000)},
            (size, size, 464),
            (1, 1, 1000),
            weight_bytes=464 * 1024 + 1024 * 1000,
            fm_bytes=1000,
        )
    )
    return stages


NETWORKS = {
    "mobilenet_v2": mobilenet_v2_stages,
    "shufflenet_v2": shufflenet_v2_stages,
}


def run_reference(stages: list, params_per_stage: list, x: jnp.ndarray) -> tuple:
    """Run the whole stage graph in python (the golden path). Returns the
    final logits and per-stage output checksums for debugging."""
    sums = []
    for stage, params in zip(stages, params_per_stage):
        x = stage.fn(params, x)
        sums.append((float(jnp.mean(x)), float(jnp.std(x))))
    return x, sums
