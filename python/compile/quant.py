"""Fake-quantization substrate (S12 in DESIGN.md).

The paper quantizes both weights and activations to 8 bits (<1% accuracy
loss, methodologies of [37]/[38]). For this reproduction quantization
matters as (a) the byte-per-element unit of the memory/bandwidth models and
(b) a numerics regime the kernels must survive; post-training-quantization
accuracy itself is out of scope. We therefore use symmetric per-tensor
int8 *fake* quantization: values are rounded to an int8 grid but kept in
f32 so the same HLO runs on any PJRT backend.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Quantized activations/weights occupy one byte.
BYTES_PER_ELEMENT = 1

#: int8 symmetric range.
QMAX = 127.0


def scale_for(x: jnp.ndarray) -> jnp.ndarray:
    """Symmetric per-tensor scale: max|x| maps to 127."""
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / QMAX


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray | float) -> jnp.ndarray:
    """Round to the int8 grid defined by ``scale`` and clamp (kept in f32)."""
    q = jnp.clip(jnp.round(x / scale), -QMAX - 1, QMAX)
    return q * scale


def quantize_static(x: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize with the tensor's own (traced) scale — used for weight
    constants at model-build time, where the scale folds into the HLO."""
    return fake_quant(x, scale_for(x))
