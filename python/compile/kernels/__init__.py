"""Layer-1 Pallas kernels and their pure-jnp oracle."""

from . import conv, ref  # noqa: F401
