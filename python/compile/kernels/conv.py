"""Layer-1 Pallas kernels — the accelerator's compute hot-spots.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper's FPGA PE
array broadcasts FM pixels and kernel weights into a ``P_f x P_w`` MAC
grid fed from BRAM line buffers. On TPU the transferable insight is the
*reuse schedule*, not the broadcast wiring:

* :func:`pwc` is the MAC-dominant kernel. Its BlockSpec grid realizes the
  two data-reuse schemes of §III-B as two grid orders of one kernel:
  ``reuse="weight"`` (WRCE flavour) keeps the FM block resident in VMEM
  and marches over weight tiles — each weight tile is read once, exactly
  the fully-reused-weight scheme; ``reuse="fm"`` (FRCE flavour) keeps the
  weight matrix resident and marches over FM-position tiles — the
  fully-reused-FM scheme.
* :func:`dwc` has no cross-channel reduction (the paper's motivation for
  skipping DSP decomposition in DWC layers); it is laid out as a VPU
  stencil over a ``(rows, C)`` block rather than an MXU matmul.
* :func:`stc` lowers the KxK standard convolution to K^2 accumulated MXU
  matmuls — the same "window fully integrated into the output pixel"
  schedule as the fully-reused FM scheme of Fig 5.
* Padding is materialized by index arithmetic *inside* the kernels (zero
  rows never occupy VMEM) — the TPU analogue of the paper's
  address-generated padding (§IV-B).

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness (and
AOT-lowering) path; real-TPU efficiency is estimated from the BlockSpecs
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _largest_tile(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (tile sizes must divide the
    dimension so the BlockSpec grid covers it exactly)."""
    t = min(n, cap)
    while n % t:
        t -= 1
    return t


# --------------------------------------------------------------------------
# PWC — pointwise convolution as a tiled MXU matmul
# --------------------------------------------------------------------------


def _pwc_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def pwc(x: jnp.ndarray, w: jnp.ndarray, *, reuse: str = "weight", tile: int = 128) -> jnp.ndarray:
    """Pointwise convolution ``(H, W, M) x (M, N) -> (H, W, N)``.

    ``reuse="weight"``: grid over N-tiles, FM block stays in VMEM (WRCE).
    ``reuse="fm"``: grid over position-tiles, weights stay in VMEM (FRCE).
    """
    h, wd, m = x.shape
    m2, n = w.shape
    assert m == m2, (x.shape, w.shape)
    f2 = h * wd
    xf = x.reshape(f2, m)
    if reuse == "weight":
        tn = _largest_tile(n, tile)
        grid = (n // tn,)
        out = pl.pallas_call(
            _pwc_kernel,
            out_shape=jax.ShapeDtypeStruct((f2, n), jnp.float32),
            grid=grid,
            in_specs=[
                pl.BlockSpec((f2, m), lambda i: (0, 0)),
                pl.BlockSpec((m, tn), lambda i: (0, i)),
            ],
            out_specs=pl.BlockSpec((f2, tn), lambda i: (0, i)),
            interpret=INTERPRET,
        )(xf, w)
    elif reuse == "fm":
        tf = _largest_tile(f2, tile)
        grid = (f2 // tf,)
        out = pl.pallas_call(
            _pwc_kernel,
            out_shape=jax.ShapeDtypeStruct((f2, n), jnp.float32),
            grid=grid,
            in_specs=[
                pl.BlockSpec((tf, m), lambda i: (i, 0)),
                pl.BlockSpec((m, n), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((tf, n), lambda i: (i, 0)),
            interpret=INTERPRET,
        )(xf, w)
    else:
        raise ValueError(f"unknown reuse scheme {reuse!r}")
    return out.reshape(h, wd, n)


def grouped_pwc(x: jnp.ndarray, w: jnp.ndarray, groups: int) -> jnp.ndarray:
    """Grouped 1x1 convolution: ``(H, W, M) x (g, M/g, N/g)``; the grid
    iterates groups, giving each group's weight slice one VMEM residence."""
    h, wd, m = x.shape
    g, mg, ng = w.shape
    assert g == groups and g * mg == m

    def kernel(x_ref, w_ref, o_ref):
        o_ref[...] = jnp.dot(x_ref[...], w_ref[0], preferred_element_type=jnp.float32)

    f2 = h * wd
    xg = x.reshape(f2, g, mg).transpose(1, 0, 2)  # (g, F2, M/g)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((g, f2, ng), jnp.float32),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, f2, mg), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, mg, ng), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, f2, ng), lambda i: (i, 0, 0)),
        interpret=INTERPRET,
    )(xg, w)
    return out.transpose(1, 0, 2).reshape(h, wd, g * ng)


# --------------------------------------------------------------------------
# DWC — depthwise stencil on the VPU
# --------------------------------------------------------------------------


def dwc(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, pad: int = 1, row_tiles: int = 4) -> jnp.ndarray:
    """Depthwise KxK convolution ``(H, W, C) x (K, K, C)``.

    The grid tiles output rows; each step holds a ``(K-1+rows*s, W, C)``
    input band in VMEM — the VMEM twin of the FRCE line buffer (the band is
    exactly the live pixel set of Fig 5). Padding rows/cols are composed by
    index clamping + masking, never stored.
    """
    h, wd, c = x.shape
    k = w.shape[0]
    oh = (h + 2 * pad - k) // stride + 1
    ow = (wd + 2 * pad - k) // stride + 1
    rt = _largest_tile(oh, max(1, oh // row_tiles))

    def kernel(x_ref, w_ref, o_ref):
        band = x_ref[...]  # full input (interpret mode keeps this cheap)
        tile_idx = pl.program_id(0)
        r0 = tile_idx * rt
        acc = jnp.zeros((rt, ow, c), jnp.float32)
        for dy in range(k):
            for dx in range(k):
                # Input rows for output rows r0..r0+rt-1 at kernel tap dy:
                # r_in = r*stride + dy - pad.
                rows = (r0 + jax.lax.iota(jnp.int32, rt)) * stride + dy - pad
                cols = jax.lax.iota(jnp.int32, ow) * stride + dx - pad
                rvalid = (rows >= 0) & (rows < h)
                cvalid = (cols >= 0) & (cols < wd)
                ridx = jnp.clip(rows, 0, h - 1)
                cidx = jnp.clip(cols, 0, wd - 1)
                patch = band[ridx][:, cidx]  # (rt, ow, c)
                mask = rvalid[:, None, None] & cvalid[None, :, None]
                acc = acc + jnp.where(mask, patch, 0.0) * w_ref[dy, dx]
        o_ref[...] = acc

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, c), jnp.float32),
        grid=(oh // rt,),
        in_specs=[
            pl.BlockSpec((h, wd, c), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, k, c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, ow, c), lambda i: (i, 0, 0)),
        interpret=INTERPRET,
    )(x, w)


# --------------------------------------------------------------------------
# STC — standard convolution as K^2 accumulated matmuls
# --------------------------------------------------------------------------


def stc(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1, pad: int = 1, tile_n: int = 128) -> jnp.ndarray:
    """Standard KxK convolution ``(H, W, M) x (K, K, M, N)``: for each
    kernel tap, gather the strided input plane and accumulate an MXU
    matmul over channels — the whole reduction stays in VMEM."""
    h, wd, m = x.shape
    k = w.shape[0]
    n = w.shape[3]
    oh = (h + 2 * pad - k) // stride + 1
    ow = (wd + 2 * pad - k) // stride + 1
    tn = _largest_tile(n, tile_n)

    def kernel(x_ref, w_ref, o_ref):
        acc = jnp.zeros((oh * ow, tn), jnp.float32)
        band = x_ref[...]
        for dy in range(k):
            for dx in range(k):
                rows = jax.lax.iota(jnp.int32, oh) * stride + dy - pad
                cols = jax.lax.iota(jnp.int32, ow) * stride + dx - pad
                rvalid = (rows >= 0) & (rows < h)
                cvalid = (cols >= 0) & (cols < wd)
                patch = band[jnp.clip(rows, 0, h - 1)][:, jnp.clip(cols, 0, wd - 1)]
                mask = rvalid[:, None, None] & cvalid[None, :, None]
                plane = jnp.where(mask, patch, 0.0).reshape(oh * ow, m)
                acc = acc + jnp.dot(plane, w_ref[dy, dx], preferred_element_type=jnp.float32)
        o_ref[...] = acc.reshape(oh, ow, tn)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((oh, ow, n), jnp.float32),
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((h, wd, m), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, k, m, tn), lambda i: (0, 0, 0, i)),
        ],
        out_specs=pl.BlockSpec((oh, ow, tn), lambda i: (0, 0, i)),
        interpret=INTERPRET,
    )(x, w)


# --------------------------------------------------------------------------
# SCB add — the shortcut join
# --------------------------------------------------------------------------


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def scb_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Element-wise SCB addition ``(H, W, C) + (H, W, C)``."""
    assert a.shape == b.shape
    h, w, c = a.shape
    rt = _largest_tile(h, max(1, h // 4))
    return pl.pallas_call(
        _add_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        grid=(h // rt,),
        in_specs=[
            pl.BlockSpec((rt, w, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((rt, w, c), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, w, c), lambda i: (i, 0, 0)),
        interpret=INTERPRET,
    )(a, b)


@functools.lru_cache(maxsize=None)
def pwc_vmem_bytes(f2: int, m: int, n: int, tile: int = 128, reuse: str = "weight") -> dict:
    """Static per-grid-step VMEM footprint of :func:`pwc` (f32 bytes).

    Used by EXPERIMENTS.md §Perf to check each layer shape against the
    ~16 MiB VMEM budget and to estimate MXU occupancy
    (``macs_per_step / (128*128 * ideal_cycles)``).
    """
    if reuse == "weight":
        tn = _largest_tile(n, tile)
        blocks = {"fm_block": f2 * m * 4, "weight_tile": m * tn * 4, "out_tile": f2 * tn * 4}
    else:
        tf = _largest_tile(f2, tile)
        blocks = {"fm_block": tf * m * 4, "weight_tile": m * n * 4, "out_tile": tf * n * 4}
    blocks["total"] = sum(blocks.values())
    return blocks
