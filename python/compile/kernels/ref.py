"""Pure-jnp oracle for the Pallas kernels (the CORE correctness signal).

Every kernel in :mod:`conv` must match these references to float tolerance
under ``pytest python/tests``; the AOT model is additionally cross-checked
against a composition of these references.

Layouts (chosen to mirror the accelerator's dataflow):
  * activations: ``(H, W, C)`` — channel-last, matching the channel-first
    pixel-vector stream of the FRCEs (a "pixel" is one ``(h, w)`` position's
    C-vector).
  * PWC weights: ``(M, N)``; DWC weights: ``(K, K, C)``; STC weights:
    ``(K, K, M, N)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pwc(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Pointwise (1x1) convolution: ``(H, W, M) x (M, N) -> (H, W, N)``."""
    h, wd, m = x.shape
    assert w.shape[0] == m, (x.shape, w.shape)
    return (x.reshape(h * wd, m) @ w).reshape(h, wd, w.shape[1])


def grouped_pwc(x: jnp.ndarray, w: jnp.ndarray, groups: int) -> jnp.ndarray:
    """Grouped 1x1 convolution (ShuffleNetV1): ``w`` is ``(g, M/g, N/g)``."""
    h, wd, m = x.shape
    g, mg, ng = w.shape
    assert groups == g and mg * g == m
    xg = x.reshape(h * wd, g, mg)
    out = jnp.einsum("pgm,gmn->pgn", xg, w)
    return out.reshape(h, wd, g * ng)


def dwc(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 1) -> jnp.ndarray:
    """Depthwise KxK convolution: ``(H, W, C) x (K, K, C)``."""
    c = x.shape[2]
    lhs = x[None].transpose(0, 3, 1, 2)  # NCHW
    rhs = w.transpose(2, 0, 1)[:, None]  # (C, 1, K, K) == OIHW with I=1
    out = jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        feature_group_count=c,
    )
    return out[0].transpose(1, 2, 0)


def stc(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 1) -> jnp.ndarray:
    """Standard KxK convolution: ``(H, W, M) x (K, K, M, N)``."""
    lhs = x[None].transpose(0, 3, 1, 2)  # NCHW
    rhs = w.transpose(3, 2, 0, 1)  # OIHW
    out = jax.lax.conv_general_dilated(
        lhs,
        rhs,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
    )
    return out[0].transpose(1, 2, 0)


def scb_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Element-wise shortcut addition."""
    return a + b


def relu6(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(x, 0.0, 6.0)


def avgpool_global(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pool: ``(H, W, C) -> (1, 1, C)``."""
    return jnp.mean(x, axis=(0, 1), keepdims=True)


def maxpool(x: jnp.ndarray, k: int = 3, stride: int = 2, pad: int = 1) -> jnp.ndarray:
    """Max pooling over ``(H, W, C)``."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (k, k, 1),
        (stride, stride, 1),
        [(pad, pad), (pad, pad), (0, 0)],
    )


def avgpool_spatial(x: jnp.ndarray, k: int = 3, stride: int = 2, pad: int = 1) -> jnp.ndarray:
    """Average pooling with a KxK window (ShuffleNetV1 shortcut branch)."""
    summed = jax.lax.reduce_window(
        x,
        0.0,
        jax.lax.add,
        (k, k, 1),
        (stride, stride, 1),
        [(pad, pad), (pad, pad), (0, 0)],
    )
    counts = jax.lax.reduce_window(
        jnp.ones_like(x),
        0.0,
        jax.lax.add,
        (k, k, 1),
        (stride, stride, 1),
        [(pad, pad), (pad, pad), (0, 0)],
    )
    return summed / counts


def channel_shuffle(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """ShuffleNet channel shuffle: ``(H, W, g*n) -> interleave groups``."""
    h, w, c = x.shape
    return x.reshape(h, w, groups, c // groups).transpose(0, 1, 3, 2).reshape(h, w, c)
