"""AOT compiler: stage graphs -> HLO-text artifacts + weights + goldens.

Emits, per network, into ``artifacts/``:

* ``<net>_stageNN_<name>.hlo.txt`` — one HLO module per stage. FRCE stages
  close over their fake-quantized weights (HLO constants == the on-chip
  weight ROM of §III-B); WRCE stages take weights as leading parameters,
  streamed from "DRAM" by the Rust coordinator on every frame (the fully
  reused weight scheme: each weight is read from host memory exactly once
  per frame).
* ``<net>_weights.bin`` — flat little-endian f32 blob of all WRCE weights.
* ``<net>_input.bin`` / ``<net>_logits.bin`` — golden input and reference
  logits for end-to-end verification in Rust.
* ``<net>_manifest.json`` — the stage plan (shapes, CE kinds, weight
  offsets, per-stage output checksums).

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

The FRCE/WRCE split follows a block-granular analogue of Algorithm 1: a
stage stays FRCE while its weights are no larger than its output FM (the
shallow-layer distribution criterion of §II-B); ``--boundary`` overrides.
The rust-side layer-granular Algorithm 1 is cross-checked against this
split in rust/tests/integration.rs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, quant

WEIGHT_SEED = 42
INPUT_SEED = 7


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: FRCE weight ROMs are baked as HLO constants;
    # the default printer elides them as '{...}' which the text parser
    # round-trips as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def default_boundary(stages) -> int:
    """First stage index whose weights outgrow its output FM (all stages
    from here on are WRCEs). The head (pooling + FC) is always WRCE."""
    for i, s in enumerate(stages):
        if s.weight_bytes > s.fm_bytes:
            return i
    return len(stages) - 1


def compile_network(net_name: str, out_dir: str, boundary: int | None = None, input_size: int = 224) -> dict:
    # First pass with default reuse to compute the boundary, then rebuild
    # with the per-stage Pallas reuse schedule implied by the CE kinds.
    probe = model.NETWORKS[net_name](input_size)
    b = default_boundary(probe) if boundary is None else boundary
    stages = model.NETWORKS[net_name](input_size, reuse_for=lambda i: "fm" if i < b else "weight")

    key = jax.random.fold_in(jax.random.PRNGKey(WEIGHT_SEED), hash(net_name) % (1 << 16))
    params_per_stage = [
        model.init_params(s.param_shapes, jax.random.fold_in(key, i)) for i, s in enumerate(stages)
    ]

    # Golden reference pass.
    x0 = quant.fake_quant(
        jax.random.uniform(jax.random.PRNGKey(INPUT_SEED), (input_size, input_size, 3), jnp.float32),
        1.0 / 127.0,
    )
    logits, checksums = model.run_reference(stages, params_per_stage, x0)

    short = {"mobilenet_v2": "mbv2", "shufflenet_v2": "snv2"}[net_name]
    manifest = {
        "network": net_name,
        "input_shape": list(x0.shape),
        "boundary": b,
        "weights_file": f"{short}_weights.bin",
        "golden_input": f"{short}_input.bin",
        "golden_logits": f"{short}_logits.bin",
        "stages": [],
    }

    weight_blob: list[np.ndarray] = []
    offset = 0
    for i, (stage, params) in enumerate(zip(stages, params_per_stage)):
        kind = "frce" if i < b else "wrce"
        hlo_name = f"{short}_stage{i:02d}_{stage.name}.hlo.txt"
        x_spec = jax.ShapeDtypeStruct(stage.in_shape, jnp.float32)
        if kind == "frce":
            fn = stage.fn
            closed = jax.jit(lambda x, fn=fn, p=params: (fn(p, x),))
            hlo = to_hlo_text(closed.lower(x_spec))
            param_entries = []
        else:
            names = sorted(params.keys())
            fn = stage.fn

            def open_fn(*args, fn=fn, names=names):
                p = dict(zip(names, args[:-1]))
                return (fn(p, args[-1]),)

            specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
            hlo = to_hlo_text(jax.jit(open_fn).lower(*specs, x_spec))
            param_entries = []
            for n in names:
                arr = np.asarray(params[n], np.float32)
                param_entries.append(
                    {"name": n, "shape": list(arr.shape), "offset": offset, "len": int(arr.size)}
                )
                weight_blob.append(arr.ravel())
                offset += arr.size
        with open(os.path.join(out_dir, hlo_name), "w") as f:
            f.write(hlo)
        mean, std = checksums[i]
        manifest["stages"].append(
            {
                "name": stage.name,
                "kind": kind,
                "hlo": hlo_name,
                "in_shape": list(stage.in_shape),
                "out_shape": list(stage.out_shape),
                "weight_bytes_8bit": stage.weight_bytes,
                "fm_bytes_8bit": stage.fm_bytes,
                "params": param_entries,
                "mean": mean,
                "std": std,
            }
        )
        print(f"  [{kind}] {hlo_name}: {len(hlo)} chars, {len(param_entries)} streamed params")

    blob = np.concatenate(weight_blob) if weight_blob else np.zeros(0, np.float32)
    blob.astype("<f4").tofile(os.path.join(out_dir, manifest["weights_file"]))
    np.asarray(x0, np.float32).astype("<f4").tofile(os.path.join(out_dir, manifest["golden_input"]))
    np.asarray(logits, np.float32).astype("<f4").tofile(os.path.join(out_dir, manifest["golden_logits"]))
    with open(os.path.join(out_dir, f"{short}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"{net_name}: {len(stages)} stages, boundary={b}, "
        f"{blob.size * 4} weight bytes streamed, logits mean={float(jnp.mean(logits)):.4f}"
    )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--nets", default="mobilenet_v2,shufflenet_v2")
    ap.add_argument("--boundary", type=int, default=None, help="override the FRCE/WRCE stage boundary")
    ap.add_argument("--input-size", type=int, default=224)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for net in args.nets.split(","):
        compile_network(net.strip(), args.out, args.boundary, args.input_size)


if __name__ == "__main__":
    main()
