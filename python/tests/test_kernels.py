"""L1 correctness: every Pallas kernel vs the pure-jnp oracle, including
hypothesis sweeps over shapes (the paper's layer geometries and beyond)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, ref
from compile import quant

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


def assert_close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---- PWC -----------------------------------------------------------------

MBV2_PWC_SHAPES = [  # (H, M, N) drawn from MobileNetV2/ShuffleNetV2 layers
    (56, 24, 144),
    (14, 96, 576),
    (7, 320, 1280),
    (28, 58, 58),
    (7, 464, 1024),
]


@pytest.mark.parametrize("h,m,n", MBV2_PWC_SHAPES)
@pytest.mark.parametrize("reuse", ["weight", "fm"])
def test_pwc_matches_ref(h, m, n, reuse):
    x, w = rand(0, (h, h, m)), rand(1, (m, n), 0.1)
    assert_close(conv.pwc(x, w, reuse=reuse), ref.pwc(x, w))


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 17),
    m=st.integers(1, 40),
    n=st.integers(1, 48),
    reuse=st.sampled_from(["weight", "fm"]),
)
def test_pwc_hypothesis(h, m, n, reuse):
    x, w = rand(2, (h, h, m)), rand(3, (m, n), 0.2)
    assert_close(conv.pwc(x, w, reuse=reuse), ref.pwc(x, w))


def test_pwc_quantized_inputs_exact():
    # Fake-quantized operands stay on the int8 grid; the kernel must be
    # bit-identical to the oracle on them.
    x = quant.fake_quant(rand(4, (14, 14, 32)), 0.05)
    w = quant.fake_quant(rand(5, (32, 64)), 0.01)
    assert_close(conv.pwc(x, w), ref.pwc(x, w), tol=1e-6)


# ---- grouped PWC ----------------------------------------------------------


@pytest.mark.parametrize("g,mg,ng", [(3, 8, 16), (3, 80, 160), (2, 12, 12)])
def test_grouped_pwc_matches_ref(g, mg, ng):
    x = rand(6, (14, 14, g * mg))
    w = rand(7, (g, mg, ng), 0.1)
    assert_close(conv.grouped_pwc(x, w, g), ref.grouped_pwc(x, w, g))


# ---- DWC -----------------------------------------------------------------

DWC_CASES = [  # (H, C, k, stride, pad)
    (112, 32, 3, 1, 1),
    (56, 144, 3, 2, 1),
    (14, 576, 3, 1, 1),
    (7, 960, 3, 1, 1),
    (28, 58, 3, 2, 1),
]


@pytest.mark.parametrize("h,c,k,s,p", DWC_CASES)
def test_dwc_matches_ref(h, c, k, s, p):
    x, w = rand(8, (h, h, c)), rand(9, (k, k, c), 0.3)
    assert_close(conv.dwc(x, w, stride=s, pad=p), ref.dwc(x, w, stride=s, pad=p))


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(3, 15),
    c=st.integers(1, 24),
    s=st.sampled_from([1, 2]),
    p=st.sampled_from([0, 1]),
)
def test_dwc_hypothesis(h, c, s, p):
    k = 3
    if h + 2 * p < k:
        return
    x, w = rand(10, (h, h, c)), rand(11, (k, k, c), 0.3)
    assert_close(conv.dwc(x, w, stride=s, pad=p), ref.dwc(x, w, stride=s, pad=p))


def test_dwc_padding_is_zero_not_garbage():
    # A one-hot corner input exercises every padding branch.
    x = jnp.zeros((5, 5, 2)).at[0, 0, 0].set(1.0)
    w = jnp.ones((3, 3, 2))
    out = conv.dwc(x, w, stride=1, pad=1)
    assert_close(out, ref.dwc(x, w, stride=1, pad=1), tol=1e-6)
    assert float(out[0, 0, 0]) == 1.0 and float(out[4, 4, 0]) == 0.0


# ---- STC -----------------------------------------------------------------


@pytest.mark.parametrize("h,m,n,s", [(224, 3, 32, 2), (32, 8, 16, 1), (11, 5, 7, 2)])
def test_stc_matches_ref(h, m, n, s):
    x, w = rand(12, (h, h, m)), rand(13, (3, 3, m, n), 0.2)
    assert_close(conv.stc(x, w, stride=s, pad=1), ref.stc(x, w, stride=s, pad=1))


@settings(max_examples=15, deadline=None)
@given(h=st.integers(4, 12), m=st.integers(1, 8), n=st.integers(1, 12), s=st.sampled_from([1, 2]))
def test_stc_hypothesis(h, m, n, s):
    x, w = rand(14, (h, h, m)), rand(15, (3, 3, m, n), 0.2)
    assert_close(conv.stc(x, w, stride=s, pad=1), ref.stc(x, w, stride=s, pad=1))


# ---- SCB add ---------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(h=st.integers(1, 16), c=st.integers(1, 32))
def test_scb_add_hypothesis(h, c):
    a, b = rand(16, (h, h, c)), rand(17, (h, h, c))
    assert_close(conv.scb_add(a, b), ref.scb_add(a, b), tol=1e-6)


# ---- quantization substrate -------------------------------------------------


def test_fake_quant_grid():
    x = rand(18, (64,))
    s = quant.scale_for(x)
    q = quant.fake_quant(x, s)
    np.testing.assert_allclose(np.asarray(q / s), np.round(np.asarray(q / s)), atol=1e-4)
    assert np.max(np.abs(np.asarray(q))) <= float(s) * 128 + 1e-6


def test_fake_quant_error_bound():
    x = rand(19, (1000,))
    q = quant.fake_quant(x, quant.scale_for(x))
    assert float(jnp.max(jnp.abs(q - x))) <= float(quant.scale_for(x)) / 2 + 1e-6


# ---- VMEM accounting --------------------------------------------------------


def test_pwc_vmem_within_budget():
    # Every PWC layer shape of MobileNetV2/ShuffleNetV2 must fit the 16 MiB
    # VMEM budget under the default tiling.
    for h, m, n in MBV2_PWC_SHAPES + [(112, 32, 16), (56, 16, 96)]:
        r = conv.pwc_vmem_bytes(h * h, m, n)
        assert r["total"] < 16 * 1024 * 1024, (h, m, n, r)
