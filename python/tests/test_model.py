"""L2 model tests: stage graphs, shapes, quantization behaviour, and the
stage-vs-oracle composition at a reduced input size (fast)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, quant
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SIZE = 32  # reduced spatial size: every stride/pad path still exercised


@pytest.fixture(scope="module", params=["mobilenet_v2", "shufflenet_v2"])
def net(request):
    name = request.param
    stages = model.NETWORKS[name](SIZE)
    key = jax.random.PRNGKey(0)
    params = [model.init_params(s.param_shapes, jax.random.fold_in(key, i)) for i, s in enumerate(stages)]
    return name, stages, params


def test_stage_shapes_chain(net):
    _, stages, params = net
    x = jnp.ones(stages[0].in_shape, jnp.float32) * 0.1
    for s, p in zip(stages, params):
        assert x.shape == s.in_shape, s.name
        x = s.fn(p, x)
        assert x.shape == s.out_shape, s.name


def test_final_logits_shape_and_finite(net):
    _, stages, params = net
    x = quant.fake_quant(jax.random.uniform(jax.random.PRNGKey(3), stages[0].in_shape), 1 / 127.0)
    logits, sums = model.run_reference(stages, params, x)
    assert logits.shape == (1, 1, 1000)
    assert np.isfinite(np.asarray(logits)).all()
    assert len(sums) == len(stages)


def test_weight_bytes_accounting(net):
    _, stages, _ = net
    for s in stages:
        total = sum(int(np.prod(shape)) for shape in s.param_shapes.values())
        assert s.weight_bytes == total, s.name


def test_activations_stay_on_quant_grid(net):
    # Every ReLU6 stage output must be on the ACT_SCALE int8 grid.
    _, stages, params = net
    x = quant.fake_quant(jax.random.uniform(jax.random.PRNGKey(5), stages[0].in_shape), 1 / 127.0)
    h = stages[0].fn(params[0], x)
    g = np.asarray(h) / model.ACT_SCALE
    np.testing.assert_allclose(g, np.round(g), atol=1e-3)
    assert float(h.max()) <= 6.0 + 1e-6 and float(h.min()) >= 0.0


def test_default_boundary_is_distribution_flip(net):
    name, stages, _ = net
    b = aot.default_boundary(stages)
    assert 0 < b < len(stages)
    for s in stages[:b]:
        assert s.weight_bytes <= s.fm_bytes, s.name
    assert stages[b].weight_bytes > stages[b].fm_bytes


def test_reuse_schedule_does_not_change_numerics(net):
    name, _, _ = net
    a = model.NETWORKS[name](SIZE, reuse_for=lambda i: "fm")
    b = model.NETWORKS[name](SIZE, reuse_for=lambda i: "weight")
    key = jax.random.PRNGKey(1)
    pa = [model.init_params(s.param_shapes, jax.random.fold_in(key, i)) for i, s in enumerate(a)]
    x = quant.fake_quant(jax.random.uniform(jax.random.PRNGKey(2), a[0].in_shape), 1 / 127.0)
    ya, _ = model.run_reference(a, pa, x)
    yb, _ = model.run_reference(b, pa, x)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-4, atol=1e-4)


def test_mbv2_stem_matches_oracle():
    stages = model.mobilenet_v2_stages(SIZE)
    p = model.init_params(stages[0].param_shapes, jax.random.PRNGKey(9))
    x = jax.random.normal(jax.random.PRNGKey(10), stages[0].in_shape) * 0.1
    got = stages[0].fn(p, x)
    want = quant.fake_quant(ref.relu6(ref.stc(x, p["w"], stride=2, pad=1)), model.ACT_SCALE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_snv2_unit_channel_bookkeeping():
    stages = model.shufflenet_v2_stages(SIZE)
    # Stage channel widths follow (116, 232, 464) with halving splits.
    widths = [s.out_shape[2] for s in stages]
    assert widths[0] == 24
    assert 116 in widths and 232 in widths and 464 in widths
    assert widths[-1] == 1000


def test_hlo_text_roundtrips_large_constants():
    # Regression for the print_large_constants bug: an FRCE-style closure
    # must keep its weight values in the HLO text.
    stages = model.mobilenet_v2_stages(SIZE)
    p = model.init_params(stages[0].param_shapes, jax.random.PRNGKey(11))
    fn = stages[0].fn
    lowered = jax.jit(lambda x: (fn(p, x),)).lower(
        jax.ShapeDtypeStruct(stages[0].in_shape, jnp.float32)
    )
    txt = aot.to_hlo_text(lowered)
    assert "constant({...}" not in txt and "constant({ ... }" not in txt
    assert "f32[3,3,3,32]" in txt
