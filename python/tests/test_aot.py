"""AOT pipeline tests: compile a reduced-size network end to end into a
temp dir and validate every artifact contract the Rust runtime relies on."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.compile_network("mobilenet_v2", str(out), input_size=32)
    return str(out), manifest


def test_manifest_file_matches_returned(built):
    out, manifest = built
    with open(os.path.join(out, "mbv2_manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest


def test_every_stage_has_hlo_file(built):
    out, manifest = built
    for s in manifest["stages"]:
        path = os.path.join(out, s["hlo"])
        assert os.path.exists(path), s["hlo"]
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # FRCE weights are constants; WRCE weights are parameters.
        nparams = text.count("parameter(")
        if s["kind"] == "frce":
            assert not s["params"]
        else:
            assert len(s["params"]) >= 1
            assert nparams >= len(s["params"]) + 1


def test_weight_blob_offsets_are_dense(built):
    out, manifest = built
    blob = np.fromfile(os.path.join(out, manifest["weights_file"]), dtype="<f4")
    cursor = 0
    for s in manifest["stages"]:
        for p in s["params"]:
            assert p["offset"] == cursor, p
            cursor += p["len"]
            assert int(np.prod(p["shape"])) == p["len"]
    assert cursor == blob.size


def test_weights_are_fake_quantized(built):
    out, manifest = built
    blob = np.fromfile(os.path.join(out, manifest["weights_file"]), dtype="<f4")
    # Per-tensor symmetric int8 grid: values/scale must be near-integers.
    for s in manifest["stages"]:
        for p in s["params"]:
            w = blob[p["offset"] : p["offset"] + p["len"]]
            scale = np.abs(w).max() / 127.0
            if scale == 0:
                continue
            grid = w / scale
            np.testing.assert_allclose(grid, np.round(grid), atol=1e-3)


def test_golden_files_consistent(built):
    out, manifest = built
    x = np.fromfile(os.path.join(out, manifest["golden_input"]), dtype="<f4")
    y = np.fromfile(os.path.join(out, manifest["golden_logits"]), dtype="<f4")
    assert x.size == int(np.prod(manifest["input_shape"]))
    assert y.size == 1000
    assert np.isfinite(x).all() and np.isfinite(y).all()


def test_stage_shapes_chain_in_manifest(built):
    _, manifest = built
    stages = manifest["stages"]
    assert stages[0]["in_shape"] == manifest["input_shape"]
    for a, b in zip(stages, stages[1:]):
        assert a["out_shape"] == b["in_shape"], (a["name"], b["name"])


def test_boundary_override(tmp_path):
    m = aot.compile_network("mobilenet_v2", str(tmp_path), boundary=3, input_size=32)
    kinds = [s["kind"] for s in m["stages"]]
    assert kinds[:3] == ["frce"] * 3
    assert all(k == "wrce" for k in kinds[3:])
