//! Layer-3 streaming coordinator.
//!
//! The Rust twin of the paper's streaming multi-CE architecture at stage
//! granularity: the compiled stages are partitioned into contiguous
//! *CE groups*, each owned by a worker thread with its own PJRT client;
//! frames stream through bounded channels of depth 2 — the software
//! analogue of the ping-pong FM buffers (§III-A) — so all groups compute
//! different frames concurrently and intermediate FMs never touch the
//! "off-chip" side (they move pointer-wise between threads).
//!
//! FRCE-group stages carry their weights inside the executable (on-chip
//! ROM); WRCE-group stages receive weight literals on every execution —
//! the DRAM weight stream, whose per-frame byte count the metrics report
//! against Eq (13).
//!
//! (The `xla` crate's wrapper types are not `Send`, so each worker
//! compiles its own stage range from the artifacts rather than sharing
//! one engine — same artifacts, same numerics.)

use std::path::PathBuf;
use std::sync::{mpsc, Arc, Barrier};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::{Engine, Manifest, StageKind};

/// A frame moving through the pipeline.
struct Frame {
    id: u64,
    data: Vec<f32>,
    /// Wall-clock time the frame entered the pipeline.
    t_in: Instant,
}

/// Per-group execution statistics.
#[derive(Debug, Clone)]
pub struct GroupStats {
    pub stages: (usize, usize),
    /// Total seconds spent executing stages (busy time).
    pub busy: f64,
    /// DRAM-streamed weight bytes per frame (8-bit model units).
    pub dram_weight_bytes_8bit: u64,
}

/// End-to-end run report.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub network: String,
    pub frames: u64,
    pub wall: Duration,
    /// Steady-state throughput (frames/s) over frames after the first.
    pub fps: f64,
    /// Mean per-frame latency (s).
    pub latency: f64,
    pub groups: Vec<GroupStats>,
    /// Max |logits - golden| on frame 0 (all frames use the golden input).
    pub max_abs_err: f32,
    /// Eq-13 DRAM weight traffic per frame (8-bit bytes).
    pub dram_weight_bytes_8bit: u64,
}

impl RunReport {
    /// Coordinator overhead: wall time not attributable to the busiest
    /// group (the paper's requirement that L3 not be the bottleneck).
    pub fn coordinator_overhead(&self) -> f64 {
        let busiest = self.groups.iter().map(|g| g.busy).fold(0.0, f64::max);
        (self.wall.as_secs_f64() - busiest).max(0.0) / self.wall.as_secs_f64()
    }
}

/// Partition `n` stages into `workers` contiguous groups balanced by a
/// cost estimate (streamed bytes + FM bytes as a compute proxy).
fn partition(manifest: &Manifest, workers: usize) -> Vec<(usize, usize)> {
    let n = manifest.stages.len();
    let w = workers.clamp(1, n);
    let cost: Vec<u64> = manifest.stages.iter().map(|s| s.fm_bytes_8bit + s.weight_bytes_8bit).collect();
    let total: u64 = cost.iter().sum();
    let mut bounds = Vec::with_capacity(w);
    let mut start = 0usize;
    let mut acc = 0u64;
    let mut target = total / w as u64;
    for (i, c) in cost.iter().enumerate() {
        acc += c;
        let groups_left = w - bounds.len();
        let stages_left = n - i - 1;
        if (acc >= target && groups_left > 1 && stages_left >= groups_left - 1) || stages_left + 1 == groups_left {
            bounds.push((start, i + 1));
            start = i + 1;
            acc = 0;
            target = total / w as u64;
        }
    }
    if start < n {
        bounds.push((start, n));
    }
    bounds
}

/// Façade entry point: stream a [`crate::design::Design`]'s network. The
/// design resolves the AOT artifact short name; errors if the design's
/// network has no compiled artifacts (non-zoo networks).
pub fn run_streaming_design(
    design: &crate::design::Design,
    dir: PathBuf,
    frames: u64,
    workers: usize,
) -> Result<RunReport> {
    let short = design.network_short_or_err().map_err(|e| anyhow::anyhow!(e))?;
    run_streaming(dir, short, frames, workers)
}

/// Streaming coordinator: run `frames` frames of the golden input through
/// the `short` network's artifact pipeline with `workers` CE groups.
pub fn run_streaming(dir: PathBuf, short: &str, frames: u64, workers: usize) -> Result<RunReport> {
    let manifest = Manifest::load(&dir, short)?;
    let input = manifest.read_f32(&manifest.golden_input)?;
    let golden = manifest.read_f32(&manifest.golden_logits)?;
    let groups = partition(&manifest, workers);

    // Channel chain with ping-pong depth 2.
    let mut senders: Vec<mpsc::SyncSender<Frame>> = Vec::new();
    let mut receivers: Vec<mpsc::Receiver<Frame>> = Vec::new();
    for _ in 0..=groups.len() {
        let (tx, rx) = mpsc::sync_channel::<Frame>(2);
        senders.push(tx);
        receivers.push(rx);
    }

    // Stage compilation happens inside each worker; the barrier keeps it
    // out of the timed window so throughput reflects the request path only.
    let ready = Arc::new(Barrier::new(groups.len() + 1));
    let mut handles = Vec::new();
    let mut stat_rxs = Vec::new();
    let mut rx_iter = receivers.into_iter();
    for (g, &(s0, s1)) in groups.iter().enumerate() {
        let rx = rx_iter.next().unwrap();
        let tx = senders[g + 1].clone();
        let (stat_tx, stat_rx) = mpsc::channel::<Result<GroupStats>>();
        stat_rxs.push(stat_rx);
        let dir = dir.clone();
        let short = short.to_string();
        let ready = ready.clone();
        handles.push(std::thread::spawn(move || {
            let run = || -> Result<GroupStats> {
                // Each worker owns its own PJRT client + stage range.
                let engine = Engine::load(&dir, &short)
                    .with_context(|| format!("group {g}: loading stages {s0}..{s1}"))?;
                ready.wait();
                let mut busy = 0.0f64;
                let dram: u64 = engine.stages[s0..s1]
                    .iter()
                    .filter(|s| s.spec.kind == StageKind::Wrce)
                    .map(|s| s.spec.weight_bytes_8bit)
                    .sum();
                while let Ok(mut frame) = rx.recv() {
                    let t0 = Instant::now();
                    for stage in &engine.stages[s0..s1] {
                        frame.data = stage.run(&frame.data)?;
                    }
                    busy += t0.elapsed().as_secs_f64();
                    if tx.send(frame).is_err() {
                        break;
                    }
                }
                Ok(GroupStats { stages: (s0, s1), busy, dram_weight_bytes_8bit: dram })
            };
            let _ = stat_tx.send(run());
        }));
    }
    // NOTE: each worker compiles the *full* engine for simplicity of
    // artifact handling but executes only its range; compile cost is
    // load-time only and excluded from throughput metrics.

    // Source: frame 0..frames of the golden input (weights and input are
    // fixed so every frame must reproduce the golden logits).
    let src = senders[0].clone();
    drop(senders);
    ready.wait(); // all workers compiled and standing by
    let t_start = Instant::now();
    let producer = std::thread::spawn(move || {
        for id in 0..frames {
            let frame = Frame { id, data: input.clone(), t_in: Instant::now() };
            if src.send(frame).is_err() {
                break;
            }
        }
    });

    // Sink.
    let sink = rx_iter.next().unwrap();
    let mut completions: Vec<Instant> = Vec::with_capacity(frames as usize);
    let mut latency_sum = 0.0f64;
    let mut max_abs_err = 0.0f32;
    for _ in 0..frames {
        let frame = sink.recv().context("pipeline dropped before completing all frames")?;
        latency_sum += frame.t_in.elapsed().as_secs_f64();
        completions.push(Instant::now());
        for (a, b) in frame.data.iter().zip(&golden) {
            max_abs_err = max_abs_err.max((a - b).abs());
        }
        let _ = frame.id;
    }
    let wall = t_start.elapsed();
    producer.join().ok();
    drop(sink);
    let mut group_stats = Vec::new();
    for rx in stat_rxs {
        group_stats.push(rx.recv().context("worker died")??);
    }
    for h in handles {
        h.join().ok();
    }

    let fps = if completions.len() > 1 {
        (completions.len() - 1) as f64
            / (completions[completions.len() - 1] - completions[0]).as_secs_f64().max(1e-9)
    } else {
        1.0 / wall.as_secs_f64()
    };
    let dram = group_stats.iter().map(|g| g.dram_weight_bytes_8bit).sum();
    Ok(RunReport {
        network: manifest.network.clone(),
        frames,
        wall,
        fps,
        latency: latency_sum / frames as f64,
        groups: group_stats,
        max_abs_err,
        dram_weight_bytes_8bit: dram,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_stages_contiguously() {
        // Build a synthetic manifest shape via the real loader is overkill;
        // exercise partition() through its public behaviour instead.
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("mbv2_manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir, "mbv2").unwrap();
        for w in [1, 2, 3, 5, 100] {
            let parts = partition(&m, w);
            assert_eq!(parts[0].0, 0);
            assert_eq!(parts.last().unwrap().1, m.stages.len());
            for pair in parts.windows(2) {
                assert_eq!(pair[0].1, pair[1].0);
            }
            assert!(parts.len() <= w.min(m.stages.len()));
            assert!(parts.iter().all(|(a, b)| a < b));
        }
    }
}
