//! Typed error taxonomy for the whole reproduction pipeline.
//!
//! Every fallible layer (CLI/spec parsing, IR loading, allocation,
//! simulation, the sweep cache) used to report ad-hoc `String` errors;
//! [`ReproError`] replaces them with one enum whose variants name the
//! *subsystem that failed*, so per-cell sweep failures can be classified,
//! rendered, and filtered (`SweepReport::failures`, `repro sweep
//! --strict`) without string matching.
//!
//! Design constraints, in order:
//!
//! * **Message compatibility.** [`ReproError`]'s `Display` prints the bare
//!   message with no variant prefix, so every existing CLI error line,
//!   doctest, and `err.contains(..)` assertion keeps its exact text. The
//!   variant is extra structure, not a text change.
//! * **`?` interop.** `impl From<ReproError> for String` lets callers that
//!   still return `Result<_, String>` (the CLI argument helpers) use `?`
//!   on converted functions unchanged.
//! * **Panic capture.** [`ReproError::from_panic`] converts a payload
//!   caught by `catch_unwind` (see
//!   [`crate::util::pool::parallel_map_fallible`]) into
//!   [`ReproError::Internal`], preserving `&str`/`String` payloads
//!   verbatim so an injected `panic!("injected fault: ...")` round-trips
//!   into the sweep report's `failures` section.
//!
//! # Examples
//!
//! ```
//! use repro::util::error::ReproError;
//!
//! let e = ReproError::config("unknown platform \"vu9p\"");
//! assert_eq!(e.kind(), "config");
//! assert!(e.contains("vu9p"));
//! assert_eq!(format!("{e}"), "unknown platform \"vu9p\""); // no prefix
//! let s: String = e.into(); // `?` in Result<_, String> contexts
//! assert_eq!(s, "unknown platform \"vu9p\"");
//! ```

use std::fmt;

use crate::util::json::Json;

/// A classified pipeline error. The variant names the subsystem that
/// failed; the payload is the human-readable message (exactly what the
/// old `String` errors carried).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReproError {
    /// CLI flags, sweep specs, platform/granularity names, design-artifact
    /// JSON: anything the *user's configuration* got wrong.
    Config(String),
    /// Network descriptions: IR parsing, shape inference, lowering,
    /// unknown zoo names.
    Network(String),
    /// Alg 1/Alg 2 resource allocation failed (degenerate budgets — zero
    /// SRAM or zero DSPs cannot host any FGPM point).
    Allocation(String),
    /// The cycle simulator stopped: an organic pipeline deadlock (the
    /// message carries the per-CE/per-FIFO report out of
    /// [`crate::sim::Pipeline::run`]) or an injected `eval.sim` fault.
    /// The sweep records a deadlock surfacing from its simulate call
    /// in-cell as `SweepCell::sim_error` (a measurement, not a cell
    /// failure); injected faults fire before that call and fail the cell.
    Simulation(String),
    /// Sweep-cache I/O: unreadable, torn, or unwritable cache entries.
    CacheIo(String),
    /// A captured panic payload from a worker (via
    /// [`ReproError::from_panic`]) or another "this is a bug" condition.
    Internal(String),
}

impl ReproError {
    pub fn config<S: Into<String>>(msg: S) -> Self {
        ReproError::Config(msg.into())
    }

    pub fn network<S: Into<String>>(msg: S) -> Self {
        ReproError::Network(msg.into())
    }

    pub fn allocation<S: Into<String>>(msg: S) -> Self {
        ReproError::Allocation(msg.into())
    }

    pub fn simulation<S: Into<String>>(msg: S) -> Self {
        ReproError::Simulation(msg.into())
    }

    pub fn cache_io<S: Into<String>>(msg: S) -> Self {
        ReproError::CacheIo(msg.into())
    }

    pub fn internal<S: Into<String>>(msg: S) -> Self {
        ReproError::Internal(msg.into())
    }

    /// Stable lower-snake kind tag — the `"kind"` field of the sweep
    /// report's `failures` entries and the `FAILED(kind)` marker in the
    /// text matrix.
    pub fn kind(&self) -> &'static str {
        match self {
            ReproError::Config(_) => "config",
            ReproError::Network(_) => "network",
            ReproError::Allocation(_) => "allocation",
            ReproError::Simulation(_) => "simulation",
            ReproError::CacheIo(_) => "cache_io",
            ReproError::Internal(_) => "internal",
        }
    }

    /// The human-readable message (what `Display` prints).
    pub fn message(&self) -> &str {
        match self {
            ReproError::Config(m)
            | ReproError::Network(m)
            | ReproError::Allocation(m)
            | ReproError::Simulation(m)
            | ReproError::CacheIo(m)
            | ReproError::Internal(m) => m,
        }
    }

    /// Substring test on the message — the assertion shape the test
    /// suites already use on `String` errors (`err.contains("...")`)
    /// keeps compiling unchanged.
    pub fn contains(&self, needle: &str) -> bool {
        self.message().contains(needle)
    }

    /// Same variant, message prefixed — for call sites that wrap an inner
    /// error with context (`--net-file <path>: ...`).
    pub fn prefixed(self, prefix: &str) -> Self {
        let wrap = |m: String| format!("{prefix}{m}");
        match self {
            ReproError::Config(m) => ReproError::Config(wrap(m)),
            ReproError::Network(m) => ReproError::Network(wrap(m)),
            ReproError::Allocation(m) => ReproError::Allocation(wrap(m)),
            ReproError::Simulation(m) => ReproError::Simulation(wrap(m)),
            ReproError::CacheIo(m) => ReproError::CacheIo(wrap(m)),
            ReproError::Internal(m) => ReproError::Internal(wrap(m)),
        }
    }

    /// Convert a payload caught by `std::panic::catch_unwind` into
    /// [`ReproError::Internal`]. `panic!("...")` payloads are `&str` or
    /// `String`; anything else gets a fixed placeholder (the payload type
    /// is unknowable without downcasting every possibility).
    pub fn from_panic(payload: Box<dyn std::any::Any + Send>) -> Self {
        let msg = if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        };
        ReproError::Internal(format!("panic: {msg}"))
    }

    /// `{"kind": ..., "message": ...}` — the shape embedded in the sweep
    /// report's `failures` entries.
    pub fn to_json_value(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("kind".to_string(), Json::Str(self.kind().to_string()));
        m.insert("message".to_string(), Json::Str(self.message().to_string()));
        Json::Obj(m)
    }
}

impl fmt::Display for ReproError {
    /// Bare message, no variant prefix: CLI output and test assertions
    /// see exactly the text the old `String` errors carried.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.message())
    }
}

impl std::error::Error for ReproError {}

/// `?` interop for callers still returning `Result<_, String>` (the CLI
/// argument helpers): a converted function's `ReproError` coerces back to
/// its message.
impl From<ReproError> for String {
    fn from(e: ReproError) -> String {
        match e {
            ReproError::Config(m)
            | ReproError::Network(m)
            | ReproError::Allocation(m)
            | ReproError::Simulation(m)
            | ReproError::CacheIo(m)
            | ReproError::Internal(m) => m,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_the_bare_message() {
        let e = ReproError::network("cycle detected through node \"a\"");
        assert_eq!(e.to_string(), "cycle detected through node \"a\"");
        assert_eq!(e.kind(), "network");
    }

    #[test]
    fn contains_matches_on_the_message() {
        let e = ReproError::config("unknown granularity \"coarse\"");
        assert!(e.contains("coarse"));
        assert!(!e.contains("config")); // the kind tag is not in the text
    }

    #[test]
    fn from_panic_captures_str_and_string_payloads() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        let e = ReproError::from_panic(p);
        assert_eq!(e, ReproError::Internal("panic: boom 7".to_string()));

        let p = std::panic::catch_unwind(|| panic!("static boom")).unwrap_err();
        assert!(ReproError::from_panic(p).contains("static boom"));

        let p = std::panic::catch_unwind(|| std::panic::panic_any(42u32)).unwrap_err();
        assert!(ReproError::from_panic(p).contains("non-string panic payload"));
    }

    #[test]
    fn prefixed_keeps_the_variant() {
        let e = ReproError::network("missing field").prefixed("--net-file x.json: ");
        assert_eq!(e, ReproError::Network("--net-file x.json: missing field".to_string()));
    }

    #[test]
    fn json_value_has_kind_and_message() {
        let e = ReproError::cache_io("torn entry");
        assert_eq!(e.to_json_value().to_string(), r#"{"kind":"cache_io","message":"torn entry"}"#);
    }

    #[test]
    fn string_conversion_is_the_message() {
        let s: String = ReproError::allocation("zero SRAM budget").into();
        assert_eq!(s, "zero SRAM budget");
    }
}
