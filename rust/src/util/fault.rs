//! Deterministic fault injection for the sweep pipeline.
//!
//! Robustness claims ("one pathological cell cannot take down the run",
//! "a torn cache write degrades to a miss") are untestable without a way
//! to *cause* those failures on demand. This module is that way: a
//! [`FaultPlan`] names injection sites inside the sweep engine and
//! decides — **from cell content only** — whether each site trips.
//!
//! # Sites
//!
//! | site          | effect when tripped                                        |
//! |---------------|------------------------------------------------------------|
//! | `cache.load`  | the cache lookup reports a miss                            |
//! | `cache.store` | the entry is written *torn* (truncated) and the store errors |
//! | `eval.alloc`  | the cell's allocation panics (exercises `catch_unwind`)    |
//! | `eval.sim`    | the cell's simulation returns a `Simulation` error         |
//!
//! # Triggers
//!
//! * `key=SUBSTRING` — trips for every cell whose content key contains
//!   the substring (e.g. `key=mobilenet_v1` fails exactly that network's
//!   cells).
//! * `nth=N` — trips when `fnv1a64(key) % N == 0`: a deterministic
//!   pseudo-random ~1/N subset of cells.
//!
//! Both triggers are pure functions of the cell's content key — never of
//! worker identity, claim order, or wall clock — so an injected run is
//! exactly reproducible at any `--jobs N`.
//!
//! # Arming
//!
//! * `REPRO_FAULTS` environment variable: semicolon-separated rules,
//!   `site:trigger` each — e.g.
//!   `REPRO_FAULTS='eval.alloc:key=mobilenet_v1;cache.store:nth=2'`.
//!   The CLI validates the spec up front and refuses to run on a bad one
//!   ([`env_spec`] + [`FaultPlan::parse`]); library consumers that skip
//!   validation get a silently disarmed harness rather than surprise
//!   faults.
//! * Test-only in-process API: [`arm`] / [`disarm`]. While armed, the
//!   override *replaces* the environment plan entirely, so tests are
//!   hermetic against an inherited `REPRO_FAULTS`.
//!
//! Disarmed (the default), every [`trip`] call is a cheap read of a
//! never-written lock returning `false` — the production path stays
//! byte-identical with the harness compiled in (asserted by the CI warm
//! gate).

use std::sync::{OnceLock, RwLock};

use crate::util::error::ReproError;

/// A named injection point inside the sweep engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Inside `CellCache::load`: a trip reports a miss.
    CacheLoad,
    /// Inside `CellCache::store`: a trip writes a torn entry and errors.
    CacheStore,
    /// Inside `sweep::eval_cell` before allocation: a trip panics.
    EvalAlloc,
    /// Inside `sweep::eval_cell` before simulation: a trip returns
    /// [`ReproError::Simulation`].
    EvalSim,
}

impl Site {
    /// The spelling used in `REPRO_FAULTS` rules.
    pub fn name(self) -> &'static str {
        match self {
            Site::CacheLoad => "cache.load",
            Site::CacheStore => "cache.store",
            Site::EvalAlloc => "eval.alloc",
            Site::EvalSim => "eval.sim",
        }
    }

    fn parse(s: &str) -> Option<Site> {
        match s {
            "cache.load" => Some(Site::CacheLoad),
            "cache.store" => Some(Site::CacheStore),
            "eval.alloc" => Some(Site::EvalAlloc),
            "eval.sim" => Some(Site::EvalSim),
            _ => None,
        }
    }
}

/// When a rule's site fires. Both arms are pure functions of the cell's
/// content key, keeping injected runs reproducible at any job count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Trigger {
    /// Trips when `fnv1a64(key) % n == 0` — a deterministic ~1/n subset.
    /// (A shared counter would depend on claim order and break `--jobs N`
    /// reproducibility; hashing the content does not.)
    Nth(u64),
    /// Trips when the content key contains the substring.
    KeySubstring(String),
}

/// FNV offset basis; any fixed seed works, it only needs to be stable.
const NTH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

impl Trigger {
    fn fires(&self, key: &str) -> bool {
        match self {
            Trigger::Nth(n) => crate::sweep::cache::fnv1a64(key.as_bytes(), NTH_SEED) % n == 0,
            Trigger::KeySubstring(s) => key.contains(s.as_str()),
        }
    }
}

/// A set of `(site, trigger)` rules. Empty plans are unrepresentable via
/// [`FaultPlan::parse`] (a set-but-empty `REPRO_FAULTS` is a config
/// error, not a silent no-op).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    rules: Vec<(Site, Trigger)>,
}

impl FaultPlan {
    /// Parse a `REPRO_FAULTS` spec: semicolon-separated `site:trigger`
    /// rules, trigger one of `key=SUBSTRING` / `nth=N`.
    ///
    /// ```
    /// use repro::util::fault::{FaultPlan, Site};
    ///
    /// let plan = FaultPlan::parse("eval.alloc:key=mobilenet_v1;cache.store:nth=2").unwrap();
    /// assert!(plan.should_trip(Site::EvalAlloc, "{\"network\":\"mobilenet_v1\"}"));
    /// assert!(!plan.should_trip(Site::EvalSim, "{\"network\":\"mobilenet_v1\"}"));
    ///
    /// let err = FaultPlan::parse("eval.malloc:nth=2").unwrap_err();
    /// assert!(err.contains("unknown site"));
    /// ```
    pub fn parse(spec: &str) -> Result<FaultPlan, ReproError> {
        let mut rules = Vec::new();
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (site_s, trig_s) = part.split_once(':').ok_or_else(|| {
                ReproError::config(format!("REPRO_FAULTS rule {part:?}: expected site:trigger"))
            })?;
            let site = Site::parse(site_s.trim()).ok_or_else(|| {
                ReproError::config(format!(
                    "REPRO_FAULTS rule {part:?}: unknown site {:?} (known sites: cache.load, cache.store, eval.alloc, eval.sim)",
                    site_s.trim()
                ))
            })?;
            let trig_s = trig_s.trim();
            let trigger = if let Some(sub) = trig_s.strip_prefix("key=") {
                Trigger::KeySubstring(sub.to_string())
            } else if let Some(nth) = trig_s.strip_prefix("nth=") {
                match nth.parse::<u64>() {
                    Ok(n) if n >= 1 => Trigger::Nth(n),
                    _ => {
                        return Err(ReproError::config(format!(
                            "REPRO_FAULTS rule {part:?}: nth wants a positive integer, got {nth:?}"
                        )))
                    }
                }
            } else {
                return Err(ReproError::config(format!(
                    "REPRO_FAULTS rule {part:?}: unknown trigger {trig_s:?} (use key=SUBSTRING or nth=N)"
                )));
            };
            rules.push((site, trigger));
        }
        if rules.is_empty() {
            return Err(ReproError::config("REPRO_FAULTS is set but contains no rules"));
        }
        Ok(FaultPlan { rules })
    }

    /// A single-rule plan — the common shape in tests.
    pub fn rule(site: Site, trigger: Trigger) -> FaultPlan {
        FaultPlan { rules: vec![(site, trigger)] }
    }

    /// Does any rule for `site` fire on this content key?
    pub fn should_trip(&self, site: Site, key: &str) -> bool {
        self.rules.iter().any(|(s, t)| *s == site && t.fires(key))
    }
}

/// Test-only in-process override; `Some` replaces the environment plan
/// entirely while armed.
static TEST_OVERRIDE: RwLock<Option<FaultPlan>> = RwLock::new(None);

/// The `REPRO_FAULTS` plan, parsed once (invalid specs disarm silently
/// here — the CLI front-end validates loudly before starting a sweep).
static ENV_PLAN: OnceLock<Option<FaultPlan>> = OnceLock::new();

/// Arm an in-process plan (test API). Replaces any environment plan
/// until [`disarm`]. Tests sharing a process must serialize around
/// arm/disarm pairs — the override is global.
pub fn arm(plan: FaultPlan) {
    *TEST_OVERRIDE.write().unwrap_or_else(|e| e.into_inner()) = Some(plan);
}

/// Clear the in-process plan (test API).
pub fn disarm() {
    *TEST_OVERRIDE.write().unwrap_or_else(|e| e.into_inner()) = None;
}

/// The raw `REPRO_FAULTS` value, if set and non-blank — what the CLI
/// validates with [`FaultPlan::parse`] before starting a sweep.
pub fn env_spec() -> Option<String> {
    std::env::var("REPRO_FAULTS").ok().filter(|s| !s.trim().is_empty())
}

fn env_plan() -> Option<&'static FaultPlan> {
    ENV_PLAN.get_or_init(|| env_spec().and_then(|s| FaultPlan::parse(&s).ok())).as_ref()
}

/// Should `site` fail for the cell identified by content `key`? The
/// single question every injection site asks. Disarmed, always `false`.
pub fn trip(site: Site, key: &str) -> bool {
    if let Some(plan) = TEST_OVERRIDE.read().unwrap_or_else(|e| e.into_inner()).as_ref() {
        return plan.should_trip(site, key);
    }
    env_plan().is_some_and(|p| p.should_trip(site, key))
}

/// Is any plan (override or environment) active?
pub fn armed() -> bool {
    TEST_OVERRIDE.read().unwrap_or_else(|e| e.into_inner()).is_some() || env_plan().is_some()
}

#[cfg(test)]
mod tests {
    // Pure-plan tests only: arming the global override here would race
    // the sweep/cache unit tests sharing this test binary. The arm/disarm
    // lifecycle is exercised (serialized) in `rust/tests/faults.rs`.
    use super::*;

    #[test]
    fn parses_multi_rule_specs() {
        let plan = FaultPlan::parse(" cache.load:key=zc706 ; eval.sim:nth=3 ").unwrap();
        assert!(plan.should_trip(Site::CacheLoad, "cell for zc706"));
        assert!(!plan.should_trip(Site::CacheStore, "cell for zc706"));
    }

    #[test]
    fn rejects_malformed_specs() {
        for (spec, needle) in [
            ("eval.alloc", "expected site:trigger"),
            ("eval.malloc:nth=2", "unknown site"),
            ("eval.alloc:every=2", "unknown trigger"),
            ("eval.alloc:nth=0", "positive integer"),
            ("eval.alloc:nth=x", "positive integer"),
            ("  ;  ", "no rules"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(err.contains(needle), "{spec}: {err}");
            assert_eq!(err.kind(), "config", "{spec}");
        }
    }

    #[test]
    fn nth_is_a_pure_function_of_the_key() {
        let plan = FaultPlan::rule(Site::EvalAlloc, Trigger::Nth(3));
        let keys: Vec<String> = (0..64).map(|i| format!("cell-{i}")).collect();
        let first: Vec<bool> =
            keys.iter().map(|k| plan.should_trip(Site::EvalAlloc, k)).collect();
        let second: Vec<bool> =
            keys.iter().map(|k| plan.should_trip(Site::EvalAlloc, k)).collect();
        assert_eq!(first, second, "same key must always give the same answer");
        let hits = first.iter().filter(|&&b| b).count();
        assert!(hits > 0 && hits < keys.len(), "nth=3 should trip a strict subset, got {hits}/64");
    }

    #[test]
    fn nth_one_trips_everything() {
        let plan = FaultPlan::rule(Site::CacheStore, Trigger::Nth(1));
        for k in ["a", "b", "anything at all"] {
            assert!(plan.should_trip(Site::CacheStore, k));
        }
    }

    #[test]
    fn site_names_round_trip() {
        for site in [Site::CacheLoad, Site::CacheStore, Site::EvalAlloc, Site::EvalSim] {
            assert_eq!(Site::parse(site.name()), Some(site));
        }
    }
}
