//! Self-contained utilities replacing unavailable third-party crates in
//! this offline build: a JSON parser ([`json`]), a scoped-thread work
//! pool with deterministic output ordering ([`pool`]), the typed error
//! taxonomy ([`error`]), the deterministic fault-injection harness
//! ([`fault`]), the CLI flag parser ([`cli`]), a deterministic PRNG +
//! property-test harness ([`prop`]), and a micro-bench timer ([`bench`]).

pub mod cli;
pub mod error;
pub mod fault;
pub mod json;
pub mod pool;

/// Deterministic xorshift64* PRNG + tiny property-test harness (proptest
/// is not vendored; invariant tests in `rust/tests/proptests.rs` use
/// this).
pub mod prop {
    pub struct Rng(u64);

    impl Rng {
        pub fn new(seed: u64) -> Self {
            Rng(seed.max(1))
        }

        pub fn next_u64(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            self.0 = x;
            x.wrapping_mul(0x2545_F491_4F6C_DD1D)
        }

        /// Uniform in `[lo, hi]` (inclusive).
        pub fn range(&mut self, lo: usize, hi: usize) -> usize {
            assert!(lo <= hi);
            lo + (self.next_u64() as usize) % (hi - lo + 1)
        }

        pub fn f64(&mut self) -> f64 {
            (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
        }

        pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            &xs[self.range(0, xs.len() - 1)]
        }
    }

    /// Run `f` against `cases` generated inputs; on failure, report the
    /// seed so the case can be replayed.
    pub fn check<G, T, F>(name: &str, cases: usize, mut gen: G, mut f: F)
    where
        G: FnMut(&mut Rng) -> T,
        T: std::fmt::Debug,
        F: FnMut(&T) -> Result<(), String>,
    {
        for case in 0..cases {
            let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1);
            let mut rng = Rng::new(seed);
            let input = gen(&mut rng);
            if let Err(msg) = f(&input) {
                panic!("property {name} failed on case {case} (seed {seed:#x}):\n  input: {input:?}\n  {msg}");
            }
        }
    }
}

/// Micro-benchmark timing (criterion is not vendored). Benches under
/// `rust/benches/` use this to print `name ... median_ms (min..max, N
/// iters)` lines consumed by EXPERIMENTS.md.
pub mod bench {
    use std::time::Instant;

    pub struct Sample {
        pub name: String,
        pub median_ms: f64,
        pub min_ms: f64,
        pub max_ms: f64,
        pub iters: usize,
    }

    /// Time `f` adaptively: run until ~`budget_ms` of wall time or 50
    /// iterations, whichever first (minimum 3 iterations).
    pub fn time<F: FnMut()>(name: &str, budget_ms: f64, mut f: F) -> Sample {
        let mut times = Vec::new();
        let start = Instant::now();
        while (times.len() < 3) || (start.elapsed().as_secs_f64() * 1e3 < budget_ms && times.len() < 50) {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let s = Sample {
            name: name.to_string(),
            median_ms: times[times.len() / 2],
            min_ms: times[0],
            max_ms: *times.last().unwrap(),
            iters: times.len(),
        };
        println!(
            "bench {:44} {:10.3} ms  (min {:.3}, max {:.3}, n={})",
            s.name, s.median_ms, s.min_ms, s.max_ms, s.iters
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::prop::Rng;

    #[test]
    fn rng_is_deterministic_and_spread() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        let mut uniq = xs.clone();
        uniq.dedup();
        assert_eq!(uniq.len(), xs.len());
    }

    #[test]
    fn range_is_inclusive_and_bounded() {
        let mut r = Rng::new(7);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }
}
