//! Minimal JSON parser + stable serializer.
//!
//! The offline build vendors only the `xla` crate's dependency closure, so
//! serde is unavailable; this covers the JSON subset `aot.py` emits
//! (objects, arrays, strings, f64 numbers, bools, null) plus escapes.
//!
//! Serialization ([`Json`]'s `Display` impl) is *stable*: objects print
//! their keys in sorted order (`Json::Obj` is a `BTreeMap`), numbers with
//! an integral value print as integers, and everything fits on one line —
//! so two serializations of equal values are byte-identical and design
//! artifacts ([`crate::design::Design::to_json`]) stay diffable.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `obj.str_field("name")` with a descriptive panic for
    /// malformed manifests (they are build artifacts, not user input).
    pub fn str_field(&self, key: &str) -> &str {
        self.get(key).and_then(Json::as_str).unwrap_or_else(|| panic!("missing string field {key:?}"))
    }

    pub fn usize_field(&self, key: &str) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or_else(|| panic!("missing number field {key:?}"))
    }

    pub fn arr_field(&self, key: &str) -> &[Json] {
        self.get(key).and_then(Json::as_arr).unwrap_or_else(|| panic!("missing array field {key:?}"))
    }

    /// Non-panicking `get(key).and_then(as_f64)` — the lookup shape every
    /// fallible reader (design reload, sweep-cache entries) repeats.
    pub fn field_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default()
    }
}

fn write_json_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            '\u{8}' => f.write_str("\\b")?,
            '\u{c}' => f.write_str("\\f")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_char(c)?,
        }
    }
    f.write_char('"')
}

impl fmt::Display for Json {
    /// Compact, stable serialization: sorted object keys, integral numbers
    /// as integers, shortest round-tripping form for the rest.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) if !n.is_finite() => f.write_str("null"),
            Json::Num(n) => {
                // 2^53-bounded integral values print without a fraction and
                // re-parse to the identical f64.
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_json_str(f, s),
            Json::Arr(a) => {
                f.write_char('[')?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_char(',')?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_char(']')
            }
            Json::Obj(m) => {
                f.write_char('{')?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_char(',')?;
                    }
                    write_json_str(f, k)?;
                    f.write_char(':')?;
                    write!(f, "{v}")?;
                }
                f.write_char('}')
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                        self.i += 1;
                    }
                    let _ = c;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"network": "mobilenet_v2", "boundary": 7,
                      "stages": [{"name": "stem", "mean": -0.25e-1, "params": []}],
                      "ok": true, "nothing": null}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.str_field("network"), "mobilenet_v2");
        assert_eq!(j.usize_field("boundary"), 7);
        let stages = j.arr_field("stages");
        assert_eq!(stages[0].str_field("name"), "stem");
        assert!((stages[0].get("mean").unwrap().as_f64().unwrap() + 0.025).abs() < 1e-12);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#"{"s": "a\n\"b\"A"}"#).unwrap();
        assert_eq!(j.str_field("s"), "a\n\"b\"A");
    }

    #[test]
    fn parses_nested_arrays() {
        let j = Json::parse("[[1,2],[3],[ ]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].usize_vec(), vec![1, 2]);
        assert_eq!(a[2].usize_vec(), Vec::<usize>::new());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn serializer_is_stable_and_roundtrips() {
        let doc = r#"{"b": [1, 2.5, -3], "a": "x\n\"y\"", "c": {"k": true, "j": null}}"#;
        let j = Json::parse(doc).unwrap();
        let s1 = j.to_string();
        // Keys sorted, one line, integral numbers printed as integers.
        assert_eq!(s1, r#"{"a":"x\n\"y\"","b":[1,2.5,-3],"c":{"j":null,"k":true}}"#);
        // Parse -> print is a fixed point.
        assert_eq!(Json::parse(&s1).unwrap().to_string(), s1);
    }

    #[test]
    fn serializer_escapes_control_chars() {
        let j = Json::Str("a\u{1}b\\c".to_string());
        let s = j.to_string();
        assert_eq!(s, "\"a\\u0001b\\\\c\"");
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parses_negative_and_exponent_numbers() {
        let j = Json::parse("[-1.5, 2e3, 0.0]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
    }
}
