//! Hand-rolled CLI flag parsing shared by the `repro` binary and its
//! integration tests (clap is not vendored offline).
//!
//! Two bugfixes over the binary's original private helpers, both of the
//! fail-loudly school the rest of the CLI follows:
//!
//! * **Duplicate flags are rejected.** The old lookup silently used the
//!   *first* occurrence (`--frames 3 ... --frames 9` ran with 3 and no
//!   warning); now any flag given more than once — in either form — is a
//!   configuration error.
//! * **`--name=VAL` is accepted.** The old parser only matched the exact
//!   token `--name`, so `--frames=3` fell through as an unknown flag (or,
//!   on commands that skip [`check_flags`]-style validation, silently ran
//!   with the default). Both `--name VAL` and `--name=VAL` now parse, and
//!   [`check_flags`]/[`positional`] understand that the `=` form carries
//!   its value inline (consuming one token, not two).
//!
//! The space form keeps its flag-shaped-value rejection (`--frames
//! --baseline` is an error, not "--baseline is the value"); the `=` form
//! is unambiguous, so its value is taken verbatim (but must be
//! non-empty — `--frames=` is an error).

/// Whether `arg` is an occurrence of flag `name`: the exact token
/// (`--name`) or the inline-value form (`--name=...`). `--cache-dir` is
/// *not* an occurrence of `--cache` — the next byte after the name must
/// be `=` or the end of the token.
fn is_occurrence(arg: &str, name: &str) -> bool {
    match arg.strip_prefix(name) {
        Some(rest) => rest.is_empty() || rest.starts_with('='),
        None => false,
    }
}

/// Whether `name` appears anywhere in `args`, in either form. The
/// presence test conflict checks use (`--load` vs `--platform`, custom
/// budgets vs `--platforms`): an `=`-form flag must count as present.
pub fn flag_present(args: &[String], name: &str) -> bool {
    args.iter().any(|a| is_occurrence(a, name))
}

/// Value of `--name VAL` or `--name=VAL`.
///
/// Errors on a repeated flag (in any mix of forms), a missing value, an
/// empty `=`-form value, and a flag-shaped space-form value.
///
/// # Examples
///
/// ```
/// use repro::util::cli::flag_val;
///
/// let args: Vec<String> =
///     ["sweep", "--frames", "3", "--jobs=4"].iter().map(|s| s.to_string()).collect();
/// assert_eq!(flag_val(&args, "--frames").unwrap(), Some("3".to_string()));
/// assert_eq!(flag_val(&args, "--jobs").unwrap(), Some("4".to_string()));
/// assert_eq!(flag_val(&args, "--clocks").unwrap(), None);
///
/// let dup: Vec<String> =
///     ["sweep", "--frames", "3", "--frames=9"].iter().map(|s| s.to_string()).collect();
/// assert!(flag_val(&dup, "--frames").unwrap_err().contains("duplicate"));
/// ```
pub fn flag_val(args: &[String], name: &str) -> Result<Option<String>, String> {
    let occurrences: Vec<usize> = args
        .iter()
        .enumerate()
        .filter(|(_, a)| is_occurrence(a, name))
        .map(|(i, _)| i)
        .collect();
    if occurrences.len() > 1 {
        return Err(format!(
            "{name}: duplicate flag (given {} times; pass each flag at most once)",
            occurrences.len()
        ));
    }
    let Some(&i) = occurrences.first() else { return Ok(None) };
    let arg = &args[i];
    if let Some(v) = arg.strip_prefix(name).and_then(|rest| rest.strip_prefix('=')) {
        if v.is_empty() {
            return Err(format!("{name}: expected a value after '='"));
        }
        return Ok(Some(v.to_string()));
    }
    match args.get(i + 1) {
        Some(v) if !v.starts_with("--") => Ok(Some(v.clone())),
        Some(v) => Err(format!("{name}: expected a value, found flag {v:?}")),
        None => Err(format!("{name}: expected a value")),
    }
}

/// Parse `--name VAL` / `--name=VAL` as `T`, reporting a per-flag error
/// on bad input instead of silently using the default.
pub fn parse_opt<T: std::str::FromStr>(args: &[String], name: &str) -> Result<Option<T>, String> {
    match flag_val(args, name)? {
        None => Ok(None),
        Some(v) => v.parse().map(Some).map_err(|_| format!("{name}: cannot parse value {v:?}")),
    }
}

/// [`parse_opt`] with a default for the absent-flag case.
pub fn parse_or<T: std::str::FromStr>(
    args: &[String],
    name: &str,
    default: T,
) -> Result<T, String> {
    Ok(parse_opt(args, name)?.unwrap_or(default))
}

/// First positional argument after the subcommand (`args[0]`), skipping
/// flags and the values consumed by space-form value-taking flags (so
/// `--load f.json mbv2` still sees `mbv2`). An `=`-form flag carries its
/// value inline and consumes one token.
///
/// # Examples
///
/// ```
/// use repro::util::cli::positional;
///
/// let args: Vec<String> =
///     ["allocate", "--platform=edge", "mbv2"].iter().map(|s| s.to_string()).collect();
/// assert_eq!(positional(&args, &["--platform"]), Some(&"mbv2".to_string()));
/// ```
pub fn positional<'a>(args: &'a [String], value_flags: &[&str]) -> Option<&'a String> {
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if !a.starts_with("--") {
            return Some(a);
        }
        i += if !a.contains('=') && value_flags.contains(&a.as_str()) { 2 } else { 1 };
    }
    None
}

/// Reject flags the subcommand does not know — a typo'd flag would
/// otherwise be silently ignored and the run would use defaults. A known
/// boolean flag given a value (`--json=1`) is rejected too.
///
/// # Examples
///
/// ```
/// use repro::util::cli::check_flags;
///
/// let ok: Vec<String> =
///     ["sweep", "--frames=3", "--json"].iter().map(|s| s.to_string()).collect();
/// assert!(check_flags(&ok, &["--frames"], &["--json"]).is_ok());
///
/// let bad: Vec<String> = ["sweep", "--json=1"].iter().map(|s| s.to_string()).collect();
/// assert!(check_flags(&bad, &["--frames"], &["--json"]).unwrap_err().contains("--json"));
/// ```
pub fn check_flags(
    args: &[String],
    value_flags: &[&str],
    bool_flags: &[&str],
) -> Result<(), String> {
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if a.starts_with("--") {
            let (stem, eq_form) = match a.find('=') {
                Some(pos) => (&a[..pos], true),
                None => (a.as_str(), false),
            };
            if value_flags.contains(&stem) {
                i += if eq_form { 1 } else { 2 };
                continue;
            }
            if bool_flags.contains(&stem) {
                if eq_form {
                    return Err(format!("{stem}: takes no value (found {a:?})"));
                }
                i += 1;
                continue;
            }
            return Err(format!("unknown flag {a:?}"));
        }
        i += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn space_and_equals_forms_agree() {
        for form in [&["sweep", "--frames", "3"][..], &["sweep", "--frames=3"][..]] {
            assert_eq!(flag_val(&args(form), "--frames").unwrap(), Some("3".to_string()));
        }
    }

    #[test]
    fn duplicates_are_rejected_in_every_form_mix() {
        for form in [
            &["s", "--frames", "3", "--frames", "9"][..],
            &["s", "--frames=3", "--frames=9"][..],
            &["s", "--frames", "3", "--frames=9"][..],
        ] {
            let err = flag_val(&args(form), "--frames").unwrap_err();
            assert!(err.contains("--frames") && err.contains("duplicate"), "{err}");
        }
    }

    #[test]
    fn flag_shaped_and_missing_values_are_rejected() {
        assert!(flag_val(&args(&["s", "--frames", "--baseline"]), "--frames")
            .unwrap_err()
            .contains("found flag"));
        assert!(flag_val(&args(&["s", "--frames"]), "--frames")
            .unwrap_err()
            .contains("expected a value"));
        assert!(flag_val(&args(&["s", "--frames="]), "--frames")
            .unwrap_err()
            .contains("after '='"));
    }

    #[test]
    fn prefix_flags_are_not_confused() {
        // --cache-dir / --cache-gc must not count as occurrences of
        // --cache, in either direction.
        let a = args(&["s", "--cache-dir", "d", "--cache-gc=3"]);
        assert!(!flag_present(&a, "--cache"));
        assert_eq!(flag_val(&a, "--cache-dir").unwrap(), Some("d".to_string()));
        assert_eq!(flag_val(&a, "--cache-gc").unwrap(), Some("3".to_string()));
    }

    #[test]
    fn positional_skips_both_value_forms() {
        let vf = ["--platform", "--load"];
        assert_eq!(
            positional(&args(&["allocate", "--platform", "edge", "mbv2"]), &vf),
            Some(&"mbv2".to_string())
        );
        assert_eq!(
            positional(&args(&["allocate", "--platform=edge", "mbv2"]), &vf),
            Some(&"mbv2".to_string())
        );
        assert_eq!(positional(&args(&["allocate", "--platform", "edge"]), &vf), None);
    }

    #[test]
    fn check_flags_is_equals_aware() {
        let vf = ["--frames"];
        let bf = ["--json"];
        assert!(check_flags(&args(&["s", "--frames=3", "--json"]), &vf, &bf).is_ok());
        assert!(check_flags(&args(&["s", "--frames", "3"]), &vf, &bf).is_ok());
        assert!(check_flags(&args(&["s", "--typo=3"]), &vf, &bf)
            .unwrap_err()
            .contains("unknown flag"));
        assert!(check_flags(&args(&["s", "--json=1"]), &vf, &bf)
            .unwrap_err()
            .contains("takes no value"));
    }
}
