//! Scoped-thread work pool with deterministic output ordering.
//!
//! The offline build vendors no threading crates (rayon, crossbeam), so
//! this is the crate's own fan-out primitive: [`parallel_map`] evaluates a
//! pure function over a slice on `jobs` scoped threads. Scheduling is
//! self-balancing — every idle worker *steals* the next unclaimed index
//! from one shared atomic cursor, so a slow cell (a big network on a big
//! platform) never serializes the rest of the matrix behind it — and the
//! results are re-sorted by input index before returning, so the output
//! `Vec` is **bit-identical to the serial path for any `jobs`**. That
//! determinism is what lets `repro sweep --jobs N` keep byte-identical
//! JSON and golden-baseline artifacts (asserted in
//! `rust/tests/pareto.rs`).
//!
//! `std::thread::scope` means borrowed inputs need no `'static` bound and
//! a panicking worker propagates on join instead of being silently lost.
//!
//! # Examples
//!
//! ```
//! use repro::util::pool::parallel_map;
//!
//! let items = [1u64, 2, 3, 4, 5];
//! let serial = parallel_map(1, &items, |_, &x| x * x);
//! let parallel = parallel_map(4, &items, |_, &x| x * x);
//! assert_eq!(serial, vec![1, 4, 9, 16, 25]);
//! assert_eq!(serial, parallel); // deterministic order for any job count
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` on up to `jobs` scoped threads, returning results
/// in input order (index `i` of the output is `f(i, &items[i])`).
///
/// * `jobs <= 1` (or a single-item/empty slice) runs entirely on the
///   caller's thread — the serial path, no threads spawned.
/// * `jobs` is clamped to `items.len()`; surplus workers are never
///   spawned.
/// * `f` must be pure with respect to ordering: it may run concurrently
///   with itself and in any claim order.
///
/// Panics in `f` propagate to the caller once all workers have joined.
pub fn parallel_map<T, U, F>(jobs: usize, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let jobs = jobs.clamp(1, items.len().max(1));
    if jobs <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // One shared cursor of unclaimed work: an idle worker steals the next
    // index with a single fetch_add, so load balances dynamically without
    // per-worker queues (cells vastly outnumber lock transitions — each
    // worker touches the results mutex exactly once, at exit).
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, U)>> = Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                }
                results.lock().unwrap().extend(local);
            });
        }
    });
    // Claim order is racy; output order is not: sort back to input order.
    let mut tagged = results.into_inner().unwrap();
    tagged.sort_by_key(|&(i, _)| i);
    debug_assert_eq!(tagged.len(), items.len());
    tagged.into_iter().map(|(_, u)| u).collect()
}

/// A sensible default worker count for CLI `--jobs`-style flags: the
/// machine's available parallelism, or 1 when it cannot be queried.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn output_order_matches_input_for_any_job_count() {
        let items: Vec<usize> = (0..97).collect();
        let expect: Vec<usize> = items.iter().map(|&x| x * 3 + 1).collect();
        for jobs in [1, 2, 3, 8, 64, 200] {
            let got = parallel_map(jobs, &items, |_, &x| x * 3 + 1);
            assert_eq!(got, expect, "jobs={jobs}");
        }
    }

    #[test]
    fn indices_are_passed_through_and_each_item_runs_once() {
        let items = vec!["a", "b", "c", "d"];
        let calls = AtomicUsize::new(0);
        let got = parallel_map(3, &items, |i, &s| {
            calls.fetch_add(1, Ordering::Relaxed);
            format!("{i}:{s}")
        });
        assert_eq!(got, vec!["0:a", "1:b", "2:c", "3:d"]);
        assert_eq!(calls.load(Ordering::Relaxed), items.len());
    }

    #[test]
    fn empty_and_singleton_inputs_take_the_serial_path() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(8, &empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map(0, &[42u32], |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn uneven_work_still_returns_sorted_results() {
        // Early items sleep so late (fast) items finish first; the output
        // must still come back in input order.
        let items: Vec<u64> = (0..16).collect();
        let got = parallel_map(8, &items, |i, &x| {
            if i < 4 {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            x
        });
        assert_eq!(got, items);
    }

    #[test]
    fn default_jobs_is_at_least_one() {
        assert!(default_jobs() >= 1);
    }
}
