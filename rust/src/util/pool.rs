//! Scoped-thread work-stealing pool with deterministic output ordering.
//!
//! The offline build vendors no threading crates (rayon, crossbeam), so
//! this is the crate's own fan-out primitive: [`parallel_map`] evaluates a
//! pure function over a slice on `jobs` scoped threads. Scheduling is
//! **chunked work stealing with per-worker deques**: the input range is
//! split into one contiguous chunk per worker (cache-friendly; a worker
//! draining its own chunk only ever touches its own uncontended lock),
//! each worker pops indices from the front of its own deque, and a worker
//! that runs dry scans the others round-robin and *steals the back half*
//! of the first victim that still has work instead of idling. Uneven item
//! costs therefore never serialize the tail behind one unlucky worker — a
//! deque holding several expensive items (e.g. sim-enabled sweep cells
//! next to predict-only ones) is progressively redistributed in halves,
//! so redistribution events stay O(workers · log(items)) even though each
//! pop is still one (almost always uncontended) lock on the worker's own
//! deque.
//!
//! Results are re-sorted by input index before returning, so the output
//! `Vec` is **bit-identical to the serial path for any `jobs`**. That
//! determinism is what lets `repro sweep --jobs N` keep byte-identical
//! JSON and golden-baseline artifacts (asserted in
//! `rust/tests/pareto.rs`).
//!
//! `std::thread::scope` means borrowed inputs need no `'static` bound and
//! a panicking worker propagates on join instead of being silently lost
//! (surviving workers recover the poisoned result mutex, so the *first*
//! panic is the one that propagates, not a secondary `PoisonError`).
//! When one bad item must not abort the rest, use
//! [`parallel_map_fallible`]: it catches each item's panic into a typed
//! [`ReproError`] slot while keeping the same deterministic ordering.
//!
//! # Examples
//!
//! ```
//! use repro::util::pool::parallel_map;
//!
//! let items = [1u64, 2, 3, 4, 5];
//! let serial = parallel_map(1, &items, |_, &x| x * x);
//! let parallel = parallel_map(4, &items, |_, &x| x * x);
//! assert_eq!(serial, vec![1, 4, 9, 16, 25]);
//! assert_eq!(serial, parallel); // deterministic order for any job count
//! ```

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Mutex, MutexGuard};

use crate::util::error::ReproError;

/// Lock recovering from poisoning: a panic in one worker must not turn
/// every surviving worker's ordinary lock into a secondary `PoisonError`
/// panic that masks the original. The protected data (claimed indices,
/// completed results) stays consistent across a mid-`f` panic — the
/// deques and results vector are only mutated while no `f` runs.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Map `f` over `items` on up to `jobs` scoped threads, returning results
/// in input order (index `i` of the output is `f(i, &items[i])`).
///
/// * `jobs <= 1` (or a single-item/empty slice) runs entirely on the
///   caller's thread — the serial path, no threads spawned.
/// * `jobs` is clamped to `items.len()`; surplus workers are never
///   spawned.
/// * `f` must be pure with respect to ordering: it may run concurrently
///   with itself and in any claim order.
///
/// Scheduling: worker `w` starts with the `w`-th contiguous chunk of the
/// index range in a private deque and pops from its front; an idle worker
/// steals the back half of the first other deque (round-robin scan from
/// its right) that still has work, publishing the stolen half into its
/// own deque *before* releasing the victim's lock, so unclaimed work is
/// always visible in some deque. Because the task set is static (claimed
/// indices are never re-queued), a worker that finds every deque empty
/// can exit — all remaining work is already claimed by running workers.
///
/// Panics in `f` propagate to the caller once all workers have joined.
pub fn parallel_map<T, U, F>(jobs: usize, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    let jobs = jobs.clamp(1, n.max(1));
    if jobs <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // One deque per worker, seeded with its contiguous chunk of the
    // index range. A Mutex per deque (not one global lock) keeps the
    // owner's pops and a thief's steals from contending with unrelated
    // workers.
    let deques: Vec<Mutex<VecDeque<usize>>> = (0..jobs)
        .map(|w| Mutex::new((w * n / jobs..(w + 1) * n / jobs).collect()))
        .collect();
    let results: Mutex<Vec<(usize, U)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for w in 0..jobs {
            let deques = &deques;
            let results = &results;
            let f = &f;
            scope.spawn(move || {
                let mut local = Vec::new();
                loop {
                    // Fast path: drain the front of our own deque.
                    let next = lock(&deques[w]).pop_front();
                    if let Some(i) = next {
                        local.push((i, f(i, &items[i])));
                        continue;
                    }
                    // Own deque dry: steal the back half of the first
                    // victim (scanning round-robin from our right) that
                    // still has unclaimed work. Taking the *back* of the
                    // victim's chunk preserves its front-to-back locality.
                    // The stolen half is published into our own deque
                    // while the victim's lock is still held, so a
                    // concurrently scanning worker can never observe
                    // "all deques empty" while unclaimed work is in
                    // flight between two deques. Holding victim-then-own
                    // cannot deadlock: a thief's own deque is empty, and
                    // no worker locks a second deque unless that victim
                    // is non-empty — so no thief ever waits on another
                    // thief's (empty) deque while holding one.
                    let mut stole = false;
                    for off in 1..jobs {
                        let mut q = lock(&deques[(w + off) % jobs]);
                        if !q.is_empty() {
                            let steal = q.len().div_ceil(2);
                            let stolen = q.split_off(q.len() - steal);
                            *lock(&deques[w]) = stolen;
                            stole = true;
                            break;
                        }
                    }
                    if !stole {
                        // Every deque is empty: all indices are claimed
                        // (claimed work is never re-queued), so nothing is
                        // left to schedule.
                        break;
                    }
                }
                lock(results).extend(local);
            });
        }
    });
    // Claim order is racy; output order is not: sort back to input order.
    let mut tagged = results.into_inner().unwrap_or_else(|e| e.into_inner());
    tagged.sort_by_key(|&(i, _)| i);
    debug_assert_eq!(tagged.len(), items.len());
    tagged.into_iter().map(|(_, u)| u).collect()
}

/// Panic-safe fallible variant of [`parallel_map`]: every per-item call
/// is wrapped in `catch_unwind`, so one panicking item becomes one
/// `Err(ReproError::Internal)` slot instead of aborting the whole map
/// and discarding every completed result.
///
/// Guarantees, for any `jobs`:
///
/// * Output slot `i` is the outcome of item `i` (deterministic input
///   order, same as [`parallel_map`]).
/// * `Ok` slots are byte-for-byte what the all-success path produces — a
///   failing neighbor cannot perturb them.
/// * The serial (`jobs <= 1`) path catches panics identically, so
///   `--jobs 1` and `--jobs N` agree on failure shape too.
///
/// This is what makes per-cell fault isolation in `repro sweep` possible:
/// `sweep::run` maps cells through here and folds `Err` slots into the
/// report's `failures` section instead of crashing.
///
/// # Examples
///
/// ```
/// use repro::util::pool::parallel_map_fallible;
/// use repro::util::error::ReproError;
///
/// let items = [1u64, 2, 3, 4];
/// let out = parallel_map_fallible(4, &items, |_, &x| {
///     if x == 3 {
///         panic!("item three explodes");
///     }
///     Ok(x * x)
/// });
/// assert_eq!(out[0], Ok(1));
/// assert_eq!(out[3], Ok(16));
/// assert!(matches!(&out[2], Err(e) if e.contains("item three explodes")));
/// ```
pub fn parallel_map_fallible<T, U, F>(
    jobs: usize,
    items: &[T],
    f: F,
) -> Vec<Result<U, ReproError>>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> Result<U, ReproError> + Sync,
{
    // AssertUnwindSafe: `f` is `Fn` (shared-reference captures only) and
    // any interior state it touches is either per-call or consistent
    // under panic (the sweep's atomics/caches are); the catch exists to
    // contain the panic, not to re-enter broken state.
    parallel_map(jobs, items, |i, t| {
        catch_unwind(AssertUnwindSafe(|| f(i, t)))
            .unwrap_or_else(|payload| Err(ReproError::from_panic(payload)))
    })
}

/// A sensible default worker count for CLI `--jobs`-style flags: the
/// machine's available parallelism, or 1 when it cannot be queried.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn output_order_matches_input_for_any_job_count() {
        let items: Vec<usize> = (0..97).collect();
        let expect: Vec<usize> = items.iter().map(|&x| x * 3 + 1).collect();
        for jobs in [1, 2, 3, 8, 64, 200] {
            let got = parallel_map(jobs, &items, |_, &x| x * 3 + 1);
            assert_eq!(got, expect, "jobs={jobs}");
        }
    }

    #[test]
    fn indices_are_passed_through_and_each_item_runs_once() {
        let items = vec!["a", "b", "c", "d"];
        let calls = AtomicUsize::new(0);
        let got = parallel_map(3, &items, |i, &s| {
            calls.fetch_add(1, Ordering::Relaxed);
            format!("{i}:{s}")
        });
        assert_eq!(got, vec!["0:a", "1:b", "2:c", "3:d"]);
        assert_eq!(calls.load(Ordering::Relaxed), items.len());
    }

    #[test]
    fn empty_and_singleton_inputs_take_the_serial_path() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(8, &empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map(0, &[42u32], |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn uneven_work_still_returns_sorted_results() {
        // Early items sleep so late (fast) items finish first; the output
        // must still come back in input order.
        let items: Vec<u64> = (0..16).collect();
        let got = parallel_map(8, &items, |i, &x| {
            if i < 4 {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            x
        });
        assert_eq!(got, items);
    }

    #[test]
    fn skewed_front_chunk_is_redistributed_by_stealing() {
        // Adversarial for the *chunked* distribution: all the expensive
        // items land in worker 0's initial chunk. With per-worker deques
        // and no stealing the run would take ~8 x 5 ms serialized on one
        // worker; correctness-wise the output must be complete and sorted
        // whatever the steal interleaving.
        let items: Vec<u64> = (0..64).collect();
        for jobs in [2, 4, 8] {
            let claims = AtomicUsize::new(0);
            let got = parallel_map(jobs, &items, |i, &x| {
                claims.fetch_add(1, Ordering::Relaxed);
                if i < 8 {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                x * 2
            });
            assert_eq!(got, items.iter().map(|&x| x * 2).collect::<Vec<_>>(), "jobs={jobs}");
            assert_eq!(claims.load(Ordering::Relaxed), items.len(), "jobs={jobs}: exactly-once");
        }
    }

    #[test]
    fn large_random_cost_spread_stays_exactly_once_and_ordered() {
        // 1000 items whose costs vary by ~100x in a deterministic but
        // shuffled pattern: every index must be evaluated exactly once and
        // come back in order for every job count.
        let items: Vec<u64> = (0..1000).collect();
        let expect: Vec<u64> = items.iter().map(|&x| x + 7).collect();
        for jobs in [2, 5, 16] {
            let calls = AtomicUsize::new(0);
            let got = parallel_map(jobs, &items, |i, &x| {
                calls.fetch_add(1, Ordering::Relaxed);
                // Busy-work spread: a pseudo-random subset spins longer.
                let spin = if (i * 2654435761) % 97 < 5 { 20_000 } else { 200 };
                let mut acc = x;
                for k in 0..spin {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
                }
                std::hint::black_box(acc);
                x + 7
            });
            assert_eq!(got, expect, "jobs={jobs}");
            assert_eq!(calls.load(Ordering::Relaxed), items.len(), "jobs={jobs}");
        }
    }

    #[test]
    fn default_jobs_is_at_least_one() {
        assert!(default_jobs() >= 1);
    }

    /// The panic hook is process-global; tests that swap it must not
    /// overlap (the test harness runs tests on multiple threads).
    static HOOK_LOCK: Mutex<()> = Mutex::new(());

    /// Run `f` with the default panic hook silenced, so intentionally
    /// panicking tests don't spray backtraces into the test log.
    fn quiet_panics<R>(f: impl FnOnce() -> R) -> R {
        let _serialize = lock(&HOOK_LOCK);
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r = f();
        std::panic::set_hook(hook);
        r
    }

    #[test]
    fn fallible_map_isolates_a_panicking_item() {
        let items: Vec<u64> = (0..32).collect();
        let expect: Vec<Result<u64, ReproError>> = items
            .iter()
            .map(|&x| {
                if x == 13 {
                    Err(ReproError::Internal("panic: unlucky".to_string()))
                } else {
                    Ok(x + 1)
                }
            })
            .collect();
        quiet_panics(|| {
            for jobs in [1, 2, 4, 8] {
                let got = parallel_map_fallible(jobs, &items, |_, &x| {
                    if x == 13 {
                        panic!("unlucky");
                    }
                    Ok(x + 1)
                });
                assert_eq!(got, expect, "jobs={jobs}");
            }
        });
    }

    #[test]
    fn fallible_map_passes_err_returns_through_untouched() {
        let items = vec!["ok", "bad", "ok"];
        let got = parallel_map_fallible(2, &items, |i, &s| {
            if s == "bad" {
                Err(ReproError::allocation(format!("item {i} infeasible")))
            } else {
                Ok(s.len())
            }
        });
        assert_eq!(
            got,
            vec![Ok(2), Err(ReproError::Allocation("item 1 infeasible".to_string())), Ok(2)]
        );
    }

    #[test]
    fn fallible_map_success_path_matches_parallel_map() {
        let items: Vec<u64> = (0..100).collect();
        let plain = parallel_map(4, &items, |_, &x| x * 3);
        let fallible = parallel_map_fallible(4, &items, |_, &x| Ok(x * 3));
        assert_eq!(fallible.into_iter().collect::<Result<Vec<_>, _>>().unwrap(), plain);
    }

    #[test]
    fn fallible_map_survives_every_item_panicking() {
        let items: Vec<u64> = (0..16).collect();
        quiet_panics(|| {
            for jobs in [1, 4] {
                let got = parallel_map_fallible(jobs, &items, |i, _| -> Result<(), _> {
                    panic!("all fail ({i})")
                });
                assert_eq!(got.len(), items.len(), "jobs={jobs}");
                for (i, r) in got.iter().enumerate() {
                    assert!(
                        matches!(r, Err(e) if e.contains(&format!("all fail ({i})"))),
                        "jobs={jobs} item={i}: {r:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn infallible_map_panic_is_the_original_never_a_poison_error() {
        // Satellite regression: with any mutex left poisoned by a
        // panicking worker, surviving workers' plain `.lock().unwrap()`
        // would raise secondary PoisonError panics that mask the original.
        // Record every panic the process sees during the run: the
        // original must be there, PoisonError must not.
        use std::sync::Arc;

        let _serialize = lock(&HOOK_LOCK);
        let seen: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let record = Arc::clone(&seen);
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            lock(&record).push(info.to_string());
        }));
        let items: Vec<u64> = (0..64).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_map(4, &items, |i, &x| {
                if i == 0 {
                    panic!("original worker panic");
                }
                // Let survivors overlap the panicking worker's unwind.
                std::thread::sleep(std::time::Duration::from_millis(1));
                x
            });
        }));
        std::panic::set_hook(hook);
        assert!(result.is_err(), "the worker panic must still propagate to the caller");
        let seen = lock(&seen).clone();
        assert!(
            seen.iter().any(|m| m.contains("original worker panic")),
            "original panic missing from {seen:?}"
        );
        assert!(
            !seen.iter().any(|m| m.contains("PoisonError")),
            "a secondary PoisonError panic fired alongside the original: {seen:?}"
        );
    }
}
