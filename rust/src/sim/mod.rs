//! Cycle-level simulator of the streaming multi-CE accelerator.
//!
//! This is the substitute for the paper's FPGA implementation (see
//! DESIGN.md §Substitutions): a cycle-stepped model of the hybrid-CE
//! pipeline that reproduces the *architectural* behaviours the paper
//! evaluates — window availability under the fully-reused-FM vs line-based
//! schemes, padding congestion under direct-insert vs address-generated
//! padding (Fig 11), stride-induced bubbles, SCB delayed-buffer
//! synchronization (Fig 6), WRCE ping-pong global buffers, and the
//! resulting actual MAC efficiency / FPS (Fig 17, Table III).
//!
//! A pixel is one spatial position across all channels; FIFOs carry pixel
//! counts (timing, not values — numerics live in the [`crate::runtime`]
//! path).

pub mod ce;
pub mod converter;
pub mod engine;

pub use ce::{CeClass, CeConfig, PaddingMode};
pub use converter::OrderConverter;
pub use engine::{MainSrc, Pipeline, SideFifo, SimRunner, SimStats};

use crate::model::memory::{scb_delay_buffer_bytes, startup_latency_px, CeKind, CePlan, FmScheme};
use crate::model::throughput::LayerAlloc;
use crate::nets::{LayerKind, LayerSrc, Network};
use crate::util::error::ReproError;

/// Simulator options: the optimization toggles of Fig 17.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimOptions {
    /// Padding handling (Fig 11(a) vs (b)).
    pub padding: PaddingMode,
    /// FRCE FM-buffer scheme (Fig 6 comparison).
    pub scheme: FmScheme,
    /// Extra line for stride > 1 (Fig 11(c) vs (d)).
    pub stride_extra_line: bool,
    /// Record per-side-FIFO peak occupancy and high-water traces in
    /// [`SimStats`] (`fifo_*` fields). Off by default: the hot loop never
    /// touches the counters and the stats are byte-identical to an
    /// untracked run's timing figures.
    pub track_fifo: bool,
    /// Enable the no-progress cycle-skip fast path. Stats are identical
    /// either way (pinned by `skip_on_off_stats_identical_across_zoo`);
    /// disable only to exercise or diagnose the cycle-exact slow path.
    pub cycle_skip: bool,
    /// Run the event-driven engine ([`SimRunner`]) instead of the
    /// cycle-stepped reference loop. Stats are bit-identical either way
    /// (pinned by `event_on_off_stats_identical_across_zoo` and the
    /// differential/proptest suites); disable only to exercise or profile
    /// the stepped reference engine.
    pub event_driven: bool,
}

impl SimOptions {
    /// The paper's "baseline" dataflow (Fig 17 "original method without
    /// any optimizations"): conventional line-granular buffers (pixels are
    /// released a full line at a time), padding written through the input
    /// port (Fig 11(a)), no stride slack line (Fig 11(c)).
    pub fn baseline() -> Self {
        SimOptions {
            padding: PaddingMode::DirectInsert,
            scheme: FmScheme::LineBased,
            stride_extra_line: false,
            track_fifo: false,
            cycle_skip: true,
            event_driven: true,
        }
    }

    /// The proposed dataflow-oriented line buffer scheme (§IV-B).
    pub fn optimized() -> Self {
        SimOptions {
            padding: PaddingMode::AddressGenerated,
            scheme: FmScheme::FullyReusedFm,
            stride_extra_line: true,
            track_fifo: false,
            cycle_skip: true,
            event_driven: true,
        }
    }
}

impl Default for SimOptions {
    fn default() -> Self {
        Self::optimized()
    }
}

/// Build a simulated pipeline for `net` with per-layer parallelism
/// `allocs` and the FRCE/WRCE split of `plan`.
pub fn build_pipeline(net: &Network, allocs: &[LayerAlloc], plan: &CePlan, opts: &SimOptions) -> Pipeline {
    assert_eq!(allocs.len(), net.layers.len());
    let n = net.layers.len();
    let mut ces = Vec::with_capacity(n);
    let mut main_src = Vec::with_capacity(n);
    let mut join_side = vec![None; n];
    let mut out_taps: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut in_taps: Vec<Option<usize>> = vec![None; n];
    let mut source_taps: Vec<usize> = Vec::new();
    let mut fifos: Vec<SideFifo> = Vec::new();

    for (i, l) in net.layers.iter().enumerate() {
        let a = allocs[i];
        let kind = plan.kind(i);
        let class = match l.kind {
            LayerKind::Add | LayerKind::Concat => CeClass::Join,
            LayerKind::Shuffle | LayerKind::Split => CeClass::Passthrough,
            _ => CeClass::Compute,
        };
        let (quantum, pf, pes, macs_per_opos) = if l.kind.is_mac() {
            let rounds_w = (l.max_pw() as u64).div_ceil(a.pw as u64);
            (
                rounds_w * l.reduction_depth(),
                a.pf,
                a.pes(),
                l.macs() / l.out_positions() as u64,
            )
        } else {
            (1, 1, 0, l.macs() / l.out_positions() as u64)
        };
        // WRCE STC/PWC/FC buffer the whole input frame (ping-pong GFM);
        // WRCE DWC/pool stream location-first through a small window.
        let full_frame = kind == CeKind::Wrce
            && matches!(l.kind, LayerKind::Stc | LayerKind::Pwc | LayerKind::Fc);
        // The FM-scheme toggle applies to FRCE line buffers; WRCE windows
        // always use the minimal fully-reused window.
        let scheme = if kind == CeKind::Frce { opts.scheme } else { FmScheme::FullyReusedFm };
        let mut cfg = CeConfig {
            name: l.name.clone(),
            class,
            f_in: l.in_size,
            f_out: l.out_size,
            k: l.k,
            stride: l.stride,
            pad: l.pad,
            padding: opts.padding,
            scheme,
            stride_extra_line: opts.stride_extra_line,
            quantum_cycles: quantum,
            pf,
            pes,
            macs_per_opos,
            full_frame_buffer: full_frame,
            extra_capacity_px: 0,
            in_interval: 1,
        };
        // Provision the input bus to the CE's own steady-state demand:
        // compute-cycles-per-frame over arrivals-per-frame. MAC CEs with
        // long compute get narrow buses (floor >= 1); data-movement CEs
        // stream at full rate.
        if l.kind.is_mac() {
            let t_frame = quantum * (cfg.outputs_per_frame().div_ceil(pf as u64));
            // ~33% bus headroom over steady-state demand (a realistic
            // provisioning margin); §IV-B's demand peaks are >= 2x, so the
            // baseline congestion effects of Fig 11/17 still manifest.
            cfg.in_interval = (t_frame * 3 / 4 / cfg.arrivals_per_frame()).max(1);
        }
        // Quantum-fit: a P_f-position quantum must fit its whole window
        // span in the buffer (plus one slack pixel so the *next* arrival
        // can land while the quantum issues).
        let span = cfg.max_quantum_span() + 1;
        let base = cfg.formula_capacity_px();
        if span > base {
            cfg.extra_capacity_px = span - base;
        }
        ces.push(cfg);
        main_src.push(match l.src {
            LayerSrc::Prev if i == 0 => MainSrc::Source,
            LayerSrc::Prev => MainSrc::Ce(i - 1),
            LayerSrc::Tee(_) => MainSrc::Fifo(usize::MAX), // patched below
        });
    }

    // Tee FIFOs.
    for (i, l) in net.layers.iter().enumerate() {
        if let LayerSrc::Tee(j) = l.src {
            let src = &net.layers[j];
            let hold_px: u64 = net.layers[j..i].iter().map(|p| startup_latency_px(p, opts.scheme)).sum();
            let frame_px = (src.in_size * src.in_size) as u64;
            let capacity = if plan.kind(i) == CeKind::Frce {
                (hold_px + src.in_size as u64 + 16).min(2 * frame_px)
            } else {
                2 * frame_px // off-chip DRAM hold
            };
            let fi = fifos.len();
            fifos.push(SideFifo {
                producer: Some(j),
                tap_input: true,
                capacity,
                occupancy: 0,
                name: format!("tee->{}", l.name),
            });
            in_taps[j] = Some(fi);
            main_src[i] = MainSrc::Fifo(fi);
        }
    }

    // SCB shortcut FIFOs.
    for scb in &net.scbs {
        let join = scb.join_layer;
        let (f, _ch) = scb.snapshot_shape(net);
        let frame_px = (f * f) as u64;
        let capacity = if plan.kind(join) == CeKind::Frce {
            let model_px = scb_delay_buffer_bytes(net, scb, opts.scheme)
                / net.layers[scb.from_layer].in_ch.max(1) as u64;
            (model_px + f as u64 + 16).min(2 * frame_px)
        } else {
            2 * frame_px // off-chip DRAM hold
        };
        let fi = fifos.len();
        fifos.push(SideFifo {
            producer: if scb.from_layer == 0 { None } else { Some(scb.from_layer - 1) },
            tap_input: false,
            capacity,
            occupancy: 0,
            name: format!("scb->{}", net.layers[join].name),
        });
        join_side[join] = Some(fi);
        match scb.from_layer {
            0 => source_taps.push(fi),
            fl => out_taps[fl - 1].push(fi),
        }
    }

    let feeds_next: Vec<bool> = (0..n)
        .map(|i| i + 1 < n && net.layers[i + 1].src == LayerSrc::Prev)
        .collect();

    Pipeline {
        ces,
        main_src,
        join_side,
        out_taps,
        in_taps,
        source_taps,
        fifos,
        feeds_next,
        source_px_per_frame: (net.input_size * net.input_size) as u64,
        track_fifo: opts.track_fifo,
        cycle_skip: opts.cycle_skip,
        event_driven: opts.event_driven,
    }
}

/// Convenience wrapper: build, run, return stats. Half the frames (at
/// least one, and always leaving one measured frame) are treated as
/// warm-up; `frames == 0` is a [`ReproError::Config`] rather than an
/// underflow.
pub fn simulate(
    net: &Network,
    allocs: &[LayerAlloc],
    plan: &CePlan,
    opts: &SimOptions,
    frames: u64,
) -> Result<SimStats, ReproError> {
    let warmup = if frames == 0 { 0 } else { (frames / 2).max(1).min(frames - 1) };
    build_pipeline(net, allocs, plan, opts).run(frames, warmup)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{dynamic_parallelism_tuning, Granularity};
    use crate::model::throughput;
    use crate::nets::{mobilenet_v2, shufflenet_v2};
    use crate::zc706;

    fn mbv2_setup(dsp: usize) -> (crate::nets::Network, Vec<LayerAlloc>, CePlan) {
        let net = mobilenet_v2();
        let plan = CePlan { boundary: net.layers.len() / 2 };
        let p = dynamic_parallelism_tuning(&net, &plan, dsp, Granularity::Fgpm);
        (net, p.allocs, plan)
    }

    #[test]
    fn completes_without_deadlock() {
        let (net, allocs, plan) = mbv2_setup(zc706::DSP_BUDGET);
        let stats = simulate(&net, &allocs, &plan, &SimOptions::optimized(), 4).unwrap();
        assert_eq!(stats.frames, 4);
        assert!(stats.period_cycles > 0.0);
    }

    #[test]
    fn optimized_sim_close_to_theoretical_period() {
        // With the dataflow-oriented buffer scheme the actual period should
        // approach the Eq-14 bottleneck time (Fig 17: actual ~= theoretical
        // after optimization).
        // Use the implemented (ZC706) boundary: the deep-FRCE configuration
        // the paper actually builds. Mid-boundary WRCE-heavy plans pay a
        // few extra percent of full-frame hand-off (see EXPERIMENTS.md).
        let net = mobilenet_v2();
        let cfg = crate::model::memory::MemoryModelCfg::default();
        let plan = CePlan {
            boundary: crate::alloc::balanced_memory_allocation(&net, crate::zc706::SRAM_BYTES, &cfg).boundary,
        };
        let p = dynamic_parallelism_tuning(&net, &plan, zc706::DSP_BUDGET, Granularity::Fgpm);
        let allocs = p.allocs;
        let perf = throughput::evaluate(&net, &allocs);
        let stats = simulate(&net, &allocs, &plan, &SimOptions::optimized(), 12).unwrap();
        // The asynchronous full-frame (WRCE) hand-off adds a few percent
        // over the ideal frame-synchronous barrel pipeline; see
        // EXPERIMENTS.md (Fig 17 discussion).
        let ratio = stats.period_cycles / perf.t_max as f64;
        assert!(ratio < 1.10, "period {} vs t_max {} (ratio {ratio})", stats.period_cycles, perf.t_max);
        assert!(ratio >= 0.999, "sim faster than theory? ratio {ratio}");
    }

    #[test]
    fn baseline_padding_slower_than_optimized() {
        // Fig 17: direct-insert padding + missing stride line cost real
        // efficiency.
        let (net, allocs, plan) = mbv2_setup(zc706::DSP_BUDGET);
        let base = simulate(&net, &allocs, &plan, &SimOptions::baseline(), 8).unwrap();
        let opt = simulate(&net, &allocs, &plan, &SimOptions::optimized(), 8).unwrap();
        assert!(
            base.period_cycles > opt.period_cycles,
            "baseline {} <= optimized {}",
            base.period_cycles,
            opt.period_cycles
        );
    }

    #[test]
    fn shufflenet_two_branch_units_stream() {
        let net = shufflenet_v2();
        let plan = CePlan { boundary: net.layers.len() / 2 };
        let p = dynamic_parallelism_tuning(&net, &plan, zc706::DSP_BUDGET, Granularity::Fgpm);
        let stats = simulate(&net, &p.allocs, &plan, &SimOptions::optimized(), 4).unwrap();
        assert!(stats.mac_efficiency() > 0.5, "eff {}", stats.mac_efficiency());
    }

    #[test]
    fn all_wrce_plan_still_streams() {
        let (net, allocs, _) = mbv2_setup(512);
        let plan = CePlan { boundary: 0 };
        let stats = simulate(&net, &allocs, &plan, &SimOptions::optimized(), 3).unwrap();
        assert!(stats.period_cycles > 0.0);
    }

    #[test]
    fn skip_on_off_stats_identical_across_zoo() {
        // The no-progress cycle-skip fast path must be a pure wall-clock
        // optimization: every SimStats field — including the stall
        // taxonomy the skip path credits explicitly — byte-identical to
        // the cycle-exact slow path, on every zoo network.
        for net in crate::nets::all_networks() {
            let plan = CePlan { boundary: net.layers.len() / 2 };
            let p = dynamic_parallelism_tuning(&net, &plan, zc706::DSP_BUDGET, Granularity::Fgpm);
            let on = simulate(&net, &p.allocs, &plan, &SimOptions::optimized(), 2).unwrap();
            let off = simulate(
                &net,
                &p.allocs,
                &plan,
                &SimOptions { cycle_skip: false, ..SimOptions::optimized() },
                2,
            )
            .unwrap();
            assert_eq!(
                format!("{on:?}"),
                format!("{off:?}"),
                "skip-on vs skip-off stats diverge for {}",
                net.name
            );
        }
    }

    #[test]
    fn event_on_off_stats_identical_across_zoo() {
        // The event-driven engine must be a pure wall-clock optimization
        // over the stepped reference loop: every SimStats field —
        // including the bulk-credited stall taxonomy and the tracked FIFO
        // peaks/high-water traces — bit-identical, on every zoo network.
        for net in crate::nets::all_networks() {
            let plan = CePlan { boundary: net.layers.len() / 2 };
            let p = dynamic_parallelism_tuning(&net, &plan, zc706::DSP_BUDGET, Granularity::Fgpm);
            let opts = SimOptions { track_fifo: true, ..SimOptions::optimized() };
            let on = simulate(&net, &p.allocs, &plan, &opts, 2).unwrap();
            let off = simulate(
                &net,
                &p.allocs,
                &plan,
                &SimOptions { event_driven: false, ..opts },
                2,
            )
            .unwrap();
            assert_eq!(
                format!("{on:?}"),
                format!("{off:?}"),
                "event-driven vs stepped stats diverge for {}",
                net.name
            );
        }
    }

    #[test]
    fn zero_frames_is_a_typed_config_error() {
        // Regression: frames = 0 used to underflow the warm-up arithmetic
        // before the engine could reject it.
        let (net, allocs, plan) = mbv2_setup(zc706::DSP_BUDGET);
        let err = simulate(&net, &allocs, &plan, &SimOptions::optimized(), 0).unwrap_err();
        assert_eq!(err.kind(), "config");
        assert!(err.contains("at least 1 frame"), "{err}");
    }

    #[test]
    fn line_based_scheme_not_faster() {
        let (net, allocs, plan) = mbv2_setup(855);
        let fr = simulate(&net, &allocs, &plan, &SimOptions::optimized(), 8).unwrap();
        let lb = simulate(
            &net,
            &allocs,
            &plan,
            &SimOptions { scheme: FmScheme::LineBased, ..SimOptions::optimized() },
            8,
        )
        .unwrap();
        assert!(lb.period_cycles >= fr.period_cycles * 0.999);
    }
}
