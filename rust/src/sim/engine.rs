//! The cycle-stepped pipeline engine.
//!
//! Entities: a source streaming frames at one pixel per cycle, one
//! simulated CE per network layer (plus an optional order-converter CE at
//! the group boundary), and *side FIFOs* carrying SCB shortcut snapshots
//! and ShuffleNet tee streams. Inter-CE transfers move one pixel-vector
//! per cycle with credit-based backpressure; a transfer out of a branch
//! point commits to the main consumer and every attached side FIFO
//! atomically.

use super::ce::{CeClass, CeConfig, CeState};

/// Where a CE's main input stream comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MainSrc {
    Source,
    Ce(usize),
    /// Side FIFO index (tee branches).
    Fifo(usize),
}

/// A side FIFO: shortcut snapshot or tee stream.
#[derive(Debug, Clone)]
pub struct SideFifo {
    /// Producing CE (`None` = the network input source).
    pub producer: Option<usize>,
    /// `true`: filled when the producer CE *accepts* an input pixel (tee
    /// of a layer's input); `false`: filled when the producer emits output
    /// (SCB snapshot).
    pub tap_input: bool,
    pub capacity: u64,
    pub occupancy: u64,
    pub name: String,
}

/// A fully-assembled pipeline.
pub struct Pipeline {
    pub ces: Vec<CeConfig>,
    pub main_src: Vec<MainSrc>,
    /// Join CEs consume one pixel per quantum from this side FIFO.
    pub join_side: Vec<Option<usize>>,
    /// Side FIFOs a CE's *output* transfer must also fill.
    pub out_taps: Vec<Vec<usize>>,
    /// Side FIFO fed by a CE's accepted *input* pixels (tee), if any.
    pub in_taps: Vec<Option<usize>>,
    /// Side FIFOs fed directly by the source.
    pub source_taps: Vec<usize>,
    pub fifos: Vec<SideFifo>,
    /// Whether CE i's output feeds CE i+1's input (false when the next CE
    /// reads from a tee FIFO instead).
    pub feeds_next: Vec<bool>,
    /// Input pixels per frame at the source.
    pub source_px_per_frame: u64,
}

/// Simulation outcome statistics.
#[derive(Debug, Clone)]
pub struct SimStats {
    /// Steady-state cycles between consecutive frame completions.
    pub period_cycles: f64,
    /// Cycles until the first frame completed (pipeline fill + compute).
    pub first_frame_cycles: u64,
    pub total_cycles: u64,
    pub frames: u64,
    /// Per-CE busy cycles.
    pub busy_cycles: Vec<u64>,
    /// Per-CE stall-on-input / stall-on-output cycles.
    pub stall_input: Vec<u64>,
    pub stall_output: Vec<u64>,
    /// Per-CE true MACs per frame.
    pub macs_per_frame: Vec<u64>,
    /// Per-CE PE counts.
    pub pes: Vec<usize>,
    /// Per-CE cycle at which each frame's last output completed
    /// (`frame_done[ce][frame]`) — the pipeline-schedule trace.
    pub frame_done: Vec<Vec<u64>>,
}

impl SimStats {
    /// Actual whole-design MAC efficiency over the steady-state period:
    /// true MACs per frame over (period x total PEs).
    pub fn mac_efficiency(&self) -> f64 {
        // Count only PE-array MACs (SCB adds run on LUT adders).
        let total_macs: u64 = self
            .macs_per_frame
            .iter()
            .zip(&self.pes)
            .filter(|(_, &p)| p > 0)
            .map(|(&m, _)| m)
            .sum();
        let total_pes: usize = self.pes.iter().sum();
        total_macs as f64 / (self.period_cycles * total_pes as f64)
    }

    /// Per-CE actual efficiency (MAC CEs only; `None` for LUT datapaths).
    pub fn layer_efficiency(&self, i: usize) -> Option<f64> {
        if self.pes[i] == 0 {
            return None;
        }
        Some(self.macs_per_frame[i] as f64 / (self.period_cycles * self.pes[i] as f64))
    }

    /// Frames per second at the design clock.
    pub fn fps(&self, clock_hz: f64) -> f64 {
        clock_hz / self.period_cycles
    }

    /// Single-frame latency in milliseconds.
    pub fn latency_ms(&self, clock_hz: f64) -> f64 {
        self.first_frame_cycles as f64 / clock_hz * 1e3
    }
}

/// Error raised when the pipeline makes no progress (the deadlock the
/// paper's delayed-buffer sizing is designed to prevent).
#[derive(Debug)]
pub struct Deadlock {
    pub cycle: u64,
    pub detail: String,
}

impl std::fmt::Display for Deadlock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pipeline deadlock at cycle {}: {}", self.cycle, self.detail)
    }
}

impl std::error::Error for Deadlock {}

impl Pipeline {
    /// Stream `frames` frames through the pipeline and collect stats.
    /// `warmup` frames are excluded from the steady-state period estimate.
    pub fn run(&self, frames: u64, warmup: u64) -> Result<SimStats, Deadlock> {
        assert!(frames > warmup, "need at least one measured frame");
        let n = self.ces.len();
        let mut st: Vec<CeState> = vec![CeState::default(); n];
        let mut fifo_occ: Vec<u64> = self.fifos.iter().map(|f| f.occupancy).collect();
        let mut source_sent: u64 = 0;
        let source_total = self.source_px_per_frame * frames;
        let last = n - 1;
        let mut completion: Vec<u64> = Vec::with_capacity(frames as usize);
        let mut frame_done: Vec<Vec<u64>> = vec![Vec::with_capacity(frames as usize); n];
        let mut next_accept: Vec<u64> = vec![0; n];
        // Hot-loop hoists: these are pure functions of the static config.
        let caps: Vec<u64> = self.ces.iter().map(|c| c.capacity_px()).collect();
        let arrivals: Vec<u64> = self.ces.iter().map(|c| c.arrivals_per_frame()).collect();
        let outs: Vec<u64> = self.ces.iter().map(|c| c.outputs_per_frame()).collect();
        let mut cycle: u64 = 0;
        let mut last_progress: u64 = 0;
        // Deadlock horizon: a legitimate stall is bounded by one frame of
        // source streaming plus one bottleneck period; anything much longer
        // means a circular wait.
        let horizon = 2 * self.source_px_per_frame + 400_000;

        while (completion.len() as u64) < frames {
            let mut progress = false;

            // ---- Phase A: compute (issue, then tick, in one cycle so
            // back-to-back quanta pipeline without bubble cycles) ----------
            for i in 0..n {
                let cfg = &self.ces[i];
                let s = &mut st[i];
                if s.busy == 0 {
                    // Idle: try to issue the next quantum.
                    let of = outs[i];
                    if s.next_out + s.pending_out >= of * frames {
                        continue; // all work done
                    }
                    let start = s.next_out;
                    let in_frame = start % of;
                    let q = (cfg.pf as u64).min(of - in_frame);
                    // The required-arrival index is invariant while the CE
                    // waits on this quantum; cache it across stall cycles.
                    let need = if s.cached_for == start {
                        s.cached_need
                    } else {
                        let frame = start / of;
                        let end = in_frame + q - 1;
                        let need = frame * arrivals[i] + cfg.required_arrival(end);
                        s.cached_need = need;
                        s.cached_for = start;
                        need
                    };
                    let out_cap = (2 * cfg.pf as u64).max(4);
                    if s.recv <= need {
                        s.stall_input += 1;
                        continue;
                    }
                    if s.out_fifo + q > out_cap {
                        s.stall_output += 1;
                        continue;
                    }
                    if cfg.class == CeClass::Join {
                        let fi = self.join_side[i].expect("join without side fifo");
                        if fifo_occ[fi] < q {
                            s.stall_input += 1;
                            continue;
                        }
                        fifo_occ[fi] -= q;
                    }
                    s.busy = cfg.quantum_cycles;
                    s.pending_out = q;
                    progress = true;
                }
                // Tick the in-flight quantum.
                s.busy -= 1;
                s.busy_cycles += 1;
                if s.busy == 0 {
                    s.out_fifo += s.pending_out;
                    s.next_out += s.pending_out;
                    s.pending_out = 0;
                    progress = true;
                    let of = outs[i];
                    let done = s.next_out / of;
                    if done > s.frames_done {
                        for _ in s.frames_done..done.min(frames) {
                            frame_done[i].push(cycle);
                        }
                        s.frames_done = done;
                        if i == last {
                            for _ in completion.len() as u64..done.min(frames) {
                                completion.push(cycle);
                            }
                        }
                    }
                    // Release dead pixels (never beyond what has arrived).
                    let a = arrivals[i];
                    if cfg.full_frame_buffer {
                        s.freed = ((s.next_out / of) * a).min(s.recv);
                    } else if s.next_out < of * frames {
                        let frame = s.next_out / of;
                        s.freed = s.freed.max(frame * a + cfg.oldest_needed(s.next_out % of)).min(s.recv);
                    }
                }
            }

            // ---- Phase B: input acceptance + transfers --------------------
            for i in 0..n {
                let cfg = &self.ces[i];
                // The inter-CE bus is provisioned to the CE's steady-state
                // demand; accepts are paced accordingly.
                let a = arrivals[i];
                if cycle < next_accept[i] {
                    continue;
                }
                if st[i].recv >= a * frames {
                    continue;
                }
                if st[i].occupancy() >= caps[i] {
                    continue;
                }
                // Padding slot? Self-insert without touching upstream (the
                // write still occupies a bus/buffer-port slot — Fig 11(a)).
                if cfg.uses_padded_stream() && is_padding_slot(cfg, st[i].recv % a) {
                    st[i].recv += 1;
                    next_accept[i] = cycle + cfg.in_interval;
                    progress = true;
                    continue;
                }
                // Need a real pixel from the main source.
                let avail = match self.main_src[i] {
                    MainSrc::Source => source_sent < source_total,
                    MainSrc::Ce(p) => st[p].out_fifo > 0,
                    MainSrc::Fifo(fi) => fifo_occ[fi] > 0,
                };
                if !avail {
                    continue;
                }
                // The producing transfer must also fit every tap.
                if let Some(ti) = self.in_taps[i] {
                    if fifo_occ[ti] >= self.fifos[ti].capacity {
                        continue;
                    }
                }
                // Output taps gate the producer's emission (branch points).
                let taps: &[usize] = match self.main_src[i] {
                    MainSrc::Source => &self.source_taps,
                    MainSrc::Ce(p) => &self.out_taps[p],
                    MainSrc::Fifo(_) => &[],
                };
                if taps.iter().any(|&t| fifo_occ[t] >= self.fifos[t].capacity) {
                    continue;
                }
                // Commit.
                match self.main_src[i] {
                    MainSrc::Source => source_sent += 1,
                    MainSrc::Ce(p) => st[p].out_fifo -= 1,
                    MainSrc::Fifo(fi) => fifo_occ[fi] -= 1,
                }
                for &t in taps {
                    fifo_occ[t] += 1;
                }
                if let Some(ti) = self.in_taps[i] {
                    fifo_occ[ti] += 1;
                }
                st[i].recv += 1;
                next_accept[i] = cycle + cfg.in_interval;
                progress = true;
            }

            // Producers not consumed by the next CE still need to drain:
            // branch points whose output feeds only side FIFOs, and the
            // final sink CE (results leave the accelerator).
            for p in 0..n {
                if self.feeds_next[p] || st[p].out_fifo == 0 {
                    continue;
                }
                let taps = &self.out_taps[p];
                if taps.is_empty() {
                    // Sink: the host consumes results immediately.
                    st[p].out_fifo = 0;
                    progress = true;
                    continue;
                }
                if taps.iter().any(|&t| fifo_occ[t] >= self.fifos[t].capacity) {
                    continue;
                }
                st[p].out_fifo -= 1;
                for &t in taps {
                    fifo_occ[t] += 1;
                }
                progress = true;
            }

            if progress {
                last_progress = cycle;
            } else {
                // Cycle-skipping: with no transfer/issue/completion this
                // cycle, nothing can change until the nearest quantum
                // completion or bus-pacing release. Jump there in one step
                // (completions still land on their exact cycle because the
                // skip is the minimum of all pending timers).
                let mut skip = u64::MAX;
                for s in st.iter() {
                    if s.busy > 0 {
                        skip = skip.min(s.busy);
                    }
                }
                for &na in next_accept.iter() {
                    if na > cycle {
                        skip = skip.min(na - cycle);
                    }
                }
                if skip != u64::MAX && skip > 1 {
                    let adv = skip - 1; // the loop tail adds the final +1
                    for s in st.iter_mut() {
                        if s.busy > 0 {
                            s.busy -= adv;
                            s.busy_cycles += adv;
                        }
                    }
                    cycle += adv;
                }
                if cycle - last_progress > horizon {
                    let detail = self.deadlock_report(&st, &fifo_occ);
                    return Err(Deadlock { cycle, detail });
                }
            }
            cycle += 1;
        }

        // Steady-state period over the measured frames.
        let w = warmup as usize;
        let period = if completion.len() > w + 1 {
            (completion[completion.len() - 1] - completion[w]) as f64 / (completion.len() - 1 - w) as f64
        } else {
            completion[completion.len() - 1] as f64
        };
        Ok(SimStats {
            period_cycles: period,
            first_frame_cycles: completion[0],
            total_cycles: cycle,
            frames,
            busy_cycles: st.iter().map(|s| s.busy_cycles).collect(),
            stall_input: st.iter().map(|s| s.stall_input).collect(),
            stall_output: st.iter().map(|s| s.stall_output).collect(),
            macs_per_frame: self
                .ces
                .iter()
                .map(|c| c.macs_per_opos * c.outputs_per_frame())
                .collect(),
            pes: self.ces.iter().map(|c| c.pes).collect(),
            frame_done,
        })
    }

    fn deadlock_report(&self, st: &[CeState], fifo_occ: &[u64]) -> String {
        let mut s = String::new();
        for (i, (cfg, ce)) in self.ces.iter().zip(st).enumerate() {
            if ce.busy > 0 || ce.out_fifo > 0 || ce.occupancy() >= cfg.capacity_px() {
                s.push_str(&format!(
                    "CE{i} {}: recv={} freed={} occ={}/{} out_fifo={} next_out={} busy={}\n",
                    cfg.name,
                    ce.recv,
                    ce.freed,
                    ce.occupancy(),
                    cfg.capacity_px(),
                    ce.out_fifo,
                    ce.next_out,
                    ce.busy
                ));
            }
        }
        for (fi, f) in self.fifos.iter().enumerate() {
            s.push_str(&format!("FIFO{fi} {}: {}/{}\n", f.name, fifo_occ[fi], f.capacity));
        }
        s
    }
}

/// Whether arrival slot `idx` of a padded frame stream is a padding
/// position.
fn is_padding_slot(cfg: &CeConfig, idx: u64) -> bool {
    let fp = (cfg.f_in + 2 * cfg.pad) as u64;
    let p = cfg.pad as u64;
    let (r, c) = (idx / fp, idx % fp);
    r < p || r >= fp - p || c < p || c >= fp - p
}
