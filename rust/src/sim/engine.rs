//! The streaming pipeline engine — an event-driven fast path
//! ([`SimRunner`]) and the cycle-stepped reference engine, bit-identical
//! by construction and locked together by the differential suites.
//!
//! Entities: a source streaming frames at one pixel per cycle, one
//! simulated CE per network layer (plus an optional order-converter CE at
//! the group boundary), and *side FIFOs* carrying SCB shortcut snapshots
//! and ShuffleNet tee streams. Inter-CE transfers move one pixel-vector
//! per cycle with credit-based backpressure; a transfer out of a branch
//! point commits to the main consumer and every attached side FIFO
//! atomically.
//!
//! The reference engine evaluates every CE on every cycle in three
//! phases (A: issue/tick compute quanta, B: paced input acceptance +
//! transfers, then the drain pass for untapped producers). The
//! event-driven engine reproduces the exact same sweep order through a
//! min-heap keyed on `(cycle, phase, ce)` and only ever evaluates a CE
//! when something it depends on changed (a quantum completion, a pacing
//! release, an upstream transfer); the per-cycle stall counters the
//! stepped engine accumulates are credited in bulk from the parked
//! verdicts, which stay frozen between wake-ups by the same argument the
//! stepped engine's no-progress cycle-skip relies on.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::ce::{CeClass, CeConfig, CeState};
use crate::util::error::ReproError;

/// Where a CE's main input stream comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MainSrc {
    Source,
    Ce(usize),
    /// Side FIFO index (tee branches).
    Fifo(usize),
}

/// A side FIFO: shortcut snapshot or tee stream.
#[derive(Debug, Clone)]
pub struct SideFifo {
    /// Producing CE (`None` = the network input source).
    pub producer: Option<usize>,
    /// `true`: filled when the producer CE *accepts* an input pixel (tee
    /// of a layer's input); `false`: filled when the producer emits output
    /// (SCB snapshot).
    pub tap_input: bool,
    pub capacity: u64,
    pub occupancy: u64,
    pub name: String,
}

/// A fully-assembled pipeline.
pub struct Pipeline {
    pub ces: Vec<CeConfig>,
    pub main_src: Vec<MainSrc>,
    /// Join CEs consume one pixel per quantum from this side FIFO.
    pub join_side: Vec<Option<usize>>,
    /// Side FIFOs a CE's *output* transfer must also fill.
    pub out_taps: Vec<Vec<usize>>,
    /// Side FIFO fed by a CE's accepted *input* pixels (tee), if any.
    pub in_taps: Vec<Option<usize>>,
    /// Side FIFOs fed directly by the source.
    pub source_taps: Vec<usize>,
    pub fifos: Vec<SideFifo>,
    /// Whether CE i's output feeds CE i+1's input (false when the next CE
    /// reads from a tee FIFO instead).
    pub feeds_next: Vec<bool>,
    /// Input pixels per frame at the source.
    pub source_px_per_frame: u64,
    /// Record per-FIFO peak occupancy + high-water traces in [`SimStats`]
    /// (`fifo_*` fields stay empty when off, and the hot loop never
    /// touches the counters).
    pub track_fifo: bool,
    /// Enable the stepped engine's no-progress cycle-skip fast path;
    /// stats are identical either way, so this exists only to exercise
    /// the cycle-exact slow path in isolation.
    pub cycle_skip: bool,
    /// Run the event-driven engine ([`SimRunner`]); `false` falls back to
    /// the cycle-stepped reference engine. Stats are bit-identical either
    /// way — the knob exists for differential testing and for profiling
    /// the engines against each other.
    pub event_driven: bool,
}

/// Simulation outcome statistics.
#[derive(Debug, Clone)]
pub struct SimStats {
    /// Steady-state cycles between consecutive frame completions.
    pub period_cycles: f64,
    /// Cycles until the first frame completed (pipeline fill + compute).
    pub first_frame_cycles: u64,
    pub total_cycles: u64,
    pub frames: u64,
    /// Per-CE busy cycles.
    pub busy_cycles: Vec<u64>,
    /// Per-CE stall-on-input / stall-on-output cycles.
    pub stall_input: Vec<u64>,
    pub stall_output: Vec<u64>,
    /// Per-CE true MACs per frame.
    pub macs_per_frame: Vec<u64>,
    /// Per-CE PE counts.
    pub pes: Vec<usize>,
    /// Per-CE cycle at which each frame's last output completed
    /// (`frame_done[ce][frame]`) — the pipeline-schedule trace.
    pub frame_done: Vec<Vec<u64>>,
    /// Side-FIFO names in pipeline order (tee FIFOs, then SCB FIFOs).
    /// Empty — as are the three fields below — unless occupancy tracking
    /// was enabled via [`Pipeline::track_fifo`].
    pub fifo_names: Vec<String>,
    /// Per-FIFO provisioned capacity in pixels.
    pub fifo_capacity: Vec<u64>,
    /// Per-FIFO peak occupancy in pixels over the whole run.
    pub fifo_peak: Vec<u64>,
    /// Running peak per FIFO sampled at each completed output frame
    /// (`fifo_high_water[fifo][frame]`) — the occupancy high-water trace.
    pub fifo_high_water: Vec<Vec<u64>>,
}

impl SimStats {
    /// Actual whole-design MAC efficiency over the steady-state period:
    /// true MACs per frame over (period x total PEs). `0.0` when the
    /// design carries no PE arrays at all (an all-LUT pipeline) or the
    /// period is degenerate — never NaN.
    pub fn mac_efficiency(&self) -> f64 {
        // Count only PE-array MACs (SCB adds run on LUT adders).
        let total_macs: u64 = self
            .macs_per_frame
            .iter()
            .zip(&self.pes)
            .filter(|(_, &p)| p > 0)
            .map(|(&m, _)| m)
            .sum();
        let total_pes: usize = self.pes.iter().sum();
        if total_pes == 0 || self.period_cycles <= 0.0 {
            return 0.0;
        }
        total_macs as f64 / (self.period_cycles * total_pes as f64)
    }

    /// Per-CE actual efficiency (MAC CEs only; `None` for LUT datapaths,
    /// `Some(0.0)` on a degenerate period).
    pub fn layer_efficiency(&self, i: usize) -> Option<f64> {
        if self.pes[i] == 0 {
            return None;
        }
        if self.period_cycles <= 0.0 {
            return Some(0.0);
        }
        Some(self.macs_per_frame[i] as f64 / (self.period_cycles * self.pes[i] as f64))
    }

    /// Frames per second at the design clock (`0.0` on a degenerate
    /// period rather than an infinity that would poison JSON output).
    pub fn fps(&self, clock_hz: f64) -> f64 {
        if self.period_cycles <= 0.0 {
            return 0.0;
        }
        clock_hz / self.period_cycles
    }

    /// Single-frame latency in milliseconds.
    pub fn latency_ms(&self, clock_hz: f64) -> f64 {
        self.first_frame_cycles as f64 / clock_hz * 1e3
    }
}

/// Steady-state period estimate shared by both engines: the mean
/// completion gap over the measured (post-warm-up) frames. With a single
/// measured frame the old estimate fell back to the *absolute* completion
/// cycle — pipeline fill plus every prior period — which overstated the
/// period severalfold; use the last inter-completion gap instead, and
/// only fall back to the first completion cycle when one frame ran in
/// total (nothing else is observable then).
fn steady_period(completion: &[u64], warmup: u64) -> f64 {
    let last = completion.len() - 1;
    let w = (warmup as usize).min(last);
    if last > w {
        (completion[last] - completion[w]) as f64 / (last - w) as f64
    } else if last >= 1 {
        (completion[last] - completion[last - 1]) as f64
    } else {
        completion[0] as f64
    }
}

fn validate_frames(frames: u64) -> Result<(), ReproError> {
    if frames == 0 {
        return Err(ReproError::config(
            "simulate: need at least 1 frame to measure (got frames = 0)",
        ));
    }
    Ok(())
}

fn validate_warmup(frames: u64, warmup: u64) -> Result<(), ReproError> {
    if warmup >= frames {
        return Err(ReproError::config(format!(
            "simulate: {frames} frame(s) with a {warmup}-frame warm-up leaves no \
             measured frame (need frames > warmup)"
        )));
    }
    Ok(())
}

impl Pipeline {
    /// Stream `frames` frames through the pipeline and collect stats.
    /// `warmup` frames are excluded from the steady-state period estimate.
    ///
    /// Degenerate arguments (`frames == 0`, `warmup >= frames`) return
    /// [`ReproError::Config`]; a pipeline that stops making progress
    /// returns [`ReproError::Simulation`] carrying the per-CE/per-FIFO
    /// deadlock report (the failure the paper's delayed-buffer sizing is
    /// designed to prevent).
    pub fn run(&self, frames: u64, warmup: u64) -> Result<SimStats, ReproError> {
        validate_frames(frames)?;
        validate_warmup(frames, warmup)?;
        if self.event_driven {
            SimRunner::new(self, frames)?.finish(warmup)
        } else {
            self.run_stepped(frames, warmup)
        }
    }

    /// The cycle-stepped reference engine: every CE evaluated on every
    /// cycle. Kept verbatim as the differential baseline for
    /// [`SimRunner`] (`event_driven: false` routes here).
    fn run_stepped(&self, frames: u64, warmup: u64) -> Result<SimStats, ReproError> {
        let n = self.ces.len();
        let mut st: Vec<CeState> = vec![CeState::default(); n];
        let mut fifo_occ: Vec<u64> = self.fifos.iter().map(|f| f.occupancy).collect();
        let track = self.track_fifo;
        let mut fifo_peak: Vec<u64> = if track { fifo_occ.clone() } else { Vec::new() };
        let mut fifo_high_water: Vec<Vec<u64>> =
            vec![Vec::with_capacity(frames as usize); if track { self.fifos.len() } else { 0 }];
        let mut source_sent: u64 = 0;
        let source_total = self.source_px_per_frame * frames;
        let last = n - 1;
        let mut completion: Vec<u64> = Vec::with_capacity(frames as usize);
        let mut frame_done: Vec<Vec<u64>> = vec![Vec::with_capacity(frames as usize); n];
        let mut next_accept: Vec<u64> = vec![0; n];
        // Hot-loop hoists: these are pure functions of the static config.
        let caps: Vec<u64> = self.ces.iter().map(|c| c.capacity_px()).collect();
        let arrivals: Vec<u64> = self.ces.iter().map(|c| c.arrivals_per_frame()).collect();
        let outs: Vec<u64> = self.ces.iter().map(|c| c.outputs_per_frame()).collect();
        let mut cycle: u64 = 0;
        let mut last_progress: u64 = 0;
        // Deadlock horizon: a legitimate stall is bounded by one frame of
        // source streaming plus one bottleneck period; anything much longer
        // means a circular wait.
        let horizon = 2 * self.source_px_per_frame + 400_000;

        while (completion.len() as u64) < frames {
            let mut progress = false;

            // ---- Phase A: compute (issue, then tick, in one cycle so
            // back-to-back quanta pipeline without bubble cycles) ----------
            for i in 0..n {
                let cfg = &self.ces[i];
                let s = &mut st[i];
                if s.busy == 0 {
                    // Idle: try to issue the next quantum.
                    let of = outs[i];
                    if s.all_work_issued(of, frames) {
                        continue; // all work done
                    }
                    let start = s.next_out;
                    let in_frame = start % of;
                    let q = (cfg.pf as u64).min(of - in_frame);
                    // The required-arrival index is invariant while the CE
                    // waits on this quantum; cache it across stall cycles.
                    let need = if s.cached_for == start {
                        s.cached_need
                    } else {
                        let frame = start / of;
                        let end = in_frame + q - 1;
                        let need = frame * arrivals[i] + cfg.required_arrival(end);
                        s.cached_need = need;
                        s.cached_for = start;
                        need
                    };
                    let out_cap = (2 * cfg.pf as u64).max(4);
                    if s.recv <= need {
                        s.stall_input += 1;
                        continue;
                    }
                    if s.out_fifo + q > out_cap {
                        s.stall_output += 1;
                        continue;
                    }
                    if cfg.class == CeClass::Join {
                        let fi = self.join_side[i].expect("join without side fifo");
                        if fifo_occ[fi] < q {
                            s.stall_input += 1;
                            continue;
                        }
                        fifo_occ[fi] -= q;
                    }
                    s.busy = cfg.quantum_cycles;
                    s.pending_out = q;
                    progress = true;
                }
                // Tick the in-flight quantum.
                s.busy -= 1;
                s.busy_cycles += 1;
                if s.busy == 0 {
                    s.out_fifo += s.pending_out;
                    s.next_out += s.pending_out;
                    s.pending_out = 0;
                    progress = true;
                    let of = outs[i];
                    let done = s.next_out / of;
                    if done > s.frames_done {
                        for _ in s.frames_done..done.min(frames) {
                            frame_done[i].push(cycle);
                        }
                        s.frames_done = done;
                        if i == last {
                            for _ in completion.len() as u64..done.min(frames) {
                                completion.push(cycle);
                                for (t, hw) in fifo_high_water.iter_mut().enumerate() {
                                    hw.push(fifo_peak[t]);
                                }
                            }
                        }
                    }
                    // Release dead pixels (never beyond what has arrived).
                    let a = arrivals[i];
                    if cfg.full_frame_buffer {
                        s.freed = ((s.next_out / of) * a).min(s.recv);
                    } else if s.next_out < of * frames {
                        let frame = s.next_out / of;
                        s.freed = s.freed.max(frame * a + cfg.oldest_needed(s.next_out % of)).min(s.recv);
                    }
                }
            }

            // ---- Phase B: input acceptance + transfers --------------------
            for i in 0..n {
                let cfg = &self.ces[i];
                // The inter-CE bus is provisioned to the CE's steady-state
                // demand; accepts are paced accordingly.
                let a = arrivals[i];
                if cycle < next_accept[i] {
                    continue;
                }
                if st[i].recv >= a * frames {
                    continue;
                }
                if st[i].occupancy() >= caps[i] {
                    continue;
                }
                // Padding slot? Self-insert without touching upstream (the
                // write still occupies a bus/buffer-port slot — Fig 11(a)).
                if cfg.uses_padded_stream() && is_padding_slot(cfg, st[i].recv % a) {
                    st[i].recv += 1;
                    next_accept[i] = cycle + cfg.in_interval;
                    progress = true;
                    continue;
                }
                // Need a real pixel from the main source.
                let avail = match self.main_src[i] {
                    MainSrc::Source => source_sent < source_total,
                    MainSrc::Ce(p) => st[p].out_fifo > 0,
                    MainSrc::Fifo(fi) => fifo_occ[fi] > 0,
                };
                if !avail {
                    continue;
                }
                // The producing transfer must also fit every tap.
                if let Some(ti) = self.in_taps[i] {
                    if fifo_occ[ti] >= self.fifos[ti].capacity {
                        continue;
                    }
                }
                // Output taps gate the producer's emission (branch points).
                let taps: &[usize] = match self.main_src[i] {
                    MainSrc::Source => &self.source_taps,
                    MainSrc::Ce(p) => &self.out_taps[p],
                    MainSrc::Fifo(_) => &[],
                };
                if taps.iter().any(|&t| fifo_occ[t] >= self.fifos[t].capacity) {
                    continue;
                }
                // Commit.
                match self.main_src[i] {
                    MainSrc::Source => source_sent += 1,
                    MainSrc::Ce(p) => st[p].out_fifo -= 1,
                    MainSrc::Fifo(fi) => fifo_occ[fi] -= 1,
                }
                for &t in taps {
                    fifo_occ[t] += 1;
                    if track && fifo_occ[t] > fifo_peak[t] {
                        fifo_peak[t] = fifo_occ[t];
                    }
                }
                if let Some(ti) = self.in_taps[i] {
                    fifo_occ[ti] += 1;
                    if track && fifo_occ[ti] > fifo_peak[ti] {
                        fifo_peak[ti] = fifo_occ[ti];
                    }
                }
                st[i].recv += 1;
                next_accept[i] = cycle + cfg.in_interval;
                progress = true;
            }

            // Producers not consumed by the next CE still need to drain:
            // branch points whose output feeds only side FIFOs, and the
            // final sink CE (results leave the accelerator).
            for p in 0..n {
                if self.feeds_next[p] || st[p].out_fifo == 0 {
                    continue;
                }
                let taps = &self.out_taps[p];
                if taps.is_empty() {
                    // Sink: the host consumes results immediately.
                    st[p].out_fifo = 0;
                    progress = true;
                    continue;
                }
                if taps.iter().any(|&t| fifo_occ[t] >= self.fifos[t].capacity) {
                    continue;
                }
                st[p].out_fifo -= 1;
                for &t in taps {
                    fifo_occ[t] += 1;
                    if track && fifo_occ[t] > fifo_peak[t] {
                        fifo_peak[t] = fifo_occ[t];
                    }
                }
                progress = true;
            }

            if progress {
                last_progress = cycle;
            } else {
                // Cycle-skipping: with no transfer/issue/completion this
                // cycle, nothing can change until the nearest quantum
                // completion or bus-pacing release. Jump there in one step
                // (completions still land on their exact cycle because the
                // skip is the minimum of all pending timers).
                let mut skip = u64::MAX;
                for s in st.iter() {
                    if s.busy > 0 {
                        skip = skip.min(s.busy);
                    }
                }
                for &na in next_accept.iter() {
                    if na > cycle {
                        skip = skip.min(na - cycle);
                    }
                }
                if self.cycle_skip && skip != u64::MAX && skip > 1 {
                    let adv = skip - 1; // the loop tail adds the final +1
                    for (i, s) in st.iter_mut().enumerate() {
                        if s.busy > 0 {
                            s.busy -= adv;
                            s.busy_cycles += adv;
                            continue;
                        }
                        // An idle CE replays the exact same stall verdict on
                        // every skipped cycle (none of its inputs can change
                        // strictly inside the span), so credit the counter
                        // the slow path would have bumped — this is what
                        // keeps skip-on and skip-off stats byte-identical.
                        let of = outs[i];
                        if s.all_work_issued(of, frames) {
                            continue; // all work done: Phase A bumps nothing
                        }
                        let cfg = &self.ces[i];
                        let q = (cfg.pf as u64).min(of - s.next_out % of);
                        if s.recv <= s.cached_need {
                            s.stall_input += adv;
                        } else if s.out_fifo + q > (2 * cfg.pf as u64).max(4) {
                            s.stall_output += adv;
                        } else {
                            // Only a join CE starved by its side FIFO can
                            // still have failed to issue this cycle.
                            s.stall_input += adv;
                        }
                    }
                    cycle += adv;
                }
                // Declare deadlock only when *nothing* is pending: an
                // in-flight quantum timer or a future bus-pacing release
                // always leads to an event (a completion is itself
                // progress), so a long stall with `skip != MAX` is
                // legitimate — e.g. a single quantum longer than the
                // horizon, where the skip advance used to trip this check
                // before the pending completion landed (false deadlock).
                if skip == u64::MAX && cycle - last_progress > horizon {
                    return Err(ReproError::simulation(format!(
                        "pipeline deadlock at cycle {cycle}: {}",
                        self.deadlock_report(&st, &fifo_occ)
                    )));
                }
            }
            cycle += 1;
        }

        Ok(SimStats {
            period_cycles: steady_period(&completion, warmup),
            first_frame_cycles: completion[0],
            total_cycles: cycle,
            frames,
            busy_cycles: st.iter().map(|s| s.busy_cycles).collect(),
            stall_input: st.iter().map(|s| s.stall_input).collect(),
            stall_output: st.iter().map(|s| s.stall_output).collect(),
            macs_per_frame: self
                .ces
                .iter()
                .map(|c| c.macs_per_opos * c.outputs_per_frame())
                .collect(),
            pes: self.ces.iter().map(|c| c.pes).collect(),
            frame_done,
            fifo_names: if track { self.fifos.iter().map(|f| f.name.clone()).collect() } else { Vec::new() },
            fifo_capacity: if track { self.fifos.iter().map(|f| f.capacity).collect() } else { Vec::new() },
            fifo_peak,
            fifo_high_water,
        })
    }

    fn deadlock_report(&self, st: &[CeState], fifo_occ: &[u64]) -> String {
        let mut s = String::new();
        for (i, (cfg, ce)) in self.ces.iter().zip(st).enumerate() {
            if ce.busy > 0 || ce.out_fifo > 0 || ce.occupancy() >= cfg.capacity_px() {
                s.push_str(&format!(
                    "CE{i} {}: recv={} freed={} occ={}/{} out_fifo={} next_out={} busy={}\n",
                    cfg.name,
                    ce.recv,
                    ce.freed,
                    ce.occupancy(),
                    cfg.capacity_px(),
                    ce.out_fifo,
                    ce.next_out,
                    ce.busy
                ));
            }
        }
        for (fi, f) in self.fifos.iter().enumerate() {
            s.push_str(&format!("FIFO{fi} {}: {}/{}\n", f.name, fifo_occ[fi], f.capacity));
        }
        s
    }
}

/// Event phases within a cycle, mirroring the stepped engine's sweep
/// order: compute issue/complete, then input acceptance, then the drain
/// pass for untapped producers.
const PH_A: u8 = 0;
const PH_B: u8 = 1;
const PH_D: u8 = 2;

/// Min-heap of `(cycle, phase, ce)` — `Reverse` flips `BinaryHeap`'s max
/// ordering, and the tuple order reproduces the stepped engine's
/// phase-A → phase-B → drain, index-ascending sweep within a cycle.
type EventHeap = BinaryHeap<Reverse<(u64, u8, usize)>>;

/// What verdict an idle CE is parked on, so the stall cycles the stepped
/// engine would have accumulated one-by-one can be credited in bulk at
/// the next wake-up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Park {
    None,
    Input,
    Output,
}

/// Schedule a wake for `(phase, i)` at cycle `at`. `slot` holds the
/// earliest pending wake per CE; a later request while an earlier one is
/// pending is dropped — safe because every evaluation either re-arms its
/// own next deadline (pacing) or is re-woken by the state change that
/// made the later request (all wake edges are re-derived per event, not
/// remembered).
fn sched(heap: &mut EventHeap, slot: &mut [u64], phase: u8, i: usize, at: u64) {
    if at < slot[i] {
        slot[i] = at;
        heap.push(Reverse((at, phase, i)));
    }
}

/// The event-driven engine behind [`Pipeline::run`].
///
/// Holds the full mid-run pipeline state, so multi-frame studies can pay
/// the pipeline fill once: [`SimRunner::advance_to`] runs the event loop
/// up to a frame count, the runner is `Clone`, and a warm clone resumed
/// with [`SimRunner::finish`] yields stats bit-identical to a cold run
/// (pinned by `warm_runner_clone_resumes_identically`).
#[derive(Clone)]
pub struct SimRunner<'p> {
    pipe: &'p Pipeline,
    frames: u64,
    // Static hoists — pure functions of the pipeline config.
    caps: Vec<u64>,
    arrivals: Vec<u64>,
    outs: Vec<u64>,
    source_total: u64,
    horizon: u64,
    last: usize,
    /// Per FIFO: CEs whose Phase-B pull is gated by this FIFO's free
    /// space (their producing transfer must also fill it).
    gated_pull: Vec<Vec<usize>>,
    /// Per FIFO: untapped producers whose drain pass fills it.
    gated_drain: Vec<Vec<usize>>,
    /// Per FIFO: the CE whose accepted inputs fill it (tee tapper).
    tee_tapper: Vec<Option<usize>>,
    /// Per FIFO: the CE reading it as its main source (tee consumer).
    tee_consumer: Vec<Option<usize>>,
    /// Per FIFO: the join CE consuming it as its side input.
    join_of: Vec<Option<usize>>,
    /// Per CE: the CE reading its output FIFO as main source.
    ce_consumer: Vec<Option<usize>>,
    // Dynamic state — the same variables the stepped loop keeps.
    st: Vec<CeState>,
    fifo_occ: Vec<u64>,
    fifo_peak: Vec<u64>,
    fifo_high_water: Vec<Vec<u64>>,
    source_sent: u64,
    completion: Vec<u64>,
    frame_done: Vec<Vec<u64>>,
    next_accept: Vec<u64>,
    // Event bookkeeping.
    heap: EventHeap,
    wake_a: Vec<u64>,
    wake_b: Vec<u64>,
    wake_d: Vec<u64>,
    /// Pending quantum-completion cycle per CE (`u64::MAX` = idle).
    completion_at: Vec<u64>,
    issue_cycle: Vec<u64>,
    park_at: Vec<u64>,
    park_kind: Vec<Park>,
    last_progress: u64,
    last_cycle: u64,
}

impl<'p> SimRunner<'p> {
    /// Prepare an event-driven run of `frames` frames over `pipe`.
    pub fn new(pipe: &'p Pipeline, frames: u64) -> Result<Self, ReproError> {
        validate_frames(frames)?;
        let n = pipe.ces.len();
        let nf = pipe.fifos.len();
        let mut gated_pull: Vec<Vec<usize>> = vec![Vec::new(); nf];
        let mut gated_drain: Vec<Vec<usize>> = vec![Vec::new(); nf];
        let mut tee_tapper: Vec<Option<usize>> = vec![None; nf];
        let mut tee_consumer: Vec<Option<usize>> = vec![None; nf];
        let mut join_of: Vec<Option<usize>> = vec![None; nf];
        let mut ce_consumer: Vec<Option<usize>> = vec![None; n];
        for i in 0..n {
            match pipe.main_src[i] {
                MainSrc::Source => {
                    for &t in &pipe.source_taps {
                        gated_pull[t].push(i);
                    }
                }
                MainSrc::Ce(p) => {
                    ce_consumer[p] = Some(i);
                    for &t in &pipe.out_taps[p] {
                        gated_pull[t].push(i);
                    }
                }
                MainSrc::Fifo(fi) => tee_consumer[fi] = Some(i),
            }
            if let Some(ti) = pipe.in_taps[i] {
                tee_tapper[ti] = Some(i);
            }
            if let Some(fi) = pipe.join_side[i] {
                join_of[fi] = Some(i);
            }
            if !pipe.feeds_next[i] {
                for &t in &pipe.out_taps[i] {
                    gated_drain[t].push(i);
                }
            }
        }
        let track = pipe.track_fifo;
        let fifo_occ: Vec<u64> = pipe.fifos.iter().map(|f| f.occupancy).collect();
        let mut heap = EventHeap::new();
        let mut wake_d = vec![u64::MAX; n];
        // Every CE is evaluated at cycle 0, exactly like the stepped
        // engine's first iteration (untapped producers join the drain
        // pass from the start; it no-ops while their out FIFO is empty).
        for i in 0..n {
            heap.push(Reverse((0, PH_A, i)));
            heap.push(Reverse((0, PH_B, i)));
            if !pipe.feeds_next[i] {
                wake_d[i] = 0;
                heap.push(Reverse((0, PH_D, i)));
            }
        }
        Ok(SimRunner {
            pipe,
            frames,
            caps: pipe.ces.iter().map(|c| c.capacity_px()).collect(),
            arrivals: pipe.ces.iter().map(|c| c.arrivals_per_frame()).collect(),
            outs: pipe.ces.iter().map(|c| c.outputs_per_frame()).collect(),
            source_total: pipe.source_px_per_frame * frames,
            horizon: 2 * pipe.source_px_per_frame + 400_000,
            last: n - 1,
            gated_pull,
            gated_drain,
            tee_tapper,
            tee_consumer,
            join_of,
            ce_consumer,
            st: vec![CeState::default(); n],
            fifo_peak: if track { fifo_occ.clone() } else { Vec::new() },
            fifo_occ,
            fifo_high_water: vec![Vec::with_capacity(frames as usize); if track { nf } else { 0 }],
            source_sent: 0,
            completion: Vec::with_capacity(frames as usize),
            frame_done: vec![Vec::with_capacity(frames as usize); n],
            next_accept: vec![0; n],
            heap,
            wake_a: vec![0; n],
            wake_b: vec![0; n],
            wake_d,
            completion_at: vec![u64::MAX; n],
            issue_cycle: vec![0; n],
            park_at: vec![0; n],
            park_kind: vec![Park::None; n],
            last_progress: 0,
            last_cycle: 0,
        })
    }

    /// Frames fully completed so far.
    pub fn frames_completed(&self) -> u64 {
        self.completion.len() as u64
    }

    /// Run the event loop until `frames` frames have completed (clamped
    /// to the run's total). Advancing one frame at a time is bit-identical
    /// to one shot — pausing the loop at a frame milestone changes no
    /// state.
    pub fn advance_to(&mut self, frames: u64) -> Result<(), ReproError> {
        let target = frames.min(self.frames);
        while (self.completion.len() as u64) < target {
            // Find the earliest cycle holding a live event; everything
            // else in the heap is a superseded wake. An empty heap means
            // no timer and no wake can ever fire again — the same "nothing
            // pending" condition the stepped engine's horizon check
            // detects, reported at the identical cycle.
            let cycle = loop {
                match self.heap.peek() {
                    None => {
                        let at = (self.last_progress + self.horizon + 1).max(self.last_cycle);
                        return Err(ReproError::simulation(format!(
                            "pipeline deadlock at cycle {at}: {}",
                            self.pipe.deadlock_report(&self.st, &self.fifo_occ)
                        )));
                    }
                    Some(&Reverse((c, ph, i))) => {
                        if self.is_live(c, ph, i) {
                            break c;
                        }
                        self.heap.pop();
                    }
                }
            };
            // Drain the whole cycle in heap order — phase A, then B, then
            // the drain pass, index-ascending within each — including
            // events pushed while processing it (a quantum issued with
            // `quantum_cycles == 1` completes this same cycle, after
            // lower-indexed pending entries, exactly like the stepped
            // sweep).
            while let Some(&Reverse((c, ph, i))) = self.heap.peek() {
                if c != cycle {
                    break;
                }
                self.heap.pop();
                if !self.is_live(c, ph, i) {
                    continue;
                }
                match ph {
                    PH_A => {
                        if self.completion_at[i] == c {
                            self.complete(i, c);
                        } else {
                            self.eval_issue(i, c);
                        }
                    }
                    PH_B => self.eval_accept(i, c),
                    _ => self.eval_drain(i, c),
                }
            }
            self.last_cycle = cycle;
        }
        Ok(())
    }

    /// Run to the end and produce the stats. Consumes the runner: the
    /// bulk busy/stall credits for states still parked at the final cycle
    /// are applied here, exactly once.
    pub fn finish(mut self, warmup: u64) -> Result<SimStats, ReproError> {
        validate_warmup(self.frames, warmup)?;
        self.advance_to(self.frames)?;
        Ok(self.into_stats(warmup))
    }

    fn is_live(&self, c: u64, ph: u8, i: usize) -> bool {
        match ph {
            PH_A => self.completion_at[i] == c || self.wake_a[i] == c,
            PH_B => self.wake_b[i] == c,
            _ => self.wake_d[i] == c,
        }
    }

    /// Phase A for an idle CE: credit the parked stall span, then replay
    /// the stepped engine's issue logic at cycle `c`.
    fn eval_issue(&mut self, i: usize, c: u64) {
        self.wake_a[i] = u64::MAX;
        if self.completion_at[i] != u64::MAX {
            return; // mid-quantum: a stray wake must not re-issue
        }
        // The stepped engine re-evaluates an idle CE every cycle, and the
        // verdict is frozen strictly inside (park_at, c): any input change
        // would have scheduled an earlier wake. Credit those cycles now.
        match self.park_kind[i] {
            Park::Input => self.st[i].stall_input += c - self.park_at[i] - 1,
            Park::Output => self.st[i].stall_output += c - self.park_at[i] - 1,
            Park::None => {}
        }
        self.park_kind[i] = Park::None;
        let pipe = self.pipe;
        let cfg = &pipe.ces[i];
        let of = self.outs[i];
        let frames = self.frames;
        let s = &mut self.st[i];
        if s.all_work_issued(of, frames) {
            return; // all work done: Phase A bumps nothing
        }
        let start = s.next_out;
        let in_frame = start % of;
        let q = (cfg.pf as u64).min(of - in_frame);
        let need = if s.cached_for == start {
            s.cached_need
        } else {
            let frame = start / of;
            let end = in_frame + q - 1;
            let need = frame * self.arrivals[i] + cfg.required_arrival(end);
            s.cached_need = need;
            s.cached_for = start;
            need
        };
        let out_cap = (2 * cfg.pf as u64).max(4);
        if s.recv <= need {
            s.stall_input += 1;
            self.park_at[i] = c;
            self.park_kind[i] = Park::Input;
            return;
        }
        if s.out_fifo + q > out_cap {
            s.stall_output += 1;
            self.park_at[i] = c;
            self.park_kind[i] = Park::Output;
            return;
        }
        if cfg.class == CeClass::Join {
            let fi = pipe.join_side[i].expect("join without side fifo");
            if self.fifo_occ[fi] < q {
                s.stall_input += 1;
                self.park_at[i] = c;
                self.park_kind[i] = Park::Input;
                return;
            }
            self.fifo_occ[fi] -= q;
            // The snapshot drain un-gates pullers and parked drain passes
            // this same cycle (Phase B and the drain pass run after A).
            for &g in &self.gated_pull[fi] {
                sched(&mut self.heap, &mut self.wake_b, PH_B, g, c.max(self.next_accept[g]));
            }
            for &g in &self.gated_drain[fi] {
                sched(&mut self.heap, &mut self.wake_d, PH_D, g, c);
            }
        }
        let s = &mut self.st[i];
        s.pending_out = q;
        self.issue_cycle[i] = c;
        let comp = c + cfg.quantum_cycles - 1;
        self.completion_at[i] = comp;
        self.heap.push(Reverse((comp, PH_A, i)));
        self.last_progress = c;
    }

    /// Phase A for a completing quantum: deliver outputs, free dead
    /// pixels, record frame milestones — then wake everyone the stepped
    /// engine's next sweep would have found unblocked.
    fn complete(&mut self, i: usize, c: u64) {
        self.completion_at[i] = u64::MAX;
        let pipe = self.pipe;
        let cfg = &pipe.ces[i];
        let of = self.outs[i];
        let frames = self.frames;
        let a = self.arrivals[i];
        let s = &mut self.st[i];
        // The stepped engine ticked this CE once per cycle of the quantum.
        s.busy_cycles += cfg.quantum_cycles;
        s.out_fifo += s.pending_out;
        s.next_out += s.pending_out;
        s.pending_out = 0;
        let done = s.next_out / of;
        if done > s.frames_done {
            let from = s.frames_done;
            s.frames_done = done;
            for _ in from..done.min(frames) {
                self.frame_done[i].push(c);
            }
            if i == self.last {
                for _ in self.completion.len() as u64..done.min(frames) {
                    self.completion.push(c);
                    for (t, hw) in self.fifo_high_water.iter_mut().enumerate() {
                        hw.push(self.fifo_peak[t]);
                    }
                }
            }
        }
        let s = &mut self.st[i];
        if cfg.full_frame_buffer {
            s.freed = ((s.next_out / of) * a).min(s.recv);
        } else if s.next_out < of * frames {
            let frame = s.next_out / of;
            s.freed = s.freed.max(frame * a + cfg.oldest_needed(s.next_out % of)).min(s.recv);
        }
        // The now-idle PE array may issue next cycle. Overwrite (not
        // `sched`): a superseded same-cycle wake entry must not trigger a
        // premature issue in this cycle's remaining phase-A drain.
        self.wake_a[i] = c + 1;
        self.heap.push(Reverse((c + 1, PH_A, i)));
        // Freed pixels may clear this CE's own occupancy gate, and the
        // delivered outputs feed the consumer — both visible to Phase B
        // this same cycle.
        sched(&mut self.heap, &mut self.wake_b, PH_B, i, c.max(self.next_accept[i]));
        if let Some(k) = self.ce_consumer[i] {
            sched(&mut self.heap, &mut self.wake_b, PH_B, k, c.max(self.next_accept[k]));
        }
        if !pipe.feeds_next[i] {
            sched(&mut self.heap, &mut self.wake_d, PH_D, i, c);
        }
        self.last_progress = c;
    }

    /// Phase B: paced input acceptance + the atomic transfer commit.
    fn eval_accept(&mut self, i: usize, c: u64) {
        self.wake_b[i] = u64::MAX;
        let pipe = self.pipe;
        let cfg = &pipe.ces[i];
        let a = self.arrivals[i];
        if c < self.next_accept[i] {
            // Paced: re-arm exactly at the release cycle. Attempts
            // strictly before it would all hit this same guard.
            let at = self.next_accept[i];
            sched(&mut self.heap, &mut self.wake_b, PH_B, i, at);
            return;
        }
        if self.st[i].recv >= a * self.frames {
            return; // stream fully accepted — permanently idle
        }
        if self.st[i].occupancy() >= self.caps[i] {
            return; // woken when this CE's next completion frees pixels
        }
        if cfg.uses_padded_stream() && is_padding_slot(cfg, self.st[i].recv % a) {
            self.st[i].recv += 1;
            self.next_accept[i] = c + cfg.in_interval;
            let at = self.next_accept[i];
            sched(&mut self.heap, &mut self.wake_a, PH_A, i, c + 1);
            sched(&mut self.heap, &mut self.wake_b, PH_B, i, at);
            self.last_progress = c;
            return;
        }
        let avail = match pipe.main_src[i] {
            MainSrc::Source => self.source_sent < self.source_total,
            MainSrc::Ce(p) => self.st[p].out_fifo > 0,
            MainSrc::Fifo(fi) => self.fifo_occ[fi] > 0,
        };
        if !avail {
            return; // woken by the producer's completion / the tee's fill
        }
        if let Some(ti) = pipe.in_taps[i] {
            if self.fifo_occ[ti] >= pipe.fifos[ti].capacity {
                return; // woken when the tee consumer drains it
            }
        }
        let taps: &[usize] = match pipe.main_src[i] {
            MainSrc::Source => &pipe.source_taps,
            MainSrc::Ce(p) => &pipe.out_taps[p],
            MainSrc::Fifo(_) => &[],
        };
        if taps.iter().any(|&t| self.fifo_occ[t] >= pipe.fifos[t].capacity) {
            return; // woken when the gating join drains the snapshot
        }
        // Commit — identical to the stepped Phase B.
        match pipe.main_src[i] {
            MainSrc::Source => self.source_sent += 1,
            MainSrc::Ce(p) => {
                self.st[p].out_fifo -= 1;
                // The producer's output-FIFO gate may clear next cycle.
                sched(&mut self.heap, &mut self.wake_a, PH_A, p, c + 1);
            }
            MainSrc::Fifo(fi) => {
                self.fifo_occ[fi] -= 1;
                if let Some(j) = self.tee_tapper[fi] {
                    // The tapper sits earlier in the chain (j < i): the
                    // freed slot is visible to its Phase B next cycle.
                    sched(
                        &mut self.heap,
                        &mut self.wake_b,
                        PH_B,
                        j,
                        (c + 1).max(self.next_accept[j]),
                    );
                }
            }
        }
        let track = pipe.track_fifo;
        for &t in taps {
            self.fifo_occ[t] += 1;
            if track && self.fifo_occ[t] > self.fifo_peak[t] {
                self.fifo_peak[t] = self.fifo_occ[t];
            }
            if let Some(j) = self.join_of[t] {
                sched(&mut self.heap, &mut self.wake_a, PH_A, j, c + 1);
            }
        }
        if let Some(ti) = pipe.in_taps[i] {
            self.fifo_occ[ti] += 1;
            if track && self.fifo_occ[ti] > self.fifo_peak[ti] {
                self.fifo_peak[ti] = self.fifo_occ[ti];
            }
            if let Some(k) = self.tee_consumer[ti] {
                // Tee consumers sit later in the chain (k > i): the fill
                // is visible to their Phase B this same cycle.
                sched(&mut self.heap, &mut self.wake_b, PH_B, k, c.max(self.next_accept[k]));
            }
        }
        self.st[i].recv += 1;
        self.next_accept[i] = c + cfg.in_interval;
        let at = self.next_accept[i];
        sched(&mut self.heap, &mut self.wake_a, PH_A, i, c + 1);
        sched(&mut self.heap, &mut self.wake_b, PH_B, i, at);
        self.last_progress = c;
    }

    /// The drain pass for a producer not consumed by the next CE: the
    /// sink hands everything to the host at once; a tapped branch point
    /// moves one pixel per cycle into its side FIFOs.
    fn eval_drain(&mut self, p: usize, c: u64) {
        self.wake_d[p] = u64::MAX;
        let pipe = self.pipe;
        if self.st[p].out_fifo == 0 {
            return; // refilled (and re-woken) by this producer's completion
        }
        let taps = &pipe.out_taps[p];
        if taps.is_empty() {
            // Sink: the host consumes results immediately.
            self.st[p].out_fifo = 0;
            sched(&mut self.heap, &mut self.wake_a, PH_A, p, c + 1);
            self.last_progress = c;
            return;
        }
        if taps.iter().any(|&t| self.fifo_occ[t] >= pipe.fifos[t].capacity) {
            return; // woken when the gating join drains the snapshot
        }
        self.st[p].out_fifo -= 1;
        let track = pipe.track_fifo;
        for &t in taps {
            self.fifo_occ[t] += 1;
            if track && self.fifo_occ[t] > self.fifo_peak[t] {
                self.fifo_peak[t] = self.fifo_occ[t];
            }
            if let Some(j) = self.join_of[t] {
                sched(&mut self.heap, &mut self.wake_a, PH_A, j, c + 1);
            }
        }
        sched(&mut self.heap, &mut self.wake_a, PH_A, p, c + 1);
        if self.st[p].out_fifo > 0 {
            sched(&mut self.heap, &mut self.wake_d, PH_D, p, c + 1);
        }
        self.last_progress = c;
    }

    /// Final bulk credits + stats assembly. Every CE still parked (or
    /// mid-quantum) at the last processed cycle gets the per-cycle
    /// stall/busy bumps the stepped engine accumulated through that
    /// cycle; the parked verdicts are frozen through it because every
    /// wake at or before it has been processed.
    fn into_stats(mut self, warmup: u64) -> SimStats {
        let last_cycle = self.last_cycle;
        for i in 0..self.pipe.ces.len() {
            if self.completion_at[i] != u64::MAX {
                self.st[i].busy_cycles += last_cycle - self.issue_cycle[i] + 1;
            } else {
                match self.park_kind[i] {
                    Park::Input => self.st[i].stall_input += last_cycle - self.park_at[i],
                    Park::Output => self.st[i].stall_output += last_cycle - self.park_at[i],
                    Park::None => {}
                }
            }
        }
        let track = self.pipe.track_fifo;
        SimStats {
            period_cycles: steady_period(&self.completion, warmup),
            first_frame_cycles: self.completion[0],
            total_cycles: last_cycle + 1,
            frames: self.frames,
            busy_cycles: self.st.iter().map(|s| s.busy_cycles).collect(),
            stall_input: self.st.iter().map(|s| s.stall_input).collect(),
            stall_output: self.st.iter().map(|s| s.stall_output).collect(),
            macs_per_frame: self
                .pipe
                .ces
                .iter()
                .map(|c| c.macs_per_opos * c.outputs_per_frame())
                .collect(),
            pes: self.pipe.ces.iter().map(|c| c.pes).collect(),
            frame_done: self.frame_done,
            fifo_names: if track {
                self.pipe.fifos.iter().map(|f| f.name.clone()).collect()
            } else {
                Vec::new()
            },
            fifo_capacity: if track {
                self.pipe.fifos.iter().map(|f| f.capacity).collect()
            } else {
                Vec::new()
            },
            fifo_peak: self.fifo_peak,
            fifo_high_water: self.fifo_high_water,
        }
    }
}

/// Whether arrival slot `idx` of a padded frame stream is a padding
/// position.
fn is_padding_slot(cfg: &CeConfig, idx: u64) -> bool {
    let fp = (cfg.f_in + 2 * cfg.pad) as u64;
    let p = cfg.pad as u64;
    let (r, c) = (idx / fp, idx % fp);
    r < p || r >= fp - p || c < p || c >= fp - p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::memory::FmScheme;
    use crate::sim::ce::PaddingMode;

    /// A minimal streaming 1x1 compute CE (k=1: 1:1 arrival/output map).
    fn stream_ce(name: &str, f: usize, quantum: u64, pf: usize) -> CeConfig {
        CeConfig {
            name: name.into(),
            class: CeClass::Compute,
            f_in: f,
            f_out: f,
            k: 1,
            stride: 1,
            pad: 0,
            padding: PaddingMode::AddressGenerated,
            scheme: FmScheme::FullyReusedFm,
            stride_extra_line: false,
            quantum_cycles: quantum,
            pf,
            pes: 1,
            macs_per_opos: 1,
            full_frame_buffer: false,
            extra_capacity_px: 0,
            in_interval: 1,
        }
    }

    /// One compute CE fed straight from the source, draining to the host.
    fn single_ce_pipeline(ce: CeConfig, source_px: u64) -> Pipeline {
        Pipeline {
            ces: vec![ce],
            main_src: vec![MainSrc::Source],
            join_side: vec![None],
            out_taps: vec![Vec::new()],
            in_taps: vec![None],
            source_taps: Vec::new(),
            fifos: Vec::new(),
            feeds_next: vec![false],
            source_px_per_frame: source_px,
            track_fifo: false,
            cycle_skip: true,
            event_driven: true,
        }
    }

    /// Source -> producer CE -> full-frame (WRCE-style) CE -> join CE,
    /// with one side FIFO snapshotting the producer's output into the
    /// join — the minimal SCB shape.
    fn scb_pipeline(side_capacity: u64) -> Pipeline {
        let producer = stream_ce("producer", 4, 1, 1);
        let mut middle = stream_ce("middle", 4, 1, 1);
        middle.full_frame_buffer = true;
        let mut join = stream_ce("join", 4, 1, 4);
        join.class = CeClass::Join;
        join.pes = 0;
        Pipeline {
            ces: vec![producer, middle, join],
            main_src: vec![MainSrc::Source, MainSrc::Ce(0), MainSrc::Ce(1)],
            join_side: vec![None, None, Some(0)],
            out_taps: vec![vec![0], Vec::new(), Vec::new()],
            in_taps: vec![None; 3],
            source_taps: Vec::new(),
            fifos: vec![SideFifo {
                producer: Some(0),
                tap_input: false,
                capacity: side_capacity,
                occupancy: 0,
                name: "scb->join".into(),
            }],
            feeds_next: vec![true, true, false],
            source_px_per_frame: 16,
            track_fifo: false,
            cycle_skip: true,
            event_driven: true,
        }
    }

    /// Run the same pipeline through both engines and require the exact
    /// same outcome — every `SimStats` field (via `Debug`, which covers
    /// all of them) or the identical typed deadlock error.
    fn assert_engines_agree(p: &mut Pipeline, frames: u64, warmup: u64) {
        p.event_driven = true;
        let event = p.run(frames, warmup);
        p.event_driven = false;
        let stepped = p.run(frames, warmup);
        p.event_driven = true;
        match (event, stepped) {
            (Ok(a), Ok(b)) => assert_eq!(format!("{a:?}"), format!("{b:?}")),
            (Err(a), Err(b)) => assert_eq!(a, b),
            (a, b) => panic!("engines disagree on outcome:\nevent:   {a:?}\nstepped: {b:?}"),
        }
    }

    #[test]
    fn quantum_longer_than_horizon_is_not_a_deadlock() {
        // Regression: one quantum of 1M cycles dwarfs the progress horizon
        // (2*64 + 400_000). The stepped engine's cycle-skip advance lands
        // past the horizon in a single jump, and the old `cycle -
        // last_progress > horizon` check fired before the pending
        // completion could count as progress; the event engine's heap
        // holds the completion timer, so its "nothing pending" condition
        // can't fire either. Both runs must complete — identically.
        let mut ce = stream_ce("extreme", 8, 1_000_000, 1);
        ce.in_interval = 1;
        let mut p = single_ce_pipeline(ce, 64);
        let stats = p.run(1, 0).expect("extreme quantum falsely reported as deadlock");
        assert_eq!(stats.frames, 1);
        // Each of the 64 one-position quanta stalls far past the horizon.
        assert!(stats.total_cycles > 2 * 64 + 400_000, "total {}", stats.total_cycles);
        assert_engines_agree(&mut p, 1, 0);
    }

    #[test]
    fn undersized_side_fifo_deadlocks_with_named_report() {
        // Capacity 2 while the join consumes 4 per quantum: the FIFO
        // saturates at 2/2, the gated producer backs up (out_fifo full),
        // the full-frame middle CE never sees a whole frame — a circular
        // wait, i.e. exactly the failure the paper's delayed-buffer sizing
        // prevents.
        let mut p = scb_pipeline(2);
        let err = p.run(1, 0).expect_err("undersized FIFO must deadlock");
        assert_eq!(err.kind(), "simulation");
        assert!(err.contains("scb->join"), "missing FIFO name: {err}");
        assert!(err.contains("2/2"), "missing saturated occupancy: {err}");
        assert!(err.contains("producer"), "missing stalled CE: {err}");
        assert!(err.to_string().contains("pipeline deadlock at cycle"));
        // The stepped engine reports the identical error (cycle + detail).
        p.event_driven = false;
        let stepped = p.run(1, 0).expect_err("stepped engine must agree on the deadlock");
        assert_eq!(err, stepped);
    }

    #[test]
    fn model_sized_side_fifo_streams_and_tracks_peaks() {
        // 2*frame_px is the builder's WRCE-join provision; with it the same
        // pipeline streams, and tracking reports peaks within capacity plus
        // a monotone per-frame high-water trace.
        let mut p = scb_pipeline(32);
        p.track_fifo = true;
        let frames = 3;
        let stats = p.run(frames, 1).expect("model-sized FIFO must stream");
        assert_eq!(stats.fifo_names, vec!["scb->join".to_string()]);
        assert_eq!(stats.fifo_capacity, vec![32]);
        assert_eq!(stats.fifo_peak.len(), 1);
        assert!(stats.fifo_peak[0] > 0 && stats.fifo_peak[0] <= 32, "peak {}", stats.fifo_peak[0]);
        let hw = &stats.fifo_high_water[0];
        assert_eq!(hw.len(), frames as usize);
        assert!(hw.windows(2).all(|w| w[0] <= w[1]), "trace not monotone: {hw:?}");
        assert!(*hw.last().unwrap() <= stats.fifo_peak[0]);
        // Untracked runs keep the stats fields empty (zero-cost default).
        let untracked = scb_pipeline(32).run(frames, 1).unwrap();
        assert!(untracked.fifo_names.is_empty() && untracked.fifo_peak.is_empty());
        assert!(untracked.fifo_high_water.is_empty());
        assert_eq!(untracked.period_cycles, stats.period_cycles);
    }

    #[test]
    fn event_engine_matches_stepped_across_shapes() {
        // Bit-identical stats across the SCB shape (joins, a full-frame
        // WRCE, a gated branch point), tracked and untracked, streaming
        // and deadlocking, at several frame/warm-up counts — and with the
        // stepped engine's own cycle-skip disabled (the cycle-exact slow
        // path), closing the triangle event == skip == exact.
        for frames in [1, 2, 3] {
            let mut p = scb_pipeline(32);
            p.track_fifo = true;
            assert_engines_agree(&mut p, frames, frames - 1);
            assert_engines_agree(&mut p, frames, 0);
        }
        let mut exact = scb_pipeline(32);
        exact.track_fifo = true;
        exact.cycle_skip = false;
        assert_engines_agree(&mut exact, 3, 1);
        // Deadlock agreement (typed error, cycle, and report) at a
        // capacity between "streams" and the 2-px case above.
        assert_engines_agree(&mut scb_pipeline(3), 2, 0);
    }

    #[test]
    fn all_lut_pipeline_mac_efficiency_is_zero_not_nan() {
        // Regression: every CE on LUT adders (pes == 0) used to make
        // `mac_efficiency` divide by zero and return NaN, which then
        // poisoned JSON output and report tables.
        let mut ce = stream_ce("lut_only", 4, 1, 1);
        ce.pes = 0;
        let stats = single_ce_pipeline(ce, 16).run(2, 1).unwrap();
        assert_eq!(stats.mac_efficiency(), 0.0);
        assert!(stats.mac_efficiency().is_finite());
        assert_eq!(stats.layer_efficiency(0), None);
    }

    #[test]
    fn degenerate_run_arguments_are_typed_config_errors() {
        // Regression: `--frames` at or below the warm-up count used to
        // trip an `assert!` deep in the engine — reachable from user
        // input; both degenerate shapes must now surface as
        // `ReproError::Config`.
        let p = scb_pipeline(32);
        let err = p.run(0, 0).expect_err("frames = 0 must be rejected");
        assert_eq!(err.kind(), "config");
        assert!(err.contains("at least 1 frame"), "{err}");
        let err = p.run(2, 2).expect_err("warmup >= frames must be rejected");
        assert_eq!(err.kind(), "config");
        assert!(err.contains("no measured frame"), "{err}");
        let mut stepped = scb_pipeline(32);
        stepped.event_driven = false;
        assert_eq!(stepped.run(2, 3).unwrap_err().kind(), "config");
    }

    #[test]
    fn single_measured_frame_period_is_the_last_gap_and_rates_stay_finite() {
        // Regression: with exactly one measured frame the old period
        // estimate fell back to the absolute completion cycle (fill +
        // every prior period), overstating the period severalfold.
        let p = scb_pipeline(32);
        // frames=1: only the first completion is observable.
        let one = p.run(1, 0).unwrap();
        assert_eq!(one.period_cycles, one.first_frame_cycles as f64);
        // frames=2, warmup=1 (the sweep's default shape): the period must
        // be the last inter-completion gap, not fill + run.
        let two = p.run(2, 1).unwrap();
        let fd = &two.frame_done[2];
        assert_eq!(two.period_cycles, (fd[1] - fd[0]) as f64);
        assert!(two.period_cycles <= two.first_frame_cycles as f64);
        // A degenerate zero period can't divide through to NaN/inf.
        let zeroed = SimStats { period_cycles: 0.0, ..two.clone() };
        assert_eq!(zeroed.fps(1e8), 0.0);
        assert_eq!(zeroed.mac_efficiency(), 0.0);
        assert_eq!(zeroed.layer_efficiency(0), Some(0.0));
    }

    #[test]
    fn incremental_advance_and_warm_clone_match_one_shot() {
        // Warm-state reuse: advancing frame-by-frame, and resuming a
        // cloned mid-run runner, must both be bit-identical to a cold
        // one-shot run — this is what lets multi-frame studies pay the
        // pipeline fill once.
        let mut p = scb_pipeline(32);
        p.track_fifo = true;
        let one_shot = p.run(5, 1).unwrap();
        let mut runner = SimRunner::new(&p, 5).unwrap();
        for f in 1..=5 {
            runner.advance_to(f).unwrap();
            assert_eq!(runner.frames_completed(), f);
        }
        let warm = runner.clone();
        let stats = runner.finish(1).unwrap();
        assert_eq!(format!("{stats:?}"), format!("{one_shot:?}"));
        // The clone finishes independently with its own exit credits.
        let warm_stats = warm.finish(1).unwrap();
        assert_eq!(format!("{warm_stats:?}"), format!("{one_shot:?}"));
        // A clone taken mid-fill (before any completion) also agrees.
        let mut base = SimRunner::new(&p, 5).unwrap();
        base.advance_to(2).unwrap();
        let resumed = base.clone().finish(1).unwrap();
        assert_eq!(format!("{resumed:?}"), format!("{one_shot:?}"));
        assert_eq!(SimRunner::new(&p, 0).unwrap_err().kind(), "config");
    }
}
