//! The cycle-stepped pipeline engine.
//!
//! Entities: a source streaming frames at one pixel per cycle, one
//! simulated CE per network layer (plus an optional order-converter CE at
//! the group boundary), and *side FIFOs* carrying SCB shortcut snapshots
//! and ShuffleNet tee streams. Inter-CE transfers move one pixel-vector
//! per cycle with credit-based backpressure; a transfer out of a branch
//! point commits to the main consumer and every attached side FIFO
//! atomically.

use super::ce::{CeClass, CeConfig, CeState};

/// Where a CE's main input stream comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MainSrc {
    Source,
    Ce(usize),
    /// Side FIFO index (tee branches).
    Fifo(usize),
}

/// A side FIFO: shortcut snapshot or tee stream.
#[derive(Debug, Clone)]
pub struct SideFifo {
    /// Producing CE (`None` = the network input source).
    pub producer: Option<usize>,
    /// `true`: filled when the producer CE *accepts* an input pixel (tee
    /// of a layer's input); `false`: filled when the producer emits output
    /// (SCB snapshot).
    pub tap_input: bool,
    pub capacity: u64,
    pub occupancy: u64,
    pub name: String,
}

/// A fully-assembled pipeline.
pub struct Pipeline {
    pub ces: Vec<CeConfig>,
    pub main_src: Vec<MainSrc>,
    /// Join CEs consume one pixel per quantum from this side FIFO.
    pub join_side: Vec<Option<usize>>,
    /// Side FIFOs a CE's *output* transfer must also fill.
    pub out_taps: Vec<Vec<usize>>,
    /// Side FIFO fed by a CE's accepted *input* pixels (tee), if any.
    pub in_taps: Vec<Option<usize>>,
    /// Side FIFOs fed directly by the source.
    pub source_taps: Vec<usize>,
    pub fifos: Vec<SideFifo>,
    /// Whether CE i's output feeds CE i+1's input (false when the next CE
    /// reads from a tee FIFO instead).
    pub feeds_next: Vec<bool>,
    /// Input pixels per frame at the source.
    pub source_px_per_frame: u64,
    /// Record per-FIFO peak occupancy + high-water traces in [`SimStats`]
    /// (`fifo_*` fields stay empty when off, and the hot loop never
    /// touches the counters).
    pub track_fifo: bool,
    /// Enable the no-progress cycle-skip fast path; stats are identical
    /// either way, so this exists only to exercise the cycle-exact slow
    /// path in isolation.
    pub cycle_skip: bool,
}

/// Simulation outcome statistics.
#[derive(Debug, Clone)]
pub struct SimStats {
    /// Steady-state cycles between consecutive frame completions.
    pub period_cycles: f64,
    /// Cycles until the first frame completed (pipeline fill + compute).
    pub first_frame_cycles: u64,
    pub total_cycles: u64,
    pub frames: u64,
    /// Per-CE busy cycles.
    pub busy_cycles: Vec<u64>,
    /// Per-CE stall-on-input / stall-on-output cycles.
    pub stall_input: Vec<u64>,
    pub stall_output: Vec<u64>,
    /// Per-CE true MACs per frame.
    pub macs_per_frame: Vec<u64>,
    /// Per-CE PE counts.
    pub pes: Vec<usize>,
    /// Per-CE cycle at which each frame's last output completed
    /// (`frame_done[ce][frame]`) — the pipeline-schedule trace.
    pub frame_done: Vec<Vec<u64>>,
    /// Side-FIFO names in pipeline order (tee FIFOs, then SCB FIFOs).
    /// Empty — as are the three fields below — unless occupancy tracking
    /// was enabled via [`Pipeline::track_fifo`].
    pub fifo_names: Vec<String>,
    /// Per-FIFO provisioned capacity in pixels.
    pub fifo_capacity: Vec<u64>,
    /// Per-FIFO peak occupancy in pixels over the whole run.
    pub fifo_peak: Vec<u64>,
    /// Running peak per FIFO sampled at each completed output frame
    /// (`fifo_high_water[fifo][frame]`) — the occupancy high-water trace.
    pub fifo_high_water: Vec<Vec<u64>>,
}

impl SimStats {
    /// Actual whole-design MAC efficiency over the steady-state period:
    /// true MACs per frame over (period x total PEs).
    pub fn mac_efficiency(&self) -> f64 {
        // Count only PE-array MACs (SCB adds run on LUT adders).
        let total_macs: u64 = self
            .macs_per_frame
            .iter()
            .zip(&self.pes)
            .filter(|(_, &p)| p > 0)
            .map(|(&m, _)| m)
            .sum();
        let total_pes: usize = self.pes.iter().sum();
        total_macs as f64 / (self.period_cycles * total_pes as f64)
    }

    /// Per-CE actual efficiency (MAC CEs only; `None` for LUT datapaths).
    pub fn layer_efficiency(&self, i: usize) -> Option<f64> {
        if self.pes[i] == 0 {
            return None;
        }
        Some(self.macs_per_frame[i] as f64 / (self.period_cycles * self.pes[i] as f64))
    }

    /// Frames per second at the design clock.
    pub fn fps(&self, clock_hz: f64) -> f64 {
        clock_hz / self.period_cycles
    }

    /// Single-frame latency in milliseconds.
    pub fn latency_ms(&self, clock_hz: f64) -> f64 {
        self.first_frame_cycles as f64 / clock_hz * 1e3
    }
}

/// Error raised when the pipeline makes no progress (the deadlock the
/// paper's delayed-buffer sizing is designed to prevent).
#[derive(Debug)]
pub struct Deadlock {
    pub cycle: u64,
    pub detail: String,
}

impl std::fmt::Display for Deadlock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pipeline deadlock at cycle {}: {}", self.cycle, self.detail)
    }
}

impl std::error::Error for Deadlock {}

impl Pipeline {
    /// Stream `frames` frames through the pipeline and collect stats.
    /// `warmup` frames are excluded from the steady-state period estimate.
    pub fn run(&self, frames: u64, warmup: u64) -> Result<SimStats, Deadlock> {
        assert!(frames > warmup, "need at least one measured frame");
        let n = self.ces.len();
        let mut st: Vec<CeState> = vec![CeState::default(); n];
        let mut fifo_occ: Vec<u64> = self.fifos.iter().map(|f| f.occupancy).collect();
        let track = self.track_fifo;
        let mut fifo_peak: Vec<u64> = if track { fifo_occ.clone() } else { Vec::new() };
        let mut fifo_high_water: Vec<Vec<u64>> =
            vec![Vec::with_capacity(frames as usize); if track { self.fifos.len() } else { 0 }];
        let mut source_sent: u64 = 0;
        let source_total = self.source_px_per_frame * frames;
        let last = n - 1;
        let mut completion: Vec<u64> = Vec::with_capacity(frames as usize);
        let mut frame_done: Vec<Vec<u64>> = vec![Vec::with_capacity(frames as usize); n];
        let mut next_accept: Vec<u64> = vec![0; n];
        // Hot-loop hoists: these are pure functions of the static config.
        let caps: Vec<u64> = self.ces.iter().map(|c| c.capacity_px()).collect();
        let arrivals: Vec<u64> = self.ces.iter().map(|c| c.arrivals_per_frame()).collect();
        let outs: Vec<u64> = self.ces.iter().map(|c| c.outputs_per_frame()).collect();
        let mut cycle: u64 = 0;
        let mut last_progress: u64 = 0;
        // Deadlock horizon: a legitimate stall is bounded by one frame of
        // source streaming plus one bottleneck period; anything much longer
        // means a circular wait.
        let horizon = 2 * self.source_px_per_frame + 400_000;

        while (completion.len() as u64) < frames {
            let mut progress = false;

            // ---- Phase A: compute (issue, then tick, in one cycle so
            // back-to-back quanta pipeline without bubble cycles) ----------
            for i in 0..n {
                let cfg = &self.ces[i];
                let s = &mut st[i];
                if s.busy == 0 {
                    // Idle: try to issue the next quantum.
                    let of = outs[i];
                    if s.next_out + s.pending_out >= of * frames {
                        continue; // all work done
                    }
                    let start = s.next_out;
                    let in_frame = start % of;
                    let q = (cfg.pf as u64).min(of - in_frame);
                    // The required-arrival index is invariant while the CE
                    // waits on this quantum; cache it across stall cycles.
                    let need = if s.cached_for == start {
                        s.cached_need
                    } else {
                        let frame = start / of;
                        let end = in_frame + q - 1;
                        let need = frame * arrivals[i] + cfg.required_arrival(end);
                        s.cached_need = need;
                        s.cached_for = start;
                        need
                    };
                    let out_cap = (2 * cfg.pf as u64).max(4);
                    if s.recv <= need {
                        s.stall_input += 1;
                        continue;
                    }
                    if s.out_fifo + q > out_cap {
                        s.stall_output += 1;
                        continue;
                    }
                    if cfg.class == CeClass::Join {
                        let fi = self.join_side[i].expect("join without side fifo");
                        if fifo_occ[fi] < q {
                            s.stall_input += 1;
                            continue;
                        }
                        fifo_occ[fi] -= q;
                    }
                    s.busy = cfg.quantum_cycles;
                    s.pending_out = q;
                    progress = true;
                }
                // Tick the in-flight quantum.
                s.busy -= 1;
                s.busy_cycles += 1;
                if s.busy == 0 {
                    s.out_fifo += s.pending_out;
                    s.next_out += s.pending_out;
                    s.pending_out = 0;
                    progress = true;
                    let of = outs[i];
                    let done = s.next_out / of;
                    if done > s.frames_done {
                        for _ in s.frames_done..done.min(frames) {
                            frame_done[i].push(cycle);
                        }
                        s.frames_done = done;
                        if i == last {
                            for _ in completion.len() as u64..done.min(frames) {
                                completion.push(cycle);
                                for (t, hw) in fifo_high_water.iter_mut().enumerate() {
                                    hw.push(fifo_peak[t]);
                                }
                            }
                        }
                    }
                    // Release dead pixels (never beyond what has arrived).
                    let a = arrivals[i];
                    if cfg.full_frame_buffer {
                        s.freed = ((s.next_out / of) * a).min(s.recv);
                    } else if s.next_out < of * frames {
                        let frame = s.next_out / of;
                        s.freed = s.freed.max(frame * a + cfg.oldest_needed(s.next_out % of)).min(s.recv);
                    }
                }
            }

            // ---- Phase B: input acceptance + transfers --------------------
            for i in 0..n {
                let cfg = &self.ces[i];
                // The inter-CE bus is provisioned to the CE's steady-state
                // demand; accepts are paced accordingly.
                let a = arrivals[i];
                if cycle < next_accept[i] {
                    continue;
                }
                if st[i].recv >= a * frames {
                    continue;
                }
                if st[i].occupancy() >= caps[i] {
                    continue;
                }
                // Padding slot? Self-insert without touching upstream (the
                // write still occupies a bus/buffer-port slot — Fig 11(a)).
                if cfg.uses_padded_stream() && is_padding_slot(cfg, st[i].recv % a) {
                    st[i].recv += 1;
                    next_accept[i] = cycle + cfg.in_interval;
                    progress = true;
                    continue;
                }
                // Need a real pixel from the main source.
                let avail = match self.main_src[i] {
                    MainSrc::Source => source_sent < source_total,
                    MainSrc::Ce(p) => st[p].out_fifo > 0,
                    MainSrc::Fifo(fi) => fifo_occ[fi] > 0,
                };
                if !avail {
                    continue;
                }
                // The producing transfer must also fit every tap.
                if let Some(ti) = self.in_taps[i] {
                    if fifo_occ[ti] >= self.fifos[ti].capacity {
                        continue;
                    }
                }
                // Output taps gate the producer's emission (branch points).
                let taps: &[usize] = match self.main_src[i] {
                    MainSrc::Source => &self.source_taps,
                    MainSrc::Ce(p) => &self.out_taps[p],
                    MainSrc::Fifo(_) => &[],
                };
                if taps.iter().any(|&t| fifo_occ[t] >= self.fifos[t].capacity) {
                    continue;
                }
                // Commit.
                match self.main_src[i] {
                    MainSrc::Source => source_sent += 1,
                    MainSrc::Ce(p) => st[p].out_fifo -= 1,
                    MainSrc::Fifo(fi) => fifo_occ[fi] -= 1,
                }
                for &t in taps {
                    fifo_occ[t] += 1;
                    if track && fifo_occ[t] > fifo_peak[t] {
                        fifo_peak[t] = fifo_occ[t];
                    }
                }
                if let Some(ti) = self.in_taps[i] {
                    fifo_occ[ti] += 1;
                    if track && fifo_occ[ti] > fifo_peak[ti] {
                        fifo_peak[ti] = fifo_occ[ti];
                    }
                }
                st[i].recv += 1;
                next_accept[i] = cycle + cfg.in_interval;
                progress = true;
            }

            // Producers not consumed by the next CE still need to drain:
            // branch points whose output feeds only side FIFOs, and the
            // final sink CE (results leave the accelerator).
            for p in 0..n {
                if self.feeds_next[p] || st[p].out_fifo == 0 {
                    continue;
                }
                let taps = &self.out_taps[p];
                if taps.is_empty() {
                    // Sink: the host consumes results immediately.
                    st[p].out_fifo = 0;
                    progress = true;
                    continue;
                }
                if taps.iter().any(|&t| fifo_occ[t] >= self.fifos[t].capacity) {
                    continue;
                }
                st[p].out_fifo -= 1;
                for &t in taps {
                    fifo_occ[t] += 1;
                    if track && fifo_occ[t] > fifo_peak[t] {
                        fifo_peak[t] = fifo_occ[t];
                    }
                }
                progress = true;
            }

            if progress {
                last_progress = cycle;
            } else {
                // Cycle-skipping: with no transfer/issue/completion this
                // cycle, nothing can change until the nearest quantum
                // completion or bus-pacing release. Jump there in one step
                // (completions still land on their exact cycle because the
                // skip is the minimum of all pending timers).
                let mut skip = u64::MAX;
                for s in st.iter() {
                    if s.busy > 0 {
                        skip = skip.min(s.busy);
                    }
                }
                for &na in next_accept.iter() {
                    if na > cycle {
                        skip = skip.min(na - cycle);
                    }
                }
                if self.cycle_skip && skip != u64::MAX && skip > 1 {
                    let adv = skip - 1; // the loop tail adds the final +1
                    for (i, s) in st.iter_mut().enumerate() {
                        if s.busy > 0 {
                            s.busy -= adv;
                            s.busy_cycles += adv;
                            continue;
                        }
                        // An idle CE replays the exact same stall verdict on
                        // every skipped cycle (none of its inputs can change
                        // strictly inside the span), so credit the counter
                        // the slow path would have bumped — this is what
                        // keeps skip-on and skip-off stats byte-identical.
                        let of = outs[i];
                        if s.next_out + s.pending_out >= of * frames {
                            continue; // all work done: Phase A bumps nothing
                        }
                        let cfg = &self.ces[i];
                        let q = (cfg.pf as u64).min(of - s.next_out % of);
                        if s.recv <= s.cached_need {
                            s.stall_input += adv;
                        } else if s.out_fifo + q > (2 * cfg.pf as u64).max(4) {
                            s.stall_output += adv;
                        } else {
                            // Only a join CE starved by its side FIFO can
                            // still have failed to issue this cycle.
                            s.stall_input += adv;
                        }
                    }
                    cycle += adv;
                }
                // Declare deadlock only when *nothing* is pending: an
                // in-flight quantum timer or a future bus-pacing release
                // always leads to an event (a completion is itself
                // progress), so a long stall with `skip != MAX` is
                // legitimate — e.g. a single quantum longer than the
                // horizon, where the skip advance used to trip this check
                // before the pending completion landed (false deadlock).
                if skip == u64::MAX && cycle - last_progress > horizon {
                    let detail = self.deadlock_report(&st, &fifo_occ);
                    return Err(Deadlock { cycle, detail });
                }
            }
            cycle += 1;
        }

        // Steady-state period over the measured frames.
        let w = warmup as usize;
        let period = if completion.len() > w + 1 {
            (completion[completion.len() - 1] - completion[w]) as f64 / (completion.len() - 1 - w) as f64
        } else {
            completion[completion.len() - 1] as f64
        };
        Ok(SimStats {
            period_cycles: period,
            first_frame_cycles: completion[0],
            total_cycles: cycle,
            frames,
            busy_cycles: st.iter().map(|s| s.busy_cycles).collect(),
            stall_input: st.iter().map(|s| s.stall_input).collect(),
            stall_output: st.iter().map(|s| s.stall_output).collect(),
            macs_per_frame: self
                .ces
                .iter()
                .map(|c| c.macs_per_opos * c.outputs_per_frame())
                .collect(),
            pes: self.ces.iter().map(|c| c.pes).collect(),
            frame_done,
            fifo_names: if track { self.fifos.iter().map(|f| f.name.clone()).collect() } else { Vec::new() },
            fifo_capacity: if track { self.fifos.iter().map(|f| f.capacity).collect() } else { Vec::new() },
            fifo_peak,
            fifo_high_water,
        })
    }

    fn deadlock_report(&self, st: &[CeState], fifo_occ: &[u64]) -> String {
        let mut s = String::new();
        for (i, (cfg, ce)) in self.ces.iter().zip(st).enumerate() {
            if ce.busy > 0 || ce.out_fifo > 0 || ce.occupancy() >= cfg.capacity_px() {
                s.push_str(&format!(
                    "CE{i} {}: recv={} freed={} occ={}/{} out_fifo={} next_out={} busy={}\n",
                    cfg.name,
                    ce.recv,
                    ce.freed,
                    ce.occupancy(),
                    cfg.capacity_px(),
                    ce.out_fifo,
                    ce.next_out,
                    ce.busy
                ));
            }
        }
        for (fi, f) in self.fifos.iter().enumerate() {
            s.push_str(&format!("FIFO{fi} {}: {}/{}\n", f.name, fifo_occ[fi], f.capacity));
        }
        s
    }
}

/// Whether arrival slot `idx` of a padded frame stream is a padding
/// position.
fn is_padding_slot(cfg: &CeConfig, idx: u64) -> bool {
    let fp = (cfg.f_in + 2 * cfg.pad) as u64;
    let p = cfg.pad as u64;
    let (r, c) = (idx / fp, idx % fp);
    r < p || r >= fp - p || c < p || c >= fp - p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::memory::FmScheme;
    use crate::sim::ce::PaddingMode;

    /// A minimal streaming 1x1 compute CE (k=1: 1:1 arrival/output map).
    fn stream_ce(name: &str, f: usize, quantum: u64, pf: usize) -> CeConfig {
        CeConfig {
            name: name.into(),
            class: CeClass::Compute,
            f_in: f,
            f_out: f,
            k: 1,
            stride: 1,
            pad: 0,
            padding: PaddingMode::AddressGenerated,
            scheme: FmScheme::FullyReusedFm,
            stride_extra_line: false,
            quantum_cycles: quantum,
            pf,
            pes: 1,
            macs_per_opos: 1,
            full_frame_buffer: false,
            extra_capacity_px: 0,
            in_interval: 1,
        }
    }

    /// Source -> producer CE -> full-frame (WRCE-style) CE -> join CE,
    /// with one side FIFO snapshotting the producer's output into the
    /// join — the minimal SCB shape.
    fn scb_pipeline(side_capacity: u64) -> Pipeline {
        let producer = stream_ce("producer", 4, 1, 1);
        let mut middle = stream_ce("middle", 4, 1, 1);
        middle.full_frame_buffer = true;
        let mut join = stream_ce("join", 4, 1, 4);
        join.class = CeClass::Join;
        join.pes = 0;
        Pipeline {
            ces: vec![producer, middle, join],
            main_src: vec![MainSrc::Source, MainSrc::Ce(0), MainSrc::Ce(1)],
            join_side: vec![None, None, Some(0)],
            out_taps: vec![vec![0], Vec::new(), Vec::new()],
            in_taps: vec![None; 3],
            source_taps: Vec::new(),
            fifos: vec![SideFifo {
                producer: Some(0),
                tap_input: false,
                capacity: side_capacity,
                occupancy: 0,
                name: "scb->join".into(),
            }],
            feeds_next: vec![true, true, false],
            source_px_per_frame: 16,
            track_fifo: false,
            cycle_skip: true,
        }
    }

    #[test]
    fn quantum_longer_than_horizon_is_not_a_deadlock() {
        // Regression: one quantum of 1M cycles dwarfs the progress horizon
        // (2*64 + 400_000). The cycle-skip advance lands past the horizon
        // in a single jump, and the old `cycle - last_progress > horizon`
        // check fired before the pending completion could count as
        // progress. With the pending-timer guard the run must complete.
        let mut ce = stream_ce("extreme", 8, 1_000_000, 1);
        ce.in_interval = 1;
        let p = Pipeline {
            ces: vec![ce],
            main_src: vec![MainSrc::Source],
            join_side: vec![None],
            out_taps: vec![Vec::new()],
            in_taps: vec![None],
            source_taps: Vec::new(),
            fifos: Vec::new(),
            feeds_next: vec![false],
            source_px_per_frame: 64,
            track_fifo: false,
            cycle_skip: true,
        };
        let stats = p.run(1, 0).expect("extreme quantum falsely reported as deadlock");
        assert_eq!(stats.frames, 1);
        // Each of the 64 one-position quanta stalls far past the horizon.
        assert!(stats.total_cycles > 2 * 64 + 400_000, "total {}", stats.total_cycles);
    }

    #[test]
    fn undersized_side_fifo_deadlocks_with_named_report() {
        // Capacity 2 while the join consumes 4 per quantum: the FIFO
        // saturates at 2/2, the gated producer backs up (out_fifo full),
        // the full-frame middle CE never sees a whole frame — a circular
        // wait, i.e. exactly the failure the paper's delayed-buffer sizing
        // prevents.
        let err = scb_pipeline(2).run(1, 0).expect_err("undersized FIFO must deadlock");
        assert!(err.detail.contains("scb->join"), "missing FIFO name: {}", err.detail);
        assert!(err.detail.contains("2/2"), "missing saturated occupancy: {}", err.detail);
        assert!(err.detail.contains("producer"), "missing stalled CE: {}", err.detail);
        let display = err.to_string();
        assert!(display.contains("pipeline deadlock at cycle"));
    }

    #[test]
    fn model_sized_side_fifo_streams_and_tracks_peaks() {
        // 2*frame_px is the builder's WRCE-join provision; with it the same
        // pipeline streams, and tracking reports peaks within capacity plus
        // a monotone per-frame high-water trace.
        let mut p = scb_pipeline(32);
        p.track_fifo = true;
        let frames = 3;
        let stats = p.run(frames, 1).expect("model-sized FIFO must stream");
        assert_eq!(stats.fifo_names, vec!["scb->join".to_string()]);
        assert_eq!(stats.fifo_capacity, vec![32]);
        assert_eq!(stats.fifo_peak.len(), 1);
        assert!(stats.fifo_peak[0] > 0 && stats.fifo_peak[0] <= 32, "peak {}", stats.fifo_peak[0]);
        let hw = &stats.fifo_high_water[0];
        assert_eq!(hw.len(), frames as usize);
        assert!(hw.windows(2).all(|w| w[0] <= w[1]), "trace not monotone: {hw:?}");
        assert!(*hw.last().unwrap() <= stats.fifo_peak[0]);
        // Untracked runs keep the stats fields empty (zero-cost default).
        let untracked = scb_pipeline(32).run(frames, 1).unwrap();
        assert!(untracked.fifo_names.is_empty() && untracked.fifo_peak.is_empty());
        assert!(untracked.fifo_high_water.is_empty());
        assert_eq!(untracked.period_cycles, stats.period_cycles);
    }
}
