//! Per-CE state machines of the cycle-level simulator.
//!
//! Every CE processes a continuous multi-frame pixel stream. A "pixel" is
//! one spatial position across all channels at that point of the network
//! (channel-first transfer order, §III-B); all FIFOs count pixels, since
//! the simulator tracks timing, not values.

use crate::model::memory::FmScheme;

/// Padding implementation of the line-buffer (§IV-B, Fig 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaddingMode {
    /// Zeros are written into the line buffer through the input port,
    /// consuming write bandwidth (Fig 11(a) — the congestion baseline).
    DirectInsert,
    /// The address generator materializes padding on the fly while real
    /// pixels stream to the PE array (Fig 11(b) — the proposed scheme).
    AddressGenerated,
}

/// What kind of datapath a CE models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CeClass {
    /// Windowed/MAC compute (STC/DWC/PWC/FC, pooling): consumes a window
    /// from the line buffer, occupies the PE array `quantum_cycles` per
    /// `pf` output positions.
    Compute,
    /// Pure data movement at one position per cycle (shuffle, split,
    /// dataflow-order converter).
    Passthrough,
    /// Two-input join (SCB `Add`, shuffle-unit `Concat`): pairs one pixel
    /// from the main stream with one from the side (shortcut) FIFO per
    /// cycle.
    Join,
}

/// Static configuration of one simulated CE.
#[derive(Debug, Clone)]
pub struct CeConfig {
    pub name: String,
    pub class: CeClass,
    /// Input spatial size (pre-padding).
    pub f_in: usize,
    /// Output spatial size.
    pub f_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Padding handling (only meaningful for windowed CEs with pad > 0).
    pub padding: PaddingMode,
    /// FM-buffer scheme: decides both line-buffer capacity and the pixel
    /// release rule.
    pub scheme: FmScheme,
    /// Extra line of buffer for stride > 1 (§IV-B, Fig 11(d)).
    pub stride_extra_line: bool,
    /// PE-array occupancy per quantum: `ceil(N / P_w) * reduction_depth`
    /// cycles produce `pf` output positions.
    pub quantum_cycles: u64,
    /// Output positions produced per quantum (the `P_f` of §III-C).
    pub pf: usize,
    /// MAC units in this CE's PE array (0 for LUT-only CEs).
    pub pes: usize,
    /// True MACs per output position (for efficiency accounting).
    pub macs_per_opos: u64,
    /// WRCE global-FM mode: the whole input frame must be buffered
    /// (ping-pong) before computation starts; pixel release happens a
    /// frame at a time.
    pub full_frame_buffer: bool,
    /// Extra buffer pixels beyond the scheme formula — sized by the
    /// builder so that every `pf`-position quantum's window span fits
    /// (a `P_f > 1` FRCE physically widens its buffer the same way).
    pub extra_capacity_px: u64,
    /// Minimum cycles between input-port accepts: the inter-CE bus is
    /// provisioned to the CE's steady-state demand (compute time over
    /// arrivals), so short-term demand peaks — padding writes, stride
    /// rows, image switches — exceed supply exactly as in §IV-B unless
    /// the optimized buffer scheme absorbs them.
    pub in_interval: u64,
}

impl CeConfig {
    /// Arrivals per frame as seen on the input port: the padded grid when
    /// padding is written through the port, the real grid otherwise.
    pub fn arrivals_per_frame(&self) -> u64 {
        if self.uses_padded_stream() {
            let fp = self.f_in + 2 * self.pad;
            (fp * fp) as u64
        } else {
            (self.f_in * self.f_in) as u64
        }
    }

    pub fn uses_padded_stream(&self) -> bool {
        self.class == CeClass::Compute && self.pad > 0 && self.padding == PaddingMode::DirectInsert
    }

    /// Real (non-padding) pixels per frame.
    pub fn real_per_frame(&self) -> u64 {
        (self.f_in * self.f_in) as u64
    }

    pub fn outputs_per_frame(&self) -> u64 {
        (self.f_out * self.f_out) as u64
    }

    /// Line-buffer capacity in pixels (§III-B / §IV-B), before the
    /// builder's quantum-fit extension.
    pub fn formula_capacity_px(&self) -> u64 {
        if self.full_frame_buffer {
            return 2 * self.arrivals_per_frame(); // ping-pong GFM
        }
        let f = if self.uses_padded_stream() { self.f_in + 2 * self.pad } else { self.f_in } as u64;
        let k = self.k as u64;
        if self.class != CeClass::Compute {
            return 4; // small synchronizer FIFO
        }
        if self.k <= 1 {
            return (2 * self.pf as u64).max(4); // PWC/FC: no inter-pixel correlation
        }
        // Fully-reused scheme: the Table-I minimum is (K-1) lines + K-1 px,
        // "even if the buffer lines increased to k full lines to reserve
        // extra space for overlapping computations between layers" (§III-B)
        // — the extra line is what lets frame f+1's first rows stream in
        // while frame f's tail windows are still live, so the simulator
        // models the k-line variant.
        let base = match self.scheme {
            FmScheme::FullyReusedFm => k * f + k,
            FmScheme::LineBased => (k + 1) * f,
        };
        if self.stride > 1 && self.stride_extra_line {
            base + f
        } else {
            base
        }
    }

    /// Effective line-buffer capacity in pixels.
    pub fn capacity_px(&self) -> u64 {
        self.formula_capacity_px() + self.extra_capacity_px
    }

    /// The largest window span (arrivals that must be co-resident) of any
    /// quantum in a frame — the builder sizes `extra_capacity_px` so this
    /// always fits.
    pub fn max_quantum_span(&self) -> u64 {
        if self.full_frame_buffer || self.class != CeClass::Compute {
            return 0;
        }
        let of = self.outputs_per_frame();
        let mut span = 0u64;
        let mut o = 0u64;
        while o < of {
            let q = (self.pf as u64).min(of - o);
            let end = o + q - 1;
            let need = self.required_arrival(end) + 1 - self.oldest_needed(o);
            span = span.max(need);
            o += q;
        }
        span
    }

    /// Grid side length of the arrival stream.
    fn fa(&self) -> usize {
        if self.uses_padded_stream() {
            self.f_in + 2 * self.pad
        } else {
            self.f_in
        }
    }

    /// Index (within a frame's arrival stream) that must have arrived
    /// before the output quantum *ending* at output position `opos` can be
    /// computed.
    pub fn required_arrival(&self, opos: u64) -> u64 {
        let fa = self.fa() as u64;
        if self.full_frame_buffer {
            return self.arrivals_per_frame() - 1;
        }
        if self.class != CeClass::Compute || self.k <= 1 {
            // 1:1 streaming (position o needs arrival o for stride 1;
            // strided 1x1 layers need the strided source position).
            let r = opos / self.f_out as u64 * self.stride as u64;
            let c = opos % self.f_out as u64 * self.stride as u64;
            return (r * fa + c).min(self.arrivals_per_frame() - 1);
        }
        let (r, c) = (opos / self.f_out as u64, opos % self.f_out as u64);
        let (s, k) = (self.stride as u64, self.k as u64);
        let (row, col) = if self.uses_padded_stream() {
            (r * s + k - 1, c * s + k - 1)
        } else {
            let p = self.pad as u64;
            (
                (r * s + k - 1).saturating_sub(p).min(self.f_in as u64 - 1),
                (c * s + k - 1).saturating_sub(p).min(self.f_in as u64 - 1),
            )
        };
        row * fa + col
    }

    /// Index (within a frame's arrival stream) of the oldest pixel still
    /// needed once the quantum ending at `opos` has been issued — arrivals
    /// strictly before it can be overwritten (the pixel-lifetime rule of
    /// Fig 5 for the fully-reused scheme, whole lines for line-based).
    pub fn oldest_needed(&self, opos: u64) -> u64 {
        let fa = self.fa() as u64;
        if self.full_frame_buffer {
            return 0; // released per frame by the engine
        }
        if self.class != CeClass::Compute || self.k <= 1 {
            let r = opos / self.f_out as u64 * self.stride as u64;
            let c = opos % self.f_out as u64 * self.stride as u64;
            return r * fa + c;
        }
        let (r, c) = (opos / self.f_out as u64, opos % self.f_out as u64);
        let s = self.stride as u64;
        let (row0, col0) = if self.uses_padded_stream() {
            (r * s, c * s)
        } else {
            let p = self.pad as u64;
            ((r * s).saturating_sub(p), (c * s).saturating_sub(p))
        };
        match self.scheme {
            FmScheme::FullyReusedFm => row0 * fa + col0,
            FmScheme::LineBased => row0 * fa,
        }
    }
}

/// Mutable per-CE simulation state. All stream positions are *global*
/// (monotone across frames): arrival `a` belongs to frame
/// `a / arrivals_per_frame()`.
#[derive(Debug, Clone)]
pub struct CeState {
    /// Total pixels accepted on the input port (real + self-inserted
    /// padding).
    pub recv: u64,
    /// Pixels released from the line buffer.
    pub freed: u64,
    /// Next output position to issue (global).
    pub next_out: u64,
    /// Remaining busy cycles of the in-flight quantum (0 = idle).
    pub busy: u64,
    /// Output positions of the in-flight quantum, delivered on completion.
    pub pending_out: u64,
    /// Pixels sitting in the output FIFO awaiting transfer downstream.
    pub out_fifo: u64,
    /// Busy-cycle counter (PE array occupied).
    pub busy_cycles: u64,
    /// Stall taxonomy for reports: cycles idle awaiting input window.
    pub stall_input: u64,
    /// Cycles idle because the output FIFO / downstream is full.
    pub stall_output: u64,
    /// Completed output frames (for frame-latency stats).
    pub frames_done: u64,
    /// Cached global arrival index required by the pending quantum
    /// (recomputed only when `next_out` advances).
    pub cached_need: u64,
    /// `next_out` value the cache was computed for (u64::MAX = stale).
    pub cached_for: u64,
}

impl Default for CeState {
    fn default() -> Self {
        CeState {
            recv: 0,
            freed: 0,
            next_out: 0,
            busy: 0,
            pending_out: 0,
            out_fifo: 0,
            busy_cycles: 0,
            stall_input: 0,
            stall_output: 0,
            frames_done: 0,
            cached_need: 0,
            // Stale marker: next_out starts at 0, so 0 must not look cached.
            cached_for: u64::MAX,
        }
    }
}

impl CeState {
    /// Pixels currently resident in the input line buffer.
    pub fn occupancy(&self) -> u64 {
        self.recv - self.freed
    }

    /// Whether every output position of the run has been issued (counting
    /// the in-flight quantum) — the CE will never occupy its PE array
    /// again. Shared by both engines' issue logic and the stepped
    /// engine's cycle-skip verdict replay.
    pub fn all_work_issued(&self, outputs_per_frame: u64, frames: u64) -> bool {
        self.next_out + self.pending_out >= outputs_per_frame * frames
    }
}
