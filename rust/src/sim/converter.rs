//! Dataflow-order converter (§III-C-2, Fig 9).
//!
//! At the FRCE/WRCE group boundary the FM stream switches from
//! channel-first (a pixel = all channels of one position) to
//! location-first (a slice = all positions of one channel group). The
//! paper implements the transpose with multiple RAM banks and write
//! masks: incoming channel-first data is serialized and written across
//! banks such that data of one location slice lands in the same address
//! of different banks and can be fetched in a single cycle — "data order
//! transpose without additional storage space".
//!
//! This module is a functional model of that banked write-mask scheme:
//! it verifies the address arithmetic (every element is written exactly
//! once, no bank conflicts per cycle, readout order is the exact
//! transpose) and sizes the structure for the memory model. The timing
//! behaviour in the pipeline simulator is a passthrough (the paper's
//! claim, which the bank-conflict freedom proven here justifies).

/// A banked converter for `channels` channels with `banks` RAM banks.
#[derive(Debug, Clone)]
pub struct OrderConverter {
    pub channels: usize,
    pub banks: usize,
}

impl OrderConverter {
    /// `banks` must divide the channel count (the paper uses the
    /// WRCE-side read parallelism as the bank count).
    pub fn new(channels: usize, banks: usize) -> Self {
        assert!(banks > 0 && channels % banks == 0, "banks must divide channels");
        OrderConverter { channels, banks }
    }

    /// Bank and address for channel `c` of position `p` in a tile of
    /// `positions` positions: channel-first writes rotate the bank with
    /// the position index so that consecutive channels of one position
    /// spread over distinct banks, while one channel's positions land at
    /// distinct addresses — the write-mask pattern of Fig 9.
    pub fn slot(&self, p: usize, c: usize) -> (usize, usize) {
        let bank = (c + p) % self.banks;
        let addr = p * (self.channels / self.banks) + c / self.banks;
        (bank, addr)
    }

    /// Simulate writing a `positions x channels` channel-first tile and
    /// reading it back location-first. Returns the read sequence as
    /// (position, channel) pairs; used by tests to prove the transpose.
    pub fn transpose_order(&self, positions: usize) -> Vec<(usize, usize)> {
        let words = self.channels / self.banks;
        let mut mem = vec![vec![usize::MAX; positions * words]; self.banks];
        // Channel-first writes: one pixel (all channels) per beat, each
        // channel masked into its bank slot.
        for p in 0..positions {
            for c in 0..self.channels {
                let (b, a) = self.slot(p, c);
                assert_eq!(mem[b][a], usize::MAX, "double write at bank {b} addr {a}");
                mem[b][a] = p * self.channels + c;
            }
        }
        // Location-first reads: for each channel group, walk positions;
        // all banks are read at the same address in one cycle.
        let mut out = Vec::with_capacity(positions * self.channels);
        for w in 0..words {
            for p in 0..positions {
                for b in 0..self.banks {
                    // Invert the rotation to find which channel this bank
                    // holds for position p, word w.
                    let c = (b + self.banks - p % self.banks) % self.banks + w * self.banks;
                    let (bb, aa) = self.slot(p, c);
                    assert_eq!(bb, b);
                    let v = mem[bb][aa];
                    out.push((v / self.channels, v % self.channels));
                }
            }
        }
        out
    }

    /// Storage bytes (8-bit elements): one tile, no double buffering —
    /// the paper's "without additional storage space" relative to a
    /// naive transpose buffer.
    pub fn bytes(&self, positions: usize) -> u64 {
        (positions * self.channels) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_write_hits_a_distinct_slot() {
        for (ch, banks, pos) in [(32, 8, 16), (96, 3, 49), (64, 64, 4), (24, 4, 9)] {
            let cv = OrderConverter::new(ch, banks);
            let mut seen = std::collections::HashSet::new();
            for p in 0..pos {
                for c in 0..ch {
                    assert!(seen.insert(cv.slot(p, c)), "collision at p={p} c={c}");
                }
            }
        }
    }

    #[test]
    fn writes_of_one_pixel_have_no_bank_conflicts_per_beat() {
        // One position's channels must spread across banks so the write
        // mask can commit `banks` channels per cycle.
        let cv = OrderConverter::new(48, 8);
        for p in 0..10 {
            for group in 0..48 / 8 {
                let banks: Vec<usize> = (0..8).map(|i| cv.slot(p, group * 8 + i).0).collect();
                let mut sorted = banks.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), 8, "bank conflict at p={p} group={group}");
            }
        }
    }

    #[test]
    fn readback_is_location_first_transpose() {
        let cv = OrderConverter::new(12, 4);
        let order = cv.transpose_order(6);
        // Each channel-group word streams all positions before the next
        // word: positions change fastest, channel groups slowest.
        for (i, &(p, c)) in order.iter().enumerate() {
            let beat = i / 4; // 4 banks per cycle
            let word = beat / 6;
            let pos = beat % 6;
            assert_eq!(p, pos, "beat {beat}");
            assert_eq!(c / 4, word, "beat {beat} channel {c}");
        }
        // And the full tile is covered exactly once.
        let mut all: Vec<_> = order.clone();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 6 * 12);
    }

    #[test]
    fn storage_is_single_tile() {
        let cv = OrderConverter::new(320, 8);
        assert_eq!(cv.bytes(49), 49 * 320);
    }

    #[test]
    #[should_panic(expected = "banks must divide")]
    fn rejects_non_dividing_banks() {
        OrderConverter::new(10, 3);
    }
}
