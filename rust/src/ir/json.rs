//! Versioned JSON network descriptions (`format: "repro-net"`).
//!
//! [`to_json`] writes a stable, line-per-node document (fixed key order,
//! integral numbers) so committed `networks/*.json` files diff cleanly;
//! [`from_json`] parses + validates, rejecting malformed documents with
//! the same actionable errors as [`Graph::validate`]. The schema is
//! documented with a worked example in `docs/net_schema.md`.

use crate::util::error::ReproError;
use crate::util::json::Json;

use super::{Graph, Node, Op, SCHEMA_FORMAT, SCHEMA_VERSION};

/// Serialize a graph as a versioned `repro-net` JSON document: fixed key
/// order, one node per line, op-specific fields only where the op defines
/// them. `python/gen_networks.py` emits this byte format exactly, and the
/// committed-catalog guard test in `rust/tests/ir.rs` pins the two
/// writers together.
pub fn to_json(graph: &Graph) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"format\": {},\n", Json::Str(SCHEMA_FORMAT.to_string())));
    out.push_str(&format!("  \"version\": {SCHEMA_VERSION},\n"));
    out.push_str(&format!("  \"name\": {},\n", Json::Str(graph.name.clone())));
    out.push_str(&format!(
        "  \"input\": {{\"size\": {}, \"channels\": {}}},\n",
        graph.input_size, graph.input_ch
    ));
    out.push_str("  \"nodes\": [\n");
    for (i, node) in graph.nodes.iter().enumerate() {
        let inputs =
            node.inputs.iter().map(|j| j.to_string()).collect::<Vec<_>>().join(", ");
        let mut line = format!(
            "    {{\"name\": {}, \"block\": {}, \"op\": {}, \"inputs\": [{inputs}]",
            Json::Str(node.name.clone()),
            Json::Str(node.block.clone()),
            Json::Str(node.op.wire_name().to_string()),
        );
        match &node.op {
            Op::Conv { out_ch, k, stride, pad } => {
                line.push_str(&format!(
                    ", \"out_ch\": {out_ch}, \"k\": {k}, \"stride\": {stride}, \"pad\": {pad}"
                ));
            }
            Op::DwConv { k, stride, pad }
            | Op::MaxPool { k, stride, pad }
            | Op::AvgPool { k, stride, pad } => {
                line.push_str(&format!(", \"k\": {k}, \"stride\": {stride}, \"pad\": {pad}"));
            }
            Op::PwConv { out_ch, groups } => {
                line.push_str(&format!(", \"out_ch\": {out_ch}, \"groups\": {groups}"));
            }
            Op::Fc { out_ch } => line.push_str(&format!(", \"out_ch\": {out_ch}")),
            Op::Split { keep } => line.push_str(&format!(", \"keep\": {keep}")),
            Op::GlobalAvgPool | Op::Add | Op::Concat | Op::Shuffle => {}
        }
        line.push('}');
        if i + 1 < graph.nodes.len() {
            line.push(',');
        }
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

fn str_field(obj: &Json, key: &str, at: &str) -> Result<String, String> {
    obj.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("{at}: missing or non-string field {key:?}"))
}

fn usize_field(obj: &Json, key: &str, at: &str) -> Result<usize, String> {
    let n = obj
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{at}: missing or non-numeric field {key:?}"))?;
    if n < 0.0 || n.fract() != 0.0 || n >= 9.0e15 {
        return Err(format!("{at}: field {key:?} must be a non-negative integer, got {n}"));
    }
    Ok(n as usize)
}

/// Parse and validate a `repro-net` JSON document. All failures — parse
/// errors, schema violations, and the [`Graph::validate`] pass — are
/// [`ReproError::Network`] errors.
pub fn from_json(text: &str) -> Result<Graph, ReproError> {
    let graph = parse_graph(text).map_err(ReproError::network)?;
    graph.validate()?;
    Ok(graph)
}

fn parse_graph(text: &str) -> Result<Graph, String> {
    let doc = Json::parse(text).map_err(|e| format!("network description: {e}"))?;
    let format = str_field(&doc, "format", "network description")?;
    if format != SCHEMA_FORMAT {
        return Err(format!(
            "network description: format {format:?} is not {SCHEMA_FORMAT:?} (is this a net file?)"
        ));
    }
    let version = usize_field(&doc, "version", "network description")? as u64;
    if version != SCHEMA_VERSION {
        return Err(format!(
            "network description: schema version {version} is not the supported version \
             {SCHEMA_VERSION}"
        ));
    }
    let name = str_field(&doc, "name", "network description")?;
    let input = doc
        .get("input")
        .ok_or_else(|| format!("network {name:?}: missing \"input\" object"))?;
    let input_size = usize_field(input, "size", &format!("network {name:?} input"))?;
    let input_ch = usize_field(input, "channels", &format!("network {name:?} input"))?;
    let nodes_json = doc
        .get("nodes")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("network {name:?}: missing \"nodes\" array"))?;

    let mut nodes = Vec::with_capacity(nodes_json.len());
    for (i, nj) in nodes_json.iter().enumerate() {
        let at = format!("network {name:?} node {i}");
        let node_name = str_field(nj, "name", &at)?;
        let at = format!("network {name:?} node {i} ({node_name:?})");
        let block = str_field(nj, "block", &at)?;
        let op_name = str_field(nj, "op", &at)?;
        let inputs_json = nj
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("{at}: missing \"inputs\" array"))?;
        let mut inputs = Vec::with_capacity(inputs_json.len());
        for (slot, v) in inputs_json.iter().enumerate() {
            let n = v
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .ok_or_else(|| format!("{at}: inputs[{slot}] must be a node index, got {v}"))?;
            inputs.push(n as usize);
        }
        let op = match op_name.as_str() {
            "conv" => Op::Conv {
                out_ch: usize_field(nj, "out_ch", &at)?,
                k: usize_field(nj, "k", &at)?,
                stride: usize_field(nj, "stride", &at)?,
                pad: usize_field(nj, "pad", &at)?,
            },
            "dwconv" => Op::DwConv {
                k: usize_field(nj, "k", &at)?,
                stride: usize_field(nj, "stride", &at)?,
                pad: usize_field(nj, "pad", &at)?,
            },
            "pwconv" => Op::PwConv {
                out_ch: usize_field(nj, "out_ch", &at)?,
                groups: match nj.get("groups") {
                    Some(_) => usize_field(nj, "groups", &at)?,
                    None => 1,
                },
            },
            "maxpool" => Op::MaxPool {
                k: usize_field(nj, "k", &at)?,
                stride: usize_field(nj, "stride", &at)?,
                pad: usize_field(nj, "pad", &at)?,
            },
            "avgpool" => Op::AvgPool {
                k: usize_field(nj, "k", &at)?,
                stride: usize_field(nj, "stride", &at)?,
                pad: usize_field(nj, "pad", &at)?,
            },
            "global_avgpool" => Op::GlobalAvgPool,
            "fc" => Op::Fc { out_ch: usize_field(nj, "out_ch", &at)? },
            "add" => Op::Add,
            "concat" => Op::Concat,
            "split" => Op::Split { keep: usize_field(nj, "keep", &at)? },
            "shuffle" => Op::Shuffle,
            other => {
                return Err(format!(
                    "{at}: unknown op {other:?} (known ops: conv, dwconv, pwconv, maxpool, \
                     avgpool, global_avgpool, fc, add, concat, split, shuffle)"
                ))
            }
        };
        nodes.push(Node { name: node_name, block, op, inputs });
    }

    Ok(Graph { name, input_size, input_ch, nodes })
}

#[cfg(test)]
mod tests {
    use super::super::GraphBuilder;
    use super::*;

    fn toy() -> Graph {
        let mut b = GraphBuilder::new("toy", 8, 3);
        b.block("stem");
        b.conv(4, 3, 2, 1);
        b.block("unit");
        let start = b.cursor().unwrap();
        b.pwconv(4);
        b.dwconv(3, 1, 1);
        b.add_from(start);
        b.block("head");
        b.global_avgpool();
        b.fc(10);
        b.finish()
    }

    #[test]
    fn to_json_from_json_round_trips() {
        let g = toy();
        let text = to_json(&g);
        let back = from_json(&text).unwrap();
        assert_eq!(g, back);
        // Serialization is a fixed point.
        assert_eq!(to_json(&back), text);
    }

    #[test]
    fn version_and_format_are_enforced() {
        let g = toy();
        let text = to_json(&g);
        let wrong_version = text.replace("\"version\": 1", "\"version\": 99");
        let err = from_json(&wrong_version).unwrap_err();
        assert!(err.contains("schema version 99"), "{err}");
        let wrong_format = text.replace("\"format\": \"repro-net\"", "\"format\": \"onnx\"");
        let err = from_json(&wrong_format).unwrap_err();
        assert!(err.contains("\"onnx\""), "{err}");
    }

    #[test]
    fn unknown_ops_and_bad_fields_are_named() {
        let g = toy();
        let text = to_json(&g);
        let bad_op = text.replace("\"op\": \"dwconv\"", "\"op\": \"winograd\"");
        let err = from_json(&bad_op).unwrap_err();
        assert!(err.contains("unknown op \"winograd\""), "{err}");
        assert!(err.contains("known ops"), "{err}");

        let missing = text.replace(", \"k\": 3, \"stride\": 1, \"pad\": 1", "");
        let err = from_json(&missing).unwrap_err();
        assert!(err.contains("\"k\""), "{err}");
    }

    #[test]
    fn malformed_graphs_fail_validation_on_load() {
        let g = toy();
        // Point the add's shortcut edge at an undefined node.
        let text = to_json(&g).replace("\"inputs\": [2, 0]", "\"inputs\": [2, 77]");
        let err = from_json(&text).unwrap_err();
        assert!(err.contains("dangling edge"), "{err}");
    }

    #[test]
    fn pwconv_groups_default_to_one() {
        let g = toy();
        let text = to_json(&g).replace(", \"groups\": 1", "");
        let back = from_json(&text).unwrap();
        assert_eq!(g, back);
    }
}
