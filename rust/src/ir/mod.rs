//! Layer-graph IR — the network front-end that decouples *describing* an
//! LWCNN from hand-porting it into Rust.
//!
//! A [`Graph`] is an explicit-edge DAG of [`Node`]s (conv / dwconv /
//! pwconv / pools / fc / add / concat / split / shuffle), validated by a
//! shape-inference pass ([`Graph::shapes`]) that rejects malformed graphs
//! with actionable, node-named errors. Graphs come from three places:
//!
//! * the zoo builders in [`crate::nets`], which construct their graphs
//!   through [`GraphBuilder`] (the deduplicated successor of the old
//!   per-network `NetBuilder` topology logic);
//! * versioned JSON network descriptions ([`from_json`] / [`to_json`],
//!   schema in `docs/net_schema.md`) — the `repro ... --net-file` path
//!   and the committed `networks/*.json` catalog;
//! * programmatic construction for transform passes (fusion, rewrites)
//!   that only become expressible over an explicit graph.
//!
//! Every consumer downstream of the front-end — Algorithm 1/2, the
//! Eq 1–14 model, the cycle simulator, the sweep engine — keeps running
//! unchanged on [`crate::nets::Network`]: the lowering pass
//! ([`lower`], `ir/lower.rs`) turns a validated graph into the linear
//! streaming order plus SCB edges that representation encodes. Lowering
//! the four zoo graphs reproduces the pre-IR hand-built networks
//! field-for-field (pinned against the golden baselines in
//! `rust/tests/ir.rs`).

mod json;
mod lower;

pub use json::{from_json, to_json};
pub use lower::lower;

use crate::nets::Network;
use crate::util::error::ReproError;

/// Schema version of the JSON network description ([`to_json`] writes it,
/// [`from_json`] enforces it).
pub const SCHEMA_VERSION: u64 = 1;
/// The `"format"` tag of a JSON network description.
pub const SCHEMA_FORMAT: &str = "repro-net";

/// One graph operation. Spatial ops carry their own kernel geometry;
/// channel counts of the data-movement ops are inferred from inputs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Standard convolution (paper: STC).
    Conv { out_ch: usize, k: usize, stride: usize, pad: usize },
    /// Depthwise 3x3-style convolution (paper: DWC); channels preserved.
    DwConv { k: usize, stride: usize, pad: usize },
    /// Pointwise 1x1 convolution (paper: PWC); `groups > 1` models the
    /// grouped 1x1 convolutions of ShuffleNetV1.
    PwConv { out_ch: usize, groups: usize },
    /// Windowed max pooling.
    MaxPool { k: usize, stride: usize, pad: usize },
    /// Windowed average pooling (ShuffleNetV1's stride-2 shortcut).
    AvgPool { k: usize, stride: usize, pad: usize },
    /// Global average pooling: whatever the input spatial size, out is 1x1.
    GlobalAvgPool,
    /// Fully connected layer (1x1 PWC on a 1x1 FM).
    Fc { out_ch: usize },
    /// Element-wise shortcut addition joining exactly two equal shapes.
    Add,
    /// Channel concatenation of exactly two equal-spatial-size streams.
    Concat,
    /// Channel split: this node's output keeps `keep` channels; the
    /// complementary channels are re-read by a later consumer (ShuffleNetV2
    /// stride-1 units model both halves as readers of the split output).
    Split { keep: usize },
    /// Channel shuffle: pure data movement, shape preserved.
    Shuffle,
}

impl Op {
    /// Stable wire name used by the JSON schema (`docs/net_schema.md`).
    pub fn wire_name(&self) -> &'static str {
        match self {
            Op::Conv { .. } => "conv",
            Op::DwConv { .. } => "dwconv",
            Op::PwConv { .. } => "pwconv",
            Op::MaxPool { .. } => "maxpool",
            Op::AvgPool { .. } => "avgpool",
            Op::GlobalAvgPool => "global_avgpool",
            Op::Fc { .. } => "fc",
            Op::Add => "add",
            Op::Concat => "concat",
            Op::Split { .. } => "split",
            Op::Shuffle => "shuffle",
        }
    }

    /// Whether the op joins two streams (and therefore lowers to an SCB).
    pub fn is_join(&self) -> bool {
        matches!(self, Op::Add | Op::Concat)
    }
}

/// One node of the layer graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Node {
    /// Unique human-readable name (the lowered layer keeps it).
    pub name: String,
    /// Block the node belongs to (Fig 3 aggregates per block).
    pub block: String,
    pub op: Op,
    /// Indices of the producing nodes. Empty = the node reads the network
    /// input. Joins name exactly two producers; everything else at most one.
    pub inputs: Vec<usize>,
}

/// A layer-graph network description: named input dims plus a
/// topologically-ordered node list with explicit edges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    pub name: String,
    /// Square input feature map: `input_size` x `input_size`.
    pub input_size: usize,
    pub input_ch: usize,
    pub nodes: Vec<Node>,
}

/// Inferred output shape of one node (square FMs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub size: usize,
    pub ch: usize,
}

/// Windowed-op output size, matching [`crate::nets::Network::validate`]'s
/// formula exactly (integer division).
fn window_out(in_size: usize, k: usize, stride: usize, pad: usize) -> usize {
    (in_size + 2 * pad - k) / stride + 1
}

impl Graph {
    /// Shape-inference + validation pass: infer every node's output shape,
    /// rejecting malformed graphs (dangling edges, forward edges/cycles,
    /// arity violations, shape mismatches at joins, degenerate kernel
    /// geometry, dead nodes) with [`ReproError::Network`] errors that name
    /// the offending node.
    pub fn shapes(&self) -> Result<Vec<Shape>, ReproError> {
        self.shapes_impl().map_err(ReproError::network)
    }

    fn shapes_impl(&self) -> Result<Vec<Shape>, String> {
        if self.name.is_empty() {
            return Err("graph: empty network name".to_string());
        }
        if self.input_size == 0 || self.input_ch == 0 {
            return Err(format!(
                "graph {:?}: input must be non-empty, got {}x{}x{}",
                self.name, self.input_size, self.input_size, self.input_ch
            ));
        }
        if self.nodes.is_empty() {
            return Err(format!("graph {:?}: no nodes", self.name));
        }
        let mut names = std::collections::BTreeSet::new();
        let mut consumed = vec![false; self.nodes.len()];
        let mut shapes: Vec<Shape> = Vec::with_capacity(self.nodes.len());
        let input_shape = Shape { size: self.input_size, ch: self.input_ch };
        for (i, node) in self.nodes.iter().enumerate() {
            let at = |msg: String| format!("graph {:?}: node {i} ({:?}): {msg}", self.name, node.name);
            if node.name.is_empty() {
                return Err(format!("graph {:?}: node {i}: empty name", self.name));
            }
            if !names.insert(node.name.clone()) {
                return Err(at("duplicate node name".to_string()));
            }
            // Edge sanity first: every named producer must exist (no
            // dangling edges) and precede this node (a forward edge means
            // the node list is not topologically ordered — i.e. the graph
            // has a cycle, or was emitted unsorted).
            for &j in &node.inputs {
                if j >= self.nodes.len() {
                    return Err(at(format!(
                        "dangling edge: references undefined node {j} (graph has {} nodes)",
                        self.nodes.len()
                    )));
                }
                if j >= i {
                    return Err(at(format!(
                        "edge from node {j} ({:?}) points forward: nodes must be listed in \
                         topological order, so a forward edge means the graph has a cycle",
                        self.nodes[j].name
                    )));
                }
                consumed[j] = true;
            }
            if node.inputs.len() == 2 && node.inputs[0] == node.inputs[1] {
                return Err(at(format!("both inputs name the same node {}", node.inputs[0])));
            }
            // Arity.
            let arity_ok = if node.op.is_join() {
                node.inputs.len() == 2
            } else {
                node.inputs.len() <= 1
            };
            if !arity_ok {
                return Err(at(format!(
                    "op {:?} takes {} input(s), got {}",
                    node.op.wire_name(),
                    if node.op.is_join() { "exactly 2" } else { "0 or 1" },
                    node.inputs.len()
                )));
            }
            let in_shape =
                |slot: usize| if node.inputs.is_empty() { input_shape } else { shapes[node.inputs[slot]] };
            let spatial = |k: usize, stride: usize, pad: usize| -> Result<usize, String> {
                let s = in_shape(0);
                if k == 0 || stride == 0 {
                    return Err(at(format!("kernel/stride must be >= 1, got k={k} stride={stride}")));
                }
                if s.size + 2 * pad < k {
                    return Err(at(format!(
                        "kernel {k} exceeds padded input {} ({}+2*{pad})",
                        s.size + 2 * pad,
                        s.size
                    )));
                }
                Ok(window_out(s.size, k, stride, pad))
            };
            let out = match &node.op {
                Op::Conv { out_ch, k, stride, pad } => {
                    if *out_ch == 0 {
                        return Err(at("conv with 0 output channels".to_string()));
                    }
                    Shape { size: spatial(*k, *stride, *pad)?, ch: *out_ch }
                }
                Op::DwConv { k, stride, pad } => {
                    Shape { size: spatial(*k, *stride, *pad)?, ch: in_shape(0).ch }
                }
                Op::PwConv { out_ch, groups } => {
                    let s = in_shape(0);
                    if *out_ch == 0 || *groups == 0 {
                        return Err(at(format!("pwconv needs out_ch/groups >= 1, got {out_ch}/{groups}")));
                    }
                    if s.ch % groups != 0 {
                        return Err(at(format!("groups {groups} does not divide in_ch {}", s.ch)));
                    }
                    Shape { size: s.size, ch: *out_ch }
                }
                Op::MaxPool { k, stride, pad } | Op::AvgPool { k, stride, pad } => {
                    Shape { size: spatial(*k, *stride, *pad)?, ch: in_shape(0).ch }
                }
                Op::GlobalAvgPool => Shape { size: 1, ch: in_shape(0).ch },
                Op::Fc { out_ch } => {
                    if *out_ch == 0 {
                        return Err(at("fc with 0 output channels".to_string()));
                    }
                    Shape { size: 1, ch: *out_ch }
                }
                Op::Add => {
                    let (a, b) = (in_shape(0), in_shape(1));
                    if a != b {
                        return Err(at(format!(
                            "shape mismatch at add: {}x{}x{} vs {}x{}x{} (element-wise add needs \
                             identical branch shapes)",
                            a.size, a.size, a.ch, b.size, b.size, b.ch
                        )));
                    }
                    a
                }
                Op::Concat => {
                    let (a, b) = (in_shape(0), in_shape(1));
                    if a.size != b.size {
                        return Err(at(format!(
                            "shape mismatch at concat: cannot concatenate {}x{} with {}x{} branches \
                             (spatial sizes must match)",
                            a.size, a.size, b.size, b.size
                        )));
                    }
                    Shape { size: a.size, ch: a.ch + b.ch }
                }
                Op::Split { keep } => {
                    let s = in_shape(0);
                    if *keep == 0 || *keep >= s.ch {
                        return Err(at(format!(
                            "split keeps {keep} of {} channels (need 1 <= keep < in_ch)",
                            s.ch
                        )));
                    }
                    Shape { size: s.size, ch: *keep }
                }
                Op::Shuffle => in_shape(0),
            };
            shapes.push(out);
        }
        // Dead nodes: only the last node (the network output) may go
        // unconsumed — anything else is a disconnected CE.
        for (i, c) in consumed.iter().enumerate().take(self.nodes.len() - 1) {
            if !c {
                return Err(format!(
                    "graph {:?}: node {i} ({:?}): output is never consumed (only the last node may \
                     be the network output)",
                    self.name, self.nodes[i].name
                ));
            }
        }
        Ok(shapes)
    }

    /// Validate without keeping the shapes.
    pub fn validate(&self) -> Result<(), ReproError> {
        self.shapes().map(|_| ())
    }
}

/// Incremental [`Graph`] constructor — the deduplicated topology logic the
/// zoo builders share (successor of the old `nets::NetBuilder`). The
/// builder tracks a *cursor* (the node the next pushed op consumes);
/// branches rewind it with [`GraphBuilder::set_cursor`] and joins name the
/// other branch explicitly.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    name: String,
    input_size: usize,
    input_ch: usize,
    nodes: Vec<Node>,
    shapes: Vec<Shape>,
    block: String,
    cur: Option<usize>,
}

impl GraphBuilder {
    pub fn new(name: &str, input_size: usize, input_ch: usize) -> GraphBuilder {
        GraphBuilder {
            name: name.to_string(),
            input_size,
            input_ch,
            nodes: Vec::new(),
            shapes: Vec::new(),
            block: String::new(),
            cur: None,
        }
    }

    /// Start a new named block; subsequent nodes belong to it.
    pub fn block(&mut self, name: &str) -> &mut Self {
        self.block = name.to_string();
        self
    }

    /// The current cursor: `None` means the network input.
    pub fn cursor(&self) -> Option<usize> {
        self.cur
    }

    /// Rewind the cursor to an earlier point (a branch start); the next
    /// pushed node consumes that stream.
    pub fn set_cursor(&mut self, at: Option<usize>) -> &mut Self {
        self.cur = at;
        self
    }

    fn shape_at(&self, at: Option<usize>) -> Shape {
        match at {
            None => Shape { size: self.input_size, ch: self.input_ch },
            Some(i) => self.shapes[i],
        }
    }

    /// Channels at the cursor.
    pub fn cur_ch(&self) -> usize {
        self.shape_at(self.cur).ch
    }

    /// Spatial size at the cursor.
    pub fn cur_size(&self) -> usize {
        self.shape_at(self.cur).size
    }

    fn push(&mut self, op: Op, inputs: Vec<usize>, out: Shape) -> usize {
        let idx = self.nodes.len();
        self.nodes.push(Node {
            name: format!("{}_{}", self.block, idx),
            block: self.block.clone(),
            op,
            inputs,
        });
        self.shapes.push(out);
        self.cur = Some(idx);
        idx
    }

    fn push_linear(&mut self, op: Op, out: Shape) -> usize {
        let inputs = self.cur.into_iter().collect();
        self.push(op, inputs, out)
    }

    pub fn conv(&mut self, out_ch: usize, k: usize, stride: usize, pad: usize) -> usize {
        let size = window_out(self.cur_size(), k, stride, pad);
        self.push_linear(Op::Conv { out_ch, k, stride, pad }, Shape { size, ch: out_ch })
    }

    pub fn dwconv(&mut self, k: usize, stride: usize, pad: usize) -> usize {
        let s = self.shape_at(self.cur);
        let size = window_out(s.size, k, stride, pad);
        self.push_linear(Op::DwConv { k, stride, pad }, Shape { size, ch: s.ch })
    }

    pub fn pwconv(&mut self, out_ch: usize) -> usize {
        self.gpwconv(out_ch, 1)
    }

    pub fn gpwconv(&mut self, out_ch: usize, groups: usize) -> usize {
        let size = self.cur_size();
        self.push_linear(Op::PwConv { out_ch, groups }, Shape { size, ch: out_ch })
    }

    pub fn maxpool(&mut self, k: usize, stride: usize, pad: usize) -> usize {
        let s = self.shape_at(self.cur);
        let size = window_out(s.size, k, stride, pad);
        self.push_linear(Op::MaxPool { k, stride, pad }, Shape { size, ch: s.ch })
    }

    /// Windowed average pooling (ShuffleNetV1's stride-2 shortcut branch).
    pub fn avgpool(&mut self, k: usize, stride: usize, pad: usize) -> usize {
        let s = self.shape_at(self.cur);
        let size = window_out(s.size, k, stride, pad);
        self.push_linear(Op::AvgPool { k, stride, pad }, Shape { size, ch: s.ch })
    }

    pub fn global_avgpool(&mut self) -> usize {
        let ch = self.cur_ch();
        self.push_linear(Op::GlobalAvgPool, Shape { size: 1, ch })
    }

    pub fn fc(&mut self, out_ch: usize) -> usize {
        self.push_linear(Op::Fc { out_ch }, Shape { size: 1, ch: out_ch })
    }

    pub fn shuffle(&mut self) -> usize {
        let s = self.shape_at(self.cur);
        self.push_linear(Op::Shuffle, s)
    }

    pub fn split(&mut self, keep: usize) -> usize {
        let size = self.cur_size();
        self.push_linear(Op::Split { keep }, Shape { size, ch: keep })
    }

    /// Element-wise Add joining the cursor (through branch) with the
    /// output of `shortcut`.
    pub fn add_from(&mut self, shortcut: usize) -> usize {
        let through = self.cur.expect("add_from needs a through branch at the cursor");
        let out = self.shapes[through];
        self.push(Op::Add, vec![through, shortcut], out)
    }

    /// Concat joining the cursor (through branch) with the output of
    /// `shortcut`; output channels are the sum.
    pub fn concat_from(&mut self, shortcut: usize) -> usize {
        let through = self.cur.expect("concat_from needs a through branch at the cursor");
        let t = self.shapes[through];
        let s = self.shapes[shortcut];
        self.push(Op::Concat, vec![through, shortcut], Shape { size: t.size, ch: t.ch + s.ch })
    }

    pub fn finish(self) -> Graph {
        Graph {
            name: self.name,
            input_size: self.input_size,
            input_ch: self.input_ch,
            nodes: self.nodes,
        }
    }
}

/// Load a JSON network description from disk and lower it to the
/// streaming [`Network`] every downstream subsystem consumes — the
/// `--net-file` path of the CLI. All failures — unreadable file, schema
/// violation, shape inference, lowering — are [`ReproError::Network`]
/// errors prefixed with the offending path.
pub fn load_file(path: &std::path::Path) -> Result<Network, ReproError> {
    let prefix = format!("{}: ", path.display());
    let text = std::fs::read_to_string(path)
        .map_err(|e| ReproError::network(format!("{}{e}", prefix)))?;
    let graph = from_json(&text).map_err(|e| e.prefixed(&prefix))?;
    lower(&graph).map_err(|e| e.prefixed(&prefix))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear_graph() -> Graph {
        let mut b = GraphBuilder::new("toy", 8, 3);
        b.block("stem");
        b.conv(4, 3, 1, 1);
        b.block("head");
        b.global_avgpool();
        b.fc(10);
        b.finish()
    }

    #[test]
    fn builder_tracks_shapes_and_names() {
        let g = linear_graph();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[0].name, "stem_0");
        assert_eq!(g.nodes[2].name, "head_2");
        let shapes = g.shapes().unwrap();
        assert_eq!(shapes[0], Shape { size: 8, ch: 4 });
        assert_eq!(shapes[2], Shape { size: 1, ch: 10 });
    }

    #[test]
    fn dangling_edge_is_rejected_with_the_node_named() {
        let mut g = linear_graph();
        g.nodes[2].inputs = vec![9];
        let err = g.validate().unwrap_err();
        assert!(err.contains("dangling edge"), "{err}");
        assert!(err.contains("head_2"), "{err}");
    }

    #[test]
    fn forward_edges_cycles_are_rejected() {
        let mut g = linear_graph();
        g.nodes[1].inputs = vec![2]; // 1 -> 2 -> 1: a cycle
        let err = g.validate().unwrap_err();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn add_shape_mismatch_is_actionable() {
        let mut b = GraphBuilder::new("toy", 8, 3);
        b.block("b");
        let a = b.conv(4, 3, 1, 1);
        b.set_cursor(None);
        b.conv(8, 3, 2, 1);
        let g = {
            let mut g = b.finish();
            g.nodes.push(Node {
                name: "bad_add".into(),
                block: "b".into(),
                op: Op::Add,
                inputs: vec![1, a],
            });
            g
        };
        let err = g.validate().unwrap_err();
        assert!(err.contains("shape mismatch at add"), "{err}");
    }

    #[test]
    fn dead_nodes_are_rejected() {
        let mut b = GraphBuilder::new("toy", 8, 3);
        b.block("b");
        b.conv(4, 3, 1, 1);
        b.set_cursor(None);
        b.conv(8, 3, 2, 1); // first conv now dangles unconsumed
        let err = b.finish().validate().unwrap_err();
        assert!(err.contains("never consumed"), "{err}");
    }

    #[test]
    fn split_and_group_constraints() {
        let mut b = GraphBuilder::new("toy", 8, 6);
        b.block("b");
        b.split(6); // keep == in_ch: invalid
        let err = b.finish().validate().unwrap_err();
        assert!(err.contains("split keeps 6 of 6"), "{err}");

        let mut b = GraphBuilder::new("toy", 8, 5);
        b.block("b");
        b.gpwconv(9, 3); // 3 does not divide 5
        let err = b.finish().validate().unwrap_err();
        assert!(err.contains("groups 3 does not divide in_ch 5"), "{err}");
    }
}
