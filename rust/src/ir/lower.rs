//! Lowering: layer-graph IR -> the linear streaming [`Network`] (one CE
//! per layer + SCB edges) that Alg 1/Alg 2, the Eq 1-14 model, the cycle
//! simulator, and the sweep engine consume.
//!
//! The pass is 1:1 — node `i` becomes layer `i` — so it only has to
//! resolve *how each node's inputs map onto the streaming order*:
//!
//! * an edge from the immediately preceding node is the stream itself
//!   ([`LayerSrc::Prev`]);
//! * an edge from an earlier node `j` becomes a tee ([`LayerSrc::Tee`])
//!   of the first layer whose stream input is `j`'s output (the paper's
//!   two-branch ShuffleNet units, where both branches read the unit
//!   input);
//! * a two-input join (add/concat) must consume the preceding node as its
//!   through branch; the other edge becomes the [`Scb`] shortcut whose
//!   snapshot is taken where that producer's output enters the stream.
//!
//! Graphs whose edges cannot be expressed this way (a stream no earlier
//! layer carries) are rejected with an error naming the node — the linear
//! multi-CE pipeline genuinely cannot stream them.

use crate::nets::{Layer, LayerKind, LayerSrc, Network, Scb};
use crate::util::error::ReproError;

use super::{Graph, Op, Shape};

/// Lower a validated graph to the streaming network representation.
/// Lowering the zoo graphs reproduces the pre-IR hand-built networks
/// field-for-field (`rust/tests/ir.rs` pins this against the golden
/// baselines). Unstreamable graphs are rejected with
/// [`ReproError::Network`].
pub fn lower(graph: &Graph) -> Result<Network, ReproError> {
    let shapes = graph.shapes()?;
    let input_shape = Shape { size: graph.input_size, ch: graph.input_ch };
    // stream_src[t]: the node whose output layer t consumes as its stream
    // input (None = the network input), whether via Prev or a tee.
    let mut stream_src: Vec<Option<usize>> = Vec::with_capacity(graph.nodes.len());
    let mut layers: Vec<Layer> = Vec::with_capacity(graph.nodes.len());
    let mut scbs: Vec<Scb> = Vec::new();
    // Block index = run-length index over consecutive block-name runs,
    // matching how the zoo builders number their `block()` calls.
    let mut block = 0usize;
    let mut prev_block_name: Option<&str> = None;

    for (i, node) in graph.nodes.iter().enumerate() {
        let at = |msg: String| format!("graph {:?}: node {i} ({:?}): {msg}", graph.name, node.name);
        if prev_block_name.is_some_and(|p| p != node.block) {
            block += 1;
        }
        prev_block_name = Some(&node.block);

        // Resolve the stream source and (for joins) the SCB shortcut.
        let (main_in, src) = if node.op.is_join() {
            let (a, b) = (node.inputs[0], node.inputs[1]);
            let shortcut = if a + 1 == i {
                b
            } else if b + 1 == i {
                a
            } else {
                return Err(ReproError::network(at(format!(
                    "join consumes nodes {a} and {b}, but neither is the immediately preceding \
                     node {} — the streaming order cannot close this shortcut",
                    i - 1
                ))));
            };
            // The shortcut snapshot is the stream entering layer
            // `shortcut + 1` (== the output of layer `shortcut`).
            scbs.push(Scb { from_layer: shortcut + 1, join_layer: i });
            (Some(i - 1), LayerSrc::Prev)
        } else {
            match node.inputs.first().copied() {
                None if i == 0 => (None, LayerSrc::Prev),
                None => {
                    let t = stream_src.iter().position(Option::is_none).ok_or_else(|| {
                        ReproError::network(at(
                            "reads the network input, but no earlier layer streams it".to_string(),
                        ))
                    })?;
                    (None, LayerSrc::Tee(t))
                }
                Some(j) if j + 1 == i => (Some(j), LayerSrc::Prev),
                Some(j) => {
                    let t = stream_src.iter().position(|s| *s == Some(j)).ok_or_else(|| {
                        ReproError::network(at(format!(
                            "reads node {j} ({:?}), but no earlier layer consumes that output as \
                             its stream input, so there is nothing to tee",
                            graph.nodes[j].name
                        )))
                    })?;
                    (Some(j), LayerSrc::Tee(t))
                }
            }
        };
        stream_src.push(main_in);

        let in_shape = match main_in {
            None => input_shape,
            Some(j) => shapes[j],
        };
        let out_shape = shapes[i];
        let (kind, k, stride, pad, groups) = match &node.op {
            Op::Conv { k, stride, pad, .. } => (LayerKind::Stc, *k, *stride, *pad, 1),
            Op::DwConv { k, stride, pad } => (LayerKind::Dwc, *k, *stride, *pad, 1),
            Op::PwConv { groups, .. } => (LayerKind::Pwc, 1, 1, 0, *groups),
            Op::MaxPool { k, stride, pad } => (LayerKind::MaxPool, *k, *stride, *pad, 1),
            Op::AvgPool { k, stride, pad } => (LayerKind::AvgPool, *k, *stride, *pad, 1),
            Op::GlobalAvgPool => (LayerKind::AvgPool, in_shape.size, 1, 0, 1),
            Op::Fc { .. } => (LayerKind::Fc, 1, 1, 0, 1),
            Op::Add => (LayerKind::Add, 1, 1, 0, 1),
            Op::Concat => (LayerKind::Concat, 1, 1, 0, 1),
            Op::Split { .. } => (LayerKind::Split, 1, 1, 0, 1),
            Op::Shuffle => (LayerKind::Shuffle, 1, 1, 0, 1),
        };
        layers.push(Layer {
            name: node.name.clone(),
            kind,
            src,
            in_ch: in_shape.ch,
            out_ch: out_shape.ch,
            in_size: in_shape.size,
            out_size: out_shape.size,
            k,
            stride,
            pad,
            groups,
            block,
            block_name: node.block.clone(),
        });
    }

    let net = Network {
        name: graph.name.clone(),
        input_size: graph.input_size,
        input_ch: graph.input_ch,
        layers,
        scbs,
    };
    net.validate().map_err(ReproError::network)?;
    Ok(net)
}

#[cfg(test)]
mod tests {
    use super::super::{GraphBuilder, Node};
    use super::*;

    #[test]
    fn linear_graph_lowers_to_prev_chain() {
        let mut b = GraphBuilder::new("toy", 16, 3);
        b.block("stem");
        b.conv(8, 3, 2, 1);
        b.block("body");
        b.dwconv(3, 1, 1);
        b.pwconv(16);
        b.block("head");
        b.global_avgpool();
        b.fc(10);
        let net = lower(&b.finish()).unwrap();
        assert_eq!(net.layers.len(), 5);
        assert!(net.layers.iter().all(|l| l.src == LayerSrc::Prev));
        assert!(net.scbs.is_empty());
        assert_eq!(net.layers[0].name, "stem_0");
        assert_eq!(net.layers[0].block, 0);
        assert_eq!(net.layers[1].block, 1);
        assert_eq!(net.layers[3].block, 2);
        // Global average pooling lowers to a full-FM window.
        assert_eq!(net.layers[3].kind, LayerKind::AvgPool);
        assert_eq!(net.layers[3].k, 8);
        assert_eq!(net.layers[3].out_size, 1);
        net.validate().unwrap();
    }

    #[test]
    fn residual_add_lowers_to_an_scb() {
        let mut b = GraphBuilder::new("toy", 8, 4);
        b.block("unit");
        let u = b.conv(4, 3, 1, 1);
        b.pwconv(8);
        b.dwconv(3, 1, 1);
        b.pwconv(4);
        b.add_from(u);
        let net = lower(&b.finish()).unwrap();
        assert_eq!(net.scbs.len(), 1);
        assert_eq!(net.scbs[0].from_layer, u + 1);
        assert_eq!(net.scbs[0].join_layer, 4);
        assert_eq!(net.layers[4].kind, LayerKind::Add);
        // The snapshot is the residual input: layer u's output.
        assert_eq!(net.scbs[0].snapshot_shape(&net), (8, 4));
    }

    #[test]
    fn two_branch_unit_lowers_to_a_tee() {
        // ShuffleNetV2-style stride-2 unit: both branches read the unit
        // input; the second branch tees the stream the first consumes.
        let mut b = GraphBuilder::new("toy", 8, 4);
        b.block("stem");
        let u = b.conv(4, 3, 1, 1);
        b.block("unit");
        b.dwconv(3, 2, 1);
        let a_out = b.pwconv(6);
        b.set_cursor(Some(u));
        let b_first = b.pwconv(6);
        b.dwconv(3, 2, 1);
        b.pwconv(6);
        b.concat_from(a_out);
        let net = lower(&b.finish()).unwrap();
        // The second branch's first layer tees the unit input.
        assert_eq!(net.layers[b_first].src, LayerSrc::Tee(u + 1));
        assert_eq!(net.scbs.len(), 1);
        // Snapshot = the first branch's final output (entering layer b_first).
        assert_eq!(net.scbs[0].from_layer, b_first);
        assert_eq!(net.layers[6].kind, LayerKind::Concat);
        assert_eq!(net.layers[6].out_ch, 12);
        net.validate().unwrap();
    }

    #[test]
    fn unstreamable_joins_are_rejected() {
        // A join whose through-branch is not the preceding node cannot be
        // expressed in the linear streaming order.
        let mut b = GraphBuilder::new("toy", 8, 4);
        b.block("b");
        let a = b.conv(4, 3, 1, 1);
        let x = b.dwconv(3, 1, 1);
        b.pwconv(4);
        let mut g = b.finish();
        g.nodes.push(Node {
            name: "bad_join".into(),
            block: "b".into(),
            op: Op::Add,
            inputs: vec![a, x], // neither is node 2 (the preceding node)
        });
        let err = lower(&g).unwrap_err();
        assert!(err.contains("streaming order cannot close"), "{err}");
    }

    #[test]
    fn untee_able_streams_are_rejected() {
        // Node 2 reads node 0, but no earlier layer streams node 0's
        // output (node 1 reads the network input), so there is no tee.
        let g = Graph {
            name: "toy".into(),
            input_size: 8,
            input_ch: 3,
            nodes: vec![
                Node {
                    name: "a".into(),
                    block: "b".into(),
                    op: Op::Conv { out_ch: 4, k: 3, stride: 1, pad: 1 },
                    inputs: vec![],
                },
                Node {
                    name: "b".into(),
                    block: "b".into(),
                    op: Op::Conv { out_ch: 4, k: 3, stride: 1, pad: 1 },
                    inputs: vec![],
                },
                Node {
                    name: "c".into(),
                    block: "b".into(),
                    op: Op::Add,
                    inputs: vec![1, 0],
                },
            ],
        };
        // The add itself is fine (node 1 precedes it); push a consumer of
        // node 0's output that nothing streams, plus a join so every
        // intermediate output is consumed (the dead-node check must not
        // fire before the tee resolution does).
        let mut g = g;
        g.nodes.push(Node {
            name: "d".into(),
            block: "b".into(),
            op: Op::DwConv { k: 3, stride: 1, pad: 1 },
            inputs: vec![0],
        });
        g.nodes.push(Node {
            name: "e".into(),
            block: "b".into(),
            op: Op::Add,
            inputs: vec![3, 2],
        });
        let err = lower(&g).unwrap_err();
        assert!(err.contains("nothing to tee"), "{err}");
    }
}
