//! Constrained design-space optimizer — branch-and-bound over the sweep
//! matrix with Eq 1–14 analytic pruning.
//!
//! The exhaustive [`SweepSpec::run`] evaluates every
//! {network} × {platform} × {granularity} cell through the full Alg 1 →
//! Alg 2 → Eq 14 pipeline. `repro optimize` answers the question the
//! ROADMAP actually asks — *the best design under this SRAM/DSP/clock
//! budget* — without paying for the cells a cheap bound already rules
//! out. Per network, candidates are visited in the sweep's deterministic
//! matrix order and a candidate subtree is pruned when its analytic bound
//! cannot beat the incumbent:
//!
//! * **FPS upper bound** (maximize): Eq 14 says the frame period is the
//!   bottleneck CE's `T(i) = ceil(M/P_w) · ceil(F²/P_f) · depth` (Eq 11
//!   rounds). For every MAC layer `M·F²·depth` equals its Eq 1–3 MAC
//!   count, so `T(i) ≥ max(depth, ceil(MACs / cap))` where `cap` is the
//!   largest PE product any allocation can give one layer: the layer's
//!   own `P_w·P_f` ceiling capped by the DSP budget (one PE per DSP for
//!   DWC, two 8-bit MACs per DSP otherwise, §VI-A). Both FGPM and
//!   factorized spaces satisfy `P_w ≤ M, P_f ≤ F²`, so the bound holds
//!   for every granularity; `clock / T_lb` is therefore an admissible
//!   FPS ceiling.
//! * **SRAM lower bound** (minimize): Algorithm 1 is replayed exactly over
//!   the network's [`boundary_sweep`] curve (Eq 4–10 SRAM totals, Eq 13
//!   DRAM) for the candidate platform's budget — the true pre-recost SRAM
//!   at the boundary Alg 1 will pick. The WRCE weight-buffer recost only
//!   ever *adds* bytes, so this is a valid lower bound on the final
//!   [`crate::design::Design::sram_bytes`].
//! * **DRAM bound** (minimize): the same Alg 1 replay yields the *exact*
//!   Eq 13 DRAM traffic (the recost does not touch DRAM), so the DRAM
//!   objective prunes with an exact oracle.
//!
//! Pruning never changes the answer: a candidate is cut only when its
//! bound cannot *strictly* beat the incumbent, and the incumbent is
//! replaced only on strict improvement, so the winner is byte-identical
//! to the exhaustive sweep's matrix-first optimum
//! (`rust/tests/optimize.rs` pins this per objective, plus pruning
//! soundness: no pruned candidate evaluates better than the winner).
//!
//! [`Strategy::Anneal`] is the fallback for objectives the bound cannot
//! order: a seeded, deterministic simulated-annealing walk proposes
//! candidates (temperature-gated acceptance of worse moves) and any
//! candidate the walk never reached is swept afterwards, so the result
//! stays exact on the committed axes while the walk provides the
//! evaluation *order* richer axes will want. It uses no bounds and never
//! prunes.
//!
//! Search statistics come back per network ([`SearchStats`]): candidates,
//! evaluated, pruned, the total FGPM/factorized parallel-space size the
//! pruned candidates covered (via the O(1)
//! [`crate::alloc::fgpm::fgpm_space_size`] closed form — this is its hot
//! loop), and mean bound tightness (bound/exact ratio in `[0, 1]`, `1.0`
//! = the bound was exact for every evaluated candidate).
//!
//! Execution reuses the sweep engine wholesale: per-(network, platform)
//! bound probes and per-network searches fan over
//! [`crate::util::pool::parallel_map_fallible`] with the sweep's
//! fault-isolation semantics (a panicking or erroring cell becomes a
//! [`CellFailure`], the search continues), and every evaluation goes
//! through the same private cell-key/eval path as [`SweepSpec::run`] —
//! including the content-keyed [`super::cache`] layer, so an optimizer
//! run hits a warm sweep cache and vice versa.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::alloc::fgpm::{factor_space, fgpm_space_size};
use crate::alloc::memory_alloc::boundary_sweep;
use crate::alloc::memory_alloc::BoundaryPoint;
use crate::alloc::Granularity;
use crate::design::Platform;
use crate::model::memory::MemoryModelCfg;
use crate::nets::{LayerKind, Network};
use crate::util::error::ReproError;
use crate::util::fault;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::prop::Rng;

use super::{cache, CacheStats, CellCache, CellFailure, SweepCell, SweepSpec};

/// The scalar objective a search optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Maximize Eq 14 predicted FPS at the candidate platform's clock.
    Fps,
    /// Minimize recosted on-chip SRAM bytes.
    Sram,
    /// Minimize Eq 13 DRAM bytes per frame.
    Dram,
}

impl Objective {
    /// Parse the CLI's `--objective` value.
    pub fn parse(s: &str) -> Result<Objective, ReproError> {
        match s.to_ascii_lowercase().as_str() {
            "fps" => Ok(Objective::Fps),
            "sram" => Ok(Objective::Sram),
            "dram" => Ok(Objective::Dram),
            _ => Err(ReproError::config(format!(
                "--objective: unknown objective {s:?} (known objectives: fps, sram, dram)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Objective::Fps => "fps",
            Objective::Sram => "sram",
            Objective::Dram => "dram",
        }
    }

    /// Whether value `a` is strictly better than `b` under this objective.
    fn better(self, a: f64, b: f64) -> bool {
        match self {
            Objective::Fps => a > b,
            Objective::Sram | Objective::Dram => a < b,
        }
    }

    /// The exact objective value of an evaluated cell.
    pub fn exact(self, cell: &SweepCell) -> f64 {
        match self {
            Objective::Fps => cell.design().predicted().fps,
            Objective::Sram => cell.design().sram_bytes() as f64,
            Objective::Dram => cell.design().dram_bytes() as f64,
        }
    }

    /// The admissible bound of a candidate (optimistic: never worse than
    /// any reachable exact value).
    fn bound_value(self, probe: &BoundProbe) -> f64 {
        match self {
            Objective::Fps => probe.fps_ub,
            Objective::Sram => probe.sram_lb as f64,
            Objective::Dram => probe.dram_exact as f64,
        }
    }
}

/// How the per-network search walks its candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Matrix-order branch-and-bound with Eq 1–14 pruning (the default).
    BranchBound,
    /// Seeded simulated-annealing walk + exhaustive sweep-up of unvisited
    /// candidates: exact, bound-free, never prunes.
    Anneal,
}

impl Strategy {
    /// Parse the CLI's `--strategy` value.
    pub fn parse(s: &str) -> Result<Strategy, ReproError> {
        match s.to_ascii_lowercase().as_str() {
            "bnb" | "branch-bound" => Ok(Strategy::BranchBound),
            "anneal" => Ok(Strategy::Anneal),
            _ => Err(ReproError::config(format!(
                "--strategy: unknown strategy {s:?} (known strategies: bnb, anneal)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Strategy::BranchBound => "bnb",
            Strategy::Anneal => "anneal",
        }
    }
}

/// One candidate's analytic bounds — per (network, platform), shared by
/// that pair's granularity candidates (Alg 1 and the Eq 14 period bound
/// are granularity-independent).
#[derive(Debug, Clone, Copy)]
struct BoundProbe {
    /// Exact pre-recost Alg 1 SRAM bytes (lower bound on the final cell).
    sram_lb: u64,
    /// Exact Eq 13 DRAM bytes/frame at Alg 1's boundary.
    dram_exact: u64,
    /// Admissible Eq 14 FPS ceiling at the platform's clock.
    fps_ub: f64,
}

/// A constrained search over a sweep matrix: which scalar to optimize and
/// how to walk the candidates. The embedded [`SweepSpec`] supplies the
/// axes, simulation depth, worker count, clock-curve axis, and cache
/// directory — an optimizer run is *defined* as picking from exactly the
/// cells the exhaustive sweep would materialize.
#[derive(Debug, Clone)]
pub struct OptimizeSpec {
    pub sweep: SweepSpec,
    pub objective: Objective,
    pub strategy: Strategy,
    /// Annealing-walk proposal count ([`Strategy::Anneal`] only).
    pub anneal_iters: usize,
}

impl OptimizeSpec {
    pub fn new(sweep: SweepSpec, objective: Objective, strategy: Strategy) -> OptimizeSpec {
        OptimizeSpec { sweep, objective, strategy, anneal_iters: 64 }
    }

    /// Run the search: per-(network, platform) bound probes, then one
    /// independent search per network, both fanned over
    /// [`pool::parallel_map_fallible`] with the sweep's fault isolation.
    /// Deterministic for any [`SweepSpec::jobs`] value.
    pub fn run(&self) -> OptimizeReport {
        let spec = &self.sweep;
        let frames_req = spec.frames.filter(|&f| f > 0);
        let per_net = spec.platforms.len() * spec.granularities.len();

        // Phase 1: analytic bounds per (network, platform). A probe that
        // fails (degenerate budget, or a panic caught by the pool) marks
        // every candidate it covers as failed — the same typed error an
        // exhaustive evaluation of those cells would report.
        let probe_items: Vec<(usize, usize)> = (0..spec.nets.len())
            .flat_map(|ni| (0..spec.platforms.len()).map(move |pi| (ni, pi)))
            .collect();
        let probes = pool::parallel_map_fallible(spec.jobs, &probe_items, |_, &(ni, pi)| {
            let (net, platform) = (&spec.nets[ni], &spec.platforms[pi]);
            if platform.sram_bytes == 0 || platform.dsp_budget == 0 {
                return Err(ReproError::allocation(format!(
                    "platform {:?}: degenerate budget (sram_bytes={}, dsp_budget={}) — \
                     Algorithm 1/2 need nonzero SRAM and DSP budgets",
                    platform.name, platform.sram_bytes, platform.dsp_budget
                )));
            }
            let points = boundary_sweep(net, &MemoryModelCfg::default());
            let (sram_lb, dram_exact) = replay_alg1(&points, platform.sram_bytes);
            Ok(BoundProbe { sram_lb, dram_exact, fps_ub: fps_upper_bound(net, platform) })
        });

        // Phase 2: one search per network over the shared cache/counters.
        let cache = spec.cache_dir.as_deref().map(CellCache::open);
        let hits = AtomicU64::new(0);
        let misses = AtomicU64::new(0);
        let store_errors = AtomicU64::new(0);
        let faults_armed = fault::armed();
        let net_indices: Vec<usize> = (0..spec.nets.len()).collect();
        let outcomes = pool::parallel_map_fallible(spec.jobs, &net_indices, |_, &ni| {
            let net_probes = &probes[ni * spec.platforms.len()..(ni + 1) * spec.platforms.len()];
            Ok(self.search_network(
                ni,
                per_net,
                net_probes,
                &cache,
                frames_req,
                faults_armed,
                (&hits, &misses, &store_errors),
            ))
        });

        let mut searches = Vec::with_capacity(spec.nets.len());
        let mut failures = Vec::new();
        for (ni, outcome) in outcomes.into_iter().enumerate() {
            match outcome {
                Ok((search, mut fs)) => {
                    searches.push(search);
                    failures.append(&mut fs);
                }
                // The search scaffolding itself died (a panic outside any
                // single evaluation): every candidate of the network is
                // reported failed, mirroring the probe-failure path.
                Err(error) => {
                    let net = &spec.nets[ni];
                    for (ci, (pi, gi)) in candidate_axes(spec).into_iter().enumerate() {
                        failures.push(CellFailure {
                            index: ni * per_net + ci,
                            network: net.name.clone(),
                            platform: spec.platforms[pi].name.clone(),
                            granularity: spec.granularities[gi],
                            error: error.clone(),
                        });
                    }
                    searches.push(NetworkSearch {
                        network: net.name.clone(),
                        winner: None,
                        winner_index: None,
                        pruned_indices: Vec::new(),
                        stats: SearchStats { candidates: per_net, ..SearchStats::default() },
                    });
                }
            }
        }
        let cache_stats = cache.map(|_| CacheStats {
            hits: hits.into_inner(),
            misses: misses.into_inner(),
            store_errors: store_errors.into_inner(),
        });
        OptimizeReport {
            objective: self.objective,
            strategy: self.strategy,
            searches,
            failures,
            cache: cache_stats,
        }
    }

    /// The branch-and-bound (or annealing) walk of one network's
    /// candidates. Every evaluation is individually fault-isolated: a
    /// typed error or caught panic becomes a [`CellFailure`] and the walk
    /// continues with the incumbent unchanged.
    #[allow(clippy::too_many_arguments)]
    fn search_network(
        &self,
        ni: usize,
        per_net: usize,
        net_probes: &[Result<BoundProbe, ReproError>],
        cell_cache: &Option<CellCache>,
        frames_req: Option<u64>,
        faults_armed: bool,
        counters: (&AtomicU64, &AtomicU64, &AtomicU64),
    ) -> (NetworkSearch, Vec<CellFailure>) {
        let spec = &self.sweep;
        let net = &spec.nets[ni];
        let candidates = candidate_axes(spec);
        let mut failures = Vec::new();
        let mut pruned_indices = Vec::new();
        let mut stats = SearchStats { candidates: per_net, ..SearchStats::default() };
        // Incumbent: (exact objective value, candidate index, cell).
        let mut winner: Option<(f64, usize, SweepCell)> = None;
        let mut tightness_sum = 0.0;

        // Evaluate candidate `ci`, fold it into the incumbent (strict
        // improvement, or an exact tie at a lower matrix index — the
        // exhaustive sweep's matrix-first rule), and return its exact
        // objective value (`None` when the evaluation failed).
        let evaluate = |ci: usize,
                        winner: &mut Option<(f64, usize, SweepCell)>,
                        stats: &mut SearchStats,
                        tightness_sum: &mut f64,
                        failures: &mut Vec<CellFailure>|
         -> Option<f64> {
            let (pi, gi) = candidates[ci];
            let (platform, granularity) = (&spec.platforms[pi], spec.granularities[gi]);
            match self.eval_one(
                net,
                platform,
                granularity,
                frames_req,
                cell_cache,
                faults_armed,
                counters,
            ) {
                Ok(cell) => {
                    stats.evaluated += 1;
                    let value = self.objective.exact(&cell);
                    if let Ok(probe) = &net_probes[pi] {
                        *tightness_sum += ratio(self.objective.bound_value(probe), value);
                    }
                    let improves = match winner {
                        None => true,
                        Some((wv, wi, _)) => {
                            self.objective.better(value, *wv) || (value == *wv && ci < *wi)
                        }
                    };
                    if improves {
                        *winner = Some((value, ci, cell));
                    }
                    Some(value)
                }
                Err(error) => {
                    failures.push(CellFailure {
                        index: ni * per_net + ci,
                        network: net.name.clone(),
                        platform: platform.name.clone(),
                        granularity,
                        error,
                    });
                    None
                }
            }
        };

        match self.strategy {
            Strategy::BranchBound => {
                for (ci, &(pi, gi)) in candidates.iter().enumerate() {
                    let probe = match &net_probes[pi] {
                        Ok(p) => p,
                        Err(error) => {
                            failures.push(CellFailure {
                                index: ni * per_net + ci,
                                network: net.name.clone(),
                                platform: spec.platforms[pi].name.clone(),
                                granularity: spec.granularities[gi],
                                error: error.clone(),
                            });
                            continue;
                        }
                    };
                    // Prune when the optimistic bound cannot strictly beat
                    // the incumbent. A bound that merely *ties* is cut too:
                    // the incumbent was evaluated earlier in matrix order,
                    // so a tying candidate could never replace it — the
                    // matrix-first optimum is preserved exactly
                    // (pruning-soundness test in rust/tests/optimize.rs).
                    if let Some((wv, _, _)) = &winner {
                        if !self.objective.better(self.objective.bound_value(probe), *wv) {
                            pruned_indices.push(ni * per_net + ci);
                            stats.pruned += 1;
                            stats.pruned_space +=
                                parallel_space_size(net, spec.granularities[gi]);
                            continue;
                        }
                    }
                    evaluate(ci, &mut winner, &mut stats, &mut tightness_sum, &mut failures);
                }
            }
            Strategy::Anneal => {
                let n = candidates.len();
                let mut visited = vec![false; n];
                // Seeded per network (content-hashed name), so the walk is
                // reproducible and independent of worker scheduling.
                let mut rng = Rng::new(cache::fnv1a64(net.name.as_bytes(), 0x5EED) | 1);
                // Metropolis chain state: the value the walk currently
                // sits on (distinct from the matrix-first incumbent, which
                // only ever improves).
                let mut current: Option<f64> = None;
                let mut temp = 1.0_f64;
                for it in 0..self.anneal_iters.max(1).min(n.saturating_mul(16).max(1)) {
                    let ci = if it == 0 { 0 } else { rng.range(0, n.max(1) - 1) };
                    temp *= 0.92;
                    if n == 0 || visited[ci] {
                        continue;
                    }
                    visited[ci] = true;
                    let value =
                        evaluate(ci, &mut winner, &mut stats, &mut tightness_sum, &mut failures);
                    if let Some(v) = value {
                        let accept = match current {
                            None => true,
                            Some(cur) => {
                                // Relative worseness of the proposal; a
                                // better move always moves the chain.
                                let worse = match self.objective {
                                    Objective::Fps => cur - v,
                                    Objective::Sram | Objective::Dram => v - cur,
                                };
                                worse <= 0.0
                                    || rng.f64()
                                        < (-(worse / cur.abs().max(1e-9)) / temp.max(1e-9)).exp()
                            }
                        };
                        if accept {
                            current = Some(v);
                        }
                    }
                }
                // Exactness sweep-up: evaluate whatever the walk never
                // reached, in matrix order, so the reported winner is the
                // true matrix-first optimum regardless of the walk's path.
                for (ci, seen) in visited.iter().enumerate() {
                    if !seen {
                        evaluate(ci, &mut winner, &mut stats, &mut tightness_sum, &mut failures);
                    }
                }
            }
        }

        stats.bound_tightness =
            (stats.evaluated > 0).then(|| tightness_sum / stats.evaluated as f64);
        let (winner_index, winner) = match winner {
            Some((_, ci, cell)) => (Some(ni * per_net + ci), Some(cell)),
            None => (None, None),
        };
        (
            NetworkSearch {
                network: net.name.clone(),
                winner,
                winner_index,
                pruned_indices,
                stats,
            },
            failures,
        )
    }

    /// Evaluate one candidate through the sweep engine's private
    /// cache/eval path — byte-identical cells to [`SweepSpec::run`], same
    /// hit/miss accounting, same fault-injection sites — with the
    /// evaluation itself wrapped in `catch_unwind` so an injected (or
    /// organic) panic degrades to a typed [`ReproError`] instead of
    /// killing the whole per-network search.
    fn eval_one(
        &self,
        net: &Network,
        platform: &Platform,
        granularity: Granularity,
        frames_req: Option<u64>,
        cell_cache: &Option<CellCache>,
        faults_armed: bool,
        (hits, misses, store_errors): (&AtomicU64, &AtomicU64, &AtomicU64),
    ) -> Result<SweepCell, ReproError> {
        let spec = &self.sweep;
        let guarded = |key_text: &str| -> Result<SweepCell, ReproError> {
            match catch_unwind(AssertUnwindSafe(|| {
                spec.eval_cell(net, platform, granularity, frames_req, key_text)
            })) {
                Ok(result) => result,
                Err(payload) => Err(ReproError::from_panic(payload)),
            }
        };
        if let Some(cache) = cell_cache {
            let key = spec.cell_key(net, platform, granularity, frames_req);
            let key_text = key.to_string();
            if let Some(cell) = cache.load(&key) {
                // Same verbatim re-check as the sweep's hit path.
                if format!("{:?}", cell.design().network()) == format!("{net:?}") {
                    hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(cell);
                }
            }
            let cell = guarded(&key_text)?;
            if cache.store(&key, &cell).is_err() {
                store_errors.fetch_add(1, Ordering::Relaxed);
            }
            misses.fetch_add(1, Ordering::Relaxed);
            Ok(cell)
        } else {
            let key_text = if faults_armed {
                spec.cell_key(net, platform, granularity, frames_req).to_string()
            } else {
                String::new()
            };
            guarded(&key_text)
        }
    }
}

/// The candidate axes of one network, in the sweep's matrix order:
/// `(platform index, granularity index)`, platforms outer.
fn candidate_axes(spec: &SweepSpec) -> Vec<(usize, usize)> {
    let mut v = Vec::with_capacity(spec.platforms.len() * spec.granularities.len());
    for pi in 0..spec.platforms.len() {
        for gi in 0..spec.granularities.len() {
            v.push((pi, gi));
        }
    }
    v
}

/// Exact replay of Algorithm 1 over a precomputed boundary curve
/// (indexed by boundary, `0..=L`): arg-min SRAM first, then advance while
/// the next boundary's SRAM stays strictly under the budget. Returns the
/// chosen boundary's `(sram_bytes, dram_bytes)` — identical to
/// [`crate::alloc::balanced_memory_allocation`] by construction, minus
/// the WRCE recost (which is why SRAM is a lower bound and DRAM exact).
fn replay_alg1(points: &[BoundaryPoint], sram_budget: u64) -> (u64, u64) {
    let mut best = u64::MAX;
    let mut b = 0usize;
    for p in points {
        if p.sram_bytes < best {
            best = p.sram_bytes;
            b = p.boundary;
        }
    }
    let l_total = points.len() - 1;
    for i in b..l_total {
        if points[i + 1].sram_bytes < sram_budget {
            b = i + 1;
        } else {
            break;
        }
    }
    (points[b].sram_bytes, points[b].dram_bytes)
}

/// Admissible Eq 14 FPS ceiling: `clock / T_lb` with `T_lb` the largest
/// per-MAC-layer period lower bound `max(depth, ceil(MACs / cap))` (see
/// the module docs for the derivation). Infinite for a network with no
/// MAC layers (nothing bounds the period).
fn fps_upper_bound(net: &Network, platform: &Platform) -> f64 {
    let mut t_lb = 0u64;
    for l in net.layers.iter().filter(|l| l.kind.is_mac()) {
        let dsp_pe_cap = match l.kind {
            // One PE per DSP for DWC; two 8-bit MACs per DSP otherwise.
            LayerKind::Dwc => platform.dsp_budget as u64,
            _ => 2 * platform.dsp_budget as u64,
        };
        let pe_cap = dsp_pe_cap.min((l.max_pw() * l.max_pf()) as u64).max(1);
        t_lb = t_lb.max(l.reduction_depth().max(l.macs().div_ceil(pe_cap)));
    }
    if t_lb == 0 {
        f64::INFINITY
    } else {
        platform.clock_hz / t_lb as f64
    }
}

/// The parallel-space cardinality one pruned candidate covered: per MAC
/// layer, the product of its `P_w` and `P_f` axis sizes — FGPM's via the
/// O(1) [`fgpm_space_size`] closed form, factorized via the divisor
/// count — summed over layers (Alg 2 tunes layers independently).
fn parallel_space_size(net: &Network, granularity: Granularity) -> u64 {
    let size = |m: usize| -> u64 {
        match granularity {
            Granularity::Fgpm => fgpm_space_size(m) as u64,
            Granularity::Factorized => factor_space(m).len() as u64,
        }
    };
    net.layers
        .iter()
        .filter(|l| l.kind.is_mac())
        .map(|l| size(l.max_pw()) * size(l.max_pf()))
        .sum()
}

/// Orientation-free bound/exact agreement in `[0, 1]` (`1.0` = exact).
fn ratio(bound: f64, exact: f64) -> f64 {
    let (lo, hi) = if bound <= exact { (bound, exact) } else { (exact, bound) };
    if hi == 0.0 {
        1.0
    } else if !lo.is_finite() || !hi.is_finite() {
        0.0
    } else {
        lo / hi
    }
}

/// Per-network search statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchStats {
    /// Candidates the network's subtree holds (platforms × granularities).
    pub candidates: usize,
    /// Candidates evaluated through the full pipeline.
    pub evaluated: usize,
    /// Candidates cut by the analytic bound before any evaluation.
    pub pruned: usize,
    /// Total FGPM/factorized parallel-space points the pruned candidates
    /// covered — the work Alg 2 never had to order.
    pub pruned_space: u64,
    /// Mean bound/exact agreement over evaluated candidates (`1.0` =
    /// exact bound); `None` when nothing was evaluated.
    pub bound_tightness: Option<f64>,
}

/// One network's search outcome.
#[derive(Debug, Clone)]
pub struct NetworkSearch {
    pub network: String,
    /// The winning cell — byte-identical to the exhaustive sweep's best
    /// cell for this network — or `None` when every candidate failed.
    pub winner: Option<SweepCell>,
    /// The winner's index in the exhaustive sweep's matrix order (the
    /// `cells` index a clean `repro sweep --json` would give it).
    pub winner_index: Option<usize>,
    /// Matrix indices of the candidates the bound pruned.
    pub pruned_indices: Vec<usize>,
    pub stats: SearchStats,
}

impl NetworkSearch {
    /// Stable sorted-key JSON value — one element of the `searches` array
    /// in `repro optimize --json` output.
    pub fn to_json_value(&self) -> Json {
        let mut s = BTreeMap::new();
        s.insert(
            "bound_tightness".to_string(),
            match self.stats.bound_tightness {
                Some(t) => Json::Num(t),
                None => Json::Null,
            },
        );
        s.insert("candidates".to_string(), Json::Num(self.stats.candidates as f64));
        s.insert("evaluated".to_string(), Json::Num(self.stats.evaluated as f64));
        s.insert("pruned".to_string(), Json::Num(self.stats.pruned as f64));
        s.insert("pruned_space".to_string(), Json::Num(self.stats.pruned_space as f64));
        let mut m = BTreeMap::new();
        m.insert("network".to_string(), Json::Str(self.network.clone()));
        m.insert(
            "pruned_indices".to_string(),
            Json::Arr(self.pruned_indices.iter().map(|&i| Json::Num(i as f64)).collect()),
        );
        m.insert("stats".to_string(), Json::Obj(s));
        m.insert(
            "winner".to_string(),
            match &self.winner {
                Some(cell) => cell.to_json_value(),
                None => Json::Null,
            },
        );
        m.insert(
            "winner_index".to_string(),
            match self.winner_index {
                Some(i) => Json::Num(i as f64),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }
}

/// The result of a constrained search: one [`NetworkSearch`] per network
/// in spec order, plus the same fault-isolation bookkeeping as
/// [`super::SweepReport`].
#[derive(Debug, Clone)]
pub struct OptimizeReport {
    pub objective: Objective,
    pub strategy: Strategy,
    pub searches: Vec<NetworkSearch>,
    /// Candidates that failed to evaluate (typed error or caught panic),
    /// in matrix order within each network.
    pub failures: Vec<CellFailure>,
    /// Hit/miss stats against the shared sweep cell cache; `None` when
    /// uncached. Excluded from [`OptimizeReport::to_json`] (stderr only)
    /// so warm and cold documents stay byte-identical.
    pub cache: Option<CacheStats>,
}

impl OptimizeReport {
    /// The whole report as one stable sorted-key JSON line — the
    /// `repro optimize --json` output. Byte-identical for any
    /// [`SweepSpec::jobs`] value and any cache state; the `failures` key
    /// appears only when at least one candidate failed (clean documents
    /// stay diffable across trajectories).
    pub fn to_json(&self) -> String {
        let mut m = BTreeMap::new();
        if !self.failures.is_empty() {
            m.insert(
                "failures".to_string(),
                Json::Arr(self.failures.iter().map(CellFailure::to_json_value).collect()),
            );
        }
        m.insert("objective".to_string(), Json::Str(self.objective.name().to_string()));
        m.insert(
            "searches".to_string(),
            Json::Arr(self.searches.iter().map(NetworkSearch::to_json_value).collect()),
        );
        m.insert("strategy".to_string(), Json::Str(self.strategy.name().to_string()));
        m.insert("version".to_string(), Json::Num(1.0));
        Json::Obj(m).to_string()
    }

    /// Total pruned candidates across every network.
    pub fn total_pruned(&self) -> usize {
        self.searches.iter().map(|s| s.stats.pruned).sum()
    }

    /// Total candidates across every network (the exhaustive cell count).
    pub fn total_candidates(&self) -> usize {
        self.searches.iter().map(|s| s.stats.candidates).sum()
    }
}

/// Process exit code for an optimizer run: [`super::EXIT_PARTIAL_FAILURE`]
/// when any candidate failed, `0` otherwise (usage errors exit 2 before a
/// report exists).
pub fn exit_code(report: &OptimizeReport) -> u8 {
    if report.failures.is_empty() {
        0
    } else {
        super::EXIT_PARTIAL_FAILURE
    }
}
