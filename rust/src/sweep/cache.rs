//! Content-keyed per-cell evaluation cache — the sweep's memoization
//! layer.
//!
//! Re-deriving the same (network, platform budgets, granularity, clock)
//! cell is what every example, test, and CI sweep spends its time on, so
//! a [`CellCache`] persists each evaluated [`SweepCell`] to one file in a
//! cache directory and serves later evaluations from disk. The warm path
//! reloads designs through [`Design::from_json_unchecked`] — **zero
//! Algorithm 1 / Algorithm 2 re-derivations** (the claim is enforced via
//! [`crate::alloc::derivations`] counters in
//! `rust/tests/differential.rs`), and a warm sweep's JSON and artifacts
//! are byte-identical to a cold one's.
//!
//! # Keying
//!
//! Entries are *content*-keyed: the key is the stable sorted-key JSON of
//! every input that can change a cell's content — network name plus a
//! structural fingerprint, the full platform budget object (SRAM, DSPs,
//! clock, name), granularity, simulated frame count, simulator options,
//! the `--clocks` curve axis, and (only when requested, so pre-FIFO
//! entries keep hitting) the `--fifo` figure request. The key hashes
//! (twice-seeded FNV-1a)
//! into the entry file name, **and** is stored verbatim inside the entry:
//! a load only hits when the stored key equals the probe key exactly. The
//! cell payload additionally carries its own FNV-1a checksum (`check`),
//! verified on load — so hash collisions, stale schema versions,
//! truncated files, and bit-rotted payloads all degrade to misses, never
//! to wrong cells (the no-stale-hits and corruption properties in
//! `rust/tests/proptests.rs`).
//!
//! The cache is best-effort by design: unreadable directories or write
//! failures degrade to cold evaluation (counted as misses) and never fail
//! the cell — but store failures are *counted*
//! ([`CacheStats::store_errors`]) and surfaced in the stderr summary
//! instead of vanishing silently. Callers that want fail-loudly semantics
//! probe the directory first, as the `repro sweep --cache-dir` CLI path
//! does. Both halves are fault-injectable ([`crate::util::fault`]: the
//! `cache.load` site forces misses, `cache.store` forces torn writes) —
//! `rust/tests/faults.rs` proves a torn or failed write never changes the
//! bytes any later run serves.
//!
//! Every network is warm-servable: zoo cells reload by rebuilding the
//! network by name from [`crate::nets`], and non-zoo cells (a `--net-file`
//! graph) reload from the `network_def` object their design artifact
//! embeds. Either way [`super::SweepSpec::run`] re-checks the reloaded
//! network verbatim against the probe's at hit time, so a renamed or
//! edited network file degrades to a miss, never a wrong cell.
//!
//! # Eviction
//!
//! The cache grows one file per distinct cell until [`CellCache::gc`]
//! (the CLI's `repro sweep --cache-gc <max-entries>`) trims it to a
//! budget. Eviction is LRU: serving a hit re-writes the entry's bytes to
//! bump its mtime, and `gc` deletes oldest-first beyond the budget — so
//! the working set of a sweep that just ran is always retained.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::design::Design;
use crate::model::throughput::ClockPoint;
use crate::util::error::ReproError;
use crate::util::fault;
use crate::util::json::Json;

use super::{SimFigures, SweepCell};

/// Schema version of one cache entry file; bumped whenever the cell or
/// key serialization changes shape, so old entries miss instead of
/// half-parsing. v2 added the `check` payload checksum.
const ENTRY_VERSION: f64 = 2.0;

/// Seed of the payload checksum (distinct from both file-name seeds so a
/// key/check confusion can never validate).
const CHECK_SEED: u64 = 0x6c62_272e_07bb_0142;

/// Hex checksum of the canonical cell serialization, stored inside the
/// entry and re-verified on load: a flipped bit anywhere in the payload
/// degrades the entry to a miss instead of serving a corrupted cell.
fn payload_check(cell_text: &str) -> String {
    format!("{:016x}", fnv1a64(cell_text.as_bytes(), CHECK_SEED))
}

/// Hit/miss counts of one sweep run against a [`CellCache`] — surfaced
/// as [`super::SweepReport::cache`] and printed (to stderr) by
/// `repro sweep --cache/--cache-dir`. Deliberately **not** part of
/// [`super::SweepReport::to_json`]: the JSON document must stay
/// byte-identical between warm and cold runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries that failed to persist (I/O error or an injected
    /// `cache.store` fault). The cell itself still succeeds — a store
    /// failure only costs a future warm hit — but it must not vanish
    /// silently: the stderr summary appends the count when nonzero.
    pub store_errors: u64,
}

impl CacheStats {
    /// Cells probed in total.
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of probes served from the cache (0.0 when nothing was
    /// probed). A fully warm run reports exactly 1.0.
    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }

    /// The one-line stats rendering the CLI prints to stderr (and CI
    /// greps for `100.0% hit rate` on its warm step). Store errors are
    /// appended only when present, so the healthy-path line is unchanged.
    pub fn summary(&self, dir: &Path) -> String {
        let errors = if self.store_errors > 0 {
            format!(", {} store errors", self.store_errors)
        } else {
            String::new()
        };
        format!(
            "cache: {} hits, {} misses ({:.1}% hit rate{}) at {}",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            errors,
            dir.display()
        )
    }
}

/// A directory of memoized sweep cells. Open is cheap; every probe is one
/// file read keyed by content hash.
#[derive(Debug, Clone)]
pub struct CellCache {
    dir: PathBuf,
}

impl CellCache {
    /// Open (creating if missing, best-effort) a cache rooted at `dir`.
    pub fn open(dir: &Path) -> CellCache {
        let _ = std::fs::create_dir_all(dir);
        CellCache { dir: dir.to_path_buf() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Entry file for a key: two differently-seeded 64-bit FNV-1a hashes
    /// of the canonical key serialization. The name is only a lookup
    /// accelerator — equality of the *stored* key decides a hit.
    fn entry_path(&self, key_text: &str) -> PathBuf {
        let b = key_text.as_bytes();
        self.dir.join(format!(
            "{:016x}{:016x}.cell.json",
            fnv1a64(b, 0xcbf2_9ce4_8422_2325),
            fnv1a64(b, 0x9747_b28c_8c5e_a5a3)
        ))
    }

    /// Probe for `key`; `Some` only when an entry exists whose stored key
    /// is byte-equal to `key` and whose cell deserializes cleanly. Every
    /// other outcome (absent file, I/O error, version or key mismatch,
    /// malformed cell) is a miss.
    ///
    /// A hit also *touches* the entry (rewrites the identical bytes via
    /// the same temp-file-and-rename path as [`CellCache::store`], best
    /// effort) so its mtime records the access — that recency is what
    /// [`CellCache::gc`]'s newest-first retention order keys on, making
    /// eviction LRU rather than insertion-order.
    pub(super) fn load(&self, key: &Json) -> Option<SweepCell> {
        let key_text = key.to_string();
        if fault::trip(fault::Site::CacheLoad, &key_text) {
            return None; // injected read failure: a plain miss
        }
        let path = self.entry_path(&key_text);
        let text = std::fs::read_to_string(&path).ok()?;
        let entry = Json::parse(&text).ok()?;
        if entry.field_f64("version") != Some(ENTRY_VERSION) {
            return None;
        }
        if entry.get("key")?.to_string() != key_text {
            return None; // hash collision or hand-edited entry: treat as cold
        }
        let cell_json = entry.get("cell")?;
        if entry.get("check")?.as_str()? != payload_check(&cell_json.to_string()) {
            return None; // bit-rotted payload: treat as cold
        }
        let cell = cell_from_json(cell_json).ok()?;
        let _ = self.write_entry(&path, text); // touch: bump mtime for LRU recency
        Some(cell)
    }

    /// Persist `cell` under `key`. Failure leaves the cache cold for this
    /// key and reports why — callers (the sweep engine) count it as a
    /// [`CacheStats::store_errors`] rather than failing the cell. The
    /// entry is written to a sibling temp file and renamed so concurrent
    /// writers — two CI steps sharing one cache directory — can never
    /// interleave a torn entry.
    ///
    /// An injected `cache.store` fault simulates the worst crash-mid-write
    /// outcome the rename path normally rules out: a *torn* (truncated)
    /// entry lands at the real path, and the store reports failure. Later
    /// loads must degrade that entry to a miss.
    pub(super) fn store(&self, key: &Json, cell: &SweepCell) -> Result<(), ReproError> {
        let key_text = key.to_string();
        let path = self.entry_path(&key_text);
        let cell_json = cell_to_json(cell);
        let mut m = BTreeMap::new();
        m.insert("check".to_string(), Json::Str(payload_check(&cell_json.to_string())));
        m.insert("cell".to_string(), cell_json);
        m.insert("key".to_string(), key.clone());
        m.insert("version".to_string(), Json::Num(ENTRY_VERSION));
        let mut text = Json::Obj(m).to_string();
        text.push('\n');
        if fault::trip(fault::Site::CacheStore, &key_text) {
            let torn = &text[..text.len() / 2];
            let _ = std::fs::write(&path, torn);
            return Err(ReproError::cache_io(format!(
                "injected fault: cache.store tore entry {}",
                path.display()
            )));
        }
        self.write_entry(&path, text)
            .map_err(|e| ReproError::cache_io(format!("cache store {}: {e}", path.display())))
    }

    /// Atomic entry write (temp sibling + rename), shared by
    /// [`CellCache::store`] and the (best-effort) touch-on-hit path in
    /// [`CellCache::load`].
    fn write_entry(&self, path: &Path, text: String) -> std::io::Result<()> {
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, text)?;
        match std::fs::rename(&tmp, path) {
            Ok(()) => Ok(()),
            Err(e) => {
                let _ = std::fs::remove_file(&tmp);
                Err(e)
            }
        }
    }

    /// Shrink the cache to at most `max_entries` entries, evicting the
    /// **least recently used** first: entries are ranked newest-mtime
    /// first (file name breaks ties deterministically) and the tail is
    /// deleted. Because [`CellCache::load`] touches every entry it
    /// serves, an entry the very next identical run would hit is by
    /// construction among the most recent and is never evicted — the
    /// invariant `gc_keeps_every_entry_the_next_run_hits` pins.
    ///
    /// Unreadable metadata ranks a file oldest (evicted first); I/O
    /// errors while deleting are ignored. Non-entry files (temp files,
    /// strays) are never counted or touched. The CLI exposes this as
    /// `repro sweep --cache-gc <max-entries>`.
    pub fn gc(&self, max_entries: usize) -> GcStats {
        let mut entries: Vec<(std::time::SystemTime, String, PathBuf)> = Vec::new();
        let Ok(dir) = std::fs::read_dir(&self.dir) else {
            return GcStats { kept: 0, evicted: 0 };
        };
        for e in dir.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if !name.ends_with(".cell.json") {
                continue;
            }
            let mtime = e
                .metadata()
                .and_then(|m| m.modified())
                .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            entries.push((mtime, name, e.path()));
        }
        // Newest first; names (content-hash derived, unique) break ties.
        entries.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
        let mut stats = GcStats { kept: entries.len().min(max_entries), evicted: 0 };
        for (_, _, path) in entries.iter().skip(max_entries) {
            let _ = std::fs::remove_file(path);
            stats.evicted += 1;
        }
        stats
    }
}

/// What [`CellCache::gc`] did: how many entries survived and how many
/// were deleted. Printed to stderr by `repro sweep --cache-gc`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GcStats {
    /// Entries retained (the `min(entries, max_entries)` newest).
    pub kept: usize,
    /// Entries deleted (oldest first beyond `max_entries`).
    pub evicted: usize,
}

impl GcStats {
    /// The one-line rendering `repro sweep --cache-gc` prints to stderr.
    pub fn summary(&self, dir: &Path) -> String {
        format!("cache gc: kept {}, evicted {} at {}", self.kept, self.evicted, dir.display())
    }
}

pub(crate) fn fnv1a64(bytes: &[u8], seed: u64) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize one evaluated cell: the design's **full** `to_json` artifact
/// (every derived figure, so the warm path never recomputes) plus the
/// sim figures, sim error, and clock curve.
fn cell_to_json(cell: &SweepCell) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "clock_curve".to_string(),
        Json::Arr(cell.clock_curve.iter().map(super::clock_point_to_json).collect()),
    );
    m.insert(
        "design".to_string(),
        Json::parse(&cell.design.to_json()).expect("Design::to_json reparses"),
    );
    m.insert(
        "sim".to_string(),
        match &cell.sim {
            None => Json::Null,
            Some(s) => {
                let mut sm = BTreeMap::new();
                sm.insert("fps".to_string(), Json::Num(s.fps));
                sm.insert("frames".to_string(), Json::Num(s.frames as f64));
                sm.insert("mac_efficiency".to_string(), Json::Num(s.mac_efficiency));
                Json::Obj(sm)
            }
        },
    );
    // Only --fifo cells carry the key: entries of non-FIFO sweeps stay
    // byte-identical to pre-FIFO caches (same bytes, same checksum).
    if let Some(fifo) = &cell.fifo {
        m.insert("fifo".to_string(), super::fifo_figures_to_json(fifo));
    }
    m.insert(
        "sim_error".to_string(),
        match &cell.sim_error {
            None => Json::Null,
            Some(e) => Json::Str(e.clone()),
        },
    );
    Json::Obj(m)
}

/// Inverse of [`cell_to_json`]. Field values land verbatim (the stable
/// serializer round-trips every f64 exactly), which is what makes warm
/// and cold cells byte-identical downstream.
fn cell_from_json(j: &Json) -> Result<SweepCell, ReproError> {
    let design = Design::from_json_unchecked(
        &j.get("design")
            .ok_or_else(|| ReproError::cache_io("cache entry: missing \"design\""))?
            .to_string(),
    )
    .map_err(|e| ReproError::cache_io(String::from(e)))?;
    let sim = match j.get("sim") {
        None | Some(Json::Null) => None,
        Some(s) => {
            let num = |key: &str| {
                s.field_f64(key)
                    .ok_or_else(|| ReproError::cache_io(format!("cache entry: missing sim/{key:?}")))
            };
            Some(SimFigures {
                frames: num("frames")? as u64,
                fps: num("fps")?,
                mac_efficiency: num("mac_efficiency")?,
            })
        }
    };
    let sim_error = match j.get("sim_error") {
        None | Some(Json::Null) => None,
        Some(Json::Str(e)) => Some(e.clone()),
        Some(other) => return Err(ReproError::cache_io(format!("cache entry: bad sim_error {other}"))),
    };
    let clock_curve = j
        .get("clock_curve")
        .and_then(Json::as_arr)
        .ok_or_else(|| ReproError::cache_io("cache entry: missing array \"clock_curve\""))?
        .iter()
        .map(|pt| {
            let num = |key: &str| {
                pt.field_f64(key)
                    .ok_or_else(|| ReproError::cache_io(format!("cache entry: missing curve {key:?}")))
            };
            Ok(ClockPoint {
                clock_hz: num("clock_hz")?,
                fps: num("fps")?,
                gops: num("gops")?,
                peak_gops: num("peak_gops")?,
            })
        })
        .collect::<Result<Vec<_>, ReproError>>()?;
    // Optional: entries stored before --fifo (or by non-FIFO sweeps)
    // simply carry no figures — never a parse failure.
    let fifo = match j.get("fifo") {
        None | Some(Json::Null) => None,
        Some(f) => Some(super::fifo_figures_from_json(f)?),
    };
    Ok(SweepCell { design, sim, sim_error, clock_curve, fifo })
}

#[cfg(test)]
mod tests {
    use super::super::SweepSpec;
    use super::*;

    fn tmp_cache(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("repro_cell_cache_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn store_then_load_round_trips_a_cell_byte_for_byte() {
        let dir = tmp_cache("roundtrip");
        let cache = CellCache::open(&dir);
        let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
        spec.clocks_hz = SweepSpec::parse_clocks_csv("100,200").unwrap();
        let report = spec.run();
        let cell = &report.cells[0];
        let key = Json::Str("probe-key".to_string());
        assert!(cache.load(&key).is_none(), "cold cache must miss");
        cache.store(&key, cell).expect("store succeeds");
        let warm = cache.load(&key).expect("stored cell loads");
        assert_eq!(warm.to_json_value().to_string(), cell.to_json_value().to_string());
        assert_eq!(warm.design().to_json(), cell.design().to_json());
        // A different key never sees the entry, whatever the hash says.
        assert!(cache.load(&Json::Str("other-key".to_string())).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_and_mismatched_entries_degrade_to_misses() {
        let dir = tmp_cache("corrupt");
        let cache = CellCache::open(&dir);
        let spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("edge"), None).unwrap();
        let cell = &spec.run().cells[0];
        let key = Json::Str("k".to_string());
        cache.store(&key, cell).expect("store succeeds");
        let path = cache.entry_path(&key.to_string());
        // Truncation: unparseable JSON is a miss, not a panic.
        std::fs::write(&path, "{\"version\":1,\"key\":\"k\",\"cell\":{").unwrap();
        assert!(cache.load(&key).is_none());
        // A well-formed entry under a *different* stored key (the on-disk
        // shape of a hash collision) is also a miss.
        cache.store(&key, cell).expect("store succeeds");
        let swapped =
            std::fs::read_to_string(&path).unwrap().replace("\"key\":\"k\"", "\"key\":\"q\"");
        std::fs::write(&path, swapped).unwrap();
        assert!(cache.load(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn entry_names(dir: &Path) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".cell.json"))
            .collect();
        names.sort();
        names
    }

    #[test]
    fn gc_keeps_every_entry_the_next_run_hits() {
        let dir = tmp_cache("gc_lru");
        let cache = CellCache::open(&dir);
        // Plant stale lookalike entries *before* the real run, so they are
        // strictly older than anything the run stores or touches.
        for i in 0..3 {
            std::fs::write(
                dir.join(format!("{:032x}.cell.json", 0xdead_beef_u64 + i)),
                "{\"version\":1,\"key\":\"stale\",\"cell\":{}}\n",
            )
            .unwrap();
        }
        let mut spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), Some("fgpm")).unwrap();
        spec.clocks_hz = SweepSpec::parse_clocks_csv("100,200").unwrap();
        spec.cache_dir = Some(dir.clone());
        let cold = spec.run();
        assert_eq!(cold.cache, Some(CacheStats { hits: 0, misses: 2, store_errors: 0 }));
        assert_eq!(entry_names(&dir).len(), 5, "2 live + 3 stale entries");
        // A warm run touches both live entries, marking them most recent.
        assert_eq!(spec.run().cache, Some(CacheStats { hits: 2, misses: 0, store_errors: 0 }));
        // GC down to exactly the working set: the 3 stale entries go, and
        // nothing the very next identical run would hit is evicted.
        let stats = cache.gc(2);
        assert_eq!(stats, GcStats { kept: 2, evicted: 3 });
        assert_eq!(stats.summary(&dir), format!("cache gc: kept 2, evicted 3 at {}", dir.display()));
        assert_eq!(entry_names(&dir).len(), 2);
        let after = spec.run();
        assert_eq!(
            after.cache,
            Some(CacheStats { hits: 2, misses: 0, store_errors: 0 }),
            "gc evicted a live cell"
        );
        assert_eq!(after.to_json(), cold.to_json(), "gc must never change sweep bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn summary_appends_store_errors_only_when_present() {
        let dir = PathBuf::from("c");
        let clean = CacheStats { hits: 3, misses: 1, store_errors: 0 };
        assert_eq!(clean.summary(&dir), "cache: 3 hits, 1 misses (75.0% hit rate) at c");
        let torn = CacheStats { hits: 4, misses: 0, store_errors: 2 };
        assert_eq!(
            torn.summary(&dir),
            "cache: 4 hits, 0 misses (100.0% hit rate, 2 store errors) at c"
        );
    }

    #[test]
    fn gc_with_headroom_evicts_nothing() {
        let dir = tmp_cache("gc_headroom");
        let cache = CellCache::open(&dir);
        let mut spec = SweepSpec::from_csv(Some("mbv1"), Some("edge"), Some("fgpm")).unwrap();
        spec.clocks_hz = SweepSpec::parse_clocks_csv("150").unwrap();
        spec.cache_dir = Some(dir.clone());
        spec.run();
        let before = entry_names(&dir);
        assert_eq!(before.len(), 1);
        assert_eq!(cache.gc(1), GcStats { kept: 1, evicted: 0 });
        assert_eq!(cache.gc(usize::MAX), GcStats { kept: 1, evicted: 0 });
        assert_eq!(entry_names(&dir), before, "gc under budget must not delete entries");
        // An empty or unreadable directory reports zeros instead of erroring.
        assert_eq!(CellCache::open(&dir.join("missing")).gc(4), GcStats { kept: 0, evicted: 0 });
        let _ = std::fs::remove_dir_all(&dir);
    }
}
