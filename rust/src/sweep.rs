//! Design-space sweep subsystem: the paper's resource-aware methodology
//! (Algorithm 1 boundary placement, Algorithm 2 parallelism tuning, Eq 14
//! prediction, optional cycle simulation) evaluated over a whole
//! {networks} x {platforms} x {granularities} matrix in one call — and
//! the analyses the paper's design-space story rests on, layered on top
//! of the raw matrix.
//!
//! A [`SweepSpec`] names the matrix axes (defaults: the full zoo, the
//! whole [`Platform::list`] catalog, FGPM granularity); [`SweepSpec::run`]
//! compiles one [`Design`] per cell and returns a [`SweepReport`] whose
//! cells carry the headline figures — FPS, MAC efficiency, SRAM bytes,
//! DSP utilization, FRCE/WRCE boundary — per (network, platform,
//! granularity) triple. Because each [`Platform`] carries its own clock,
//! the predictions are clock-aware (ZCU102 cells are evaluated at
//! 300 MHz, edge cells at 150 MHz).
//!
//! # Parallel evaluation
//!
//! Cells are independent (each is one pure `Design` build plus an
//! optional cycle simulation), so [`SweepSpec::jobs`] > 1 fans the matrix
//! out over the scoped-thread pool in [`crate::util::pool`]. Output
//! ordering is deterministic — cells always come back in nets-outer /
//! platforms / granularities-inner order regardless of which worker
//! finished first — so `--jobs N` produces **byte-identical** JSON and
//! golden-baseline artifacts to the serial path for any `N` (asserted in
//! `rust/tests/pareto.rs`).
//!
//! # Analyses
//!
//! * [`pareto`] — the per-network non-dominated set over {on-chip SRAM,
//!   predicted FPS, off-chip DRAM bytes/frame}, with dominated-by
//!   attribution: the memory-vs-throughput frontier that motivates the
//!   whole balanced-dataflow methodology (`repro sweep --pareto`).
//! * [`SweepSpec::clocks_hz`] — a clock-scaling axis: every cell also
//!   reports an FPS-vs-clock curve ([`crate::model::throughput::clock_curve`],
//!   which reuses [`crate::model::throughput::peak_gops_at`]) so one
//!   `repro sweep --clocks 100,200,300` call emits frequency-scaling
//!   curves per platform.
//!
//! # Stable renderings
//!
//! Two stable renderings back BENCH trajectories and CI:
//!
//! * [`crate::report::sweep_matrix`] — an aligned text table (plus
//!   [`crate::report::pareto_table`] / [`crate::report::clock_curves`]
//!   for the analyses);
//! * [`SweepReport::to_json`] — one sorted-key JSON line (the `repro
//!   sweep --json` output), diffable across commits;
//!
//! and [`SweepReport::save_designs`] persists every cell's full
//! [`Design::to_json`] artifact (`<net>_<platform>_<granularity>.design.json`)
//! — the same artifact format committed as golden regression baselines
//! under `rust/tests/baselines/`.
//!
//! ```no_run
//! use repro::sweep::{self, SweepSpec};
//!
//! let mut spec = SweepSpec::from_csv(
//!     Some("mobilenet_v2,shufflenet_v2"),
//!     Some("zc706,zcu102,edge"),
//!     None, // granularities: default FGPM
//! )
//! .unwrap();
//! spec.jobs = 4; // parallel cells, byte-identical output to jobs = 1
//! let report = spec.run();
//! println!("{}", repro::report::sweep_matrix(&report));
//! println!("{}", repro::report::pareto_table(&report, &sweep::pareto(&report)));
//! std::fs::write("sweep.json", report.to_json()).unwrap();
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::alloc::Granularity;
use crate::design::{granularity_name, parse_granularity, Design, Platform};
use crate::model::throughput::{self, ClockPoint};
use crate::nets::{self, Network};
use crate::sim::SimOptions;
use crate::util::json::Json;
use crate::util::pool;

/// The matrix a sweep runs over, plus per-cell simulation depth.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub nets: Vec<Network>,
    pub platforms: Vec<Platform>,
    pub granularities: Vec<Granularity>,
    /// `Some(n)` with `n > 0`: also cycle-simulate every cell for `n`
    /// frames (the sweep's actual-vs-theoretical columns). `None` or
    /// `Some(0)`: model only.
    pub frames: Option<u64>,
    /// Simulator options for the cells' designs. `None` keeps the
    /// builder default ([`SimOptions::optimized`]); ablation sweeps set
    /// e.g. [`SimOptions::baseline`], under which a cell can deadlock —
    /// recorded per cell as [`SweepCell::sim_error`].
    pub sim_options: Option<SimOptions>,
    /// Worker threads evaluating cells ([`crate::util::pool`]); the CLI's
    /// `--jobs`. `0` and `1` both mean the serial path. Any value
    /// produces byte-identical output — parallelism only changes
    /// wall-clock time.
    pub jobs: usize,
    /// Clock-scaling curve axis (the CLI's `--clocks`, in Hz here): when
    /// non-empty, every cell also carries
    /// [`SweepCell::clock_curve`] — its allocation's predicted FPS/GOPS
    /// re-evaluated at each of these clocks next to the PE array's
    /// [`crate::model::throughput::peak_gops_at`] peak. Empty: no curves
    /// (and no `clock_curve` key in the JSON, keeping pre-curve
    /// trajectories diffable).
    pub clocks_hz: Vec<f64>,
}

impl Default for SweepSpec {
    /// The full catalog sweep: every zoo network on every named platform
    /// at FGPM granularity, model only, serial, no clock curves.
    fn default() -> Self {
        SweepSpec {
            nets: nets::all_networks(),
            platforms: Platform::list(),
            granularities: vec![Granularity::Fgpm],
            frames: None,
            sim_options: None,
            jobs: 1,
            clocks_hz: Vec::new(),
        }
    }
}

fn split_csv(csv: &str) -> Vec<&str> {
    csv.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
}

/// Reject axis entries that resolve to the same canonical element
/// (`mbv2,mobilenet_v2`, `zc706,ZC706`, ...) — they would produce
/// duplicate cells and clashing artifact file names.
fn reject_duplicates(flag: &str, keys: impl IntoIterator<Item = String>) -> Result<(), String> {
    let mut seen = std::collections::BTreeSet::new();
    for k in keys {
        if !seen.insert(k.clone()) {
            return Err(format!(
                "{flag}: duplicate entry {k:?} (two names resolve to the same element)"
            ));
        }
    }
    Ok(())
}

impl SweepSpec {
    /// Build a spec from the CLI's comma-separated axis lists. `None`
    /// selects the full default axis (all zoo networks / the whole
    /// platform catalog / FGPM); `Some` must name at least one element,
    /// and unknown names fail with the list of known ones.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::sweep::SweepSpec;
    ///
    /// let spec = SweepSpec::from_csv(
    ///     Some("mobilenet_v2,shufflenet_v2"),
    ///     Some("zc706,edge"),
    ///     Some("fgpm,factorized"),
    /// )
    /// .unwrap();
    /// assert_eq!(spec.cell_count(), 8); // 2 nets x 2 platforms x 2 grans
    ///
    /// let err = SweepSpec::from_csv(None, Some("vu9p"), None).unwrap_err();
    /// assert!(err.contains("known platforms: zc706, zcu102, edge"));
    /// ```
    pub fn from_csv(
        nets_csv: Option<&str>,
        platforms_csv: Option<&str>,
        granularities_csv: Option<&str>,
    ) -> Result<SweepSpec, String> {
        let mut spec = SweepSpec::default();
        if let Some(csv) = nets_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err("--nets: empty network list".to_string());
            }
            spec.nets = names
                .iter()
                .map(|n| {
                    nets::by_name(n).ok_or_else(|| {
                        format!(
                            "unknown network {n:?} (known networks: {})",
                            nets::zoo_names().join(", ")
                        )
                    })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(csv) = platforms_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err("--platforms: empty platform list".to_string());
            }
            spec.platforms = names.iter().map(|n| Platform::resolve(n)).collect::<Result<_, _>>()?;
        }
        if let Some(csv) = granularities_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err("--granularities: empty granularity list".to_string());
            }
            spec.granularities =
                names.iter().map(|g| parse_granularity(g)).collect::<Result<_, _>>()?;
        }
        reject_duplicates("--nets", spec.nets.iter().map(|n| n.name.clone()))?;
        reject_duplicates("--platforms", spec.platforms.iter().map(|p| p.name.clone()))?;
        reject_duplicates(
            "--granularities",
            spec.granularities.iter().map(|g| granularity_name(*g).to_string()),
        )?;
        Ok(spec)
    }

    /// Parse the CLI's `--clocks` value — a comma-separated list of MHz
    /// points — into the Hz values [`SweepSpec::clocks_hz`] stores.
    /// Points must be positive finite numbers; duplicates are rejected
    /// (they would produce duplicate curve points); order is preserved.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::sweep::SweepSpec;
    ///
    /// assert_eq!(
    ///     SweepSpec::parse_clocks_csv("100, 200,300").unwrap(),
    ///     vec![100.0e6, 200.0e6, 300.0e6]
    /// );
    /// assert!(SweepSpec::parse_clocks_csv("0,200").is_err());
    /// assert!(SweepSpec::parse_clocks_csv("200,200").is_err());
    /// ```
    pub fn parse_clocks_csv(csv: &str) -> Result<Vec<f64>, String> {
        let points = split_csv(csv);
        if points.is_empty() {
            return Err("--clocks: empty clock list".to_string());
        }
        let mut hz = Vec::with_capacity(points.len());
        for p in points {
            let mhz: f64 =
                p.parse().map_err(|_| format!("--clocks: cannot parse MHz value {p:?}"))?;
            if !mhz.is_finite() || mhz <= 0.0 {
                return Err(format!("--clocks: MHz points must be positive, got {p:?}"));
            }
            let v = mhz * 1.0e6;
            if hz.contains(&v) {
                return Err(format!("--clocks: duplicate entry {p:?}"));
            }
            hz.push(v);
        }
        Ok(hz)
    }

    /// Number of cells the matrix will produce.
    pub fn cell_count(&self) -> usize {
        self.nets.len() * self.platforms.len() * self.granularities.len()
    }

    /// Run the full pipeline for every cell. Cells are evaluated on
    /// [`SweepSpec::jobs`] worker threads (serial when `jobs <= 1`), but
    /// the report's cell order is always the deterministic nets-outer /
    /// platforms / granularities-inner order — the output is
    /// byte-identical for any job count.
    pub fn run(&self) -> SweepReport {
        let frames_req = self.frames.filter(|&f| f > 0);
        let mut combos = Vec::with_capacity(self.cell_count());
        for net in &self.nets {
            for platform in &self.platforms {
                for &granularity in &self.granularities {
                    combos.push((net, platform, granularity));
                }
            }
        }
        let cells = pool::parallel_map(self.jobs, &combos, |_, &(net, platform, granularity)| {
            self.eval_cell(net, platform, granularity, frames_req)
        });
        SweepReport { cells }
    }

    /// Evaluate one matrix cell: build the [`Design`], optionally
    /// cycle-simulate it, and attach the clock-scaling curve. Pure —
    /// shares nothing mutable, so the pool may run any number of these
    /// concurrently.
    fn eval_cell(
        &self,
        net: &Network,
        platform: &Platform,
        granularity: Granularity,
        frames_req: Option<u64>,
    ) -> SweepCell {
        let mut builder = Design::builder(net).platform(platform.clone()).granularity(granularity);
        if let Some(opts) = self.sim_options {
            builder = builder.sim_options(opts);
        }
        let design = builder.build();
        // A deadlocked simulation (possible only under non-default
        // `sim_options`) is recorded as an explicit per-cell error,
        // distinguishable from a model-only sweep, rather than poisoning
        // the run.
        let (sim, sim_error) = match frames_req {
            None => (None, None),
            Some(frames) => match design.simulate(frames) {
                Ok(st) => (
                    Some(SimFigures {
                        frames,
                        fps: st.fps(platform.clock_hz),
                        mac_efficiency: st.mac_efficiency(),
                    }),
                    None,
                ),
                Err(e) => (None, Some(e.to_string())),
            },
        };
        let clock_curve =
            throughput::clock_curve(design.network(), design.allocs(), &self.clocks_hz);
        SweepCell { design, sim, sim_error, clock_curve }
    }
}

/// Cycle-simulation figures of one cell (present only when the spec set
/// [`SweepSpec::frames`] and the simulation completed).
#[derive(Debug, Clone, Copy)]
pub struct SimFigures {
    pub frames: u64,
    /// Simulated FPS at the cell platform's clock.
    pub fps: f64,
    /// Actual (simulated) MAC efficiency.
    pub mac_efficiency: f64,
}

/// One (network, platform, granularity) cell: the compiled [`Design`]
/// plus optional simulation figures.
#[derive(Debug, Clone)]
pub struct SweepCell {
    design: Design,
    sim: Option<SimFigures>,
    /// Why the requested simulation produced no figures (deadlock text);
    /// `None` both when the cell simulated fine and when the sweep was
    /// model-only — [`SweepCell::sim`] disambiguates.
    sim_error: Option<String>,
    /// FPS-vs-clock points at the spec's [`SweepSpec::clocks_hz`] axis
    /// (empty when no `--clocks` axis was requested).
    clock_curve: Vec<ClockPoint>,
}

/// File-name-safe lowercase slug of a platform/network name.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect()
}

impl SweepCell {
    pub fn design(&self) -> &Design {
        &self.design
    }

    pub fn sim(&self) -> Option<&SimFigures> {
        self.sim.as_ref()
    }

    /// The error that prevented a requested simulation (deadlock), if any.
    pub fn sim_error(&self) -> Option<&str> {
        self.sim_error.as_deref()
    }

    /// The cell's FPS-vs-clock scaling curve, one point per entry of the
    /// spec's [`SweepSpec::clocks_hz`] axis (empty when the sweep ran
    /// without a `--clocks` axis).
    pub fn clock_curve(&self) -> &[ClockPoint] {
        &self.clock_curve
    }

    pub fn network_name(&self) -> &str {
        &self.design.network().name
    }

    pub fn platform(&self) -> &Platform {
        self.design.platform()
    }

    /// DSP slices used over the part's total (Table II's utilization).
    pub fn dsp_utilization(&self) -> f64 {
        self.design.parallelism().dsps as f64 / self.platform().dsp_total as f64
    }

    /// Recosted SRAM bytes over the platform budget. Exceeds 1.0 when
    /// even the minimum-SRAM configuration does not fit the part (the
    /// edge-class regime).
    pub fn sram_utilization(&self) -> f64 {
        self.design.sram_bytes() as f64 / self.platform().sram_bytes as f64
    }

    /// Whether the recosted SRAM footprint fits the platform budget.
    pub fn fits_sram(&self) -> bool {
        self.design.sram_bytes() <= self.platform().sram_bytes
    }

    /// File name [`SweepReport::save_designs`] writes this cell's design
    /// artifact under: `<net>_<platform>_<granularity>.design.json`, with
    /// the network's AOT short name when it is a zoo network.
    pub fn artifact_file_name(&self) -> String {
        let net = nets::short_name(self.network_name())
            .map(str::to_string)
            .unwrap_or_else(|| sanitize(self.network_name()));
        format!(
            "{net}_{}_{}.design.json",
            sanitize(&self.platform().name),
            granularity_name(self.design.granularity())
        )
    }

    /// The cell's headline figures as a stable sorted-key JSON value —
    /// one element of the `repro sweep --json` document.
    pub fn to_json_value(&self) -> Json {
        let d = &self.design;
        let p = d.predicted();
        let mut m = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        put("boundary", Json::Num(d.ce_plan().boundary as f64));
        put("boundary_min_sram", Json::Num(d.memory().boundary_min_sram as f64));
        // Only curve-bearing sweeps carry the key, so curve-less JSON
        // stays byte-identical to pre-curve BENCH trajectories.
        if !self.clock_curve.is_empty() {
            let pts = self
                .clock_curve
                .iter()
                .map(|pt| {
                    let mut p = BTreeMap::new();
                    p.insert("clock_hz".to_string(), Json::Num(pt.clock_hz));
                    p.insert("fps".to_string(), Json::Num(pt.fps));
                    p.insert("gops".to_string(), Json::Num(pt.gops));
                    p.insert("peak_gops".to_string(), Json::Num(pt.peak_gops));
                    Json::Obj(p)
                })
                .collect();
            put("clock_curve", Json::Arr(pts));
        }
        put("clock_hz", Json::Num(d.platform().clock_hz));
        put("dram_bytes", Json::Num(d.dram_bytes() as f64));
        put("dsp_utilization", Json::Num(self.dsp_utilization()));
        put("dsps", Json::Num(d.parallelism().dsps as f64));
        put("fits_sram", Json::Bool(self.fits_sram()));
        put("fps", Json::Num(p.fps));
        put("gops", Json::Num(p.gops));
        put("granularity", Json::Str(granularity_name(d.granularity()).to_string()));
        put("layers", Json::Num(d.network().layers.len() as f64));
        put("mac_efficiency", Json::Num(p.mac_efficiency));
        put("network", Json::Str(d.network().name.clone()));
        put("pes", Json::Num(d.parallelism().pes as f64));
        put("platform", Json::Str(d.platform().name.clone()));
        match &self.sim {
            Some(s) => {
                put("sim_fps", Json::Num(s.fps));
                put("sim_frames", Json::Num(s.frames as f64));
                put("sim_mac_efficiency", Json::Num(s.mac_efficiency));
            }
            None => {
                put("sim_fps", Json::Null);
                put("sim_frames", Json::Null);
                put("sim_mac_efficiency", Json::Null);
            }
        }
        put(
            "sim_error",
            match &self.sim_error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        );
        put("sram_bytes", Json::Num(d.sram_bytes() as f64));
        put("sram_utilization", Json::Num(self.sram_utilization()));
        put("t_max", Json::Num(p.t_max as f64));
        Json::Obj(m)
    }
}

/// The result of a sweep: one [`SweepCell`] per matrix combination, in
/// the spec's deterministic iteration order.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub cells: Vec<SweepCell>,
}

impl SweepReport {
    /// The whole report as one stable sorted-key JSON line — the
    /// `repro sweep --json` output recorded in BENCH trajectories.
    ///
    /// Byte-identical for any [`SweepSpec::jobs`] value: parallelism
    /// changes wall-clock time, never content or ordering.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::sweep::SweepSpec;
    ///
    /// let spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
    /// let json = spec.run().to_json();
    /// assert!(!json.contains('\n')); // one line, stable sorted keys
    /// let parsed = repro::util::json::Json::parse(&json).unwrap();
    /// assert_eq!(parsed.arr_field("cells").len(), 1);
    /// ```
    pub fn to_json(&self) -> String {
        self.to_json_with(None)
    }

    /// [`SweepReport::to_json`] with an optional embedded Pareto analysis
    /// (the `repro sweep --pareto --json` output): when given, the
    /// document gains a top-level `"pareto"` key holding
    /// [`ParetoReport::to_json_value`].
    pub fn to_json_with(&self, pareto: Option<&ParetoReport>) -> String {
        let mut m = BTreeMap::new();
        m.insert(
            "cells".to_string(),
            Json::Arr(self.cells.iter().map(SweepCell::to_json_value).collect()),
        );
        if let Some(p) = pareto {
            m.insert("pareto".to_string(), p.to_json_value());
        }
        m.insert("version".to_string(), Json::Num(1.0));
        Json::Obj(m).to_string()
    }

    /// Convenience for [`pareto`] (the free function) on this report.
    pub fn pareto(&self) -> ParetoReport {
        pareto(self)
    }

    /// Persist every cell's full [`Design::to_json`] artifact into `dir`
    /// (created if missing), returning the paths written in cell order.
    pub fn save_designs(&self, dir: &Path) -> Result<Vec<PathBuf>, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        let mut paths = Vec::with_capacity(self.cells.len());
        for cell in &self.cells {
            let path = dir.join(cell.artifact_file_name());
            let mut text = cell.design.to_json();
            text.push('\n');
            std::fs::write(&path, text).map_err(|e| format!("{}: {e}", path.display()))?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// The cell for a (network, platform, granularity) triple, if swept.
    pub fn cell(&self, net: &str, platform: &str, granularity: Granularity) -> Option<&SweepCell> {
        self.cells.iter().find(|c| {
            c.network_name() == net
                && c.platform().name == platform
                && c.design.granularity() == granularity
        })
    }
}

/// The three objectives the Pareto analysis trades off for one cell:
/// minimize on-chip SRAM, maximize predicted FPS, minimize off-chip DRAM
/// traffic per frame — the axes Petrica et al. and the memory-wall line
/// of work argue must sit on one frontier for streaming dataflow
/// accelerators.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    /// On-chip SRAM bytes (minimize) — [`Design::sram_bytes`].
    pub sram_bytes: u64,
    /// Predicted FPS at the cell platform's clock (maximize) — Eq 14.
    pub fps: f64,
    /// Off-chip DRAM bytes per frame (minimize) — Eq 13.
    pub dram_bytes: u64,
}

impl Objectives {
    /// The objective vector of one sweep cell.
    pub fn of(cell: &SweepCell) -> Objectives {
        Objectives {
            sram_bytes: cell.design().sram_bytes(),
            fps: cell.design().predicted().fps,
            dram_bytes: cell.design().dram_bytes(),
        }
    }

    /// Pareto dominance: `self` dominates `other` when it is no worse on
    /// every objective (≤ SRAM, ≥ FPS, ≤ DRAM) and strictly better on at
    /// least one. Exact ties on all three dominate in neither direction —
    /// both cells land on the frontier.
    pub fn dominates(&self, other: &Objectives) -> bool {
        let no_worse = self.sram_bytes <= other.sram_bytes
            && self.fps >= other.fps
            && self.dram_bytes <= other.dram_bytes;
        let strictly_better = self.sram_bytes < other.sram_bytes
            || self.fps > other.fps
            || self.dram_bytes < other.dram_bytes;
        no_worse && strictly_better
    }
}

/// The non-dominated set of one network's cells, with dominated-by
/// attribution for everything off the frontier.
#[derive(Debug, Clone)]
pub struct ParetoFront {
    /// The network this frontier belongs to.
    pub network: String,
    /// Indices (into [`SweepReport::cells`]) of the non-dominated cells,
    /// in cell order.
    pub frontier: Vec<usize>,
    /// `(dominated cell index, dominating frontier cell index)` for every
    /// cell off the frontier: the attribution names the first frontier
    /// cell (lowest index) that dominates it, in cell order.
    pub dominated: Vec<(usize, usize)>,
}

/// Every per-network frontier of one sweep, in the report's network
/// order.
#[derive(Debug, Clone)]
pub struct ParetoReport {
    pub fronts: Vec<ParetoFront>,
}

impl ParetoReport {
    /// Stable sorted-key JSON value of the analysis — the `"pareto"`
    /// entry of `repro sweep --pareto --json`. Frontier cells and
    /// dominated-by attributions reference cells by index into the same
    /// document's `"cells"` array, with (platform, granularity) labels
    /// repeated for readability.
    pub fn to_json_value(&self) -> Json {
        let fronts = self
            .fronts
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert(
                    "dominated".to_string(),
                    Json::Arr(
                        f.dominated
                            .iter()
                            .map(|&(cell, by)| {
                                let mut d = BTreeMap::new();
                                d.insert("by".to_string(), Json::Num(by as f64));
                                d.insert("cell".to_string(), Json::Num(cell as f64));
                                Json::Obj(d)
                            })
                            .collect(),
                    ),
                );
                m.insert(
                    "frontier".to_string(),
                    Json::Arr(f.frontier.iter().map(|&i| Json::Num(i as f64)).collect()),
                );
                m.insert("network".to_string(), Json::Str(f.network.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("fronts".to_string(), Json::Arr(fronts));
        Json::Obj(m)
    }
}

/// Extract the per-network Pareto frontier of a sweep over {on-chip SRAM,
/// predicted FPS, off-chip DRAM bytes/frame} (see [`Objectives`]).
///
/// Cells are grouped by network (frontiers across different networks
/// would compare apples to oranges — a ShuffleNet cell always "beats" a
/// MobileNet cell on work done per frame) and each group's non-dominated
/// set is computed exactly, with dominated-by attribution pointing every
/// off-frontier cell at the first frontier cell that dominates it. Output
/// is deterministic: networks in first-appearance order, indices in cell
/// order.
///
/// An empty report yields an empty analysis; a single-cell group is its
/// own frontier; exact-tie cells (identical objective vectors) dominate
/// in neither direction and both stay on the frontier.
///
/// # Examples
///
/// ```
/// use repro::sweep::{pareto, SweepSpec};
///
/// let spec = SweepSpec::from_csv(
///     Some("shufflenet_v2"),
///     Some("zc706,zcu102,edge"),
///     None,
/// )
/// .unwrap();
/// let report = spec.run();
/// let analysis = pareto(&report);
/// assert_eq!(analysis.fronts.len(), 1); // one frontier per network
/// let front = &analysis.fronts[0];
/// // Every cell is either on the frontier or attributed to a dominator.
/// assert_eq!(front.frontier.len() + front.dominated.len(), report.cells.len());
/// ```
pub fn pareto(report: &SweepReport) -> ParetoReport {
    // Group cell indices by network, preserving first-appearance order.
    let mut order: Vec<&str> = Vec::new();
    let mut groups: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, cell) in report.cells.iter().enumerate() {
        let name = cell.network_name();
        let group = groups.entry(name).or_default();
        if group.is_empty() {
            order.push(name);
        }
        group.push(i);
    }
    let fronts = order
        .into_iter()
        .map(|name| {
            let idxs = &groups[name];
            let objs: Vec<Objectives> =
                idxs.iter().map(|&i| Objectives::of(&report.cells[i])).collect();
            // Frontier as (local, global) index pairs so attribution can
            // compare objectives without re-searching `idxs` per probe.
            let front_pairs: Vec<(usize, usize)> = idxs
                .iter()
                .enumerate()
                .filter(|&(a, _)| !objs.iter().any(|ob| ob.dominates(&objs[a])))
                .map(|(a, &cell_a)| (a, cell_a))
                .collect();
            let mut dominated = Vec::new();
            for (a, &cell_a) in idxs.iter().enumerate() {
                if front_pairs.iter().any(|&(b, _)| b == a) {
                    continue;
                }
                // A dominated cell always has a *frontier* dominator:
                // dominance is transitive and irreflexive, so a maximal
                // element above it exists and is itself non-dominated.
                let (_, by) = front_pairs
                    .iter()
                    .copied()
                    .find(|&(b, _)| objs[b].dominates(&objs[a]))
                    .expect("dominated cell must have a frontier dominator");
                dominated.push((cell_a, by));
            }
            let frontier = front_pairs.into_iter().map(|(_, cell)| cell).collect();
            ParetoFront { network: name.to_string(), frontier, dominated }
        })
        .collect();
    ParetoReport { fronts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_covers_the_whole_catalog_matrix() {
        let spec = SweepSpec::default();
        assert_eq!(spec.nets.len(), 4);
        assert_eq!(spec.platforms.len(), 3);
        assert_eq!(spec.granularities, vec![Granularity::Fgpm]);
        assert_eq!(spec.cell_count(), 12);
        assert!(spec.frames.is_none());
        assert_eq!(spec.jobs, 1, "default is the serial path");
        assert!(spec.clocks_hz.is_empty(), "no clock curves unless asked");
    }

    #[test]
    fn clock_curve_cells_report_points_at_each_requested_clock() {
        let mut spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), Some("fgpm")).unwrap();
        spec.clocks_hz = SweepSpec::parse_clocks_csv("100,200").unwrap();
        let report = spec.run();
        let cell = &report.cells[0];
        let curve = cell.clock_curve();
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[0].clock_hz, 100.0e6);
        assert_eq!(curve[1].clock_hz, 200.0e6);
        // The 200 MHz curve point is the cell's own prediction (zc706
        // runs at 200 MHz), and rates scale linearly along the curve.
        assert_eq!(curve[1].fps, cell.design().predicted().fps);
        assert!((curve[1].fps / curve[0].fps - 2.0).abs() < 1e-9);
        // Curves appear in the JSON only when requested.
        assert!(report.to_json().contains("\"clock_curve\""));
        let plain = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), Some("fgpm"))
            .unwrap()
            .run();
        assert!(!plain.to_json().contains("\"clock_curve\""));
    }

    #[test]
    fn single_cell_sweep_matches_direct_design_build() {
        let spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zcu102"), Some("fgpm")).unwrap();
        let report = spec.run();
        assert_eq!(report.cells.len(), 1);
        let cell = report.cell("shufflenet_v2", "zcu102", Granularity::Fgpm).unwrap();
        let direct = Design::builder(&nets::shufflenet_v2()).platform(Platform::zcu102()).build();
        assert_eq!(cell.design().to_json(), direct.to_json());
        assert_eq!(cell.artifact_file_name(), "snv2_zcu102_fgpm.design.json");
        assert!(cell.dsp_utilization() > 0.0 && cell.dsp_utilization() <= 1.0);
    }

    #[test]
    fn csv_axes_trim_whitespace_and_keep_order() {
        let spec = SweepSpec::from_csv(
            Some(" shufflenet_v2 , mobilenet_v2"),
            Some("edge, zc706"),
            Some("factorized , fgpm"),
        )
        .unwrap();
        assert_eq!(spec.nets[0].name, "shufflenet_v2");
        assert_eq!(spec.nets[1].name, "mobilenet_v2");
        assert_eq!(spec.platforms[0].name, "edge");
        assert_eq!(spec.platforms[1].name, "zc706");
        assert_eq!(spec.granularities, vec![Granularity::Factorized, Granularity::Fgpm]);
    }
}
