//! Design-space sweep subsystem: the paper's resource-aware methodology
//! (Algorithm 1 boundary placement, Algorithm 2 parallelism tuning, Eq 14
//! prediction, optional cycle simulation) evaluated over a whole
//! {networks} x {platforms} x {granularities} matrix in one call.
//!
//! A [`SweepSpec`] names the matrix axes (defaults: the full zoo, the
//! whole [`Platform::list`] catalog, FGPM granularity); [`SweepSpec::run`]
//! compiles one [`Design`] per cell and returns a [`SweepReport`] whose
//! cells carry the headline figures — FPS, MAC efficiency, SRAM bytes,
//! DSP utilization, FRCE/WRCE boundary — per (network, platform,
//! granularity) triple. Because each [`Platform`] carries its own clock,
//! the predictions are clock-aware (ZCU102 cells are evaluated at
//! 300 MHz, edge cells at 150 MHz).
//!
//! Two stable renderings back BENCH trajectories and CI:
//!
//! * [`crate::report::sweep_matrix`] — an aligned text table;
//! * [`SweepReport::to_json`] — one sorted-key JSON line (the `repro
//!   sweep --json` output), diffable across commits;
//!
//! and [`SweepReport::save_designs`] persists every cell's full
//! [`Design::to_json`] artifact (`<net>_<platform>_<granularity>.design.json`)
//! — the same artifact format committed as golden regression baselines
//! under `rust/tests/baselines/`.
//!
//! ```no_run
//! use repro::sweep::SweepSpec;
//!
//! let spec = SweepSpec::from_csv(
//!     Some("mobilenet_v2,shufflenet_v2"),
//!     Some("zc706,zcu102,edge"),
//!     None, // granularities: default FGPM
//! )
//! .unwrap();
//! let report = spec.run();
//! println!("{}", repro::report::sweep_matrix(&report));
//! std::fs::write("sweep.json", report.to_json()).unwrap();
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::alloc::Granularity;
use crate::design::{granularity_name, parse_granularity, Design, Platform};
use crate::nets::{self, Network};
use crate::sim::SimOptions;
use crate::util::json::Json;

/// The matrix a sweep runs over, plus per-cell simulation depth.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub nets: Vec<Network>,
    pub platforms: Vec<Platform>,
    pub granularities: Vec<Granularity>,
    /// `Some(n)` with `n > 0`: also cycle-simulate every cell for `n`
    /// frames (the sweep's actual-vs-theoretical columns). `None` or
    /// `Some(0)`: model only.
    pub frames: Option<u64>,
    /// Simulator options for the cells' designs. `None` keeps the
    /// builder default ([`SimOptions::optimized`]); ablation sweeps set
    /// e.g. [`SimOptions::baseline`], under which a cell can deadlock —
    /// recorded per cell as [`SweepCell::sim_error`].
    pub sim_options: Option<SimOptions>,
}

impl Default for SweepSpec {
    /// The full catalog sweep: every zoo network on every named platform
    /// at FGPM granularity, model only.
    fn default() -> Self {
        SweepSpec {
            nets: nets::all_networks(),
            platforms: Platform::list(),
            granularities: vec![Granularity::Fgpm],
            frames: None,
            sim_options: None,
        }
    }
}

fn split_csv(csv: &str) -> Vec<&str> {
    csv.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
}

/// Reject axis entries that resolve to the same canonical element
/// (`mbv2,mobilenet_v2`, `zc706,ZC706`, ...) — they would produce
/// duplicate cells and clashing artifact file names.
fn reject_duplicates(flag: &str, keys: impl IntoIterator<Item = String>) -> Result<(), String> {
    let mut seen = std::collections::BTreeSet::new();
    for k in keys {
        if !seen.insert(k.clone()) {
            return Err(format!(
                "{flag}: duplicate entry {k:?} (two names resolve to the same element)"
            ));
        }
    }
    Ok(())
}

impl SweepSpec {
    /// Build a spec from the CLI's comma-separated axis lists. `None`
    /// selects the full default axis (all zoo networks / the whole
    /// platform catalog / FGPM); `Some` must name at least one element,
    /// and unknown names fail with the list of known ones.
    pub fn from_csv(
        nets_csv: Option<&str>,
        platforms_csv: Option<&str>,
        granularities_csv: Option<&str>,
    ) -> Result<SweepSpec, String> {
        let mut spec = SweepSpec::default();
        if let Some(csv) = nets_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err("--nets: empty network list".to_string());
            }
            spec.nets = names
                .iter()
                .map(|n| {
                    nets::by_name(n).ok_or_else(|| {
                        format!(
                            "unknown network {n:?} (known networks: {})",
                            nets::zoo_names().join(", ")
                        )
                    })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(csv) = platforms_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err("--platforms: empty platform list".to_string());
            }
            spec.platforms = names.iter().map(|n| Platform::resolve(n)).collect::<Result<_, _>>()?;
        }
        if let Some(csv) = granularities_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err("--granularities: empty granularity list".to_string());
            }
            spec.granularities =
                names.iter().map(|g| parse_granularity(g)).collect::<Result<_, _>>()?;
        }
        reject_duplicates("--nets", spec.nets.iter().map(|n| n.name.clone()))?;
        reject_duplicates("--platforms", spec.platforms.iter().map(|p| p.name.clone()))?;
        reject_duplicates(
            "--granularities",
            spec.granularities.iter().map(|g| granularity_name(*g).to_string()),
        )?;
        Ok(spec)
    }

    /// Number of cells the matrix will produce.
    pub fn cell_count(&self) -> usize {
        self.nets.len() * self.platforms.len() * self.granularities.len()
    }

    /// Run the full pipeline for every cell, in deterministic
    /// nets-outer / platforms / granularities-inner order.
    pub fn run(&self) -> SweepReport {
        let frames_req = self.frames.filter(|&f| f > 0);
        let mut cells = Vec::with_capacity(self.cell_count());
        for net in &self.nets {
            for platform in &self.platforms {
                for &granularity in &self.granularities {
                    let mut builder = Design::builder(net)
                        .platform(platform.clone())
                        .granularity(granularity);
                    if let Some(opts) = self.sim_options {
                        builder = builder.sim_options(opts);
                    }
                    let design = builder.build();
                    // A deadlocked simulation (possible only under
                    // non-default `sim_options`) is recorded as an
                    // explicit per-cell error, distinguishable from a
                    // model-only sweep, rather than poisoning the run.
                    let (sim, sim_error) = match frames_req {
                        None => (None, None),
                        Some(frames) => match design.simulate(frames) {
                            Ok(st) => (
                                Some(SimFigures {
                                    frames,
                                    fps: st.fps(platform.clock_hz),
                                    mac_efficiency: st.mac_efficiency(),
                                }),
                                None,
                            ),
                            Err(e) => (None, Some(e.to_string())),
                        },
                    };
                    cells.push(SweepCell { design, sim, sim_error });
                }
            }
        }
        SweepReport { cells }
    }
}

/// Cycle-simulation figures of one cell (present only when the spec set
/// [`SweepSpec::frames`] and the simulation completed).
#[derive(Debug, Clone, Copy)]
pub struct SimFigures {
    pub frames: u64,
    /// Simulated FPS at the cell platform's clock.
    pub fps: f64,
    /// Actual (simulated) MAC efficiency.
    pub mac_efficiency: f64,
}

/// One (network, platform, granularity) cell: the compiled [`Design`]
/// plus optional simulation figures.
#[derive(Debug, Clone)]
pub struct SweepCell {
    design: Design,
    sim: Option<SimFigures>,
    /// Why the requested simulation produced no figures (deadlock text);
    /// `None` both when the cell simulated fine and when the sweep was
    /// model-only — [`SweepCell::sim`] disambiguates.
    sim_error: Option<String>,
}

/// File-name-safe lowercase slug of a platform/network name.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect()
}

impl SweepCell {
    pub fn design(&self) -> &Design {
        &self.design
    }

    pub fn sim(&self) -> Option<&SimFigures> {
        self.sim.as_ref()
    }

    /// The error that prevented a requested simulation (deadlock), if any.
    pub fn sim_error(&self) -> Option<&str> {
        self.sim_error.as_deref()
    }

    pub fn network_name(&self) -> &str {
        &self.design.network().name
    }

    pub fn platform(&self) -> &Platform {
        self.design.platform()
    }

    /// DSP slices used over the part's total (Table II's utilization).
    pub fn dsp_utilization(&self) -> f64 {
        self.design.parallelism().dsps as f64 / self.platform().dsp_total as f64
    }

    /// Recosted SRAM bytes over the platform budget. Exceeds 1.0 when
    /// even the minimum-SRAM configuration does not fit the part (the
    /// edge-class regime).
    pub fn sram_utilization(&self) -> f64 {
        self.design.sram_bytes() as f64 / self.platform().sram_bytes as f64
    }

    /// Whether the recosted SRAM footprint fits the platform budget.
    pub fn fits_sram(&self) -> bool {
        self.design.sram_bytes() <= self.platform().sram_bytes
    }

    /// File name [`SweepReport::save_designs`] writes this cell's design
    /// artifact under: `<net>_<platform>_<granularity>.design.json`, with
    /// the network's AOT short name when it is a zoo network.
    pub fn artifact_file_name(&self) -> String {
        let net = nets::short_name(self.network_name())
            .map(str::to_string)
            .unwrap_or_else(|| sanitize(self.network_name()));
        format!(
            "{net}_{}_{}.design.json",
            sanitize(&self.platform().name),
            granularity_name(self.design.granularity())
        )
    }

    /// The cell's headline figures as a stable sorted-key JSON value —
    /// one element of the `repro sweep --json` document.
    pub fn to_json_value(&self) -> Json {
        let d = &self.design;
        let p = d.predicted();
        let mut m = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        put("boundary", Json::Num(d.ce_plan().boundary as f64));
        put("boundary_min_sram", Json::Num(d.memory().boundary_min_sram as f64));
        put("clock_hz", Json::Num(d.platform().clock_hz));
        put("dram_bytes", Json::Num(d.dram_bytes() as f64));
        put("dsp_utilization", Json::Num(self.dsp_utilization()));
        put("dsps", Json::Num(d.parallelism().dsps as f64));
        put("fits_sram", Json::Bool(self.fits_sram()));
        put("fps", Json::Num(p.fps));
        put("gops", Json::Num(p.gops));
        put("granularity", Json::Str(granularity_name(d.granularity()).to_string()));
        put("layers", Json::Num(d.network().layers.len() as f64));
        put("mac_efficiency", Json::Num(p.mac_efficiency));
        put("network", Json::Str(d.network().name.clone()));
        put("pes", Json::Num(d.parallelism().pes as f64));
        put("platform", Json::Str(d.platform().name.clone()));
        match &self.sim {
            Some(s) => {
                put("sim_fps", Json::Num(s.fps));
                put("sim_frames", Json::Num(s.frames as f64));
                put("sim_mac_efficiency", Json::Num(s.mac_efficiency));
            }
            None => {
                put("sim_fps", Json::Null);
                put("sim_frames", Json::Null);
                put("sim_mac_efficiency", Json::Null);
            }
        }
        put(
            "sim_error",
            match &self.sim_error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        );
        put("sram_bytes", Json::Num(d.sram_bytes() as f64));
        put("sram_utilization", Json::Num(self.sram_utilization()));
        put("t_max", Json::Num(p.t_max as f64));
        Json::Obj(m)
    }
}

/// The result of a sweep: one [`SweepCell`] per matrix combination, in
/// the spec's deterministic iteration order.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub cells: Vec<SweepCell>,
}

impl SweepReport {
    /// The whole report as one stable sorted-key JSON line — the
    /// `repro sweep --json` output recorded in BENCH trajectories.
    pub fn to_json(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert(
            "cells".to_string(),
            Json::Arr(self.cells.iter().map(SweepCell::to_json_value).collect()),
        );
        m.insert("version".to_string(), Json::Num(1.0));
        Json::Obj(m).to_string()
    }

    /// Persist every cell's full [`Design::to_json`] artifact into `dir`
    /// (created if missing), returning the paths written in cell order.
    pub fn save_designs(&self, dir: &Path) -> Result<Vec<PathBuf>, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        let mut paths = Vec::with_capacity(self.cells.len());
        for cell in &self.cells {
            let path = dir.join(cell.artifact_file_name());
            let mut text = cell.design.to_json();
            text.push('\n');
            std::fs::write(&path, text).map_err(|e| format!("{}: {e}", path.display()))?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// The cell for a (network, platform, granularity) triple, if swept.
    pub fn cell(&self, net: &str, platform: &str, granularity: Granularity) -> Option<&SweepCell> {
        self.cells.iter().find(|c| {
            c.network_name() == net
                && c.platform().name == platform
                && c.design.granularity() == granularity
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_covers_the_whole_catalog_matrix() {
        let spec = SweepSpec::default();
        assert_eq!(spec.nets.len(), 4);
        assert_eq!(spec.platforms.len(), 3);
        assert_eq!(spec.granularities, vec![Granularity::Fgpm]);
        assert_eq!(spec.cell_count(), 12);
        assert!(spec.frames.is_none());
    }

    #[test]
    fn single_cell_sweep_matches_direct_design_build() {
        let spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zcu102"), Some("fgpm")).unwrap();
        let report = spec.run();
        assert_eq!(report.cells.len(), 1);
        let cell = report.cell("shufflenet_v2", "zcu102", Granularity::Fgpm).unwrap();
        let direct = Design::builder(&nets::shufflenet_v2()).platform(Platform::zcu102()).build();
        assert_eq!(cell.design().to_json(), direct.to_json());
        assert_eq!(cell.artifact_file_name(), "snv2_zcu102_fgpm.design.json");
        assert!(cell.dsp_utilization() > 0.0 && cell.dsp_utilization() <= 1.0);
    }

    #[test]
    fn csv_axes_trim_whitespace_and_keep_order() {
        let spec = SweepSpec::from_csv(
            Some(" shufflenet_v2 , mobilenet_v2"),
            Some("edge, zc706"),
            Some("factorized , fgpm"),
        )
        .unwrap();
        assert_eq!(spec.nets[0].name, "shufflenet_v2");
        assert_eq!(spec.nets[1].name, "mobilenet_v2");
        assert_eq!(spec.platforms[0].name, "edge");
        assert_eq!(spec.platforms[1].name, "zc706");
        assert_eq!(spec.granularities, vec![Granularity::Factorized, Granularity::Fgpm]);
    }
}
