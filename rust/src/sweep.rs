//! Design-space sweep subsystem: the paper's resource-aware methodology
//! (Algorithm 1 boundary placement, Algorithm 2 parallelism tuning, Eq 14
//! prediction, optional cycle simulation) evaluated over a whole
//! {networks} x {platforms} x {granularities} matrix in one call — and
//! the analyses the paper's design-space story rests on, layered on top
//! of the raw matrix.
//!
//! A [`SweepSpec`] names the matrix axes (defaults: the full zoo, the
//! whole [`Platform::list`] catalog, FGPM granularity); [`SweepSpec::run`]
//! compiles one [`Design`] per cell and returns a [`SweepReport`] whose
//! cells carry the headline figures — FPS, MAC efficiency, SRAM bytes,
//! DSP utilization, FRCE/WRCE boundary — per (network, platform,
//! granularity) triple. Because each [`Platform`] carries its own clock,
//! the predictions are clock-aware (ZCU102 cells are evaluated at
//! 300 MHz, edge cells at 150 MHz).
//!
//! # Parallel evaluation
//!
//! Cells are independent (each is one pure `Design` build plus an
//! optional cycle simulation), so [`SweepSpec::jobs`] > 1 fans the matrix
//! out over the scoped-thread pool in [`crate::util::pool`]. Output
//! ordering is deterministic — cells always come back in nets-outer /
//! platforms / granularities-inner order regardless of which worker
//! finished first — so `--jobs N` produces **byte-identical** JSON and
//! golden-baseline artifacts to the serial path for any `N` (asserted in
//! `rust/tests/pareto.rs`).
//!
//! # Fault isolation
//!
//! One pathological cell — a degenerate custom budget, a panicking
//! allocation, a corrupt cache entry — must not take down the whole
//! matrix. Cells are evaluated through the panic-safe
//! [`crate::util::pool::parallel_map_fallible`] path: a cell that fails
//! (typed [`ReproError`], including captured panics) becomes one entry of
//! [`SweepReport::failures`] while every other cell's bytes are exactly
//! what a fault-free run produces. Failed cells are excluded from the
//! Pareto analyses and from [`SweepReport::save_designs`]; the JSON
//! document gains a `failures` section only when at least one cell
//! failed, so clean-run output stays byte-identical to earlier
//! trajectories. The failure paths themselves are exercised by the
//! deterministic injection harness in [`crate::util::fault`]
//! (`REPRO_FAULTS`, `rust/tests/faults.rs`).
//!
//! # Memoization
//!
//! [`SweepSpec::cache_dir`] points the run at a content-keyed per-cell
//! evaluation cache ([`cache`], the CLI's `--cache` / `--cache-dir`):
//! every cell already derived by *any* prior sweep — an example, a test,
//! a CI step — is reloaded from disk through the trusted
//! [`crate::design::Design::from_json_unchecked`] path with **zero**
//! Algorithm 1 / Algorithm 2 re-derivation, and hit/miss counts surface
//! as [`SweepReport::cache`]. Warm output is byte-identical to cold
//! (asserted in `rust/tests/differential.rs`).
//!
//! # Analyses
//!
//! * [`pareto`] — the per-network non-dominated set over {on-chip SRAM,
//!   predicted FPS, off-chip DRAM bytes/frame}, with dominated-by
//!   attribution: the memory-vs-throughput frontier that motivates the
//!   whole balanced-dataflow methodology (`repro sweep --pareto`).
//! * [`SweepSpec::clocks_hz`] — a clock-scaling axis: every cell also
//!   reports an FPS-vs-clock curve ([`crate::model::throughput::clock_curve`],
//!   which reuses [`crate::model::throughput::peak_gops_at`]) so one
//!   `repro sweep --clocks 100,200,300` call emits frequency-scaling
//!   curves per platform.
//! * [`pareto_clocks`] — clock frequency promoted to a **fourth Pareto
//!   axis** (`repro sweep --clocks .. --pareto-clocks`): every (cell,
//!   curve point) pair becomes a candidate and the non-dominated set is
//!   taken over {SRAM ↓, FPS ↑, DRAM ↓, clock ↓}, so a slower clock that
//!   still meets a throughput target shows up on the frontier instead of
//!   being flattened into the per-platform side curves.
//!
//! # Stable renderings
//!
//! Two stable renderings back BENCH trajectories and CI:
//!
//! * [`crate::report::sweep_matrix`] — an aligned text table (plus
//!   [`crate::report::pareto_table`] / [`crate::report::clock_curves`]
//!   for the analyses);
//! * [`SweepReport::to_json`] — one sorted-key JSON line (the `repro
//!   sweep --json` output), diffable across commits;
//!
//! and [`SweepReport::save_designs`] persists every cell's full
//! [`Design::to_json`] artifact (`<net>_<platform>_<granularity>.design.json`)
//! — the same artifact format committed as golden regression baselines
//! under `rust/tests/baselines/`.
//!
//! ```no_run
//! use repro::sweep::{self, SweepSpec};
//!
//! let mut spec = SweepSpec::from_csv(
//!     Some("mobilenet_v2,shufflenet_v2"),
//!     Some("zc706,zcu102,edge"),
//!     None, // granularities: default FGPM
//! )
//! .unwrap();
//! spec.jobs = 4; // parallel cells, byte-identical output to jobs = 1
//! let report = spec.run();
//! println!("{}", repro::report::sweep_matrix(&report));
//! println!("{}", repro::report::pareto_table(&report, &sweep::pareto(&report)));
//! std::fs::write("sweep.json", report.to_json()).unwrap();
//! ```

pub mod cache;
pub mod optimize;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::alloc::Granularity;
use crate::design::{granularity_name, parse_granularity, Design, Platform};
use crate::model::throughput::{self, ClockPoint};
use crate::nets::{self, Network};
use crate::sim::SimOptions;
use crate::util::error::ReproError;
use crate::util::fault;
use crate::util::json::Json;
use crate::util::pool;

pub use cache::{CacheStats, CellCache, GcStats};

/// The matrix a sweep runs over, plus per-cell simulation depth.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub nets: Vec<Network>,
    pub platforms: Vec<Platform>,
    pub granularities: Vec<Granularity>,
    /// `Some(n)` with `n > 0`: also cycle-simulate every cell for `n`
    /// frames (the sweep's actual-vs-theoretical columns). `None` or
    /// `Some(0)`: model only.
    pub frames: Option<u64>,
    /// Simulator options for the cells' designs. `None` keeps the
    /// builder default ([`SimOptions::optimized`]); ablation sweeps set
    /// e.g. [`SimOptions::baseline`], under which a cell can deadlock —
    /// recorded per cell as [`SweepCell::sim_error`].
    pub sim_options: Option<SimOptions>,
    /// Worker threads evaluating cells ([`crate::util::pool`]); the CLI's
    /// `--jobs`. `0` and `1` both mean the serial path. Any value
    /// produces byte-identical output — parallelism only changes
    /// wall-clock time.
    pub jobs: usize,
    /// Clock-scaling curve axis (the CLI's `--clocks`, in Hz here): when
    /// non-empty, every cell also carries
    /// [`SweepCell::clock_curve`] — its allocation's predicted FPS/GOPS
    /// re-evaluated at each of these clocks next to the PE array's
    /// [`crate::model::throughput::peak_gops_at`] peak. Empty: no curves
    /// (and no `clock_curve` key in the JSON, keeping pre-curve
    /// trajectories diffable).
    pub clocks_hz: Vec<f64>,
    /// Memoize cells in the content-keyed [`cache::CellCache`] at this
    /// directory (the CLI's `--cache` / `--cache-dir`). `None` evaluates
    /// every cell cold. The cache never changes output bytes — only
    /// whether a cell is derived or reloaded — and the run's hit/miss
    /// stats come back as [`SweepReport::cache`].
    pub cache_dir: Option<PathBuf>,
    /// Attach side-FIFO depth figures to every cell (the CLI's `--fifo`):
    /// the modeled [`crate::model::fifo::fifo_depths`] bounds, and — when
    /// the cell also simulates ([`SweepSpec::frames`]) — the simulator's
    /// observed per-FIFO peak occupancies, captured by forcing
    /// [`SimOptions::track_fifo`] on for the measurement run. `false`
    /// (default) keeps cells, JSON documents, *and cache keys*
    /// byte-identical to pre-FIFO trajectories.
    pub fifo: bool,
}

impl Default for SweepSpec {
    /// The full catalog sweep: every zoo network on every named platform
    /// at FGPM granularity, model only, serial, no clock curves.
    fn default() -> Self {
        SweepSpec {
            nets: nets::all_networks(),
            platforms: Platform::list(),
            granularities: vec![Granularity::Fgpm],
            frames: None,
            sim_options: None,
            jobs: 1,
            clocks_hz: Vec::new(),
            cache_dir: None,
            fifo: false,
        }
    }
}

fn split_csv(csv: &str) -> Vec<&str> {
    csv.split(',').map(str::trim).filter(|s| !s.is_empty()).collect()
}

/// Reject axis entries that resolve to the same canonical element
/// (`mbv2,mobilenet_v2`, `zc706,ZC706`, ...) — they would produce
/// duplicate cells and clashing artifact file names.
fn reject_duplicates(
    flag: &str,
    keys: impl IntoIterator<Item = String>,
) -> Result<(), ReproError> {
    let mut seen = std::collections::BTreeSet::new();
    for k in keys {
        if !seen.insert(k.clone()) {
            return Err(ReproError::config(format!(
                "{flag}: duplicate entry {k:?} (two names resolve to the same element)"
            )));
        }
    }
    Ok(())
}

impl SweepSpec {
    /// Build a spec from the CLI's comma-separated axis lists. `None`
    /// selects the full default axis (all zoo networks / the whole
    /// platform catalog / FGPM); `Some` must name at least one element,
    /// and unknown names fail with the list of known ones.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::sweep::SweepSpec;
    ///
    /// let spec = SweepSpec::from_csv(
    ///     Some("mobilenet_v2,shufflenet_v2"),
    ///     Some("zc706,edge"),
    ///     Some("fgpm,factorized"),
    /// )
    /// .unwrap();
    /// assert_eq!(spec.cell_count(), 8); // 2 nets x 2 platforms x 2 grans
    ///
    /// let err = SweepSpec::from_csv(None, Some("vu9p"), None).unwrap_err();
    /// assert!(err.contains("known platforms: zc706, zcu102, edge"));
    /// ```
    pub fn from_csv(
        nets_csv: Option<&str>,
        platforms_csv: Option<&str>,
        granularities_csv: Option<&str>,
    ) -> Result<SweepSpec, ReproError> {
        let mut spec = SweepSpec::default();
        if let Some(csv) = nets_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err(ReproError::config("--nets: empty network list"));
            }
            spec.nets = names
                .iter()
                .map(|n| nets::resolve(n).map_err(ReproError::network))
                .collect::<Result<_, _>>()?;
        }
        if let Some(csv) = platforms_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err(ReproError::config("--platforms: empty platform list"));
            }
            spec.platforms = names.iter().map(|n| Platform::resolve(n)).collect::<Result<_, _>>()?;
        }
        if let Some(csv) = granularities_csv {
            let names = split_csv(csv);
            if names.is_empty() {
                return Err(ReproError::config("--granularities: empty granularity list"));
            }
            spec.granularities =
                names.iter().map(|g| parse_granularity(g)).collect::<Result<_, _>>()?;
        }
        reject_duplicates("--nets", spec.nets.iter().map(|n| n.name.clone()))?;
        reject_duplicates("--platforms", spec.platforms.iter().map(|p| p.name.clone()))?;
        reject_duplicates(
            "--granularities",
            spec.granularities.iter().map(|g| granularity_name(*g).to_string()),
        )?;
        Ok(spec)
    }

    /// Build a spec from the CLI's full network-selection surface:
    /// [`SweepSpec::from_csv`] plus `--net-file`, a comma-separated list
    /// of JSON network-description paths ([`crate::ir::from_json`],
    /// schema in `docs/net_schema.md`), each loaded, validated, and
    /// lowered through [`crate::ir::load_file`].
    ///
    /// `--net-file` alone sweeps exactly the loaded networks (the default
    /// zoo axis would bury them); combined with `--nets` the loaded
    /// networks are appended to the named ones, with duplicates rejected
    /// across the union.
    pub fn from_cli(
        nets_csv: Option<&str>,
        net_files_csv: Option<&str>,
        platforms_csv: Option<&str>,
        granularities_csv: Option<&str>,
    ) -> Result<SweepSpec, ReproError> {
        let mut spec = SweepSpec::from_csv(nets_csv, platforms_csv, granularities_csv)?;
        if let Some(csv) = net_files_csv {
            let paths = split_csv(csv);
            if paths.is_empty() {
                return Err(ReproError::config("--net-file: empty file list"));
            }
            let mut loaded = Vec::with_capacity(paths.len());
            for p in paths {
                loaded.push(
                    crate::ir::load_file(Path::new(p)).map_err(|e| e.prefixed("--net-file "))?,
                );
            }
            if nets_csv.is_none() {
                spec.nets = loaded;
            } else {
                spec.nets.extend(loaded);
            }
            reject_duplicates("--nets/--net-file", spec.nets.iter().map(|n| n.name.clone()))?;
        }
        Ok(spec)
    }

    /// Parse the CLI's `--clocks` value — a comma-separated list of MHz
    /// points — into the Hz values [`SweepSpec::clocks_hz`] stores.
    /// Points must be positive finite numbers; duplicates are rejected
    /// (they would produce duplicate curve points); order is preserved.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::sweep::SweepSpec;
    ///
    /// assert_eq!(
    ///     SweepSpec::parse_clocks_csv("100, 200,300").unwrap(),
    ///     vec![100.0e6, 200.0e6, 300.0e6]
    /// );
    /// assert!(SweepSpec::parse_clocks_csv("0,200").is_err());
    /// assert!(SweepSpec::parse_clocks_csv("200,200").is_err());
    /// ```
    pub fn parse_clocks_csv(csv: &str) -> Result<Vec<f64>, ReproError> {
        let points = split_csv(csv);
        if points.is_empty() {
            return Err(ReproError::config("--clocks: empty clock list"));
        }
        let mut hz = Vec::with_capacity(points.len());
        for p in points {
            let mhz: f64 = p
                .parse()
                .map_err(|_| ReproError::config(format!("--clocks: cannot parse MHz value {p:?}")))?;
            if !mhz.is_finite() || mhz <= 0.0 {
                return Err(ReproError::config(format!(
                    "--clocks: MHz points must be positive, got {p:?}"
                )));
            }
            let v = mhz * 1.0e6;
            if hz.contains(&v) {
                return Err(ReproError::config(format!("--clocks: duplicate entry {p:?}")));
            }
            hz.push(v);
        }
        Ok(hz)
    }

    /// Resolve the CLI's cache flag pair into [`SweepSpec::cache_dir`]:
    /// `--cache` enables the cache at the default directory
    /// (`.sweep-cache`), `--cache-dir DIR` enables it at `DIR`, and
    /// passing both is rejected — silently preferring one would hide
    /// which directory the entries actually landed in.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::sweep::SweepSpec;
    ///
    /// assert_eq!(SweepSpec::resolve_cache_flags(false, None).unwrap(), None);
    /// assert_eq!(
    ///     SweepSpec::resolve_cache_flags(true, None).unwrap().unwrap(),
    ///     std::path::PathBuf::from(".sweep-cache")
    /// );
    /// let err = SweepSpec::resolve_cache_flags(true, Some("warm")).unwrap_err();
    /// assert!(err.contains("conflicts with --cache-dir"));
    /// ```
    pub fn resolve_cache_flags(
        cache: bool,
        cache_dir: Option<&str>,
    ) -> Result<Option<PathBuf>, ReproError> {
        match (cache, cache_dir) {
            (true, Some(dir)) => Err(ReproError::config(format!(
                "--cache: conflicts with --cache-dir {dir:?} (--cache-dir already enables the \
                 cache there; pass exactly one of the two)"
            ))),
            (true, None) => Ok(Some(PathBuf::from(".sweep-cache"))),
            (false, Some(dir)) => Ok(Some(PathBuf::from(dir))),
            (false, None) => Ok(None),
        }
    }

    /// Number of cells the matrix will produce.
    pub fn cell_count(&self) -> usize {
        self.nets.len() * self.platforms.len() * self.granularities.len()
    }

    /// Run the full pipeline for every cell. Cells are evaluated on
    /// [`SweepSpec::jobs`] worker threads (serial when `jobs <= 1`), but
    /// the report's cell order is always the deterministic nets-outer /
    /// platforms / granularities-inner order — the output is
    /// byte-identical for any job count, and — when
    /// [`SweepSpec::cache_dir`] is set — for any mix of cache hits and
    /// cold evaluations.
    ///
    /// Cells are fault-isolated: a cell whose evaluation fails — a typed
    /// [`ReproError`] from [`SweepSpec::eval_cell`] *or a panic*, caught
    /// by [`pool::parallel_map_fallible`] — becomes one
    /// [`SweepReport::failures`] entry (carrying its matrix position and
    /// error) while every other cell completes and keeps the exact bytes
    /// a fault-free run gives it. Cache store failures never fail a cell;
    /// they surface as [`CacheStats::store_errors`].
    pub fn run(&self) -> SweepReport {
        let frames_req = self.frames.filter(|&f| f > 0);
        let mut combos = Vec::with_capacity(self.cell_count());
        for net in &self.nets {
            for platform in &self.platforms {
                for &granularity in &self.granularities {
                    combos.push((net, platform, granularity));
                }
            }
        }
        let cache = self.cache_dir.as_deref().map(CellCache::open);
        let hits = AtomicU64::new(0);
        let misses = AtomicU64::new(0);
        let store_errors = AtomicU64::new(0);
        // Injection sites key on the cell's content key — never on worker
        // identity — so an injected run reproduces at any job count. The
        // key render is skipped entirely on the uncached disarmed path
        // (the common case), where nothing consumes it.
        let faults_armed = fault::armed();
        let outcomes =
            pool::parallel_map_fallible(self.jobs, &combos, |_, &(net, platform, granularity)| {
                if let Some(cache) = &cache {
                    let key = self.cell_key(net, platform, granularity, frames_req);
                    let key_text = key.to_string();
                    if let Some(cell) = cache.load(&key) {
                        // The trusted reloader rebuilds the network by zoo
                        // name or from the artifact's embedded network_def
                        // (non-zoo `--net-file` cells); either way, a *custom*
                        // Network sharing a stored cell's name (or any
                        // structural drift the key somehow missed) must not be
                        // served that cell. Verbatim structural equality with
                        // the probe network, or it's a miss.
                        if format!("{:?}", cell.design().network()) == format!("{net:?}") {
                            hits.fetch_add(1, Ordering::Relaxed);
                            return Ok(cell);
                        }
                    }
                    let cell = self.eval_cell(net, platform, granularity, frames_req, &key_text)?;
                    if cache.store(&key, &cell).is_err() {
                        store_errors.fetch_add(1, Ordering::Relaxed);
                    }
                    misses.fetch_add(1, Ordering::Relaxed);
                    Ok(cell)
                } else {
                    let key_text = if faults_armed {
                        self.cell_key(net, platform, granularity, frames_req).to_string()
                    } else {
                        String::new()
                    };
                    self.eval_cell(net, platform, granularity, frames_req, &key_text)
                }
            });
        let mut cells = Vec::with_capacity(combos.len());
        let mut failures = Vec::new();
        for (index, (outcome, &(net, platform, granularity))) in
            outcomes.into_iter().zip(&combos).enumerate()
        {
            match outcome {
                Ok(cell) => cells.push(cell),
                Err(error) => failures.push(CellFailure {
                    index,
                    network: net.name.clone(),
                    platform: platform.name.clone(),
                    granularity,
                    error,
                }),
            }
        }
        let cache_stats = cache.map(|_| CacheStats {
            hits: hits.into_inner(),
            misses: misses.into_inner(),
            store_errors: store_errors.into_inner(),
        });
        SweepReport { cells, failures, cache: cache_stats }
    }

    /// Content key of one cell for the [`cache`] layer: every input that
    /// can change the cell's bytes, as one stable sorted-key JSON value —
    /// network identity (name plus a full structural digest over the
    /// `Debug` form of the whole `Network` value: dims, every layer,
    /// every SCB — so even a field tweak or layer reorder that preserves
    /// name/length/total MACs changes the key), the full platform budget
    /// object (SRAM / DSP / clock / name), granularity, requested
    /// simulation depth, effective simulator options, and the clock-curve
    /// axis. The `--fifo` request keys in only when set (a `"fifo": true`
    /// marker), so pre-FIFO entries keep warm-hitting non-`--fifo` sweeps
    /// byte-for-byte. Changing *any* component changes the key, so a
    /// stale hit is structurally impossible (property-tested in
    /// `rust/tests/proptests.rs`); [`SweepSpec::run`] additionally
    /// re-checks the reconstructed network verbatim at hit time.
    fn cell_key(
        &self,
        net: &Network,
        platform: &Platform,
        granularity: Granularity,
        frames_req: Option<u64>,
    ) -> Json {
        let dbg = format!("{net:?}");
        let mut fp = BTreeMap::new();
        fp.insert(
            "digest".to_string(),
            Json::Str(format!(
                "{:016x}{:016x}",
                cache::fnv1a64(dbg.as_bytes(), 0xcbf2_9ce4_8422_2325),
                cache::fnv1a64(dbg.as_bytes(), 0x9747_b28c_8c5e_a5a3)
            )),
        );
        fp.insert("layers".to_string(), Json::Num(net.layers.len() as f64));
        fp.insert("macs".to_string(), Json::Num(net.total_macs() as f64));
        let mut m = BTreeMap::new();
        m.insert(
            "clocks_hz".to_string(),
            Json::Arr(self.clocks_hz.iter().map(|&hz| Json::Num(hz)).collect()),
        );
        // Only `--fifo` runs carry the marker: non-FIFO keys (and the
        // entries they name) stay byte-identical to pre-FIFO caches.
        if self.fifo {
            m.insert("fifo".to_string(), Json::Bool(true));
        }
        m.insert(
            "frames".to_string(),
            match frames_req {
                Some(f) => Json::Num(f as f64),
                None => Json::Null,
            },
        );
        m.insert("granularity".to_string(), Json::Str(granularity_name(granularity).to_string()));
        m.insert("net_fingerprint".to_string(), Json::Obj(fp));
        m.insert("network".to_string(), Json::Str(net.name.clone()));
        m.insert("platform".to_string(), platform.to_json_value());
        m.insert(
            "sim_options".to_string(),
            crate::design::sim_options_to_json(
                &self.sim_options.unwrap_or_else(SimOptions::optimized),
            ),
        );
        m.insert("version".to_string(), Json::Num(1.0));
        Json::Obj(m)
    }

    /// Evaluate one matrix cell: build the [`Design`], optionally
    /// cycle-simulate it, and attach the clock-scaling curve. Pure —
    /// shares nothing mutable, so the pool may run any number of these
    /// concurrently.
    ///
    /// Fallible per-cell: a degenerate platform budget is a typed
    /// [`ReproError::Allocation`] error instead of a downstream panic,
    /// and the `eval.alloc` / `eval.sim` injection sites
    /// ([`crate::util::fault`]) fail exactly the cells whose content key
    /// (`fault_key`) their trigger selects. An *organic* simulator
    /// deadlock ([`ReproError::Simulation`]) is deliberately **not** a
    /// cell failure — it is a measurement, recorded in-cell as
    /// [`SweepCell::sim_error`]; any other simulate error (a degenerate
    /// frame count would be [`ReproError::Config`]) propagates.
    fn eval_cell(
        &self,
        net: &Network,
        platform: &Platform,
        granularity: Granularity,
        frames_req: Option<u64>,
        fault_key: &str,
    ) -> Result<SweepCell, ReproError> {
        if platform.sram_bytes == 0 || platform.dsp_budget == 0 {
            return Err(ReproError::allocation(format!(
                "platform {:?}: degenerate budget (sram_bytes={}, dsp_budget={}) — Algorithm 1/2 \
                 need nonzero SRAM and DSP budgets",
                platform.name, platform.sram_bytes, platform.dsp_budget
            )));
        }
        if fault::trip(fault::Site::EvalAlloc, fault_key) {
            panic!(
                "injected fault: eval.alloc for cell {}/{}/{}",
                net.name,
                platform.name,
                granularity_name(granularity)
            );
        }
        let mut builder = Design::builder(net).platform(platform.clone()).granularity(granularity);
        if let Some(opts) = self.sim_options {
            builder = builder.sim_options(opts);
        }
        let design = builder.build();
        if fault::trip(fault::Site::EvalSim, fault_key) {
            return Err(ReproError::simulation(format!(
                "injected fault: eval.sim for cell {}/{}/{}",
                net.name,
                platform.name,
                granularity_name(granularity)
            )));
        }
        // A deadlocked simulation (possible only under non-default
        // `sim_options`) is recorded as an explicit per-cell error,
        // distinguishable from a model-only sweep, rather than poisoning
        // the run. A `--fifo` measurement forces `track_fifo` on for the
        // same single run — occupancy tracking never changes the stats
        // (pinned by `skip_on_off_stats_identical_across_zoo`), so the
        // headline figures stay byte-identical to a non-FIFO sweep's.
        let mut fifo_peaks = None;
        let (sim, sim_error) = match frames_req {
            None => (None, None),
            Some(frames) => {
                let base = *design.sim_options();
                let opts = SimOptions { track_fifo: self.fifo || base.track_fifo, ..base };
                match design.simulate_with(&opts, frames) {
                    Ok(st) => {
                        if self.fifo {
                            fifo_peaks = Some(st.fifo_peak.clone());
                        }
                        (
                            Some(SimFigures {
                                frames,
                                fps: st.fps(platform.clock_hz),
                                mac_efficiency: st.mac_efficiency(),
                            }),
                            None,
                        )
                    }
                    // Deadlock = an in-cell measurement; anything else
                    // (config misuse) is a real cell failure.
                    Err(e @ ReproError::Simulation(_)) => (None, Some(e.to_string())),
                    Err(e) => return Err(e),
                }
            }
        };
        let fifo = if self.fifo {
            Some(FifoFigures {
                report: crate::model::fifo::fifo_depths(
                    design.network(),
                    design.ce_plan(),
                    design.sim_options().scheme,
                ),
                peaks: fifo_peaks,
            })
        } else {
            None
        };
        let clock_curve =
            throughput::clock_curve(design.network(), design.allocs(), &self.clocks_hz);
        Ok(SweepCell { design, sim, sim_error, clock_curve, fifo })
    }
}

/// Cycle-simulation figures of one cell (present only when the spec set
/// [`SweepSpec::frames`] and the simulation completed).
#[derive(Debug, Clone, Copy)]
pub struct SimFigures {
    pub frames: u64,
    /// Simulated FPS at the cell platform's clock.
    pub fps: f64,
    /// Actual (simulated) MAC efficiency.
    pub mac_efficiency: f64,
}

/// Side-FIFO figures of one cell (present only under [`SweepSpec::fifo`]):
/// the modeled depth bounds, plus the simulator's observed per-FIFO peak
/// occupancies when the cell also simulated. `peaks[i]` is the observed
/// peak of `report.fifos[i]` — [`crate::model::fifo::fifo_depths`]
/// enumerates FIFOs in exactly the simulator's pipeline order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FifoFigures {
    /// Modeled per-FIFO depth bounds, in simulator pipeline order.
    pub report: crate::model::fifo::FifoReport,
    /// Observed per-FIFO peak occupancy (pixels) from the cell's
    /// simulation; `None` for model-only sweeps or deadlocked cells.
    pub peaks: Option<Vec<u64>>,
}

/// One (network, platform, granularity) cell: the compiled [`Design`]
/// plus optional simulation figures.
#[derive(Debug, Clone)]
pub struct SweepCell {
    design: Design,
    sim: Option<SimFigures>,
    /// Why the requested simulation produced no figures (deadlock text);
    /// `None` both when the cell simulated fine and when the sweep was
    /// model-only — [`SweepCell::sim`] disambiguates.
    sim_error: Option<String>,
    /// FPS-vs-clock points at the spec's [`SweepSpec::clocks_hz`] axis
    /// (empty when no `--clocks` axis was requested).
    clock_curve: Vec<ClockPoint>,
    /// Side-FIFO depth figures ([`SweepSpec::fifo`] sweeps only).
    fifo: Option<FifoFigures>,
}

/// The stable JSON object of one clock-curve point — shared by the cell
/// document serializer and the [`cache`] entry format so the two can
/// never drift field-by-field.
pub(crate) fn clock_point_to_json(pt: &ClockPoint) -> Json {
    let mut p = BTreeMap::new();
    p.insert("clock_hz".to_string(), Json::Num(pt.clock_hz));
    p.insert("fps".to_string(), Json::Num(pt.fps));
    p.insert("gops".to_string(), Json::Num(pt.gops));
    p.insert("peak_gops".to_string(), Json::Num(pt.peak_gops));
    Json::Obj(p)
}

/// The stable JSON object of one cell's side-FIFO figures — shared by the
/// cell document serializer and the [`cache`] entry format so the two can
/// never drift field-by-field. `peak_px` is `Null` for model-only cells.
pub(crate) fn fifo_figures_to_json(fifo: &FifoFigures) -> Json {
    let fifos = fifo
        .report
        .fifos
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let mut m = BTreeMap::new();
            m.insert("bytes".to_string(), Json::Num(f.bytes as f64));
            m.insert("channels".to_string(), Json::Num(f.channels as f64));
            m.insert("depth_px".to_string(), Json::Num(f.depth_px as f64));
            m.insert("margin_px".to_string(), Json::Num(f.margin_px as f64));
            m.insert("name".to_string(), Json::Str(f.name.clone()));
            m.insert("on_chip".to_string(), Json::Bool(f.on_chip));
            m.insert(
                "peak_px".to_string(),
                match &fifo.peaks {
                    Some(p) => Json::Num(p[i] as f64),
                    None => Json::Null,
                },
            );
            m.insert("rate_px".to_string(), Json::Num(f.rate_px as f64));
            Json::Obj(m)
        })
        .collect();
    let mut m = BTreeMap::new();
    m.insert("fifos".to_string(), Json::Arr(fifos));
    m.insert("total_bytes".to_string(), Json::Num(fifo.report.total_bytes() as f64));
    Json::Obj(m)
}

/// Inverse of [`fifo_figures_to_json`], for the [`cache`] warm path.
pub(crate) fn fifo_figures_from_json(j: &Json) -> Result<FifoFigures, ReproError> {
    let entries = j
        .get("fifos")
        .and_then(Json::as_arr)
        .ok_or_else(|| ReproError::cache_io("cache entry: missing array fifo/\"fifos\""))?;
    let mut fifos = Vec::with_capacity(entries.len());
    let mut peaks = Vec::with_capacity(entries.len());
    let mut any_peak = false;
    for e in entries {
        let num = |key: &str| {
            e.field_f64(key)
                .ok_or_else(|| ReproError::cache_io(format!("cache entry: missing fifo {key:?}")))
        };
        let name = match e.get("name") {
            Some(Json::Str(s)) => s.clone(),
            _ => return Err(ReproError::cache_io("cache entry: missing fifo \"name\"")),
        };
        let on_chip = matches!(e.get("on_chip"), Some(Json::Bool(true)));
        fifos.push(crate::model::fifo::FifoDepth {
            name,
            on_chip,
            rate_px: num("rate_px")? as u64,
            margin_px: num("margin_px")? as u64,
            depth_px: num("depth_px")? as u64,
            channels: num("channels")? as usize,
            bytes: num("bytes")? as u64,
        });
        match e.get("peak_px") {
            Some(Json::Num(p)) => {
                any_peak = true;
                peaks.push(*p as u64);
            }
            _ => peaks.push(0),
        }
    }
    Ok(FifoFigures {
        report: crate::model::fifo::FifoReport { fifos },
        peaks: if any_peak { Some(peaks) } else { None },
    })
}

/// File-name-safe lowercase slug of a platform/network name.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect()
}

impl SweepCell {
    pub fn design(&self) -> &Design {
        &self.design
    }

    pub fn sim(&self) -> Option<&SimFigures> {
        self.sim.as_ref()
    }

    /// The error that prevented a requested simulation (deadlock), if any.
    pub fn sim_error(&self) -> Option<&str> {
        self.sim_error.as_deref()
    }

    /// The cell's FPS-vs-clock scaling curve, one point per entry of the
    /// spec's [`SweepSpec::clocks_hz`] axis (empty when the sweep ran
    /// without a `--clocks` axis).
    pub fn clock_curve(&self) -> &[ClockPoint] {
        &self.clock_curve
    }

    /// The cell's side-FIFO figures (`--fifo` sweeps only).
    pub fn fifo(&self) -> Option<&FifoFigures> {
        self.fifo.as_ref()
    }

    pub fn network_name(&self) -> &str {
        &self.design.network().name
    }

    pub fn platform(&self) -> &Platform {
        self.design.platform()
    }

    /// DSP slices used over the part's total (Table II's utilization).
    pub fn dsp_utilization(&self) -> f64 {
        self.design.parallelism().dsps as f64 / self.platform().dsp_total as f64
    }

    /// Recosted SRAM bytes over the platform budget. Exceeds 1.0 when
    /// even the minimum-SRAM configuration does not fit the part (the
    /// edge-class regime).
    pub fn sram_utilization(&self) -> f64 {
        self.design.sram_bytes() as f64 / self.platform().sram_bytes as f64
    }

    /// Whether the recosted SRAM footprint fits the platform budget.
    pub fn fits_sram(&self) -> bool {
        self.design.sram_bytes() <= self.platform().sram_bytes
    }

    /// File name [`SweepReport::save_designs`] writes this cell's design
    /// artifact under: `<net>_<platform>_<granularity>.design.json`, with
    /// the network's AOT short name when it is a zoo network.
    pub fn artifact_file_name(&self) -> String {
        let net = nets::short_name(self.network_name())
            .map(str::to_string)
            .unwrap_or_else(|| sanitize(self.network_name()));
        format!(
            "{net}_{}_{}.design.json",
            sanitize(&self.platform().name),
            granularity_name(self.design.granularity())
        )
    }

    /// The cell's headline figures as a stable sorted-key JSON value —
    /// one element of the `repro sweep --json` document.
    pub fn to_json_value(&self) -> Json {
        let d = &self.design;
        let p = d.predicted();
        let mut m = BTreeMap::new();
        let mut put = |k: &str, v: Json| {
            m.insert(k.to_string(), v);
        };
        put("boundary", Json::Num(d.ce_plan().boundary as f64));
        put("boundary_min_sram", Json::Num(d.memory().boundary_min_sram as f64));
        // Only curve-bearing sweeps carry the key, so curve-less JSON
        // stays byte-identical to pre-curve BENCH trajectories.
        if !self.clock_curve.is_empty() {
            put(
                "clock_curve",
                Json::Arr(self.clock_curve.iter().map(clock_point_to_json).collect()),
            );
        }
        put("clock_hz", Json::Num(d.platform().clock_hz));
        put("dram_bytes", Json::Num(d.dram_bytes() as f64));
        put("dsp_utilization", Json::Num(self.dsp_utilization()));
        put("dsps", Json::Num(d.parallelism().dsps as f64));
        // Only `--fifo` sweeps carry the key, so non-FIFO documents stay
        // byte-identical to pre-FIFO trajectories.
        if let Some(fifo) = &self.fifo {
            put("fifo", fifo_figures_to_json(fifo));
        }
        put("fits_sram", Json::Bool(self.fits_sram()));
        put("fps", Json::Num(p.fps));
        put("gops", Json::Num(p.gops));
        put("granularity", Json::Str(granularity_name(d.granularity()).to_string()));
        put("layers", Json::Num(d.network().layers.len() as f64));
        put("mac_efficiency", Json::Num(p.mac_efficiency));
        put("network", Json::Str(d.network().name.clone()));
        put("pes", Json::Num(d.parallelism().pes as f64));
        put("platform", Json::Str(d.platform().name.clone()));
        match &self.sim {
            Some(s) => {
                put("sim_fps", Json::Num(s.fps));
                put("sim_frames", Json::Num(s.frames as f64));
                put("sim_mac_efficiency", Json::Num(s.mac_efficiency));
            }
            None => {
                put("sim_fps", Json::Null);
                put("sim_frames", Json::Null);
                put("sim_mac_efficiency", Json::Null);
            }
        }
        put(
            "sim_error",
            match &self.sim_error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        );
        put("sram_bytes", Json::Num(d.sram_bytes() as f64));
        put("sram_utilization", Json::Num(self.sram_utilization()));
        put("t_max", Json::Num(p.t_max as f64));
        Json::Obj(m)
    }
}

/// One matrix cell that failed to evaluate: its position and axes plus
/// the typed [`ReproError`] that killed it (a returned error or a caught
/// panic — [`crate::util::pool::parallel_map_fallible`] makes no
/// distinction downstream). Collected into [`SweepReport::failures`] so
/// one pathological cell degrades the run instead of aborting it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellFailure {
    /// Position in the spec's deterministic nets-outer / platforms /
    /// granularities-inner combination order — the row this cell *would*
    /// have occupied. Not an index into [`SweepReport::cells`] (failed
    /// cells are absent there); renderers use it to interleave failure
    /// rows at the right matrix position.
    pub index: usize,
    pub network: String,
    pub platform: String,
    pub granularity: Granularity,
    pub error: ReproError,
}

impl CellFailure {
    /// `net/platform/granularity` — the human-readable cell label used in
    /// stderr failure summaries and the matrix table.
    pub fn label(&self) -> String {
        format!("{}/{}/{}", self.network, self.platform, granularity_name(self.granularity))
    }

    /// Stable sorted-key JSON value — one element of the `failures` array
    /// in `repro sweep --json` output (the array appears only when at
    /// least one cell failed).
    pub fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("error".to_string(), self.error.to_json_value());
        m.insert(
            "granularity".to_string(),
            Json::Str(granularity_name(self.granularity).to_string()),
        );
        m.insert("index".to_string(), Json::Num(self.index as f64));
        m.insert("network".to_string(), Json::Str(self.network.clone()));
        m.insert("platform".to_string(), Json::Str(self.platform.clone()));
        Json::Obj(m)
    }
}

/// The result of a sweep: one [`SweepCell`] per matrix combination that
/// evaluated successfully, in the spec's deterministic iteration order,
/// plus a [`CellFailure`] record for every combination that did not.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub cells: Vec<SweepCell>,
    /// Cells that failed to evaluate (typed error or caught panic), in
    /// matrix order. Empty on a clean run — and only then is the report's
    /// JSON byte-identical to pre-fault-isolation trajectories. Failed
    /// cells are excluded from the Pareto analyses and from
    /// [`SweepReport::save_designs`].
    pub failures: Vec<CellFailure>,
    /// Hit/miss stats of the run against [`SweepSpec::cache_dir`]'s
    /// [`cache::CellCache`]; `None` when the sweep ran uncached. A fully
    /// warm run reports `misses == 0` and
    /// [`CacheStats::hit_rate`] `== 1.0`. Deliberately excluded from
    /// [`SweepReport::to_json`] so warm and cold documents stay
    /// byte-identical; the CLI prints it to stderr instead.
    pub cache: Option<CacheStats>,
}

impl SweepReport {
    /// The whole report as one stable sorted-key JSON line — the
    /// `repro sweep --json` output recorded in BENCH trajectories.
    ///
    /// Byte-identical for any [`SweepSpec::jobs`] value: parallelism
    /// changes wall-clock time, never content or ordering.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::sweep::SweepSpec;
    ///
    /// let spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
    /// let json = spec.run().to_json();
    /// assert!(!json.contains('\n')); // one line, stable sorted keys
    /// let parsed = repro::util::json::Json::parse(&json).unwrap();
    /// assert_eq!(parsed.arr_field("cells").len(), 1);
    /// ```
    pub fn to_json(&self) -> String {
        self.to_json_with(None)
    }

    /// [`SweepReport::to_json`] with an optional embedded Pareto analysis
    /// (the `repro sweep --pareto --json` output): when given, the
    /// document gains a top-level `"pareto"` key holding
    /// [`ParetoReport::to_json_value`].
    pub fn to_json_with(&self, pareto: Option<&ParetoReport>) -> String {
        self.to_json_full(pareto, None)
    }

    /// The full document: [`SweepReport::to_json`] plus optional embedded
    /// analyses — `"pareto"` (3-D, [`ParetoReport`]) and
    /// `"pareto_clocks"` (the 4-D clock-axis frontier,
    /// [`ClockParetoReport`], the `repro sweep --pareto-clocks --json`
    /// output). Cache stats are never embedded (see
    /// [`SweepReport::cache`]).
    pub fn to_json_full(
        &self,
        pareto: Option<&ParetoReport>,
        pareto_clocks: Option<&ClockParetoReport>,
    ) -> String {
        let mut m = BTreeMap::new();
        m.insert(
            "cells".to_string(),
            Json::Arr(self.cells.iter().map(SweepCell::to_json_value).collect()),
        );
        // Clean runs carry no `failures` key at all, keeping their
        // documents byte-identical to pre-fault-isolation trajectories.
        if !self.failures.is_empty() {
            m.insert(
                "failures".to_string(),
                Json::Arr(self.failures.iter().map(CellFailure::to_json_value).collect()),
            );
        }
        if let Some(p) = pareto {
            m.insert("pareto".to_string(), p.to_json_value());
        }
        if let Some(p) = pareto_clocks {
            m.insert("pareto_clocks".to_string(), p.to_json_value());
        }
        m.insert("version".to_string(), Json::Num(1.0));
        Json::Obj(m).to_string()
    }

    /// Convenience for [`pareto`] (the free function) on this report.
    pub fn pareto(&self) -> ParetoReport {
        pareto(self)
    }

    /// Convenience for [`pareto_clocks`] (the free function) on this
    /// report.
    pub fn pareto_clocks(&self) -> ClockParetoReport {
        pareto_clocks(self)
    }

    /// Persist every *successful* cell's full [`Design::to_json`] artifact
    /// into `dir` (created if missing), returning the paths written in
    /// cell order. Failed cells ([`SweepReport::failures`]) have no design
    /// to save and are skipped — the CLI reports the skip count next to
    /// the saved count.
    pub fn save_designs(&self, dir: &Path) -> Result<Vec<PathBuf>, ReproError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| ReproError::config(format!("{}: {e}", dir.display())))?;
        let mut paths = Vec::with_capacity(self.cells.len());
        for cell in &self.cells {
            let path = dir.join(cell.artifact_file_name());
            let mut text = cell.design.to_json();
            text.push('\n');
            std::fs::write(&path, text)
                .map_err(|e| ReproError::config(format!("{}: {e}", path.display())))?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// The cell for a (network, platform, granularity) triple, if swept.
    pub fn cell(&self, net: &str, platform: &str, granularity: Granularity) -> Option<&SweepCell> {
        self.cells.iter().find(|c| {
            c.network_name() == net
                && c.platform().name == platform
                && c.design.granularity() == granularity
        })
    }
}

/// The objectives the Pareto analyses trade off for one candidate:
/// minimize on-chip SRAM, maximize predicted FPS, minimize off-chip DRAM
/// traffic per frame — the axes Petrica et al. and the memory-wall line
/// of work argue must sit on one frontier for streaming dataflow
/// accelerators — plus an opt-in fourth axis, the design clock
/// (minimize: a lower clock closes timing on cheaper speed grades and
/// burns less power for the same allocation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    /// On-chip SRAM bytes (minimize) — [`Design::sram_bytes`].
    pub sram_bytes: u64,
    /// Predicted FPS (maximize) — Eq 14, at the cell platform's clock in
    /// the 3-D analysis, at [`Objectives::clock_hz`] in the 4-D one.
    pub fps: f64,
    /// Off-chip DRAM bytes per frame (minimize) — Eq 13.
    pub dram_bytes: u64,
    /// The frequency axis (minimize), fed by
    /// [`crate::model::throughput::clock_curve`] points. `None` in the
    /// classic 3-D analysis ([`pareto`]), where it is ignored by
    /// [`Objectives::dominates`]; `Some` for every [`pareto_clocks`]
    /// candidate.
    pub clock_hz: Option<f64>,
    /// Modeled side-FIFO footprint in bytes (minimize) —
    /// [`crate::model::fifo::FifoReport::total_bytes`], the inter-CE
    /// buffering Eq 12 does not count. `Some` only for cells of a
    /// [`SweepSpec::fifo`] sweep; like the clock axis it participates in
    /// [`Objectives::dominates`] only when **both** vectors carry it, so
    /// non-`--fifo` analyses are unchanged.
    pub fifo_bytes: Option<u64>,
}

impl Objectives {
    /// The 3-D objective vector of one sweep cell (no clock axis).
    pub fn of(cell: &SweepCell) -> Objectives {
        Objectives {
            sram_bytes: cell.design().sram_bytes(),
            fps: cell.design().predicted().fps,
            dram_bytes: cell.design().dram_bytes(),
            clock_hz: None,
            fifo_bytes: cell.fifo().map(|f| f.report.total_bytes()),
        }
    }

    /// The 4-D objective vector of one (cell, clock point) candidate:
    /// SRAM and DRAM come from the (clock-independent) allocation, FPS
    /// from the curve point's Eq-14 re-evaluation, and the point's clock
    /// becomes the fourth axis.
    pub fn at_clock(cell: &SweepCell, point: ClockPoint) -> Objectives {
        Objectives {
            sram_bytes: cell.design().sram_bytes(),
            fps: point.fps,
            dram_bytes: cell.design().dram_bytes(),
            clock_hz: Some(point.clock_hz),
            fifo_bytes: cell.fifo().map(|f| f.report.total_bytes()),
        }
    }

    /// Pareto dominance: `self` dominates `other` when it is no worse on
    /// every objective (≤ SRAM, ≥ FPS, ≤ DRAM, and ≤ clock / ≤ FIFO
    /// bytes when both carry those axes) and strictly better on at least
    /// one. Exact ties on all axes dominate in neither direction — both
    /// candidates land on the frontier. The optional axes only
    /// participate when **both** vectors carry them, so 3-D, 4-D, and
    /// `--fifo` analyses never mix dominance rules mid-comparison.
    pub fn dominates(&self, other: &Objectives) -> bool {
        let (clock_no_worse, clock_better) = match (self.clock_hz, other.clock_hz) {
            (Some(a), Some(b)) => (a <= b, a < b),
            _ => (true, false),
        };
        let (fifo_no_worse, fifo_better) = match (self.fifo_bytes, other.fifo_bytes) {
            (Some(a), Some(b)) => (a <= b, a < b),
            _ => (true, false),
        };
        let no_worse = self.sram_bytes <= other.sram_bytes
            && self.fps >= other.fps
            && self.dram_bytes <= other.dram_bytes
            && clock_no_worse
            && fifo_no_worse;
        let strictly_better = self.sram_bytes < other.sram_bytes
            || self.fps > other.fps
            || self.dram_bytes < other.dram_bytes
            || clock_better
            || fifo_better;
        no_worse && strictly_better
    }
}

/// The non-dominated set of one network's cells, with dominated-by
/// attribution for everything off the frontier.
#[derive(Debug, Clone)]
pub struct ParetoFront {
    /// The network this frontier belongs to.
    pub network: String,
    /// Indices (into [`SweepReport::cells`]) of the non-dominated cells,
    /// in cell order.
    pub frontier: Vec<usize>,
    /// `(dominated cell index, dominating frontier cell index)` for every
    /// cell off the frontier: the attribution names the first frontier
    /// cell (lowest index) that dominates it, in cell order.
    pub dominated: Vec<(usize, usize)>,
}

/// Every per-network frontier of one sweep, in the report's network
/// order.
#[derive(Debug, Clone)]
pub struct ParetoReport {
    pub fronts: Vec<ParetoFront>,
}

impl ParetoReport {
    /// Stable sorted-key JSON value of the analysis — the `"pareto"`
    /// entry of `repro sweep --pareto --json`. Frontier cells and
    /// dominated-by attributions reference cells by index into the same
    /// document's `"cells"` array, with (platform, granularity) labels
    /// repeated for readability.
    pub fn to_json_value(&self) -> Json {
        let fronts = self
            .fronts
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert(
                    "dominated".to_string(),
                    Json::Arr(
                        f.dominated
                            .iter()
                            .map(|&(cell, by)| {
                                let mut d = BTreeMap::new();
                                d.insert("by".to_string(), Json::Num(by as f64));
                                d.insert("cell".to_string(), Json::Num(cell as f64));
                                Json::Obj(d)
                            })
                            .collect(),
                    ),
                );
                m.insert(
                    "frontier".to_string(),
                    Json::Arr(f.frontier.iter().map(|&i| Json::Num(i as f64)).collect()),
                );
                m.insert("network".to_string(), Json::Str(f.network.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("fronts".to_string(), Json::Arr(fronts));
        Json::Obj(m)
    }
}

/// Extract the per-network Pareto frontier of a sweep over {on-chip SRAM,
/// predicted FPS, off-chip DRAM bytes/frame} (see [`Objectives`]).
///
/// Cells are grouped by network (frontiers across different networks
/// would compare apples to oranges — a ShuffleNet cell always "beats" a
/// MobileNet cell on work done per frame) and each group's non-dominated
/// set is computed exactly, with dominated-by attribution pointing every
/// off-frontier cell at the first frontier cell that dominates it. Output
/// is deterministic: networks in first-appearance order, indices in cell
/// order.
///
/// An empty report yields an empty analysis; a single-cell group is its
/// own frontier; exact-tie cells (identical objective vectors) dominate
/// in neither direction and both stay on the frontier.
///
/// # Examples
///
/// ```
/// use repro::sweep::{pareto, SweepSpec};
///
/// let spec = SweepSpec::from_csv(
///     Some("shufflenet_v2"),
///     Some("zc706,zcu102,edge"),
///     None,
/// )
/// .unwrap();
/// let report = spec.run();
/// let analysis = pareto(&report);
/// assert_eq!(analysis.fronts.len(), 1); // one frontier per network
/// let front = &analysis.fronts[0];
/// // Every cell is either on the frontier or attributed to a dominator.
/// assert_eq!(front.frontier.len() + front.dominated.len(), report.cells.len());
/// ```
pub fn pareto(report: &SweepReport) -> ParetoReport {
    let groups = group_by_network(report.cells.iter().map(SweepCell::network_name));
    let fronts = groups
        .into_iter()
        .map(|(name, idxs)| {
            let objs: Vec<Objectives> =
                idxs.iter().map(|&i| Objectives::of(&report.cells[i])).collect();
            let (front_local, dom_local) = non_dominated_split(&objs);
            ParetoFront {
                network: name,
                frontier: front_local.iter().map(|&a| idxs[a]).collect(),
                dominated: dom_local.iter().map(|&(a, b)| (idxs[a], idxs[b])).collect(),
            }
        })
        .collect();
    ParetoReport { fronts }
}

/// Group element indices by network name, preserving first-appearance
/// order (frontiers across networks would compare apples to oranges).
fn group_by_network<'a>(names: impl Iterator<Item = &'a str>) -> Vec<(String, Vec<usize>)> {
    let mut order: Vec<&str> = Vec::new();
    let mut groups: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (i, name) in names.enumerate() {
        let group = groups.entry(name).or_default();
        if group.is_empty() {
            order.push(name);
        }
        group.push(i);
    }
    order.into_iter().map(|name| (name.to_string(), groups.remove(name).unwrap())).collect()
}

/// Exact non-dominated split of one objective group, as local indices:
/// `(frontier, dominated)` where every dominated element is attributed to
/// the first (lowest-index) frontier element that dominates it. A
/// dominated element always has a *frontier* dominator: dominance is
/// transitive and irreflexive, so a maximal element above it exists and
/// is itself non-dominated.
fn non_dominated_split(objs: &[Objectives]) -> (Vec<usize>, Vec<(usize, usize)>) {
    let frontier: Vec<usize> = (0..objs.len())
        .filter(|&a| !objs.iter().any(|ob| ob.dominates(&objs[a])))
        .collect();
    let mut dominated = Vec::new();
    for a in 0..objs.len() {
        if frontier.binary_search(&a).is_ok() {
            continue;
        }
        let by = *frontier
            .iter()
            .find(|&&b| objs[b].dominates(&objs[a]))
            .expect("dominated element must have a frontier dominator");
        dominated.push((a, by));
    }
    (frontier, dominated)
}

/// One candidate of the 4-D clock-axis analysis: a sweep cell evaluated
/// at one candidate design clock.
#[derive(Debug, Clone, Copy)]
pub struct ClockCandidate {
    /// Index into [`SweepReport::cells`].
    pub cell: usize,
    /// The candidate clock in Hz.
    pub clock_hz: f64,
    /// The full 4-D objective vector ([`Objectives::at_clock`]).
    pub objectives: Objectives,
}

/// The 4-D non-dominated set of one network's candidates; indices point
/// into [`ClockParetoReport::candidates`].
#[derive(Debug, Clone)]
pub struct ClockParetoFront {
    pub network: String,
    /// Candidate indices on the frontier, in candidate order.
    pub frontier: Vec<usize>,
    /// `(dominated candidate, dominating frontier candidate)` pairs, in
    /// candidate order, attributing the first (lowest-index) dominator.
    pub dominated: Vec<(usize, usize)>,
}

/// The clock-axis Pareto analysis of one sweep (`repro sweep --clocks ..
/// --pareto-clocks`): the candidate list plus one per-network front.
#[derive(Debug, Clone)]
pub struct ClockParetoReport {
    /// Every (cell, clock) candidate, cells in report order, clock points
    /// in curve order (one native-clock candidate for curve-less cells).
    pub candidates: Vec<ClockCandidate>,
    pub fronts: Vec<ClockParetoFront>,
}

impl ClockParetoReport {
    /// Stable sorted-key JSON value — the `"pareto_clocks"` entry of
    /// `repro sweep --pareto-clocks --json`. Candidates carry their full
    /// objective vector (`cell` indexes the same document's `"cells"`
    /// array); frontier and dominated-by entries index `"candidates"`.
    pub fn to_json_value(&self) -> Json {
        let candidates = self
            .candidates
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert("cell".to_string(), Json::Num(c.cell as f64));
                m.insert("clock_hz".to_string(), Json::Num(c.clock_hz));
                m.insert("dram_bytes".to_string(), Json::Num(c.objectives.dram_bytes as f64));
                m.insert("fps".to_string(), Json::Num(c.objectives.fps));
                m.insert("sram_bytes".to_string(), Json::Num(c.objectives.sram_bytes as f64));
                Json::Obj(m)
            })
            .collect();
        let fronts = self
            .fronts
            .iter()
            .map(|f| {
                let mut m = BTreeMap::new();
                m.insert(
                    "dominated".to_string(),
                    Json::Arr(
                        f.dominated
                            .iter()
                            .map(|&(cand, by)| {
                                let mut d = BTreeMap::new();
                                d.insert("by".to_string(), Json::Num(by as f64));
                                d.insert("candidate".to_string(), Json::Num(cand as f64));
                                Json::Obj(d)
                            })
                            .collect(),
                    ),
                );
                m.insert(
                    "frontier".to_string(),
                    Json::Arr(f.frontier.iter().map(|&i| Json::Num(i as f64)).collect()),
                );
                m.insert("network".to_string(), Json::Str(f.network.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("candidates".to_string(), Json::Arr(candidates));
        m.insert("fronts".to_string(), Json::Arr(fronts));
        Json::Obj(m)
    }
}

/// Expand a report into the 4-D candidate set: one candidate per (cell,
/// clock-curve point), in deterministic cell-then-curve order. A cell
/// swept without a `--clocks` axis contributes a single candidate at its
/// platform's native clock ([`crate::model::throughput::clock_point`],
/// which reproduces the cell's own prediction exactly).
pub fn clock_candidates(report: &SweepReport) -> Vec<ClockCandidate> {
    let mut out = Vec::new();
    for (i, cell) in report.cells.iter().enumerate() {
        let points: Vec<ClockPoint> = if cell.clock_curve().is_empty() {
            let d = cell.design();
            vec![throughput::clock_point(d.network(), d.allocs(), d.platform().clock_hz)]
        } else {
            cell.clock_curve().to_vec()
        };
        for pt in points {
            out.push(ClockCandidate {
                cell: i,
                clock_hz: pt.clock_hz,
                objectives: Objectives::at_clock(cell, pt),
            });
        }
    }
    out
}

/// The frequency-axis Pareto analysis: clock promoted to a fourth
/// objective next to {SRAM, FPS, DRAM/frame}.
///
/// Candidates are every (cell, clock) pair of [`clock_candidates`],
/// grouped per network like [`pareto`], and each group's exact
/// non-dominated set is taken under the 4-D rule of
/// [`Objectives::dominates`] (SRAM ↓, FPS ↑, DRAM ↓, clock ↓). Because a
/// fixed allocation's FPS scales linearly with its clock, two points of
/// the *same* cell never dominate each other — the interesting structure
/// is across cells: a candidate falls off the frontier exactly when some
/// other (platform, granularity, clock) choice is at least as good on
/// memory, traffic, *and* frequency while matching its throughput.
///
/// Verified against a brute-force O(n²) dominance scan including the
/// clock axis in `rust/tests/pareto.rs`.
///
/// # Examples
///
/// ```
/// use repro::sweep::{clock_candidates, pareto_clocks, SweepSpec};
///
/// let mut spec =
///     SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
/// spec.clocks_hz = SweepSpec::parse_clocks_csv("150,200").unwrap();
/// let report = spec.run();
/// let analysis = pareto_clocks(&report);
/// assert_eq!(analysis.candidates.len(), 4); // 2 cells x 2 clock points
/// let front = &analysis.fronts[0];
/// assert_eq!(front.frontier.len() + front.dominated.len(), 4);
/// assert_eq!(analysis.candidates.len(), clock_candidates(&report).len());
/// ```
pub fn pareto_clocks(report: &SweepReport) -> ClockParetoReport {
    let candidates = clock_candidates(report);
    let groups = group_by_network(
        candidates.iter().map(|c| report.cells[c.cell].network_name()),
    );
    let fronts = groups
        .into_iter()
        .map(|(name, idxs)| {
            let objs: Vec<Objectives> = idxs.iter().map(|&i| candidates[i].objectives).collect();
            let (front_local, dom_local) = non_dominated_split(&objs);
            ClockParetoFront {
                network: name,
                frontier: front_local.iter().map(|&a| idxs[a]).collect(),
                dominated: dom_local.iter().map(|&(a, b)| (idxs[a], idxs[b])).collect(),
            }
        })
        .collect();
    ClockParetoReport { candidates, fronts }
}

/// Validate the CLI's `--pareto-clocks` flag against the spec's clock
/// axis: the 4-D analysis without a `--clocks` axis would silently
/// degenerate to one native point per cell — reject the combination with
/// a message that names the missing flag instead.
///
/// # Examples
///
/// ```
/// use repro::sweep::validate_pareto_clocks;
///
/// assert!(validate_pareto_clocks(false, &[]).is_ok());
/// assert!(validate_pareto_clocks(true, &[150.0e6]).is_ok());
/// let err = validate_pareto_clocks(true, &[]).unwrap_err();
/// assert!(err.contains("--clocks"));
/// ```
pub fn validate_pareto_clocks(requested: bool, clocks_hz: &[f64]) -> Result<(), ReproError> {
    if requested && clocks_hz.is_empty() {
        return Err(ReproError::config(
            "--pareto-clocks: requires --clocks MHZ[,MHZ..] — the clock axis supplies the \
             frequency dimension of the 4-D frontier",
        ));
    }
    Ok(())
}

/// Documented process exit code of a *partially failed* `repro sweep` run:
/// at least one cell failed, the report (and any `--save-dir` artifacts)
/// covers only the survivors. Distinct from `2` — usage/configuration
/// errors, where nothing ran at all — so CI and scripts can tell a bad
/// invocation from a degraded run. Documented in `docs/robustness.md`.
pub const EXIT_PARTIAL_FAILURE: u8 = 3;

/// The `repro sweep` exit code for a completed (non-`--strict`) run:
/// `0` when every cell evaluated, [`EXIT_PARTIAL_FAILURE`] when the
/// report is partial. `--strict` runs never reach this policy — they
/// refuse partial results and fail hard on the first recorded failure.
///
/// # Examples
///
/// ```
/// use repro::sweep::{exit_code, SweepSpec, EXIT_PARTIAL_FAILURE};
///
/// let clean = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None)
///     .unwrap()
///     .run();
/// assert_eq!(exit_code(&clean), 0);
/// assert_eq!(EXIT_PARTIAL_FAILURE, 3);
/// ```
pub fn exit_code(report: &SweepReport) -> u8 {
    if report.failures.is_empty() {
        0
    } else {
        EXIT_PARTIAL_FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_covers_the_whole_catalog_matrix() {
        let spec = SweepSpec::default();
        assert_eq!(spec.nets.len(), 4);
        assert_eq!(spec.platforms.len(), 3);
        assert_eq!(spec.granularities, vec![Granularity::Fgpm]);
        assert_eq!(spec.cell_count(), 12);
        assert!(spec.frames.is_none());
        assert_eq!(spec.jobs, 1, "default is the serial path");
        assert!(spec.clocks_hz.is_empty(), "no clock curves unless asked");
        assert!(spec.cache_dir.is_none(), "no memoization unless asked");
    }

    #[test]
    fn cell_key_changes_with_every_component_and_only_those() {
        let spec = SweepSpec::default();
        let net = nets::shufflenet_v2();
        let base = spec.cell_key(&net, &Platform::zc706(), Granularity::Fgpm, None);
        // Same inputs -> byte-identical key (the cache's hit condition).
        assert_eq!(
            base.to_string(),
            spec.cell_key(&net, &Platform::zc706(), Granularity::Fgpm, None).to_string()
        );
        // Each component perturbs the key: platform budget, platform
        // clock, granularity, frames, sim options, clocks axis, network.
        let mut keys = vec![
            spec.cell_key(&net, &Platform::zc706().with_sram_bytes(1), Granularity::Fgpm, None),
            spec.cell_key(&net, &Platform::zc706().with_clock_hz(1.0e6), Granularity::Fgpm, None),
            spec.cell_key(&net, &Platform::zc706(), Granularity::Factorized, None),
            spec.cell_key(&net, &Platform::zc706(), Granularity::Fgpm, Some(3)),
            spec.cell_key(&nets::mobilenet_v2(), &Platform::zc706(), Granularity::Fgpm, None),
        ];
        let mut opts = spec.clone();
        opts.sim_options = Some(crate::sim::SimOptions::baseline());
        keys.push(opts.cell_key(&net, &Platform::zc706(), Granularity::Fgpm, None));
        let mut clocks = spec.clone();
        clocks.clocks_hz = vec![100.0e6];
        keys.push(clocks.cell_key(&net, &Platform::zc706(), Granularity::Fgpm, None));
        let mut fifo = spec.clone();
        fifo.fifo = true;
        keys.push(fifo.cell_key(&net, &Platform::zc706(), Granularity::Fgpm, None));
        // Structural drift invisible to name/layer-count/total-MACs: two
        // layers swapped must still change the key (the Debug digest).
        let mut swapped = nets::shufflenet_v2();
        swapped.layers.swap(0, 1);
        assert_eq!(swapped.layers.len(), net.layers.len());
        assert_eq!(swapped.total_macs(), net.total_macs());
        keys.push(spec.cell_key(&swapped, &Platform::zc706(), Granularity::Fgpm, None));
        for (i, k) in keys.iter().enumerate() {
            assert_ne!(k.to_string(), base.to_string(), "perturbation {i} did not change the key");
        }
    }

    #[test]
    fn warm_path_never_serves_a_zoo_cell_to_a_lookalike_custom_network() {
        let dir = std::env::temp_dir().join("repro_sweep_cache_lookalike");
        let _ = std::fs::remove_dir_all(&dir);
        // A *custom* network sharing the zoo name but structurally
        // different: the digest keys it separately, and even if an entry
        // is found, run()'s verbatim network check refuses to serve the
        // zoo-rebuilt cell — such sweeps stay correct but cold.
        let mut lookalike = nets::shufflenet_v2();
        lookalike.layers.swap(0, 1);
        let spec = SweepSpec {
            nets: vec![lookalike],
            platforms: vec![Platform::zc706()],
            cache_dir: Some(dir.clone()),
            ..SweepSpec::default()
        };
        let cold = spec.run();
        assert_eq!(cold.cache, Some(CacheStats { hits: 0, misses: 1, store_errors: 0 }));
        let rerun = spec.run();
        assert_eq!(
            rerun.cache,
            Some(CacheStats { hits: 0, misses: 1, store_errors: 0 }),
            "a lookalike custom network must never warm-hit"
        );
        assert_eq!(cold.to_json(), rerun.to_json());
        // The stock zoo network is keyed apart and stays unpoisoned.
        let stock = SweepSpec {
            nets: vec![nets::shufflenet_v2()],
            platforms: vec![Platform::zc706()],
            cache_dir: Some(dir.clone()),
            ..SweepSpec::default()
        };
        assert_eq!(stock.run().cache, Some(CacheStats { hits: 0, misses: 1, store_errors: 0 }));
        assert_eq!(stock.run().cache, Some(CacheStats { hits: 1, misses: 0, store_errors: 0 }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_run_reports_stats_and_identical_bytes() {
        let dir = std::env::temp_dir().join("repro_sweep_cache_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
        let cold_uncached = spec.run();
        assert!(cold_uncached.cache.is_none(), "uncached runs carry no stats");
        spec.cache_dir = Some(dir.clone());
        let cold = spec.run();
        assert_eq!(cold.cache, Some(CacheStats { hits: 0, misses: 2, store_errors: 0 }));
        let warm = spec.run();
        assert_eq!(warm.cache, Some(CacheStats { hits: 2, misses: 0, store_errors: 0 }));
        assert!((warm.cache.unwrap().hit_rate() - 1.0).abs() < 1e-12);
        // The cache changes *where* cells come from, never their bytes —
        // and the JSON document embeds no stats, so all three agree.
        assert_eq!(cold_uncached.to_json(), cold.to_json());
        assert_eq!(cold.to_json(), warm.to_json());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pareto_clocks_expands_curve_points_and_falls_back_to_native() {
        let mut spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
        spec.clocks_hz = SweepSpec::parse_clocks_csv("150,200").unwrap();
        let analysis = pareto_clocks(&spec.run());
        assert_eq!(analysis.candidates.len(), 4, "2 cells x 2 curve points");
        assert_eq!(analysis.fronts.len(), 1);
        // Two points of one cell never dominate each other (FPS and clock
        // move together), so each cell has at least one frontier point...
        let f = &analysis.fronts[0];
        assert_eq!(f.frontier.len() + f.dominated.len(), 4);
        // ...and a curve-less sweep still yields one native candidate per
        // cell, at the platform clock, matching the cell's own prediction.
        let plain = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None)
            .unwrap()
            .run();
        let native = clock_candidates(&plain);
        assert_eq!(native.len(), 2);
        for c in &native {
            let d = plain.cells[c.cell].design();
            assert_eq!(c.clock_hz, d.platform().clock_hz);
            assert_eq!(c.objectives.fps, d.predicted().fps);
            assert_eq!(c.objectives.clock_hz, Some(c.clock_hz));
        }
    }

    #[test]
    fn fifo_figures_appear_only_when_requested_and_bound_observed_peaks() {
        // A non-FIFO sweep's document must stay byte-identical to the
        // pre-FIFO format: no "fifo" key anywhere, and its cell keys
        // unchanged (warm caches built before --fifo keep hitting).
        let mut spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), Some("fgpm")).unwrap();
        spec.frames = Some(2);
        let plain = spec.run();
        assert!(!plain.to_json().contains("\"fifo\""));
        let plain_key = spec
            .cell_key(&nets::shufflenet_v2(), &Platform::zc706(), Granularity::Fgpm, Some(2))
            .to_string();
        assert!(!plain_key.contains("\"fifo\""));
        // The --fifo run carries modeled depths + observed peaks, every
        // peak within its modeled bound, and all *other* headline figures
        // byte-identical to the plain run's.
        spec.fifo = true;
        let report = spec.run();
        let cell = &report.cells[0];
        let fifo = cell.fifo().expect("--fifo sweeps attach figures");
        assert!(!fifo.report.is_empty(), "shufflenet_v2 has side FIFOs");
        let peaks = fifo.peaks.as_ref().expect("simulated cells observe peaks");
        assert_eq!(peaks.len(), fifo.report.fifos.len());
        for (f, &peak) in fifo.report.fifos.iter().zip(peaks) {
            assert!(peak <= f.depth_px, "{}: observed {peak} > modeled {}", f.name, f.depth_px);
        }
        let json = report.to_json();
        assert!(json.contains("\"fifo\"") && json.contains("\"peak_px\""));
        assert_eq!(
            Objectives::of(cell).fifo_bytes,
            Some(fifo.report.total_bytes()),
            "the optional Pareto axis is fed by the modeled total"
        );
        // Stripping the fifo member of every cell object recovers the
        // plain document exactly — the figures are purely additive.
        let stripped = {
            let mut c = cell.clone();
            c.fifo = None;
            SweepReport { cells: vec![c], failures: vec![], cache: None }.to_json()
        };
        assert_eq!(stripped, plain.to_json());
        // Model-only --fifo sweeps still carry depths, without peaks.
        spec.frames = None;
        let model_only = spec.run();
        let f = model_only.cells[0].fifo().unwrap();
        assert!(f.peaks.is_none() && !f.report.is_empty());
        assert!(model_only.to_json().contains("\"peak_px\":null"));
        // The JSON round-trips through the cache deserializer.
        let back = fifo_figures_from_json(&fifo_figures_to_json(fifo)).unwrap();
        assert_eq!(&back, fifo);
    }

    #[test]
    fn clock_axis_only_participates_when_both_sides_carry_it() {
        let lean = Objectives {
            sram_bytes: 10,
            fps: 5.0,
            dram_bytes: 10,
            clock_hz: None,
            fifo_bytes: None,
        };
        let rich = Objectives { clock_hz: Some(1.0), ..lean };
        // 3-D ties stay mutually non-dominating regardless of one side's
        // extra axis; with both axes present, the lower clock wins.
        assert!(!lean.dominates(&rich) && !rich.dominates(&lean));
        let slower = Objectives { clock_hz: Some(2.0), ..rich };
        assert!(rich.dominates(&slower) && !slower.dominates(&rich));
        // The FIFO axis obeys the same both-sides rule.
        let small = Objectives { fifo_bytes: Some(100), ..lean };
        assert!(!lean.dominates(&small) && !small.dominates(&lean));
        let big = Objectives { fifo_bytes: Some(200), ..lean };
        assert!(small.dominates(&big) && !big.dominates(&small));
    }

    #[test]
    fn clock_curve_cells_report_points_at_each_requested_clock() {
        let mut spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), Some("fgpm")).unwrap();
        spec.clocks_hz = SweepSpec::parse_clocks_csv("100,200").unwrap();
        let report = spec.run();
        let cell = &report.cells[0];
        let curve = cell.clock_curve();
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[0].clock_hz, 100.0e6);
        assert_eq!(curve[1].clock_hz, 200.0e6);
        // The 200 MHz curve point is the cell's own prediction (zc706
        // runs at 200 MHz), and rates scale linearly along the curve.
        assert_eq!(curve[1].fps, cell.design().predicted().fps);
        assert!((curve[1].fps / curve[0].fps - 2.0).abs() < 1e-9);
        // Curves appear in the JSON only when requested.
        assert!(report.to_json().contains("\"clock_curve\""));
        let plain = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), Some("fgpm"))
            .unwrap()
            .run();
        assert!(!plain.to_json().contains("\"clock_curve\""));
    }

    #[test]
    fn single_cell_sweep_matches_direct_design_build() {
        let spec =
            SweepSpec::from_csv(Some("shufflenet_v2"), Some("zcu102"), Some("fgpm")).unwrap();
        let report = spec.run();
        assert_eq!(report.cells.len(), 1);
        let cell = report.cell("shufflenet_v2", "zcu102", Granularity::Fgpm).unwrap();
        let direct = Design::builder(&nets::shufflenet_v2()).platform(Platform::zcu102()).build();
        assert_eq!(cell.design().to_json(), direct.to_json());
        assert_eq!(cell.artifact_file_name(), "snv2_zcu102_fgpm.design.json");
        assert!(cell.dsp_utilization() > 0.0 && cell.dsp_utilization() <= 1.0);
    }

    #[test]
    fn degenerate_platform_budget_is_an_isolated_cell_failure() {
        let spec = SweepSpec {
            nets: vec![nets::shufflenet_v2()],
            platforms: vec![Platform::zc706(), Platform::custom("broken", 0, 0)],
            ..SweepSpec::default()
        };
        let report = spec.run();
        assert_eq!(report.cells.len(), 1, "the healthy cell survives");
        assert_eq!(report.failures.len(), 1);
        let f = &report.failures[0];
        assert_eq!(f.index, 1, "matrix position of the broken cell");
        assert_eq!(f.label(), "shufflenet_v2/broken/fgpm");
        assert_eq!(f.error.kind(), "allocation");
        assert!(f.error.contains("degenerate budget"), "{}", f.error);
        // The surviving cell's bytes match a sweep that never saw the
        // broken platform at all.
        let healthy = SweepSpec {
            nets: vec![nets::shufflenet_v2()],
            platforms: vec![Platform::zc706()],
            ..SweepSpec::default()
        };
        assert_eq!(
            report.cells[0].to_json_value().to_string(),
            healthy.run().cells[0].to_json_value().to_string()
        );
        let json = report.to_json();
        assert!(json.contains("\"failures\""));
        assert!(json.contains("\"kind\":\"allocation\""));
        assert!(
            !healthy.run().to_json().contains("\"failures\""),
            "clean runs must not carry a failures key"
        );
        assert_eq!(exit_code(&report), EXIT_PARTIAL_FAILURE);
        assert_eq!(exit_code(&healthy.run()), 0);
    }

    #[test]
    fn failed_cells_are_skipped_by_save_designs() {
        let dir = std::env::temp_dir().join("repro_sweep_save_partial_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let spec = SweepSpec {
            nets: vec![nets::shufflenet_v2()],
            platforms: vec![Platform::custom("broken", 0, 0), Platform::edge()],
            ..SweepSpec::default()
        };
        let report = spec.run();
        assert_eq!(report.failures.len(), 1);
        let paths = report.save_designs(&dir).unwrap();
        assert_eq!(paths.len(), 1, "only the surviving cell has an artifact");
        assert!(paths[0].ends_with("snv2_edge_fgpm.design.json"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_axes_trim_whitespace_and_keep_order() {
        let spec = SweepSpec::from_csv(
            Some(" shufflenet_v2 , mobilenet_v2"),
            Some("edge, zc706"),
            Some("factorized , fgpm"),
        )
        .unwrap();
        assert_eq!(spec.nets[0].name, "shufflenet_v2");
        assert_eq!(spec.nets[1].name, "mobilenet_v2");
        assert_eq!(spec.platforms[0].name, "edge");
        assert_eq!(spec.platforms[1].name, "zc706");
        assert_eq!(spec.granularities, vec![Granularity::Factorized, Granularity::Fgpm]);
    }
}
