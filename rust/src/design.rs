//! The `Design`/`Platform` façade: one builder API for the paper's whole
//! methodology pipeline — network → balanced memory allocation (Alg 1) →
//! dynamic parallelism tuning (Alg 2) → streaming simulation → reporting.
//!
//! # The platform catalog
//!
//! A [`Platform`] names an FPGA resource budget — the "(network, FPGA)
//! pair" half of the paper's design-space methodology. The catalog ships
//! three named parts, enumerable via [`Platform::list`] and resolvable by
//! name via [`Platform::by_name`] / [`Platform::resolve`] (the CLI's
//! `--platform` / `--platforms` values):
//!
//! * [`Platform::zc706`] — the paper's evaluation part (855-DSP budget,
//!   1.80 MB SRAM, 200 MHz);
//! * [`Platform::zcu102`] — a ZCU102-class UltraScale+ budget (2520
//!   DSP48E2 at a 95% cap, ~4.7 MB SRAM, 300 MHz — the platform clock
//!   flows through [`crate::model::throughput::evaluate_at`], so
//!   predictions are clock-aware);
//! * [`Platform::edge`] — an edge-class part (220 DSPs, <1 MB SRAM,
//!   150 MHz) small enough that some networks' min-SRAM configurations
//!   do not fit, exercising the sweep report's `fits_sram` column.
//!
//! [`Platform::custom`] expresses anything else, refined by the `with_*`
//! setters. Whole {network} x {platform} x {granularity} matrices are
//! evaluated in one call by [`crate::sweep`], rendered via
//! [`crate::report::sweep_matrix`], and locked down by the golden
//! baselines in `rust/tests/baselines/`.
//!
//! # Designs
//!
//! A [`Design`] is the fully-resolved artifact for one (network, platform,
//! granularity) triple: the FRCE/WRCE boundary, per-layer parallelism,
//! predicted performance and memory figures, plus the simulator options it
//! should be cycle-simulated with.
//!
//! ```no_run
//! use repro::design::{Design, Platform};
//! use repro::alloc::Granularity;
//! use repro::sim::SimOptions;
//!
//! let net = repro::nets::mobilenet_v2();
//! let design = Design::builder(&net)
//!     .platform(Platform::zc706())
//!     .granularity(Granularity::Fgpm)
//!     .sim_options(SimOptions::optimized())
//!     .build();
//! println!("{:.1} FPS predicted", design.predicted().fps);
//! let stats = design.simulate(10).unwrap();
//! let json = design.to_json(); // persistable, diffable, reloadable
//! ```
//!
//! Design points serialize to stable one-line JSON (sorted keys) via
//! [`Design::to_json`] and reload via [`Design::from_json`], which re-runs
//! the deterministic pipeline and cross-checks the stored figures — so
//! saved design points double as regression baselines for benches and CI.

use std::collections::BTreeMap;

use crate::alloc::{
    balanced_memory_allocation, dynamic_parallelism_tuning, DesignPoint, Granularity, MemoryPlan,
    ParallelismPlan,
};
use crate::model::memory::{self, CePlan, FmScheme, MemoryModelCfg, SramReport};
use crate::model::throughput::{self, Performance};
use crate::nets::{self, Network};
use crate::sim::{self, PaddingMode, SimOptions, SimStats};
use crate::util::error::ReproError;
use crate::util::json::Json;
use crate::{edge, zc706, zcu102, CLOCK_HZ};

/// A named FPGA resource budget — the "(network, FPGA) pair" half of the
/// paper's design-space exploration, replacing loose `sram`/`dsp`
/// positional arguments and raw [`crate::zc706`] constants.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    /// Human-readable part name (`"zc706"`, or whatever `custom` is given).
    pub name: String,
    /// On-chip SRAM byte budget handed to Algorithm 1.
    pub sram_bytes: u64,
    /// DSP budget handed to Algorithm 2 (already below any utilization cap).
    pub dsp_budget: usize,
    /// Total DSP slices on the part (for utilization reporting only).
    pub dsp_total: usize,
    /// Total BRAM36K blocks on the part (for utilization reporting only).
    pub bram36k: usize,
    /// Design clock in Hz.
    pub clock_hz: f64,
}

impl Platform {
    /// The ZC706 (XC7Z045) budget used throughout the paper's evaluation:
    /// 1.80 MB SRAM (75% of 545 BRAM36K), 855 DSPs (95% of 900), 200 MHz.
    pub fn zc706() -> Platform {
        Platform {
            name: "zc706".to_string(),
            sram_bytes: zc706::SRAM_BYTES,
            dsp_budget: zc706::DSP_BUDGET,
            dsp_total: zc706::DSP,
            bram36k: zc706::BRAM36K,
            clock_hz: CLOCK_HZ,
        }
    }

    /// A ZCU102-class (XCZU9EG) budget — the catalog's mid-range part:
    /// ~4.7 MB SRAM, 2520 DSP48E2 capped at 95% (2394), 300 MHz.
    pub fn zcu102() -> Platform {
        Platform {
            name: "zcu102".to_string(),
            sram_bytes: zcu102::SRAM_BYTES,
            dsp_budget: zcu102::DSP_BUDGET,
            dsp_total: zcu102::DSP,
            bram36k: zcu102::BRAM36K,
            clock_hz: zcu102::CLOCK_HZ,
        }
    }

    /// An edge-class budget — the catalog's small part: 960 KB SRAM
    /// (<1 MB), 220 DSPs, 150 MHz.
    pub fn edge() -> Platform {
        Platform {
            name: "edge".to_string(),
            sram_bytes: edge::SRAM_BYTES,
            dsp_budget: edge::DSP_BUDGET,
            dsp_total: edge::DSP,
            bram36k: edge::BRAM36K,
            clock_hz: edge::CLOCK_HZ,
        }
    }

    /// Every named platform in the catalog, in canonical order — the axis
    /// a default [`crate::sweep::SweepSpec`] runs over.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::Platform;
    ///
    /// let names: Vec<String> = Platform::list().into_iter().map(|p| p.name).collect();
    /// assert_eq!(names, ["zc706", "zcu102", "edge"]);
    /// ```
    pub fn list() -> Vec<Platform> {
        vec![Platform::zc706(), Platform::zcu102(), Platform::edge()]
    }

    /// Comma-separated catalog names, for CLI error messages.
    pub fn known_names() -> String {
        Platform::list().iter().map(|p| p.name.as_str()).collect::<Vec<_>>().join(", ")
    }

    /// A custom budget. `dsp_total` defaults to `dsp_budget` and `bram36k`
    /// to the blocks covering `sram_bytes`; refine with the `with_*`
    /// setters when modelling a real part.
    pub fn custom(name: &str, sram_bytes: u64, dsp_budget: usize) -> Platform {
        Platform {
            name: name.to_string(),
            sram_bytes,
            dsp_budget,
            dsp_total: dsp_budget,
            bram36k: crate::model::brams_for(sram_bytes) as usize,
            clock_hz: CLOCK_HZ,
        }
    }

    /// Resolve a catalog platform by name, case-folded (the CLI's
    /// `--platform` / `--platforms` values).
    pub fn by_name(name: &str) -> Option<Platform> {
        let name = name.to_ascii_lowercase();
        Platform::list().into_iter().find(|p| p.name == name)
    }

    /// [`Platform::by_name`] with the uniform "known platforms: ..."
    /// error the CLI and sweep parser report for unknown names, instead
    /// of a silent `None`.
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::Platform;
    ///
    /// assert_eq!(Platform::resolve("ZC706").unwrap(), Platform::zc706());
    /// let err = Platform::resolve("vu9p").unwrap_err();
    /// assert!(err.contains("known platforms: zc706, zcu102, edge"));
    /// ```
    pub fn resolve(name: &str) -> Result<Platform, ReproError> {
        Platform::by_name(name).ok_or_else(|| {
            ReproError::config(format!(
                "unknown platform {name:?} (known platforms: {})",
                Platform::known_names()
            ))
        })
    }

    pub fn with_sram_bytes(mut self, bytes: u64) -> Platform {
        self.sram_bytes = bytes;
        self
    }

    pub fn with_dsp_budget(mut self, dsps: usize) -> Platform {
        self.dsp_budget = dsps;
        self
    }

    pub fn with_dsp_total(mut self, dsps: usize) -> Platform {
        self.dsp_total = dsps;
        self
    }

    pub fn with_bram36k(mut self, blocks: usize) -> Platform {
        self.bram36k = blocks;
        self
    }

    pub fn with_clock_hz(mut self, hz: f64) -> Platform {
        self.clock_hz = hz;
        self
    }

    pub(crate) fn to_json_value(&self) -> Json {
        obj(vec![
            ("bram36k", Json::Num(self.bram36k as f64)),
            ("clock_hz", Json::Num(self.clock_hz)),
            ("dsp_budget", Json::Num(self.dsp_budget as f64)),
            ("dsp_total", Json::Num(self.dsp_total as f64)),
            ("name", Json::Str(self.name.clone())),
            ("sram_bytes", Json::Num(self.sram_bytes as f64)),
        ])
    }

    pub(crate) fn from_json_value(j: &Json) -> Result<Platform, ReproError> {
        Ok(Platform {
            name: str_field(j, "name")?,
            sram_bytes: num_field(j, "sram_bytes")? as u64,
            dsp_budget: num_field(j, "dsp_budget")? as usize,
            dsp_total: num_field(j, "dsp_total")? as usize,
            bram36k: num_field(j, "bram36k")? as usize,
            clock_hz: num_field(j, "clock_hz")?,
        })
    }
}

/// Builder for [`Design`]; obtain via [`Design::builder`]. Defaults:
/// [`Platform::zc706`], [`Granularity::Fgpm`], [`SimOptions::optimized`].
#[derive(Debug, Clone)]
pub struct DesignBuilder {
    net: Network,
    platform: Platform,
    granularity: Granularity,
    sim_options: SimOptions,
}

impl DesignBuilder {
    pub fn platform(mut self, platform: Platform) -> DesignBuilder {
        self.platform = platform;
        self
    }

    pub fn granularity(mut self, granularity: Granularity) -> DesignBuilder {
        self.granularity = granularity;
        self
    }

    pub fn sim_options(mut self, opts: SimOptions) -> DesignBuilder {
        self.sim_options = opts;
        self
    }

    /// Run the complete resource-aware methodology: Algorithm 1 places the
    /// FRCE/WRCE boundary within the platform's SRAM budget, Algorithm 2
    /// tunes per-layer parallelism within its DSP budget, Eq 14 predicts
    /// performance, and the WRCE ping-pong weight buffers are re-costed
    /// with the chosen kernel parallelism (Alg 1 runs with `P_w = 1`).
    pub fn build(self) -> Design {
        let DesignBuilder { net, platform, granularity, sim_options } = self;
        let cfg = MemoryModelCfg::default();
        let memory = balanced_memory_allocation(&net, platform.sram_bytes, &cfg);
        let ce_plan = CePlan { boundary: memory.boundary };
        let parallelism = dynamic_parallelism_tuning(&net, &ce_plan, platform.dsp_budget, granularity);
        // Predictions are evaluated at the platform's clock, so custom
        // clocks give fps/gops/latency consistent with `simulate` results
        // reported via `stats.fps(platform.clock_hz)`.
        let performance = throughput::evaluate_at(&net, &parallelism.allocs, platform.clock_hz);
        // Per-layer delta of the WRCE weight ping-pong buffers: CE i holds
        // P_w(i) kernels, Alg 1 assumed one.
        let base = memory::sram_report(&net, &ce_plan, &cfg).total();
        let weight_buffer_delta: u64 = net
            .layers
            .iter()
            .zip(&parallelism.allocs)
            .enumerate()
            .filter(|(i, (l, _))| *i >= memory.boundary && l.kind.has_weights())
            .map(|(_, (l, a))| {
                let kernel_bytes = (l.k * l.k * l.in_ch / l.groups) as u64;
                2 * kernel_bytes * (a.pw as u64 - 1)
            })
            .sum();
        let sram_bytes = base + weight_buffer_delta;
        let dram_bytes = memory.dram_bytes;
        Design {
            net,
            platform,
            granularity,
            sim_options,
            ce_plan,
            memory,
            parallelism,
            performance,
            sram_bytes,
            dram_bytes,
        }
    }
}

/// A fully-resolved design point: the compiled artifact of one
/// (network, platform, granularity) triple, carrying everything the
/// paper's per-design evaluation needs.
#[derive(Debug, Clone)]
pub struct Design {
    net: Network,
    platform: Platform,
    granularity: Granularity,
    sim_options: SimOptions,
    ce_plan: CePlan,
    memory: MemoryPlan,
    parallelism: ParallelismPlan,
    performance: Performance,
    /// SRAM bytes after re-costing WRCE weight buffers with the tuned P_w.
    sram_bytes: u64,
    /// DRAM bytes per frame at the chosen boundary.
    dram_bytes: u64,
}

impl Design {
    /// Start building a design for `net` (the network is cloned: a design
    /// is a self-contained artifact).
    ///
    /// # Examples
    ///
    /// ```
    /// use repro::{Design, Platform};
    ///
    /// let net = repro::nets::shufflenet_v2();
    /// let design = Design::builder(&net).platform(Platform::zc706()).build();
    /// assert!(design.predicted().fps > 0.0);
    /// assert!(design.sram_bytes() <= Platform::zc706().sram_bytes);
    /// // Persist, reload, and the derivation cross-checks bit-for-bit.
    /// let reloaded = Design::from_json(&design.to_json()).unwrap();
    /// assert_eq!(reloaded.to_json(), design.to_json());
    /// ```
    pub fn builder(net: &Network) -> DesignBuilder {
        DesignBuilder {
            net: net.clone(),
            platform: Platform::zc706(),
            granularity: Granularity::Fgpm,
            sim_options: SimOptions::optimized(),
        }
    }

    /// The network this design was compiled for.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// AOT-artifact short name of the network (`"mbv2"`, ...), if it is a
    /// zoo network with compiled artifacts.
    pub fn network_short(&self) -> Option<&'static str> {
        nets::short_name(&self.net.name)
    }

    /// [`Design::network_short`] with the uniform error the runtime and
    /// coordinator façade entry points report for non-zoo networks.
    pub fn network_short_or_err(&self) -> Result<&'static str, String> {
        self.network_short()
            .ok_or_else(|| format!("no AOT artifacts for network {:?}", self.net.name))
    }

    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    pub fn granularity(&self) -> Granularity {
        self.granularity
    }

    pub fn sim_options(&self) -> &SimOptions {
        &self.sim_options
    }

    /// The FRCE/WRCE split chosen by Algorithm 1.
    pub fn ce_plan(&self) -> &CePlan {
        &self.ce_plan
    }

    /// Algorithm 1's full result (min-SRAM and budget boundaries).
    pub fn memory(&self) -> &MemoryPlan {
        &self.memory
    }

    /// Algorithm 2's full result (per-layer `P_w`/`P_f`, PE/DSP totals).
    pub fn parallelism(&self) -> &ParallelismPlan {
        &self.parallelism
    }

    /// Per-layer parallelism allocations.
    pub fn allocs(&self) -> &[crate::model::throughput::LayerAlloc] {
        &self.parallelism.allocs
    }

    /// Theoretical (Eq 14) performance of the design.
    pub fn predicted(&self) -> &Performance {
        &self.performance
    }

    /// SRAM bytes with the tuned kernel parallelism re-costed into the
    /// WRCE weight buffers.
    pub fn sram_bytes(&self) -> u64 {
        self.sram_bytes
    }

    /// Off-chip traffic per frame (Eq 13) at the chosen boundary.
    pub fn dram_bytes(&self) -> u64 {
        self.dram_bytes
    }

    /// Per-layer SRAM breakdown (Eq 12) under this design's CE plan.
    pub fn sram_report(&self) -> SramReport {
        memory::sram_report(&self.net, &self.ce_plan, &MemoryModelCfg::default())
    }

    /// Modeled side-FIFO depth bounds (SCB snapshots, tee streams) under
    /// this design's CE plan and FM scheme — the figures the simulator's
    /// observed peaks are differentially checked against.
    pub fn fifo_report(&self) -> crate::model::fifo::FifoReport {
        crate::model::fifo::fifo_depths(&self.net, &self.ce_plan, self.sim_options.scheme)
    }

    /// Cycle-simulate the design with its own [`SimOptions`]. Degenerate
    /// frame counts are [`ReproError::Config`]; a pipeline deadlock is
    /// [`ReproError::Simulation`].
    pub fn simulate(&self, frames: u64) -> Result<SimStats, ReproError> {
        self.simulate_with(&self.sim_options, frames)
    }

    /// Cycle-simulate with explicit options (ablations, Fig 17).
    pub fn simulate_with(&self, opts: &SimOptions, frames: u64) -> Result<SimStats, ReproError> {
        sim::simulate(&self.net, &self.parallelism.allocs, &self.ce_plan, opts, frames)
    }

    /// Convert into the legacy [`DesignPoint`] shape (the pre-façade API).
    pub fn to_design_point(&self) -> DesignPoint {
        DesignPoint {
            memory: self.memory.clone(),
            parallelism: self.parallelism.clone(),
            performance: self.performance.clone(),
            sram_bytes: self.sram_bytes,
            dram_bytes: self.dram_bytes,
        }
    }

    /// Full design artifact as stable one-line JSON (sorted keys): the
    /// build inputs plus every derived figure, so saved designs are
    /// diffable and [`Design::from_json`] can cross-check on reload.
    pub fn to_json(&self) -> String {
        let allocs = self
            .parallelism
            .allocs
            .iter()
            .map(|a| Json::Arr(vec![Json::Num(a.pw as f64), Json::Num(a.pf as f64)]))
            .collect();
        let p = &self.performance;
        let mut fields = vec![
            ("allocs", Json::Arr(allocs)),
            ("boundary", Json::Num(self.ce_plan.boundary as f64)),
            ("boundary_min_sram", Json::Num(self.memory.boundary_min_sram as f64)),
            ("dram_bytes", Json::Num(self.dram_bytes as f64)),
            ("dsps", Json::Num(self.parallelism.dsps as f64)),
            ("granularity", Json::Str(granularity_name(self.granularity).to_string())),
            ("network", Json::Str(self.net.name.clone())),
            (
                "performance",
                obj(vec![
                    ("bottleneck", Json::Num(p.bottleneck as f64)),
                    ("fps", Json::Num(p.fps)),
                    ("gops", Json::Num(p.gops)),
                    ("latency_ms", Json::Num(p.latency_ms)),
                    ("mac_efficiency", Json::Num(p.mac_efficiency)),
                    ("t_max", Json::Num(p.t_max as f64)),
                    ("total_dsps", Json::Num(p.total_dsps as f64)),
                    ("total_pes", Json::Num(p.total_pes as f64)),
                ]),
            ),
            ("pes", Json::Num(self.parallelism.pes as f64)),
            ("platform", self.platform.to_json_value()),
            ("sim_options", sim_options_to_json(&self.sim_options)),
            ("sram_bytes", Json::Num(self.sram_bytes as f64)),
            ("sram_bytes_alg1", Json::Num(self.memory.sram_bytes as f64)),
            ("version", Json::Num(1.0)),
        ];
        // Networks the reload path cannot rebuild by name (anything that is
        // not byte-for-byte a zoo member — `--net-file` loads, programmatic
        // IR graphs) embed their full lowered definition, so
        // `from_json`/`from_json_unchecked` stay self-contained. Zoo
        // artifacts stay byte-identical to the pre-IR format.
        let is_zoo = nets::by_name(&self.net.name)
            .is_some_and(|z| format!("{z:?}") == format!("{:?}", self.net));
        if !is_zoo {
            fields.push(("network_def", nets::network_to_json_value(&self.net)));
        }
        obj(fields).to_string()
    }

    /// One-line machine-readable summary (stable sorted keys) — the
    /// `repro allocate --json` output consumed by BENCH trajectories.
    pub fn summary_json(&self) -> String {
        obj(vec![
            ("boundary", Json::Num(self.ce_plan.boundary as f64)),
            ("dram_bytes", Json::Num(self.dram_bytes as f64)),
            ("dsps", Json::Num(self.parallelism.dsps as f64)),
            ("fps", Json::Num(self.performance.fps)),
            ("gops", Json::Num(self.performance.gops)),
            ("granularity", Json::Str(granularity_name(self.granularity).to_string())),
            ("mac_efficiency", Json::Num(self.performance.mac_efficiency)),
            ("network", Json::Str(self.net.name.clone())),
            ("pes", Json::Num(self.parallelism.pes as f64)),
            ("platform", Json::Str(self.platform.name.clone())),
            ("sram_bytes", Json::Num(self.sram_bytes as f64)),
            ("t_max", Json::Num(self.performance.t_max as f64)),
        ])
        .to_string()
    }

    /// Reload a design saved by [`Design::to_json`]: re-runs the
    /// deterministic pipeline from the stored build inputs (network name,
    /// platform, granularity, sim options) and cross-checks the stored
    /// derived figures, so stale artifacts fail loudly instead of silently
    /// drifting from the current algorithms.
    pub fn from_json(text: &str) -> Result<Design, ReproError> {
        let j = Json::parse(text).map_err(|e| ReproError::config(e.to_string()))?;
        if let Some(v) = j.get("version").and_then(Json::as_f64) {
            if v != 1.0 {
                return Err(ReproError::config(format!(
                    "design json: unsupported version {v} (this reader supports 1)"
                )));
            }
        }
        let net = network_from_design_json(&j)?;
        let platform = Platform::from_json_value(
            j.get("platform")
                .ok_or_else(|| ReproError::config("design json: missing \"platform\""))?,
        )?;
        let granularity = parse_granularity(&str_field(&j, "granularity")?)?;
        let sim_options = sim_options_from_json(
            j.get("sim_options")
                .ok_or_else(|| ReproError::config("design json: missing \"sim_options\""))?,
        )?;
        let d = Design::builder(&net)
            .platform(platform)
            .granularity(granularity)
            .sim_options(sim_options)
            .build();
        // Cross-check stored derived figures (when present) against the
        // recomputed pipeline.
        let checks: [(&str, f64); 5] = [
            ("boundary", d.ce_plan.boundary as f64),
            ("pes", d.parallelism.pes as f64),
            ("dsps", d.parallelism.dsps as f64),
            ("sram_bytes", d.sram_bytes as f64),
            ("dram_bytes", d.dram_bytes as f64),
        ];
        for (key, recomputed) in checks {
            if let Some(stored) = j.get(key).and_then(Json::as_f64) {
                if stored != recomputed {
                    return Err(ReproError::config(format!(
                        "design json: stored {key}={stored} disagrees with recomputed {recomputed} \
                         (stale artifact? regenerate with `repro allocate --save`)"
                    )));
                }
            }
        }
        if let Some(t) = j.get("performance").and_then(|p| p.get("t_max")).and_then(Json::as_f64) {
            if t != d.performance.t_max as f64 {
                return Err(ReproError::config(format!(
                    "design json: stored t_max={t} disagrees with recomputed {}",
                    d.performance.t_max
                )));
            }
        }
        Ok(d)
    }

    /// Reconstruct a design **verbatim** from a full [`Design::to_json`]
    /// artifact without re-running Algorithm 1, Algorithm 2, or Eq 14 —
    /// every derived figure is taken from the stored document as-is.
    ///
    /// This is the warm path of the sweep cell cache
    /// ([`crate::sweep::cache`]): a cache hit must cost zero Alg 1/Alg 2
    /// re-derivations (asserted via [`crate::alloc::derivations`] in
    /// `rust/tests/differential.rs`), which rules out [`Design::from_json`]
    /// — its cross-check *is* a re-derivation. Integrity is therefore the
    /// caller's job: the cache guards entries with a content key and the
    /// differential suite pins warm-vs-cold byte identity. Anywhere trust
    /// hasn't been established (user-supplied `--load` files, committed
    /// baselines), keep using [`Design::from_json`].
    ///
    /// The document must carry the complete figure set `to_json` writes
    /// (an inputs-only seed is rejected), and
    /// `Design::from_json_unchecked(d.to_json())?.to_json()` is
    /// byte-identical to `d.to_json()`.
    pub fn from_json_unchecked(text: &str) -> Result<Design, ReproError> {
        let j = Json::parse(text).map_err(|e| ReproError::config(e.to_string()))?;
        match j.field_f64("version") {
            Some(v) if v == 1.0 => {}
            Some(v) => {
                return Err(ReproError::config(format!(
                    "design json: unsupported version {v} (this reader supports 1)"
                )))
            }
            None => return Err(ReproError::config("design json: missing number \"version\"")),
        }
        let net = network_from_design_json(&j)?;
        let platform = Platform::from_json_value(
            j.get("platform")
                .ok_or_else(|| ReproError::config("design json: missing \"platform\""))?,
        )?;
        let granularity = parse_granularity(&str_field(&j, "granularity")?)?;
        let sim_options = sim_options_from_json(
            j.get("sim_options")
                .ok_or_else(|| ReproError::config("design json: missing \"sim_options\""))?,
        )?;
        let allocs = j
            .get("allocs")
            .and_then(Json::as_arr)
            .ok_or_else(|| ReproError::config("design json: missing array \"allocs\""))?
            .iter()
            .map(|a| match a.as_arr() {
                Some([pw, pf]) => match (pw.as_f64(), pf.as_f64()) {
                    (Some(pw), Some(pf)) => Ok(crate::model::throughput::LayerAlloc {
                        pw: pw as usize,
                        pf: pf as usize,
                    }),
                    _ => Err(ReproError::config("design json: non-numeric alloc pair")),
                },
                _ => Err(ReproError::config("design json: alloc entries must be [pw, pf] pairs")),
            })
            .collect::<Result<Vec<_>, _>>()?;
        if allocs.len() != net.layers.len() {
            return Err(ReproError::config(format!(
                "design json: {} allocs for a {}-layer network",
                allocs.len(),
                net.layers.len()
            )));
        }
        let num = |key: &str| {
            j.field_f64(key)
                .ok_or_else(|| ReproError::config(format!("design json: missing number {key:?}")))
        };
        let p = j
            .get("performance")
            .ok_or_else(|| ReproError::config("design json: missing \"performance\""))?;
        let pnum = |key: &str| {
            p.field_f64(key).ok_or_else(|| {
                ReproError::config(format!("design json: missing number performance/{key:?}"))
            })
        };
        let performance = Performance {
            t_max: pnum("t_max")? as u64,
            bottleneck: pnum("bottleneck")? as usize,
            fps: pnum("fps")?,
            gops: pnum("gops")?,
            total_pes: pnum("total_pes")? as usize,
            total_dsps: pnum("total_dsps")? as usize,
            mac_efficiency: pnum("mac_efficiency")?,
            latency_ms: pnum("latency_ms")?,
        };
        let boundary = num("boundary")? as usize;
        let memory = MemoryPlan {
            boundary_min_sram: num("boundary_min_sram")? as usize,
            boundary,
            sram_bytes: num("sram_bytes_alg1")? as u64,
            dram_bytes: num("dram_bytes")? as u64,
        };
        let parallelism = ParallelismPlan {
            allocs,
            granularity,
            dsps: num("dsps")? as usize,
            pes: num("pes")? as usize,
        };
        Ok(Design {
            net,
            platform,
            granularity,
            sim_options,
            ce_plan: CePlan { boundary },
            memory,
            parallelism,
            performance,
            sram_bytes: num("sram_bytes")? as u64,
            dram_bytes: num("dram_bytes")? as u64,
        })
    }
}

/// Resolve the network a design artifact was built for: an embedded
/// `network_def` (non-zoo artifacts — `--net-file` loads) takes
/// precedence and is validated + cross-checked against the artifact's
/// `network` name; otherwise the name must resolve in the zoo.
fn network_from_design_json(j: &Json) -> Result<Network, ReproError> {
    let net_name = str_field(j, "network")?;
    if let Some(def) = j.get("network_def") {
        let net = nets::network_from_json_value(def)
            .map_err(|e| ReproError::config(format!("design json: {e}")))?;
        if net.name != net_name {
            return Err(ReproError::config(format!(
                "design json: embedded network_def describes {:?} but the artifact names \
                 {net_name:?}",
                net.name
            )));
        }
        return Ok(net);
    }
    nets::by_name(&net_name).ok_or_else(|| {
        ReproError::config(format!(
            "design json: network {net_name:?} is not in the zoo and the artifact embeds no \
             network_def"
        ))
    })
}

/// Stable wire name of a [`Granularity`].
pub fn granularity_name(g: Granularity) -> &'static str {
    match g {
        Granularity::Fgpm => "fgpm",
        Granularity::Factorized => "factorized",
    }
}

/// Parse the wire name produced by [`granularity_name`].
pub fn parse_granularity(s: &str) -> Result<Granularity, ReproError> {
    match s {
        "fgpm" => Ok(Granularity::Fgpm),
        "factorized" => Ok(Granularity::Factorized),
        _ => Err(ReproError::config(format!(
            "unknown granularity {s:?} (expected \"fgpm\" or \"factorized\")"
        ))),
    }
}

pub(crate) fn sim_options_to_json(o: &SimOptions) -> Json {
    let padding = match o.padding {
        PaddingMode::DirectInsert => "direct_insert",
        PaddingMode::AddressGenerated => "address_generated",
    };
    let scheme = match o.scheme {
        FmScheme::FullyReusedFm => "fully_reused_fm",
        FmScheme::LineBased => "line_based",
    };
    let mut fields = vec![
        ("padding", Json::Str(padding.to_string())),
        ("scheme", Json::Str(scheme.to_string())),
        ("stride_extra_line", Json::Bool(o.stride_extra_line)),
    ];
    // The observability/diagnosis knobs serialize only at their non-default
    // values, so every pre-existing artifact and sweep cache key stays
    // byte-identical when they are off.
    if o.track_fifo {
        fields.push(("track_fifo", Json::Bool(true)));
    }
    if !o.cycle_skip {
        fields.push(("cycle_skip", Json::Bool(false)));
    }
    if !o.event_driven {
        fields.push(("event_driven", Json::Bool(false)));
    }
    obj(fields)
}

fn sim_options_from_json(j: &Json) -> Result<SimOptions, ReproError> {
    let padding = match str_field(j, "padding")?.as_str() {
        "direct_insert" => PaddingMode::DirectInsert,
        "address_generated" => PaddingMode::AddressGenerated,
        other => return Err(ReproError::config(format!("unknown padding mode {other:?}"))),
    };
    let scheme = match str_field(j, "scheme")?.as_str() {
        "fully_reused_fm" => FmScheme::FullyReusedFm,
        "line_based" => FmScheme::LineBased,
        other => return Err(ReproError::config(format!("unknown FM scheme {other:?}"))),
    };
    let stride_extra_line = match j.get("stride_extra_line") {
        Some(Json::Bool(b)) => *b,
        _ => return Err(ReproError::config("design json: missing bool \"stride_extra_line\"")),
    };
    // Optional knobs (absent in artifacts written before they existed, and
    // in any artifact using the defaults).
    let track_fifo = matches!(j.get("track_fifo"), Some(Json::Bool(true)));
    let cycle_skip = !matches!(j.get("cycle_skip"), Some(Json::Bool(false)));
    let event_driven = !matches!(j.get("event_driven"), Some(Json::Bool(false)));
    Ok(SimOptions { padding, scheme, stride_extra_line, track_fifo, cycle_skip, event_driven })
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in entries {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn num_field(j: &Json, key: &str) -> Result<f64, ReproError> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| ReproError::config(format!("design json: missing number {key:?}")))
}

fn str_field(j: &Json, key: &str) -> Result<String, ReproError> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| ReproError::config(format!("design json: missing string {key:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_are_zc706_fgpm_optimized() {
        let net = nets::mobilenet_v2();
        let d = Design::builder(&net).build();
        assert_eq!(d.platform().name, "zc706");
        assert_eq!(d.platform().sram_bytes, zc706::SRAM_BYTES);
        assert_eq!(d.granularity(), Granularity::Fgpm);
        assert_eq!(*d.sim_options(), SimOptions::optimized());
        assert_eq!(d.ce_plan().boundary, d.memory().boundary);
        assert_eq!(d.allocs().len(), net.layers.len());
        assert!(d.predicted().fps > 0.0);
    }

    #[test]
    fn platform_by_name_and_custom() {
        assert_eq!(Platform::by_name("zc706").unwrap(), Platform::zc706());
        assert_eq!(Platform::by_name("ZC706").unwrap(), Platform::zc706());
        assert_eq!(Platform::by_name("zcu102").unwrap(), Platform::zcu102());
        assert_eq!(Platform::by_name("EDGE").unwrap(), Platform::edge());
        assert!(Platform::by_name("vu9p").is_none());
        let p = Platform::custom("pico", 900 * 1024, 220).with_clock_hz(150.0e6);
        assert_eq!(p.dsp_total, 220);
        assert_eq!(p.clock_hz, 150.0e6);
    }

    #[test]
    fn platform_catalog_lists_and_resolves() {
        let names: Vec<&str> = Platform::list().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["zc706", "zcu102", "edge"]);
        for p in Platform::list() {
            assert_eq!(Platform::by_name(&p.name).unwrap(), p);
            assert!(p.dsp_budget <= p.dsp_total, "{}", p.name);
            assert!(p.sram_bytes > 0 && p.clock_hz > 0.0, "{}", p.name);
        }
        assert_eq!(Platform::zcu102().clock_hz, 300.0e6);
        assert!(Platform::edge().sram_bytes < 1 << 20, "edge must stay under 1 MB");
        let err = Platform::resolve("vu9p").unwrap_err();
        assert!(err.contains("known platforms: zc706, zcu102, edge"), "{err}");
    }

    #[test]
    fn summary_json_is_one_sorted_line() {
        let net = nets::shufflenet_v2();
        let d = Design::builder(&net).build();
        let s = d.summary_json();
        assert!(!s.contains('\n'));
        assert!(s.starts_with("{\"boundary\":"));
        // Parse back and spot-check.
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.str_field("network"), "shufflenet_v2");
        assert_eq!(j.str_field("platform"), "zc706");
        assert_eq!(j.usize_field("boundary"), d.ce_plan().boundary);
    }

    #[test]
    fn sim_option_knobs_serialize_only_when_non_default() {
        // Default artifacts carry no knob keys (byte-compat with every
        // pre-existing artifact and cache key); non-default values round-trip.
        let d = Design::builder(&nets::mobilenet_v2()).build();
        let text = d.to_json();
        assert!(!text.contains("track_fifo") && !text.contains("cycle_skip"), "{text}");
        assert!(!text.contains("event_driven"), "{text}");
        let opts = SimOptions {
            track_fifo: true,
            cycle_skip: false,
            event_driven: false,
            ..SimOptions::optimized()
        };
        let d2 = Design::builder(&nets::mobilenet_v2()).sim_options(opts).build();
        let text2 = d2.to_json();
        assert!(text2.contains("\"track_fifo\":true"), "{text2}");
        assert!(text2.contains("\"cycle_skip\":false"), "{text2}");
        assert!(text2.contains("\"event_driven\":false"), "{text2}");
        let r = Design::from_json(&text2).unwrap();
        assert_eq!(*r.sim_options(), opts);
        assert_eq!(r.to_json(), text2);
    }

    #[test]
    fn from_json_rejects_tampered_figures() {
        let net = nets::mobilenet_v2();
        let d = Design::builder(&net).build();
        let good = d.to_json();
        assert!(Design::from_json(&good).is_ok());
        let bad = good.replace(
            &format!("\"pes\":{}", d.parallelism().pes),
            &format!("\"pes\":{}", d.parallelism().pes + 1),
        );
        assert_ne!(good, bad, "replacement should have applied");
        assert!(Design::from_json(&bad).is_err());
    }

    #[test]
    fn from_json_rejects_unknown_network() {
        let err = Design::from_json(r#"{"network":"resnet50"}"#).unwrap_err();
        assert!(err.contains("not in the zoo"), "{err}");
    }

    #[test]
    fn from_json_unchecked_is_a_byte_identical_fixed_point() {
        // The trusted reload restores every field verbatim: serialize ->
        // unchecked reload -> serialize is byte-identical, for a catalog
        // platform and for a custom one with a non-catalog clock.
        for d in [
            Design::builder(&nets::mobilenet_v2()).build(),
            Design::builder(&nets::shufflenet_v1())
                .platform(Platform::custom("oddball", 1_234_567, 321).with_clock_hz(173.5e6))
                .granularity(Granularity::Factorized)
                .build(),
        ] {
            let text = d.to_json();
            let r = Design::from_json_unchecked(&text).expect("unchecked reload");
            assert_eq!(r.to_json(), text, "not a fixed point");
            // Zero Alg 1/Alg 2 re-derivation is asserted process-wide in
            // rust/tests/differential.rs (its own binary, serialized);
            // counter checks here would race sibling unit tests.
        }
    }

    #[test]
    fn non_zoo_networks_embed_their_definition_and_reload() {
        // A renamed zoo net is structurally valid but unknown to by_name —
        // exactly the shape `--net-file` loads produce.
        let mut net = nets::mobilenet_v2();
        net.name = "mobilenet_v2_custom".to_string();
        let d = Design::builder(&net).build();
        let text = d.to_json();
        assert!(text.contains("\"network_def\":"), "non-zoo artifact must embed its network");
        // Zoo artifacts stay byte-identical to the pre-IR format.
        let zoo_text = Design::builder(&nets::mobilenet_v2()).build().to_json();
        assert!(!zoo_text.contains("network_def"));
        // Both readers rebuild the embedded network, and reload is a fixed
        // point for each.
        let checked = Design::from_json(&text).expect("checked reload");
        assert_eq!(checked.network().name, "mobilenet_v2_custom");
        assert_eq!(checked.to_json(), text);
        let unchecked = Design::from_json_unchecked(&text).expect("unchecked reload");
        assert_eq!(unchecked.to_json(), text, "not a fixed point");
        // A name/definition mismatch fails loudly.
        let bad =
            text.replace("\"network\":\"mobilenet_v2_custom\"", "\"network\":\"mobilenet_v2\"");
        assert_ne!(bad, text, "replacement should have applied");
        let err = Design::from_json(&bad).unwrap_err();
        assert!(err.contains("network_def"), "{err}");
    }

    #[test]
    fn from_json_unchecked_rejects_inputs_only_seeds() {
        // A committed inputs-only baseline seed lacks the derived figures;
        // the trusted reader must refuse it instead of fabricating zeros.
        let net = nets::shufflenet_v2();
        let d = Design::builder(&net).build();
        let j = Json::parse(&d.to_json()).unwrap();
        let seed = obj(vec![
            ("granularity", j.get("granularity").unwrap().clone()),
            ("network", j.get("network").unwrap().clone()),
            ("platform", j.get("platform").unwrap().clone()),
            ("sim_options", j.get("sim_options").unwrap().clone()),
            ("version", Json::Num(1.0)),
        ])
        .to_string();
        assert!(Design::from_json(&seed).is_ok(), "the checked reader accepts seeds");
        let err = Design::from_json_unchecked(&seed).unwrap_err();
        assert!(err.contains("allocs"), "{err}");
    }
}
