//! MobileNetV2 (1.0x, 224x224) — Sandler et al. 2018.
//!
//! Stem STC + 17 inverted-residual bottlenecks (expansion t, output c,
//! repeats n, stride s) + head PWC + avgpool + FC. Stride-1 repeats carry
//! an identity SCB over the (expand, dwc, project) main branch — exactly
//! the pw/dw/pw SCB the paper's Fig 6 timing analysis uses.

use crate::ir::{lower, Graph, GraphBuilder};

use super::Network;

/// Inverted-residual settings (t, c, n, s) from Table 2 of the paper.
pub const BOTTLENECKS: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// The layer-graph description (the zoo's source of truth; lowered below).
pub(crate) fn graph() -> Graph {
    let mut b = GraphBuilder::new("mobilenet_v2", 224, 3);

    b.block("stem");
    b.conv(32, 3, 2, 1); // 224 -> 112

    let mut stage = 0;
    for (t, c, n, s) in BOTTLENECKS {
        stage += 1;
        for rep in 0..n {
            b.block(&format!("bneck{}_{}", stage, rep + 1));
            let stride = if rep == 0 { s } else { 1 };
            let in_ch = b.cur_ch();
            let residual = stride == 1 && in_ch == c;
            // The residual shortcut reads the unit input node.
            let unit_input = b.cursor().expect("stem precedes every bottleneck");
            if t != 1 {
                b.pwconv(in_ch * t);
            }
            b.dwconv(3, stride, 1);
            b.pwconv(c);
            if residual {
                b.add_from(unit_input);
            }
        }
    }

    b.block("head");
    b.pwconv(1280);
    b.global_avgpool();
    b.fc(1000);
    b.finish()
}

pub fn mobilenet_v2() -> Network {
    lower(&graph()).expect("zoo graph lowers")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::LayerKind;

    #[test]
    fn structure() {
        let net = mobilenet_v2();
        assert_eq!(net.layers.iter().filter(|l| l.kind == LayerKind::Dwc).count(), 17);
        // 10 stride-1 repeats carry residual SCBs: (n-1) per stage with n>1
        // and c unchanged: 1+2+3+2+2 = 10.
        assert_eq!(net.scbs.len(), 10);
        let last_pwc = net.layers.iter().filter(|l| l.kind == LayerKind::Pwc).last().unwrap();
        assert_eq!((last_pwc.out_size, last_pwc.out_ch), (7, 1280));
        // 7x7x320 -> 1280 head: input FM 15.7KB, weights 409.6KB (the "~26x"
        // observation of Fig 3a).
        assert_eq!(last_pwc.weight_bytes(), 320 * 1280);
    }

    #[test]
    fn scb_branches_are_pw_dw_pw() {
        let net = mobilenet_v2();
        for scb in &net.scbs {
            let kinds: Vec<_> = net.layers[scb.from_layer..scb.join_layer].iter().map(|l| l.kind).collect();
            assert_eq!(kinds, vec![LayerKind::Pwc, LayerKind::Dwc, LayerKind::Pwc]);
        }
    }
}
