//! Network zoo: layer-graph descriptions of the four LWCNNs the paper
//! evaluates (MobileNetV1/V2, ShuffleNetV1/V2, all at 224x224 input,
//! 8-bit weights/activations).
//!
//! These descriptions are the substrate every other subsystem consumes:
//! the analytical performance model (Eqs 1-14), the allocation algorithms
//! (Alg 1/2), the cycle-level streaming simulator, and the AOT stage plan.
//!
//! A [`Network`] is a linear streaming order of [`Layer`]s (one CE per
//! layer, exactly as the paper's multi-CE architecture) plus a list of
//! skip-connection blocks ([`Scb`]) expressed as (branch point -> join
//! point) edges over layer indices.

mod mobilenet_v1;
mod mobilenet_v2;
mod shufflenet_v1;
mod shufflenet_v2;

pub use mobilenet_v1::mobilenet_v1;
pub use mobilenet_v2::mobilenet_v2;
pub use shufflenet_v1::shufflenet_v1;
pub use shufflenet_v2::shufflenet_v2;



/// The kind of computation a layer (and therefore its dedicated CE) performs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution, kernel `k`x`k` (paper: STC).
    Stc,
    /// Depthwise convolution (paper: DWC). `in_ch == out_ch`, no cross-channel
    /// reduction.
    Dwc,
    /// Pointwise (1x1) convolution (paper: PWC). `groups > 1` models the
    /// grouped 1x1 convolutions of ShuffleNetV1.
    Pwc,
    /// Element-wise shortcut addition closing an SCB (paper counts these as
    /// half-MACs, Eq 3).
    Add,
    /// Max pooling (LUT-based on the FPGA: consumes no DSPs).
    MaxPool,
    /// Global average pooling.
    AvgPool,
    /// Fully connected layer (executed as a 1x1 PWC on a 1x1 FM; the paper
    /// excludes FC weights from the on-chip memory comparison of Fig 13).
    Fc,
    /// Channel shuffle (ShuffleNet): pure data movement, no MACs, no DSPs.
    Shuffle,
    /// Channel split (ShuffleNetV2 stride-1 unit): routes half the channels
    /// to the shortcut branch. Pure data movement.
    Split,
    /// Channel concatenation (ShuffleNet unit join). Pure data movement.
    Concat,
}

impl LayerKind {
    /// Layers that perform multiply-accumulates on the PE array.
    pub fn is_mac(self) -> bool {
        matches!(self, LayerKind::Stc | LayerKind::Dwc | LayerKind::Pwc | LayerKind::Fc)
    }

    /// Layers that hold trainable weights.
    pub fn has_weights(self) -> bool {
        matches!(self, LayerKind::Stc | LayerKind::Dwc | LayerKind::Pwc | LayerKind::Fc)
    }

    /// Whether the layer's window spans multiple spatial positions and thus
    /// needs a line buffer in an FRCE (PWC/FC/Add do not: "the line buffer is
    /// not required in PWC layers since they do not involve inter-pixel
    /// correlation operations", Sec. V-A).
    pub fn needs_line_buffer(self) -> bool {
        matches!(self, LayerKind::Stc | LayerKind::Dwc | LayerKind::MaxPool | LayerKind::AvgPool)
    }
}

/// Where a layer's input stream comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerSrc {
    /// The output of the previous layer in streaming order (the common case).
    Prev,
    /// A tee of the *input* of layer `i` — used for the second branch of
    /// two-branch ShuffleNet units, whose both branches consume the unit
    /// input. The teed stream is buffered exactly like an SCB shortcut.
    Tee(usize),
}

/// One layer of the streaming order == one CE of the accelerator.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Human-readable name, unique within the network.
    pub name: String,
    pub kind: LayerKind,
    /// Input stream source (almost always [`LayerSrc::Prev`]).
    pub src: LayerSrc,
    /// Input channels (M in the paper's notation).
    pub in_ch: usize,
    /// Output channels (N).
    pub out_ch: usize,
    /// Input spatial size (square FMs: `in_size` x `in_size`).
    pub in_size: usize,
    /// Output spatial size.
    pub out_size: usize,
    /// Kernel size K (1 for PWC/Add/Shuffle/...).
    pub k: usize,
    pub stride: usize,
    /// Symmetric padding on all sides.
    pub pad: usize,
    /// Grouped convolution group count (ShuffleNetV1 grouped PWC); 1 otherwise.
    pub groups: usize,
    /// Index of the block this layer belongs to (Fig 3 aggregates per block;
    /// the AOT plan compiles one HLO artifact per block).
    pub block: usize,
    /// Name of the block, e.g. `"bottleneck3_1"`.
    pub block_name: String,
}

impl Layer {
    /// Spatial output positions.
    pub fn out_positions(&self) -> usize {
        self.out_size * self.out_size
    }

    /// Number of MAC operations of this layer (Eqs 1-3).
    ///
    /// * STC: `F_out^2 * K^2 * M * N` (Eq 1)
    /// * DWC: `F_out^2 * K^2 * M`
    /// * PWC (grouped): `F_out^2 * M/g * N`
    /// * Add: `M * F^2 / 2` — additions count as half MACs (Eq 3)
    /// * pooling/shuffle/split/concat: 0 (no PE array involvement)
    pub fn macs(&self) -> u64 {
        let f2 = self.out_positions() as u64;
        let (m, n, k2) = (self.in_ch as u64, self.out_ch as u64, (self.k * self.k) as u64);
        match self.kind {
            LayerKind::Stc => f2 * k2 * m * n,
            LayerKind::Dwc => f2 * k2 * m,
            LayerKind::Pwc | LayerKind::Fc => f2 * m / self.groups as u64 * n,
            LayerKind::Add => m * f2 / 2,
            _ => 0,
        }
    }

    /// Weight parameter count (bytes at 8-bit precision).
    pub fn weight_bytes(&self) -> u64 {
        let (m, n, k2) = (self.in_ch as u64, self.out_ch as u64, (self.k * self.k) as u64);
        match self.kind {
            LayerKind::Stc => k2 * m * n,
            LayerKind::Dwc => k2 * m,
            LayerKind::Pwc | LayerKind::Fc => k2 * m / self.groups as u64 * n,
            _ => 0,
        }
    }

    /// Input FM bytes (8-bit).
    pub fn in_fm_bytes(&self) -> u64 {
        (self.in_size * self.in_size * self.in_ch) as u64
    }

    /// Output FM bytes (8-bit).
    pub fn out_fm_bytes(&self) -> u64 {
        (self.out_size * self.out_size * self.out_ch) as u64
    }

    /// The reduction depth of one output activation: MACs a single PE chain
    /// must accumulate (K^2*M for STC, K^2 for DWC, M/g for PWC).
    pub fn reduction_depth(&self) -> u64 {
        let k2 = (self.k * self.k) as u64;
        match self.kind {
            LayerKind::Stc => k2 * self.in_ch as u64,
            LayerKind::Dwc => k2,
            LayerKind::Pwc | LayerKind::Fc => self.in_ch as u64 / self.groups as u64,
            LayerKind::Add => 1,
            _ => 0,
        }
    }

    /// Maximum kernel-dimension parallelism P_w (output channels; channels
    /// for DWC).
    pub fn max_pw(&self) -> usize {
        match self.kind {
            LayerKind::Dwc => self.in_ch,
            _ => self.out_ch,
        }
    }

    /// Maximum FM-dimension parallelism P_f (spatial output positions).
    pub fn max_pf(&self) -> usize {
        self.out_positions()
    }
}

/// A skip-connection block: the FM snapshot buffered on the shortcut branch
/// is the *output of layer `from_layer - 1`* (the stream entering the branch
/// region; the network input when `from_layer == 0`), joined by the
/// `Add`/`Concat` layer at index `join_layer`.
#[derive(Debug, Clone)]
pub struct Scb {
    pub from_layer: usize,
    pub join_layer: usize,
}

impl Scb {
    /// Bytes of one frame's shortcut snapshot (8-bit activations).
    pub fn snapshot_bytes(&self, net: &Network) -> u64 {
        if self.from_layer == 0 {
            (net.input_size * net.input_size * net.input_ch) as u64
        } else {
            net.layers[self.from_layer - 1].out_fm_bytes()
        }
    }

    /// Spatial size / channels of the snapshot.
    pub fn snapshot_shape(&self, net: &Network) -> (usize, usize) {
        if self.from_layer == 0 {
            (net.input_size, net.input_ch)
        } else {
            let l = &net.layers[self.from_layer - 1];
            (l.out_size, l.out_ch)
        }
    }
}

/// A full network description in streaming (CE) order.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub input_size: usize,
    pub input_ch: usize,
    pub layers: Vec<Layer>,
    pub scbs: Vec<Scb>,
}

impl Network {
    /// Total MAC operations for one frame (the paper's `O_total`).
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Total weight bytes (8-bit), FC included.
    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(Layer::weight_bytes).sum()
    }

    /// MACs spent inside DSC structures (DWC + the PWC that follows) — used
    /// by the Fig 1 structure-share report.
    pub fn dsc_macs(&self) -> u64 {
        self.layers
            .iter()
            .enumerate()
            .filter(|(i, l)| {
                l.kind == LayerKind::Dwc
                    || (l.kind == LayerKind::Pwc
                        && self.layers[..*i].iter().rev().find(|p| p.kind.is_mac() || p.kind == LayerKind::Add)
                            .is_some_and(|p| p.kind == LayerKind::Dwc))
            })
            .map(|(_, l)| l.macs())
            .sum()
    }

    /// Number of layers participating in DSC or SCB structures, as a
    /// fraction of weight-bearing + Add layers (Fig 1 reports a structure
    /// percentage).
    pub fn dsc_scb_layer_fraction(&self) -> f64 {
        let total = self.layers.iter().filter(|l| l.kind.is_mac() || l.kind == LayerKind::Add).count();
        let mut in_structure = vec![false; self.layers.len()];
        for (i, l) in self.layers.iter().enumerate() {
            if l.kind == LayerKind::Dwc {
                in_structure[i] = true;
                // The PWC following a DWC forms the DSC pair.
                if let Some(j) = (i + 1..self.layers.len()).find(|&j| self.layers[j].kind.is_mac()) {
                    if self.layers[j].kind == LayerKind::Pwc {
                        in_structure[j] = true;
                    }
                }
            }
        }
        for scb in &self.scbs {
            for s in in_structure[scb.from_layer..=scb.join_layer].iter_mut() {
                *s = true;
            }
        }
        let hits = self
            .layers
            .iter()
            .enumerate()
            .filter(|(i, l)| (l.kind.is_mac() || l.kind == LayerKind::Add) && in_structure[*i])
            .count();
        hits as f64 / total as f64
    }

    /// Block count.
    pub fn num_blocks(&self) -> usize {
        self.layers.iter().map(|l| l.block + 1).max().unwrap_or(0)
    }

    /// Per-block (fm_bytes, weight_bytes) sums — Fig 3's series. The FM size
    /// of a block is the output FM bytes of its last layer.
    pub fn block_memory_profile(&self) -> Vec<(String, u64, u64)> {
        let mut out: Vec<(String, u64, u64)> = Vec::new();
        for l in &self.layers {
            if out.len() <= l.block {
                out.push((l.block_name.clone(), 0, 0));
            }
            let e = &mut out[l.block];
            e.1 = l.out_fm_bytes(); // last layer of the block wins
            e.2 += l.weight_bytes();
        }
        out
    }

    /// Find the SCB (if any) whose join layer is `idx`.
    pub fn scb_joining_at(&self, idx: usize) -> Option<&Scb> {
        self.scbs.iter().find(|s| s.join_layer == idx)
    }

    /// Validate structural invariants; used by tests and the builders.
    pub fn validate(&self) -> Result<(), String> {
        for (i, l) in self.layers.iter().enumerate() {
            let expect_out = match l.kind {
                LayerKind::Fc | LayerKind::AvgPool => l.out_size,
                _ => (l.in_size + 2 * l.pad - l.k) / l.stride + 1,
            };
            if l.kind != LayerKind::AvgPool && l.kind != LayerKind::Fc && l.out_size != expect_out {
                return Err(format!(
                    "{} layer {i} ({}): out_size {} != computed {}",
                    self.name, l.name, l.out_size, expect_out
                ));
            }
            if l.kind == LayerKind::Dwc && l.in_ch != l.out_ch {
                return Err(format!("{}: DWC layer {} has in_ch != out_ch", self.name, l.name));
            }
            match l.src {
                LayerSrc::Tee(j) => {
                    if j >= i {
                        return Err(format!("{}: layer {} tees forward layer {j}", self.name, l.name));
                    }
                    if self.layers[j].in_ch != l.in_ch {
                        return Err(format!(
                            "{}: tee channel mismatch {} ({}) -> {} ({})",
                            self.name, self.layers[j].name, self.layers[j].in_ch, l.name, l.in_ch
                        ));
                    }
                }
                LayerSrc::Prev => {
                    if i > 0 && !matches!(l.kind, LayerKind::Concat | LayerKind::Add) {
                        let prev = &self.layers[i - 1];
                        if prev.out_ch != l.in_ch {
                            return Err(format!(
                                "{}: channel mismatch {} ({}) -> {} ({})",
                                self.name, prev.name, prev.out_ch, l.name, l.in_ch
                            ));
                        }
                    }
                }
            }
        }
        for scb in &self.scbs {
            if scb.from_layer >= scb.join_layer || scb.join_layer >= self.layers.len() {
                return Err(format!("{}: bad SCB {:?}", self.name, scb));
            }
            let join = &self.layers[scb.join_layer];
            if !matches!(join.kind, LayerKind::Add | LayerKind::Concat) {
                return Err(format!("{}: SCB join {} is not Add/Concat", self.name, join.name));
            }
        }
        Ok(())
    }
}

/// Wire name of a [`LayerKind`] in the embedded `network_def` object of
/// saved design artifacts ([`network_to_json_value`]).
fn kind_wire_name(kind: LayerKind) -> &'static str {
    match kind {
        LayerKind::Stc => "stc",
        LayerKind::Dwc => "dwc",
        LayerKind::Pwc => "pwc",
        LayerKind::Add => "add",
        LayerKind::MaxPool => "maxpool",
        LayerKind::AvgPool => "avgpool",
        LayerKind::Fc => "fc",
        LayerKind::Shuffle => "shuffle",
        LayerKind::Split => "split",
        LayerKind::Concat => "concat",
    }
}

fn kind_from_wire(name: &str) -> Option<LayerKind> {
    Some(match name {
        "stc" => LayerKind::Stc,
        "dwc" => LayerKind::Dwc,
        "pwc" => LayerKind::Pwc,
        "add" => LayerKind::Add,
        "maxpool" => LayerKind::MaxPool,
        "avgpool" => LayerKind::AvgPool,
        "fc" => LayerKind::Fc,
        "shuffle" => LayerKind::Shuffle,
        "split" => LayerKind::Split,
        "concat" => LayerKind::Concat,
        _ => return None,
    })
}

/// Serialize a lowered [`Network`] as a JSON value — the `network_def`
/// key design artifacts embed when their network is not a zoo member, so
/// reloading ([`crate::Design::from_json`] and the sweep cache's warm
/// path) can rebuild `--net-file` networks without the source file.
pub(crate) fn network_to_json_value(net: &Network) -> crate::util::json::Json {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let layers: Vec<Json> = net
        .layers
        .iter()
        .map(|l| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(l.name.clone()));
            o.insert("kind".to_string(), Json::Str(kind_wire_name(l.kind).to_string()));
            o.insert(
                "src".to_string(),
                match l.src {
                    LayerSrc::Prev => Json::Str("prev".to_string()),
                    LayerSrc::Tee(i) => Json::Num(i as f64),
                },
            );
            o.insert("in_ch".to_string(), Json::Num(l.in_ch as f64));
            o.insert("out_ch".to_string(), Json::Num(l.out_ch as f64));
            o.insert("in_size".to_string(), Json::Num(l.in_size as f64));
            o.insert("out_size".to_string(), Json::Num(l.out_size as f64));
            o.insert("k".to_string(), Json::Num(l.k as f64));
            o.insert("stride".to_string(), Json::Num(l.stride as f64));
            o.insert("pad".to_string(), Json::Num(l.pad as f64));
            o.insert("groups".to_string(), Json::Num(l.groups as f64));
            o.insert("block".to_string(), Json::Num(l.block as f64));
            o.insert("block_name".to_string(), Json::Str(l.block_name.clone()));
            Json::Obj(o)
        })
        .collect();
    let scbs: Vec<Json> = net
        .scbs
        .iter()
        .map(|s| Json::Arr(vec![Json::Num(s.from_layer as f64), Json::Num(s.join_layer as f64)]))
        .collect();
    let mut o = BTreeMap::new();
    o.insert("input_ch".to_string(), Json::Num(net.input_ch as f64));
    o.insert("input_size".to_string(), Json::Num(net.input_size as f64));
    o.insert("layers".to_string(), Json::Arr(layers));
    o.insert("name".to_string(), Json::Str(net.name.clone()));
    o.insert("scbs".to_string(), Json::Arr(scbs));
    Json::Obj(o)
}

/// Rebuild a [`Network`] from an embedded `network_def` value; validates
/// the result so a hand-edited artifact cannot smuggle in a malformed
/// network.
pub(crate) fn network_from_json_value(j: &crate::util::json::Json) -> Result<Network, String> {
    use crate::util::json::Json;
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or("network_def: missing name")?
        .to_string();
    let need = |key: &str, o: &Json, at: &str| -> Result<usize, String> {
        o.get(key)
            .and_then(Json::as_f64)
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| n as usize)
            .ok_or_else(|| format!("network_def {at}: missing integer field {key:?}"))
    };
    let input_size = need("input_size", j, "")?;
    let input_ch = need("input_ch", j, "")?;
    let layers_json =
        j.get("layers").and_then(Json::as_arr).ok_or("network_def: missing layers array")?;
    let mut layers = Vec::with_capacity(layers_json.len());
    for (i, lj) in layers_json.iter().enumerate() {
        let at = format!("layer {i}");
        let layer_name = lj
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("network_def {at}: missing name"))?
            .to_string();
        let kind_name = lj
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("network_def {at}: missing kind"))?;
        let kind = kind_from_wire(kind_name)
            .ok_or_else(|| format!("network_def {at}: unknown layer kind {kind_name:?}"))?;
        let src = match lj.get("src") {
            Some(Json::Str(s)) if s == "prev" => LayerSrc::Prev,
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => LayerSrc::Tee(*n as usize),
            _ => return Err(format!("network_def {at}: src must be \"prev\" or a layer index")),
        };
        layers.push(Layer {
            name: layer_name,
            kind,
            src,
            in_ch: need("in_ch", lj, &at)?,
            out_ch: need("out_ch", lj, &at)?,
            in_size: need("in_size", lj, &at)?,
            out_size: need("out_size", lj, &at)?,
            k: need("k", lj, &at)?,
            stride: need("stride", lj, &at)?,
            pad: need("pad", lj, &at)?,
            groups: need("groups", lj, &at)?,
            block: need("block", lj, &at)?,
            block_name: lj
                .get("block_name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("network_def {at}: missing block_name"))?
                .to_string(),
        });
    }
    let scbs_json =
        j.get("scbs").and_then(Json::as_arr).ok_or("network_def: missing scbs array")?;
    let mut scbs = Vec::with_capacity(scbs_json.len());
    for (i, sj) in scbs_json.iter().enumerate() {
        let pair = sj.usize_vec();
        if pair.len() != 2 || sj.as_arr().map(|a| a.len()) != Some(2) {
            return Err(format!("network_def scb {i}: expected [from_layer, join_layer]"));
        }
        scbs.push(Scb { from_layer: pair[0], join_layer: pair[1] });
    }
    let net = Network { name, input_size, input_ch, layers, scbs };
    net.validate()?;
    Ok(net)
}

/// All four zoo networks, by canonical name.
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "mobilenet_v1" | "mbv1" => Some(mobilenet_v1()),
        "mobilenet_v2" | "mbv2" => Some(mobilenet_v2()),
        "shufflenet_v1" | "snv1" => Some(shufflenet_v1()),
        "shufflenet_v2" | "snv2" => Some(shufflenet_v2()),
        _ => None,
    }
}

/// Resolve a zoo network by name with the catalog-listing error UX of
/// [`crate::Platform::resolve`]: an unknown name lists the zoo and points
/// at the `--net-file` escape hatch for non-zoo networks.
pub fn resolve(name: &str) -> Result<Network, String> {
    by_name(name).ok_or_else(|| {
        format!(
            "unknown network {name:?} (known networks: {}; or load a JSON network \
             description with --net-file)",
            zoo_names().join(", ")
        )
    })
}

/// The layer-graph IR of a zoo network ([`crate::ir::Graph`]) — what the
/// committed `networks/*.json` catalog is generated from, and what
/// [`by_name`] lowers.
pub fn zoo_graph(name: &str) -> Option<crate::ir::Graph> {
    match name {
        "mobilenet_v1" | "mbv1" => Some(mobilenet_v1::graph()),
        "mobilenet_v2" | "mbv2" => Some(mobilenet_v2::graph()),
        "shufflenet_v1" | "snv1" => Some(shufflenet_v1::graph()),
        "shufflenet_v2" | "snv2" => Some(shufflenet_v2::graph()),
        _ => None,
    }
}

/// Canonical short name (the AOT artifact prefix) for a zoo network,
/// accepting either the full name or the short alias.
pub fn short_name(name: &str) -> Option<&'static str> {
    match name {
        "mobilenet_v1" | "mbv1" => Some("mbv1"),
        "mobilenet_v2" | "mbv2" => Some("mbv2"),
        "shufflenet_v1" | "snv1" => Some("snv1"),
        "shufflenet_v2" | "snv2" => Some("snv2"),
        _ => None,
    }
}

/// The four zoo networks in the paper's order.
pub fn all_networks() -> Vec<Network> {
    vec![mobilenet_v1(), mobilenet_v2(), shufflenet_v1(), shufflenet_v2()]
}

/// Canonical names of the zoo networks, in the paper's order — the CLI
/// and sweep parser's "known networks: ..." error listing.
pub fn zoo_names() -> [&'static str; 4] {
    ["mobilenet_v1", "mobilenet_v2", "shufflenet_v1", "shufflenet_v2"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_nets_validate() {
        for net in all_networks() {
            net.validate().unwrap();
        }
    }

    #[test]
    fn mac_totals_match_literature() {
        // Published multiply-accumulate counts (224x224): MobileNetV1 ~569M,
        // MobileNetV2 ~300M, ShuffleNetV1(g3) ~140M, ShuffleNetV2(1x) ~146M.
        let tol = |macs: u64, expect: f64| {
            let m = macs as f64 / 1e6;
            assert!((m - expect).abs() / expect < 0.10, "got {m:.1}M expected {expect}M");
        };
        tol(mobilenet_v1().total_macs(), 569.0);
        tol(mobilenet_v2().total_macs(), 300.0);
        tol(shufflenet_v1().total_macs(), 140.0);
        tol(shufflenet_v2().total_macs(), 146.0);
    }

    #[test]
    fn param_totals_match_literature() {
        // Parameters: MBv1 ~4.2M, MBv2 ~3.4M, SNv1(g3) ~1.9M (conv+fc, no BN),
        // SNv2 1x ~2.3M.
        let tol = |bytes: u64, expect: f64, rel: f64| {
            let m = bytes as f64 / 1e6;
            assert!((m - expect).abs() / expect < rel, "got {m:.2}M expected {expect}M");
        };
        tol(mobilenet_v1().total_weight_bytes(), 4.2, 0.08);
        tol(mobilenet_v2().total_weight_bytes(), 3.4, 0.08);
        tol(shufflenet_v1().total_weight_bytes(), 1.9, 0.25);
        tol(shufflenet_v2().total_weight_bytes(), 2.3, 0.15);
    }

    #[test]
    fn first_layer_fm_vs_weights_fig3() {
        // Fig 3(a): the first STC layer of MobileNetV2 produces ~400KB of FMs
        // while using merely 896 parameters (864 weights + bias; we count
        // weights only).
        let net = mobilenet_v2();
        let first = &net.layers[0];
        assert_eq!(first.kind, LayerKind::Stc);
        assert_eq!(first.out_fm_bytes(), 112 * 112 * 32); // ~401KB
        assert_eq!(first.weight_bytes(), 3 * 3 * 3 * 32); // 864
        // "the weight size in the last PWC layer is almost 26x the input
        // activations" — last PWC: 320->1280 at 7x7.
        let last_pwc = net
            .layers
            .iter()
            .rev()
            .find(|l| l.kind == LayerKind::Pwc)
            .unwrap();
        let ratio = last_pwc.weight_bytes() as f64 / last_pwc.in_fm_bytes() as f64;
        assert!(ratio > 20.0 && ratio < 30.0, "ratio {ratio}");
    }

    #[test]
    fn dsc_scb_share_fig1() {
        // Fig 1: DSC+SCB structures dominate LWCNN layer composition.
        for net in all_networks() {
            let frac = net.dsc_scb_layer_fraction();
            assert!(frac > 0.6, "{}: structure fraction {frac}", net.name);
        }
    }

    #[test]
    fn scb_joins_have_matching_channels() {
        for net in all_networks() {
            for scb in &net.scbs {
                let join = &net.layers[scb.join_layer];
                let (size, ch) = scb.snapshot_shape(&net);
                if join.kind == LayerKind::Add {
                    assert_eq!(ch, join.out_ch, "{} scb {:?}", net.name, scb);
                    assert_eq!(size, join.out_size, "{} scb {:?}", net.name, scb);
                }
            }
        }
    }

    #[test]
    fn by_name_resolves_aliases() {
        for (a, b) in [("mbv1", "mobilenet_v1"), ("mbv2", "mobilenet_v2"), ("snv1", "shufflenet_v1"), ("snv2", "shufflenet_v2")] {
            assert_eq!(by_name(a).unwrap().name, by_name(b).unwrap().name);
        }
        assert!(by_name("resnet50").is_none());
    }

    #[test]
    fn zoo_names_match_all_networks() {
        let names: Vec<String> = all_networks().into_iter().map(|n| n.name).collect();
        assert_eq!(names, zoo_names());
    }

    #[test]
    fn resolve_lists_the_zoo_and_mentions_net_file() {
        assert_eq!(resolve("mbv2").unwrap().name, "mobilenet_v2");
        let err = resolve("resnet50").unwrap_err();
        assert!(err.contains("unknown network \"resnet50\""), "{err}");
        for name in zoo_names() {
            assert!(err.contains(name), "{err}");
        }
        assert!(err.contains("--net-file"), "{err}");
    }

    #[test]
    fn zoo_graphs_validate_and_lower_to_the_zoo_networks() {
        for name in zoo_names() {
            let g = zoo_graph(name).unwrap();
            g.validate().unwrap();
            let lowered = crate::ir::lower(&g).unwrap();
            assert_eq!(format!("{lowered:?}"), format!("{:?}", by_name(name).unwrap()));
        }
        assert!(zoo_graph("resnet50").is_none());
    }

    #[test]
    fn network_def_round_trips_every_zoo_network() {
        for net in all_networks() {
            let text = network_to_json_value(&net).to_string();
            let parsed = crate::util::json::Json::parse(&text).unwrap();
            let back = network_from_json_value(&parsed).unwrap();
            assert_eq!(format!("{back:?}"), format!("{net:?}"));
        }
    }

    #[test]
    fn short_name_covers_the_zoo() {
        for net in all_networks() {
            let short = short_name(&net.name).unwrap();
            assert_eq!(by_name(short).unwrap().name, net.name);
            assert_eq!(short_name(short), Some(short));
        }
        assert!(short_name("resnet50").is_none());
    }
}
