//! MobileNetV1 (1.0x, 224x224) — Howard et al. 2017.
//!
//! Stem STC + 13 depthwise-separable pairs + avgpool + FC. No SCBs: the
//! network is the pure-DSC member of the zoo (Fig 1's DSC-only bar).

use crate::ir::{lower, Graph, GraphBuilder};

use super::Network;

/// The layer-graph description (the zoo's source of truth; lowered below).
pub(crate) fn graph() -> Graph {
    let mut b = GraphBuilder::new("mobilenet_v1", 224, 3);

    b.block("stem");
    b.conv(32, 3, 2, 1); // 224 -> 112

    // (pwc_out_channels, dwc_stride) for the 13 DSC pairs.
    let pairs: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, (out, s)) in pairs.iter().enumerate() {
        b.block(&format!("dsc{}", i + 1));
        b.dwconv(3, *s, 1);
        b.pwconv(*out);
    }

    b.block("head");
    b.global_avgpool();
    b.fc(1000);
    b.finish()
}

pub fn mobilenet_v1() -> Network {
    lower(&graph()).expect("zoo graph lowers")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::LayerKind;

    #[test]
    fn structure() {
        let net = mobilenet_v1();
        assert_eq!(net.layers.iter().filter(|l| l.kind == LayerKind::Dwc).count(), 13);
        assert_eq!(net.layers.iter().filter(|l| l.kind == LayerKind::Pwc).count(), 13);
        assert!(net.scbs.is_empty());
        // Final spatial size before pooling is 7x7 x 1024.
        let last_pwc = net.layers.iter().filter(|l| l.kind == LayerKind::Pwc).last().unwrap();
        assert_eq!((last_pwc.out_size, last_pwc.out_ch), (7, 1024));
    }
}
