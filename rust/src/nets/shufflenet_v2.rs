//! ShuffleNetV2 (1.0x, 224x224) — Ma et al. 2018.
//!
//! Stem STC + maxpool, three stages of units, head PWC + avgpool + FC.
//!
//! * Stride-1 unit: channel split (half to each branch); the through branch
//!   runs pwc -> dwc3x3 -> pwc; Concat rejoins; channel shuffle follows.
//! * Stride-2 unit: both branches consume the unit input — branch A
//!   (shortcut-side) is dwc3x3/s2 -> pwc, branch B is pwc -> dwc3x3/s2 ->
//!   pwc; Concat doubles the channels; shuffle follows. Branch B is
//!   expressed with a [`crate::nets::LayerSrc::Tee`] back to the unit
//!   input, and branch A's output is the buffered SCB snapshot.

use crate::ir::{lower, Graph, GraphBuilder};

use super::Network;

/// (output channels, repeats) per stage for the 1.0x model.
const STAGES: [(usize, usize); 3] = [(116, 4), (232, 8), (464, 4)];

/// The layer-graph description (the zoo's source of truth; lowered below).
pub(crate) fn graph() -> Graph {
    let mut b = GraphBuilder::new("shufflenet_v2", 224, 3);

    b.block("stem");
    b.conv(24, 3, 2, 1); // 224 -> 112
    b.maxpool(3, 2, 1); // 112 -> 56

    for (stage_idx, (out_ch, repeats)) in STAGES.iter().enumerate() {
        let stage = stage_idx + 2;
        let half = out_ch / 2;
        for rep in 0..*repeats {
            b.block(&format!("stage{}_{}", stage, rep + 1));
            if rep == 0 {
                // Stride-2 unit. Branch A (shortcut side) first in stream
                // order; its output is buffered while branch B computes.
                let unit_input = b.cursor().expect("stem precedes every unit");
                b.dwconv(3, 2, 1);
                let a_out = b.pwconv(half);
                // Branch B re-reads the unit input through a tee; the SCB
                // snapshot (buffered stream) is branch A's output.
                b.set_cursor(Some(unit_input));
                b.pwconv(half);
                b.dwconv(3, 2, 1);
                b.pwconv(half);
                b.concat_from(a_out);
                b.shuffle();
            } else {
                // Stride-1 unit: split, through-branch, concat, shuffle.
                let split = b.split(half);
                b.pwconv(half);
                b.dwconv(3, 1, 1);
                b.pwconv(half);
                b.concat_from(split);
                b.shuffle();
            }
        }
    }

    b.block("head");
    b.pwconv(1024);
    b.global_avgpool();
    b.fc(1000);
    b.finish()
}

pub fn shufflenet_v2() -> Network {
    lower(&graph()).expect("zoo graph lowers")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{LayerKind, LayerSrc};

    #[test]
    fn structure() {
        let net = shufflenet_v2();
        // 16 units: stride-2 units have 2 DWCs, stride-1 have 1 -> 3*2 + 13 = 19.
        assert_eq!(net.layers.iter().filter(|l| l.kind == LayerKind::Dwc).count(), 19);
        assert_eq!(net.layers.iter().filter(|l| l.kind == LayerKind::Concat).count(), 16);
        assert_eq!(net.layers.iter().filter(|l| l.src != LayerSrc::Prev).count(), 3);
        let head = net.layers.iter().filter(|l| l.kind == LayerKind::Pwc).last().unwrap();
        assert_eq!((head.out_size, head.out_ch), (7, 1024));
    }

    #[test]
    fn stage_channel_progression() {
        let net = shufflenet_v2();
        // After each stage's last shuffle the channel width matches STAGES.
        let shuffles: Vec<_> = net.layers.iter().filter(|l| l.kind == LayerKind::Shuffle).collect();
        assert_eq!(shuffles[3].out_ch, 116);
        assert_eq!(shuffles[11].out_ch, 232);
        assert_eq!(shuffles[15].out_ch, 464);
    }

    #[test]
    fn concat_restores_width() {
        let net = shufflenet_v2();
        for l in net.layers.iter().filter(|l| l.kind == LayerKind::Concat) {
            assert_eq!(l.out_ch % 2, 0);
            assert_eq!(l.in_ch + l.out_ch / 2, l.out_ch);
        }
    }
}
