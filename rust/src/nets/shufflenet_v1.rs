//! ShuffleNetV1 (1.0x, g = 3, 224x224) — Zhang et al. 2018.
//!
//! Stem STC + maxpool, then three stages of shuffle units built from
//! grouped PWCs, channel shuffle, and a 3x3 DWC. Stride-1 units close with
//! an element-wise Add SCB; stride-2 units concatenate the main branch
//! with a 3x3/s2 average-pooled shortcut (modelled as a teed AvgPool layer
//! feeding the Concat join).

use crate::ir::{lower, Graph, GraphBuilder};

use super::Network;

const GROUPS: usize = 3;
/// (output channels, repeats) per stage for g = 3.
const STAGES: [(usize, usize); 3] = [(240, 4), (480, 8), (960, 4)];

/// The layer-graph description (the zoo's source of truth; lowered below).
pub(crate) fn graph() -> Graph {
    let mut b = GraphBuilder::new("shufflenet_v1", 224, 3);

    b.block("stem");
    b.conv(24, 3, 2, 1); // 224 -> 112
    b.maxpool(3, 2, 1); // 112 -> 56

    for (stage_idx, (out_ch, repeats)) in STAGES.iter().enumerate() {
        let stage = stage_idx + 2;
        for rep in 0..*repeats {
            b.block(&format!("stage{}_{}", stage, rep + 1));
            let in_ch = b.cur_ch();
            let mid = out_ch / 4;
            let unit_input = b.cursor().expect("stem precedes every unit");
            if rep == 0 {
                // Stride-2 unit: main branch narrows to out_ch - in_ch so the
                // pooled shortcut concat restores out_ch.
                // First grouped PWC of stage2 unit1 operates on 24 input
                // channels and is conventionally ungrouped.
                let g1 = if stage == 2 { 1 } else { GROUPS };
                b.gpwconv(mid, g1);
                b.shuffle();
                b.dwconv(3, 2, 1);
                let main_out = b.gpwconv(out_ch - in_ch, GROUPS);
                // Shortcut branch: 3x3/s2 avgpool on the unit input; the
                // main branch output is buffered (snapshot) until the pooled
                // stream joins it at the Concat.
                b.set_cursor(Some(unit_input));
                b.avgpool(3, 2, 1);
                b.concat_from(main_out);
            } else {
                b.gpwconv(mid, GROUPS);
                b.shuffle();
                b.dwconv(3, 1, 1);
                b.gpwconv(*out_ch, GROUPS);
                b.add_from(unit_input);
            }
        }
    }

    b.block("head");
    b.global_avgpool();
    b.fc(1000);
    b.finish()
}

pub fn shufflenet_v1() -> Network {
    lower(&graph()).expect("zoo graph lowers")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::LayerKind;

    #[test]
    fn structure() {
        let net = shufflenet_v1();
        let units: usize = STAGES.iter().map(|(_, r)| r).sum();
        assert_eq!(units, 16);
        assert_eq!(net.layers.iter().filter(|l| l.kind == LayerKind::Dwc).count(), units);
        // 13 stride-1 Add SCBs + 3 stride-2 Concat SCBs.
        assert_eq!(net.scbs.len(), 16);
        assert_eq!(
            net.layers.iter().filter(|l| l.kind == LayerKind::Concat).count(),
            3
        );
        let last_mac = net.layers.iter().filter(|l| l.kind == LayerKind::Pwc).last().unwrap();
        assert_eq!(last_mac.out_size, 7);
    }

    #[test]
    fn grouped_pwc_reduces_macs() {
        let net = shufflenet_v1();
        let g = net
            .layers
            .iter()
            .find(|l| l.kind == LayerKind::Pwc && l.groups == GROUPS)
            .unwrap();
        let full = g.out_positions() as u64 * g.in_ch as u64 * g.out_ch as u64;
        assert_eq!(g.macs(), full / GROUPS as u64);
    }
}
