//! ShuffleNetV1 (1.0x, g = 3, 224x224) — Zhang et al. 2018.
//!
//! Stem STC + maxpool, then three stages of shuffle units built from
//! grouped PWCs, channel shuffle, and a 3x3 DWC. Stride-1 units close with
//! an element-wise Add SCB; stride-2 units concatenate the main branch
//! with a 3x3/s2 average-pooled shortcut (modelled as a teed AvgPool layer
//! feeding the Concat join).

use super::{NetBuilder, Network};

const GROUPS: usize = 3;
/// (output channels, repeats) per stage for g = 3.
const STAGES: [(usize, usize); 3] = [(240, 4), (480, 8), (960, 4)];

pub fn shufflenet_v1() -> Network {
    let mut b = NetBuilder::new("shufflenet_v1", 224, 3);

    b.block("stem");
    b.stc(24, 3, 2, 1); // 224 -> 112
    b.maxpool(3, 2, 1); // 112 -> 56

    for (stage_idx, (out_ch, repeats)) in STAGES.iter().enumerate() {
        let stage = stage_idx + 2;
        for rep in 0..*repeats {
            b.block(&format!("stage{}_{}", stage, rep + 1));
            let in_ch = b.cur_ch();
            let mid = out_ch / 4;
            if rep == 0 {
                // Stride-2 unit: main branch narrows to out_ch - in_ch so the
                // pooled shortcut concat restores out_ch.
                let branch_start = b.len();
                // First grouped PWC of stage2 unit1 operates on 24 input
                // channels and is conventionally ungrouped.
                let g1 = if stage == 2 { 1 } else { GROUPS };
                b.gpwc(mid, g1);
                b.shuffle();
                b.dwc(3, 2, 1);
                b.gpwc(out_ch - in_ch, GROUPS);
                // Shortcut branch: 3x3/s2 avgpool on the unit input; the
                // main branch output is buffered (snapshot) until the pooled
                // stream joins it at the Concat.
                b.from_tee(branch_start);
                let ap = b.avgpool_spatial(3, 2, 1);
                b.concat_scb(ap, out_ch - in_ch);
            } else {
                let branch_start = b.len();
                b.gpwc(mid, GROUPS);
                b.shuffle();
                b.dwc(3, 1, 1);
                b.gpwc(*out_ch, GROUPS);
                b.add_scb(branch_start);
            }
        }
    }

    b.block("head");
    b.avgpool();
    b.fc(1000);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::LayerKind;

    #[test]
    fn structure() {
        let net = shufflenet_v1();
        let units: usize = STAGES.iter().map(|(_, r)| r).sum();
        assert_eq!(units, 16);
        assert_eq!(net.layers.iter().filter(|l| l.kind == LayerKind::Dwc).count(), units);
        // 13 stride-1 Add SCBs + 3 stride-2 Concat SCBs.
        assert_eq!(net.scbs.len(), 16);
        assert_eq!(
            net.layers.iter().filter(|l| l.kind == LayerKind::Concat).count(),
            3
        );
        let last_mac = net.layers.iter().filter(|l| l.kind == LayerKind::Pwc).last().unwrap();
        assert_eq!(last_mac.out_size, 7);
    }

    #[test]
    fn grouped_pwc_reduces_macs() {
        let net = shufflenet_v1();
        let g = net
            .layers
            .iter()
            .find(|l| l.kind == LayerKind::Pwc && l.groups == GROUPS)
            .unwrap();
        let full = g.out_positions() as u64 * g.in_ch as u64 * g.out_ch as u64;
        assert_eq!(g.macs(), full / GROUPS as u64);
    }
}
