//! `repro` — leader CLI of the balanced-dataflow LWCNN accelerator
//! reproduction.
//!
//! Subcommands (hand-rolled parser; clap is not vendored offline):
//!
//! * `report <id>` — regenerate a paper table/figure
//!   (`fig1|fig3|tab1|fig10|fig12|fig13|fig14|fig15|fig16|fig17|tab2|tab3|tab4|tab5|all`).
//! * `allocate <net>` — run the resource-aware methodology (Alg 1 + Alg 2)
//!   through the [`Design`] builder and print the design point
//!   (`--json` for a stable one-line summary, `--save FILE` to persist the
//!   full design artifact).
//! * `simulate <net>` — cycle-level simulation of the design point
//!   (`--load FILE` re-simulates a saved design; `--fifo` also tracks
//!   per-side-FIFO peak occupancies and prints them next to the
//!   [`repro::model::fifo`] depth bounds).
//! * `sweep` — the design-space sweep: the full pipeline over a
//!   {networks} x {platforms} x {granularities} matrix (defaults: whole
//!   zoo x whole catalog x FGPM). `--net-file FILE,..` adds networks
//!   loaded from JSON graph descriptions (`docs/net_schema.md`) to the
//!   network axis, `--json` emits the stable sorted-key document,
//!   `--save-dir DIR` persists one `Design` artifact per cell,
//!   `--frames N` also cycle-simulates each cell, `--jobs N` evaluates
//!   cells on N work-stealing workers (byte-identical output for any N),
//!   `--cache` / `--cache-dir DIR` memoize cells across invocations in a
//!   content-keyed cache (hit/miss stats on stderr, zero Alg 1/Alg 2
//!   re-derivation on hits), `--cache-gc N` trims the cache to its N
//!   most-recently-used entries after the run, `--clocks MHZ,..` adds an
//!   FPS-vs-clock curve per cell, `--fifo` attaches modeled side-FIFO
//!   depth bounds (and, with `--frames`, the simulator's observed peak
//!   occupancies) to every cell — without it, documents and cache keys
//!   stay byte-identical to pre-FIFO runs — `--pareto` layers the
//!   per-network {SRAM, FPS, DRAM} Pareto-frontier analysis on top
//!   (gaining FIFO bytes as an extra axis under `--fifo`), and
//!   `--pareto-clocks` (with `--clocks`) promotes frequency to a fourth
//!   Pareto axis. Cells are fault-isolated: a failing cell degrades the
//!   run (partial report, stderr failure summary, exit code
//!   [`sweep::EXIT_PARTIAL_FAILURE`]) instead of aborting it; `--strict`
//!   refuses partial results and fails hard on the first failure. The
//!   `REPRO_FAULTS` environment variable arms the deterministic
//!   fault-injection harness (`docs/robustness.md`).
//! * `optimize` — the constrained design-space search (`sweep::optimize`):
//!   per-network branch-and-bound over the same matrix as `sweep`, pruning
//!   with admissible Eq 1–14 analytic bounds and returning the byte-exact
//!   best cell per network for `--objective fps|sram|dram`, plus search
//!   statistics (candidates / evaluated / pruned / pruned parallel-space /
//!   bound tightness). `--platform`/`--sram-mb`/`--dsp`/`--clock` describe
//!   a single custom budget to search under (instead of a `--platforms`
//!   axis); `--strategy anneal` selects the seeded simulated-annealing
//!   fallback; the cache, fault-isolation, `--strict`, `--fifo`,
//!   `--json`, and exit code semantics are the sweep's.
//! * `net <FILE>` — load and validate a JSON network description through
//!   the [`repro::ir`] front-end and print its lowered summary (`--json`
//!   for a stable one-line document); CI runs this over every committed
//!   `networks/*.json`.
//! * `infer <short> [--frames N]` — sequential PJRT inference vs golden.
//! * `stream <short> [--frames N] [--workers N]` — the threaded streaming
//!   coordinator (the end-to-end system path).
//!
//! Design points are constructed exclusively through
//! [`Design::builder`]/[`Platform`]; `--platform` selects a named budget
//! and `--sram-mb`/`--dsp` refine it into a custom one.

use std::process::ExitCode;

use repro::design::{Design, Platform};
use repro::sweep::optimize::{self as optimize_mod, Objective, OptimizeSpec, Strategy};
use repro::sweep::{self, SweepSpec};
use repro::util::cli::{self, check_flags, flag_val, parse_opt, parse_or};
use repro::util::fault;
use repro::util::json::Json;
use repro::{alloc, coordinator, nets, report, runtime, sim};

fn usage() -> ExitCode {
    eprintln!(
        "usage: repro <command>\n\
         \x20 report <fig1|fig3|tab1|fig10|fig12|fig13|fig14|fig15|fig16|fig17|tab2|tab3|tab4|tab5|ablation|all>\n\
         \x20 allocate <mbv1|mbv2|snv1|snv2> [--net-file FILE] [--platform zc706] [--sram-mb F]\n\
         \x20          [--dsp N] [--factorized] [--json] [--save FILE] [--load FILE]\n\
         \x20 simulate <mbv1|mbv2|snv1|snv2> [--net-file FILE] [--platform zc706] [--sram-mb F]\n\
         \x20          [--dsp N] [--factorized] [--frames N] [--baseline] [--fifo] [--save FILE]\n\
         \x20          [--load FILE]\n\
         \x20 sweep  [--nets a,b,..] [--net-file FILE,..] [--platforms zc706,zcu102,edge]\n\
         \x20          [--granularities fgpm,factorized] [--frames N] [--jobs N] [--clocks MHZ,MHZ,..]\n\
         \x20          [--fifo] [--pareto] [--pareto-clocks] [--cache | --cache-dir DIR] [--cache-gc N]\n\
         \x20          [--json] [--save-dir DIR] [--strict]\n\
         \x20 optimize --objective <fps|sram|dram> [--strategy bnb|anneal]\n\
         \x20          [--nets a,b,..] [--net-file FILE,..] [--platforms zc706,zcu102,edge]\n\
         \x20          [--platform NAME] [--sram-mb F] [--dsp N] [--clock MHZ]\n\
         \x20          [--granularities fgpm,factorized] [--frames N] [--jobs N] [--clocks MHZ,..]\n\
         \x20          [--fifo] [--cache | --cache-dir DIR] [--json] [--strict]\n\
         \x20 net    <FILE.json> [--json]\n\
         \x20 infer  <mbv2|snv2> [--frames N]\n\
         \x20 stream <mbv2|snv2> [--frames N] [--workers N]"
    );
    ExitCode::from(2)
}

fn fail(msg: &str) -> ExitCode {
    eprintln!("repro: {msg}");
    ExitCode::from(2)
}

/// Resolve the platform: `--platform` names a known budget (default
/// zc706); `--sram-mb` / `--dsp` refine it into a custom variant.
fn platform_from_args(args: &[String]) -> Result<Platform, String> {
    let mut p = match flag_val(args, "--platform")? {
        None => Platform::zc706(),
        // `resolve` lists the whole catalog on unknown names instead of
        // the old silent usage failure.
        Some(n) => Platform::resolve(&n)
            .map_err(|e| format!("--platform: {e}; use --sram-mb/--dsp for custom budgets"))?,
    };
    let mut custom = false;
    if let Some(mb) = parse_opt::<f64>(args, "--sram-mb")? {
        if !mb.is_finite() || mb < 0.0 {
            return Err(format!("--sram-mb: must be a non-negative number, got {mb}"));
        }
        p = p.with_sram_bytes((mb * 1024.0 * 1024.0) as u64);
        custom = true;
    }
    if let Some(dsp) = parse_opt::<usize>(args, "--dsp")? {
        p = p.with_dsp_budget(dsp);
        custom = true;
    }
    if custom {
        p.name = format!("{}-custom", p.name);
    }
    Ok(p)
}

/// Flags that consume the following argument as their value (in the
/// space form; `--name=VAL` carries the value inline).
const VALUE_FLAGS: [&str; 19] = [
    "--platform",
    "--sram-mb",
    "--dsp",
    "--clock",
    "--frames",
    "--workers",
    "--save",
    "--load",
    "--nets",
    "--net-file",
    "--platforms",
    "--granularities",
    "--save-dir",
    "--jobs",
    "--clocks",
    "--cache-dir",
    "--cache-gc",
    "--objective",
    "--strategy",
];

/// First positional argument after the subcommand (see
/// [`cli::positional`]).
fn positional(args: &[String]) -> Option<&String> {
    cli::positional(args, &VALUE_FLAGS)
}

/// Build (or `--load`) the design point shared by `allocate`/`simulate`.
fn design_from_args(args: &[String], opts: sim::SimOptions) -> Result<Design, String> {
    if let Some(path) = flag_val(args, "--load")? {
        // A loaded design carries its own platform/granularity/network;
        // silently ignoring build flags next to --load would contradict
        // the fail-loudly flag parsing, so reject the combination.
        let conflicting: Vec<&str> =
            ["--platform", "--sram-mb", "--dsp", "--factorized", "--net-file"]
                .into_iter()
                .filter(|f| cli::flag_present(args, f))
                .collect();
        if !conflicting.is_empty() {
            return Err(format!(
                "--load: conflicts with {} (the loaded design already fixes them)",
                conflicting.join(", ")
            ));
        }
        let text = std::fs::read_to_string(&path).map_err(|e| format!("--load {path}: {e}"))?;
        let d = Design::from_json(&text)?;
        // A positional <net> next to --load is a cross-check, not an input.
        if let Some(name) = positional(args) {
            let expect = nets::resolve(name)?;
            if expect.name != d.network().name {
                return Err(format!(
                    "--load {path}: design is for {:?}, not {:?}",
                    d.network().name,
                    expect.name
                ));
            }
        }
        return Ok(d);
    }
    let net = match flag_val(args, "--net-file")? {
        Some(path) => {
            // The file *is* the network; a positional <net> next to it
            // would be ambiguous, so reject the combination.
            if let Some(name) = positional(args) {
                return Err(format!(
                    "--net-file: conflicts with positional network {name:?} (the file already \
                     names the network)"
                ));
            }
            repro::ir::load_file(std::path::Path::new(&path))
                .map_err(|e| format!("--net-file {e}"))?
        }
        None => {
            let Some(name) = positional(args) else {
                return Err("missing <net> (or --net-file FILE, or --load FILE)".to_string());
            };
            nets::resolve(name)?
        }
    };
    let granularity = if args.iter().any(|a| a == "--factorized") {
        alloc::Granularity::Factorized
    } else {
        alloc::Granularity::Fgpm
    };
    Ok(Design::builder(&net)
        .platform(platform_from_args(args)?)
        .granularity(granularity)
        .sim_options(opts)
        .build())
}

fn save_if_asked(args: &[String], d: &Design) -> Result<(), String> {
    if let Some(path) = flag_val(args, "--save")? {
        let mut text = d.to_json();
        text.push('\n');
        std::fs::write(&path, text).map_err(|e| format!("--save {path}: {e}"))?;
        eprintln!("saved design to {path}");
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { return usage() };
    match cmd.as_str() {
        "report" => {
            if let Err(e) = check_flags(&args, &["--net-file"], &[]) {
                return fail(&e);
            }
            let id = positional(&args).map(String::as_str).unwrap_or("all");
            // The per-network renderers accept any lowered network, so
            // `--net-file` points them at a loaded graph instead of the
            // zoo; the aggregate/paper-comparison ids only make sense for
            // the paper's networks and reject it.
            let loaded = match flag_val(&args, "--net-file") {
                Err(e) => return fail(&e),
                Ok(None) => None,
                Ok(Some(path)) => {
                    if !matches!(id, "fig3" | "fig12" | "fig15") {
                        return fail(&format!(
                            "--net-file: only the per-network renderers (fig3, fig12, fig15) \
                             accept a loaded network, not {id:?}"
                        ));
                    }
                    match repro::ir::load_file(std::path::Path::new(&path)) {
                        Ok(net) => Some(net),
                        Err(e) => return fail(&format!("--net-file {e}")),
                    }
                }
            };
            let out = match id {
                "fig1" => report::fig1(),
                "fig3" => match &loaded {
                    Some(net) => report::fig3(net),
                    None => {
                        let mut s = String::new();
                        for net in [nets::mobilenet_v2(), nets::shufflenet_v2()] {
                            s.push_str(&report::fig3(&net));
                        }
                        s
                    }
                },
                "tab1" => report::tab1(),
                "fig10" => report::fig10(),
                "fig12" => match &loaded {
                    Some(net) => report::fig12(net),
                    None => nets::all_networks().iter().map(report::fig12).collect(),
                },
                "fig13" => report::fig13(),
                "fig14" => report::fig14(),
                "fig15" => match &loaded {
                    Some(net) => report::fig15(net),
                    None => nets::all_networks().iter().map(report::fig15).collect(),
                },
                "fig16" => report::fig16(),
                "fig17" => report::fig17(),
                "tab2" => report::tab2(),
                "tab3" => report::tab3(),
                "tab4" => report::tab4(),
                "tab5" => report::tab5(),
                "ablation" => report::ablation(),
                "fig17layers" => report::fig17_layers(),
                "all" => report::all(),
                _ => return usage(),
            };
            println!("{out}");
        }
        "allocate" => {
            if let Err(e) = check_flags(
                &args,
                &["--net-file", "--platform", "--sram-mb", "--dsp", "--save", "--load"],
                &["--factorized", "--json"],
            ) {
                return fail(&e);
            }
            let d = match design_from_args(&args, sim::SimOptions::optimized()) {
                Ok(d) => d,
                Err(e) => return fail(&e),
            };
            if let Err(e) = save_if_asked(&args, &d) {
                return fail(&e);
            }
            if args.iter().any(|a| a == "--json") {
                println!("{}", d.summary_json());
            } else {
                let (p, perf) = (d.platform(), d.predicted());
                println!(
                    "{} @ {}: boundary={} (min-SRAM {}), SRAM {:.2} MB, DRAM {:.2} MB/frame",
                    d.network().name,
                    p.name,
                    d.ce_plan().boundary,
                    d.memory().boundary_min_sram,
                    d.sram_bytes() as f64 / 1048576.0,
                    d.dram_bytes() as f64 / 1048576.0
                );
                println!(
                    "PEs={} DSPs={} ({:.1}% of {}), T_max={} cyc, FPS={:.1}, GOPS={:.1}, theoretical MAC eff={:.2}%",
                    d.parallelism().pes,
                    d.parallelism().dsps,
                    d.parallelism().dsps as f64 / p.dsp_total as f64 * 100.0,
                    p.dsp_total,
                    perf.t_max,
                    perf.fps,
                    perf.gops,
                    perf.mac_efficiency * 100.0
                );
            }
        }
        "simulate" => {
            if let Err(e) = check_flags(
                &args,
                &["--net-file", "--platform", "--sram-mb", "--dsp", "--frames", "--save", "--load"],
                &["--factorized", "--baseline", "--fifo"],
            ) {
                return fail(&e);
            }
            let baseline = args.iter().any(|a| a == "--baseline");
            let fifo = args.iter().any(|a| a == "--fifo");
            let opts = if baseline { sim::SimOptions::baseline() } else { sim::SimOptions::optimized() };
            let d = match design_from_args(&args, opts) {
                Ok(d) => d,
                Err(e) => return fail(&e),
            };
            // Validate every flag before --save writes anything to disk.
            let frames = match parse_or(&args, "--frames", 10u64) {
                Ok(f) => f,
                Err(e) => return fail(&e),
            };
            // The simulator needs at least one measured frame; it returns
            // a typed config error for 0, but the flag-shaped message here
            // is friendlier (and fails before --save writes anything).
            if frames == 0 {
                return fail("--frames: must be >= 1");
            }
            if let Err(e) = save_if_asked(&args, &d) {
                return fail(&e);
            }
            // An explicit --baseline overrides whatever options a --load'ed
            // design was saved with; --fifo turns occupancy tracking on
            // for the same run (zero effect on the headline stats).
            let base_opts = if baseline { opts } else { *d.sim_options() };
            let sim_opts =
                sim::SimOptions { track_fifo: fifo || base_opts.track_fifo, ..base_opts };
            match d.simulate_with(&sim_opts, frames) {
                Ok(stats) => {
                    let clock = d.platform().clock_hz;
                    println!(
                        "{}: period={:.0} cyc, FPS={:.1} @{:.0}MHz, actual MAC eff={:.2}%, latency={:.2} ms",
                        d.network().name,
                        stats.period_cycles,
                        stats.fps(clock),
                        clock / 1e6,
                        stats.mac_efficiency() * 100.0,
                        stats.latency_ms(clock)
                    );
                    if fifo {
                        // Model under the *effective* options (--baseline
                        // switches the buffer scheme), so the bounds mirror
                        // exactly what this run's pipeline provisioned.
                        let modeled = repro::model::fifo::fifo_depths(
                            d.network(),
                            d.ce_plan(),
                            sim_opts.scheme,
                        );
                        println!(
                            "{}",
                            report::fifo_design_table(&modeled, Some(&stats.fifo_peak))
                        );
                    }
                }
                Err(e) => {
                    eprintln!("{e}");
                    return ExitCode::FAILURE;
                }
            }
        }
        "sweep" => {
            if let Err(e) = check_flags(
                &args,
                &[
                    "--nets",
                    "--net-file",
                    "--platforms",
                    "--granularities",
                    "--frames",
                    "--jobs",
                    "--clocks",
                    "--save-dir",
                    "--cache-dir",
                    "--cache-gc",
                ],
                &["--json", "--fifo", "--pareto", "--pareto-clocks", "--cache", "--strict"],
            ) {
                return fail(&e);
            }
            if let Some(p) = positional(&args) {
                return fail(&format!("sweep takes no positional argument, found {p:?}"));
            }
            // The library silently disarms an unparsable REPRO_FAULTS (it
            // cannot assume a CLI context); the CLI validates it loudly up
            // front so a typo'd injection spec never runs fault-free and
            // masquerades as a passed experiment.
            if let Some(fault_spec) = fault::env_spec() {
                if let Err(e) = fault::FaultPlan::parse(&fault_spec) {
                    return fail(&format!("REPRO_FAULTS: {e}"));
                }
                eprintln!("sweep: fault injection armed: REPRO_FAULTS={fault_spec}");
            }
            let strict = args.iter().any(|a| a == "--strict");
            // Validate every flag (including --save-dir) before the
            // potentially expensive matrix run starts.
            let parsed = (|| -> Result<(SweepSpec, Option<String>, Option<usize>), String> {
                let mut spec = SweepSpec::from_cli(
                    flag_val(&args, "--nets")?.as_deref(),
                    flag_val(&args, "--net-file")?.as_deref(),
                    flag_val(&args, "--platforms")?.as_deref(),
                    flag_val(&args, "--granularities")?.as_deref(),
                )?;
                spec.frames = parse_opt(&args, "--frames")?;
                if spec.frames == Some(0) {
                    return Err("--frames: must be >= 1".to_string());
                }
                // Parallel cell evaluation: any job count produces
                // byte-identical output, so this is purely a wall-clock
                // knob. 0 would mean "no workers"; fail loudly like the
                // other flags instead of silently running serial.
                spec.jobs = parse_or(&args, "--jobs", 1usize)?;
                if spec.jobs == 0 {
                    return Err("--jobs: must be >= 1".to_string());
                }
                if let Some(csv) = flag_val(&args, "--clocks")? {
                    spec.clocks_hz = SweepSpec::parse_clocks_csv(&csv)?;
                }
                spec.fifo = args.iter().any(|a| a == "--fifo");
                sweep::validate_pareto_clocks(
                    args.iter().any(|a| a == "--pareto-clocks"),
                    &spec.clocks_hz,
                )?;
                spec.cache_dir = SweepSpec::resolve_cache_flags(
                    args.iter().any(|a| a == "--cache"),
                    flag_val(&args, "--cache-dir")?.as_deref(),
                )?;
                let cache_gc = parse_opt::<usize>(&args, "--cache-gc")?;
                if let Some(n) = cache_gc {
                    if spec.cache_dir.is_none() {
                        return Err(
                            "--cache-gc: requires the cache (pass --cache or --cache-dir DIR)"
                                .to_string(),
                        );
                    }
                    if n == 0 {
                        return Err("--cache-gc: must be >= 1 (0 would evict this run's own \
                                    cells)"
                            .to_string());
                    }
                }
                Ok((spec, flag_val(&args, "--save-dir")?, cache_gc))
            })();
            let (spec, save_dir, cache_gc) = match parsed {
                Ok(p) => p,
                Err(e) => return fail(&e),
            };
            // Fail on an unwritable save or cache directory now, not
            // after the matrix has been computed: create it and probe
            // with a scratch file (create_dir_all alone succeeds on an
            // existing read-only directory). The cache layer itself is
            // best-effort, so without this probe a bad --cache-dir would
            // silently run cold forever.
            let probe_dir = |flag: &str, dir: &std::path::Path| -> Result<(), String> {
                std::fs::create_dir_all(dir).map_err(|e| format!("{flag} {}: {e}", dir.display()))?;
                let probe = dir.join(".sweep-write-probe");
                std::fs::write(&probe, b"")
                    .map_err(|e| format!("{flag} {}: not writable: {e}", dir.display()))?;
                let _ = std::fs::remove_file(&probe);
                Ok(())
            };
            if let Some(dir) = &save_dir {
                if let Err(e) = probe_dir("--save-dir", std::path::Path::new(dir)) {
                    return fail(&e);
                }
            }
            if let Some(dir) = &spec.cache_dir {
                if let Err(e) = probe_dir("--cache/--cache-dir", dir) {
                    return fail(&e);
                }
            }
            let sweep_report = spec.run();
            // --strict refuses partial results: the first failure (in
            // matrix order) becomes a hard error before any report,
            // artifact, or cache line is emitted.
            if strict {
                if let Some(f) = sweep_report.failures.first() {
                    return fail(&format!(
                        "sweep --strict: cell {} failed ({}): {}",
                        f.label(),
                        f.error.kind(),
                        f.error
                    ));
                }
            }
            if !sweep_report.failures.is_empty() {
                // Stderr, like the cache stats: the JSON document carries
                // the same data under its `failures` key.
                eprintln!(
                    "sweep: {} of {} cells failed:",
                    sweep_report.failures.len(),
                    spec.cell_count()
                );
                for f in &sweep_report.failures {
                    eprintln!("  {} [{}]: {}", f.label(), f.error.kind(), f.error);
                }
            }
            if let (Some(stats), Some(dir)) = (&sweep_report.cache, &spec.cache_dir) {
                // Stderr, not the JSON document: warm and cold documents
                // must stay byte-identical (CI greps this line instead).
                eprintln!("{}", stats.summary(dir));
            }
            if let (Some(n), Some(dir)) = (cache_gc, &spec.cache_dir) {
                // After the run, so this run's (just stored or just
                // touched) cells rank most recent and are never evicted.
                eprintln!("{}", sweep::CellCache::open(dir).gc(n).summary(dir));
            }
            if let Some(dir) = save_dir {
                match sweep_report.save_designs(std::path::Path::new(&dir)) {
                    Ok(paths) if sweep_report.failures.is_empty() => {
                        eprintln!("saved {} design artifacts to {dir}", paths.len())
                    }
                    Ok(paths) => eprintln!(
                        "saved {} design artifacts to {dir} ({} cells failed, skipped)",
                        paths.len(),
                        sweep_report.failures.len()
                    ),
                    Err(e) => return fail(&format!("--save-dir: {e}")),
                }
            }
            let pareto = args.iter().any(|a| a == "--pareto").then(|| sweep_report.pareto());
            let pareto_clocks = args
                .iter()
                .any(|a| a == "--pareto-clocks")
                .then(|| sweep_report.pareto_clocks());
            if args.iter().any(|a| a == "--json") {
                println!("{}", sweep_report.to_json_full(pareto.as_ref(), pareto_clocks.as_ref()));
            } else {
                println!("{}", report::sweep_matrix(&sweep_report));
                if spec.fifo {
                    println!("{}", report::fifo_table(&sweep_report));
                }
                if !spec.clocks_hz.is_empty() {
                    println!("{}", report::clock_curves(&sweep_report));
                }
                if let Some(analysis) = &pareto {
                    println!("{}", report::pareto_table(&sweep_report, analysis));
                }
                if let Some(analysis) = &pareto_clocks {
                    println!("{}", report::pareto_clocks_table(&sweep_report, analysis));
                }
            }
            // After the partial report has been emitted in full:
            // EXIT_PARTIAL_FAILURE (3) when any cell failed, 0 otherwise,
            // so scripts can distinguish "degraded" from "clean" and from
            // usage errors (2).
            let code = sweep::exit_code(&sweep_report);
            if code != 0 {
                return ExitCode::from(code);
            }
        }
        "optimize" => {
            if let Err(e) = check_flags(
                &args,
                &[
                    "--objective",
                    "--strategy",
                    "--nets",
                    "--net-file",
                    "--platforms",
                    "--granularities",
                    "--platform",
                    "--sram-mb",
                    "--dsp",
                    "--clock",
                    "--frames",
                    "--jobs",
                    "--clocks",
                    "--cache-dir",
                ],
                &["--json", "--fifo", "--cache", "--strict"],
            ) {
                return fail(&e);
            }
            if let Some(p) = positional(&args) {
                return fail(&format!("optimize takes no positional argument, found {p:?}"));
            }
            // Same loud REPRO_FAULTS validation as the sweep arm: a typo'd
            // injection spec must never run fault-free silently.
            if let Some(fault_spec) = fault::env_spec() {
                if let Err(e) = fault::FaultPlan::parse(&fault_spec) {
                    return fail(&format!("REPRO_FAULTS: {e}"));
                }
                eprintln!("optimize: fault injection armed: REPRO_FAULTS={fault_spec}");
            }
            let strict = args.iter().any(|a| a == "--strict");
            let parsed = (|| -> Result<OptimizeSpec, String> {
                let objective = match flag_val(&args, "--objective")? {
                    Some(o) => Objective::parse(&o)?,
                    None => {
                        return Err(
                            "--objective: required (fps, sram, or dram — the scalar to optimize)"
                                .to_string(),
                        )
                    }
                };
                let strategy = match flag_val(&args, "--strategy")? {
                    Some(s) => Strategy::parse(&s)?,
                    None => Strategy::BranchBound,
                };
                // A custom budget query (--platform/--sram-mb/--dsp/--clock)
                // defines the single platform to search under; combining it
                // with a --platforms axis would be ambiguous.
                let budget_flags: Vec<&str> = ["--platform", "--sram-mb", "--dsp", "--clock"]
                    .into_iter()
                    .filter(|f| cli::flag_present(&args, f))
                    .collect();
                if !budget_flags.is_empty() && cli::flag_present(&args, "--platforms") {
                    return Err(format!(
                        "--platforms: conflicts with the budget flags {} (name platforms or \
                         describe one budget, not both)",
                        budget_flags.join(", ")
                    ));
                }
                let mut spec = SweepSpec::from_cli(
                    flag_val(&args, "--nets")?.as_deref(),
                    flag_val(&args, "--net-file")?.as_deref(),
                    flag_val(&args, "--platforms")?.as_deref(),
                    flag_val(&args, "--granularities")?.as_deref(),
                )?;
                if !budget_flags.is_empty() {
                    let mut p = platform_from_args(&args)?;
                    if let Some(mhz) = parse_opt::<f64>(&args, "--clock")? {
                        if !mhz.is_finite() || mhz <= 0.0 {
                            return Err(format!("--clock: must be a positive MHz value, got {mhz}"));
                        }
                        p = p.with_clock_hz(mhz * 1.0e6);
                        if !p.name.ends_with("-custom") {
                            p.name = format!("{}-custom", p.name);
                        }
                    }
                    spec.platforms = vec![p];
                }
                spec.frames = parse_opt(&args, "--frames")?;
                if spec.frames == Some(0) {
                    return Err("--frames: must be >= 1".to_string());
                }
                spec.jobs = parse_or(&args, "--jobs", 1usize)?;
                if spec.jobs == 0 {
                    return Err("--jobs: must be >= 1".to_string());
                }
                if let Some(csv) = flag_val(&args, "--clocks")? {
                    spec.clocks_hz = SweepSpec::parse_clocks_csv(&csv)?;
                }
                spec.fifo = args.iter().any(|a| a == "--fifo");
                spec.cache_dir = SweepSpec::resolve_cache_flags(
                    args.iter().any(|a| a == "--cache"),
                    flag_val(&args, "--cache-dir")?.as_deref(),
                )?;
                Ok(OptimizeSpec::new(spec, objective, strategy))
            })();
            let opt_spec = match parsed {
                Ok(s) => s,
                Err(e) => return fail(&e),
            };
            // Same pre-run writability probe as the sweep arm: the cache
            // layer is best-effort, so a bad directory would otherwise
            // silently run cold forever.
            let probe_dir = |flag: &str, dir: &std::path::Path| -> Result<(), String> {
                std::fs::create_dir_all(dir).map_err(|e| format!("{flag} {}: {e}", dir.display()))?;
                let probe = dir.join(".sweep-write-probe");
                std::fs::write(&probe, b"")
                    .map_err(|e| format!("{flag} {}: not writable: {e}", dir.display()))?;
                let _ = std::fs::remove_file(&probe);
                Ok(())
            };
            if let Some(dir) = &opt_spec.sweep.cache_dir {
                if let Err(e) = probe_dir("--cache/--cache-dir", dir) {
                    return fail(&e);
                }
            }
            let opt_report = opt_spec.run();
            if strict {
                if let Some(f) = opt_report.failures.first() {
                    return fail(&format!(
                        "optimize --strict: cell {} failed ({}): {}",
                        f.label(),
                        f.error.kind(),
                        f.error
                    ));
                }
            }
            if !opt_report.failures.is_empty() {
                eprintln!(
                    "optimize: {} of {} cells failed:",
                    opt_report.failures.len(),
                    opt_spec.sweep.cell_count()
                );
                for f in &opt_report.failures {
                    eprintln!("  {} [{}]: {}", f.label(), f.error.kind(), f.error);
                }
            }
            if let (Some(stats), Some(dir)) = (&opt_report.cache, &opt_spec.sweep.cache_dir) {
                // Stderr, like the sweep: warm and cold JSON documents
                // must stay byte-identical (CI greps this line instead).
                eprintln!("{}", stats.summary(dir));
            }
            if args.iter().any(|a| a == "--json") {
                println!("{}", opt_report.to_json());
            } else {
                println!("{}", report::optimize_table(&opt_report));
            }
            let code = optimize_mod::exit_code(&opt_report);
            if code != 0 {
                return ExitCode::from(code);
            }
        }
        "net" => {
            if let Err(e) = check_flags(&args, &[], &["--json"]) {
                return fail(&e);
            }
            let Some(path) = positional(&args) else {
                return fail("missing <FILE.json> (a network description; see docs/net_schema.md)");
            };
            // Loading runs the full IR pipeline — parse, shape-inference
            // validation, lowering — so a zero exit *is* the validation
            // result CI wants for every committed networks/*.json.
            let net = match repro::ir::load_file(std::path::Path::new(path)) {
                Ok(n) => n,
                Err(e) => return fail(e.message()),
            };
            if args.iter().any(|a| a == "--json") {
                let mut m = std::collections::BTreeMap::new();
                m.insert("blocks".to_string(), Json::Num(net.num_blocks() as f64));
                m.insert("input_ch".to_string(), Json::Num(net.input_ch as f64));
                m.insert("input_size".to_string(), Json::Num(net.input_size as f64));
                m.insert("layers".to_string(), Json::Num(net.layers.len() as f64));
                m.insert("name".to_string(), Json::Str(net.name.clone()));
                m.insert("scbs".to_string(), Json::Num(net.scbs.len() as f64));
                m.insert("total_macs".to_string(), Json::Num(net.total_macs() as f64));
                m.insert("weight_bytes".to_string(), Json::Num(net.total_weight_bytes() as f64));
                println!("{}", Json::Obj(m));
            } else {
                println!(
                    "{}: {}x{}x{} input, {} layers in {} blocks, {:.1} MMACs/frame, {:.2} MB \
                     weights (8-bit), {} SCB edge(s)",
                    net.name,
                    net.input_size,
                    net.input_size,
                    net.input_ch,
                    net.layers.len(),
                    net.num_blocks(),
                    net.total_macs() as f64 / 1e6,
                    net.total_weight_bytes() as f64 / 1048576.0,
                    net.scbs.len()
                );
            }
        }
        "infer" => {
            if let Err(e) = check_flags(&args, &["--frames"], &[]) {
                return fail(&e);
            }
            let Some(short) = positional(&args) else { return usage() };
            let frames: u64 = match parse_or(&args, "--frames", 1u64) {
                Ok(f) => f,
                Err(e) => return fail(&e),
            };
            let engine = match runtime::Engine::load(&runtime::artifacts_dir(), short) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("{e:#}");
                    return ExitCode::FAILURE;
                }
            };
            // Golden artifacts are user-provided files: a missing or
            // truncated tensor is a reportable error, not a panic.
            let input = match engine.manifest.read_f32(&engine.manifest.golden_input) {
                Ok(v) => v,
                Err(e) => {
                    return fail(&format!("golden input {}: {e:#}", engine.manifest.golden_input))
                }
            };
            let golden = match engine.manifest.read_f32(&engine.manifest.golden_logits) {
                Ok(v) => v,
                Err(e) => {
                    return fail(&format!("golden logits {}: {e:#}", engine.manifest.golden_logits))
                }
            };
            let t0 = std::time::Instant::now();
            let mut out = Vec::new();
            for _ in 0..frames {
                out = match engine.infer(&input) {
                    Ok(v) => v,
                    Err(e) => return fail(&format!("inference failed: {e:#}")),
                };
            }
            let dt = t0.elapsed().as_secs_f64();
            let err = out.iter().zip(&golden).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            println!(
                "{}: {} frames in {:.2}s ({:.2} FPS sequential), max |logits err| = {:.2e}",
                engine.manifest.network,
                frames,
                dt,
                frames as f64 / dt,
                err
            );
        }
        "stream" => {
            if let Err(e) = check_flags(&args, &["--frames", "--workers"], &[]) {
                return fail(&e);
            }
            let Some(short) = positional(&args) else { return usage() };
            let (frames, workers) = match (parse_or(&args, "--frames", 8u64), parse_or(&args, "--workers", 4usize)) {
                (Ok(f), Ok(w)) => (f, w),
                (Err(e), _) | (_, Err(e)) => return fail(&e),
            };
            match coordinator::run_streaming(runtime::artifacts_dir(), short, frames, workers) {
                Ok(r) => {
                    println!(
                        "{}: {} frames, {:.2} FPS streaming, mean latency {:.1} ms, max |err| {:.2e}",
                        r.network,
                        r.frames,
                        r.fps,
                        r.latency * 1e3,
                        r.max_abs_err
                    );
                    println!(
                        "DRAM weight stream: {:.2} MB/frame (8-bit model); coordinator overhead {:.1}%",
                        r.dram_weight_bytes_8bit as f64 / 1048576.0,
                        r.coordinator_overhead() * 100.0
                    );
                    for g in &r.groups {
                        println!("  group stages {:?}: busy {:.2}s", g.stages, g.busy);
                    }
                }
                Err(e) => {
                    eprintln!("{e:#}");
                    return ExitCode::FAILURE;
                }
            }
        }
        _ => return usage(),
    }
    ExitCode::SUCCESS
}
