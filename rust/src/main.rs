//! `repro` — leader CLI of the balanced-dataflow LWCNN accelerator
//! reproduction.
//!
//! Subcommands (hand-rolled parser; clap is not vendored offline):
//!
//! * `report <id>` — regenerate a paper table/figure
//!   (`fig1|fig3|tab1|fig10|fig12|fig13|fig14|fig15|fig16|fig17|tab2|tab3|tab4|tab5|all`).
//! * `allocate <net> [--sram-mb F] [--dsp N] [--factorized]` — run the
//!   resource-aware methodology (Alg 1 + Alg 2) and print the design point.
//! * `simulate <net> [--frames N] [--baseline]` — cycle-level simulation.
//! * `infer <short> [--frames N]` — sequential PJRT inference vs golden.
//! * `stream <short> [--frames N] [--workers N]` — the threaded streaming
//!   coordinator (the end-to-end system path).

use std::process::ExitCode;

use repro::model::memory::CePlan;
use repro::{alloc, coordinator, nets, report, runtime, sim, zc706, CLOCK_HZ};

fn usage() -> ExitCode {
    eprintln!(
        "usage: repro <command>\n\
         \x20 report <fig1|fig3|tab1|fig10|fig12|fig13|fig14|fig15|fig16|fig17|tab2|tab3|tab4|tab5|ablation|all>\n\
         \x20 allocate <mbv1|mbv2|snv1|snv2> [--sram-mb F] [--dsp N] [--factorized]\n\
         \x20 simulate <mbv1|mbv2|snv1|snv2> [--frames N] [--baseline]\n\
         \x20 infer  <mbv2|snv2> [--frames N]\n\
         \x20 stream <mbv2|snv2> [--frames N] [--workers N]"
    );
    ExitCode::from(2)
}

fn flag_val(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { return usage() };
    match cmd.as_str() {
        "report" => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            let out = match id {
                "fig1" => report::fig1(),
                "fig3" => {
                    let mut s = String::new();
                    for net in [nets::mobilenet_v2(), nets::shufflenet_v2()] {
                        s.push_str(&report::fig3(&net));
                    }
                    s
                }
                "tab1" => report::tab1(),
                "fig10" => report::fig10(),
                "fig12" => nets::all_networks().iter().map(report::fig12).collect(),
                "fig13" => report::fig13(),
                "fig14" => report::fig14(),
                "fig15" => nets::all_networks().iter().map(report::fig15).collect(),
                "fig16" => report::fig16(),
                "fig17" => report::fig17(),
                "tab2" => report::tab2(),
                "tab3" => report::tab3(),
                "tab4" => report::tab4(),
                "tab5" => report::tab5(),
                "ablation" => report::ablation(),
                "fig17layers" => report::fig17_layers(),
                "all" => report::all(),
                _ => return usage(),
            };
            println!("{out}");
        }
        "allocate" => {
            let Some(net) = args.get(1).and_then(|n| nets::by_name(n)) else { return usage() };
            let sram = flag_val(&args, "--sram-mb")
                .and_then(|v| v.parse::<f64>().ok())
                .map(|mb| (mb * 1024.0 * 1024.0) as u64)
                .unwrap_or(zc706::SRAM_BYTES);
            let dsp = flag_val(&args, "--dsp").and_then(|v| v.parse().ok()).unwrap_or(zc706::DSP_BUDGET);
            let g = if args.iter().any(|a| a == "--factorized") {
                alloc::Granularity::Factorized
            } else {
                alloc::Granularity::Fgpm
            };
            let d = alloc::design_point(&net, sram, dsp, g);
            println!(
                "{}: boundary={} (min-SRAM {}), SRAM {:.2} MB, DRAM {:.2} MB/frame",
                net.name,
                d.memory.boundary,
                d.memory.boundary_min_sram,
                d.sram_bytes as f64 / 1048576.0,
                d.dram_bytes as f64 / 1048576.0
            );
            println!(
                "PEs={} DSPs={} ({:.1}% of {}), T_max={} cyc, FPS={:.1}, GOPS={:.1}, theoretical MAC eff={:.2}%",
                d.parallelism.pes,
                d.parallelism.dsps,
                d.parallelism.dsps as f64 / zc706::DSP as f64 * 100.0,
                zc706::DSP,
                d.performance.t_max,
                d.performance.fps,
                d.performance.gops,
                d.performance.mac_efficiency * 100.0
            );
        }
        "simulate" => {
            let Some(net) = args.get(1).and_then(|n| nets::by_name(n)) else { return usage() };
            let frames = flag_val(&args, "--frames").and_then(|v| v.parse().ok()).unwrap_or(10);
            let opts = if args.iter().any(|a| a == "--baseline") {
                sim::SimOptions::baseline()
            } else {
                sim::SimOptions::optimized()
            };
            let d = alloc::design_point(&net, zc706::SRAM_BYTES, zc706::DSP_BUDGET, alloc::Granularity::Fgpm);
            let plan = CePlan { boundary: d.memory.boundary };
            match sim::simulate(&net, &d.parallelism.allocs, &plan, &opts, frames) {
                Ok(stats) => println!(
                    "{}: period={:.0} cyc, FPS={:.1} @200MHz, actual MAC eff={:.2}%, latency={:.2} ms",
                    net.name,
                    stats.period_cycles,
                    stats.fps(CLOCK_HZ),
                    stats.mac_efficiency() * 100.0,
                    stats.latency_ms(CLOCK_HZ)
                ),
                Err(e) => {
                    eprintln!("{e}");
                    return ExitCode::FAILURE;
                }
            }
        }
        "infer" => {
            let Some(short) = args.get(1) else { return usage() };
            let frames: u64 = flag_val(&args, "--frames").and_then(|v| v.parse().ok()).unwrap_or(1);
            let engine = match runtime::Engine::load(&runtime::artifacts_dir(), short) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("{e:#}");
                    return ExitCode::FAILURE;
                }
            };
            let input = engine.manifest.read_f32(&engine.manifest.golden_input).unwrap();
            let golden = engine.manifest.read_f32(&engine.manifest.golden_logits).unwrap();
            let t0 = std::time::Instant::now();
            let mut out = Vec::new();
            for _ in 0..frames {
                out = engine.infer(&input).unwrap();
            }
            let dt = t0.elapsed().as_secs_f64();
            let err = out.iter().zip(&golden).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            println!(
                "{}: {} frames in {:.2}s ({:.2} FPS sequential), max |logits err| = {:.2e}",
                engine.manifest.network,
                frames,
                dt,
                frames as f64 / dt,
                err
            );
        }
        "stream" => {
            let Some(short) = args.get(1) else { return usage() };
            let frames: u64 = flag_val(&args, "--frames").and_then(|v| v.parse().ok()).unwrap_or(8);
            let workers: usize = flag_val(&args, "--workers").and_then(|v| v.parse().ok()).unwrap_or(4);
            match coordinator::run_streaming(runtime::artifacts_dir(), short, frames, workers) {
                Ok(r) => {
                    println!(
                        "{}: {} frames, {:.2} FPS streaming, mean latency {:.1} ms, max |err| {:.2e}",
                        r.network,
                        r.frames,
                        r.fps,
                        r.latency * 1e3,
                        r.max_abs_err
                    );
                    println!(
                        "DRAM weight stream: {:.2} MB/frame (8-bit model); coordinator overhead {:.1}%",
                        r.dram_weight_bytes_8bit as f64 / 1048576.0,
                        r.coordinator_overhead() * 100.0
                    );
                    for g in &r.groups {
                        println!("  group stages {:?}: busy {:.2}s", g.stages, g.busy);
                    }
                }
                Err(e) => {
                    eprintln!("{e:#}");
                    return ExitCode::FAILURE;
                }
            }
        }
        _ => return usage(),
    }
    ExitCode::SUCCESS
}
