//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the CPU PJRT client.
//!
//! Python never runs on this path — the HLO text is parsed and compiled by
//! XLA through the `xla` crate (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute`), exactly the
//! pattern validated by /opt/xla-example/load_hlo.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// CE-group kind of a stage (mirrors the manifest's `kind` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// Weights baked into the HLO as constants (on-chip ROM).
    Frce,
    /// Weights passed as leading runtime parameters (streamed from DRAM).
    Wrce,
}

/// A weight tensor slice in the flat `<net>_weights.bin` blob.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset/length in f32 elements.
    pub offset: usize,
    pub len: usize,
}

/// One stage of the compiled pipeline.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    pub kind: StageKind,
    pub hlo_file: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
    /// 8-bit byte counts from the memory model (for DRAM-traffic metrics).
    pub weight_bytes_8bit: u64,
    pub fm_bytes_8bit: u64,
    /// Reference output checksum (mean, std) from the golden pass.
    pub mean: f64,
    pub std: f64,
}

/// Parsed `<net>_manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub network: String,
    pub input_shape: Vec<usize>,
    pub boundary: usize,
    pub stages: Vec<StageSpec>,
    pub weights_file: String,
    pub golden_input: String,
    pub golden_logits: String,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path, short: &str) -> Result<Manifest> {
        let path = dir.join(format!("{short}_manifest.json"));
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let stages = j
            .arr_field("stages")
            .iter()
            .map(|s| StageSpec {
                name: s.str_field("name").to_string(),
                kind: match s.str_field("kind") {
                    "frce" => StageKind::Frce,
                    _ => StageKind::Wrce,
                },
                hlo_file: s.str_field("hlo").to_string(),
                in_shape: s.get("in_shape").unwrap().usize_vec(),
                out_shape: s.get("out_shape").unwrap().usize_vec(),
                params: s
                    .arr_field("params")
                    .iter()
                    .map(|p| ParamSpec {
                        name: p.str_field("name").to_string(),
                        shape: p.get("shape").unwrap().usize_vec(),
                        offset: p.usize_field("offset"),
                        len: p.usize_field("len"),
                    })
                    .collect(),
                weight_bytes_8bit: s.usize_field("weight_bytes_8bit") as u64,
                fm_bytes_8bit: s.usize_field("fm_bytes_8bit") as u64,
                mean: s.get("mean").and_then(Json::as_f64).unwrap_or(0.0),
                std: s.get("std").and_then(Json::as_f64).unwrap_or(0.0),
            })
            .collect();
        Ok(Manifest {
            network: j.str_field("network").to_string(),
            input_shape: j.get("input_shape").unwrap().usize_vec(),
            boundary: j.usize_field("boundary"),
            stages,
            weights_file: j.str_field("weights_file").to_string(),
            golden_input: j.str_field("golden_input").to_string(),
            golden_logits: j.str_field("golden_logits").to_string(),
            dir: dir.to_path_buf(),
        })
    }

    /// Load a little-endian f32 blob referenced by the manifest.
    pub fn read_f32(&self, file: &str) -> Result<Vec<f32>> {
        read_f32_file(&self.dir.join(file))
    }
}

pub fn read_f32_file(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// A compiled, executable stage.
pub struct StageExe {
    pub spec: StageSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Pre-staged weight literals for WRCE stages, in parameter order. In
    /// the accelerator these live in off-chip DRAM; the coordinator
    /// "streams" them by passing them to every execution (the fully reused
    /// weight scheme reads each exactly once per frame).
    weights: Vec<xla::Literal>,
}

impl StageExe {
    /// Execute on one frame: `(H, W, C) -> (H', W', C')` as flat vecs.
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        let dims = &self.spec.in_shape;
        let expect: usize = dims.iter().product();
        if input.len() != expect {
            bail!("stage {}: input len {} != {:?}", self.spec.name, input.len(), dims);
        }
        let x = xla::Literal::vec1(input).reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?;
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&x);
        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Bytes of weights streamed from "DRAM" per frame (f32 on this
    /// substrate; the paper's 8-bit count is `spec.weight_bytes_8bit`).
    pub fn streamed_bytes_per_frame(&self) -> u64 {
        self.spec.params.iter().map(|p| p.len as u64 * 4).sum()
    }
}

/// The PJRT engine owning the client and all compiled stages of one
/// network.
pub struct Engine {
    pub manifest: Manifest,
    pub stages: Vec<StageExe>,
}

impl Engine {
    /// Façade entry point: load the engine for a
    /// [`crate::design::Design`]'s network (resolves the AOT artifact
    /// short name from the design).
    pub fn load_for(design: &crate::design::Design, dir: &Path) -> Result<Engine> {
        let short = design.network_short_or_err().map_err(|e| anyhow::anyhow!(e))?;
        Engine::load(dir, short)
    }

    /// Load + compile every stage of `<short>` (e.g. `"mbv2"`) from `dir`.
    pub fn load(dir: &Path, short: &str) -> Result<Engine> {
        let manifest = Manifest::load(dir, short)?;
        let client = xla::PjRtClient::cpu()?;
        let weights_blob = manifest.read_f32(&manifest.weights_file)?;
        let mut stages = Vec::with_capacity(manifest.stages.len());
        for spec in &manifest.stages {
            let proto = xla::HloModuleProto::from_text_file(
                manifest.dir.join(&spec.hlo_file).to_str().unwrap(),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let mut weights = Vec::with_capacity(spec.params.len());
            for p in &spec.params {
                let slice = &weights_blob[p.offset..p.offset + p.len];
                let lit = xla::Literal::vec1(slice)
                    .reshape(&p.shape.iter().map(|&d| d as i64).collect::<Vec<_>>())?;
                weights.push(lit);
            }
            stages.push(StageExe { spec: spec.clone(), exe, weights });
        }
        Ok(Engine { manifest, stages })
    }

    /// Run a frame through all stages sequentially (the single-threaded
    /// reference path; the coordinator pipelines stages across threads).
    pub fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
        let mut x = input.to_vec();
        for s in &self.stages {
            x = s.run(&x)?;
        }
        Ok(x)
    }

    /// Total per-frame DRAM weight traffic (8-bit model bytes), i.e. Eq 13's
    /// weight term evaluated on the compiled plan.
    pub fn dram_weight_bytes_8bit(&self) -> u64 {
        self.stages
            .iter()
            .filter(|s| s.spec.kind == StageKind::Wrce)
            .map(|s| s.spec.weight_bytes_8bit)
            .sum()
    }
}

/// Default artifacts directory: `$REPRO_ARTIFACTS` or `artifacts/`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("REPRO_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}
