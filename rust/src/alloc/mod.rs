//! Resource-aware memory and parallelism allocation (§IV-A, §V):
//!
//! * [`fgpm`] — the fine-grained parallel mechanism (Eq 11, §IV-A).
//! * [`memory_alloc`] — Algorithm 1, the balanced memory allocator that
//!   places the FRCE/WRCE group boundary.
//! * [`parallelism`] — Algorithm 2, the dynamic parallelism tuner, plus
//!   the factorized-granularity baseline.
//!
//! The full design-space exploration the paper performs per
//! (network, FPGA) pair lives behind the [`crate::design::Design`]
//! builder; the [`design_point`] free function remains as a deprecated
//! shim over it.

pub mod fgpm;
pub mod memory_alloc;
pub mod parallelism;

/// Process-wide Algorithm 1 / Algorithm 2 run counters.
///
/// Every call to [`balanced_memory_allocation`] (Alg 1) and
/// [`parallelism::dynamic_parallelism_tuning_with`] (Alg 2, which both
/// tuning entry points funnel through) ticks its counter. The counters
/// exist so the sweep cache's central claim — a warm-cache sweep performs
/// **zero** re-derivations — is *testable* rather than asserted: the
/// differential suite snapshots them around a warm [`crate::sweep`] run
/// and requires the deltas to be zero (`rust/tests/differential.rs`).
///
/// Monotonic, relaxed, never reset: callers compare before/after deltas,
/// so concurrent tests in other threads of the same process must
/// serialize around the measured region themselves.
pub mod derivations {
    use std::sync::atomic::{AtomicU64, Ordering};

    pub(crate) static ALG1_RUNS: AtomicU64 = AtomicU64::new(0);
    pub(crate) static ALG2_RUNS: AtomicU64 = AtomicU64::new(0);

    /// Times Algorithm 1 (balanced memory allocation) has run in this
    /// process.
    pub fn alg1_runs() -> u64 {
        ALG1_RUNS.load(Ordering::Relaxed)
    }

    /// Times Algorithm 2 (dynamic parallelism tuning) has run in this
    /// process.
    pub fn alg2_runs() -> u64 {
        ALG2_RUNS.load(Ordering::Relaxed)
    }
}

pub use fgpm::{factor_space, fgpm_space};
pub use memory_alloc::{balanced_memory_allocation, boundary_sweep, MemoryPlan};
pub use parallelism::{config_ladder, dynamic_parallelism_tuning, tune_and_evaluate, Granularity, ParallelismPlan};

use crate::model::throughput::Performance;
use crate::nets::Network;

/// A fully-resolved design point: CE plan + parallelism + predicted
/// performance and memory figures.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub memory: MemoryPlan,
    pub parallelism: ParallelismPlan,
    pub performance: Performance,
    pub sram_bytes: u64,
    pub dram_bytes: u64,
}

/// Run the complete resource-aware methodology for a (network, budget)
/// pair: Algorithm 1 then Algorithm 2, then re-cost the WRCE weight
/// buffers with the chosen kernel parallelism.
///
/// Deprecated shim over the [`crate::design::Design`] builder — it
/// produces the identical numbers; prefer
/// `Design::builder(net).platform(Platform::custom(..)).build()`, which
/// also carries the simulator options and persists to JSON.
#[deprecated(note = "use `Design::builder(&net).platform(...).build()` (crate::design) instead")]
pub fn design_point(
    net: &Network,
    sram_budget: u64,
    dsp_budget: usize,
    granularity: Granularity,
) -> DesignPoint {
    crate::design::Design::builder(net)
        .platform(crate::design::Platform::custom("custom", sram_budget, dsp_budget))
        .granularity(granularity)
        .build()
        .to_design_point()
}

#[cfg(test)]
#[allow(deprecated)] // the shim's own regression tests
mod tests {
    use super::*;
    use crate::nets::{mobilenet_v2, shufflenet_v2};
    use crate::zc706;

    #[test]
    fn zc706_design_points_match_paper_regime() {
        // Table III: MobileNetV2 ~1567 PEs / 985.8 FPS; ShuffleNetV2 ~1604
        // PEs / 2092.4 FPS. Check the methodology lands in the same regime
        // (within ~25% on FPS, PEs in the right band).
        let mb = design_point(&mobilenet_v2(), 0, zc706::DSP_BUDGET, Granularity::Fgpm);
        assert!(mb.performance.fps > 700.0 && mb.performance.fps < 1400.0, "fps {}", mb.performance.fps);
        assert!(mb.parallelism.pes > 1200 && mb.parallelism.pes < 1900, "pes {}", mb.parallelism.pes);

        let sn = design_point(&shufflenet_v2(), 0, zc706::DSP_BUDGET, Granularity::Fgpm);
        assert!(sn.performance.fps > 1400.0, "fps {}", sn.performance.fps);
    }

    #[test]
    fn sram_recosting_is_bounded() {
        let d = design_point(&mobilenet_v2(), zc706::SRAM_BYTES, zc706::DSP_BUDGET, Granularity::Fgpm);
        // Recosted SRAM (with real P_w ping-pong weight buffers) stays within
        // 2x of the Alg-1 estimate.
        assert!(d.sram_bytes < 2 * d.memory.sram_bytes.max(1) + (1 << 20));
    }
}
