//! Algorithm 1 — Balanced Memory Allocation (§V-A).
//!
//! Determines the FRCE/WRCE group boundary: the first iteration advances
//! the boundary while deploying the layer as FRCE costs no more SRAM than
//! deploying it as WRCE (yielding the minimum-SRAM configuration); the
//! second iteration keeps advancing while the total SRAM stays within the
//! target FPGA's budget, trading spare BRAM for reduced DRAM traffic.

use crate::model::dram;
use crate::model::memory::{sram_report, CePlan, MemoryModelCfg};
use crate::nets::Network;

/// Result of running Algorithm 1.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// Boundary after the first iteration: the minimum-SRAM configuration
    /// (the paper's default comparison configuration).
    pub boundary_min_sram: usize,
    /// Boundary after the second iteration for the given SRAM budget (the
    /// paper's "ZC706 version").
    pub boundary: usize,
    /// SRAM bytes at `boundary`.
    pub sram_bytes: u64,
    /// DRAM bytes/frame at `boundary`.
    pub dram_bytes: u64,
}

/// One point of the Fig 12 boundary sweep.
#[derive(Debug, Clone, Copy)]
pub struct BoundaryPoint {
    pub boundary: usize,
    pub sram_bytes: u64,
    pub dram_bytes: u64,
}

/// Evaluate SRAM/DRAM for every boundary location (Fig 12's x-axis).
pub fn boundary_sweep(net: &Network, cfg: &MemoryModelCfg) -> Vec<BoundaryPoint> {
    (0..=net.layers.len())
        .map(|b| {
            let plan = CePlan { boundary: b };
            BoundaryPoint {
                boundary: b,
                sram_bytes: sram_report(net, &plan, cfg).total(),
                dram_bytes: dram::proposed(net, &plan).total(),
            }
        })
        .collect()
}

/// Algorithm 1. `sram_budget` is the available on-chip memory in bytes
/// (e.g. [`crate::zc706::SRAM_BYTES`]).
pub fn balanced_memory_allocation(net: &Network, sram_budget: u64, cfg: &MemoryModelCfg) -> MemoryPlan {
    crate::alloc::derivations::ALG1_RUNS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let l_total = net.layers.len();

    // First iteration: find the minimum-SRAM boundary by incrementally
    // advancing it layer by layer. The paper stops at the first layer whose
    // FRCE deployment costs more SRAM than its WRCE deployment; because
    // DWC layers have near-zero WRCE footprints that per-layer test can
    // fire spuriously mid-group, so we walk the whole prefix and keep the
    // arg-min — identical under the paper's "typical distribution"
    // assumption and robust otherwise. The per-layer FRCE-vs-WRCE
    // comparison itself is exposed as
    // [`crate::model::memory::frce_vs_wrce_cost`] and tested to agree on
    // PWC/STC layers.
    let mut num_frce = 0;
    let mut best = u64::MAX;
    for b in 0..=l_total {
        let total = sram_report(net, &CePlan { boundary: b }, cfg).total();
        if total < best {
            best = total;
            num_frce = b;
        }
    }
    let boundary_min_sram = num_frce;

    // Second iteration: keep advancing while total SRAM fits the budget.
    for i in num_frce..l_total {
        let plan = CePlan { boundary: i + 1 };
        let total = sram_report(net, &plan, cfg).total();
        if total < sram_budget {
            num_frce = i + 1;
        } else {
            break;
        }
    }

    let plan = CePlan { boundary: num_frce };
    MemoryPlan {
        boundary_min_sram,
        boundary: num_frce,
        sram_bytes: sram_report(net, &plan, cfg).total(),
        dram_bytes: dram::proposed(net, &plan).total(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{all_networks, mobilenet_v2};
    use crate::zc706;

    fn cfg() -> MemoryModelCfg {
        MemoryModelCfg::default()
    }

    #[test]
    fn sweep_is_u_shaped_in_sram() {
        // Fig 12: "the SRAM size follows a U-shaped pattern as the group
        // boundary advances" — the minimum is strictly inside (0, L) and the
        // endpoints are costlier than the minimum.
        for net in all_networks() {
            let sweep = boundary_sweep(&net, &cfg());
            let min = sweep.iter().map(|p| p.sram_bytes).min().unwrap();
            let first = sweep.first().unwrap().sram_bytes;
            let last = sweep.last().unwrap().sram_bytes;
            assert!(min < first && min < last, "{}: not U-shaped", net.name);
        }
    }

    #[test]
    fn sweep_dram_monotone_decreasing() {
        for net in all_networks() {
            let sweep = boundary_sweep(&net, &cfg());
            for w in sweep.windows(2) {
                assert!(w[1].dram_bytes <= w[0].dram_bytes, "{}", net.name);
            }
        }
    }

    #[test]
    fn first_iteration_lands_near_sram_minimum() {
        // "this configuration is considered to represent the minimum
        // requirement of SRAM size" — the greedy first iteration should land
        // within a few percent of the global sweep minimum.
        for net in all_networks() {
            let plan = balanced_memory_allocation(&net, 0, &cfg());
            let sweep = boundary_sweep(&net, &cfg());
            let min = sweep.iter().map(|p| p.sram_bytes).min().unwrap() as f64;
            let got = sweep[plan.boundary_min_sram].sram_bytes as f64;
            assert!(got <= min * 1.15, "{}: {} vs min {}", net.name, got, min);
        }
    }

    #[test]
    fn zero_budget_stops_at_min_sram() {
        let net = mobilenet_v2();
        let plan = balanced_memory_allocation(&net, 0, &cfg());
        assert_eq!(plan.boundary, plan.boundary_min_sram);
    }

    #[test]
    fn zc706_budget_advances_boundary_and_cuts_dram() {
        // Table III: the ZC706 configurations trade SRAM for reduced DRAM
        // traffic relative to the min-SRAM configurations.
        for net in all_networks() {
            let min_plan = balanced_memory_allocation(&net, 0, &cfg());
            let big_plan = balanced_memory_allocation(&net, zc706::SRAM_BYTES, &cfg());
            assert!(big_plan.boundary >= min_plan.boundary, "{}", net.name);
            assert!(big_plan.dram_bytes <= min_plan.dram_bytes, "{}", net.name);
            assert!(big_plan.sram_bytes < zc706::SRAM_BYTES, "{}", net.name);
        }
    }

    #[test]
    fn huge_budget_deploys_everything_frce() {
        // "In extreme scenarios with abundant memory resources ... the
        // entire model can be deployed with FRCEs."
        let net = mobilenet_v2();
        let plan = balanced_memory_allocation(&net, u64::MAX, &cfg());
        assert_eq!(plan.boundary, net.layers.len());
        assert_eq!(plan.dram_bytes, 0);
    }
}
