//! Algorithm 2 — Dynamic Parallelism Tuning (§V-B), plus the
//! factorized-granularity baseline used throughout Figs 10/15/16/17.
//!
//! Starting from `P_w = P_f = 1` everywhere (so `T(i) = O(i)`), the tuner
//! repeatedly finds the bottleneck CE(s) and raises their parallelism to
//! the next level of their config ladder until the DSP budget is
//! exhausted. Ladders honour the CE-type priorities of §III-C: FRCEs grow
//! the kernel dimension `P_w` first (more output channels per iteration,
//! no output buffer), WRCEs grow the FM dimension `P_f` first (wider
//! output scope per loaded kernel).

use crate::model::memory::{CeKind, CePlan};
use crate::model::throughput::{self, LayerAlloc};
use crate::nets::{Layer, Network};

use super::fgpm::{factor_space, fgpm_space};

/// Parallelism granularity mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// The proposed fine-grained parallel mechanism.
    Fgpm,
    /// Conventional factorized granularity (baseline).
    Factorized,
}

fn dim_space(m: usize, g: Granularity) -> Vec<usize> {
    match g {
        Granularity::Fgpm => fgpm_space(m),
        Granularity::Factorized => factor_space(m),
    }
}

/// The ordered config ladder of one layer: the Pareto front of the 2D
/// `(P_w, P_f)` product space — every rung strictly decreases computing
/// time at strictly increasing PE cost. The CE-type priority of §III-C
/// breaks ties between equal-(T, PE) configs: FRCEs prefer kernel-side
/// parallelism (results stream out channel-first with no output buffer),
/// WRCEs prefer FM-side parallelism (wider output scope per loaded
/// kernel).
pub fn config_ladder(l: &Layer, kind: CeKind, g: Granularity) -> Vec<LayerAlloc> {
    if !l.kind.is_mac() {
        return vec![LayerAlloc::ONE];
    }
    if g == Granularity::Factorized {
        // Conventional factorized allocation sweeps the CE's natural
        // parallel dimension first and only then multiplies the secondary
        // dimension on top (the baseline of Figs 10/15/16) — it has no
        // fine-grained 2D space to draw from.
        let (pref_max, sec_max, pw_first) = match kind {
            CeKind::Frce => (l.max_pw(), l.max_pf(), true),
            CeKind::Wrce => (l.max_pf(), l.max_pw(), false),
        };
        let mut ladder: Vec<LayerAlloc> = Vec::new();
        for p in factor_space(pref_max) {
            ladder.push(if pw_first { LayerAlloc { pw: p, pf: 1 } } else { LayerAlloc { pw: 1, pf: p } });
        }
        for p in factor_space(sec_max).into_iter().skip(1) {
            ladder.push(if pw_first {
                LayerAlloc { pw: pref_max, pf: p }
            } else {
                LayerAlloc { pw: p, pf: pref_max }
            });
        }
        let mut out: Vec<LayerAlloc> = Vec::new();
        let mut last_t = u64::MAX;
        for a in ladder {
            let t = throughput::layer_cycles(l, a);
            if t < last_t {
                out.push(a);
                last_t = t;
            }
        }
        return out;
    }
    let pws = dim_space(l.max_pw(), g);
    let pfs = dim_space(l.max_pf(), g);
    let mut cands: Vec<(u64, usize, usize, LayerAlloc)> = Vec::with_capacity(pws.len() * pfs.len());
    for &pw in &pws {
        for &pf in &pfs {
            let a = LayerAlloc { pw, pf };
            let pref = match kind {
                CeKind::Frce => pw,
                CeKind::Wrce => pf,
            };
            cands.push((throughput::layer_cycles(l, a), a.pes(), usize::MAX - pref, a));
        }
    }
    // Sort by PE cost, then by T, then by the CE-type preference; sweep to
    // keep the strictly-decreasing-T front.
    cands.sort_by_key(|&(t, pes, pref_inv, _)| (pes, t, pref_inv));
    let mut out: Vec<LayerAlloc> = Vec::new();
    let mut last_t = u64::MAX;
    for (t, _, _, a) in cands {
        if t < last_t {
            out.push(a);
            last_t = t;
        }
    }
    out
}

/// What resource Algorithm 2's budget counts.
///
/// The ZC706 implementation budgets DSP48E1 slices (with 2x 8-bit
/// decomposition); the Fig 15/16 scalability sweeps budget raw MAC units
/// ("60-4000 MACs").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetKind {
    Dsp,
    Pes,
}

/// Outcome of Algorithm 2.
#[derive(Debug, Clone)]
pub struct ParallelismPlan {
    pub allocs: Vec<LayerAlloc>,
    pub granularity: Granularity,
    /// DSPs consumed (after 2x 8-bit decomposition).
    pub dsps: usize,
    /// Total MAC units.
    pub pes: usize,
}

/// Algorithm 2: greedy bottleneck-first DSP assignment.
///
/// `dsp_budget` is the DSP constraint (e.g. [`crate::zc706::DSP_BUDGET`]);
/// `ce_plan` supplies the FRCE/WRCE split that decides ladder priorities.
pub fn dynamic_parallelism_tuning(
    net: &Network,
    ce_plan: &CePlan,
    dsp_budget: usize,
    g: Granularity,
) -> ParallelismPlan {
    dynamic_parallelism_tuning_with(net, ce_plan, dsp_budget, g, BudgetKind::Dsp)
}

/// Algorithm 2 with an explicit budget kind (see [`BudgetKind`]).
pub fn dynamic_parallelism_tuning_with(
    net: &Network,
    ce_plan: &CePlan,
    dsp_budget: usize,
    g: Granularity,
    budget_kind: BudgetKind,
) -> ParallelismPlan {
    crate::alloc::derivations::ALG2_RUNS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let ladders: Vec<Vec<LayerAlloc>> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| config_ladder(l, ce_plan.kind(i), g))
        .collect();
    let mut level = vec![0usize; net.layers.len()];
    let alloc_at = |level: &[usize], i: usize| ladders[i][level[i]];
    let times = |level: &[usize]| -> Vec<u64> {
        net.layers
            .iter()
            .enumerate()
            .map(|(i, l)| if l.kind.is_mac() { throughput::layer_cycles(l, alloc_at(level, i)) } else { 0 })
            .collect()
    };
    let dsp_total = |level: &[usize]| -> usize {
        net.layers
            .iter()
            .enumerate()
            .map(|(i, l)| match budget_kind {
                BudgetKind::Dsp => throughput::layer_dsps(l, alloc_at(level, i)),
                BudgetKind::Pes => {
                    if l.kind.is_mac() {
                        alloc_at(level, i).pes()
                    } else {
                        0
                    }
                }
            })
            .sum()
    };

    // Greedy bottleneck-first tuning (the paper's while-loop): each
    // iteration raises every CE currently at T_max one rung, skipping rungs
    // that would overflow the DSP budget. When no bottleneck CE can be
    // raised (ladder saturated or budget exhausted) the throughput is
    // final and the loop stops.
    loop {
        let t = times(&level);
        let t_max = *t.iter().max().unwrap();
        if t_max == 0 {
            break;
        }
        // Trim slack: every non-bottleneck CE drops to the cheapest rung
        // that still meets the bottleneck period. Greedy bumps overshoot
        // whenever a rung more than halves a layer's T; reclaiming the
        // overshoot is what lets the saved PEs "be reallocated to the
        // slowest layer" (Fig 10(b)).
        for i in 0..net.layers.len() {
            while level[i] > 0 {
                let t_down = throughput::layer_cycles(&net.layers[i], ladders[i][level[i] - 1]);
                if t_down <= t_max {
                    level[i] -= 1;
                } else {
                    break;
                }
            }
        }
        // T_max only drops if EVERY bottleneck CE advances a rung, so the
        // bump is all-or-nothing: a partial bump would spend DSPs without
        // improving throughput (the waste Fig 10(a) attributes to the
        // staircase effect).
        let bottlenecks: Vec<usize> = (0..net.layers.len()).filter(|&i| t[i] == t_max).collect();
        if bottlenecks.iter().any(|&i| level[i] + 1 >= ladders[i].len()) {
            break;
        }
        for &i in &bottlenecks {
            level[i] += 1;
        }
        if dsp_total(&level) > dsp_budget {
            for &i in &bottlenecks {
                level[i] -= 1;
            }
            break;
        }
    }

    let allocs: Vec<LayerAlloc> = (0..net.layers.len()).map(|i| alloc_at(&level, i)).collect();
    // Report true DSP slices regardless of which resource was budgeted.
    let dsps = net
        .layers
        .iter()
        .zip(&allocs)
        .map(|(l, &a)| throughput::layer_dsps(l, a))
        .sum();
    let pes = net
        .layers
        .iter()
        .zip(&allocs)
        .filter(|(l, _)| l.kind.is_mac())
        .map(|(_, a)| a.pes())
        .sum();
    ParallelismPlan { allocs, granularity: g, dsps, pes }
}

/// Convenience: tune and evaluate in one call.
pub fn tune_and_evaluate(
    net: &Network,
    ce_plan: &CePlan,
    dsp_budget: usize,
    g: Granularity,
) -> (ParallelismPlan, throughput::Performance) {
    let plan = dynamic_parallelism_tuning(net, ce_plan, dsp_budget, g);
    let perf = throughput::evaluate(net, &plan.allocs);
    (plan, perf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{mobilenet_v2, shufflenet_v2};
    use crate::zc706;

    fn mid_plan(net: &Network) -> CePlan {
        CePlan { boundary: net.layers.len() / 2 }
    }

    #[test]
    fn ladder_times_strictly_decrease() {
        let net = mobilenet_v2();
        for (i, l) in net.layers.iter().enumerate() {
            for kind in [CeKind::Frce, CeKind::Wrce] {
                let ladder = config_ladder(l, kind, Granularity::Fgpm);
                let mut last = u64::MAX;
                for a in ladder {
                    let t = throughput::layer_cycles(l, a);
                    assert!(t < last, "{} level not decreasing", i);
                    last = t;
                }
            }
        }
    }

    #[test]
    fn ladder_priorities_follow_ce_kind() {
        let net = mobilenet_v2();
        let l = net.layers.iter().find(|l| l.kind == crate::nets::LayerKind::Pwc).unwrap();
        let fr = config_ladder(l, CeKind::Frce, Granularity::Fgpm);
        let wr = config_ladder(l, CeKind::Wrce, Granularity::Fgpm);
        // Second rung grows the preferred dimension.
        assert!(fr[1].pw > 1 && fr[1].pf == 1);
        assert!(wr[1].pf > 1 && wr[1].pw == 1);
    }

    #[test]
    fn respects_dsp_budget() {
        let net = mobilenet_v2();
        for budget in [64, 256, 855, 2048] {
            let plan = dynamic_parallelism_tuning(&net, &mid_plan(&net), budget, Granularity::Fgpm);
            assert!(plan.dsps <= budget, "budget {budget}: used {}", plan.dsps);
        }
    }

    #[test]
    fn fgpm_never_slower_than_factorized() {
        for net in [mobilenet_v2(), shufflenet_v2()] {
            for budget in [128, 512, 855] {
                let cp = mid_plan(&net);
                let (_, pf) = tune_and_evaluate(&net, &cp, budget, Granularity::Fgpm);
                let (_, pb) = tune_and_evaluate(&net, &cp, budget, Granularity::Factorized);
                assert!(
                    pf.t_max <= pb.t_max,
                    "{} @{budget}: fgpm {} vs factorized {}",
                    net.name,
                    pf.t_max,
                    pb.t_max
                );
            }
        }
    }

    #[test]
    fn zc706_fgpm_hits_high_efficiency_and_utilization() {
        // Table IV: 94.35% MAC efficiency, 844/900 DSPs for MobileNetV2.
        // The theoretical model should land in the >90% efficiency,
        // >90% DSP-utilization regime.
        let net = mobilenet_v2();
        let (plan, perf) = tune_and_evaluate(&net, &mid_plan(&net), zc706::DSP_BUDGET, Granularity::Fgpm);
        assert!(perf.mac_efficiency > 0.90, "eff {}", perf.mac_efficiency);
        assert!(plan.dsps > 760, "dsps {}", plan.dsps);
        // And the throughput should be in the high-hundreds FPS range the
        // paper reports (985.8 FPS).
        assert!(perf.fps > 600.0, "fps {}", perf.fps);
    }

    #[test]
    fn more_dsps_never_hurt_throughput() {
        let net = shufflenet_v2();
        let cp = mid_plan(&net);
        let mut last = u64::MAX;
        for budget in [60, 120, 240, 480, 855, 1700] {
            let (_, perf) = tune_and_evaluate(&net, &cp, budget, Granularity::Fgpm);
            assert!(perf.t_max <= last);
            last = perf.t_max;
        }
    }
}
