//! Fine-Grained Parallel Mechanism (FGPM) — §IV-A.
//!
//! For a parallel dimension with maximum parallelism `M`, conventional
//! streaming accelerators pick `P` from the *factors* of `M` (factorized
//! granularity). FGPM instead admits every integer `P` that yields a
//! distinct computing-round count `T = ceil(M/P)` (Eq 11), giving a
//! parallel space of exactly `2 * floor(sqrt(M))` distinct times — always
//! at least as large as the factor count. Non-factor parallelisms are
//! realized by dimension padding; the padded excess is discarded at the CE
//! boundary.

/// Eq (11): computing rounds for parallelism `p` over dimension size `m`.
pub fn rounds(m: usize, p: usize) -> usize {
    m.div_ceil(p)
}

/// The FGPM parallel space of dimension `m`: the ascending set of
/// parallelism values that each produce a distinct `T = ceil(m/p)`,
/// keeping the *smallest* `p` for each `T` (any larger `p` with the same
/// `T` wastes PEs on padding without reducing time).
pub fn fgpm_space(m: usize) -> Vec<usize> {
    if m == 0 {
        return vec![];
    }
    let mut ps = Vec::new();
    // Jump enumeration: from parallelism p with T = ceil(m/p), the smallest
    // p' achieving a strictly smaller T' is floor((m-1)/(T-1)) + 1. This
    // visits exactly one representative (the cheapest) per distinct T.
    let mut p = 1;
    loop {
        let t = m.div_ceil(p);
        ps.push(p);
        if t == 1 {
            break;
        }
        p = (m - 1) / (t - 1) + 1;
    }
    ps
}

/// The factorized-granularity space: the divisors of `m` (the baseline the
/// paper compares against in Figs 10/15/16).
pub fn factor_space(m: usize) -> Vec<usize> {
    let mut fs = Vec::new();
    let mut d = 1;
    while d * d <= m {
        if m % d == 0 {
            fs.push(d);
            if d != m / d {
                fs.push(m / d);
            }
        }
        d += 1;
    }
    fs.sort_unstable();
    fs
}

/// Size of the FGPM space in O(1), without materializing it — the exact
/// count of distinct `T = ceil(m/p)` values, which the paper approximates
/// as `2 * floor(sqrt(M))`.
///
/// Derivation: `ceil(m/p) = floor((m-1)/p) + 1` for every `p >= 1`, so
/// with `n = m - 1` the distinct `T` values over `p in 1..=m` are the
/// distinct values of `floor(n/p)` shifted by one, plus the extra
/// `T = 1` contributed by `p = m` (where `floor(n/m) = 0`). The classic
/// divisor-count identity gives, with `s = floor(sqrt(n))`, exactly
/// `2s - 1` distinct `floor(n/p)` values when `n < s*(s+1)` (the
/// perfect-square/overlap correction: the two `sqrt`-halves share their
/// middle value) and `2s` otherwise.
///
/// The constrained optimizer ([`crate::sweep::optimize`]) calls this in
/// its pruning loop to account the parallel space a pruned candidate
/// covers, so it must not rebuild the space per call; equality with
/// `fgpm_space(m).len()` for every `m in 1..=4096` is pinned by
/// `space_size_closed_form_matches_materialized_space` below.
///
/// # Examples
///
/// ```
/// use repro::alloc::fgpm::{fgpm_space, fgpm_space_size};
///
/// assert_eq!(fgpm_space_size(0), 0);
/// for m in [1, 2, 32, 116, 512] {
///     assert_eq!(fgpm_space_size(m), fgpm_space(m).len());
/// }
/// ```
pub fn fgpm_space_size(m: usize) -> usize {
    match m {
        0 => 0,
        1 => 1,
        _ => {
            let n = m - 1;
            let s = isqrt(n);
            let distinct = if n < s * (s + 1) { 2 * s - 1 } else { 2 * s };
            distinct + 1
        }
    }
}

/// Integer square root (`usize::isqrt` needs a newer toolchain than the
/// offline build guarantees): float estimate corrected to exactness.
fn isqrt(n: usize) -> usize {
    let mut s = (n as f64).sqrt() as usize;
    while s.saturating_mul(s) > n {
        s -= 1;
    }
    while (s + 1).saturating_mul(s + 1) <= n {
        s += 1;
    }
    s
}

/// Padded dimension size when running `m` at parallelism `p`: the hardware
/// computes `p * ceil(m/p)` lanes and discards the excess (§IV-A,
/// "dimension padding").
pub fn padded_dim(m: usize, p: usize) -> usize {
    p * m.div_ceil(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_size_formula() {
        // "the size of the parallel space is 2 x floor(sqrt(M))" — the
        // paper's closed form counts the distinct T values; our space keeps
        // one representative p per T, so the sizes agree within the
        // perfect-square overlap of 1.
        for m in [7, 32, 64, 100, 128, 256, 512, 960, 1280] {
            let sz = fgpm_space(m).len();
            let formula = 2 * (m as f64).sqrt().floor() as usize;
            assert!(
                (sz as i64 - formula as i64).abs() <= 1,
                "m={m}: space {sz} vs formula {formula}"
            );
        }
    }

    #[test]
    fn space_size_closed_form_matches_materialized_space() {
        // The O(1) closed form must agree with the materialized space
        // everywhere the optimizer's pruning loop can reach it.
        assert_eq!(fgpm_space_size(0), 0);
        for m in 1..=4096 {
            assert_eq!(fgpm_space_size(m), fgpm_space(m).len(), "m={m}");
        }
    }

    #[test]
    fn isqrt_is_exact_at_square_boundaries() {
        for r in 0..=128usize {
            let sq = r * r;
            assert_eq!(isqrt(sq), r);
            if sq > 0 {
                assert_eq!(isqrt(sq - 1), r - 1);
                assert_eq!(isqrt(sq + 1), r);
            }
        }
    }

    #[test]
    fn distinct_round_counts() {
        for m in [31, 32, 100, 116, 512] {
            let space = fgpm_space(m);
            let mut ts: Vec<usize> = space.iter().map(|&p| rounds(m, p)).collect();
            let n = ts.len();
            ts.dedup();
            assert_eq!(ts.len(), n, "duplicate T in space of {m}");
            // And every achievable T is covered.
            let mut all: Vec<usize> = (1..=m).map(|p| rounds(m, p)).collect();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), n, "m={m}: missing T values");
        }
    }

    #[test]
    fn fgpm_superset_of_factor_times() {
        // Every computing time reachable with factorized granularity is
        // reachable under FGPM (with no more PEs).
        for m in [24, 116, 232, 464, 960] {
            let ftimes: Vec<usize> = factor_space(m).iter().map(|&p| rounds(m, p)).collect();
            let gtimes: Vec<usize> = fgpm_space(m).iter().map(|&p| rounds(m, p)).collect();
            for t in ftimes {
                assert!(gtimes.contains(&t), "m={m}: T={t} missing");
            }
        }
    }

    #[test]
    fn paper_growth_percentages() {
        // "using common output channel numbers like 32, 64, 128, 256, and
        // 512, the size of parallel space can be increased by 67%, 114%,
        // 175%, 244%, and 340%"
        let expect = [(32usize, 0.67), (64, 1.14), (128, 1.75), (256, 2.44), (512, 3.40)];
        for (m, growth) in expect {
            let f = factor_space(m).len() as f64;
            let g = fgpm_space(m).len() as f64;
            // The paper counts the space with its 2*floor(sqrt(M)) closed
            // form; the exact distinct-T count can differ by one element,
            // so compare within one element of the implied size.
            let implied = f * (1.0 + growth);
            assert!((g - implied).abs() <= 1.01, "m={m}: space {g} vs implied {implied:.1}");
        }
    }

    #[test]
    fn sparse_factor_dims_benefit_most() {
        // ShuffleNetV2's 116/232/464 channels have sparse factors — the
        // motivation for FGPM's ShuffleNetV2 gains in Fig 15(d).
        for m in [116, 232, 464] {
            assert!(fgpm_space(m).len() as f64 >= 2.5 * factor_space(m).len() as f64);
        }
    }

    #[test]
    fn padding_bounds() {
        for m in [17, 116, 960] {
            for &p in fgpm_space(m).iter() {
                let pad = padded_dim(m, p);
                assert!(pad >= m && pad < m + p);
            }
        }
    }
}
