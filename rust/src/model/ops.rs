//! Operation counts and FM memory-access costs — Eqs (1)-(10) of §II-A.
//!
//! These closed forms are stated for an isolated structure (stride 1,
//! padding included, `F x F` FMs, `M`/`N` channels, `K x K` kernels); the
//! per-[`crate::nets::Layer`] generalizations live on the `Layer` methods.
//! This module keeps the paper's exact formulas so tests can check both
//! against each other, and provides the DSC/SCB-vs-STC ratio analysis the
//! paper uses to motivate the architecture.

/// MACs of a standard convolution (Eq 1): `F^2 * K^2 * M * N`.
pub fn o_stc(f: u64, k: u64, m: u64, n: u64) -> u64 {
    f * f * k * k * m * n
}

/// MACs of a depthwise-separable convolution (Eq 2):
/// `O_DWC + O_PWC = F^2 * M * (K^2 + N)`.
pub fn o_dsc(f: u64, k: u64, m: u64, n: u64) -> u64 {
    f * f * m * (k * k + n)
}

/// MACs of a skip-connection block's element-wise additions (Eq 3):
/// `M * F^2 / 2` — additions count as half MACs.
pub fn o_scb(f: u64, m: u64) -> u64 {
    m * f * f / 2
}

/// FM memory access of a standard convolution (Eq 4): `F^2 * (M + N)`.
pub fn a_stc(f: u64, m: u64, n: u64) -> u64 {
    f * f * (m + n)
}

/// FM memory access of a DSC (Eq 5): `F^2 * (3M + N)` — the extra `2M`
/// term is the intermediate FM written by the DWC and read by the PWC.
pub fn a_dsc(f: u64, m: u64, n: u64) -> u64 {
    f * f * (3 * m + n)
}

/// FM memory access of an SCB (Eq 6): `M_in + M_mid + M_out = 3 * M * F^2`.
pub fn a_scb(f: u64, m: u64) -> u64 {
    3 * m * f * f
}

/// Eq (7): `RA_DSC = 1 + 2M / (M + N)`.
pub fn ra_dsc(m: f64, n: f64) -> f64 {
    1.0 + 2.0 * m / (m + n)
}

/// Eq (8): `RO_DSC = 1/N + 1/K^2`.
pub fn ro_dsc(k: f64, n: f64) -> f64 {
    1.0 / n + 1.0 / (k * k)
}

/// Eq (9): `RA_SCB = 3M / (M + N)`.
pub fn ra_scb(m: f64, n: f64) -> f64 {
    3.0 * m / (m + n)
}

/// Eq (10): `RO_SCB = 1 / (2 * N * K^2)`.
pub fn ro_scb(k: f64, n: f64) -> f64 {
    1.0 / (2.0 * n * k * k)
}

/// Operational intensity proxy: MACs per FM element accessed. The paper's
/// motivation (Fig 2) is that DSC/SCB have far lower intensity than STC.
pub fn intensity_ratio_dsc_vs_stc(f: u64, k: u64, m: u64, n: u64) -> f64 {
    let dsc = o_dsc(f, k, m, n) as f64 / a_dsc(f, m, n) as f64;
    let stc = o_stc(f, k, m, n) as f64 / a_stc(f, m, n) as f64;
    dsc / stc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_are_consistent_with_closed_forms() {
        for &(f, k, m, n) in &[(56u64, 3u64, 64u64, 128u64), (14, 3, 160, 160), (7, 3, 320, 1280)] {
            let ra = a_dsc(f, m, n) as f64 / a_stc(f, m, n) as f64;
            assert!((ra - ra_dsc(m as f64, n as f64)).abs() < 1e-12);
            let ro = o_dsc(f, k, m, n) as f64 / o_stc(f, k, m, n) as f64;
            assert!((ro - ro_dsc(k as f64, n as f64)).abs() < 1e-12);
            let ra_s = a_scb(f, m) as f64 / a_stc(f, m, n) as f64;
            assert!((ra_s - ra_scb(m as f64, n as f64)).abs() < 1e-12);
            let ro_s = o_scb(f, m) as f64 / o_stc(f, k, m, n) as f64;
            assert!((ro_s - ro_scb(k as f64, n as f64)).abs() < 1e-9);
        }
    }

    #[test]
    fn dsc_reduces_ops_but_increases_access() {
        // "DSC reduces operations by nearly K^2 times compared to STC but
        // increases FM access by about one time."
        let (f, k, m, n) = (56, 3, 128, 128);
        let ro = ro_dsc(k as f64, n as f64);
        assert!(ro < 1.2 / (k * k) as f64 + 0.01);
        let ra = ra_dsc(m as f64, n as f64);
        assert!(ra > 1.9 && ra <= 2.0);
    }

    #[test]
    fn scb_is_access_dominated() {
        // SCB: ~1.5x the FM access of an STC for ~1/(2NK^2) of its MACs.
        let (k, m, n) = (3.0, 64.0, 64.0);
        assert!(ra_scb(m, n) == 1.5);
        assert!(ro_scb(k, n) < 0.001);
    }

    #[test]
    fn intensity_collapse() {
        // The DSC's ops/byte is at least ~5x lower than the STC's at typical
        // LWCNN shapes — the paper's core motivation.
        assert!(intensity_ratio_dsc_vs_stc(56, 3, 64, 128) < 0.2);
    }
}
