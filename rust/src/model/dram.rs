//! Off-chip (DRAM) traffic model — Eq (13) of §V-A and the UE/SE baseline
//! comparison of Fig 14. All quantities are bytes per inference frame at
//! 8-bit precision; the network input image and final results are excluded
//! (as in the paper).

use crate::nets::{LayerKind, LayerSrc, Network};

use super::memory::{scb_on_chip, CePlan};

/// Per-architecture DRAM traffic, split the way Fig 14 plots it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DramTraffic {
    /// Intermediate feature-map reads + writes.
    pub fm: u64,
    /// Shortcut (SCB) data movement.
    pub shortcut: u64,
    /// Weight fetches.
    pub weights: u64,
}

impl DramTraffic {
    pub fn total(&self) -> u64 {
        self.fm + self.shortcut + self.weights
    }
}

/// The proposed streaming architecture under a CE plan (Eq 13):
/// `DRAM_total = sum_{i=l..L} (Weight(i) + Shortcut(i))` — only WRCE-region
/// weights are fetched (exactly once each, fully-reused weight scheme) and
/// only WRCE-region shortcuts spill off-chip (write + read = twice the
/// snapshot size).
pub fn proposed(net: &Network, plan: &CePlan) -> DramTraffic {
    let mut t = DramTraffic::default();
    for (i, l) in net.layers.iter().enumerate() {
        if i >= plan.boundary && l.kind.has_weights() {
            t.weights += l.weight_bytes();
        }
        // Tee branches in the WRCE region buffer their stream off-chip,
        // like shortcuts.
        if i >= plan.boundary {
            if let LayerSrc::Tee(j) = l.src {
                t.shortcut += 2 * net.layers[j].in_fm_bytes();
            }
        }
    }
    for scb in &net.scbs {
        if !scb_on_chip(scb, plan) {
            t.shortcut += 2 * scb.snapshot_bytes(net);
        }
    }
    t
}

/// Unified-CE overlay baseline (Light-OPU-class, [2]): every layer's input
/// FM is read from and output FM written to DRAM; all weights fetched; the
/// shortcut snapshot is re-read at the join. "All data in the UE
/// architecture are accessed off-chip exactly once."
pub fn unified_ce(net: &Network) -> DramTraffic {
    let mut t = DramTraffic::default();
    for l in &net.layers {
        if l.kind.is_mac() || matches!(l.kind, LayerKind::MaxPool | LayerKind::AvgPool | LayerKind::Add) {
            t.fm += l.in_fm_bytes() + l.out_fm_bytes();
        }
        t.weights += l.weight_bytes();
    }
    for scb in &net.scbs {
        t.shortcut += scb.snapshot_bytes(net);
    }
    t
}

/// Separated-CE baseline ([3]-[5]): the dedicated DWC engine is fused with
/// the adjacent PWC, eliminating DRAM FM traffic for every DWC layer.
pub fn separated_ce(net: &Network) -> DramTraffic {
    let mut t = unified_ce(net);
    for l in &net.layers {
        if l.kind == LayerKind::Dwc {
            t.fm -= l.in_fm_bytes() + l.out_fm_bytes();
        }
    }
    t
}

/// Weight traffic of a partial-fusion dataflow ([10]): FMs of an SCB are
/// tiled and fused, but weights are re-fetched once per tile.
pub fn partial_fusion_weights(net: &Network, tiles: u64) -> u64 {
    net.layers.iter().map(|l| l.weight_bytes()).sum::<u64>() * tiles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::memory::CePlan;
    use crate::nets::{all_networks, mobilenet_v2};

    #[test]
    fn proposed_eliminates_intermediate_fm_traffic() {
        for net in all_networks() {
            for b in [0, net.layers.len() / 2, net.layers.len()] {
                assert_eq!(proposed(&net, &CePlan { boundary: b }).fm, 0, "{}", net.name);
            }
        }
    }

    #[test]
    fn full_frce_plan_needs_no_dram() {
        for net in all_networks() {
            let t = proposed(&net, &CePlan { boundary: net.layers.len() });
            assert_eq!(t.total(), 0, "{}", net.name);
        }
    }

    #[test]
    fn dram_decreases_as_boundary_advances() {
        let net = mobilenet_v2();
        let mut prev = u64::MAX;
        for b in 0..=net.layers.len() {
            let t = proposed(&net, &CePlan { boundary: b }).total();
            assert!(t <= prev, "boundary {b}: {t} > {prev}");
            prev = t;
        }
    }

    #[test]
    fn fig14_ordering_ue_ge_se_ge_proposed() {
        for net in all_networks() {
            let ue = unified_ce(&net);
            let se = separated_ce(&net);
            let ours = proposed(&net, &CePlan { boundary: 0 });
            assert!(ue.total() >= se.total(), "{}", net.name);
            assert!(se.total() >= ours.total(), "{}", net.name);
            // FM access reduction vs UE is ~98% in the paper; with boundary 0
            // ours is exactly 0 here.
            assert!(ue.fm > 0 && se.fm < ue.fm);
        }
    }

    #[test]
    fn ue_weight_traffic_equals_model_size() {
        let net = mobilenet_v2();
        assert_eq!(unified_ce(&net).weights, net.total_weight_bytes());
    }
}
