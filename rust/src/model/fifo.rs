//! Side-FIFO depth estimation — the inter-CE sizing the SRAM model
//! (Eq 12) does not cover but real dataflow builds live or die by
//! (undersizing is exactly the pipeline-deadlock failure mode — the typed
//! simulation error out of [`crate::sim::Pipeline::run`] — the paper's
//! delayed-buffer sizing exists to prevent).
//!
//! A *side FIFO* is any stream that leaves the main CE chain: an SCB
//! shortcut snapshot delayed until its join layer (§III-B, Fig 6), or a
//! ShuffleNet tee stream held while the sibling branch computes. Each
//! depth bound is the producer/consumer **rate mismatch** — the pixels the
//! producer emits before the consumer can retire them, i.e. the summed
//! startup latencies of the intervening layers — plus a **quantum-skew
//! margin** (one row of the snapshot grid plus a fixed synchronizer
//! allowance) absorbing the coarse-grained issue of `P_f`-position
//! quanta. Off-chip (WRCE-join) holds are provisioned as a two-frame
//! ping-pong instead, mirroring the WRCE global-FM rule.
//!
//! The bounds are *exactly* the capacities [`crate::sim::build_pipeline`]
//! provisions, in the same FIFO order (tee FIFOs in layer order, then SCB
//! FIFOs) — so a modeled depth is a sound upper bound on the simulator's
//! observed peak occupancy by construction, and `rust/tests/differential.rs`
//! pins both soundness and tightness (no vacuous over-sizing) on every
//! committed baseline cell.

use crate::model::memory::{scb_delay_buffer_bytes, startup_latency_px, CeKind, CePlan, FmScheme};
use crate::nets::{LayerSrc, Network};

/// Depth bound for one side FIFO, in pixels and bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FifoDepth {
    /// Same name the simulator gives the FIFO (`"tee->..."` / `"scb->..."`).
    pub name: String,
    /// `true` when the join side is FRCE (on-chip delayed buffer); `false`
    /// for a WRCE join, where the hold is an off-chip two-frame ping-pong
    /// and the depth is a provision, not a rate bound.
    pub on_chip: bool,
    /// Steady-state hold from producer/consumer rate mismatch (the summed
    /// startup latencies of the intervening layers), in pixels. For
    /// off-chip holds this is the two-frame ping-pong itself.
    pub rate_px: u64,
    /// Quantum-skew safety margin: one snapshot row + 16 px synchronizer
    /// allowance (zero for off-chip holds).
    pub margin_px: u64,
    /// Total depth bound: `min(rate_px + margin_px, 2 * frame_px)` — never
    /// deeper than the ping-pong worst case.
    pub depth_px: u64,
    /// Channels per pixel at the snapshot point (a simulator "pixel" is
    /// one spatial position across all channels).
    pub channels: usize,
    /// Depth in bytes at 8-bit activations: `depth_px * channels`.
    pub bytes: u64,
}

/// Per-design side-FIFO depth report, FIFOs in simulator pipeline order.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FifoReport {
    pub fifos: Vec<FifoDepth>,
}

impl FifoReport {
    /// Total modeled FIFO footprint in bytes (reported alongside the
    /// Eq-12 SRAM figures; off-chip holds included for comparability).
    pub fn total_bytes(&self) -> u64 {
        self.fifos.iter().map(|f| f.bytes).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.fifos.is_empty()
    }
}

/// Derive the per-side-FIFO depth bounds for `net` under the FRCE/WRCE
/// split of `plan` and the FRCE buffer `scheme`.
///
/// Enumerates FIFOs in exactly the order [`crate::sim::build_pipeline`]
/// creates them: tee FIFOs (layer iteration order over `LayerSrc::Tee`
/// consumers), then SCB FIFOs (network `scbs` order) — so report entry
/// `i` describes simulator FIFO `i`.
pub fn fifo_depths(net: &Network, plan: &CePlan, scheme: FmScheme) -> FifoReport {
    let mut fifos = Vec::new();

    // Tee streams: layer j's input snapshotted for a later consumer i
    // while the j..i branch computes.
    for (i, l) in net.layers.iter().enumerate() {
        if let LayerSrc::Tee(j) = l.src {
            let src = &net.layers[j];
            let frame_px = (src.in_size * src.in_size) as u64;
            let channels = src.in_ch;
            let (on_chip, rate_px, margin_px) = if plan.kind(i) == CeKind::Frce {
                let hold_px: u64 =
                    net.layers[j..i].iter().map(|p| startup_latency_px(p, scheme)).sum();
                (true, hold_px, src.in_size as u64 + 16)
            } else {
                (false, 2 * frame_px, 0)
            };
            let depth_px = (rate_px + margin_px).min(2 * frame_px);
            fifos.push(FifoDepth {
                name: format!("tee->{}", l.name),
                on_chip,
                rate_px,
                margin_px,
                depth_px,
                channels,
                bytes: depth_px * channels as u64,
            });
        }
    }

    // SCB shortcut snapshots, delayed until their join layer.
    for scb in &net.scbs {
        let join = scb.join_layer;
        let (f, channels) = scb.snapshot_shape(net);
        let frame_px = (f * f) as u64;
        let (on_chip, rate_px, margin_px) = if plan.kind(join) == CeKind::Frce {
            let model_px = scb_delay_buffer_bytes(net, scb, scheme)
                / net.layers[scb.from_layer].in_ch.max(1) as u64;
            (true, model_px, f as u64 + 16)
        } else {
            (false, 2 * frame_px, 0)
        };
        let depth_px = (rate_px + margin_px).min(2 * frame_px);
        fifos.push(FifoDepth {
            name: format!("scb->{}", net.layers[join].name),
            on_chip,
            rate_px,
            margin_px,
            depth_px,
            channels,
            bytes: depth_px * channels as u64,
        });
    }

    FifoReport { fifos }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::{dynamic_parallelism_tuning, Granularity};
    use crate::sim::{self, SimOptions};

    #[test]
    fn depths_mirror_the_simulator_capacities_in_order() {
        // The structural soundness anchor: the modeled depth of FIFO i is
        // byte-for-byte the capacity build_pipeline provisions for FIFO i,
        // for every zoo network at several FRCE/WRCE boundaries.
        for net in crate::nets::all_networks() {
            for boundary in [0, net.layers.len() / 2, net.layers.len()] {
                let plan = CePlan { boundary };
                let p = dynamic_parallelism_tuning(&net, &plan, 512, Granularity::Fgpm);
                let opts = SimOptions::optimized();
                let pipe = sim::build_pipeline(&net, &p.allocs, &plan, &opts);
                let report = fifo_depths(&net, &plan, opts.scheme);
                assert_eq!(report.fifos.len(), pipe.fifos.len(), "{} b={boundary}", net.name);
                for (m, s) in report.fifos.iter().zip(&pipe.fifos) {
                    assert_eq!(m.name, s.name, "{} b={boundary}", net.name);
                    assert_eq!(m.depth_px, s.capacity, "{} {}", net.name, m.name);
                    assert!(m.channels > 0 && m.bytes == m.depth_px * m.channels as u64);
                    assert!(m.depth_px <= m.rate_px + m.margin_px, "{}", m.name);
                }
            }
        }
    }

    #[test]
    fn chain_networks_have_no_side_fifos() {
        let net = crate::nets::mobilenet_v1();
        let report = fifo_depths(&net, &CePlan { boundary: net.layers.len() }, FmScheme::FullyReusedFm);
        assert!(report.is_empty());
        assert_eq!(report.total_bytes(), 0);
    }

    #[test]
    fn wrce_joins_are_two_frame_ping_pongs() {
        // boundary 0 = everything WRCE: every hold is the off-chip
        // two-frame provision with zero margin.
        let net = crate::nets::mobilenet_v2();
        let report = fifo_depths(&net, &CePlan { boundary: 0 }, FmScheme::FullyReusedFm);
        assert!(!report.is_empty());
        for f in &report.fifos {
            assert!(!f.on_chip, "{}", f.name);
            assert_eq!(f.margin_px, 0, "{}", f.name);
            assert_eq!(f.depth_px, f.rate_px, "{}", f.name);
        }
        // All-FRCE: every hold is on-chip, margined, and no deeper than
        // the ping-pong worst case.
        let frce = fifo_depths(&net, &CePlan { boundary: net.layers.len() }, FmScheme::FullyReusedFm);
        for (f, w) in frce.fifos.iter().zip(&report.fifos) {
            assert!(f.on_chip, "{}", f.name);
            assert!(f.margin_px > 0 && f.depth_px <= w.depth_px, "{}", f.name);
        }
        assert!(frce.total_bytes() < report.total_bytes());
    }
}
