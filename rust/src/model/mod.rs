//! Analytical performance model of the accelerator (§II-A, §V):
//!
//! * [`ops`] — Eqs (1)-(10): MAC/access costs of STC/DSC/SCB structures.
//! * [`memory`] — Eq (12): SRAM footprint under a hybrid-CE plan, with the
//!   fully-reused-FM vs line-based buffer schemes of §III-B.
//! * [`dram`] — Eq (13): off-chip traffic of the proposed design and the
//!   unified-/separated-CE baselines of Fig 14.
//! * [`fifo`] — side-FIFO depth bounds (SCB snapshots, tee streams) from
//!   producer/consumer rate mismatch + quantum skew, differentially
//!   validated against the simulator's observed peak occupancies.
//! * [`throughput`] — Eq (14): barrel-effect throughput, MAC efficiency,
//!   DSP accounting with 2x 8-bit decomposition.

pub mod dram;
pub mod fifo;
pub mod memory;
pub mod ops;
pub mod throughput;

pub use dram::DramTraffic;
pub use fifo::{fifo_depths, FifoDepth, FifoReport};
pub use memory::{CeKind, CePlan, FmScheme, MemoryModelCfg, SramReport};
pub use throughput::{LayerAlloc, Performance};

/// Bytes of one BRAM36K block (36 Kbit).
pub const BRAM36K_BYTES: u64 = 36 * 1024 / 8;

/// Approximate BRAM36K blocks for a byte footprint (the paper notes "the
/// SRAM footprint is only an approximate estimate based on the BRAM
/// number").
pub fn brams_for(bytes: u64) -> u64 {
    bytes.div_ceil(BRAM36K_BYTES)
}
