//! Theoretical throughput model — Eq (14) of §V-B.
//!
//! In the streaming architecture every CE computes one frame concurrently,
//! so frame throughput is set by the slowest CE ("barrel effect", §IV-A):
//! `Throughput = 2 * O_total / max_i T(i)` with
//! `T(i) = ceil(N_i / P_w) * ceil(F_i^2 / P_f) * depth_i` cycles.

use crate::nets::{Layer, LayerKind, Network};
use crate::CLOCK_HZ;

/// Parallelism assigned to one CE: `P_w` across kernels/output-channels,
/// `P_f` across FM positions (§III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerAlloc {
    pub pw: usize,
    pub pf: usize,
}

impl LayerAlloc {
    pub const ONE: LayerAlloc = LayerAlloc { pw: 1, pf: 1 };

    /// MAC units (PEs) this allocation instantiates.
    pub fn pes(&self) -> usize {
        self.pw * self.pf
    }
}

/// Compute cycles of layer `l` under allocation `a` — the denominator term
/// of Eq (14). Non-MAC layers stream at one pixel-vector per cycle and are
/// handled by LUT logic.
pub fn layer_cycles(l: &Layer, a: LayerAlloc) -> u64 {
    if l.kind.is_mac() {
        let rounds_w = div_ceil(l.max_pw() as u64, a.pw as u64);
        let rounds_f = div_ceil(l.max_pf() as u64, a.pf as u64);
        rounds_w * rounds_f * l.reduction_depth()
    } else {
        // Add / pool / shuffle / split / concat: one output position per
        // cycle through LUT datapaths.
        l.out_positions() as u64
    }
}

/// MACs including FGPM dimension padding: the PE array always computes
/// `P_w * ceil(N/P_w) * P_f * ceil(F^2/P_f)` positions worth of work, and
/// the excess is discarded (§IV-A). This is the `O(i)` of Eq (14)'s note.
pub fn padded_macs(l: &Layer, a: LayerAlloc) -> u64 {
    if !l.kind.is_mac() {
        return l.macs();
    }
    let n_pad = a.pw as u64 * div_ceil(l.max_pw() as u64, a.pw as u64);
    let f_pad = a.pf as u64 * div_ceil(l.max_pf() as u64, a.pf as u64);
    n_pad * f_pad * l.reduction_depth()
}

/// DSP48E1 slices consumed by an allocation (§VI-A): two 8x8 multipliers
/// per DSP everywhere except DWC layers, whose independent channels cannot
/// share the pre-adder trick. Non-MAC layers use LUTs only.
pub fn layer_dsps(l: &Layer, a: LayerAlloc) -> usize {
    if !l.kind.is_mac() {
        return 0;
    }
    match l.kind {
        LayerKind::Dwc => a.pes(),
        _ => a.pes().div_ceil(2),
    }
}

/// Whole-design theoretical performance summary.
#[derive(Debug, Clone)]
pub struct Performance {
    /// Bottleneck CE cycles per frame.
    pub t_max: u64,
    /// Index of the bottleneck layer.
    pub bottleneck: usize,
    /// Frames per second at the evaluated design clock (the paper's
    /// 200 MHz unless a [`crate::design::Platform`] overrides it).
    pub fps: f64,
    /// Giga-operations per second (1 MAC = 2 ops).
    pub gops: f64,
    /// Total MAC units instantiated.
    pub total_pes: usize,
    /// Total DSP slices after 2x 8-bit decomposition.
    pub total_dsps: usize,
    /// Theoretical MAC efficiency: achieved MACs/cycle over peak
    /// MACs/cycle (= total PEs).
    pub mac_efficiency: f64,
    /// Latency of a single frame through the whole pipeline (ms): the sum
    /// of per-CE startup plus the bottleneck period — reported like Table
    /// III's batch-mode latency as `sum T(i)` / clock.
    pub latency_ms: f64,
}

/// Evaluate Eq (14) for a full per-layer allocation at the paper's 200 MHz
/// design clock.
pub fn evaluate(net: &Network, allocs: &[LayerAlloc]) -> Performance {
    evaluate_at(net, allocs, CLOCK_HZ)
}

/// Evaluate Eq (14) at an explicit design clock in Hz (the clock a
/// [`crate::design::Platform`] carries).
pub fn evaluate_at(net: &Network, allocs: &[LayerAlloc], clock_hz: f64) -> Performance {
    assert_eq!(allocs.len(), net.layers.len());
    let mut t_max = 0u64;
    let mut bottleneck = 0usize;
    let mut total_pes = 0usize;
    let mut total_dsps = 0usize;
    let mut latency_cycles = 0u64;
    for (i, (l, &a)) in net.layers.iter().zip(allocs).enumerate() {
        let t = layer_cycles(l, a);
        latency_cycles += pipeline_fill_cycles(l, a);
        if l.kind.is_mac() {
            total_pes += a.pes();
            total_dsps += layer_dsps(l, a);
            if t > t_max {
                t_max = t;
                bottleneck = i;
            }
        }
    }
    let o_total = net.total_macs();
    // SCB additions (Eq 3) count toward throughput (the paper's O_total)
    // but execute on LUT adders, not the PE array — exclude them from the
    // MAC-efficiency numerator so efficiency is bounded by 1.
    let o_pe: u64 = net.layers.iter().filter(|l| l.kind.is_mac()).map(|l| l.macs()).sum();
    let fps = clock_hz / t_max as f64;
    let gops = o_total as f64 * 2.0 * fps / 1e9;
    let mac_efficiency = o_pe as f64 / (t_max as f64 * total_pes as f64);
    let latency_ms = (latency_cycles + t_max) as f64 / clock_hz * 1e3;
    Performance { t_max, bottleneck, fps, gops, total_pes, total_dsps, mac_efficiency, latency_ms }
}

/// Cycles before a CE can forward its first outputs — used for the
/// single-frame latency estimate. FRCE-style overlap means a windowed layer
/// only waits for its first window; WRCE STC/PWC layers buffer their whole
/// input FM, which dominates Table III's latency gap between the min-SRAM
/// and ZC706 configurations.
fn pipeline_fill_cycles(l: &Layer, _a: LayerAlloc) -> u64 {
    if l.kind.needs_line_buffer() && l.k > 1 {
        ((l.k - 1) * l.in_size + l.k) as u64
    } else {
        1
    }
}

/// Peak GOPS of a PE budget at the paper's 200 MHz design clock.
pub fn peak_gops(total_pes: usize) -> f64 {
    peak_gops_at(total_pes, CLOCK_HZ)
}

/// Peak GOPS of a PE budget at an explicit design clock — the
/// clock-aware companion of [`evaluate_at`] for catalog platforms with
/// non-200 MHz clocks (ZCU102 at 300 MHz, edge at 150 MHz).
pub fn peak_gops_at(total_pes: usize, clock_hz: f64) -> f64 {
    total_pes as f64 * 2.0 * clock_hz / 1e9
}

/// One point of an FPS-vs-clock scaling curve: the Eq-14 prediction of a
/// fixed allocation re-evaluated at one candidate design clock, next to
/// the PE array's raw peak at that clock ([`peak_gops_at`]).
///
/// The allocation itself is clock-independent (Alg 1 and Alg 2 count
/// bytes and cycles, not seconds), so along a curve only the rates move:
/// `fps`/`gops`/`peak_gops` scale linearly with the clock while the
/// bottleneck CE and MAC efficiency stay fixed — which is exactly what
/// makes the curve a frequency-scaling *what-if* rather than a re-design.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClockPoint {
    /// The candidate design clock in Hz.
    pub clock_hz: f64,
    /// Predicted frames per second at this clock (Eq 14).
    pub fps: f64,
    /// Achieved giga-ops per second at this clock.
    pub gops: f64,
    /// Raw PE-array peak at this clock ([`peak_gops_at`]); `gops /
    /// peak_gops` is clock-invariant along the curve (it tracks
    /// [`Performance::mac_efficiency`], counting the SCB additions
    /// `gops` includes on top of the PE-array MACs).
    pub peak_gops: f64,
}

/// Evaluate an allocation's FPS/GOPS curve across candidate design clocks
/// (the `repro sweep --clocks` axis). Each point re-runs [`evaluate_at`]
/// and pairs it with [`peak_gops_at`] for the same clock; points come
/// back in the order given.
///
/// # Examples
///
/// ```
/// use repro::model::throughput::{clock_curve, LayerAlloc};
///
/// let net = repro::nets::shufflenet_v2();
/// let allocs = vec![LayerAlloc::ONE; net.layers.len()];
/// let curve = clock_curve(&net, &allocs, &[100.0e6, 200.0e6]);
/// assert_eq!(curve.len(), 2);
/// // Rates scale linearly with the clock; efficiency does not move.
/// assert!((curve[1].fps / curve[0].fps - 2.0).abs() < 1e-9);
/// assert!((curve[0].gops / curve[0].peak_gops
///        - curve[1].gops / curve[1].peak_gops).abs() < 1e-12);
/// ```
pub fn clock_curve(net: &Network, allocs: &[LayerAlloc], clocks_hz: &[f64]) -> Vec<ClockPoint> {
    clocks_hz.iter().map(|&hz| clock_point(net, allocs, hz)).collect()
}

/// One [`clock_curve`] point at a single candidate clock — the
/// convenience the clock-axis Pareto analysis ([`crate::sweep::pareto_clocks`])
/// uses to give a curve-less cell its native-clock candidate. At the
/// platform's own clock this reproduces the cell's
/// [`Performance`] prediction exactly (`evaluate_at` is deterministic).
pub fn clock_point(net: &Network, allocs: &[LayerAlloc], clock_hz: f64) -> ClockPoint {
    let p = evaluate_at(net, allocs, clock_hz);
    ClockPoint { clock_hz, fps: p.fps, gops: p.gops, peak_gops: peak_gops_at(p.total_pes, clock_hz) }
}

pub(crate) fn div_ceil(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::mobilenet_v2;

    #[test]
    fn unit_alloc_cycles_equal_macs() {
        // With P_w = P_f = 1 and no padding, T(i) == O(i) for MAC layers
        // (Alg 2's initialization: "making the initial computing time equal
        // to the number of operations").
        let net = mobilenet_v2();
        for l in net.layers.iter().filter(|l| l.kind.is_mac() && l.groups == 1) {
            assert_eq!(layer_cycles(l, LayerAlloc::ONE), l.macs());
        }
    }

    #[test]
    fn padding_never_reduces_work() {
        let net = mobilenet_v2();
        for l in net.layers.iter().filter(|l| l.kind.is_mac()) {
            for &a in &[LayerAlloc { pw: 3, pf: 1 }, LayerAlloc { pw: 7, pf: 2 }, LayerAlloc { pw: 13, pf: 5 }] {
                assert!(padded_macs(l, a) >= l.macs());
                // Work/cycle never exceeds the PE count.
                let t = layer_cycles(l, a);
                assert!(padded_macs(l, a) <= t * a.pes() as u64 * l.groups as u64);
            }
        }
    }

    #[test]
    fn dwc_layers_get_no_dsp_decomposition() {
        let net = mobilenet_v2();
        let dwc = net.layers.iter().find(|l| l.kind == LayerKind::Dwc).unwrap();
        let pwc = net.layers.iter().find(|l| l.kind == LayerKind::Pwc).unwrap();
        let a = LayerAlloc { pw: 8, pf: 1 };
        assert_eq!(layer_dsps(dwc, a), 8);
        assert_eq!(layer_dsps(pwc, a), 4);
    }

    #[test]
    fn efficiency_is_unity_for_perfectly_divisible_alloc() {
        // A single-layer toy: allocate a divisor of every dimension ->
        // efficiency exactly 1 for that layer.
        let net = mobilenet_v2();
        let l = &net.layers[0]; // stem STC: N=32, F=112^2
        let a = LayerAlloc { pw: 32, pf: 16 };
        let t = layer_cycles(l, a);
        assert_eq!(t * a.pes() as u64, l.macs());
    }

    #[test]
    fn evaluate_at_scales_linearly_with_clock() {
        // The allocation is clock-independent, so a 300 MHz platform's
        // prediction is exactly the 200 MHz one scaled by 1.5 — the
        // property that lets ZCU102 catalog cells share the ZC706 math.
        let net = mobilenet_v2();
        let allocs = vec![LayerAlloc { pw: 4, pf: 2 }; net.layers.len()];
        let p200 = evaluate_at(&net, &allocs, 200.0e6);
        let p300 = evaluate_at(&net, &allocs, 300.0e6);
        assert_eq!(p200.t_max, p300.t_max);
        assert_eq!(p200.bottleneck, p300.bottleneck);
        assert_eq!(p200.mac_efficiency, p300.mac_efficiency);
        assert!((p300.fps / p200.fps - 1.5).abs() < 1e-9);
        assert!((p300.gops / p200.gops - 1.5).abs() < 1e-9);
        assert!((p200.latency_ms / p300.latency_ms - 1.5).abs() < 1e-9);
        assert!((peak_gops_at(100, 300.0e6) / peak_gops(100) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn clock_curve_points_match_direct_evaluation() {
        let net = mobilenet_v2();
        let allocs = vec![LayerAlloc { pw: 4, pf: 2 }; net.layers.len()];
        let clocks = [150.0e6, 200.0e6, 300.0e6];
        let curve = clock_curve(&net, &allocs, &clocks);
        assert_eq!(curve.len(), 3);
        for (pt, &hz) in curve.iter().zip(&clocks) {
            let p = evaluate_at(&net, &allocs, hz);
            assert_eq!(pt.clock_hz, hz);
            assert_eq!(pt.fps, p.fps);
            assert_eq!(pt.gops, p.gops);
            assert_eq!(pt.peak_gops, peak_gops_at(p.total_pes, hz));
            // O_total also counts SCB additions executed on LUT adders,
            // so allow their thin margin above the PE-array peak.
            assert!(pt.gops <= pt.peak_gops * 1.01);
        }
        assert!(clock_curve(&net, &allocs, &[]).is_empty());
        // The single-point convenience is exactly one curve entry.
        assert_eq!(clock_point(&net, &allocs, 200.0e6), curve[1]);
    }

    #[test]
    fn evaluate_reports_consistent_totals() {
        let net = mobilenet_v2();
        let allocs = vec![LayerAlloc::ONE; net.layers.len()];
        let p = evaluate(&net, &allocs);
        assert!(p.mac_efficiency > 0.0 && p.mac_efficiency <= 1.0);
        assert_eq!(
            p.total_pes,
            net.layers.iter().filter(|l| l.kind.is_mac()).count()
        );
        assert!(p.fps > 0.0);
        assert!(p.latency_ms * 1e-3 >= 1.0 / p.fps);
    }
}
