//! On-chip (SRAM) memory model — Eq (12) of §V-A and the buffer-size
//! analysis of §III-B (Table I, Fig 5/6, Fig 13).
//!
//! All quantities are bytes at 8-bit precision. A "pixel" is one spatial
//! position across all channels of the stream at that point (channel-first
//! order in FRCEs), so a buffer of `p` pixels on a stream of `C` channels
//! occupies `p * C` bytes.

use crate::nets::{Layer, LayerKind, LayerSrc, Network, Scb};

/// Which data-reuse scheme a CE's FM buffer follows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FmScheme {
    /// The paper's fully-reused-feature-map scheme (§III-B, Fig 5): a
    /// window's oldest pixel dies as soon as the window is computed, so a
    /// `K x K` conv needs only `(K-1) * F + (K-1)` pixels.
    FullyReusedFm,
    /// The conventional line-based weight-reuse scheme of [14], [22], [28]:
    /// processing granularity is a full line; `K + 1` lines are buffered
    /// (K for the window + 1 for continuity).
    LineBased,
}

/// CE type assignment (§III-B, Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CeKind {
    /// Feature-map-reused CE: weights on-chip, minimal line buffer,
    /// shortcut in an on-chip delayed buffer. Zero off-chip access.
    Frce,
    /// Weight-reused CE: weights streamed from DRAM once per frame,
    /// ping-pong global FM buffer, shortcut stored off-chip.
    Wrce,
}

/// A CE assignment for a whole network: layers `0..boundary` are FRCEs,
/// the rest WRCEs ("the location of the group boundary", §V-A).
#[derive(Debug, Clone)]
pub struct CePlan {
    pub boundary: usize,
}

impl CePlan {
    pub fn kind(&self, layer_idx: usize) -> CeKind {
        if layer_idx < self.boundary {
            CeKind::Frce
        } else {
            CeKind::Wrce
        }
    }
}

/// Options of the SRAM model (shared by Figs 12/13 and the allocator).
#[derive(Debug, Clone, Copy)]
pub struct MemoryModelCfg {
    /// FM-buffer scheme used in FRCEs.
    pub fm_scheme: FmScheme,
    /// Dataflow-oriented line buffer (§IV-B): one extra line for
    /// stride > 1 convolutions to avoid window bubbles.
    pub stride_extra_line: bool,
    /// Kernel-side parallelism assumed when sizing WRCE ping-pong weight
    /// buffers (Alg 1 runs before parallelism is known; 1 reproduces the
    /// paper's "relatively small" weight buffers).
    pub wrce_pw: usize,
}

impl Default for MemoryModelCfg {
    fn default() -> Self {
        MemoryModelCfg { fm_scheme: FmScheme::FullyReusedFm, stride_extra_line: true, wrce_pw: 1 }
    }
}

/// Line-buffer pixels required by a windowed layer under `scheme`
/// (PWC/FC/Add need none under the fully-reused scheme).
pub fn line_buffer_px(l: &Layer, scheme: FmScheme, stride_extra_line: bool) -> u64 {
    let f = l.in_size as u64;
    let k = l.k as u64;
    if !l.kind.needs_line_buffer() || l.k <= 1 {
        return match scheme {
            FmScheme::FullyReusedFm => 0,
            // Line granularity: ping-pong pair of lines even for 1x1 work.
            FmScheme::LineBased => 2 * f,
        };
    }
    match scheme {
        FmScheme::FullyReusedFm => {
            let base = (k - 1) * f + (k - 1);
            if stride_extra_line && l.stride > 1 {
                base + f
            } else {
                base
            }
        }
        FmScheme::LineBased => (k + 1) * f,
    }
}

/// Startup latency of a layer in *input pixels* before its first output can
/// be produced — the pixel "lifetime" that the delayed shortcut buffer must
/// absorb (§III-B, Fig 6).
pub fn startup_latency_px(l: &Layer, scheme: FmScheme) -> u64 {
    let f = l.in_size as u64;
    let k = l.k as u64;
    match scheme {
        FmScheme::FullyReusedFm => {
            if l.kind.needs_line_buffer() && l.k > 1 {
                (k - 1) * f + k
            } else {
                1
            }
        }
        FmScheme::LineBased => {
            if l.kind.needs_line_buffer() && l.k > 1 {
                k * f
            } else {
                f
            }
        }
    }
}

/// Bytes of the delayed shortcut buffer for one SCB whose branch layers are
/// all FRCEs: the accumulated main-branch startup latency, held at the
/// snapshot's channel width (Fig 6: ~2 lines for the pw/dw/pw SCB under the
/// fully-reused scheme vs >= 5 lines line-based).
pub fn scb_delay_buffer_bytes(net: &Network, scb: &Scb, scheme: FmScheme) -> u64 {
    let (_, ch) = scb.snapshot_shape(net);
    let delay_px: u64 = net.layers[scb.from_layer..scb.join_layer]
        .iter()
        .map(|l| startup_latency_px(l, scheme))
        .sum();
    delay_px * ch as u64
}

/// Per-layer SRAM breakdown.
#[derive(Debug, Clone, Default)]
pub struct LayerSram {
    pub line_buffer: u64,
    pub weight_rom: u64,
    pub gfm_buffer: u64,
    pub weight_buffer: u64,
}

impl LayerSram {
    pub fn total(&self) -> u64 {
        self.line_buffer + self.weight_rom + self.gfm_buffer + self.weight_buffer
    }
}

/// SRAM contribution of one layer under a CE kind (Table I):
///
/// * FRCE: line buffer (fully-reused FM scheme) + on-chip weight ROM.
/// * WRCE: ping-pong global FM buffer (`2 * F^2 * M`; a few lines of one
///   channel for DWC since the FM arrives location-first) + ping-pong
///   weight buffer sized by the kernel parallelism.
pub fn layer_sram(l: &Layer, kind: CeKind, cfg: &MemoryModelCfg) -> LayerSram {
    let mut s = LayerSram::default();
    match kind {
        CeKind::Frce => {
            if l.kind.needs_line_buffer() || matches!(cfg.fm_scheme, FmScheme::LineBased) {
                s.line_buffer = line_buffer_px(l, cfg.fm_scheme, cfg.stride_extra_line) * l.in_ch as u64;
            }
            s.weight_rom = l.weight_bytes();
        }
        CeKind::Wrce => {
            match l.kind {
                LayerKind::Dwc | LayerKind::MaxPool | LayerKind::AvgPool => {
                    // Location-first order: a K-line window of a single
                    // channel, ping-ponged.
                    s.gfm_buffer = 2 * (l.k as u64) * l.in_size as u64;
                }
                LayerKind::Stc | LayerKind::Pwc | LayerKind::Fc => {
                    s.gfm_buffer = 2 * l.in_fm_bytes();
                }
                // Data-movement layers and Adds keep no FM state in WRCEs
                // (shortcuts live off-chip).
                _ => {}
            }
            if l.kind.has_weights() {
                let kernel_bytes = (l.k * l.k * l.in_ch / l.groups) as u64;
                s.weight_buffer = 2 * kernel_bytes * cfg.wrce_pw as u64;
            }
        }
    }
    s
}

/// Full-network SRAM report under a CE plan (Eq 12).
#[derive(Debug, Clone)]
pub struct SramReport {
    /// Per-layer breakdown, FRCE/WRCE assigned per the plan.
    pub layers: Vec<LayerSram>,
    /// Delayed-buffer bytes per SCB fully inside the FRCE region (SCBs
    /// joining in the WRCE region are stored off-chip instead).
    pub scb_buffers: u64,
    /// Sum of line buffers (FRCE region).
    pub line_buffer_total: u64,
    /// Sum of on-chip weight ROMs (FRCE region).
    pub weight_rom_total: u64,
    /// Sum of WRCE global-FM + weight ping-pong buffers.
    pub wrce_total: u64,
}

impl SramReport {
    pub fn total(&self) -> u64 {
        self.layers.iter().map(LayerSram::total).sum::<u64>() + self.scb_buffers
    }
}

/// Whether an SCB's shortcut is held on-chip (join strictly inside the FRCE
/// region) under `plan`.
pub fn scb_on_chip(scb: &Scb, plan: &CePlan) -> bool {
    scb.join_layer < plan.boundary
}

/// Tee branches (two-branch ShuffleNet units) buffer the teed stream like a
/// shortcut; on-chip iff the consuming tee layer is an FRCE.
fn tee_buffer_bytes(net: &Network, scheme: FmScheme) -> Vec<(usize, u64)> {
    net.layers
        .iter()
        .enumerate()
        .filter_map(|(i, l)| match l.src {
            LayerSrc::Tee(j) => {
                // The tee stream must be held while the layers between the
                // tee point and this branch head produce their startup
                // latency, bounded by one full snapshot.
                let src = &net.layers[j];
                let hold_px: u64 = net.layers[j..i].iter().map(|p| startup_latency_px(p, scheme)).sum();
                let cap = (src.in_size * src.in_size) as u64;
                Some((i, hold_px.min(cap) * src.in_ch as u64))
            }
            LayerSrc::Prev => None,
        })
        .collect()
}

/// Evaluate Eq (12) for `net` under `plan`.
pub fn sram_report(net: &Network, plan: &CePlan, cfg: &MemoryModelCfg) -> SramReport {
    let mut layers = Vec::with_capacity(net.layers.len());
    let (mut line_total, mut rom_total, mut wrce_total) = (0u64, 0u64, 0u64);
    for (i, l) in net.layers.iter().enumerate() {
        // FC weights are excluded from the on-chip comparison (Fig 13) by
        // always streaming them (they sit at the very end of the WRCE
        // region in every plan).
        let s = layer_sram(l, plan.kind(i), cfg);
        match plan.kind(i) {
            CeKind::Frce => {
                line_total += s.line_buffer;
                rom_total += s.weight_rom;
            }
            CeKind::Wrce => wrce_total += s.total(),
        }
        layers.push(s);
    }
    let mut scb_buffers = 0u64;
    for scb in &net.scbs {
        if scb_on_chip(scb, plan) {
            scb_buffers += scb_delay_buffer_bytes(net, scb, cfg.fm_scheme);
        }
    }
    for (i, bytes) in tee_buffer_bytes(net, cfg.fm_scheme) {
        if plan.kind(i) == CeKind::Frce {
            scb_buffers += bytes;
        }
    }
    SramReport { layers, scb_buffers, line_buffer_total: line_total, weight_rom_total: rom_total, wrce_total }
}

/// Marginal SRAM cost of deploying layer `i` as FRCE vs WRCE — the
/// comparison Algorithm 1's first iteration performs per layer.
pub fn frce_vs_wrce_cost(net: &Network, i: usize, cfg: &MemoryModelCfg) -> (u64, u64) {
    let l = &net.layers[i];
    let mut frce = layer_sram(l, CeKind::Frce, cfg).total();
    // Moving the boundary past an SCB join pulls its delayed buffer on-chip;
    // charge it to the join layer.
    if let Some(scb) = net.scb_joining_at(i) {
        frce += scb_delay_buffer_bytes(net, scb, cfg.fm_scheme);
    }
    if let LayerSrc::Tee(j) = l.src {
        // The branch head pulls the tee hold buffer on-chip with it.
        let hold_px: u64 = net.layers[j..i].iter().map(|p| startup_latency_px(p, cfg.fm_scheme)).sum();
        let src = &net.layers[j];
        let cap = (src.in_size * src.in_size) as u64;
        frce += hold_px.min(cap) * src.in_ch as u64;
    }
    let wrce = layer_sram(l, CeKind::Wrce, cfg).total();
    (frce, wrce)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::mobilenet_v2;

    /// Build the paper's Fig 6 SCB: pw -> dw3x3 -> pw over a 56x56x64 FM.
    fn fig6_scb() -> (Network, Scb) {
        let net = crate::nets::mobilenet_v2();
        let scb = net.scbs[0].clone();
        (net, scb)
    }

    #[test]
    fn fully_reused_scheme_saves_a_line_vs_line_based() {
        // "for a k x k kernel, the fully reused feature map scheme only
        // needs to cache k-1 full lines plus k-1 pixels ... saves one line
        // of buffer size even if the buffer lines increased to k full lines"
        let net = mobilenet_v2();
        let dwc = net.layers.iter().find(|l| l.kind == LayerKind::Dwc && l.stride == 1).unwrap();
        let fr = line_buffer_px(dwc, FmScheme::FullyReusedFm, false);
        let lb = line_buffer_px(dwc, FmScheme::LineBased, false);
        let f = dwc.in_size as u64;
        assert_eq!(fr, 2 * f + 2);
        assert_eq!(lb, 4 * f);
        assert!(lb - fr >= f); // at least one full line saved
    }

    #[test]
    fn fig6_shortcut_buffer_ratio() {
        // Fig 6: ~2 lines of shortcut delay (fully reused) vs >= 5 lines
        // (line-based), a 69.23%-class reduction of the SCB FM buffer.
        let (net, scb) = fig6_scb();
        let f = net.layers[scb.from_layer].in_size as u64;
        let ch = net.layers[scb.from_layer].in_ch as u64;
        let fast = scb_delay_buffer_bytes(&net, &scb, FmScheme::FullyReusedFm);
        let slow = scb_delay_buffer_bytes(&net, &scb, FmScheme::LineBased);
        // fully reused: 1 + (2F + 3) + 1 px  ~= 2 lines
        assert_eq!(fast, (2 * f + 5) * ch);
        // line-based: F + 3F + F = 5 lines
        assert_eq!(slow, 5 * f * ch);
        let total_fast = fast + net.layers[scb.from_layer..scb.join_layer]
            .iter()
            .map(|l| line_buffer_px(l, FmScheme::FullyReusedFm, false) * l.in_ch as u64)
            .sum::<u64>();
        let total_slow = slow + net.layers[scb.from_layer..scb.join_layer]
            .iter()
            .map(|l| line_buffer_px(l, FmScheme::LineBased, false) * l.in_ch as u64)
            .sum::<u64>();
        let reduction = 1.0 - total_fast as f64 / total_slow as f64;
        assert!(reduction > 0.5, "reduction {reduction}");
    }

    #[test]
    fn boundary_zero_means_all_wrce() {
        let net = mobilenet_v2();
        let cfg = MemoryModelCfg::default();
        let r = sram_report(&net, &CePlan { boundary: 0 }, &cfg);
        assert_eq!(r.weight_rom_total, 0);
        assert_eq!(r.line_buffer_total, 0);
        assert_eq!(r.scb_buffers, 0);
        assert!(r.wrce_total > 0);
    }

    #[test]
    fn full_frce_holds_all_weights_on_chip() {
        let net = mobilenet_v2();
        let cfg = MemoryModelCfg::default();
        let r = sram_report(&net, &CePlan { boundary: net.layers.len() }, &cfg);
        assert_eq!(r.weight_rom_total, net.total_weight_bytes());
        assert_eq!(r.wrce_total, 0);
    }

    #[test]
    fn sram_total_is_monotone_in_components() {
        let net = mobilenet_v2();
        let cfg = MemoryModelCfg::default();
        for b in [0, 10, 30, net.layers.len()] {
            let r = sram_report(&net, &CePlan { boundary: b }, &cfg);
            assert_eq!(
                r.total(),
                r.layers.iter().map(LayerSram::total).sum::<u64>() + r.scb_buffers
            );
        }
    }
}
