//! Reproduction of *"A High-Throughput FPGA Accelerator for Lightweight
//! CNNs With Balanced Dataflow"* (Zhao et al., 2024) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! # The `Design`/`Platform` flow
//!
//! The paper's contribution is a methodology pipeline — network →
//! balanced memory allocation (Alg 1) → dynamic parallelism tuning
//! (Alg 2) → streaming execution. The [`design`] module exposes that
//! pipeline as one builder API, and every consumer (CLI, examples,
//! benches, report renderers) goes through it:
//!
//! ```no_run
//! use repro::{Design, Platform};
//!
//! let net = repro::nets::mobilenet_v2();
//! let design = Design::builder(&net).platform(Platform::zc706()).build();
//! println!("{:.1} FPS predicted, boundary {}", design.predicted().fps, design.ce_plan().boundary);
//! let stats = design.simulate(10).unwrap();               // cycle-level sim
//! std::fs::write("mbv2.design.json", design.to_json()).unwrap(); // persist
//! ```
//!
//! [`Platform::zc706`] names the paper's evaluation budget; the catalog
//! ([`Platform::list`]) also ships [`Platform::zcu102`] (UltraScale+
//! class: 2520 DSP48E2, ~4.7 MB SRAM, 300 MHz) and [`Platform::edge`]
//! (220 DSPs, <1 MB SRAM), and [`Platform::custom`] expresses any other
//! part. Whole {network} x {platform} x {granularity} matrices are
//! evaluated in one call by the [`sweep`] module (`repro sweep` on the
//! CLI), whose per-cell `Design` artifacts double as the golden
//! regression baselines under `rust/tests/baselines/`.
//!
//! # Subsystems
//!
//! * [`ir`] — the layer-graph IR front-end: explicit-edge `Graph`/`Node`
//!   networks with shape-inference validation, a versioned JSON
//!   loader/exporter (`networks/*.json`, `--net-file` on the CLI; schema
//!   in `docs/net_schema.md`), and the lowering pass that produces the
//!   streaming [`nets::Network`] every downstream subsystem consumes.
//! * [`nets`] — the LWCNN zoo (MobileNetV1/V2, ShuffleNetV1/V2), built as
//!   [`ir`] graphs and lowered through the same path as loaded files.
//! * [`model`] — the analytical performance model (Eqs 1-14: MAC/access
//!   costs, SRAM/DRAM models, throughput).
//! * [`alloc`] — FGPM parallel spaces, Algorithm 1 (balanced memory
//!   allocation) and Algorithm 2 (dynamic parallelism tuning), plus the
//!   factorized-granularity baseline.
//! * [`design`] — the `Design`/`Platform` façade chaining the above into
//!   one compiled, persistable artifact per (network, platform) pair,
//!   plus the named platform catalog.
//! * [`sweep`] — the design-space sweep subsystem: the full pipeline over
//!   a {networks} x {platforms} x {granularities} matrix, evaluated in
//!   parallel on the [`util::pool`] work-stealing pool with deterministic
//!   (byte-identical to serial) output and memoized across invocations by
//!   the content-keyed [`sweep::cache`] layer (zero Alg 1/Alg 2
//!   re-derivation on a warm cache), plus the per-network
//!   {SRAM, FPS, DRAM} Pareto-frontier analysis ([`sweep::pareto`]), the
//!   4-D frequency-axis frontier ([`sweep::pareto_clocks`]), and
//!   FPS-vs-clock scaling curves; rendered as text tables
//!   ([`report::sweep_matrix`], [`report::pareto_table`],
//!   [`report::pareto_clocks_table`], [`report::clock_curves`]) or stable
//!   sorted-key JSON. Its constrained counterpart, [`sweep::optimize`]
//!   (`repro optimize`), answers "best design under this budget" directly:
//!   per-network branch-and-bound over the same matrix, pruning with
//!   admissible Eq 1–14 bounds and guaranteed to return the exhaustive
//!   sweep's byte-identical best cell, with a seeded simulated-annealing
//!   fallback for objectives the bound cannot order.
//! * [`sim`] — the cycle-level streaming simulator (hybrid CEs, line
//!   buffers with both padding schemes, order converter, SCB joins).
//! * [`runtime`] — PJRT wrapper loading AOT-compiled HLO artifacts.
//! * [`coordinator`] — the streaming inference pipeline chaining per-stage
//!   executables with FM channels and a DRAM weight streamer.
//! * [`report`] — paper-style table/figure renderers with the paper's
//!   reference numbers side by side.
//! * [`util`] — the offline-build support layer, including the typed
//!   error taxonomy ([`util::error::ReproError`]) every fallible pipeline
//!   stage reports through, and the deterministic fault-injection harness
//!   ([`util::fault`], armed via `REPRO_FAULTS`) that the robustness
//!   tests drive (`docs/robustness.md`).

pub mod alloc;
pub mod coordinator;
pub mod design;
pub mod ir;
pub mod model;
pub mod nets;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod util;

pub use design::{Design, Platform};
pub use sweep::optimize::{OptimizeReport, OptimizeSpec};
pub use sweep::{CacheStats, CellFailure, ClockParetoReport, ParetoReport, SweepReport, SweepSpec};
pub use util::error::ReproError;

/// Clock frequency of the evaluated design (the paper implements at 200 MHz).
pub const CLOCK_HZ: f64 = 200.0e6;

/// ZC706 (XC7Z045) resource budget used throughout the paper's evaluation:
/// 545 BRAM36K (75% of 545 -> the paper's 1.80 MB SRAM cap is 75% of the
/// 545-BRAM budget), 900 DSP48E1 with a 95% empirical cap (855).
///
/// Prefer [`Platform::zc706`], which carries the same numbers as a named
/// value; these constants remain as the single source of truth it reads.
pub mod zc706 {
    /// Total BRAM36K blocks.
    pub const BRAM36K: usize = 545;
    /// SRAM byte budget at the paper's 75% utilization cap (1.80 MB).
    pub const SRAM_BYTES: u64 = (545.0 * 0.75 * 36.0 * 1024.0 / 8.0) as u64;
    /// Total DSP48E1 slices.
    pub const DSP: usize = 900;
    /// DSP cap at the paper's empirical 95% utilization target.
    pub const DSP_BUDGET: usize = 855;
    /// LUT / DFF totals (reported, not modelled).
    pub const LUT: usize = 218_600;
    pub const DFF: usize = 437_200;
}

/// ZCU102-class (XCZU9EG, UltraScale+) resource budget — the ROADMAP's
/// mid-range follow-on part: 2520 DSP48E2 with the same empirical 95%
/// utilization cap as the ZC706, ~4.7 MB of on-chip SRAM (BRAM plus
/// UltraRAM-class headroom), and a 300 MHz-class design clock.
///
/// Prefer [`crate::Platform::zcu102`], which carries the same numbers as
/// a named catalog value; these constants are the single source of truth
/// it reads.
pub mod zcu102 {
    /// Total BRAM36K blocks on the part.
    pub const BRAM36K: usize = 912;
    /// On-chip SRAM byte budget (~4.7 MB: 4800 KB).
    pub const SRAM_BYTES: u64 = 4800 * 1024;
    /// Total DSP48E2 slices.
    pub const DSP: usize = 2520;
    /// DSP cap at the 95% empirical utilization target (ZC706 convention).
    pub const DSP_BUDGET: usize = 2394;
    /// UltraScale+ parts close timing at 300 MHz-class clocks.
    pub const CLOCK_HZ: f64 = 300.0e6;
}

/// Edge-class resource budget — the ROADMAP's small follow-on part:
/// <1 MB of on-chip SRAM and 220 DSPs (a Zynq-7020-class envelope) at a
/// conservative 150 MHz clock. Small enough that even the minimum-SRAM
/// configuration of some zoo networks does not fit, which is exactly the
/// regime the sweep report's `fits_sram` / `sram_utilization` columns
/// surface.
///
/// Prefer [`crate::Platform::edge`]; these constants are the single
/// source of truth it reads.
pub mod edge {
    /// BRAM36K blocks covering the SRAM budget (960 KB / 4.5 KB, rounded up).
    pub const BRAM36K: usize = 214;
    /// On-chip SRAM byte budget: 960 KB (<1 MB).
    pub const SRAM_BYTES: u64 = 960 * 1024;
    /// Total DSP slices.
    pub const DSP: usize = 220;
    /// Small parts run the PE array on the full DSP complement.
    pub const DSP_BUDGET: usize = 220;
    /// Conservative edge-class design clock.
    pub const CLOCK_HZ: f64 = 150.0e6;
}
