//! Reproduction of *"A High-Throughput FPGA Accelerator for Lightweight
//! CNNs With Balanced Dataflow"* (Zhao et al., 2024) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The crate hosts every system the paper describes or depends on:
//!
//! * [`nets`] — the LWCNN zoo (MobileNetV1/V2, ShuffleNetV1/V2).
//! * [`model`] — the analytical performance model (Eqs 1-14: MAC/access
//!   costs, SRAM/DRAM models, throughput).
//! * [`alloc`] — FGPM parallel spaces, Algorithm 1 (balanced memory
//!   allocation) and Algorithm 2 (dynamic parallelism tuning), plus the
//!   factorized-granularity baseline.
//! * [`sim`] — the cycle-level streaming simulator (hybrid CEs, line
//!   buffers with both padding schemes, order converter, SCB joins).
//! * [`runtime`] — PJRT wrapper loading AOT-compiled HLO artifacts.
//! * [`coordinator`] — the streaming inference pipeline chaining per-stage
//!   executables with FM channels and a DRAM weight streamer.
//! * [`report`] — paper-style table/figure renderers with the paper's
//!   reference numbers side by side.

pub mod alloc;
pub mod coordinator;
pub mod model;
pub mod nets;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Clock frequency of the evaluated design (the paper implements at 200 MHz).
pub const CLOCK_HZ: f64 = 200.0e6;

/// ZC706 (XC7Z045) resource budget used throughout the paper's evaluation:
/// 545 BRAM36K (75% of 545 -> the paper's 1.80 MB SRAM cap is 75% of the
/// 545-BRAM budget), 900 DSP48E1 with a 95% empirical cap (855).
pub mod zc706 {
    /// Total BRAM36K blocks.
    pub const BRAM36K: usize = 545;
    /// SRAM byte budget at the paper's 75% utilization cap (1.80 MB).
    pub const SRAM_BYTES: u64 = (545.0 * 0.75 * 36.0 * 1024.0 / 8.0) as u64;
    /// Total DSP48E1 slices.
    pub const DSP: usize = 900;
    /// DSP cap at the paper's empirical 95% utilization target.
    pub const DSP_BUDGET: usize = 855;
    /// LUT / DFF totals (reported, not modelled).
    pub const LUT: usize = 218_600;
    pub const DFF: usize = 437_200;
}
