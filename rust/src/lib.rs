//! Reproduction of *"A High-Throughput FPGA Accelerator for Lightweight
//! CNNs With Balanced Dataflow"* (Zhao et al., 2024) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! # The `Design`/`Platform` flow
//!
//! The paper's contribution is a methodology pipeline — network →
//! balanced memory allocation (Alg 1) → dynamic parallelism tuning
//! (Alg 2) → streaming execution. The [`design`] module exposes that
//! pipeline as one builder API, and every consumer (CLI, examples,
//! benches, report renderers) goes through it:
//!
//! ```no_run
//! use repro::{Design, Platform};
//!
//! let net = repro::nets::mobilenet_v2();
//! let design = Design::builder(&net).platform(Platform::zc706()).build();
//! println!("{:.1} FPS predicted, boundary {}", design.predicted().fps, design.ce_plan().boundary);
//! let stats = design.simulate(10).unwrap();               // cycle-level sim
//! std::fs::write("mbv2.design.json", design.to_json()).unwrap(); // persist
//! ```
//!
//! [`Platform::zc706`] names the paper's evaluation budget;
//! [`Platform::custom`] expresses any other part (edge-class SRAM,
//! ZCU102-class DSP counts, ...), which makes multi-platform sweeps
//! one-liners.
//!
//! # Subsystems
//!
//! * [`nets`] — the LWCNN zoo (MobileNetV1/V2, ShuffleNetV1/V2).
//! * [`model`] — the analytical performance model (Eqs 1-14: MAC/access
//!   costs, SRAM/DRAM models, throughput).
//! * [`alloc`] — FGPM parallel spaces, Algorithm 1 (balanced memory
//!   allocation) and Algorithm 2 (dynamic parallelism tuning), plus the
//!   factorized-granularity baseline.
//! * [`design`] — the `Design`/`Platform` façade chaining the above into
//!   one compiled, persistable artifact per (network, platform) pair.
//! * [`sim`] — the cycle-level streaming simulator (hybrid CEs, line
//!   buffers with both padding schemes, order converter, SCB joins).
//! * [`runtime`] — PJRT wrapper loading AOT-compiled HLO artifacts.
//! * [`coordinator`] — the streaming inference pipeline chaining per-stage
//!   executables with FM channels and a DRAM weight streamer.
//! * [`report`] — paper-style table/figure renderers with the paper's
//!   reference numbers side by side.

pub mod alloc;
pub mod coordinator;
pub mod design;
pub mod model;
pub mod nets;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

pub use design::{Design, Platform};

/// Clock frequency of the evaluated design (the paper implements at 200 MHz).
pub const CLOCK_HZ: f64 = 200.0e6;

/// ZC706 (XC7Z045) resource budget used throughout the paper's evaluation:
/// 545 BRAM36K (75% of 545 -> the paper's 1.80 MB SRAM cap is 75% of the
/// 545-BRAM budget), 900 DSP48E1 with a 95% empirical cap (855).
///
/// Prefer [`Platform::zc706`], which carries the same numbers as a named
/// value; these constants remain as the single source of truth it reads.
pub mod zc706 {
    /// Total BRAM36K blocks.
    pub const BRAM36K: usize = 545;
    /// SRAM byte budget at the paper's 75% utilization cap (1.80 MB).
    pub const SRAM_BYTES: u64 = (545.0 * 0.75 * 36.0 * 1024.0 / 8.0) as u64;
    /// Total DSP48E1 slices.
    pub const DSP: usize = 900;
    /// DSP cap at the paper's empirical 95% utilization target.
    pub const DSP_BUDGET: usize = 855;
    /// LUT / DFF totals (reported, not modelled).
    pub const LUT: usize = 218_600;
    pub const DFF: usize = 437_200;
}
