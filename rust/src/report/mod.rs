//! Paper-style table/figure renderers.
//!
//! Each `figNN`/`tabN` function regenerates one table or figure of the
//! paper's evaluation as text (rows of the same series the paper plots),
//! printing the paper's reference numbers from [`paper_ref`] next to the
//! measured values. `cargo run --release -- report <id>` renders one;
//! `report all` renders everything (that output is the backbone of
//! EXPERIMENTS.md).

pub mod paper_ref;

use std::fmt::Write as _;

use crate::alloc::{
    self,
    parallelism::{dynamic_parallelism_tuning_with, BudgetKind},
    Granularity,
};
use crate::design::{Design, Platform};
use crate::model::memory::{self, CeKind, CePlan, FmScheme, MemoryModelCfg};
use crate::model::{dram, throughput};
use crate::nets::{self, LayerKind, Network};
use crate::sim::{self, SimOptions};
use crate::{zc706, CLOCK_HZ};

const MB: f64 = 1024.0 * 1024.0;

fn header(s: &mut String, title: &str) {
    let _ = writeln!(s, "\n=== {title} ===");
}

/// Fig 1 — share of DSC/SCB structure in the zoo LWCNNs.
pub fn fig1() -> String {
    let mut s = String::new();
    header(&mut s, "Fig 1: DSC/SCB structure share");
    let _ = writeln!(s, "{:16} {:>14} {:>14} {:>14}", "network", "DSC+SCB layers", "DSC MACs", "SCB count");
    for net in nets::all_networks() {
        let frac = net.dsc_scb_layer_fraction();
        let dsc_macs = net.dsc_macs() as f64 / net.total_macs() as f64;
        let _ = writeln!(
            s,
            "{:16} {:>13.1}% {:>13.1}% {:>14}",
            net.name,
            frac * 100.0,
            dsc_macs * 100.0,
            net.scbs.len()
        );
    }
    let _ = writeln!(s, "(paper: DSC+SCB dominate every LWCNN's structure)");
    s
}

/// Fig 3 — per-block FM vs weight memory (KB, 8-bit, 224x224).
pub fn fig3(net: &Network) -> String {
    let mut s = String::new();
    header(&mut s, &format!("Fig 3: FM vs weight distribution — {}", net.name));
    let _ = writeln!(s, "{:16} {:>12} {:>12}", "block", "FM KB", "weight KB");
    for (name, fm, w) in net.block_memory_profile() {
        let _ = writeln!(s, "{:16} {:>12.1} {:>12.1}", name, fm as f64 / 1024.0, w as f64 / 1024.0);
    }
    s
}

/// Table I — FRCE vs WRCE analytical comparison on a representative layer.
pub fn tab1() -> String {
    let net = nets::mobilenet_v2();
    let dwc = net.layers.iter().find(|l| l.kind == LayerKind::Dwc).unwrap();
    let (k, f) = (dwc.k as u64, dwc.in_size as u64);
    let mut s = String::new();
    header(&mut s, "Table I: FRCE vs WRCE (3x3 DWC @112x112 example)");
    let _ = writeln!(s, "{:28} {:>22} {:>22}", "feature", "FRCE", "WRCE");
    let _ = writeln!(s, "{:28} {:>22} {:>22}", "reuse scheme", "fully FM reuse", "fully weight reuse");
    let _ = writeln!(
        s,
        "{:28} {:>22} {:>22}",
        "min FM buffer (px)",
        format!("(K-1)F+K-1 = {}", (k - 1) * f + k - 1),
        "2F^2M (GFM)".to_string(),
    );
    let _ = writeln!(s, "{:28} {:>22} {:>22}", "weight storage", "on-chip", "off-chip");
    let _ = writeln!(s, "{:28} {:>22} {:>22}", "weight reads/frame", format!("F^2 = {}", f * f), "1");
    let _ = writeln!(s, "{:28} {:>22} {:>22}", "shortcut", "delayed buffer", "off-chip");
    let _ = writeln!(s, "{:28} {:>22} {:>22}", "off-chip access", "0", "weights+shortcuts");
    s
}

/// Fig 10 — FGPM vs factorized granularity on the paper's toy example
/// (three single-dimension layers sharing 9 PEs).
pub fn fig10() -> String {
    // Three layers with output-channel maxima chosen so factorized
    // granularity over-allocates: the bottleneck is L2.
    let dims = [12usize, 28, 7];
    let budget = 9usize;
    let mut s = String::new();
    header(&mut s, "Fig 10: parallelism granularity toy (9 PEs, dims 12/28/7)");
    let spaces_of: [(&str, fn(usize) -> Vec<usize>); 2] =
        [("factorized", alloc::factor_space), ("FGPM", alloc::fgpm_space)];
    for (label, space) in spaces_of {
        // Greedy bottleneck-first allocation from each space.
        let spaces: Vec<Vec<usize>> = dims.iter().map(|&m| space(m)).collect();
        let mut level = vec![0usize; 3];
        loop {
            let t: Vec<usize> = (0..3).map(|i| dims[i].div_ceil(spaces[i][level[i]])).collect();
            let tmax = *t.iter().max().unwrap();
            let bott: Vec<usize> = (0..3).filter(|&i| t[i] == tmax).collect();
            if bott.iter().any(|&i| level[i] + 1 >= spaces[i].len()) {
                break;
            }
            for &i in &bott {
                level[i] += 1;
            }
            let pes: usize = (0..3).map(|i| spaces[i][level[i]]).sum();
            if pes > budget {
                for &i in &bott {
                    level[i] -= 1;
                }
                break;
            }
        }
        let pes: Vec<usize> = (0..3).map(|i| spaces[i][level[i]]).collect();
        let t: Vec<usize> = (0..3).map(|i| dims[i].div_ceil(pes[i])).collect();
        let tmax = *t.iter().max().unwrap();
        let eff: Vec<String> = (0..3)
            .map(|i| format!("{:.2}", dims[i] as f64 / (tmax as f64 * pes[i] as f64)))
            .collect();
        let _ = writeln!(
            s,
            "{:11} PEs={:?} (total {:>2})  rounds={:?}  eff={:?}",
            label,
            pes,
            pes.iter().sum::<usize>(),
            t,
            eff
        );
    }
    let _ = writeln!(s, "(paper: FGPM conserves PEs on non-bottleneck layers and softens the staircase)");
    s
}

/// Fig 12 — SRAM size & DRAM access vs group boundary.
pub fn fig12(net: &Network) -> String {
    let cfg = MemoryModelCfg::default();
    let sweep = alloc::boundary_sweep(net, &cfg);
    // Algorithm 1 alone decides this figure — no need to pay for the full
    // Design build (Alg 2) per network here.
    let plan = alloc::balanced_memory_allocation(net, Platform::zc706().sram_bytes, &cfg);
    let mut s = String::new();
    header(&mut s, &format!("Fig 12: boundary sweep — {}", net.name));
    let _ = writeln!(s, "{:>9} {:>11} {:>15}", "boundary", "SRAM MB", "DRAM MB/frame");
    let step = (sweep.len() / 16).max(1);
    for p in sweep.iter().step_by(step) {
        let mark = if p.boundary == plan.boundary_min_sram {
            " <- min-SRAM"
        } else if p.boundary == plan.boundary {
            " <- ZC706"
        } else {
            ""
        };
        let _ = writeln!(
            s,
            "{:>9} {:>11.3} {:>15.3}{}",
            p.boundary,
            p.sram_bytes as f64 / MB,
            p.dram_bytes as f64 / MB,
            mark
        );
    }
    let _ = writeln!(
        s,
        "min-SRAM boundary={} ({:.2} MB, {:.2} MB/frame); ZC706 boundary={} ({:.2} MB, {:.2} MB/frame)",
        plan.boundary_min_sram,
        sweep[plan.boundary_min_sram].sram_bytes as f64 / MB,
        sweep[plan.boundary_min_sram].dram_bytes as f64 / MB,
        plan.boundary,
        plan.sram_bytes as f64 / MB,
        plan.dram_bytes as f64 / MB,
    );
    s
}

/// On-chip memory components of one scheme for Fig 13 (FC weights
/// excluded, as in the paper).
fn fig13_components(net: &Network, boundary: usize, scheme: FmScheme) -> (f64, f64, f64, f64) {
    let cfg = MemoryModelCfg { fm_scheme: scheme, ..MemoryModelCfg::default() };
    let plan = CePlan { boundary };
    let rep = memory::sram_report(net, &plan, &cfg);
    let fc_rom: u64 = net
        .layers
        .iter()
        .enumerate()
        .filter(|(i, l)| l.kind == LayerKind::Fc && plan.kind(*i) == CeKind::Frce)
        .map(|(_, l)| l.weight_bytes())
        .sum();
    let line = rep.line_buffer_total as f64 / MB;
    let scb = rep.scb_buffers as f64 / MB;
    let weights = (rep.weight_rom_total - fc_rom) as f64 / MB;
    let wrce = rep.wrce_total as f64 / MB;
    (line, scb, weights, wrce)
}

/// Fig 13 — on-chip memory across streaming schemes.
pub fn fig13() -> String {
    let mut s = String::new();
    header(&mut s, "Fig 13: on-chip memory, baseline vs specific vs proposed (MB, FC weights excluded)");
    let _ = writeln!(
        s,
        "{:16} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "network", "scheme", "line", "SCB", "weights", "GFM+WB", "total"
    );
    for net in nets::all_networks() {
        let full = net.layers.len();
        let min_plan = alloc::balanced_memory_allocation(&net, 0, &MemoryModelCfg::default());
        for (label, boundary, scheme) in [
            ("baseline", full, FmScheme::LineBased),
            ("specific", full, FmScheme::FullyReusedFm),
            ("proposed", min_plan.boundary_min_sram, FmScheme::FullyReusedFm),
        ] {
            let (line, scb, w, wrce) = fig13_components(&net, boundary, scheme);
            let _ = writeln!(
                s,
                "{:16} {:>10} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
                net.name,
                label,
                line,
                scb,
                w,
                wrce,
                line + scb + w + wrce
            );
        }
    }
    let _ = writeln!(
        s,
        "(paper: specific saves {:.1}%/{:.0}% line/SCB buffer vs baseline; hybrid cuts weight storage {:.1}%)",
        paper_ref::claims::LINE_BUFFER_SAVING_PCT,
        paper_ref::claims::SCB_BUFFER_SAVING_PCT,
        paper_ref::claims::WEIGHT_STORAGE_SAVING_PCT
    );
    s
}

/// Fig 14 — off-chip traffic: UE vs SE vs proposed.
pub fn fig14() -> String {
    let mut s = String::new();
    header(&mut s, "Fig 14: off-chip traffic per frame (MB): UE vs SE vs proposed");
    let _ = writeln!(
        s,
        "{:16} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "network", "arch", "FM", "shortcut", "weights", "total"
    );
    let cfg = MemoryModelCfg::default();
    let mut red_fm_ue = Vec::new();
    let mut red_fm_se = Vec::new();
    for net in nets::all_networks() {
        let plan = CePlan { boundary: alloc::balanced_memory_allocation(&net, 0, &cfg).boundary_min_sram };
        let rows = [
            ("UE", dram::unified_ce(&net)),
            ("SE", dram::separated_ce(&net)),
            ("proposed", dram::proposed(&net, &plan)),
        ];
        for (label, t) in &rows {
            let _ = writeln!(
                s,
                "{:16} {:>9} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
                net.name,
                label,
                t.fm as f64 / MB,
                t.shortcut as f64 / MB,
                t.weights as f64 / MB,
                t.total() as f64 / MB
            );
        }
        red_fm_ue.push(1.0 - rows[2].1.fm as f64 / rows[0].1.fm.max(1) as f64);
        red_fm_se.push(1.0 - rows[2].1.fm as f64 / rows[1].1.fm.max(1) as f64);
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64 * 100.0;
    let _ = writeln!(
        s,
        "avg FM reduction: vs UE {:.2}% (paper {:.2}%), vs SE {:.2}% (paper {:.2}%)",
        avg(&red_fm_ue),
        paper_ref::claims::FM_REDUCTION_VS_UE_PCT,
        avg(&red_fm_se),
        paper_ref::claims::FM_REDUCTION_VS_SE_PCT
    );
    s
}

/// One point of the Fig 15 sweep.
pub struct SweepPoint {
    pub pes: usize,
    pub eff_fgpm: f64,
    pub eff_fact: f64,
    pub gops_fgpm: f64,
    pub gops_fact: f64,
}

/// Fig 15 backing data: MAC-unit sweep (60..=4000), FGPM vs factorized.
/// The FRCE/WRCE boundary is the ZC706 one (Algorithm 1 only); the sweep
/// then budgets raw MAC units (the paper's 60-4000 x-axis), which is why
/// it drives Algorithm 2 directly rather than through a DSP-budgeted
/// [`Design`].
pub fn fig15_sweep(net: &Network, budgets: &[usize]) -> Vec<SweepPoint> {
    let plan = CePlan { boundary: zc706_boundary(net) };
    budgets
        .iter()
        .map(|&b| {
            let run = |g| {
                let p = dynamic_parallelism_tuning_with(net, &plan, b, g, BudgetKind::Pes);
                throughput::evaluate(net, &p.allocs)
            };
            let pf = run(Granularity::Fgpm);
            let pb = run(Granularity::Factorized);
            SweepPoint {
                pes: b,
                eff_fgpm: pf.mac_efficiency,
                eff_fact: pb.mac_efficiency,
                gops_fgpm: pf.gops,
                gops_fact: pb.gops,
            }
        })
        .collect()
}

/// The ZC706 Algorithm-1 boundary the Fig 15/16 sweeps run under — the
/// single source of truth shared with `examples/efficiency_sweep.rs`.
pub fn zc706_boundary(net: &Network) -> usize {
    alloc::balanced_memory_allocation(net, Platform::zc706().sram_bytes, &MemoryModelCfg::default()).boundary
}

/// Standard budget grid used by Figs 15/16 (60..4000 MAC units).
pub fn fig15_budgets() -> Vec<usize> {
    let mut v = Vec::new();
    let mut b = 60usize;
    while b <= 4000 {
        v.push(b);
        b = (b as f64 * 1.22) as usize + 10;
    }
    v
}

/// Fig 15 — rendered sweep.
pub fn fig15(net: &Network) -> String {
    let budgets = fig15_budgets();
    let pts = fig15_sweep(net, &budgets);
    let mut s = String::new();
    header(&mut s, &format!("Fig 15: FGPM vs factorized across MAC units — {} @200MHz", net.name));
    let _ = writeln!(
        s,
        "{:>6} {:>10} {:>10} {:>12} {:>12}",
        "MACs", "eff FGPM", "eff fact", "GOPS FGPM", "GOPS fact"
    );
    for p in &pts {
        let _ = writeln!(
            s,
            "{:>6} {:>9.2}% {:>9.2}% {:>12.1} {:>12.1}",
            p.pes,
            p.eff_fgpm * 100.0,
            p.eff_fact * 100.0,
            p.gops_fgpm,
            p.gops_fact
        );
    }
    s
}

/// Fig 16 — average efficiency and standard deviation across the sweep.
pub fn fig16() -> String {
    let budgets = fig15_budgets();
    let mut s = String::new();
    header(&mut s, "Fig 16: sweep-average MAC efficiency +/- std (60-4000 MAC units)");
    let _ = writeln!(
        s,
        "{:16} {:>11} {:>9} {:>11} {:>9} {:>8}",
        "network", "FGPM avg", "std", "fact avg", "std", "gain"
    );
    for net in nets::all_networks() {
        let pts = fig15_sweep(&net, &budgets);
        let stats = |f: &dyn Fn(&SweepPoint) -> f64| {
            let m = pts.iter().map(|p| f(p)).sum::<f64>() / pts.len() as f64;
            let var = pts.iter().map(|p| (f(p) - m).powi(2)).sum::<f64>() / pts.len() as f64;
            (m * 100.0, var.sqrt() * 100.0)
        };
        let (mf, sf) = stats(&|p: &SweepPoint| p.eff_fgpm);
        let (mb, sb) = stats(&|p: &SweepPoint| p.eff_fact);
        let _ = writeln!(
            s,
            "{:16} {:>10.2}% {:>8.2} {:>10.2}% {:>8.2} {:>7.2}%",
            net.name,
            mf,
            sf,
            mb,
            sb,
            mf - mb
        );
    }
    let _ = writeln!(
        s,
        "(paper: FGPM average {:.2}%..{:.2}%, gains {:.2}%..{:.2}%)",
        paper_ref::claims::FGPM_EFF_RANGE_PCT.0,
        paper_ref::claims::FGPM_EFF_RANGE_PCT.1,
        paper_ref::claims::FGPM_GAIN_RANGE_PCT.0,
        paper_ref::claims::FGPM_GAIN_RANGE_PCT.1
    );
    s
}

/// Fig 17's three configurations for MobileNetV2 on the ZC706 DSP budget.
pub struct Fig17Row {
    pub label: &'static str,
    pub actual_eff: f64,
    pub theoretical_eff: f64,
    pub fps: f64,
}

pub fn fig17_rows(frames: u64) -> Vec<Fig17Row> {
    let net = nets::mobilenet_v2();
    let fact = Design::builder(&net).platform(Platform::zc706()).granularity(Granularity::Factorized).build();
    let fgpm = Design::builder(&net).platform(Platform::zc706()).granularity(Granularity::Fgpm).build();
    let mut rows = Vec::new();
    for (label, design, opts) in [
        ("baseline", &fact, SimOptions::baseline()),
        ("optimized", &fact, SimOptions::optimized()),
        ("reallocation", &fgpm, SimOptions::optimized()),
    ] {
        let stats = design.simulate_with(&opts, frames).expect("sim deadlock");
        rows.push(Fig17Row {
            label,
            actual_eff: stats.mac_efficiency(),
            theoretical_eff: design.predicted().mac_efficiency,
            fps: stats.fps(CLOCK_HZ),
        });
    }
    rows
}

/// Fig 17 — balanced-dataflow ablation (cycle-accurate).
pub fn fig17() -> String {
    let rows = fig17_rows(10);
    let mut s = String::new();
    header(&mut s, "Fig 17: MobileNetV2 @ZC706 DSPs — dataflow optimization ablation");
    let _ = writeln!(s, "{:>14} {:>12} {:>14} {:>10}", "scheme", "actual eff", "theoretical", "FPS");
    for r in &rows {
        let _ = writeln!(
            s,
            "{:>14} {:>11.2}% {:>13.2}% {:>10.1}",
            r.label,
            r.actual_eff * 100.0,
            r.theoretical_eff * 100.0,
            r.fps
        );
    }
    let gain = (rows[2].fps / rows[1].fps - 1.0) * 100.0;
    let _ = writeln!(
        s,
        "reallocation throughput gain {:.2}% (paper {:.2}%); paper actual eff: baseline {:.2}%, optimized {:.2}%",
        gain,
        paper_ref::claims::FIG17_REALLOC_GAIN_PCT,
        paper_ref::claims::FIG17_BASELINE_EFF_PCT,
        paper_ref::claims::FIG17_OPTIMIZED_EFF_PCT
    );
    s
}

/// A fully-evaluated implementation row for Tables II/III/IV/V.
pub struct ImplRow {
    pub net_name: String,
    pub config: &'static str,
    pub pes: usize,
    pub dsps: usize,
    pub sram_mb: f64,
    pub dram_mb: f64,
    pub fps_model: f64,
    pub fps_sim: f64,
    pub mac_eff_sim: f64,
    pub latency_ms: f64,
    pub brams: u64,
}

/// Evaluate one (network, SRAM budget) implementation like §VI-B. The
/// budget is expressed as a [`Platform`]: `sram_budget == 0` is the
/// paper's min-SRAM configuration (Alg 1 stops at its first-iteration
/// boundary), anything else a ZC706-DSP part with that SRAM cap.
pub fn impl_row(net: &Network, config: &'static str, sram_budget: u64, frames: u64) -> ImplRow {
    // Every §VI-B configuration uses the ZC706 DSP budget; only the SRAM
    // cap varies between the min-SRAM and ZC706 rows.
    let d = Design::builder(net)
        .platform(Platform::custom(config, sram_budget, zc706::DSP_BUDGET))
        .build();
    let stats = d.simulate(frames).expect("sim");
    // Table rows report the Alg-1 SRAM figure (weight buffers at P_w = 1),
    // exactly as the pre-façade renderer did.
    let sram = d.memory().sram_bytes;
    ImplRow {
        net_name: net.name.clone(),
        config,
        pes: d.parallelism().pes,
        dsps: d.parallelism().dsps,
        sram_mb: sram as f64 / MB,
        dram_mb: d.dram_bytes() as f64 / MB,
        fps_model: d.predicted().fps,
        fps_sim: stats.fps(CLOCK_HZ),
        mac_eff_sim: stats.mac_efficiency(),
        latency_ms: stats.latency_ms(CLOCK_HZ),
        brams: crate::model::brams_for(sram),
    }
}

/// The four implementation rows of Table III.
pub fn tab3_rows(frames: u64) -> Vec<ImplRow> {
    let mut rows = Vec::new();
    for net in [nets::mobilenet_v2(), nets::shufflenet_v2()] {
        rows.push(impl_row(&net, "min-SRAM", 0, frames));
        rows.push(impl_row(&net, "ZC706", zc706::SRAM_BYTES, frames));
    }
    rows
}

/// Table III — performance summary.
pub fn tab3() -> String {
    let rows = tab3_rows(10);
    let mut s = String::new();
    header(&mut s, "Table III: performance summary (batch mode @200MHz)");
    let _ = writeln!(
        s,
        "{:14} {:>9} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "network", "config", "MACs", "FPS(sim)", "FPS(mod)", "SRAM MB", "DRAM MB", "lat ms"
    );
    for r in &rows {
        let _ = writeln!(
            s,
            "{:14} {:>9} {:>6} {:>9.1} {:>9.1} {:>9.2} {:>9.2} {:>9.2}",
            r.net_name, r.config, r.pes, r.fps_sim, r.fps_model, r.sram_mb, r.dram_mb, r.latency_ms
        );
    }
    let _ = writeln!(s, "paper:");
    for (n, c, macs, fps, sram, off, lat) in paper_ref::TABLE3 {
        let _ = writeln!(
            s,
            "{:14} {:>9} {:>6} {:>9.1} {:>9} {:>9.2} {:>9.2} {:>9.2}",
            n, c, macs, fps, "-", sram, off, lat
        );
    }
    s
}

/// Table II — resource utilization.
pub fn tab2() -> String {
    let rows = tab3_rows(6);
    let mut s = String::new();
    header(&mut s, "Table II: resource utilization (ZC706: 545 BRAM36K, 900 DSP)");
    let _ = writeln!(s, "{:14} {:>10} {:>12} {:>10} {:>12}", "network", "BRAM36K", "BRAM util", "DSP", "DSP util");
    for r in rows.iter().filter(|r| r.config == "ZC706") {
        let _ = writeln!(
            s,
            "{:14} {:>10} {:>11.1}% {:>10} {:>11.1}%",
            r.net_name,
            r.brams,
            r.brams as f64 / zc706::BRAM36K as f64 * 100.0,
            r.dsps,
            r.dsps as f64 / zc706::DSP as f64 * 100.0
        );
    }
    let _ = writeln!(s, "paper (LUT/DFF are physical-design artefacts, cited not modelled):");
    for (n, lut, dff, bram, dsp) in paper_ref::TABLE2 {
        let _ = writeln!(s, "{:14} BRAM {:>6.1} DSP {:>4} LUT {:>7} DFF {:>7}", n, bram, dsp, lut, dff);
    }
    s
}

/// Table IV — comparison with prior LWCNN accelerators.
pub fn tab4() -> String {
    let rows = tab3_rows(10);
    let mut s = String::new();
    header(&mut s, "Table IV: comparison with prior LWCNN accelerators");
    let _ = writeln!(
        s,
        "{:16} {:>20} {:>5} {:>6} {:>8} {:>9} {:>10}",
        "work", "network", "DSP", "util%", "FPS", "Thr/DSP", "MAC eff%"
    );
    for (w, _p, _mhz, dsp, util, netn, fps, thr, eff) in paper_ref::TABLE4_PRIOR {
        let _ = writeln!(
            s,
            "{:16} {:>20} {:>5} {:>6.0} {:>8.1} {:>9.2} {:>10.2}",
            w, netn, dsp, util, fps, thr, eff
        );
    }
    for r in rows.iter().filter(|r| r.config == "min-SRAM") {
        let net = nets::by_name(&r.net_name).unwrap();
        let gops_per_dsp = net.total_macs() as f64 * 2.0 * r.fps_sim / 1e9 / r.dsps as f64;
        let _ = writeln!(
            s,
            "{:16} {:>20} {:>5} {:>6.1} {:>8.1} {:>9.2} {:>10.2}  <- ours (sim)",
            "Ours",
            r.net_name,
            r.dsps,
            r.dsps as f64 / zc706::DSP as f64 * 100.0,
            r.fps_sim,
            gops_per_dsp,
            r.mac_eff_sim * 100.0
        );
    }
    let _ = writeln!(s, "paper's own rows: MobileNetV2 985.8 FPS / 94.35%; ShuffleNetV2 2092.4 FPS / 94.58%");
    s
}

/// Table V — memory comparison with prior MobileNetV2 accelerators.
pub fn tab5() -> String {
    let r = impl_row(&nets::mobilenet_v2(), "min-SRAM", 0, 8);
    let mut s = String::new();
    header(&mut s, "Table V: MobileNetV2 memory comparison");
    let _ = writeln!(s, "{:16} {:>9} {:>18} {:>9}", "work", "SRAM MB", "off-chip MB/frame", "FPS");
    for (w, sram, off, fps) in paper_ref::TABLE5 {
        let _ = writeln!(s, "{:16} {:>9.1} {:>18.1} {:>9.1}", w, sram, off, fps);
    }
    let _ = writeln!(
        s,
        "{:16} {:>9.2} {:>18.2} {:>9.1}  <- ours (model+sim)",
        "Ours (repro)", r.sram_mb, r.dram_mb, r.fps_sim
    );
    let (lo, hi) = paper_ref::claims::SRAM_SAVING_VS_16_PCT;
    let saving = (1.0 - r.sram_mb / 3.0) * 100.0; // [16] uses 3.0 MB
    let _ = writeln!(s, "SRAM saving vs [16]: {saving:.1}% (paper claims {lo}..{hi}%)");
    s
}

/// Fig 17's per-layer breakdown: DSPs and actual MAC efficiency per CE
/// under the reallocation configuration (the paper plots these as bars).
pub fn fig17_layers() -> String {
    let net = nets::mobilenet_v2();
    let d = Design::builder(&net).platform(Platform::zc706()).build();
    let stats = d.simulate(10).expect("sim");
    let mut s = String::new();
    header(&mut s, "Fig 17 (per-layer): MobileNetV2 reallocation config");
    let _ = writeln!(
        s,
        "{:>3} {:18} {:>9} {:>5} {:>5} {:>6} {:>9} {:>10}",
        "#", "layer", "kind", "Pw", "Pf", "DSPs", "CE", "actual eff"
    );
    for (i, l) in net.layers.iter().enumerate() {
        if !l.kind.is_mac() {
            continue;
        }
        let a = d.allocs()[i];
        let eff = stats.layer_efficiency(i).unwrap_or(0.0);
        let _ = writeln!(
            s,
            "{:>3} {:18} {:>9} {:>5} {:>5} {:>6} {:>9} {:>9.1}%",
            i,
            l.name,
            format!("{:?}", l.kind),
            a.pw,
            a.pf,
            throughput::layer_dsps(l, a),
            if i < d.ce_plan().boundary { "FRCE" } else { "WRCE" },
            eff * 100.0
        );
    }
    let _ = writeln!(s, "overall actual MAC efficiency {:.2}%", stats.mac_efficiency() * 100.0);
    s
}

/// Ablation matrix (DESIGN.md design-choice benches): every combination
/// of the three dataflow options on MobileNetV2 at the ZC706 budget —
/// isolating each mechanism's contribution to the Fig 17 gap.
pub fn ablation() -> String {
    use crate::sim::PaddingMode;
    let net = nets::mobilenet_v2();
    let d = Design::builder(&net).platform(Platform::zc706()).build();
    let mut s = String::new();
    header(&mut s, "Ablation: dataflow options (MBv2, FGPM alloc @ZC706 DSPs)");
    let _ = writeln!(s, "{:>18} {:>16} {:>12} {:>12} {:>10}", "padding", "buffer scheme", "stride line", "actual eff", "FPS");
    for padding in [PaddingMode::DirectInsert, PaddingMode::AddressGenerated] {
        for scheme in [FmScheme::LineBased, FmScheme::FullyReusedFm] {
            for extra in [false, true] {
                let opts = sim::SimOptions {
                    padding,
                    scheme,
                    stride_extra_line: extra,
                    ..SimOptions::optimized()
                };
                let row = match d.simulate_with(&opts, 8) {
                    Ok(st) => format!("{:>11.2}% {:>10.1}", st.mac_efficiency() * 100.0, st.fps(CLOCK_HZ)),
                    Err(_) => "   DEADLOCK        -".to_string(),
                };
                let _ = writeln!(
                    s,
                    "{:>18} {:>16} {:>12} {row}",
                    format!("{padding:?}"),
                    format!("{scheme:?}"),
                    if extra { "yes" } else { "no" },
                );
            }
        }
    }
    let _ = writeln!(s, "(address-generated padding and the stride line each close part of the Fig 17 gap;");
    let _ = writeln!(s, " the fully-reused scheme also shrinks buffers — Fig 13 — at equal or better speed)");
    s
}

/// Aligned text rendering of a design-space [`crate::sweep::SweepReport`]
/// — one row per (network, platform, granularity) cell with the headline
/// figures (FRCE/WRCE boundary, DSP utilization, SRAM fit, predicted FPS
/// at each platform's own clock, and simulated FPS when the sweep ran the
/// cycle simulator). Failed cells ([`crate::sweep::CellFailure`]) render
/// as `FAILED(kind)` rows interleaved at their matrix position, so a
/// degraded run still shows the full requested matrix. The text twin of
/// `repro sweep --json`.
pub fn sweep_matrix(report: &crate::sweep::SweepReport) -> String {
    let mut s = String::new();
    header(&mut s, "Design-space sweep: networks x platforms x granularities");
    let _ = writeln!(
        s,
        "{:16} {:8} {:10} {:>8} {:>6} {:>6} {:>6} {:>8} {:>5} {:>8} {:>6} {:>9} {:>7} {:>9}",
        "network",
        "platform",
        "gran",
        "boundary",
        "PEs",
        "DSPs",
        "DSP%",
        "SRAM MB",
        "fits",
        "DRAM MB",
        "MHz",
        "FPS",
        "eff%",
        "sim FPS"
    );
    // Walk the requested matrix in combination order: successful cells
    // are stored in that order, and each failure records the matrix
    // `index` it would have occupied, so the two streams zip back into
    // the full matrix.
    let mut cells = report.cells.iter();
    let total = report.cells.len() + report.failures.len();
    for index in 0..total {
        if let Some(f) = report.failures.iter().find(|f| f.index == index) {
            let _ = writeln!(
                s,
                "{:16} {:8} {:10} FAILED({}): {}",
                f.network,
                f.platform,
                crate::design::granularity_name(f.granularity),
                f.error.kind(),
                f.error
            );
            continue;
        }
        let Some(cell) = cells.next() else { break };
        let d = cell.design();
        let sim_fps = match (cell.sim(), cell.sim_error()) {
            (Some(f), _) => format!("{:.1}", f.fps),
            (None, Some(_)) => "DEADLOCK".to_string(),
            (None, None) => "-".to_string(),
        };
        let _ = writeln!(
            s,
            "{:16} {:8} {:10} {:>8} {:>6} {:>6} {:>5.1}% {:>8.2} {:>5} {:>8.2} {:>6.0} {:>9.1} {:>6.2}% {:>9}",
            d.network().name,
            d.platform().name,
            crate::design::granularity_name(d.granularity()),
            format!("{}/{}", d.ce_plan().boundary, d.network().layers.len()),
            d.parallelism().pes,
            d.parallelism().dsps,
            cell.dsp_utilization() * 100.0,
            d.sram_bytes() as f64 / MB,
            if cell.fits_sram() { "yes" } else { "NO" },
            d.dram_bytes() as f64 / MB,
            d.platform().clock_hz / 1e6,
            d.predicted().fps,
            d.predicted().mac_efficiency * 100.0,
            sim_fps
        );
    }
    let _ = writeln!(
        s,
        "(boundary b/L: the first b of L CEs are FRCEs; FPS is Eq 14 at each platform's own clock;"
    );
    let _ = writeln!(
        s,
        " fits=NO marks parts whose SRAM budget is below even this network's allocation)"
    );
    if !report.failures.is_empty() {
        let _ = writeln!(
            s,
            "({} cell(s) FAILED — see the stderr summary or the JSON `failures` section)",
            report.failures.len()
        );
    }
    s
}

/// Aligned text rendering of a constrained-search
/// [`crate::sweep::optimize::OptimizeReport`] — one row per network with
/// the winning cell's headline figures next to the search statistics
/// (evaluated/candidates, pruned count, the parallel-space cardinality
/// the pruning skipped, and mean bound tightness). Networks whose every
/// candidate failed render as `ALL-FAILED` rows; individual failures are
/// footnoted like the sweep matrix. The text twin of
/// `repro optimize --json`.
pub fn optimize_table(report: &crate::sweep::optimize::OptimizeReport) -> String {
    let mut s = String::new();
    header(
        &mut s,
        &format!(
            "Constrained search: best {} per network ({})",
            report.objective.name(),
            match report.strategy {
                crate::sweep::optimize::Strategy::BranchBound => "branch-and-bound, Eq 1-14 bounds",
                crate::sweep::optimize::Strategy::Anneal => "simulated annealing + sweep-up",
            }
        ),
    );
    let _ = writeln!(
        s,
        "{:16} {:14} {:10} {:>12} {:>9} {:>8} {:>5} {:>8} {:>6} {:>12} {:>9}",
        "network",
        "winner",
        "gran",
        report.objective.name(),
        "FPS",
        "SRAM MB",
        "fits",
        "DRAM MB",
        "eval",
        "pruned(space)",
        "tightness"
    );
    for search in &report.searches {
        let Some(cell) = &search.winner else {
            let _ = writeln!(
                s,
                "{:16} ALL-FAILED ({} candidate(s) — see the stderr summary or the JSON \
                 `failures` section)",
                search.network, search.stats.candidates
            );
            continue;
        };
        let d = cell.design();
        let objective_value = match report.objective {
            crate::sweep::optimize::Objective::Fps => format!("{:.1}", d.predicted().fps),
            crate::sweep::optimize::Objective::Sram => {
                format!("{:.2} MB", d.sram_bytes() as f64 / MB)
            }
            crate::sweep::optimize::Objective::Dram => {
                format!("{:.2} MB", d.dram_bytes() as f64 / MB)
            }
        };
        let _ = writeln!(
            s,
            "{:16} {:14} {:10} {:>12} {:>9.1} {:>8.2} {:>5} {:>8.2} {:>6} {:>12} {:>9}",
            search.network,
            d.platform().name,
            crate::design::granularity_name(d.granularity()),
            objective_value,
            d.predicted().fps,
            d.sram_bytes() as f64 / MB,
            if cell.fits_sram() { "yes" } else { "NO" },
            d.dram_bytes() as f64 / MB,
            format!("{}/{}", search.stats.evaluated, search.stats.candidates),
            format!("{}({})", search.stats.pruned, search.stats.pruned_space),
            match search.stats.bound_tightness {
                Some(t) => format!("{t:.3}"),
                None => "-".to_string(),
            }
        );
    }
    let _ = writeln!(
        s,
        "(winner = the exhaustive sweep's byte-identical best cell; pruned(space) counts \
         candidates cut"
    );
    let _ = writeln!(
        s,
        " by the analytic bound and the FGPM/factorized parallel-space points they covered; \
         tightness"
    );
    let _ = writeln!(s, " = mean bound/exact agreement over evaluated candidates, 1.0 = exact)");
    if !report.failures.is_empty() {
        let _ = writeln!(
            s,
            "({} candidate(s) FAILED — see the stderr summary or the JSON `failures` section)",
            report.failures.len()
        );
    }
    s
}

/// Aligned text rendering of a sweep's Pareto analysis
/// ([`crate::sweep::pareto`]): per network, the non-dominated cells over
/// {on-chip SRAM, predicted FPS, off-chip DRAM bytes/frame} followed by
/// every dominated cell with the frontier cell that dominates it, each
/// with the platform clock the FPS column was predicted at. The text twin
/// of the `"pareto"` key in `repro sweep --pareto --json`.
pub fn pareto_table(
    report: &crate::sweep::SweepReport,
    analysis: &crate::sweep::ParetoReport,
) -> String {
    let mut s = String::new();
    header(&mut s, "Pareto frontier: {SRAM, predicted FPS, DRAM/frame} per network");
    let label = |i: usize| {
        let d = report.cells[i].design();
        format!("{}/{}", d.platform().name, crate::design::granularity_name(d.granularity()))
    };
    for front in &analysis.fronts {
        let _ = writeln!(s, "{}:", front.network);
        let _ = writeln!(
            s,
            "  {:20} {:>6} {:>9} {:>9} {:>9}  {}",
            "cell", "MHz", "SRAM MB", "FPS", "DRAM MB", "status"
        );
        let mut row = |i: usize, status: String| {
            let d = report.cells[i].design();
            let _ = writeln!(
                s,
                "  {:20} {:>6.0} {:>9.2} {:>9.1} {:>9.2}  {status}",
                label(i),
                d.platform().clock_hz / 1e6,
                d.sram_bytes() as f64 / MB,
                d.predicted().fps,
                d.dram_bytes() as f64 / MB,
            );
        };
        for &i in &front.frontier {
            row(i, "frontier".to_string());
        }
        for &(i, by) in &front.dominated {
            row(i, format!("dominated by {}", label(by)));
        }
    }
    let _ = writeln!(
        s,
        "(frontier = no other cell of the same network is ≤ SRAM, ≥ FPS and ≤ DRAM with one strict;"
    );
    let _ = writeln!(
        s,
        " MHz is each platform's own clock — pass --pareto-clocks to trade frequency as an axis)"
    );
    if !report.failures.is_empty() {
        let _ = writeln!(
            s,
            "({} FAILED cell(s) are excluded from the frontier analysis)",
            report.failures.len()
        );
    }
    s
}

/// Aligned text rendering of the 4-D clock-axis Pareto analysis
/// ([`crate::sweep::pareto_clocks`]): per network, every (cell, clock)
/// candidate over {SRAM, FPS, DRAM/frame, clock}, frontier first, then
/// each dominated candidate with its dominating candidate. The text twin
/// of the `"pareto_clocks"` key in `repro sweep --pareto-clocks --json`.
pub fn pareto_clocks_table(
    report: &crate::sweep::SweepReport,
    analysis: &crate::sweep::ClockParetoReport,
) -> String {
    let mut s = String::new();
    header(&mut s, "4-D Pareto frontier: {SRAM, predicted FPS, DRAM/frame, clock} per network");
    let label = |c: usize| {
        let cand = &analysis.candidates[c];
        let d = report.cells[cand.cell].design();
        format!(
            "{}/{}@{:.0}",
            d.platform().name,
            crate::design::granularity_name(d.granularity()),
            cand.clock_hz / 1e6
        )
    };
    for front in &analysis.fronts {
        let _ = writeln!(s, "{}:", front.network);
        let _ = writeln!(
            s,
            "  {:24} {:>6} {:>9} {:>9} {:>9}  {}",
            "candidate", "MHz", "SRAM MB", "FPS", "DRAM MB", "status"
        );
        let mut row = |c: usize, status: String| {
            let o = &analysis.candidates[c].objectives;
            let _ = writeln!(
                s,
                "  {:24} {:>6.0} {:>9.2} {:>9.1} {:>9.2}  {status}",
                label(c),
                analysis.candidates[c].clock_hz / 1e6,
                o.sram_bytes as f64 / MB,
                o.fps,
                o.dram_bytes as f64 / MB,
            );
        };
        for &c in &front.frontier {
            row(c, "frontier".to_string());
        }
        for &(c, by) in &front.dominated {
            row(c, format!("dominated by {}", label(by)));
        }
    }
    let _ = writeln!(
        s,
        "(candidates = cells x their --clocks curve points; lower clock is better — a slower"
    );
    let _ = writeln!(
        s,
        " candidate stays on the frontier unless something matches its FPS at ≤ SRAM/DRAM/MHz)"
    );
    if !report.failures.is_empty() {
        let _ = writeln!(
            s,
            "({} FAILED cell(s) are excluded from the frontier analysis)",
            report.failures.len()
        );
    }
    s
}

/// Aligned text rendering of a sweep's clock-scaling curves (`repro
/// sweep --clocks`): per cell, the Eq-14 FPS/GOPS prediction re-evaluated
/// at each requested clock next to the PE array's raw peak
/// ([`crate::model::throughput::peak_gops_at`]). Empty curves render a
/// pointer to the `--clocks` flag instead of an empty table.
pub fn clock_curves(report: &crate::sweep::SweepReport) -> String {
    let mut s = String::new();
    header(&mut s, "Clock-scaling curves: predicted FPS/GOPS vs design clock");
    if report.cells.iter().all(|c| c.clock_curve().is_empty()) {
        let _ = writeln!(s, "(no curve points — pass --clocks MHZ[,MHZ..] to request them)");
        return s;
    }
    let _ = writeln!(
        s,
        "{:16} {:8} {:10} {:>6} {:>9} {:>9} {:>10} {:>7}",
        "network", "platform", "gran", "MHz", "FPS", "GOPS", "peak GOPS", "eff%"
    );
    for cell in &report.cells {
        let d = cell.design();
        for pt in cell.clock_curve() {
            let _ = writeln!(
                s,
                "{:16} {:8} {:10} {:>6.0} {:>9.1} {:>9.1} {:>10.1} {:>6.2}%",
                d.network().name,
                d.platform().name,
                crate::design::granularity_name(d.granularity()),
                pt.clock_hz / 1e6,
                pt.fps,
                pt.gops,
                pt.peak_gops,
                pt.gops / pt.peak_gops * 100.0,
            );
        }
    }
    let _ = writeln!(
        s,
        "(the allocation is clock-independent: FPS/GOPS scale linearly, efficiency stays fixed)"
    );
    s
}

/// One aligned row of a FIFO table: the modeled bound columns next to the
/// observed-peak column (`-` when the FIFO was not simulated). Shared by
/// the per-design and per-sweep renderers so the two can never drift.
fn fifo_row(s: &mut String, f: &crate::model::fifo::FifoDepth, peak: Option<u64>) {
    let (peak_s, util_s) = match peak {
        Some(p) => (
            p.to_string(),
            format!("{:.1}%", p as f64 / f.depth_px.max(1) as f64 * 100.0),
        ),
        None => ("-".to_string(), "-".to_string()),
    };
    let _ = writeln!(
        s,
        "  {:24} {:>8} {:>9} {:>10} {:>9} {:>9} {:>9} {:>7}",
        f.name,
        if f.on_chip { "on-chip" } else { "off-chip" },
        f.rate_px,
        f.margin_px,
        f.depth_px,
        format!("{:.1}", f.bytes as f64 / 1024.0),
        peak_s,
        util_s,
    );
}

fn fifo_table_columns(s: &mut String) {
    let _ = writeln!(
        s,
        "  {:24} {:>8} {:>9} {:>10} {:>9} {:>9} {:>9} {:>7}",
        "fifo", "kind", "rate px", "margin px", "depth px", "KB", "peak px", "util"
    );
}

/// Aligned text rendering of one design's side-FIFO depth report
/// ([`crate::model::fifo::fifo_depths`], the `repro simulate --fifo`
/// output): per FIFO, the modeled rate/margin/depth bound next to the
/// simulator's observed peak occupancy when one was tracked
/// (`peaks[i]` pairs with `report.fifos[i]` — model order *is* pipeline
/// order). Chain networks (no side FIFOs) render a note instead of an
/// empty table.
pub fn fifo_design_table(
    report: &crate::model::fifo::FifoReport,
    peaks: Option<&[u64]>,
) -> String {
    let mut s = String::new();
    header(&mut s, "Side-FIFO depths: modeled bound vs observed peak occupancy");
    if report.is_empty() {
        let _ = writeln!(s, "(chain network — no tee or SCB side FIFOs to size)");
        return s;
    }
    fifo_table_columns(&mut s);
    for (i, f) in report.fifos.iter().enumerate() {
        fifo_row(&mut s, f, peaks.map(|p| p[i]));
    }
    let _ = writeln!(
        s,
        "  total modeled footprint: {:.1} KB ({} FIFOs)",
        report.total_bytes() as f64 / 1024.0,
        report.fifos.len()
    );
    let _ = writeln!(
        s,
        "(depth = rate + margin, capped at the 2-frame ping-pong; every observed peak must stay"
    );
    let _ = writeln!(
        s,
        " within its modeled depth — the differential suite enforces this on all baseline cells)"
    );
    s
}

/// Aligned text rendering of a `--fifo` sweep's side-FIFO figures: per
/// cell, every side FIFO with the modeled depth bound next to the
/// simulated peak occupancy (when the sweep also ran the simulator) and
/// the cell's total modeled footprint. Cells without figures render a
/// pointer to the `--fifo` flag instead of an empty table. The text twin
/// of the per-cell `"fifo"` key in `repro sweep --fifo --json`.
pub fn fifo_table(report: &crate::sweep::SweepReport) -> String {
    let mut s = String::new();
    header(&mut s, "Side-FIFO depths per cell: modeled bound vs observed peak occupancy");
    if report.cells.iter().all(|c| c.fifo().is_none()) {
        let _ = writeln!(s, "(no FIFO figures — pass --fifo to request them)");
        return s;
    }
    for cell in &report.cells {
        let d = cell.design();
        let Some(fifo) = cell.fifo() else { continue };
        let _ = writeln!(
            s,
            "{}/{}/{}:",
            d.network().name,
            d.platform().name,
            crate::design::granularity_name(d.granularity())
        );
        if fifo.report.is_empty() {
            let _ = writeln!(s, "  (chain network — no side FIFOs)");
            continue;
        }
        fifo_table_columns(&mut s);
        for (i, f) in fifo.report.fifos.iter().enumerate() {
            fifo_row(&mut s, f, fifo.peaks.as_ref().map(|p| p[i]));
        }
        let _ = writeln!(
            s,
            "  total modeled footprint: {:.1} KB",
            fifo.report.total_bytes() as f64 / 1024.0
        );
    }
    let _ = writeln!(
        s,
        "(peak px is the simulator's high-water occupancy — `-` for model-only sweeps; util ="
    );
    let _ = writeln!(s, " peak/depth, so 100% means the bound was reached but never exceeded)");
    s
}

/// Render every table and figure (the `report all` target).
pub fn all() -> String {
    let mut s = String::new();
    s.push_str(&fig1());
    for net in [nets::mobilenet_v2(), nets::shufflenet_v2()] {
        s.push_str(&fig3(&net));
    }
    s.push_str(&tab1());
    s.push_str(&fig10());
    for net in nets::all_networks() {
        s.push_str(&fig12(&net));
    }
    s.push_str(&fig13());
    s.push_str(&fig14());
    for net in nets::all_networks() {
        s.push_str(&fig15(&net));
    }
    s.push_str(&fig16());
    s.push_str(&fig17());
    s.push_str(&ablation());
    s.push_str(&tab2());
    s.push_str(&tab3());
    s.push_str(&tab4());
    s.push_str(&tab5());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_reports_all_networks() {
        let s = fig1();
        for n in ["mobilenet_v1", "mobilenet_v2", "shufflenet_v1", "shufflenet_v2"] {
            assert!(s.contains(n), "{s}");
        }
    }

    #[test]
    fn fig13_weight_saving_matches_claim_band() {
        // Hybrid scheme weight storage should be dramatically below the
        // fixed schemes (paper: 81.37% average saving).
        let mut savings = Vec::new();
        for net in nets::all_networks() {
            let full = net.layers.len();
            let min = alloc::balanced_memory_allocation(&net, 0, &MemoryModelCfg::default());
            let (_, _, w_fixed, _) = fig13_components(&net, full, FmScheme::FullyReusedFm);
            let (_, _, w_prop, _) = fig13_components(&net, min.boundary_min_sram, FmScheme::FullyReusedFm);
            savings.push(1.0 - w_prop / w_fixed);
        }
        let avg = savings.iter().sum::<f64>() / savings.len() as f64 * 100.0;
        assert!(avg > 65.0, "avg weight-storage saving {avg:.1}%");
    }

    #[test]
    fn fig15_fgpm_dominates_factorized() {
        let net = nets::shufflenet_v2();
        let pts = fig15_sweep(&net, &[60, 240, 960, 2400]);
        for p in &pts {
            assert!(p.gops_fgpm >= p.gops_fact * 0.999, "pes {}", p.pes);
        }
        // And the average gain is substantial for ShuffleNetV2 (sparse
        // factors; paper reports up to 31.29%).
        let gain: f64 = pts.iter().map(|p| p.eff_fgpm - p.eff_fact).sum::<f64>() / pts.len() as f64;
        assert!(gain > 0.05, "avg gain {gain}");
    }

    #[test]
    fn tab1_and_fig10_render() {
        assert!(tab1().contains("FRCE"));
        let f = fig10();
        assert!(f.contains("factorized") && f.contains("FGPM"));
    }

    #[test]
    fn pareto_table_and_clock_curves_render() {
        let mut spec = crate::sweep::SweepSpec::from_csv(
            Some("shufflenet_v2"),
            Some("zc706,zcu102,edge"),
            None,
        )
        .unwrap();
        spec.clocks_hz = crate::sweep::SweepSpec::parse_clocks_csv("150,300").unwrap();
        let report = spec.run();
        let t = pareto_table(&report, &crate::sweep::pareto(&report));
        assert!(t.contains("shufflenet_v2:"), "{t}");
        assert!(t.contains("frontier"), "{t}");
        let c = clock_curves(&report);
        // 3 cells x 2 clock points.
        assert_eq!(c.matches("shufflenet_v2 ").count(), 6, "{c}");
        // And the empty-curve sweep points at the flag instead of a table.
        let plain = crate::sweep::SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None)
            .unwrap()
            .run();
        assert!(clock_curves(&plain).contains("--clocks"), "{}", clock_curves(&plain));
    }

    #[test]
    fn pareto_clocks_table_renders_every_candidate() {
        let mut spec = crate::sweep::SweepSpec::from_csv(
            Some("shufflenet_v2"),
            Some("zc706,edge"),
            None,
        )
        .unwrap();
        spec.clocks_hz = crate::sweep::SweepSpec::parse_clocks_csv("150,200").unwrap();
        let report = spec.run();
        let analysis = crate::sweep::pareto_clocks(&report);
        let t = pareto_clocks_table(&report, &analysis);
        assert!(t.contains("shufflenet_v2:"), "{t}");
        assert!(t.contains("frontier"), "{t}");
        // 2 cells x 2 clock points: every candidate label appears.
        for label in ["zc706/fgpm@150", "zc706/fgpm@200", "edge/fgpm@150", "edge/fgpm@200"] {
            assert!(t.contains(label), "missing {label} in:\n{t}");
        }
    }

    #[test]
    fn fifo_tables_render_modeled_and_observed_columns() {
        let mut spec =
            crate::sweep::SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
        // Without --fifo the sweep renderer points at the flag.
        assert!(fifo_table(&spec.run()).contains("--fifo"));
        spec.fifo = true;
        spec.frames = Some(2);
        let report = spec.run();
        let t = fifo_table(&report);
        assert!(t.contains("shufflenet_v2/zc706/fgpm:"), "{t}");
        assert!(t.contains("tee->") && t.contains("peak px"), "{t}");
        assert!(t.contains("total modeled footprint"), "{t}");
        assert!(!t.contains(" -\n"), "simulated cells must show real peaks:\n{t}");
        // The per-design twin: observed column filled when peaks are given,
        // dashed when not, and a chain network explains itself.
        let cell = &report.cells[0];
        let fifo = cell.fifo().unwrap();
        let with = fifo_design_table(&fifo.report, fifo.peaks.as_deref());
        assert!(with.contains("tee->") && !with.lines().any(|l| l.ends_with(" -")), "{with}");
        let without = fifo_design_table(&fifo.report, None);
        assert!(without.contains(" -"), "{without}");
        let chain = crate::model::fifo::fifo_depths(
            &nets::mobilenet_v1(),
            &CePlan { boundary: 0 },
            FmScheme::FullyReusedFm,
        );
        assert!(fifo_design_table(&chain, None).contains("chain network"));
    }

    #[test]
    fn sweep_matrix_renders_every_cell() {
        let spec = crate::sweep::SweepSpec::from_csv(
            Some("shufflenet_v2"),
            Some("zc706,edge"),
            None,
        )
        .unwrap();
        let s = sweep_matrix(&spec.run());
        assert!(s.contains("shufflenet_v2"), "{s}");
        assert!(s.contains("zc706") && s.contains("edge"), "{s}");
        // Two cells -> header + 2 rows + 2 footnote lines at minimum.
        assert!(s.lines().count() >= 5, "{s}");
    }
}
