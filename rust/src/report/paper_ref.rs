//! The paper's reported numbers, kept verbatim so every renderer can print
//! *paper vs measured* side by side (EXPERIMENTS.md consumes these).

/// Table III — performance summary (batch mode, 200 MHz).
/// (network, config, mac_units, fps, sram_mb, offchip_mb_per_frame, latency_ms)
pub const TABLE3: [(&str, &str, u32, f64, f64, f64, f64); 4] = [
    ("mobilenet_v2", "min-SRAM", 1567, 985.8, 1.27, 2.81, 10.63),
    ("mobilenet_v2", "ZC706", 1569, 981.4, 1.75, 2.05, 5.46),
    ("shufflenet_v2", "min-SRAM", 1604, 2092.4, 0.71, 1.96, 4.74),
    ("shufflenet_v2", "ZC706", 1612, 2199.2, 1.34, 0.98, 1.33),
];

/// Table II — resource utilization on ZC706.
/// (network, lut, dff, bram36k, dsp)
pub const TABLE2: [(&str, u32, u32, f64, u32); 2] = [
    ("mobilenet_v2", 163_087, 189_476, 329.5, 844),
    ("shufflenet_v2", 117_554, 177_863, 209.0, 853),
];

/// Table IV — prior-work comparison rows (as published).
/// (work, platform, mhz, dsp, dsp_util_pct, network, fps, thr_per_dsp_gops,
///  mac_eff_pct)
pub const TABLE4_PRIOR: [(&str, &str, u32, u32, f64, &str, f64, f64, f64); 11] = [
    ("FPL'19 [3]", "ZYNQ XCZU9EG", 333, 2070, 82.0, "MobileNetV2", 809.8, 0.23, 17.62),
    ("FPGA'20 [2]", "Kintex7 XC7K325T", 200, 704, 84.0, "MobileNetV2", 325.7, 0.28, 34.70),
    ("FPGA'20 [2]", "Kintex7 XC7K325T", 200, 704, 84.0, "MobileNetV1", 264.6, 0.43, 53.46),
    ("FPL'20 [5]", "Arria10 SOC", 200, 1220, 72.0, "MobileNetV2", 1050.0, 0.52, 64.55),
    ("TCASII'20 [39]", "Virtex-7 XC7VX485T", 200, 1926, 68.0, "ShuffleNetV1", 787.4, 0.11, 28.00),
    ("SMC'21 [40]", "ZYNQ XC7Z045", 100, 0, 0.0, "ShuffleNetV2", 291.5, 0.0, 0.0),
    ("FPL'21 [11]", "Virtex-7 XC7V690T", 150, 2160, 60.0, "MobileNetV2", 302.3, 0.08, 14.00),
    ("TCASI'21 [6]", "ZYNQ XCZU9EQ", 200, 576, 23.0, "MobileNetV2", 381.7, 0.40, 0.0),
    ("TCAD'22 [16]", "ZYNQ XCZU9EG", 333, 1283, 51.0, "MobileNetV2", 1910.0, 0.89, 80.07),
    ("TCASI'22 [23]", "AMD KCU1500", 200, 2240, 41.0, "EfficientNet-B1", 213.2, 0.15, 19.37),
    ("TCASI'22 [4]", "Arria10 SOC", 200, 607, 36.0, "MobileNetV2", 222.2, 0.30, 44.46),
];

/// Table IV — the paper's own rows.
pub const TABLE4_OURS: [(&str, u32, f64, f64, f64, f64); 2] = [
    // (network, dsp, dsp_util, fps, thr/dsp, mac_eff)
    ("MobileNetV2", 844, 94.0, 985.8, 0.70, 94.35),
    ("ShuffleNetV2", 853, 95.0, 2092.4, 0.71, 94.58),
];

/// Table V — memory comparison for MobileNetV2 accelerators.
/// (work, sram_mb, offchip_mb_per_frame, fps)
pub const TABLE5: [(&str, f64, f64, f64); 5] = [
    ("FPGA'20 [2]", 0.9, 16.9, 325.7),
    ("TCASI'21 [6]", 1.0, 3.3, 381.7),
    ("FPL'21 [11]", 4.1, 3.3, 302.3),
    ("TCAD'22 [16]", 3.0, 1.4, 1910.0),
    ("Our", 1.3, 2.8, 985.8),
];

/// Headline claims quoted in the abstract / §VI.
pub mod claims {
    /// On-chip memory saving vs the reference design [16].
    pub const SRAM_SAVING_VS_16_PCT: (f64, f64) = (56.67, 68.29);
    /// Peak FPS (ShuffleNetV2).
    pub const PEAK_FPS: f64 = 2092.4;
    /// Peak MAC efficiency (%).
    pub const PEAK_MAC_EFF: f64 = 94.58;
    /// DSP utilization (%).
    pub const DSP_UTIL: f64 = 95.0;
    /// Average FM-access reduction vs UE / SE (Fig 14).
    pub const FM_REDUCTION_VS_UE_PCT: f64 = 98.07;
    pub const FM_REDUCTION_VS_SE_PCT: f64 = 96.69;
    /// Shortcut / weight access reductions (Fig 14).
    pub const SHORTCUT_REDUCTION_PCT: f64 = 93.30;
    pub const WEIGHT_REDUCTION_PCT: f64 = 12.56;
    /// Fig 13: line-buffer / SCB-buffer savings of "specific" vs "baseline".
    pub const LINE_BUFFER_SAVING_PCT: f64 = 53.71;
    pub const SCB_BUFFER_SAVING_PCT: f64 = 60.0;
    /// Weight-storage reduction of the hybrid scheme (Fig 13).
    pub const WEIGHT_STORAGE_SAVING_PCT: f64 = 81.37;
    /// Fig 16: theoretical MAC efficiency band with FGPM.
    pub const FGPM_EFF_RANGE_PCT: (f64, f64) = (93.06, 95.68);
    /// Fig 16: improvement over factorized baseline.
    pub const FGPM_GAIN_RANGE_PCT: (f64, f64) = (6.46, 31.29);
    /// Fig 17: baseline -> optimized actual efficiency.
    pub const FIG17_BASELINE_EFF_PCT: f64 = 69.13;
    pub const FIG17_OPTIMIZED_EFF_PCT: f64 = 84.79;
    /// Fig 17: reallocation throughput gain.
    pub const FIG17_REALLOC_GAIN_PCT: f64 = 11.29;
    /// Fig 6: SCB FM-buffer reduction (fully-reused vs line-based).
    pub const FIG6_SCB_BUFFER_REDUCTION_PCT: f64 = 69.23;
}
