//! Regenerates the paper's evaluation *tables* (II-V) end to end — each
//! table is produced by the real pipeline: Algorithm 1 boundary placement,
//! Algorithm 2 parallelism tuning, the Eq-12/13/14 models, and the
//! cycle-level simulator for actual FPS / MAC efficiency.

use repro::util::bench::time;
use repro::report;

fn main() {
    println!("== paper_tables: regenerating Tables II-V ==");

    let mut out = String::new();
    time("tab2_resource_utilization", 30000.0, || out = report::tab2());
    println!("{out}");

    time("tab3_performance_summary", 30000.0, || out = report::tab3());
    println!("{out}");

    time("tab4_prior_work_comparison", 30000.0, || out = report::tab4());
    println!("{out}");

    time("tab5_memory_comparison", 20000.0, || out = report::tab5());
    println!("{out}");
}
