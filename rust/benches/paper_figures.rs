//! Regenerates every *figure* of the paper's evaluation (Figs 1, 3, 10,
//! 12-17 + Table I's analytic comparison) and times each renderer.
//!
//! Run: `cargo bench --offline` (or `--bench paper_figures`). The rendered
//! rows are printed so the bench log doubles as the reproduction record
//! consumed by EXPERIMENTS.md.

use repro::util::bench::time;
use repro::{nets, report};

fn main() {
    println!("== paper_figures: regenerating every figure ==");

    let mut out = String::new();
    time("fig1_structure_share", 2000.0, || out = report::fig1());
    println!("{out}");

    time("fig3_memory_distribution", 2000.0, || {
        out = [nets::mobilenet_v2(), nets::shufflenet_v2()]
            .iter()
            .map(report::fig3)
            .collect();
    });
    println!("{out}");

    time("tab1_ce_comparison", 1000.0, || out = report::tab1());
    println!("{out}");

    time("fig10_granularity_toy", 1000.0, || out = report::fig10());
    println!("{out}");

    time("fig12_boundary_sweep_all_nets", 4000.0, || {
        out = nets::all_networks().iter().map(report::fig12).collect();
    });
    println!("{out}");

    time("fig13_onchip_memory_schemes", 2000.0, || out = report::fig13());
    println!("{out}");

    time("fig14_offchip_traffic", 2000.0, || out = report::fig14());
    println!("{out}");

    time("fig15_fgpm_sweep_all_nets", 8000.0, || {
        out = nets::all_networks().iter().map(report::fig15).collect();
    });
    println!("{out}");

    time("fig16_sweep_statistics", 8000.0, || out = report::fig16());
    println!("{out}");

    time("fig17_balanced_dataflow_ablation", 20000.0, || out = report::fig17());
    println!("{out}");
}
