//! §Perf hot-path benches (DESIGN.md §Perf, EXPERIMENTS.md §Perf):
//!
//! * cycle-level simulator throughput (wall ms per simulated frame) — the
//!   L3 bottleneck for every sweep-style experiment;
//! * allocation pipeline latency (Alg 1 + Alg 2 at ZC706 budgets);
//! * FGPM space construction;
//! * streaming-coordinator overhead vs the busiest worker (only when
//!   artifacts exist).

use repro::alloc::{self, Granularity};
use repro::model::memory::{CePlan, MemoryModelCfg};
use repro::sim::{self, SimOptions};
use repro::util::bench::time;
use repro::{coordinator, nets, runtime, zc706};

fn main() {
    println!("== sim_hotpath: performance of the reproduction stack itself ==");

    let net = nets::mobilenet_v2();
    let cfg = MemoryModelCfg::default();
    let boundary = alloc::balanced_memory_allocation(&net, zc706::SRAM_BYTES, &cfg).boundary;
    let plan = CePlan { boundary };
    let par = alloc::dynamic_parallelism_tuning(&net, &plan, zc706::DSP_BUDGET, Granularity::Fgpm);

    let frames = 10u64;
    let s = time("sim_mbv2_zc706_10frames", 15000.0, || {
        sim::simulate(&net, &par.allocs, &plan, &SimOptions::optimized(), frames).unwrap();
    });
    println!("  -> {:.2} ms per simulated frame", s.median_ms / frames as f64);

    time("pipeline_build_mbv2", 3000.0, || {
        let _ = sim::build_pipeline(&net, &par.allocs, &plan, &SimOptions::optimized());
    });

    time("alg1_balanced_memory_allocation", 3000.0, || {
        let _ = alloc::balanced_memory_allocation(&net, zc706::SRAM_BYTES, &cfg);
    });

    time("alg2_dynamic_parallelism_tuning", 5000.0, || {
        let _ = alloc::dynamic_parallelism_tuning(&net, &plan, zc706::DSP_BUDGET, Granularity::Fgpm);
    });

    time("fgpm_space_1280", 1000.0, || {
        let _ = alloc::fgpm_space(1280);
    });

    time("design_point_full_methodology", 8000.0, || {
        let _ = alloc::design_point(&net, zc706::SRAM_BYTES, zc706::DSP_BUDGET, Granularity::Fgpm);
    });

    // Coordinator overhead (needs `make artifacts`).
    let dir = runtime::artifacts_dir();
    if dir.join("mbv2_manifest.json").exists() {
        let report = coordinator::run_streaming(dir, "mbv2", 6, 3).expect("stream");
        println!(
            "coordinator: {:.2} FPS, overhead {:.1}% (target <5% of wall; XLA-CPU compute dominates)",
            report.fps,
            report.coordinator_overhead() * 100.0
        );
    } else {
        println!("coordinator bench skipped: run `make artifacts` first");
    }
}
