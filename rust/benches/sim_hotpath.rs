//! §Perf hot-path benches (DESIGN.md §Perf, EXPERIMENTS.md §Perf):
//!
//! * cycle-level simulator throughput (wall ms per simulated frame) — the
//!   L3 bottleneck for every sweep-style experiment;
//! * full `Design` compilation latency (Alg 1 + Alg 2 at the ZC706
//!   platform) and its JSON persistence round-trip;
//! * the individual Alg 1 / Alg 2 / FGPM-space stages;
//! * the design-space sweep engine, serial vs parallel (`--jobs`), with
//!   a byte-identical-output assertion on the parallel path;
//! * the memoized sweep cache, cold fill vs warm reload over the full
//!   12-cell catalog matrix, with hit-rate and byte-identity assertions;
//! * streaming-coordinator overhead vs the busiest worker (only when
//!   artifacts exist).

use repro::alloc::{self, Granularity};
use repro::model::memory::MemoryModelCfg;
use repro::sim::{self, SimOptions};
use repro::util::bench::time;
use repro::{coordinator, nets, runtime, Design, Platform};

fn main() {
    println!("== sim_hotpath: performance of the reproduction stack itself ==");

    let net = nets::mobilenet_v2();
    let design = Design::builder(&net).platform(Platform::zc706()).build();

    let frames = 10u64;
    let s = time("sim_mbv2_zc706_10frames", 15000.0, || {
        design.simulate(frames).unwrap();
    });
    println!("  -> {:.2} ms per simulated frame", s.median_ms / frames as f64);

    time("pipeline_build_mbv2", 3000.0, || {
        let _ = sim::build_pipeline(&net, design.allocs(), design.ce_plan(), &SimOptions::optimized());
    });

    let cfg = MemoryModelCfg::default();
    time("alg1_balanced_memory_allocation", 3000.0, || {
        let _ = alloc::balanced_memory_allocation(&net, design.platform().sram_bytes, &cfg);
    });

    time("alg2_dynamic_parallelism_tuning", 5000.0, || {
        let _ = alloc::dynamic_parallelism_tuning(
            &net,
            design.ce_plan(),
            design.platform().dsp_budget,
            Granularity::Fgpm,
        );
    });

    time("fgpm_space_1280", 1000.0, || {
        let _ = alloc::fgpm_space(1280);
    });

    time("design_build_full_methodology", 8000.0, || {
        let _ = Design::builder(&net).platform(Platform::zc706()).build();
    });

    time("design_json_roundtrip", 2000.0, || {
        let d = Design::from_json(&design.to_json()).expect("round trip");
        let _ = d;
    });

    // The design-space sweep: one full catalog row (every platform, model
    // only) for MobileNetV2 — the per-cell cost every BENCH sweep pays.
    let sweep_spec = repro::sweep::SweepSpec::from_csv(Some("mobilenet_v2"), None, None).unwrap();
    time("sweep_mbv2_full_catalog_model_only", 20000.0, || {
        let rep = sweep_spec.run();
        let _ = rep.to_json();
    });

    // Serial vs parallel sweep engine over the full 12-cell catalog
    // matrix: the headline wall-clock win of `--jobs`, plus a one-shot
    // assertion that parallelism never changes the bytes.
    let full = repro::sweep::SweepSpec::default();
    let mut serial_report = None;
    let serial = time("sweep_catalog_12cells_jobs1", 20000.0, || {
        serial_report = Some(full.run());
    });
    let jobs = repro::util::pool::default_jobs().clamp(2, 8);
    let mut par_spec = full.clone();
    par_spec.jobs = jobs;
    let mut par_report = None;
    let par = time(&format!("sweep_catalog_12cells_jobs{jobs}"), 20000.0, || {
        par_report = Some(par_spec.run());
    });
    assert_eq!(
        serial_report.expect("timed at least once").to_json(),
        par_report.expect("timed at least once").to_json(),
        "parallel sweep must be byte-identical to serial"
    );
    println!(
        "  -> parallel speedup {:.2}x at {} jobs (deterministic output verified)",
        serial.median_ms / par.median_ms,
        jobs
    );

    // The memoized cache over the same 12-cell matrix: one cold fill,
    // then timed warm reloads (the cost every repeat BENCH sweep pays).
    let cache_dir = std::env::temp_dir().join("repro_sim_hotpath_sweep_cache");
    let _ = std::fs::remove_dir_all(&cache_dir);
    let cached_spec = repro::sweep::SweepSpec {
        cache_dir: Some(cache_dir.clone()),
        ..repro::sweep::SweepSpec::default()
    };
    let cold_report = {
        let mut report = None;
        time("sweep_catalog_12cells_cache_cold", 20000.0, || {
            let _ = std::fs::remove_dir_all(&cache_dir);
            report = Some(cached_spec.run());
        });
        report.expect("timed at least once")
    };
    let mut warm_report = None;
    let warm = time("sweep_catalog_12cells_cache_warm", 5000.0, || {
        warm_report = Some(cached_spec.run());
    });
    let warm_report = warm_report.expect("timed at least once");
    let stats = warm_report.cache.expect("cached run reports stats");
    assert_eq!((stats.hits, stats.misses), (12, 0), "warm run must be all hits");
    assert_eq!(
        cold_report.to_json(),
        warm_report.to_json(),
        "warm sweep must be byte-identical to cold"
    );
    println!(
        "  -> warm-cache speedup {:.2}x over serial cold (100% hit rate, zero re-derivation)",
        serial.median_ms / warm.median_ms
    );
    let _ = std::fs::remove_dir_all(&cache_dir);

    // Coordinator overhead (needs `make artifacts`).
    let dir = runtime::artifacts_dir();
    if dir.join("mbv2_manifest.json").exists() {
        let report = coordinator::run_streaming_design(&design, dir, 6, 3).expect("stream");
        println!(
            "coordinator: {:.2} FPS, overhead {:.1}% (target <5% of wall; XLA-CPU compute dominates)",
            report.fps,
            report.coordinator_overhead() * 100.0
        );
    } else {
        println!("coordinator bench skipped: run `make artifacts` first");
    }
}
