//! §Perf hot-path benches (DESIGN.md §Perf, EXPERIMENTS.md §Perf):
//!
//! * cycle-level simulator throughput (wall ms per simulated frame) — the
//!   L3 bottleneck for every sweep-style experiment;
//! * full `Design` compilation latency (Alg 1 + Alg 2 at the ZC706
//!   platform) and its JSON persistence round-trip;
//! * the individual Alg 1 / Alg 2 / FGPM-space stages;
//! * the design-space sweep engine, serial vs parallel (`--jobs`), with
//!   a byte-identical-output assertion on the parallel path;
//! * the memoized sweep cache, cold fill vs warm reload over the full
//!   12-cell catalog matrix, with hit-rate and byte-identity assertions;
//! * streaming-coordinator overhead vs the busiest worker (only when
//!   artifacts exist).
//!
//! Environment knobs (the BENCH_sim.json trajectory, EXPERIMENTS.md §3):
//!
//! * `REPRO_BENCH_JSON=path` — write the simulator section's records as a
//!   machine-readable `BENCH_sim.json` document (stepped-vs-event
//!   ms-per-frame, the measured speedup ratio, and the warm-marginal
//!   per-frame cost).
//! * `REPRO_BENCH_SMOKE=1` — CI check mode: tiny frame counts and time
//!   budgets, and only the simulator section runs (enough to validate the
//!   harness and the emitted schema, not to publish numbers).

use std::collections::BTreeMap;

use repro::alloc::{self, Granularity};
use repro::model::memory::MemoryModelCfg;
use repro::sim::{self, SimOptions, SimRunner};
use repro::util::bench::{time, Sample};
use repro::util::json::Json;
use repro::{coordinator, nets, runtime, Design, Platform};

/// One BENCH_sim.json record out of a [`Sample`].
fn record(s: &Sample, engine: &str, frames: u64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(s.name.clone()));
    m.insert("engine".to_string(), Json::Str(engine.to_string()));
    m.insert("median_ms".to_string(), Json::Num(s.median_ms));
    m.insert("min_ms".to_string(), Json::Num(s.min_ms));
    m.insert("max_ms".to_string(), Json::Num(s.max_ms));
    m.insert("ms_per_frame".to_string(), Json::Num(s.median_ms / frames as f64));
    m.insert("iters".to_string(), Json::Num(s.iters as f64));
    Json::Obj(m)
}

fn main() {
    println!("== sim_hotpath: performance of the reproduction stack itself ==");

    // CI check mode: prove the harness runs and the schema is valid
    // without paying publishable-number budgets.
    let smoke = std::env::var("REPRO_BENCH_SMOKE").is_ok();
    let net = nets::mobilenet_v2();
    let design = Design::builder(&net).platform(Platform::zc706()).build();

    let frames = if smoke { 3u64 } else { 10u64 };
    let sim_budget = if smoke { 1500.0 } else { 15000.0 };
    let event = time("sim_mbv2_zc706_10frames", sim_budget, || {
        design.simulate(frames).unwrap();
    });
    println!("  -> {:.2} ms per simulated frame", event.median_ms / frames as f64);

    // The cycle-stepped reference engine on the identical run: the
    // "before" row of the BENCH_sim.json trajectory.
    let stepped_opts = SimOptions { event_driven: false, ..*design.sim_options() };
    let stepped = time("sim_mbv2_zc706_10frames_stepped", sim_budget, || {
        design.simulate_with(&stepped_opts, frames).unwrap();
    });
    let speedup = stepped.median_ms / event.median_ms;
    println!("  -> event-driven speedup {speedup:.2}x over the stepped engine");

    // Warm-state reuse: pay the pipeline fill once, then measure the
    // marginal cost of the remaining frames from a warm clone.
    let pipeline =
        sim::build_pipeline(&net, design.allocs(), design.ce_plan(), design.sim_options());
    let mut warm_runner = SimRunner::new(&pipeline, frames).unwrap();
    warm_runner.advance_to(1).unwrap();
    let warm = time("sim_mbv2_zc706_warm_marginal", sim_budget / 2.0, || {
        let mut r = warm_runner.clone();
        r.advance_to(frames).unwrap();
    });
    let marginal_frames = frames - 1;
    println!(
        "  -> {:.2} ms per marginal frame from warm state",
        warm.median_ms / marginal_frames as f64
    );

    if let Ok(path) = std::env::var("REPRO_BENCH_JSON") {
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("sim_mbv2_zc706_10frames".to_string()));
        doc.insert("frames".to_string(), Json::Num(frames as f64));
        doc.insert(
            "records".to_string(),
            Json::Arr(vec![
                record(&stepped, "stepped", frames),
                record(&event, "event_driven", frames),
                record(&warm, "event_driven_warm", marginal_frames),
            ]),
        );
        doc.insert("required_speedup".to_string(), Json::Num(2.0));
        doc.insert("speedup_stepped_over_event".to_string(), Json::Num(speedup));
        doc.insert("trajectory".to_string(), Json::Str("sim".to_string()));
        doc.insert("version".to_string(), Json::Num(1.0));
        std::fs::write(&path, format!("{}\n", Json::Obj(doc)))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("  -> wrote {path}");
    }

    if smoke {
        println!("== smoke mode: skipping the non-sim sections ==");
        return;
    }

    time("pipeline_build_mbv2", 3000.0, || {
        let _ = sim::build_pipeline(&net, design.allocs(), design.ce_plan(), &SimOptions::optimized());
    });

    let cfg = MemoryModelCfg::default();
    time("alg1_balanced_memory_allocation", 3000.0, || {
        let _ = alloc::balanced_memory_allocation(&net, design.platform().sram_bytes, &cfg);
    });

    time("alg2_dynamic_parallelism_tuning", 5000.0, || {
        let _ = alloc::dynamic_parallelism_tuning(
            &net,
            design.ce_plan(),
            design.platform().dsp_budget,
            Granularity::Fgpm,
        );
    });

    time("fgpm_space_1280", 1000.0, || {
        let _ = alloc::fgpm_space(1280);
    });

    time("design_build_full_methodology", 8000.0, || {
        let _ = Design::builder(&net).platform(Platform::zc706()).build();
    });

    time("design_json_roundtrip", 2000.0, || {
        let d = Design::from_json(&design.to_json()).expect("round trip");
        let _ = d;
    });

    // The design-space sweep: one full catalog row (every platform, model
    // only) for MobileNetV2 — the per-cell cost every BENCH sweep pays.
    let sweep_spec = repro::sweep::SweepSpec::from_csv(Some("mobilenet_v2"), None, None).unwrap();
    time("sweep_mbv2_full_catalog_model_only", 20000.0, || {
        let rep = sweep_spec.run();
        let _ = rep.to_json();
    });

    // Serial vs parallel sweep engine over the full 12-cell catalog
    // matrix: the headline wall-clock win of `--jobs`, plus a one-shot
    // assertion that parallelism never changes the bytes.
    let full = repro::sweep::SweepSpec::default();
    let mut serial_report = None;
    let serial = time("sweep_catalog_12cells_jobs1", 20000.0, || {
        serial_report = Some(full.run());
    });
    let jobs = repro::util::pool::default_jobs().clamp(2, 8);
    let mut par_spec = full.clone();
    par_spec.jobs = jobs;
    let mut par_report = None;
    let par = time(&format!("sweep_catalog_12cells_jobs{jobs}"), 20000.0, || {
        par_report = Some(par_spec.run());
    });
    assert_eq!(
        serial_report.expect("timed at least once").to_json(),
        par_report.expect("timed at least once").to_json(),
        "parallel sweep must be byte-identical to serial"
    );
    println!(
        "  -> parallel speedup {:.2}x at {} jobs (deterministic output verified)",
        serial.median_ms / par.median_ms,
        jobs
    );

    // The memoized cache over the same 12-cell matrix: one cold fill,
    // then timed warm reloads (the cost every repeat BENCH sweep pays).
    let cache_dir = std::env::temp_dir().join("repro_sim_hotpath_sweep_cache");
    let _ = std::fs::remove_dir_all(&cache_dir);
    let cached_spec = repro::sweep::SweepSpec {
        cache_dir: Some(cache_dir.clone()),
        ..repro::sweep::SweepSpec::default()
    };
    let cold_report = {
        let mut report = None;
        time("sweep_catalog_12cells_cache_cold", 20000.0, || {
            let _ = std::fs::remove_dir_all(&cache_dir);
            report = Some(cached_spec.run());
        });
        report.expect("timed at least once")
    };
    let mut warm_report = None;
    let warm = time("sweep_catalog_12cells_cache_warm", 5000.0, || {
        warm_report = Some(cached_spec.run());
    });
    let warm_report = warm_report.expect("timed at least once");
    let stats = warm_report.cache.expect("cached run reports stats");
    assert_eq!((stats.hits, stats.misses), (12, 0), "warm run must be all hits");
    assert_eq!(
        cold_report.to_json(),
        warm_report.to_json(),
        "warm sweep must be byte-identical to cold"
    );
    println!(
        "  -> warm-cache speedup {:.2}x over serial cold (100% hit rate, zero re-derivation)",
        serial.median_ms / warm.median_ms
    );
    let _ = std::fs::remove_dir_all(&cache_dir);

    // Coordinator overhead (needs `make artifacts`).
    let dir = runtime::artifacts_dir();
    if dir.join("mbv2_manifest.json").exists() {
        let report = coordinator::run_streaming_design(&design, dir, 6, 3).expect("stream");
        println!(
            "coordinator: {:.2} FPS, overhead {:.1}% (target <5% of wall; XLA-CPU compute dominates)",
            report.fps,
            report.coordinator_overhead() * 100.0
        );
    } else {
        println!("coordinator bench skipped: run `make artifacts` first");
    }
}
