//! Property-based tests over the coordinator/allocator/model invariants
//! (using the self-contained harness in `repro::util::prop`; proptest is
//! not vendored in this offline build).

use repro::alloc::{self, fgpm, parallelism::BudgetKind, Granularity};
use repro::model::memory::{CePlan, MemoryModelCfg};
use repro::model::{dram, fifo, memory, throughput};
use repro::nets;
use repro::sim::{self, SimOptions};
use repro::util::json::Json;
use repro::util::prop::{check, Rng};
use repro::{Design, Platform};

// ---------------------------------------------------------------------
// FGPM space properties (Eq 11, §IV-A)
// ---------------------------------------------------------------------

#[test]
fn prop_fgpm_space_is_canonical() {
    check("fgpm_space", 300, |r: &mut Rng| r.range(1, 5000), |&m| {
        let space = fgpm::fgpm_space(m);
        // Strictly ascending; starts at 1; ends at m.
        if space.first() != Some(&1) || space.last() != Some(&m) {
            return Err("endpoints".into());
        }
        if space.windows(2).any(|w| w[0] >= w[1]) {
            return Err("not ascending".into());
        }
        // Every distinct T is hit exactly once, by its cheapest P.
        let mut all: Vec<usize> = (1..=m).map(|p| fgpm::rounds(m, p)).collect();
        all.sort_unstable();
        all.dedup();
        if all.len() != space.len() {
            return Err(format!("covers {} of {} T values", space.len(), all.len()));
        }
        for &p in &space {
            if p > 1 && fgpm::rounds(m, p - 1) == fgpm::rounds(m, p) {
                return Err(format!("p={p} not minimal for its T"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fgpm_size_tracks_closed_form() {
    check("fgpm_size", 200, |r: &mut Rng| r.range(1, 100_000), |&m| {
        let sz = fgpm::fgpm_space(m).len() as i64;
        let formula = 2 * (m as f64).sqrt().floor() as i64;
        if (sz - formula).abs() > 1 {
            return Err(format!("{sz} vs 2*floor(sqrt) {formula}"));
        }
        Ok(())
    });
}

#[test]
fn prop_factor_space_subset_of_fgpm_times() {
    check("factor_subset", 100, |r: &mut Rng| r.range(2, 2048), |&m| {
        let gt: Vec<usize> = fgpm::fgpm_space(m).iter().map(|&p| fgpm::rounds(m, p)).collect();
        for &p in &fgpm::factor_space(m) {
            if !gt.contains(&fgpm::rounds(m, p)) {
                return Err(format!("factor {p} time missing"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_padded_dim_bounds() {
    check(
        "padded_dim",
        200,
        |r: &mut Rng| (r.range(1, 4096), r.range(1, 4096)),
        |&(m, p)| {
            let pad = fgpm::padded_dim(m, p);
            if pad < m || pad >= m + p {
                return Err(format!("padded {pad} outside [{m}, {})", m + p));
            }
            if pad % p != 0 {
                return Err("padded dim not a multiple of p".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Algorithm 2 invariants
// ---------------------------------------------------------------------

#[test]
fn prop_tuner_respects_random_budgets_and_is_monotone() {
    let net = nets::shufflenet_v2();
    check(
        "tuner_budget",
        12,
        |r: &mut Rng| (r.range(30, 3000), r.range(0, net.layers.len())),
        |&(budget, boundary)| {
            let plan = CePlan { boundary };
            let p = alloc::dynamic_parallelism_tuning(&net, &plan, budget, Granularity::Fgpm);
            if p.dsps > budget {
                return Err(format!("used {} of {budget}", p.dsps));
            }
            let perf = throughput::evaluate(&net, &p.allocs);
            let p2 = alloc::dynamic_parallelism_tuning(&net, &plan, budget * 2, Granularity::Fgpm);
            let perf2 = throughput::evaluate(&net, &p2.allocs);
            if perf2.t_max > perf.t_max {
                return Err("more budget made it slower".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pe_budget_mode_counts_pes() {
    let net = nets::mobilenet_v1();
    check("pe_budget", 10, |r: &mut Rng| r.range(40, 4000), |&budget| {
        let plan = CePlan { boundary: net.layers.len() / 2 };
        let p = alloc::parallelism::dynamic_parallelism_tuning_with(
            &net,
            &plan,
            budget,
            Granularity::Fgpm,
            BudgetKind::Pes,
        );
        if p.pes > budget {
            return Err(format!("{} PEs > budget {budget}", p.pes));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Memory/DRAM model invariants
// ---------------------------------------------------------------------

#[test]
fn prop_dram_monotone_and_sram_bounded() {
    check(
        "mem_models",
        40,
        |r: &mut Rng| {
            let nets_all = nets::all_networks();
            let net = r.range(0, nets_all.len() - 1);
            let b = r.range(0, nets_all[net].layers.len());
            (net, b)
        },
        |&(ni, b)| {
            let net = &nets::all_networks()[ni];
            let cfg = MemoryModelCfg::default();
            let d0 = dram::proposed(net, &CePlan { boundary: b }).total();
            if b + 1 <= net.layers.len() {
                let d1 = dram::proposed(net, &CePlan { boundary: b + 1 }).total();
                if d1 > d0 {
                    return Err("DRAM not monotone in boundary".into());
                }
            }
            let s = memory::sram_report(net, &CePlan { boundary: b }, &cfg).total();
            // Never exceeds all-weights + all-double-buffered-FMs.
            let bound: u64 = net.total_weight_bytes()
                + 2 * net.layers.iter().map(|l| l.in_fm_bytes()).sum::<u64>()
                + (4 << 20);
            if s > bound {
                return Err(format!("SRAM {s} above bound {bound}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_line_buffer_scheme_ordering() {
    // For every windowed layer: fully-reused buffer <= line-based buffer.
    check(
        "line_buffer",
        40,
        |r: &mut Rng| (r.range(0, 3), r.f64()),
        |&(ni, frac)| {
            let net = &nets::all_networks()[ni];
            let idx = ((net.layers.len() - 1) as f64 * frac) as usize;
            let l = &net.layers[idx];
            if l.kind.needs_line_buffer() && l.k > 1 {
                let fr = memory::line_buffer_px(l, memory::FmScheme::FullyReusedFm, false);
                let lb = memory::line_buffer_px(l, memory::FmScheme::LineBased, false);
                if fr > lb {
                    return Err(format!("{}: {fr} > {lb}", l.name));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Simulator: deadlock freedom across random configurations — the paper's
// delayed-buffer sizing claim (§III-B).
// ---------------------------------------------------------------------

#[test]
fn prop_sim_deadlock_free_on_random_configs() {
    let nets_all = [nets::mobilenet_v2(), nets::shufflenet_v2()];
    check(
        "sim_deadlock_free",
        6,
        |r: &mut Rng| {
            (
                r.range(0, 1),
                r.range(0, 64),
                r.range(100, 1200),
                r.range(0, 1) == 1,
            )
        },
        |&(ni, bfrac, dsp, baseline)| {
            let net = &nets_all[ni];
            let boundary = bfrac.min(net.layers.len());
            let plan = CePlan { boundary };
            let p = alloc::dynamic_parallelism_tuning(net, &plan, dsp, Granularity::Fgpm);
            let opts = if baseline { SimOptions::baseline() } else { SimOptions::optimized() };
            match sim::simulate(net, &p.allocs, &plan, &opts, 3) {
                Ok(stats) => {
                    if stats.period_cycles <= 0.0 {
                        return Err("non-positive period".into());
                    }
                    Ok(())
                }
                Err(e) => Err(format!("deadlock: {e}")),
            }
        },
    );
}

/// ISSUE 9: the FIFO-depth model is sound against the simulator across
/// random boundaries, granularities, and DSP budgets over the full zoo —
/// the observed per-FIFO peak occupancy never exceeds the modeled depth
/// bound, the provisioned capacities are exactly the modeled depths (the
/// pairing the differential suite relies on), and a model-sized pipeline
/// never deadlocks.
#[test]
fn prop_fifo_model_bounds_sim_peaks_on_random_configs() {
    let nets_all = nets::all_networks();
    check(
        "fifo_model_bounds",
        6,
        |r: &mut Rng| {
            (
                r.range(0, nets_all.len() - 1),
                r.range(0, 64),
                r.range(100, 1200),
                *r.pick(&[Granularity::Fgpm, Granularity::Factorized]),
            )
        },
        |&(ni, bfrac, dsp, gran)| {
            let net = &nets_all[ni];
            let boundary = bfrac.min(net.layers.len());
            let plan = CePlan { boundary };
            let p = alloc::dynamic_parallelism_tuning(net, &plan, dsp, gran);
            let opts = SimOptions { track_fifo: true, ..SimOptions::optimized() };
            let modeled = fifo::fifo_depths(net, &plan, opts.scheme);
            let stats = sim::simulate(net, &p.allocs, &plan, &opts, 2)
                .map_err(|e| format!("model-sized pipeline deadlocked: {e}"))?;
            if stats.fifo_peak.len() != modeled.fifos.len() {
                return Err(format!(
                    "sim tracks {} FIFOs, model sizes {}",
                    stats.fifo_peak.len(),
                    modeled.fifos.len()
                ));
            }
            for (i, f) in modeled.fifos.iter().enumerate() {
                if stats.fifo_names[i] != f.name {
                    return Err(format!(
                        "FIFO #{i} pairing drifted: sim {:?} vs model {:?}",
                        stats.fifo_names[i], f.name
                    ));
                }
                if stats.fifo_capacity[i] != f.depth_px {
                    return Err(format!(
                        "{}: capacity {} != modeled depth {}",
                        f.name, stats.fifo_capacity[i], f.depth_px
                    ));
                }
                if stats.fifo_peak[i] > f.depth_px {
                    return Err(format!(
                        "{}: observed peak {} px exceeds modeled depth {} px",
                        f.name, stats.fifo_peak[i], f.depth_px
                    ));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE 10: the event-driven engine is bit-identical to the cycle-stepped
/// reference across random boundaries, granularities, DSP budgets, frame
/// counts, and both option presets over the full zoo — every `SimStats`
/// field including the stall taxonomy, `frame_done` schedules, and the
/// tracked FIFO peaks/high-water traces (`Debug` covers all of them), or
/// the identical typed deadlock error.
#[test]
fn prop_event_driven_engine_bit_identical_to_stepped() {
    let nets_all = nets::all_networks();
    check(
        "event_vs_stepped",
        6,
        |r: &mut Rng| {
            (
                r.range(0, nets_all.len() - 1),
                r.range(0, 64),
                r.range(100, 1200),
                *r.pick(&[Granularity::Fgpm, Granularity::Factorized]),
                r.range(2, 3) as u64,
                r.range(0, 1) == 1,
            )
        },
        |&(ni, bfrac, dsp, gran, frames, baseline)| {
            let net = &nets_all[ni];
            let boundary = bfrac.min(net.layers.len());
            let plan = CePlan { boundary };
            let p = alloc::dynamic_parallelism_tuning(net, &plan, dsp, gran);
            let base = if baseline { SimOptions::baseline() } else { SimOptions::optimized() };
            let opts = SimOptions { track_fifo: true, ..base };
            let event = sim::simulate(net, &p.allocs, &plan, &opts, frames);
            let stepped = sim::simulate(
                net,
                &p.allocs,
                &plan,
                &SimOptions { event_driven: false, ..opts },
                frames,
            );
            match (event, stepped) {
                (Ok(a), Ok(b)) => {
                    let (a, b) = (format!("{a:?}"), format!("{b:?}"));
                    if a != b {
                        return Err(format!("stats diverge:\nevent:   {a}\nstepped: {b}"));
                    }
                    Ok(())
                }
                (Err(a), Err(b)) => {
                    if a != b {
                        return Err(format!("errors diverge:\nevent:   {a}\nstepped: {b}"));
                    }
                    Ok(())
                }
                (a, b) => Err(format!("outcomes diverge:\nevent:   {a:?}\nstepped: {b:?}")),
            }
        },
    );
}

// ---------------------------------------------------------------------
// Platform catalog invariants (the design-space sweep's budget axes).
// ---------------------------------------------------------------------

#[test]
fn prop_more_sram_never_retreats_the_boundary() {
    // Algorithm 1's second iteration only ever advances the FRCE/WRCE
    // boundary with extra SRAM headroom, which in turn can only reduce
    // DRAM traffic (the boundary sweep is monotone in DRAM).
    check(
        "platform_sram_monotone",
        6,
        |r: &mut Rng| (r.range(0, 3), r.range(128, 3072)),
        |&(ni, kb)| {
            let net = &nets::all_networks()[ni];
            let small = Platform::custom("small", kb as u64 * 1024, 855);
            let large = small.clone().with_sram_bytes(kb as u64 * 2 * 1024);
            let ds = Design::builder(net).platform(small).build();
            let dl = Design::builder(net).platform(large).build();
            if dl.ce_plan().boundary < ds.ce_plan().boundary {
                return Err(format!(
                    "2x SRAM retreated the boundary: {} -> {}",
                    ds.ce_plan().boundary,
                    dl.ce_plan().boundary
                ));
            }
            if dl.dram_bytes() > ds.dram_bytes() {
                return Err("2x SRAM increased DRAM traffic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_more_dsps_never_lower_predicted_fps() {
    check(
        "platform_dsp_monotone",
        6,
        |r: &mut Rng| (r.range(0, 3), r.range(60, 1500)),
        |&(ni, dsp)| {
            let net = &nets::all_networks()[ni];
            let base = Platform::custom("base", repro::zc706::SRAM_BYTES, dsp);
            let doubled = base.clone().with_dsp_budget(dsp * 2);
            let db = Design::builder(net).platform(base).build();
            let dd = Design::builder(net).platform(doubled).build();
            if dd.predicted().t_max > db.predicted().t_max {
                return Err(format!(
                    "2x DSPs slowed t_max: {} -> {}",
                    db.predicted().t_max,
                    dd.predicted().t_max
                ));
            }
            if dd.predicted().fps < db.predicted().fps {
                return Err("2x DSPs lowered predicted FPS".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_catalog_platforms_fit_their_own_budgets() {
    // Algorithm 1's contract per catalog part: whenever the second
    // iteration advanced past the min-SRAM boundary, the Alg-1 footprint
    // fits the budget; big parts (>= the ZC706 budget) always fit, and
    // the DSP budget is never exceeded. The edge part may legitimately
    // not fit some networks' min-SRAM configurations — exactly what the
    // sweep's `fits_sram` column surfaces — but then the allocator must
    // have stopped at the min-SRAM boundary rather than overshooting.
    for platform in Platform::list() {
        for net in nets::all_networks() {
            let d = Design::builder(&net).platform(platform.clone()).build();
            assert!(
                d.parallelism().dsps <= platform.dsp_budget,
                "{} on {}: {} DSPs over budget {}",
                net.name,
                platform.name,
                d.parallelism().dsps,
                platform.dsp_budget
            );
            if d.memory().boundary > d.memory().boundary_min_sram {
                assert!(
                    d.memory().sram_bytes < platform.sram_bytes,
                    "{} on {}: advanced boundary but {} B over budget {} B",
                    net.name,
                    platform.name,
                    d.memory().sram_bytes,
                    platform.sram_bytes
                );
            }
            if platform.sram_bytes >= repro::zc706::SRAM_BYTES {
                assert!(
                    d.memory().sram_bytes < platform.sram_bytes,
                    "{} does not fit {} ({} B of {} B)",
                    net.name,
                    platform.name,
                    d.memory().sram_bytes,
                    platform.sram_bytes
                );
            }
            // sram_report at the chosen boundary is what Alg 1 budgeted.
            assert_eq!(
                d.sram_report().total(),
                d.memory().sram_bytes,
                "{} on {}: sram_report disagrees with Alg 1",
                net.name,
                platform.name
            );
        }
    }
}

// ---------------------------------------------------------------------
// JSON parser: print/parse round-trip on random documents.
// ---------------------------------------------------------------------

fn gen_json(r: &mut Rng, depth: usize) -> (String, Json) {
    use std::collections::BTreeMap;
    match if depth == 0 { r.range(0, 2) } else { r.range(0, 4) } {
        0 => {
            let n = (r.range(0, 2_000_000) as f64) / 16.0;
            (format!("{n}"), Json::Num(n))
        }
        1 => {
            let words = ["stem", "bneck", "a b", "x\\ny", "тест"];
            let w = *r.pick(&words);
            (format!("{:?}", w), Json::Str(w.to_string()))
        }
        2 => ("true".into(), Json::Bool(true)),
        3 => {
            let n = r.range(0, 3);
            let mut parts = Vec::new();
            let mut vals = Vec::new();
            for _ in 0..n {
                let (s, v) = gen_json(r, depth - 1);
                parts.push(s);
                vals.push(v);
            }
            (format!("[{}]", parts.join(",")), Json::Arr(vals))
        }
        _ => {
            let n = r.range(0, 3);
            let mut parts = Vec::new();
            let mut map = BTreeMap::new();
            for i in 0..n {
                let key = format!("k{i}");
                let (s, v) = gen_json(r, depth - 1);
                parts.push(format!("{key:?}:{s}"));
                map.insert(key, v);
            }
            (format!("{{{}}}", parts.join(",")), Json::Obj(map))
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    check("json_roundtrip", 300, |r: &mut Rng| gen_json(r, 3), |(text, expect)| {
        match Json::parse(text) {
            Ok(v) if v == *expect => Ok(()),
            Ok(v) => Err(format!("parsed {v:?}")),
            Err(e) => Err(format!("{e}")),
        }
    });
}

#[test]
fn prop_json_serializer_roundtrip() {
    // print -> parse recovers the value, and a second print is
    // byte-identical (the stability the Design JSON artifacts rely on).
    check("json_serializer", 300, |r: &mut Rng| gen_json(r, 3).1, |v| {
        let s = v.to_string();
        let back = Json::parse(&s).map_err(|e| format!("reparse of {s:?}: {e}"))?;
        if back != *v {
            return Err(format!("value changed: {v:?} -> {s} -> {back:?}"));
        }
        if back.to_string() != s {
            return Err(format!("print not a fixed point: {s}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Window geometry: the CE's closed-form required_arrival / oldest_needed
// vs a brute-force window enumeration.
// ---------------------------------------------------------------------

fn brute_force_window(
    f_in: usize,
    k: usize,
    s: usize,
    pad: usize,
    padded_stream: bool,
    opos: u64,
) -> (u64, u64) {
    // Enumerate the input coordinates (in arrival-grid terms) that the
    // output position's window touches; return (max raster index, min
    // window start raster index).
    let f_out = (f_in + 2 * pad - k) / s + 1;
    let (r, c) = ((opos as usize) / f_out, (opos as usize) % f_out);
    let fa = if padded_stream { f_in + 2 * pad } else { f_in };
    let mut max_idx = 0u64;
    let mut min_start = u64::MAX;
    for dy in 0..k {
        for dx in 0..k {
            let (ry, rx) = (r * s + dy, c * s + dx);
            let (gy, gx) = if padded_stream {
                (ry as i64, rx as i64)
            } else {
                (ry as i64 - pad as i64, rx as i64 - pad as i64)
            };
            if gy < 0 || gx < 0 || gy >= fa as i64 || gx >= fa as i64 {
                continue; // padding: not an arrival
            }
            let idx = gy as u64 * fa as u64 + gx as u64;
            max_idx = max_idx.max(idx);
            if dy == 0 && dx == 0 {
                min_start = idx;
            }
        }
    }
    if min_start == u64::MAX {
        // Window origin is padding: the live set starts at the clamped
        // origin row/col.
        let oy = (r * s).saturating_sub(if padded_stream { 0 } else { pad });
        let ox = (c * s).saturating_sub(if padded_stream { 0 } else { pad });
        min_start = (oy * fa + ox) as u64;
    }
    (max_idx, min_start)
}

#[test]
fn prop_window_geometry_matches_brute_force() {
    use repro::model::memory::FmScheme;
    use repro::sim::{CeClass, CeConfig, PaddingMode};
    check(
        "window_geometry",
        200,
        |r: &mut Rng| {
            let k = *r.pick(&[2usize, 3, 5]);
            let s = *r.pick(&[1usize, 2]);
            let pad = r.range(0, k / 2);
            let f_in = r.range(k + s, 24);
            let padded = r.range(0, 1) == 1 && pad > 0;
            (f_in, k, s, pad, padded)
        },
        |&(f_in, k, s, pad, padded)| {
            let f_out = (f_in + 2 * pad - k) / s + 1;
            let cfg = CeConfig {
                name: "t".into(),
                class: CeClass::Compute,
                f_in,
                f_out,
                k,
                stride: s,
                pad,
                padding: if padded { PaddingMode::DirectInsert } else { PaddingMode::AddressGenerated },
                scheme: FmScheme::FullyReusedFm,
                stride_extra_line: false,
                quantum_cycles: 1,
                pf: 1,
                pes: 1,
                macs_per_opos: 1,
                full_frame_buffer: false,
                extra_capacity_px: 0,
                in_interval: 1,
            };
            for opos in 0..(f_out * f_out) as u64 {
                let (bf_req, bf_old) = brute_force_window(f_in, k, s, pad, padded, opos);
                let req = cfg.required_arrival(opos);
                if req != bf_req {
                    return Err(format!("required({opos}) = {req}, brute force {bf_req} (cfg {f_in},{k},{s},{pad},{padded})"));
                }
                let old = cfg.oldest_needed(opos);
                if old > bf_old {
                    return Err(format!(
                        "oldest({opos}) = {old} releases live pixel {bf_old} (cfg {f_in},{k},{s},{pad},{padded})"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Sweep cell cache: save/load round-trip, no stale hits (ISSUE 5)
// ---------------------------------------------------------------------

/// One randomly drawn single-cell sweep over a custom platform budget.
#[derive(Debug, Clone)]
struct CacheCase {
    net: &'static str,
    sram_bytes: u64,
    dsp_budget: usize,
    clock_hz: f64,
    granularity: Granularity,
    clocks_hz: Vec<f64>,
}

fn cache_case(r: &mut Rng) -> CacheCase {
    CacheCase {
        net: *r.pick(&["mobilenet_v1", "mobilenet_v2", "shufflenet_v1", "shufflenet_v2"]),
        sram_bytes: r.range(256 * 1024, 4 * 1024 * 1024) as u64,
        dsp_budget: r.range(64, 2400),
        clock_hz: r.range(100, 350) as f64 * 1.0e6,
        granularity: *r.pick(&[Granularity::Fgpm, Granularity::Factorized]),
        clocks_hz: match r.range(0, 2) {
            0 => vec![],
            1 => vec![150.0e6],
            _ => vec![100.0e6, 250.0e6],
        },
    }
}

fn cache_case_spec(case: &CacheCase, cache_dir: Option<std::path::PathBuf>) -> repro::SweepSpec {
    repro::SweepSpec {
        nets: vec![nets::by_name(case.net).unwrap()],
        platforms: vec![Platform::custom("prop", case.sram_bytes, case.dsp_budget)
            .with_clock_hz(case.clock_hz)],
        granularities: vec![case.granularity],
        clocks_hz: case.clocks_hz.clone(),
        cache_dir,
        ..repro::SweepSpec::default()
    }
}

#[test]
fn prop_sweep_cache_round_trips_and_never_serves_stale_cells() {
    let root = std::env::temp_dir().join("repro_prop_sweep_cache");
    let _ = std::fs::remove_dir_all(&root);
    let mut case_no = 0u64;
    // 8 cases x (3 + 5x2) runs: each case costs ~13 single-cell builds.
    check("sweep_cache", 8, cache_case, |case| {
        case_no += 1;
        let dir = root.join(format!("case{case_no}"));
        let spec = cache_case_spec(case, Some(dir.clone()));
        let uncached = cache_case_spec(case, None).run();

        // Round-trip: cold fills, warm serves, bytes never move.
        let cold = spec.run();
        if cold.cache != Some(repro::CacheStats { hits: 0, misses: 1, store_errors: 0 }) {
            return Err(format!("cold stats {:?}", cold.cache));
        }
        let warm = spec.run();
        if warm.cache != Some(repro::CacheStats { hits: 1, misses: 0, store_errors: 0 }) {
            return Err(format!("warm stats {:?}", warm.cache));
        }
        for (label, report) in [("cold", &cold), ("warm", &warm)] {
            if report.to_json() != uncached.to_json() {
                return Err(format!("{label} cached bytes differ from uncached"));
            }
        }

        // No stale hits: perturbing any single key component must MISS
        // and reproduce the perturbed spec's uncached bytes exactly.
        let mut mutants: Vec<(&str, CacheCase)> = Vec::new();
        let mut m = case.clone();
        m.sram_bytes += 4096;
        mutants.push(("sram_budget", m));
        let mut m = case.clone();
        m.dsp_budget += 2;
        mutants.push(("dsp_budget", m));
        let mut m = case.clone();
        m.clock_hz += 1.0e6;
        mutants.push(("clock", m));
        let mut m = case.clone();
        m.granularity = match case.granularity {
            Granularity::Fgpm => Granularity::Factorized,
            Granularity::Factorized => Granularity::Fgpm,
        };
        mutants.push(("granularity", m));
        let mut m = case.clone();
        m.clocks_hz.push(317.0e6);
        mutants.push(("clocks_axis", m));
        for (which, mutant) in mutants {
            let report = cache_case_spec(&mutant, Some(dir.clone())).run();
            let stats = report.cache.unwrap();
            if stats.hits != 0 {
                return Err(format!("changing {which} still hit the cache: {stats:?}"));
            }
            let fresh = cache_case_spec(&mutant, None).run();
            if report.to_json() != fresh.to_json() {
                return Err(format!("{which}: mutated cached bytes differ from uncached"));
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&root);
}

/// Robustness PR: an arbitrarily corrupted on-disk cache entry —
/// truncated at a random offset or with a random bit flipped — must
/// never panic a sweep. The corrupted entry degrades to a miss (the
/// cell is recomputed, bytes identical to an uncached run), the miss
/// re-stores a good entry, and the next run is a clean hit again.
#[test]
fn prop_corrupted_cache_entries_degrade_to_misses() {
    let root = std::env::temp_dir().join("repro_prop_sweep_cache_corrupt");
    let _ = std::fs::remove_dir_all(&root);
    let mut case_no = 0u64;
    check(
        "sweep_cache_corrupt",
        8,
        |r: &mut Rng| (cache_case(r), r.range(0, 1), r.range(0, 1_000_000)),
        |(case, mode, seed)| {
            case_no += 1;
            let dir = root.join(format!("case{case_no}"));
            let spec = cache_case_spec(case, Some(dir.clone()));
            let uncached = cache_case_spec(case, None).run();
            spec.run(); // cold fill

            // Corrupt the (single) stored entry in place.
            let entry = std::fs::read_dir(&dir)
                .map_err(|e| format!("read_dir: {e}"))?
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .find(|p| p.to_string_lossy().ends_with(".cell.json"))
                .ok_or("no .cell.json entry after the cold run")?;
            let mut bytes = std::fs::read(&entry).map_err(|e| format!("read entry: {e}"))?;
            if bytes.is_empty() {
                return Err("stored entry is empty".into());
            }
            match mode {
                0 => bytes.truncate(seed % bytes.len()),
                _ => {
                    let at = seed % bytes.len();
                    bytes[at] ^= 1 << (seed % 8);
                }
            }
            std::fs::write(&entry, &bytes).map_err(|e| format!("corrupt entry: {e}"))?;

            // The corrupted entry is a miss — never a panic, and never a
            // hit serving flipped bytes: truncation breaks the JSON, and
            // any payload flip fails the entry's `check` checksum.
            let degraded = spec.run();
            if degraded.to_json() != uncached.to_json() {
                return Err("corrupted cache changed the served bytes".into());
            }
            if degraded.cache
                != Some(repro::CacheStats { hits: 0, misses: 1, store_errors: 0 })
            {
                return Err(format!("degraded stats {:?}", degraded.cache));
            }

            // A miss re-stores a pristine entry; either way the next run
            // round-trips warm.
            let recovered = spec.run();
            if recovered.cache != Some(repro::CacheStats { hits: 1, misses: 0, store_errors: 0 })
            {
                return Err(format!("post-recovery stats {:?}", recovered.cache));
            }
            if recovered.to_json() != uncached.to_json() {
                return Err("recovered cache changed the served bytes".into());
            }
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&root);
}
