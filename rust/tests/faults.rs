//! Fault-isolation acceptance tests, driven through the deterministic
//! injection harness (`repro::util::fault`).
//!
//! These are the robustness claims the harness exists to prove:
//!
//! * a panicking cell becomes exactly one [`CellFailure`] while every
//!   other cell's bytes are identical to a fault-free run, at any
//!   `--jobs N`;
//! * a torn (`cache.store`-faulted) cache write surfaces in
//!   [`CacheStats::store_errors`], degrades later loads to misses, and
//!   never changes the bytes any run serves;
//! * `cache.load` faults cost hit rate, never content;
//! * the partial-failure exit-code policy ([`sweep::exit_code`]).
//!
//! The in-process fault override is global, so every test here grabs one
//! lock and disarms via an RAII guard — a failing assertion must not
//! leak an armed plan into the next test.

use std::sync::{Mutex, MutexGuard};

use repro::sweep::{self, SweepSpec};
use repro::util::fault::{self, FaultPlan, Site, Trigger};
use repro::CacheStats;

static LOCK: Mutex<()> = Mutex::new(());

fn seq() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arms a plan for the guard's lifetime; disarms on drop (including the
/// unwind of a failed assertion).
struct Armed;

impl Armed {
    fn new(plan: FaultPlan) -> Armed {
        fault::arm(plan);
        Armed
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        fault::disarm();
    }
}

/// Run a sweep with the injected-panic spew silenced. The quiet hook is
/// scoped to the `run()` call only, so the test's own assertion panics
/// still report normally.
fn run_quiet(spec: &SweepSpec) -> repro::SweepReport {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let report = spec.run();
    std::panic::set_hook(prev);
    report
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("repro_faults_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The tentpole acceptance: one injected allocation panic fails exactly
/// its cell; the survivors are byte-identical to a fault-free run at
/// `--jobs 1` and `--jobs 4`; the report carries the failure under
/// `failures`; and the run maps to the partial-failure exit code.
#[test]
fn injected_alloc_panic_isolates_one_cell_at_any_job_count() {
    let _guard = seq();
    let spec =
        SweepSpec::from_csv(Some("mobilenet_v1,shufflenet_v2"), Some("zc706"), None).unwrap();
    let clean = spec.run();
    assert_eq!(clean.cells.len(), 2);
    assert!(clean.failures.is_empty());
    assert_eq!(sweep::exit_code(&clean), 0, "clean runs exit 0");

    // The content key embeds the network name, so this substring selects
    // exactly the mobilenet_v1 cell — worker identity never enters.
    let _armed = Armed::new(FaultPlan::rule(
        Site::EvalAlloc,
        Trigger::KeySubstring("\"network\":\"mobilenet_v1\"".to_string()),
    ));
    let mut documents = Vec::new();
    for jobs in [1usize, 4] {
        let mut par = spec.clone();
        par.jobs = jobs;
        let report = run_quiet(&par);

        assert_eq!(report.failures.len(), 1, "jobs={jobs}: exactly one failed cell");
        let f = &report.failures[0];
        assert_eq!(f.index, 0, "mobilenet_v1 is the first matrix combination");
        assert_eq!(f.label(), "mobilenet_v1/zc706/fgpm");
        assert_eq!(f.error.kind(), "internal", "a caught panic is an Internal error");
        assert!(
            f.error.contains("panic: injected fault: eval.alloc"),
            "jobs={jobs}: {}",
            f.error
        );

        // The survivor is bit-for-bit the cell the fault-free run built.
        assert_eq!(report.cells.len(), 1);
        assert_eq!(
            report.cells[0].to_json_value().to_string(),
            clean.cells[1].to_json_value().to_string(),
            "jobs={jobs}: surviving cell drifted from the fault-free run"
        );

        let json = report.to_json();
        assert!(json.contains("\"failures\""), "{json}");
        assert!(json.contains("\"kind\":\"internal\""), "{json}");
        assert_eq!(sweep::exit_code(&report), sweep::EXIT_PARTIAL_FAILURE);
        documents.push(json);
    }
    assert_eq!(documents[0], documents[1], "degraded documents must not depend on --jobs");

    // Clean-run documents never carry the key at all.
    assert!(!clean.to_json().contains("failures"));
}

/// An injected `eval.sim` fault is a *typed* Simulation failure — and
/// stays distinguishable from an organic simulator deadlock, which is a
/// per-cell measurement (`SweepCell::sim_error`), not a `CellFailure`.
#[test]
fn injected_sim_fault_is_a_typed_simulation_failure() {
    let _guard = seq();
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
    spec.frames = Some(2);
    let _armed = Armed::new(FaultPlan::rule(Site::EvalSim, Trigger::Nth(1)));
    let report = spec.run();
    assert!(report.cells.is_empty());
    assert_eq!(report.failures.len(), 1);
    let f = &report.failures[0];
    assert_eq!(f.error.kind(), "simulation");
    assert!(f.error.contains("injected fault: eval.sim for cell shufflenet_v2/zc706/fgpm"), "{}", f.error);
    assert_eq!(sweep::exit_code(&report), sweep::EXIT_PARTIAL_FAILURE);
}

/// `cache.store` faults write torn entries and error the store: the run
/// still succeeds (store failures never fail a cell), the stats count
/// them, torn entries degrade later loads to misses, and after disarming
/// the cache heals back to a 100% warm hit rate — with every document
/// byte-identical throughout.
#[test]
fn torn_cache_stores_surface_in_stats_and_degrade_to_misses() {
    let _guard = seq();
    let dir = tmp_dir("torn_store");
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
    spec.cache_dir = Some(dir.clone());
    let mut uncached = spec.clone();
    uncached.cache_dir = None;
    let reference = uncached.run().to_json();

    {
        let _armed = Armed::new(FaultPlan::rule(Site::CacheStore, Trigger::Nth(1)));
        let cold = spec.run();
        assert_eq!(
            cold.cache,
            Some(CacheStats { hits: 0, misses: 2, store_errors: 2 }),
            "every store fails torn"
        );
        assert!(cold.failures.is_empty(), "store failures never fail cells");
        assert_eq!(sweep::exit_code(&cold), 0, "store errors alone do not fail the run");
        assert_eq!(cold.to_json(), reference);
        // The stderr summary line appends the count only when nonzero.
        let line = cold.cache.unwrap().summary(&dir);
        assert!(line.contains("2 store errors"), "{line}");

        // The torn entries on disk are strictly shorter than a valid
        // entry and must degrade the next run to misses, not panics.
        let rerun = spec.run();
        assert_eq!(rerun.cache, Some(CacheStats { hits: 0, misses: 2, store_errors: 2 }));
        assert_eq!(rerun.to_json(), reference);
    }

    // Disarmed: the misses re-store pristine entries and the cache heals.
    let recovered = spec.run();
    assert_eq!(recovered.cache, Some(CacheStats { hits: 0, misses: 2, store_errors: 0 }));
    assert_eq!(recovered.to_json(), reference);
    let warm = spec.run();
    assert_eq!(warm.cache, Some(CacheStats { hits: 2, misses: 0, store_errors: 0 }));
    assert_eq!(warm.cache.unwrap().hit_rate(), 1.0);
    assert_eq!(warm.to_json(), reference);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `cache.load` faults force misses on a warm cache: the hit rate drops,
/// the served bytes never move.
#[test]
fn injected_load_faults_cost_hits_but_never_change_served_bytes() {
    let _guard = seq();
    let dir = tmp_dir("load_miss");
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
    spec.cache_dir = Some(dir.clone());
    let cold = spec.run();
    assert_eq!(spec.run().cache, Some(CacheStats { hits: 2, misses: 0, store_errors: 0 }));

    {
        let _armed = Armed::new(FaultPlan::rule(Site::CacheLoad, Trigger::Nth(1)));
        let degraded = spec.run();
        assert_eq!(
            degraded.cache,
            Some(CacheStats { hits: 0, misses: 2, store_errors: 0 }),
            "every load trips to a miss"
        );
        assert_eq!(degraded.to_json(), cold.to_json());
    }

    // Disarmed again: the re-stored entries serve warm as before.
    let warm = spec.run();
    assert_eq!(warm.cache, Some(CacheStats { hits: 2, misses: 0, store_errors: 0 }));
    assert_eq!(warm.to_json(), cold.to_json());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The test override replaces the environment plan entirely while armed,
/// and `armed()` reflects the lifecycle — the hermeticity the RAII guard
/// in every test above relies on.
#[test]
fn arm_disarm_lifecycle_is_hermetic() {
    let _guard = seq();
    assert!(!fault::armed(), "tests must start disarmed");
    {
        let _armed = Armed::new(FaultPlan::rule(Site::CacheLoad, Trigger::Nth(1)));
        assert!(fault::armed());
        assert!(fault::trip(Site::CacheLoad, "any key"));
        assert!(!fault::trip(Site::CacheStore, "any key"), "other sites stay quiet");
    }
    assert!(!fault::armed(), "the guard disarms on drop");
    assert!(!fault::trip(Site::CacheLoad, "any key"));
}
