//! Differential test layer over the sweep cache and the analytic model
//! (ISSUE 5):
//!
//! * **Sim vs model** — for every committed golden-baseline cell
//!   (`rust/tests/baselines/*.design.json`, the full 12-cell
//!   zoo x catalog matrix), the cycle simulator's measured FPS must agree
//!   with the analytic Eq-14 prediction within a stated tolerance. The
//!   simulator can never meaningfully beat the bound; the balanced
//!   dataflow keeps it close below.
//! * **Warm vs cold** — a cached re-run of the full baseline matrix must
//!   be byte-identical to the cold run (JSON document and per-cell design
//!   artifacts), report a 100% hit rate, and perform **zero** Algorithm 1
//!   / Algorithm 2 re-derivations, measured via the
//!   [`repro::alloc::derivations`] counters.
//! * **FIFO soundness and tightness** (ISSUE 9) — on the same 12 baseline
//!   cells, every [`repro::model::fifo`] depth bound must contain the
//!   simulator's observed peak occupancy of the same FIFO (soundness),
//!   and every on-chip bound must sit within a pinned slack factor of the
//!   observed peak (tightness: the model is not vacuously over-sizing).
//!
//! The counter-delta assertions require that no other Alg 1/Alg 2 runs
//! happen concurrently in this process, so every test in this binary
//! serializes on one mutex (different test binaries are separate
//! processes and cannot interfere).

use std::path::PathBuf;
use std::sync::Mutex;

use repro::alloc::derivations;
use repro::sim::SimOptions;
use repro::sweep::{CacheStats, SweepSpec};
use repro::{nets, Design, Platform};

/// Serializes the tests in this binary; `lock()` falls back to the
/// poisoned guard so one failing test doesn't cascade into the rest.
static SEQ: Mutex<()> = Mutex::new(());

fn seq() -> std::sync::MutexGuard<'static, ()> {
    SEQ.lock().unwrap_or_else(|p| p.into_inner())
}

fn baseline_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("baselines")
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("repro_differential_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Stated tolerance of the sim-vs-model differential: measured FPS in
/// `[SIM_FPS_LOWER, SIM_FPS_UPPER] x predicted`. The upper bound is the
/// simulator's known <=0.1% quantization wobble over Eq 14 (see
/// `rust/tests/integration.rs`, which pins the zc706 min-SRAM configs to
/// a period ratio in [0.999, 1.10)); the lower bound allows the residual
/// dataflow overheads the paper's Fig 17 ablation closes, with headroom
/// for the off-paper zcu102/edge budgets.
const SIM_FPS_LOWER: f64 = 0.75;
const SIM_FPS_UPPER: f64 = 1.002;

#[test]
fn every_committed_baseline_cell_simulates_within_model_tolerance() {
    let _guard = seq();
    for net in nets::all_networks() {
        let short = nets::short_name(&net.name).expect("zoo net has a short name");
        for platform in Platform::list() {
            let file = format!("{short}_{}_fgpm.design.json", platform.name);
            let path = baseline_dir().join(&file);
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let design = Design::from_json(&text).unwrap_or_else(|e| panic!("{file}: {e}"));
            let predicted = design.predicted().fps;
            let stats = design
                .simulate(2)
                .unwrap_or_else(|e| panic!("{file}: optimized sim deadlocked: {e}"));
            let measured = stats.fps(design.platform().clock_hz);
            let ratio = measured / predicted;
            assert!(
                ratio >= SIM_FPS_LOWER,
                "{file}: simulated {measured:.1} FPS is below {SIM_FPS_LOWER} x \
                 predicted {predicted:.1} (ratio {ratio:.4})"
            );
            assert!(
                ratio <= SIM_FPS_UPPER,
                "{file}: simulated {measured:.1} FPS beats the Eq-14 bound \
                 {predicted:.1} beyond quantization wobble (ratio {ratio:.4})"
            );
        }
    }
}

/// The ISSUE 5 acceptance criterion: a warm-cache `repro sweep` over the
/// full 12-cell baseline matrix performs zero Alg 1/Alg 2 re-derivations
/// and reports a 100% hit rate — and its bytes are identical to cold.
#[test]
fn warm_cache_full_matrix_rederives_nothing_and_is_byte_identical() {
    let _guard = seq();
    let dir = tmp_dir("warm_full_matrix");
    // The 12-cell zoo x catalog matrix, memoized.
    let spec = SweepSpec { cache_dir: Some(dir.clone()), ..SweepSpec::default() };

    let cold = spec.run();
    assert_eq!(cold.cache, Some(CacheStats { hits: 0, misses: 12, store_errors: 0 }));

    let save_cold = tmp_dir("warm_full_matrix_artifacts_cold");
    let cold_paths = cold.save_designs(&save_cold).expect("save cold artifacts");

    let (alg1_before, alg2_before) = (derivations::alg1_runs(), derivations::alg2_runs());
    let warm = spec.run();
    let (alg1_after, alg2_after) = (derivations::alg1_runs(), derivations::alg2_runs());
    assert_eq!(alg1_after - alg1_before, 0, "warm sweep re-ran Algorithm 1");
    assert_eq!(alg2_after - alg2_before, 0, "warm sweep re-ran Algorithm 2");

    let stats = warm.cache.expect("cached run reports stats");
    assert_eq!(stats, CacheStats { hits: 12, misses: 0, store_errors: 0 });
    assert_eq!(stats.hit_rate(), 1.0, "hit-rate 100% reported in stats");

    assert_eq!(cold.to_json(), warm.to_json(), "warm JSON document drifted from cold");
    let save_warm = tmp_dir("warm_full_matrix_artifacts_warm");
    let warm_paths = warm.save_designs(&save_warm).expect("save warm artifacts");
    assert_eq!(cold_paths.len(), warm_paths.len());
    for (c, w) in cold_paths.iter().zip(&warm_paths) {
        assert_eq!(c.file_name(), w.file_name());
        assert_eq!(
            std::fs::read_to_string(c).unwrap(),
            std::fs::read_to_string(w).unwrap(),
            "cached vs cold artifact bytes differ for {}",
            c.display()
        );
    }

    // A warm run through the parallel pool is the same bytes again, and
    // still zero re-derivations.
    let mut par = spec.clone();
    par.jobs = 4;
    let before = derivations::alg1_runs();
    let warm_par = par.run();
    assert_eq!(derivations::alg1_runs(), before, "parallel warm sweep re-ran Algorithm 1");
    assert_eq!(warm_par.cache, Some(CacheStats { hits: 12, misses: 0, store_errors: 0 }));
    assert_eq!(cold.to_json(), warm_par.to_json());

    for d in [dir, save_cold, save_warm] {
        let _ = std::fs::remove_dir_all(&d);
    }
}

/// Simulated (frames-bearing) cells memoize too: the warm path restores
/// the stored sim figures instead of re-simulating, byte-identically.
#[test]
fn warm_cache_restores_simulated_figures_byte_identically() {
    let _guard = seq();
    let dir = tmp_dir("warm_sim");
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
    spec.frames = Some(2);
    spec.clocks_hz = SweepSpec::parse_clocks_csv("150,200").unwrap();
    spec.cache_dir = Some(dir.clone());
    let cold = spec.run();
    assert!(cold.cells[0].sim().is_some(), "premise: the cold run simulated");
    let warm = spec.run();
    assert_eq!(warm.cache, Some(CacheStats { hits: 1, misses: 0, store_errors: 0 }));
    assert_eq!(cold.to_json(), warm.to_json());
    let (c, w) = (cold.cells[0].sim().unwrap(), warm.cells[0].sim().unwrap());
    assert_eq!(c.frames, w.frames);
    assert_eq!(c.fps, w.fps);
    assert_eq!(c.mac_efficiency, w.mac_efficiency);
    // A model-only probe of the same cell is a *different* key: no stale
    // sim figures leak into it, and nothing is served across the gap.
    let mut model_only = spec.clone();
    model_only.frames = None;
    let probe = model_only.run();
    assert_eq!(probe.cache, Some(CacheStats { hits: 0, misses: 1, store_errors: 0 }));
    assert!(probe.cells[0].sim().is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE 10 acceptance criterion: on all 12 committed baseline cells,
/// the event-driven engine produces **bit-identical** `SimStats` to the
/// cycle-stepped reference — every field, including the stall taxonomy,
/// `frame_done` schedules, and the `--fifo` peaks/high-water traces
/// (`Debug` formatting covers all of them, bit-for-bit for the integer
/// fields and digit-for-digit for the derived period).
#[test]
fn every_baseline_cell_event_engine_matches_stepped_bit_for_bit() {
    let _guard = seq();
    for net in nets::all_networks() {
        let short = nets::short_name(&net.name).expect("zoo net has a short name");
        for platform in Platform::list() {
            let file = format!("{short}_{}_fgpm.design.json", platform.name);
            let path = baseline_dir().join(&file);
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let design = Design::from_json(&text).unwrap_or_else(|e| panic!("{file}: {e}"));
            let opts = SimOptions { track_fifo: true, ..*design.sim_options() };
            let event = design
                .simulate_with(&opts, 2)
                .unwrap_or_else(|e| panic!("{file}: event-driven sim failed: {e}"));
            let stepped = design
                .simulate_with(&SimOptions { event_driven: false, ..opts }, 2)
                .unwrap_or_else(|e| panic!("{file}: stepped sim failed: {e}"));
            assert_eq!(
                format!("{event:?}"),
                format!("{stepped:?}"),
                "{file}: event-driven stats diverge from the stepped reference"
            );
        }
    }
}

/// Pinned slack of the FIFO tightness check: an on-chip modeled depth may
/// exceed the simulator's observed peak occupancy by at most this factor
/// once the quantum-skew margin is set aside. The margin is excluded
/// because it provisions for worst-case transfer-quantum interleavings a
/// 2-frame run need not exercise; the factor itself absorbs the model's
/// conservative per-layer startup-latency sum against the sim's actual
/// drain schedule. Off-chip WRCE holds are deliberate 2-frame ping-pong
/// provisions and are exempt from tightness (soundness still applies).
const FIFO_SLACK_FACTOR: u64 = 4;

/// The ISSUE 9 acceptance criterion: on all 12 committed baseline cells,
/// every modeled FIFO depth bounds the sim's observed peak occupancy from
/// above (soundness), and on-chip bounds sit within
/// [`FIFO_SLACK_FACTOR`] of the peak (no vacuous over-sizing). The
/// modeled report and the tracked stats pair index-by-index because
/// `model::fifo::fifo_depths` mirrors `build_pipeline`'s FIFO
/// construction order; the name assertions pin that pairing.
#[test]
fn every_baseline_cell_fifo_model_bounds_observed_peaks() {
    let _guard = seq();
    for net in nets::all_networks() {
        let short = nets::short_name(&net.name).expect("zoo net has a short name");
        for platform in Platform::list() {
            let file = format!("{short}_{}_fgpm.design.json", platform.name);
            let path = baseline_dir().join(&file);
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let design = Design::from_json(&text).unwrap_or_else(|e| panic!("{file}: {e}"));
            let modeled = design.fifo_report();
            let opts = SimOptions { track_fifo: true, ..*design.sim_options() };
            let stats = design
                .simulate_with(&opts, 2)
                .unwrap_or_else(|e| panic!("{file}: tracked sim deadlocked: {e}"));
            assert_eq!(
                stats.fifo_names.len(),
                modeled.fifos.len(),
                "{file}: sim tracks a different FIFO count than the model sizes"
            );
            for (i, f) in modeled.fifos.iter().enumerate() {
                assert_eq!(
                    stats.fifo_names[i], f.name,
                    "{file}: FIFO #{i} pairing drifted between sim and model"
                );
                assert_eq!(
                    stats.fifo_capacity[i], f.depth_px,
                    "{file}: {}: provisioned capacity diverged from the modeled depth",
                    f.name
                );
                let peak = stats.fifo_peak[i];
                assert!(
                    peak <= f.depth_px,
                    "{file}: {}: observed peak {peak} px exceeds the modeled \
                     depth bound {} px (model is unsound)",
                    f.name,
                    f.depth_px
                );
                if f.on_chip {
                    assert!(
                        f.depth_px <= peak * FIFO_SLACK_FACTOR + f.margin_px,
                        "{file}: {}: modeled depth {} px is more than {FIFO_SLACK_FACTOR}x \
                         the observed peak {peak} px plus the {} px margin \
                         (vacuous over-sizing)",
                        f.name,
                        f.depth_px,
                        f.margin_px
                    );
                }
            }
        }
    }
}
