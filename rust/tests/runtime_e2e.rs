//! End-to-end runtime tests: AOT artifacts -> PJRT -> golden check.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! note) when the artifacts are absent so `cargo test` stays usable on a
//! fresh checkout.

use repro::coordinator;
use repro::runtime::{artifacts_dir, Engine, StageKind};

fn have(short: &str) -> bool {
    let ok = artifacts_dir().join(format!("{short}_manifest.json")).exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first ({short})");
    }
    ok
}

#[test]
fn mbv2_sequential_inference_matches_golden() {
    if !have("mbv2") {
        return;
    }
    let engine = Engine::load(&artifacts_dir(), "mbv2").unwrap();
    let input = engine.manifest.read_f32(&engine.manifest.golden_input).unwrap();
    let golden = engine.manifest.read_f32(&engine.manifest.golden_logits).unwrap();
    let logits = engine.infer(&input).unwrap();
    assert_eq!(logits.len(), golden.len());
    let max_err = logits.iter().zip(&golden).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "max |err| = {max_err}");
}

#[test]
fn mbv2_stagewise_shapes_and_checksums() {
    if !have("mbv2") {
        return;
    }
    let engine = Engine::load(&artifacts_dir(), "mbv2").unwrap();
    let mut x = engine.manifest.read_f32(&engine.manifest.golden_input).unwrap();
    for stage in &engine.stages {
        x = stage.run(&x).unwrap();
        let expect: usize = stage.spec.out_shape.iter().product();
        assert_eq!(x.len(), expect, "stage {}", stage.spec.name);
        let mean = x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64;
        assert!(
            (mean - stage.spec.mean).abs() < 1e-3 + stage.spec.mean.abs() * 1e-3,
            "stage {}: mean {mean} vs manifest {}",
            stage.spec.name,
            stage.spec.mean
        );
    }
}

#[test]
fn snv2_sequential_inference_matches_golden() {
    if !have("snv2") {
        return;
    }
    let engine = Engine::load(&artifacts_dir(), "snv2").unwrap();
    let input = engine.manifest.read_f32(&engine.manifest.golden_input).unwrap();
    let golden = engine.manifest.read_f32(&engine.manifest.golden_logits).unwrap();
    let logits = engine.infer(&input).unwrap();
    let max_err = logits.iter().zip(&golden).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "max |err| = {max_err}");
}

#[test]
fn frce_wrce_split_matches_manifest_boundary() {
    for short in ["mbv2", "snv2"] {
        if !have(short) {
            continue;
        }
        let engine = Engine::load(&artifacts_dir(), short).unwrap();
        let b = engine.manifest.boundary;
        for (i, s) in engine.stages.iter().enumerate() {
            let expect = if i < b { StageKind::Frce } else { StageKind::Wrce };
            assert_eq!(s.spec.kind, expect, "{short} stage {i}");
            // FRCE stages stream no weights; WRCE stages stream all theirs.
            if s.spec.kind == StageKind::Frce {
                assert!(s.spec.params.is_empty());
                assert_eq!(s.streamed_bytes_per_frame(), 0);
            } else {
                assert!(!s.spec.params.is_empty());
            }
        }
        // Eq-13 weight term == sum over WRCE stages.
        let dram = engine.dram_weight_bytes_8bit();
        assert!(dram > 0);
    }
}

#[test]
fn streaming_coordinator_pipelines_and_verifies() {
    if !have("mbv2") {
        return;
    }
    let report = coordinator::run_streaming(artifacts_dir(), "mbv2", 6, 3).unwrap();
    assert_eq!(report.frames, 6);
    assert!(report.max_abs_err < 1e-3, "err {}", report.max_abs_err);
    assert!(report.fps > 0.0);
    assert_eq!(report.groups.len(), 3);
    // The partition covers all stages contiguously.
    assert_eq!(report.groups[0].stages.0, 0);
    for w in report.groups.windows(2) {
        assert_eq!(w[0].stages.1, w[1].stages.0);
    }
}

// ---------------------------------------------------------------------
// Failure injection: the runtime must reject corrupted artifacts with
// errors, never silently compute garbage.
// ---------------------------------------------------------------------

#[test]
fn rejects_corrupt_manifest_json() {
    let dir = std::env::temp_dir().join("repro_fail_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad_manifest.json"), "{ not json").unwrap();
    let err = repro::runtime::Manifest::load(&dir, "bad").unwrap_err();
    assert!(format!("{err}").contains("parse error"), "{err}");
}

#[test]
fn rejects_missing_manifest() {
    let dir = std::env::temp_dir().join("repro_fail_missing");
    std::fs::create_dir_all(&dir).unwrap();
    assert!(repro::runtime::Manifest::load(&dir, "nope").is_err());
}

#[test]
fn rejects_truncated_weight_blob() {
    if !have("mbv2") {
        return;
    }
    // Copy the manifest + HLO files but truncate the weights blob: stage
    // compilation must fail on the out-of-range slice, not fabricate data.
    let src = artifacts_dir();
    let dir = std::env::temp_dir().join("repro_fail_weights");
    std::fs::create_dir_all(&dir).unwrap();
    for entry in std::fs::read_dir(&src).unwrap() {
        let p = entry.unwrap().path();
        let name = p.file_name().unwrap().to_str().unwrap().to_string();
        if name.starts_with("mbv2") {
            std::fs::copy(&p, dir.join(&name)).unwrap();
        }
    }
    std::fs::write(dir.join("mbv2_weights.bin"), [0u8; 64]).unwrap();
    let result = std::panic::catch_unwind(|| Engine::load(&dir, "mbv2"));
    assert!(result.is_err() || result.unwrap().is_err(), "truncated weights accepted");
}

#[test]
fn rejects_wrong_input_length() {
    if !have("mbv2") {
        return;
    }
    let engine = Engine::load(&artifacts_dir(), "mbv2").unwrap();
    let err = engine.stages[0].run(&[0.0f32; 7]).unwrap_err();
    assert!(format!("{err}").contains("input len"), "{err}");
}

#[test]
fn odd_byte_f32_file_is_rejected() {
    let dir = std::env::temp_dir().join("repro_fail_f32");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("odd.bin");
    std::fs::write(&p, [1u8, 2, 3]).unwrap();
    assert!(repro::runtime::read_f32_file(&p).is_err());
}
