//! Coverage of the `repro sweep` subcommand's parsing and output
//! surface, exercised through the same library entry points `main.rs`
//! delegates to (`SweepSpec::from_csv`, `SweepSpec::resolve_cache_flags`,
//! `sweep::validate_pareto_clocks`, `SweepReport::to_json`,
//! `SweepReport::save_designs`) — unknown axis names, empty matrices,
//! conflicting flag combinations with helpful messages, JSON that parses
//! back through `util::json`, and `--save-dir` / `--cache-dir`
//! round-trips.

use std::collections::BTreeSet;
use std::path::PathBuf;

use repro::alloc::Granularity;
use repro::sim::SimOptions;
use repro::sweep::{self, CacheStats, SweepSpec};
use repro::util::json::Json;
use repro::{Design, Platform};

#[test]
fn unknown_platform_error_lists_the_catalog() {
    let err = SweepSpec::from_csv(None, Some("zc999"), None).unwrap_err();
    assert!(err.contains("unknown platform \"zc999\""), "{err}");
    assert!(err.contains("known platforms: zc706, zcu102, edge"), "{err}");
    // Same catalog listing as Platform::resolve (the allocate/simulate
    // `--platform` path fixed in this PR).
    assert!(Platform::resolve("zc999").unwrap_err().contains("known platforms"), "{err}");
}

#[test]
fn unknown_network_and_granularity_fail_loudly() {
    let err = SweepSpec::from_csv(Some("resnet50"), None, None).unwrap_err();
    assert!(err.contains("unknown network \"resnet50\""), "{err}");
    assert!(err.contains("mobilenet_v1") && err.contains("shufflenet_v2"), "{err}");
    let err = SweepSpec::from_csv(None, None, Some("coarse")).unwrap_err();
    assert!(err.contains("unknown granularity"), "{err}");
}

#[test]
fn empty_matrix_axes_are_rejected() {
    for (n, p, g) in [
        (Some(""), None, None),
        (Some(" , ,"), None, None),
        (None, Some(""), None),
        (None, None, Some(",")),
    ] {
        let err = SweepSpec::from_csv(n, p, g).unwrap_err();
        assert!(err.contains("empty"), "{err}");
    }
}

#[test]
fn aliased_axis_entries_are_rejected_as_duplicates() {
    let err = SweepSpec::from_csv(Some("mbv2,mobilenet_v2"), None, None).unwrap_err();
    assert!(err.contains("duplicate entry \"mobilenet_v2\""), "{err}");
    let err = SweepSpec::from_csv(None, Some("zc706,ZC706"), None).unwrap_err();
    assert!(err.contains("duplicate entry \"zc706\""), "{err}");
    let err = SweepSpec::from_csv(None, None, Some("fgpm,fgpm")).unwrap_err();
    assert!(err.contains("duplicate entry \"fgpm\""), "{err}");
}

#[test]
fn default_axes_cover_zoo_and_catalog() {
    let spec = SweepSpec::from_csv(None, None, None).unwrap();
    assert_eq!(spec.nets.len(), 4);
    assert_eq!(spec.platforms.len(), 3);
    assert_eq!(spec.granularities, vec![Granularity::Fgpm]);
    assert_eq!(spec.cell_count(), 12);
}

#[test]
fn json_output_has_one_cell_per_combination_and_reparses() {
    let spec = SweepSpec::from_csv(
        Some("mobilenet_v2,shufflenet_v2"),
        Some("zc706,edge"),
        Some("fgpm,factorized"),
    )
    .unwrap();
    let report = spec.run();
    let text = report.to_json();
    assert!(!text.contains('\n'), "not one line");
    let j = Json::parse(&text).expect("sweep JSON reparses through util::json");
    let cells = j.arr_field("cells");
    assert_eq!(cells.len(), 8, "2 nets x 2 platforms x 2 granularities");
    assert_eq!(j.usize_field("version"), 1);
    let mut seen = BTreeSet::new();
    for c in cells {
        // Acceptance keys: FPS, MAC efficiency, SRAM bytes, DSP
        // utilization, FRCE/WRCE boundary — present and sane per cell.
        assert!(c.get("fps").unwrap().as_f64().unwrap() > 0.0);
        let eff = c.get("mac_efficiency").unwrap().as_f64().unwrap();
        assert!(eff > 0.0 && eff <= 1.0);
        assert!(c.get("sram_bytes").unwrap().as_f64().unwrap() > 0.0);
        let util = c.get("dsp_utilization").unwrap().as_f64().unwrap();
        assert!(util > 0.0 && util <= 1.0);
        assert!(c.get("boundary").unwrap().as_usize().unwrap() <= c.usize_field("layers"));
        assert!(
            seen.insert((
                c.str_field("network").to_string(),
                c.str_field("platform").to_string(),
                c.str_field("granularity").to_string(),
            )),
            "duplicate cell"
        );
    }
    // Stable output: a second run serializes byte-identically, and the
    // unrequested platform never appears.
    assert_eq!(text, spec.run().to_json());
    assert!(!text.contains("zcu102"));
}

#[test]
fn clock_aware_cells_report_platform_clocks() {
    let spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,zcu102"), None).unwrap();
    let report = spec.run();
    let zc = report.cell("shufflenet_v2", "zc706", Granularity::Fgpm).unwrap();
    let zu = report.cell("shufflenet_v2", "zcu102", Granularity::Fgpm).unwrap();
    assert_eq!(zc.platform().clock_hz, 200.0e6);
    assert_eq!(zu.platform().clock_hz, 300.0e6);
    // ZCU102 has both a bigger DSP budget and a faster clock: never
    // slower than the ZC706 cell, and the 300 MHz flows through Eq 14.
    assert!(zu.design().predicted().fps >= zc.design().predicted().fps);
}

#[test]
fn save_dir_round_trips_every_design() {
    let spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
    let report = spec.run();
    let dir = std::env::temp_dir().join("repro_sweep_save_dir_test");
    let _ = std::fs::remove_dir_all(&dir);
    let paths = report.save_designs(&dir).expect("save designs");
    assert_eq!(paths.len(), report.cells.len());
    for (path, cell) in paths.iter().zip(&report.cells) {
        assert_eq!(
            path.file_name().unwrap().to_str().unwrap(),
            cell.artifact_file_name(),
            "path order matches cell order"
        );
        let text = std::fs::read_to_string(path).unwrap();
        let reloaded = Design::from_json(&text).expect("saved artifact reloads");
        assert_eq!(reloaded.to_json(), cell.design().to_json(), "{}", path.display());
    }
    let names: BTreeSet<String> = paths
        .iter()
        .map(|p| p.file_name().unwrap().to_str().unwrap().to_string())
        .collect();
    let expect: BTreeSet<String> =
        ["snv2_zc706_fgpm.design.json", "snv2_edge_fgpm.design.json"]
            .into_iter()
            .map(str::to_string)
            .collect();
    assert_eq!(names, expect);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn clocks_axis_parses_like_the_cli_and_flows_into_cells() {
    // The `--clocks` surface: MHz CSV -> Hz points, fail-loudly on junk.
    assert_eq!(SweepSpec::parse_clocks_csv("150, 300").unwrap(), vec![150.0e6, 300.0e6]);
    for bad in ["", " , ", "abc", "-100", "0", "inf", "200,200"] {
        assert!(SweepSpec::parse_clocks_csv(bad).is_err(), "{bad:?} should be rejected");
    }
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zcu102"), None).unwrap();
    spec.clocks_hz = SweepSpec::parse_clocks_csv("150,300").unwrap();
    spec.jobs = 2;
    let report = spec.run();
    let curve = report.cells[0].clock_curve();
    assert_eq!(curve.len(), 2);
    // zcu102's native clock is the second point, so its curve FPS there
    // equals the cell's own prediction.
    assert_eq!(curve[1].fps, report.cells[0].design().predicted().fps);
    // The JSON cells carry the curve under a stable key.
    let j = Json::parse(&report.to_json()).unwrap();
    let pts = j.arr_field("cells")[0].arr_field("clock_curve");
    assert_eq!(pts.len(), 2);
    assert_eq!(pts[0].usize_field("clock_hz"), 150_000_000);
    assert!(pts[0].get("peak_gops").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn cache_flags_resolve_like_the_cli_and_conflicts_explain_themselves() {
    // Neither flag: no cache. --cache alone: the default directory.
    // --cache-dir DIR alone: DIR. Both: a helpful conflict error that
    // names both flags instead of silently preferring one.
    assert_eq!(SweepSpec::resolve_cache_flags(false, None).unwrap(), None);
    assert_eq!(
        SweepSpec::resolve_cache_flags(true, None).unwrap(),
        Some(PathBuf::from(".sweep-cache"))
    );
    assert_eq!(
        SweepSpec::resolve_cache_flags(false, Some("warm/cells")).unwrap(),
        Some(PathBuf::from("warm/cells"))
    );
    let err = SweepSpec::resolve_cache_flags(true, Some("warm/cells")).unwrap_err();
    assert!(err.contains("--cache"), "{err}");
    assert!(err.contains("conflicts with --cache-dir"), "{err}");
    assert!(err.contains("warm/cells"), "names the directory: {err}");
    assert!(err.contains("exactly one"), "says how to fix it: {err}");
}

#[test]
fn pareto_clocks_without_a_clock_axis_is_rejected_helpfully() {
    // --pareto-clocks needs the --clocks axis that feeds its fourth
    // dimension; the error must name the missing flag.
    assert!(sweep::validate_pareto_clocks(false, &[]).is_ok());
    assert!(sweep::validate_pareto_clocks(false, &[150.0e6]).is_ok());
    assert!(sweep::validate_pareto_clocks(true, &[150.0e6, 300.0e6]).is_ok());
    let err = sweep::validate_pareto_clocks(true, &[]).unwrap_err();
    assert!(err.contains("--pareto-clocks"), "{err}");
    assert!(err.contains("--clocks"), "{err}");
}

#[test]
fn cache_dir_spec_round_trips_with_stats_and_stable_documents() {
    let dir = std::env::temp_dir().join("repro_sweep_cli_cache_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
    spec.cache_dir = Some(dir.clone());
    let cold = spec.run();
    assert_eq!(cold.cache, Some(CacheStats { hits: 0, misses: 2, store_errors: 0 }));
    assert_eq!(cold.cache.unwrap().hit_rate(), 0.0);
    let warm = spec.run();
    assert_eq!(warm.cache, Some(CacheStats { hits: 2, misses: 0, store_errors: 0 }));
    assert_eq!(warm.cache.unwrap().hit_rate(), 1.0);
    // The stats line CI greps on the warm step.
    let line = warm.cache.unwrap().summary(&dir);
    assert!(line.contains("2 hits, 0 misses"), "{line}");
    assert!(line.contains("100.0% hit rate"), "{line}");
    // The JSON document never embeds stats — warm/cold stay diffable.
    assert_eq!(cold.to_json(), warm.to_json());
    assert!(!cold.to_json().contains("\"cache\""));
    // The cache directory holds exactly one content-keyed entry per cell.
    let entries = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().ends_with(".cell.json")
        })
        .count();
    assert_eq!(entries, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pareto_clocks_json_document_embeds_candidates_next_to_cells() {
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706,edge"), None).unwrap();
    spec.clocks_hz = SweepSpec::parse_clocks_csv("150,200").unwrap();
    let report = spec.run();
    let analysis = report.pareto_clocks();
    let text = report.to_json_full(None, Some(&analysis));
    assert!(!text.contains('\n'), "one line");
    let j = Json::parse(&text).unwrap();
    let pc = j.get("pareto_clocks").expect("embedded analysis");
    assert_eq!(pc.arr_field("candidates").len(), 4, "2 cells x 2 clocks");
    assert_eq!(pc.arr_field("fronts").len(), 1, "one network");
    for c in pc.arr_field("candidates") {
        assert!(c.usize_field("cell") < j.arr_field("cells").len());
        assert!(c.get("fps").unwrap().as_f64().unwrap() > 0.0);
        let hz = c.get("clock_hz").unwrap().as_f64().unwrap();
        assert!(hz == 150.0e6 || hz == 200.0e6);
    }
    // Without the flag the document stays analysis-free.
    assert!(!report.to_json().contains("pareto_clocks"));
}

#[test]
fn simulated_sweep_cells_carry_actual_figures() {
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
    spec.frames = Some(2);
    let report = spec.run();
    let cell = &report.cells[0];
    let sim = cell.sim().expect("optimized options never deadlock");
    assert!(cell.sim_error().is_none());
    assert_eq!(sim.frames, 2);
    assert!(sim.fps > 0.0);
    assert!(sim.mac_efficiency > 0.0 && sim.mac_efficiency <= 1.0);
    // Simulation never meaningfully beats the Eq-14 bound (the sim is
    // allowed a <=0.1% quantization wobble, see integration.rs).
    assert!(sim.fps <= cell.design().predicted().fps * 1.002);
    let j = Json::parse(&report.to_json()).unwrap();
    let c = &j.arr_field("cells")[0];
    assert!(c.get("sim_fps").unwrap().as_f64().is_some());
    assert_eq!(c.usize_field("sim_frames"), 2);
}

#[test]
fn sweep_sim_options_flow_into_cells_and_zero_frames_is_model_only() {
    // Ablation-style sweep: the spec's SimOptions reach every cell's
    // design (and therefore its simulation and saved artifact).
    let mut spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), None).unwrap();
    spec.sim_options = Some(SimOptions::baseline());
    spec.frames = Some(2);
    let report = spec.run();
    let cell = &report.cells[0];
    assert_eq!(*cell.design().sim_options(), SimOptions::baseline());
    // Baseline options are deadlock-free on the zoo (see proptests), so
    // the cell either simulated or recorded an explicit error — never a
    // silent null next to a requested simulation.
    assert!(cell.sim().is_some() ^ cell.sim_error().is_some());

    // frames = 0 cannot drive the simulator; the sweep treats it as
    // model-only instead of panicking in the warmup arithmetic.
    spec.frames = Some(0);
    spec.sim_options = None;
    let report = spec.run();
    assert!(report.cells[0].sim().is_none());
    assert!(report.cells[0].sim_error().is_none());
}

#[test]
fn degenerate_frame_counts_are_typed_config_errors_not_aborts() {
    // Regression (ISSUE 10): `Pipeline::run` used to `assert!(frames >
    // warmup)` — reachable from user input, and a panic inside one sweep
    // cell aborts the whole run. Both degenerate shapes are now typed
    // `ReproError::Config` values a caller can report per-cell.
    let d = Design::builder(&repro::nets::shufflenet_v2()).build();
    let err = d.simulate(0).unwrap_err();
    assert_eq!(err.kind(), "config");
    assert!(err.contains("at least 1 frame"), "{err}");
    // The engine-level warmup guard surfaces the same way (the library
    // simulate() derives warmup < frames itself, so drive run() directly).
    let opts = *d.sim_options();
    let pipeline = repro::sim::build_pipeline(d.network(), d.allocs(), d.ce_plan(), &opts);
    let err = pipeline.run(2, 2).unwrap_err();
    assert_eq!(err.kind(), "config");
    assert!(err.contains("no measured frame"), "{err}");
}

// --- `util::cli` flag-parser regressions (the PR 8 bugfix batch) -------
//
// The CLI's hand-rolled parser used to (a) silently take the *first*
// occurrence of a repeated flag, letting `--frames 3 ... --frames 9`
// drop the user's override without a word, and (b) not understand the
// ubiquitous `--name=VAL` spelling at all (the value flowed into the
// positional slot or tripped `check_flags`). Both are fixed in
// `util::cli`, which `main.rs` now delegates every subcommand to.

#[test]
fn repeated_flags_are_a_config_error_not_a_silent_first_win() {
    use repro::util::cli::flag_val;
    let args: Vec<String> =
        ["--frames", "3", "--jobs", "2", "--frames", "9"].iter().map(|s| s.to_string()).collect();
    let err = flag_val(&args, "--frames").unwrap_err();
    assert!(err.contains("--frames: duplicate flag"), "{err}");
    assert!(err.contains("given 2 times"), "{err}");
    // Every space/= form mix of the duplicate is caught the same way.
    for pair in [
        ["--frames=3", "--frames=9"],
        ["--frames=3", "--frames"],
        ["--frames", "3", "--frames=9"],
    ] {
        let args: Vec<String> = pair.iter().map(|s| s.to_string()).collect();
        let err = flag_val(&args, "--frames").unwrap_err();
        assert!(err.contains("duplicate flag"), "{pair:?}: {err}");
    }
    // A single occurrence still parses in either form.
    let args: Vec<String> = ["--frames", "3"].iter().map(|s| s.to_string()).collect();
    assert_eq!(flag_val(&args, "--frames").unwrap().as_deref(), Some("3"));
}

#[test]
fn equals_form_values_parse_and_keep_the_flag_shaped_rejection() {
    use repro::util::cli::{check_flags, flag_val, positional};
    let args: Vec<String> =
        ["--nets=mbv2,shv2", "--jobs=4", "--json"].iter().map(|s| s.to_string()).collect();
    assert_eq!(flag_val(&args, "--nets").unwrap().as_deref(), Some("mbv2,shv2"));
    assert_eq!(flag_val(&args, "--jobs").unwrap().as_deref(), Some("4"));
    // `=`-aware check_flags: value flags consume nothing extra, bool
    // flags reject an attached value, unknown stems still fail loudly.
    check_flags(&args, &["--nets", "--jobs"], &["--json"]).unwrap();
    let err = check_flags(&args, &["--nets"], &["--json"]).unwrap_err();
    assert!(err.contains("unknown flag"), "{err}");
    let args: Vec<String> = ["--json=yes"].iter().map(|s| s.to_string()).collect();
    let err = check_flags(&args, &[], &["--json"]).unwrap_err();
    assert!(err.contains("--json: takes no value"), "{err}");
    // An empty `=` value is an explicit error, not Some("").
    let args: Vec<String> = ["--nets="].iter().map(|s| s.to_string()).collect();
    let err = flag_val(&args, "--nets").unwrap_err();
    assert!(err.contains("expected a value after '='"), "{err}");
    // Space-form keeps its flag-shaped-value and missing-value guards.
    let args: Vec<String> = ["--nets", "--json"].iter().map(|s| s.to_string()).collect();
    let err = flag_val(&args, "--nets").unwrap_err();
    assert!(err.contains("expected a value, found flag"), "{err}");
    let args: Vec<String> = ["--nets".to_string()];
    assert!(flag_val(&args, "--nets").unwrap_err().contains("expected a value"));
    // And the positional scanner skips both spellings of a value flag.
    let args: Vec<String> =
        ["--nets=mbv2", "--jobs", "4", "net.json"].iter().map(|s| s.to_string()).collect();
    assert_eq!(positional(&args, &["--nets", "--jobs"]).map(String::as_str), Some("net.json"));
}
