//! Pareto-frontier extraction and parallel-sweep determinism coverage.
//!
//! * Edge cases the extractor must get right: empty matrix, single cell,
//!   exact-tie cells (identical objective vectors dominate in neither
//!   direction — both stay on the frontier).
//! * The acceptance check: every reported frontier is verified against a
//!   brute-force O(n²) dominance scan that re-implements the dominance
//!   rule from the raw per-cell objectives, independently of
//!   [`Objectives::dominates`].
//! * Serial-vs-parallel determinism: `--jobs 1` and `--jobs 8` produce
//!   byte-identical `to_json` documents and identical per-cell design
//!   artifacts (the golden-baseline format), including under the
//!   deliberately uneven per-cell costs of a sim-enabled sweep driven
//!   through the work-stealing pool.
//! * The 4-D acceptance check: [`repro::sweep::pareto_clocks`]'s
//!   frequency-axis frontier verified against a brute-force O(n²)
//!   dominance scan that includes the clock axis.

use repro::alloc::Granularity;
use repro::nets;
use repro::sweep::{pareto, pareto_clocks, Objectives, SweepReport, SweepSpec};
use repro::util::json::Json;
use repro::Platform;

/// Brute-force dominance over raw objective triples (min SRAM, max FPS,
/// min DRAM; strict in at least one) — deliberately re-derived here
/// rather than calling the library's `Objectives::dominates`.
fn dominates_bf(a: (u64, f64, u64), b: (u64, f64, u64)) -> bool {
    (a.0 <= b.0 && a.1 >= b.1 && a.2 <= b.2) && (a.0 < b.0 || a.1 > b.1 || a.2 < b.2)
}

fn raw_objectives(report: &SweepReport) -> Vec<(String, (u64, f64, u64))> {
    report
        .cells
        .iter()
        .map(|c| {
            let d = c.design();
            (d.network().name.clone(), (d.sram_bytes(), d.predicted().fps, d.dram_bytes()))
        })
        .collect()
}

/// The acceptance criterion: for every network, a cell is reported on the
/// frontier iff no same-network cell dominates it under the O(n²) scan,
/// and every dominated-by attribution names a frontier cell that really
/// dominates.
fn assert_frontier_matches_brute_force(report: &SweepReport) {
    let objs = raw_objectives(report);
    let analysis = pareto(report);
    let mut cells_seen = 0usize;
    for front in &analysis.fronts {
        for i in 0..report.cells.len() {
            if objs[i].0 != front.network {
                continue;
            }
            cells_seen += 1;
            let dominated_bf = (0..report.cells.len())
                .any(|j| objs[j].0 == front.network && dominates_bf(objs[j].1, objs[i].1));
            assert_eq!(
                front.frontier.contains(&i),
                !dominated_bf,
                "cell {i} ({}) frontier membership disagrees with brute force",
                front.network
            );
        }
        for &(cell, by) in &front.dominated {
            assert!(front.frontier.contains(&by), "attribution {by} is not a frontier cell");
            assert_eq!(objs[cell].0, front.network);
            assert_eq!(objs[by].0, front.network, "attribution crosses networks");
            assert!(
                dominates_bf(objs[by].1, objs[cell].1),
                "cell {by} does not actually dominate cell {cell}"
            );
        }
        assert_eq!(
            front.frontier.len() + front.dominated.len(),
            report.cells.iter().filter(|c| c.network_name() == front.network).count(),
            "{}: every cell is frontier xor dominated",
            front.network
        );
    }
    assert_eq!(cells_seen, report.cells.len(), "every cell belongs to exactly one front");
}

#[test]
fn empty_matrix_yields_empty_analysis() {
    let report = SweepReport { cells: Vec::new(), failures: Vec::new(), cache: None };
    let analysis = pareto(&report);
    assert!(analysis.fronts.is_empty());
    // And the JSON embedding is well-formed — for the 4-D analysis too.
    let clocks = pareto_clocks(&report);
    assert!(clocks.candidates.is_empty() && clocks.fronts.is_empty());
    let j = Json::parse(&report.to_json_full(Some(&analysis), Some(&clocks))).unwrap();
    assert_eq!(j.get("pareto").unwrap().arr_field("fronts").len(), 0);
    assert_eq!(j.get("pareto_clocks").unwrap().arr_field("candidates").len(), 0);
}

#[test]
fn single_cell_is_its_own_frontier() {
    let spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), Some("fgpm")).unwrap();
    let report = spec.run();
    let analysis = pareto(&report);
    assert_eq!(analysis.fronts.len(), 1);
    assert_eq!(analysis.fronts[0].network, "shufflenet_v2");
    assert_eq!(analysis.fronts[0].frontier, vec![0]);
    assert!(analysis.fronts[0].dominated.is_empty());
    assert_frontier_matches_brute_force(&report);
}

#[test]
fn exact_tie_cells_both_stay_on_the_frontier() {
    // Two custom platforms with identical budgets and clocks differ only
    // in name, so their cells' objective vectors tie exactly: neither
    // dominates and both must be reported as frontier.
    let spec = SweepSpec {
        nets: vec![nets::shufflenet_v2()],
        platforms: vec![
            Platform::custom("tie-a", 2 * 1024 * 1024, 855),
            Platform::custom("tie-b", 2 * 1024 * 1024, 855),
        ],
        granularities: vec![Granularity::Fgpm],
        ..SweepSpec::default()
    };
    let report = spec.run();
    let o0 = Objectives::of(&report.cells[0]);
    let o1 = Objectives::of(&report.cells[1]);
    assert_eq!(o0, o1, "test premise: identical budgets tie exactly");
    assert!(!o0.dominates(&o1) && !o1.dominates(&o0), "ties dominate in neither direction");
    let analysis = pareto(&report);
    assert_eq!(analysis.fronts[0].frontier, vec![0, 1]);
    assert!(analysis.fronts[0].dominated.is_empty());
    assert_frontier_matches_brute_force(&report);
}

#[test]
fn full_matrix_frontier_survives_brute_force_dominance_check() {
    // 2 networks x 3 platforms x 2 granularities: big enough that the
    // frontier is non-trivial (zc706/zcu102/edge trade SRAM, FPS and
    // DRAM against each other) and dominated cells exist (a factorized
    // cell is typically beaten by its FGPM twin at equal memory).
    let spec = SweepSpec::from_csv(
        Some("mobilenet_v2,shufflenet_v2"),
        Some("zc706,zcu102,edge"),
        Some("fgpm,factorized"),
    )
    .unwrap();
    let report = spec.run();
    assert_eq!(report.cells.len(), 12);
    assert_frontier_matches_brute_force(&report);
    let analysis = pareto(&report);
    assert_eq!(analysis.fronts.len(), 2, "one front per network");
    assert!(
        analysis.fronts.iter().any(|f| !f.dominated.is_empty()),
        "expected at least one dominated cell in a mixed-granularity sweep"
    );
    // The JSON embedding round-trips with cells and attributions indexed
    // into the same document.
    let j = Json::parse(&report.to_json_with(Some(&analysis))).unwrap();
    let fronts = j.get("pareto").unwrap().arr_field("fronts");
    assert_eq!(fronts.len(), 2);
    let n_cells = j.arr_field("cells").len();
    for f in fronts {
        for idx in f.arr_field("frontier") {
            assert!(idx.as_usize().unwrap() < n_cells);
        }
        for d in f.arr_field("dominated") {
            assert!(d.usize_field("cell") < n_cells);
            assert!(d.usize_field("by") < n_cells);
        }
    }
    // Plain to_json stays pareto-free (BENCH trajectory compatibility).
    assert!(!report.to_json().contains("\"pareto\""));
}

/// Brute-force 4-D dominance over raw (SRAM, FPS, DRAM, clock) tuples —
/// min/max/min/min, strict in at least one — deliberately re-derived
/// here rather than calling the library's `Objectives::dominates`.
fn dominates_bf4(a: (u64, f64, u64, f64), b: (u64, f64, u64, f64)) -> bool {
    (a.0 <= b.0 && a.1 >= b.1 && a.2 <= b.2 && a.3 <= b.3)
        && (a.0 < b.0 || a.1 > b.1 || a.2 < b.2 || a.3 < b.3)
}

/// Independently expand a report into (network, 4-tuple) candidates the
/// way the analysis documents it: one candidate per clock-curve point,
/// or one at the platform's native clock for curve-less cells, reading
/// FPS straight off the curve / prediction.
fn raw_candidates_4d(report: &SweepReport) -> Vec<(String, (u64, f64, u64, f64))> {
    let mut out = Vec::new();
    for cell in &report.cells {
        let d = cell.design();
        let (sram, dram) = (d.sram_bytes(), d.dram_bytes());
        if cell.clock_curve().is_empty() {
            out.push((
                d.network().name.clone(),
                (sram, d.predicted().fps, dram, d.platform().clock_hz),
            ));
        } else {
            for pt in cell.clock_curve() {
                out.push((d.network().name.clone(), (sram, pt.fps, dram, pt.clock_hz)));
            }
        }
    }
    out
}

/// The ISSUE 5 acceptance criterion: the 4-D frontier (clock axis
/// included) agrees with a brute-force O(n²) dominance scan, every
/// attribution names a frontier candidate that really dominates, and
/// every candidate is frontier xor dominated within its network.
#[test]
fn clock_axis_frontier_survives_brute_force_dominance_check() {
    let mut spec = SweepSpec::from_csv(
        Some("mobilenet_v2,shufflenet_v2"),
        Some("zc706,zcu102,edge"),
        Some("fgpm,factorized"),
    )
    .unwrap();
    spec.clocks_hz = SweepSpec::parse_clocks_csv("100,150,200,300").unwrap();
    let report = spec.run();
    let analysis = pareto_clocks(&report);
    let raw = raw_candidates_4d(&report);
    assert_eq!(analysis.candidates.len(), raw.len(), "12 cells x 4 clocks");
    assert_eq!(raw.len(), 48);
    // The library's candidate expansion matches the independent one
    // value-for-value (same order: cells outer, curve points inner).
    for (cand, (net, t)) in analysis.candidates.iter().zip(&raw) {
        assert_eq!(report.cells[cand.cell].network_name(), net);
        assert_eq!(cand.objectives.sram_bytes, t.0);
        assert_eq!(cand.objectives.fps, t.1);
        assert_eq!(cand.objectives.dram_bytes, t.2);
        assert_eq!(cand.clock_hz, t.3);
        assert_eq!(cand.objectives.clock_hz, Some(t.3));
    }
    let mut seen = 0usize;
    for front in &analysis.fronts {
        for i in 0..raw.len() {
            if raw[i].0 != front.network {
                continue;
            }
            seen += 1;
            let dominated_bf = (0..raw.len())
                .any(|j| raw[j].0 == front.network && dominates_bf4(raw[j].1, raw[i].1));
            assert_eq!(
                front.frontier.contains(&i),
                !dominated_bf,
                "candidate {i} ({}) 4-D frontier membership disagrees with brute force",
                front.network
            );
        }
        for &(cand, by) in &front.dominated {
            assert!(front.frontier.contains(&by), "attribution {by} is not a frontier candidate");
            assert_eq!(raw[cand].0, front.network);
            assert_eq!(raw[by].0, front.network, "attribution crosses networks");
            assert!(
                dominates_bf4(raw[by].1, raw[cand].1),
                "candidate {by} does not actually dominate candidate {cand} on 4 axes"
            );
        }
        assert_eq!(
            front.frontier.len() + front.dominated.len(),
            raw.iter().filter(|(n, _)| *n == front.network).count(),
            "{}: every candidate is frontier xor dominated",
            front.network
        );
    }
    assert_eq!(seen, raw.len(), "every candidate belongs to exactly one front");
    // Sanity on the axis itself: with FPS scaling linearly in clock, two
    // points of one cell never dominate each other, so every *cell*
    // keeps at least one candidate... and with four clocks per cell,
    // dominated candidates must exist across platforms.
    assert!(
        analysis.fronts.iter().any(|f| !f.dominated.is_empty()),
        "expected cross-cell domination in a mixed-granularity clock sweep"
    );
    // The JSON embedding indexes candidates consistently.
    let j = Json::parse(&report.to_json_full(None, Some(&analysis))).unwrap();
    let pc = j.get("pareto_clocks").unwrap();
    let n_cand = pc.arr_field("candidates").len();
    assert_eq!(n_cand, raw.len());
    let n_cells = j.arr_field("cells").len();
    for c in pc.arr_field("candidates") {
        assert!(c.usize_field("cell") < n_cells);
    }
    for f in pc.arr_field("fronts") {
        for idx in f.arr_field("frontier") {
            assert!(idx.as_usize().unwrap() < n_cand);
        }
        for d in f.arr_field("dominated") {
            assert!(d.usize_field("candidate") < n_cand);
            assert!(d.usize_field("by") < n_cand);
        }
    }
    // Plain to_json stays free of both analyses (BENCH compatibility).
    assert!(!report.to_json().contains("\"pareto\""));
    assert!(!report.to_json().contains("\"pareto_clocks\""));
}

#[test]
fn parallel_sweep_is_byte_identical_to_serial_for_any_job_count() {
    // The acceptance criterion for `--jobs N`: identical JSON documents
    // and identical per-cell golden-baseline artifacts, with the clock
    // and pareto analyses stacked on to stress every serialized surface.
    let mut serial = SweepSpec::from_csv(None, None, Some("fgpm,factorized")).unwrap();
    serial.clocks_hz = SweepSpec::parse_clocks_csv("150,200,300").unwrap();
    let mut parallel = serial.clone();
    let serial_report = serial.run();
    assert_eq!(serial.jobs, 1);
    for jobs in [2, 8] {
        parallel.jobs = jobs;
        let par_report = parallel.run();
        assert_eq!(
            serial_report.to_json(),
            par_report.to_json(),
            "jobs={jobs}: sweep JSON must be byte-identical to serial"
        );
        assert_eq!(
            serial_report.to_json_with(Some(&pareto(&serial_report))),
            par_report.to_json_with(Some(&pareto(&par_report))),
            "jobs={jobs}: pareto-bearing JSON must be byte-identical to serial"
        );
        for (a, b) in serial_report.cells.iter().zip(&par_report.cells) {
            assert_eq!(a.artifact_file_name(), b.artifact_file_name(), "jobs={jobs}: cell order");
            assert_eq!(
                a.design().to_json(),
                b.design().to_json(),
                "jobs={jobs}: golden-baseline artifact bytes must match ({})",
                a.artifact_file_name()
            );
        }
    }
}

#[test]
fn uneven_simulated_cells_stay_byte_identical_across_job_counts() {
    // The work-stealing stress case: per-cell costs differ by orders of
    // magnitude (a cycle-simulated MobileNetV2 cell vs a predict-only-ish
    // tiny ShuffleNetV2/edge cell), so with chunked distribution one
    // worker's deque starts loaded with the expensive cells and the rest
    // must steal. Whatever the steal interleaving, `--jobs 1/2/8` must
    // produce byte-identical documents and per-cell artifacts.
    let mut serial =
        SweepSpec::from_csv(Some("mobilenet_v2,shufflenet_v2"), Some("zc706,edge"), None).unwrap();
    serial.frames = Some(1);
    let serial_report = serial.run();
    assert_eq!(serial_report.cells.len(), 4);
    assert!(
        serial_report.cells.iter().any(|c| c.sim().is_some()),
        "premise: the sweep actually simulated"
    );
    let mut parallel = serial.clone();
    for jobs in [2, 8] {
        parallel.jobs = jobs;
        let par_report = parallel.run();
        assert_eq!(
            serial_report.to_json(),
            par_report.to_json(),
            "jobs={jobs}: uneven (sim-enabled) sweep JSON must be byte-identical to serial"
        );
        for (a, b) in serial_report.cells.iter().zip(&par_report.cells) {
            assert_eq!(
                a.design().to_json(),
                b.design().to_json(),
                "jobs={jobs}: artifact bytes must match ({})",
                a.artifact_file_name()
            );
        }
    }
}
