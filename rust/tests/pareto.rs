//! Pareto-frontier extraction and parallel-sweep determinism coverage.
//!
//! * Edge cases the extractor must get right: empty matrix, single cell,
//!   exact-tie cells (identical objective vectors dominate in neither
//!   direction — both stay on the frontier).
//! * The acceptance check: every reported frontier is verified against a
//!   brute-force O(n²) dominance scan that re-implements the dominance
//!   rule from the raw per-cell objectives, independently of
//!   [`Objectives::dominates`].
//! * Serial-vs-parallel determinism: `--jobs 1` and `--jobs 8` produce
//!   byte-identical `to_json` documents and identical per-cell design
//!   artifacts (the golden-baseline format).

use repro::alloc::Granularity;
use repro::nets;
use repro::sweep::{pareto, Objectives, SweepReport, SweepSpec};
use repro::util::json::Json;
use repro::Platform;

/// Brute-force dominance over raw objective triples (min SRAM, max FPS,
/// min DRAM; strict in at least one) — deliberately re-derived here
/// rather than calling the library's `Objectives::dominates`.
fn dominates_bf(a: (u64, f64, u64), b: (u64, f64, u64)) -> bool {
    (a.0 <= b.0 && a.1 >= b.1 && a.2 <= b.2) && (a.0 < b.0 || a.1 > b.1 || a.2 < b.2)
}

fn raw_objectives(report: &SweepReport) -> Vec<(String, (u64, f64, u64))> {
    report
        .cells
        .iter()
        .map(|c| {
            let d = c.design();
            (d.network().name.clone(), (d.sram_bytes(), d.predicted().fps, d.dram_bytes()))
        })
        .collect()
}

/// The acceptance criterion: for every network, a cell is reported on the
/// frontier iff no same-network cell dominates it under the O(n²) scan,
/// and every dominated-by attribution names a frontier cell that really
/// dominates.
fn assert_frontier_matches_brute_force(report: &SweepReport) {
    let objs = raw_objectives(report);
    let analysis = pareto(report);
    let mut cells_seen = 0usize;
    for front in &analysis.fronts {
        for i in 0..report.cells.len() {
            if objs[i].0 != front.network {
                continue;
            }
            cells_seen += 1;
            let dominated_bf = (0..report.cells.len())
                .any(|j| objs[j].0 == front.network && dominates_bf(objs[j].1, objs[i].1));
            assert_eq!(
                front.frontier.contains(&i),
                !dominated_bf,
                "cell {i} ({}) frontier membership disagrees with brute force",
                front.network
            );
        }
        for &(cell, by) in &front.dominated {
            assert!(front.frontier.contains(&by), "attribution {by} is not a frontier cell");
            assert_eq!(objs[cell].0, front.network);
            assert_eq!(objs[by].0, front.network, "attribution crosses networks");
            assert!(
                dominates_bf(objs[by].1, objs[cell].1),
                "cell {by} does not actually dominate cell {cell}"
            );
        }
        assert_eq!(
            front.frontier.len() + front.dominated.len(),
            report.cells.iter().filter(|c| c.network_name() == front.network).count(),
            "{}: every cell is frontier xor dominated",
            front.network
        );
    }
    assert_eq!(cells_seen, report.cells.len(), "every cell belongs to exactly one front");
}

#[test]
fn empty_matrix_yields_empty_analysis() {
    let report = SweepReport { cells: Vec::new() };
    let analysis = pareto(&report);
    assert!(analysis.fronts.is_empty());
    // And the JSON embedding is well-formed.
    let j = Json::parse(&report.to_json_with(Some(&analysis))).unwrap();
    assert_eq!(j.get("pareto").unwrap().arr_field("fronts").len(), 0);
}

#[test]
fn single_cell_is_its_own_frontier() {
    let spec = SweepSpec::from_csv(Some("shufflenet_v2"), Some("zc706"), Some("fgpm")).unwrap();
    let report = spec.run();
    let analysis = pareto(&report);
    assert_eq!(analysis.fronts.len(), 1);
    assert_eq!(analysis.fronts[0].network, "shufflenet_v2");
    assert_eq!(analysis.fronts[0].frontier, vec![0]);
    assert!(analysis.fronts[0].dominated.is_empty());
    assert_frontier_matches_brute_force(&report);
}

#[test]
fn exact_tie_cells_both_stay_on_the_frontier() {
    // Two custom platforms with identical budgets and clocks differ only
    // in name, so their cells' objective vectors tie exactly: neither
    // dominates and both must be reported as frontier.
    let spec = SweepSpec {
        nets: vec![nets::shufflenet_v2()],
        platforms: vec![
            Platform::custom("tie-a", 2 * 1024 * 1024, 855),
            Platform::custom("tie-b", 2 * 1024 * 1024, 855),
        ],
        granularities: vec![Granularity::Fgpm],
        ..SweepSpec::default()
    };
    let report = spec.run();
    let o0 = Objectives::of(&report.cells[0]);
    let o1 = Objectives::of(&report.cells[1]);
    assert_eq!(o0, o1, "test premise: identical budgets tie exactly");
    assert!(!o0.dominates(&o1) && !o1.dominates(&o0), "ties dominate in neither direction");
    let analysis = pareto(&report);
    assert_eq!(analysis.fronts[0].frontier, vec![0, 1]);
    assert!(analysis.fronts[0].dominated.is_empty());
    assert_frontier_matches_brute_force(&report);
}

#[test]
fn full_matrix_frontier_survives_brute_force_dominance_check() {
    // 2 networks x 3 platforms x 2 granularities: big enough that the
    // frontier is non-trivial (zc706/zcu102/edge trade SRAM, FPS and
    // DRAM against each other) and dominated cells exist (a factorized
    // cell is typically beaten by its FGPM twin at equal memory).
    let spec = SweepSpec::from_csv(
        Some("mobilenet_v2,shufflenet_v2"),
        Some("zc706,zcu102,edge"),
        Some("fgpm,factorized"),
    )
    .unwrap();
    let report = spec.run();
    assert_eq!(report.cells.len(), 12);
    assert_frontier_matches_brute_force(&report);
    let analysis = pareto(&report);
    assert_eq!(analysis.fronts.len(), 2, "one front per network");
    assert!(
        analysis.fronts.iter().any(|f| !f.dominated.is_empty()),
        "expected at least one dominated cell in a mixed-granularity sweep"
    );
    // The JSON embedding round-trips with cells and attributions indexed
    // into the same document.
    let j = Json::parse(&report.to_json_with(Some(&analysis))).unwrap();
    let fronts = j.get("pareto").unwrap().arr_field("fronts");
    assert_eq!(fronts.len(), 2);
    let n_cells = j.arr_field("cells").len();
    for f in fronts {
        for idx in f.arr_field("frontier") {
            assert!(idx.as_usize().unwrap() < n_cells);
        }
        for d in f.arr_field("dominated") {
            assert!(d.usize_field("cell") < n_cells);
            assert!(d.usize_field("by") < n_cells);
        }
    }
    // Plain to_json stays pareto-free (BENCH trajectory compatibility).
    assert!(!report.to_json().contains("\"pareto\""));
}

#[test]
fn parallel_sweep_is_byte_identical_to_serial_for_any_job_count() {
    // The acceptance criterion for `--jobs N`: identical JSON documents
    // and identical per-cell golden-baseline artifacts, with the clock
    // and pareto analyses stacked on to stress every serialized surface.
    let mut serial = SweepSpec::from_csv(None, None, Some("fgpm,factorized")).unwrap();
    serial.clocks_hz = SweepSpec::parse_clocks_csv("150,200,300").unwrap();
    let mut parallel = serial.clone();
    let serial_report = serial.run();
    assert_eq!(serial.jobs, 1);
    for jobs in [2, 8] {
        parallel.jobs = jobs;
        let par_report = parallel.run();
        assert_eq!(
            serial_report.to_json(),
            par_report.to_json(),
            "jobs={jobs}: sweep JSON must be byte-identical to serial"
        );
        assert_eq!(
            serial_report.to_json_with(Some(&pareto(&serial_report))),
            par_report.to_json_with(Some(&pareto(&par_report))),
            "jobs={jobs}: pareto-bearing JSON must be byte-identical to serial"
        );
        for (a, b) in serial_report.cells.iter().zip(&par_report.cells) {
            assert_eq!(a.artifact_file_name(), b.artifact_file_name(), "jobs={jobs}: cell order");
            assert_eq!(
                a.design().to_json(),
                b.design().to_json(),
                "jobs={jobs}: golden-baseline artifact bytes must match ({})",
                a.artifact_file_name()
            );
        }
    }
}
