//! Coverage of the constrained design-space optimizer
//! (`repro::sweep::optimize`): the acceptance pin that the branch-and-bound
//! winner byte-matches the exhaustive sweep's best cell on the committed
//! 12-cell baseline grid, a pruning-soundness check over every index the
//! bound cut, determinism of the annealing fallback, and an
//! optimizer-vs-exhaustive equivalence property over random seeded custom
//! budgets.

use repro::alloc::Granularity;
use repro::design::Platform;
use repro::sweep::optimize::{Objective, OptimizeSpec, Strategy};
use repro::sweep::{optimize, SweepReport, SweepSpec};
use repro::util::json::Json;
use repro::util::prop;

const OBJECTIVES: [Objective; 3] = [Objective::Fps, Objective::Sram, Objective::Dram];

/// Strict "a beats b" under an objective — the test-local twin of the
/// optimizer's private ordering (Fps maximizes, Sram/Dram minimize).
fn better(objective: Objective, a: f64, b: f64) -> bool {
    match objective {
        Objective::Fps => a > b,
        Objective::Sram | Objective::Dram => a < b,
    }
}

/// Matrix-first argbest over one network's slice of an exhaustive report:
/// the global index and objective value the optimizer must reproduce.
/// Requires a failure-free report (cells then line up with matrix order).
fn exhaustive_best(
    report: &SweepReport,
    objective: Objective,
    ni: usize,
    per_net: usize,
) -> (usize, f64) {
    assert!(report.failures.is_empty(), "baseline grids must evaluate cleanly");
    let mut best: Option<(usize, f64)> = None;
    for ci in 0..per_net {
        let index = ni * per_net + ci;
        let value = objective.exact(&report.cells[index]);
        match best {
            Some((_, incumbent)) if !better(objective, value, incumbent) => {}
            _ => best = Some((index, value)),
        }
    }
    best.expect("non-empty per-network slice")
}

/// The acceptance criterion from the issue: on the committed 12-cell
/// baseline grid (4 zoo nets x 3 catalog platforms x FGPM), the optimizer's
/// winner must byte-match the exhaustive sweep's matrix-first best cell —
/// same global index, identical JSON bytes — for every objective.
#[test]
fn bnb_winner_byte_matches_exhaustive_best_on_baseline_grid() {
    let spec = SweepSpec::default();
    let per_net = spec.platforms.len() * spec.granularities.len();
    let exhaustive = spec.run();
    for objective in OBJECTIVES {
        let report = OptimizeSpec::new(spec.clone(), objective, Strategy::BranchBound).run();
        assert!(report.failures.is_empty(), "{objective:?}: {:?}", report.failures);
        assert_eq!(report.searches.len(), spec.nets.len());
        for (ni, search) in report.searches.iter().enumerate() {
            let (want_index, _) = exhaustive_best(&exhaustive, objective, ni, per_net);
            assert_eq!(search.winner_index, Some(want_index), "{objective:?}/{}", search.network);
            let winner = search.winner.as_ref().expect("winner on a clean grid");
            assert_eq!(
                winner.to_json_value().to_string(),
                exhaustive.cells[want_index].to_json_value().to_string(),
                "{objective:?}/{}: winner must byte-match the exhaustive cell",
                search.network
            );
        }
        assert_eq!(optimize::exit_code(&report), 0);
    }
}

/// The issue's second acceptance pin: search statistics must show real
/// pruning on at least one baseline. Under the `dram` objective the bound
/// is exact and the catalog order (zc706, zcu102, edge) guarantees the
/// edge candidate is always cut; `fps` prunes too (edge's analytic FPS
/// ceiling sits far below zc706's achieved throughput on every zoo net).
#[test]
fn bnb_prunes_on_the_baseline_grid_and_its_accounting_balances() {
    for objective in [Objective::Dram, Objective::Fps] {
        let report =
            OptimizeSpec::new(SweepSpec::default(), objective, Strategy::BranchBound).run();
        assert!(report.total_pruned() > 0, "{objective:?}: expected pruned > 0 on the baseline");
        for search in &report.searches {
            let s = &search.stats;
            assert_eq!(s.candidates, 3, "{objective:?}/{}", search.network);
            assert_eq!(s.evaluated + s.pruned, s.candidates, "{objective:?}/{}", search.network);
            assert_eq!(search.pruned_indices.len(), s.pruned);
            if s.pruned > 0 {
                assert!(s.pruned_space > 0, "pruned candidates cover a nonzero FGPM space");
            }
            let tightness = s.bound_tightness.expect("evaluated > 0 on a clean grid");
            assert!((0.0..=1.0).contains(&tightness), "{tightness}");
        }
    }
}

/// Pruning soundness: no pruned index may hold a cell that is strictly
/// better than the reported winner, nor an equal-valued cell at a lower
/// matrix index (which matrix-first tie-breaking would have preferred).
#[test]
fn pruning_is_sound_no_pruned_cell_beats_the_winner() {
    let spec = SweepSpec::default();
    let per_net = spec.platforms.len() * spec.granularities.len();
    let exhaustive = spec.run();
    assert!(exhaustive.failures.is_empty());
    for objective in OBJECTIVES {
        let report = OptimizeSpec::new(spec.clone(), objective, Strategy::BranchBound).run();
        for search in &report.searches {
            let wi = search.winner_index.expect("winner on a clean grid");
            let wv = objective.exact(&exhaustive.cells[wi]);
            for &pi in &search.pruned_indices {
                assert_eq!(pi / per_net, wi / per_net, "pruned indices stay in-network");
                let pv = objective.exact(&exhaustive.cells[pi]);
                assert!(
                    !better(objective, pv, wv),
                    "{objective:?}/{}: pruned cell {pi} ({pv}) beats winner {wi} ({wv})",
                    search.network
                );
                if pv == wv {
                    assert!(pi > wi, "an equal-valued earlier index must not be pruned");
                }
            }
        }
    }
}

/// The annealing fallback is exact by construction (walk + sweep-up visits
/// every candidate) and bound-free: it must reproduce the branch-and-bound
/// winner byte-for-byte with zero pruning, deterministically across runs.
#[test]
fn anneal_is_exact_deterministic_and_never_prunes() {
    let spec = SweepSpec::default();
    let per_net = spec.platforms.len() * spec.granularities.len();
    let exhaustive = spec.run();
    for objective in OBJECTIVES {
        let report = OptimizeSpec::new(spec.clone(), objective, Strategy::Anneal).run();
        let again = OptimizeSpec::new(spec.clone(), objective, Strategy::Anneal).run();
        assert_eq!(report.to_json(), again.to_json(), "{objective:?}: anneal must be seeded");
        for (ni, search) in report.searches.iter().enumerate() {
            let (want_index, _) = exhaustive_best(&exhaustive, objective, ni, per_net);
            assert_eq!(search.winner_index, Some(want_index), "{objective:?}/{}", search.network);
            assert_eq!(search.stats.pruned, 0);
            assert!(search.pruned_indices.is_empty());
            assert_eq!(search.stats.evaluated, search.stats.candidates);
        }
    }
}

/// Optimizer-vs-exhaustive equivalence over random seeded `custom`-budget
/// platforms (the issue's property test): for any budget the generator
/// produces — both granularities, varied SRAM/DSP/clock — the
/// branch-and-bound winner equals the exhaustive matrix-first argbest, and
/// every pruned index is sound.
#[test]
fn optimizer_equals_exhaustive_on_random_custom_budgets() {
    prop::check(
        "optimize_vs_exhaustive",
        12,
        |rng| {
            let sram_kb = rng.range(256, 6144) as u64;
            let dsp = rng.range(48, 3000);
            let clock_mhz = rng.range(80, 400) as f64;
            let alt_sram_kb = rng.range(256, 6144) as u64;
            let alt_dsp = rng.range(48, 3000);
            (sram_kb, dsp, clock_mhz, alt_sram_kb, alt_dsp)
        },
        |&(sram_kb, dsp, clock_mhz, alt_sram_kb, alt_dsp)| {
            let spec = SweepSpec {
                nets: vec![repro::nets::mobilenet_v2(), repro::nets::shufflenet_v2()],
                platforms: vec![
                    Platform::custom("a-custom", sram_kb * 1024, dsp)
                        .with_clock_hz(clock_mhz * 1.0e6),
                    Platform::custom("b-custom", alt_sram_kb * 1024, alt_dsp),
                ],
                granularities: vec![Granularity::Fgpm, Granularity::Factorized],
                ..SweepSpec::default()
            };
            let per_net = spec.platforms.len() * spec.granularities.len();
            let exhaustive = spec.run();
            if !exhaustive.failures.is_empty() {
                return Err(format!("exhaustive run failed: {:?}", exhaustive.failures));
            }
            for objective in OBJECTIVES {
                let report =
                    OptimizeSpec::new(spec.clone(), objective, Strategy::BranchBound).run();
                for (ni, search) in report.searches.iter().enumerate() {
                    let (want_index, wv) = exhaustive_best(&exhaustive, objective, ni, per_net);
                    if search.winner_index != Some(want_index) {
                        return Err(format!(
                            "{objective:?}/{}: winner {:?} != exhaustive best {want_index}",
                            search.network, search.winner_index
                        ));
                    }
                    let winner = search.winner.as_ref().expect("clean run");
                    if winner.to_json_value().to_string()
                        != exhaustive.cells[want_index].to_json_value().to_string()
                    {
                        return Err(format!(
                            "{objective:?}/{}: winner bytes diverge from the exhaustive cell",
                            search.network
                        ));
                    }
                    for &pi in &search.pruned_indices {
                        let pv = objective.exact(&exhaustive.cells[pi]);
                        if better(objective, pv, wv) || (pv == wv && pi < want_index) {
                            return Err(format!(
                                "{objective:?}/{}: unsound prune of index {pi}",
                                search.network
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Report surface: the JSON round-trips through `util::json` with the
/// documented keys, and the text renderer names every network next to the
/// search statistics.
#[test]
fn report_json_and_table_surface_the_search() {
    let report =
        OptimizeSpec::new(SweepSpec::default(), Objective::Fps, Strategy::BranchBound).run();
    let json = Json::parse(&report.to_json()).expect("optimize JSON parses back");
    let Json::Obj(top) = &json else { panic!("top-level object") };
    for key in ["objective", "strategy", "searches", "version"] {
        assert!(top.contains_key(key), "missing key {key:?}");
    }
    assert!(!top.contains_key("failures"), "no failures key on a clean run");
    let table = repro::report::optimize_table(&report);
    assert!(table.contains("Constrained search"), "{table}");
    for search in &report.searches {
        assert!(table.contains(&search.network), "{table}");
    }
    assert!(table.contains("pruned"), "{table}");
}
