//! Cross-module integration tests: the analytical model, the allocation
//! algorithms, the cycle-level simulator, and the AOT stage plan must all
//! tell one consistent story.

use repro::alloc;
use repro::model::dram;
use repro::model::memory::{CePlan, MemoryModelCfg};
use repro::nets::{self, LayerKind};
use repro::report;
use repro::util::json::Json;
use repro::{zc706, Design, Platform, CLOCK_HZ};

// ---------------------------------------------------------------------
// Model <-> simulator consistency
// ---------------------------------------------------------------------

#[test]
fn sim_never_beats_theory_and_stays_close_on_implemented_configs() {
    for net in [nets::mobilenet_v2(), nets::shufflenet_v2()] {
        let d = Design::builder(&net).platform(Platform::zc706()).build();
        let stats = d.simulate(10).unwrap();
        let ratio = stats.period_cycles / d.predicted().t_max as f64;
        assert!(ratio >= 0.999, "{}: sim beat theory ({ratio})", net.name);
        assert!(ratio < 1.10, "{}: ratio {ratio}", net.name);
    }
}

#[test]
fn sim_efficiency_reproduces_paper_band_on_both_networks() {
    // Table IV: 94.35% / 94.58% actual MAC efficiency. Require >= 90%.
    for (net, paper) in [(nets::mobilenet_v2(), 94.35), (nets::shufflenet_v2(), 94.58)] {
        let r = report::impl_row(&net, "ZC706", zc706::SRAM_BYTES, 10);
        let eff = r.mac_eff_sim * 100.0;
        assert!(eff > 90.0, "{}: {eff:.2}% (paper {paper}%)", net.name);
        assert!(eff <= 100.0);
    }
}

#[test]
fn fps_reproduces_table3_within_15_percent() {
    let rows = report::tab3_rows(10);
    for (r, (pn, pc, _, pfps, ..)) in rows.iter().zip(report::paper_ref::TABLE3) {
        assert_eq!(r.net_name, pn);
        assert_eq!(r.config, pc);
        let rel = (r.fps_sim - pfps).abs() / pfps;
        assert!(rel < 0.15, "{} {}: {:.1} vs paper {:.1}", pn, pc, r.fps_sim, pfps);
    }
}

#[test]
fn table3_memory_figures_track_paper() {
    let rows = report::tab3_rows(6);
    for (r, (pn, pc, _, _, psram, pdram, _)) in rows.iter().zip(report::paper_ref::TABLE3) {
        assert!((r.sram_mb - psram).abs() / psram < 0.25, "{pn} {pc} sram {:.2} vs {psram}", r.sram_mb);
        assert!((r.dram_mb - pdram).abs() / pdram.max(0.5) < 0.35, "{pn} {pc} dram {:.2} vs {pdram}", r.dram_mb);
    }
}

#[test]
fn zc706_dsp_utilization_target() {
    // Table II: 844/853 DSPs (93.8/94.8%). Require > 90%.
    for net in [nets::mobilenet_v2(), nets::shufflenet_v2()] {
        let r = report::impl_row(&net, "ZC706", zc706::SRAM_BYTES, 6);
        let util = r.dsps as f64 / zc706::DSP as f64;
        assert!(util > 0.90 && r.dsps <= zc706::DSP_BUDGET, "{}: {}", net.name, r.dsps);
    }
}

#[test]
fn fig17_ablation_ordering_holds() {
    // baseline < optimized < reallocation (Fig 17's monotone improvement).
    let rows = report::fig17_rows(8);
    assert!(rows[0].actual_eff < rows[1].actual_eff, "padding/stride congestion missing");
    assert!(rows[1].actual_eff < rows[2].actual_eff, "FGPM reallocation gain missing");
    // Optimized closes most of the gap to theory (paper: 84.79% vs ~85%).
    assert!(rows[1].actual_eff / rows[1].theoretical_eff > 0.97);
}

#[test]
fn dram_model_vs_ue_se_shape() {
    // Fig 14: UE >= SE >= proposed, and FM reduction ~98% (ours: 100% by
    // construction since non-shortcut FMs never leave the chip).
    for net in nets::all_networks() {
        let cfg = MemoryModelCfg::default();
        let b = alloc::balanced_memory_allocation(&net, 0, &cfg).boundary_min_sram;
        let ue = dram::unified_ce(&net);
        let se = dram::separated_ce(&net);
        let ours = dram::proposed(&net, &CePlan { boundary: b });
        assert!(ue.total() > se.total() && se.total() > ours.total(), "{}", net.name);
        let ratio = ue.total() as f64 / ours.total() as f64;
        assert!(ratio > 2.0, "{}: UE/ours only {ratio:.2}", net.name);
    }
}

// ---------------------------------------------------------------------
// AOT stage plan <-> rust network zoo consistency (no PJRT needed: the
// manifest is plain JSON).
// ---------------------------------------------------------------------

fn load_manifest(short: &str) -> Option<Json> {
    let path = repro::runtime::artifacts_dir().join(format!("{short}_manifest.json"));
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("manifest parses"))
}

#[test]
fn manifest_stage_weights_match_zoo_blocks() {
    let Some(m) = load_manifest("mbv2") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let net = nets::mobilenet_v2();
    let blocks = net.block_memory_profile();
    let stages = m.arr_field("stages");
    // Stage k of the AOT plan == block k of the zoo description (stem,
    // 17 bottlenecks, head). The zoo splits the head into pwc/pool/fc
    // blocks; compare the prefix.
    for (i, stage) in stages.iter().enumerate().take(blocks.len() - 1) {
        let sw = stage.usize_field("weight_bytes_8bit") as u64;
        // Head stage aggregates the zoo's remaining blocks.
        if i + 1 == stages.len() {
            break;
        }
        let zw = blocks[i].2;
        assert_eq!(sw, zw, "stage {i} ({})", stage.str_field("name"));
    }
}

#[test]
fn manifest_boundary_agrees_with_distribution_criterion() {
    // The python block-level split (weights <= FM) must put the boundary in
    // the same region as rust's layer-level Algorithm 1 minimum: all FRCE
    // stages must be shallow (weight-light) blocks.
    for short in ["mbv2", "snv2"] {
        let Some(m) = load_manifest(short) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let b = m.usize_field("boundary");
        let stages = m.arr_field("stages");
        assert!(b > 0 && b < stages.len());
        for (i, s) in stages.iter().enumerate() {
            let w = s.usize_field("weight_bytes_8bit");
            let fm = s.usize_field("fm_bytes_8bit");
            if i < b {
                assert!(w <= fm, "{short} FRCE stage {i} is weight-heavy");
            }
        }
        // WRCE region holds the bulk of the parameters (the paper's deep
        // layer observation).
        let frce_w: usize = stages[..b].iter().map(|s| s.usize_field("weight_bytes_8bit")).sum();
        let wrce_w: usize = stages[b..].iter().map(|s| s.usize_field("weight_bytes_8bit")).sum();
        assert!(wrce_w > 5 * frce_w, "{short}: {frce_w} vs {wrce_w}");
    }
}

// ---------------------------------------------------------------------
// Whole-methodology regression: design points for all four networks.
// ---------------------------------------------------------------------

#[test]
fn design_points_all_networks_reasonable() {
    for net in nets::all_networks() {
        let d = Design::builder(&net).platform(Platform::zc706()).build();
        let perf = d.predicted();
        assert!(perf.mac_efficiency > 0.85, "{}: eff {}", net.name, perf.mac_efficiency);
        assert!(d.parallelism().dsps <= zc706::DSP_BUDGET);
        assert!(d.sram_bytes() < zc706::SRAM_BYTES * 3 / 2, "{}", net.name);
        let fps = perf.fps;
        assert!(fps > 300.0 && fps < 10_000.0, "{}: {fps}", net.name);
        // Throughput sanity vs the clock: GOPS <= 2 * PEs * f.
        assert!(perf.gops <= d.parallelism().pes as f64 * 2.0 * CLOCK_HZ / 1e9 + 1e-6);
    }
}

#[test]
fn pool_and_movement_layers_never_bottleneck() {
    for net in nets::all_networks() {
        let d = Design::builder(&net).platform(Platform::zc706()).build();
        let b = &net.layers[d.predicted().bottleneck];
        assert!(
            b.kind.is_mac(),
            "{}: bottleneck is {:?}",
            net.name,
            b.kind
        );
        assert!(!matches!(b.kind, LayerKind::Add));
    }
}
