//! IR front-end integration tests: the layer-graph `Graph` description,
//! its JSON wire format, and the lowering pass, pinned against both the
//! committed `networks/*.json` catalog and the golden design baselines.
//!
//! The contract under test, end to end:
//!
//! * `ir::to_json` -> `ir::from_json` -> `ir::lower` is equivalent to
//!   lowering the zoo graph directly, for every zoo network;
//! * the committed catalog files are byte-identical to what the Rust
//!   writer emits (so `python/gen_networks.py` and `ir::to_json` can
//!   never drift apart silently);
//! * a `Design` built from an IR-lowered zoo network reproduces the
//!   committed golden baseline byte-for-byte — the IR refactor moved the
//!   zoo's construction path without moving a single derived figure;
//! * a committed non-zoo network (`mobilenet_v2_050.json`) flows through
//!   the whole pipeline: load, design (with an embedded `network_def`),
//!   both artifact readers, and a cached sweep that goes 100% warm on
//!   re-run and cold again when the graph content changes;
//! * malformed documents die with actionable, node-named errors.

use std::path::PathBuf;

use repro::design::{Design, Platform};
use repro::sweep::{CacheStats, SweepSpec};
use repro::{ir, nets};

fn networks_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("networks")
}

fn baselines_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("baselines")
}

/// (full name, baseline short name) for the whole zoo.
const ZOO: [(&str, &str); 4] = [
    ("mobilenet_v1", "mbv1"),
    ("mobilenet_v2", "mbv2"),
    ("shufflenet_v1", "snv1"),
    ("shufflenet_v2", "snv2"),
];

#[test]
fn zoo_graphs_round_trip_through_json_and_lower_identically() {
    for (name, _) in ZOO {
        let graph = nets::zoo_graph(name).expect("zoo graph");
        let text = ir::to_json(&graph);
        let back = ir::from_json(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(graph, back, "{name}: from_json(to_json(g)) must be the identity");
        // Serialization is a fixed point, so committed files re-export
        // byte-identically no matter which side wrote them.
        assert_eq!(ir::to_json(&back), text, "{name}: to_json must be a fixed point");
        let direct = nets::by_name(name).expect("zoo network");
        let via_json = ir::lower(&back).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(
            format!("{direct:?}"),
            format!("{via_json:?}"),
            "{name}: lowering a JSON round-tripped graph diverged from the zoo network"
        );
    }
}

#[test]
fn committed_catalog_matches_the_rust_writer_byte_for_byte() {
    for (name, _) in ZOO {
        let path = networks_dir().join(format!("{name}.json"));
        let committed = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!("{}: {e} (regenerate with `python3 python/gen_networks.py`)", path.display())
        });
        let expected = ir::to_json(&nets::zoo_graph(name).expect("zoo graph"));
        assert_eq!(
            committed,
            expected,
            "{}: stale against the Rust builder — regenerate with `python3 python/gen_networks.py`",
            path.display()
        );
    }
}

#[test]
fn every_committed_network_loads_validates_and_lowers() {
    let mut loaded = Vec::new();
    for entry in std::fs::read_dir(networks_dir()).expect("networks/ directory") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let net = ir::load_file(&path).unwrap_or_else(|e| panic!("{e}"));
        net.validate().unwrap_or_else(|e| panic!("{}: lowered network invalid: {e}", path.display()));
        loaded.push(net.name.clone());
    }
    loaded.sort();
    // The four zoo networks plus at least one non-zoo LWCNN.
    for (name, _) in ZOO {
        assert!(loaded.iter().any(|n| n == name), "catalog is missing {name}: {loaded:?}");
    }
    assert!(
        loaded.iter().any(|n| nets::by_name(n).is_none()),
        "catalog must carry at least one non-zoo network, found only {loaded:?}"
    );
}

#[test]
fn ir_lowered_designs_match_committed_golden_baselines() {
    // The acceptance bar of the IR refactor: every zoo network, lowered
    // through the IR path, produces byte-identical design artifacts to
    // the committed pre-IR golden baselines on every catalog platform.
    for (name, short) in ZOO {
        let net = ir::lower(&nets::zoo_graph(name).expect("zoo graph")).expect("zoo graph lowers");
        for platform in Platform::list() {
            let path = baselines_dir().join(format!("{short}_{}_fgpm.design.json", platform.name));
            let committed = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let design = Design::builder(&net).platform(platform.clone()).build();
            assert_eq!(
                committed.trim_end_matches('\n'),
                design.to_json(),
                "{name} on {}: IR-lowered design diverged from the golden baseline",
                platform.name
            );
        }
    }
}

/// A minimal `repro-net` document with the given node lines.
fn doc(nodes: &str) -> String {
    format!(
        "{{\n  \"format\": \"repro-net\",\n  \"version\": 1,\n  \"name\": \"t\",\n  \
         \"input\": {{\"size\": 8, \"channels\": 4}},\n  \"nodes\": [\n{nodes}\n  ]\n}}\n"
    )
}

#[test]
fn malformed_documents_fail_with_actionable_errors() {
    // Shape mismatch at a concat: one branch strides down to 4x4, the
    // other stays 8x8.
    let mismatch = doc(
        r#"    {"name": "a", "block": "b", "op": "conv", "inputs": [], "out_ch": 4, "k": 3, "stride": 2, "pad": 1},
    {"name": "c", "block": "b", "op": "conv", "inputs": [], "out_ch": 4, "k": 3, "stride": 1, "pad": 1},
    {"name": "join", "block": "b", "op": "concat", "inputs": [1, 0]}"#,
    );
    let err = ir::from_json(&mismatch).unwrap_err();
    assert!(err.contains("shape mismatch at concat"), "{err}");
    assert!(err.contains("\"join\""), "error must name the node: {err}");

    // Dangling edge: references a node index past the end of the list.
    let dangling = doc(
        r#"    {"name": "a", "block": "b", "op": "conv", "inputs": [], "out_ch": 4, "k": 3, "stride": 1, "pad": 1},
    {"name": "out", "block": "b", "op": "fc", "inputs": [7], "out_ch": 10}"#,
    );
    let err = ir::from_json(&dangling).unwrap_err();
    assert!(err.contains("dangling edge"), "{err}");
    assert!(err.contains("undefined node 7"), "{err}");

    // Cycle: a forward edge means the topological order cannot exist.
    let cycle = doc(
        r#"    {"name": "a", "block": "b", "op": "conv", "inputs": [1], "out_ch": 4, "k": 3, "stride": 1, "pad": 1},
    {"name": "c", "block": "b", "op": "conv", "inputs": [0], "out_ch": 4, "k": 3, "stride": 1, "pad": 1}"#,
    );
    let err = ir::from_json(&cycle).unwrap_err();
    assert!(err.contains("cycle"), "{err}");

    // Loader-level failures point at the file.
    let err = ir::load_file(&networks_dir().join("no_such_network.json")).unwrap_err();
    assert!(err.contains("no_such_network.json"), "{err}");
}

#[test]
fn sweep_from_cli_threads_net_files_onto_the_network_axis() {
    let file = networks_dir().join("mobilenet_v2_050.json");
    let file = file.to_str().expect("utf-8 path");

    // --net-file alone replaces the default zoo axis.
    let solo = SweepSpec::from_cli(None, Some(file), Some("zc706"), Some("fgpm")).unwrap();
    assert_eq!(solo.nets.len(), 1);
    assert_eq!(solo.nets[0].name, "mobilenet_v2_050");

    // Next to --nets it extends the axis instead.
    let both = SweepSpec::from_cli(Some("mbv1"), Some(file), Some("zc706"), Some("fgpm")).unwrap();
    assert_eq!(both.nets.len(), 2);
    assert_eq!((both.nets[0].name.as_str(), both.nets[1].name.as_str()),
               ("mobilenet_v1", "mobilenet_v2_050"));

    // A missing file fails loudly, naming the flag and the path.
    let err = SweepSpec::from_cli(None, Some("networks/absent.json"), None, None).unwrap_err();
    assert!(err.contains("--net-file"), "{err}");
    assert!(err.contains("absent.json"), "{err}");

    // The resolver behind --nets lists the zoo and mentions --net-file.
    let err = SweepSpec::from_cli(Some("resnet50"), None, None, None).unwrap_err();
    assert!(err.contains("unknown network \"resnet50\""), "{err}");
    assert!(err.contains("--net-file"), "{err}");
}

#[test]
fn non_zoo_network_designs_embed_their_definition_and_sweep_warm() {
    let path = networks_dir().join("mobilenet_v2_050.json");
    let net = ir::load_file(&path).expect("catalog loads");
    assert!(nets::by_name(&net.name).is_none(), "mobilenet_v2_050 must stay out of the zoo");

    // The design artifact is self-contained: it embeds the network
    // definition, and both readers rebuild it bit-for-bit.
    let design = Design::builder(&net).build();
    let text = design.to_json();
    assert!(text.contains("\"network_def\""), "non-zoo artifact must embed its network");
    let checked = Design::from_json(&text).expect("checked reload");
    assert_eq!(format!("{:?}", checked.network()), format!("{net:?}"));
    let trusted = Design::from_json_unchecked(&text).expect("trusted reload");
    assert_eq!(trusted.to_json(), text, "trusted reload must be a byte-identical fixed point");

    // Cached sweep: cold run stores, identical re-run is 100% warm, and
    // the documents are byte-identical.
    let dir = std::env::temp_dir().join("repro_ir_netfile_cache");
    let _ = std::fs::remove_dir_all(&dir);
    let spec = SweepSpec {
        nets: vec![net],
        platforms: vec![Platform::zc706()],
        cache_dir: Some(dir.clone()),
        ..SweepSpec::default()
    };
    let cold = spec.run();
    assert_eq!(cold.cache, Some(CacheStats { hits: 0, misses: 1, store_errors: 0 }));
    let warm = spec.run();
    assert_eq!(warm.cache, Some(CacheStats { hits: 1, misses: 0, store_errors: 0 }));
    assert_eq!(cold.to_json(), warm.to_json(), "warm document must be byte-identical");

    // Editing the network file changes the content key: the same sweep
    // over the edited graph misses instead of serving the stale cell.
    let edited_text = std::fs::read_to_string(&path)
        .unwrap()
        .replace("\"out_ch\": 1000", "\"out_ch\": 1001");
    let edited_graph = ir::from_json(&edited_text).expect("edited graph still valid");
    let edited = ir::lower(&edited_graph).expect("edited graph lowers");
    let respec = SweepSpec { nets: vec![edited], ..spec };
    assert_eq!(respec.run().cache, Some(CacheStats { hits: 0, misses: 1, store_errors: 0 }));
    let _ = std::fs::remove_dir_all(&dir);
}
