//! Tests of the `Design`/`Platform` façade: JSON persistence round-trips,
//! platform budgets steering Algorithm 1, and the deprecated
//! `alloc::design_point` shim agreeing with the builder path.

use repro::alloc::Granularity;
use repro::sim::SimOptions;
use repro::{nets, zc706, Design, Platform};

#[test]
fn design_json_roundtrips_bit_identically() {
    for net in [nets::mobilenet_v2(), nets::shufflenet_v2()] {
        let d = Design::builder(&net).platform(Platform::zc706()).build();
        let json = d.to_json();
        let reloaded = Design::from_json(&json).expect("reload");
        assert_eq!(json, reloaded.to_json(), "{}: to_json not a fixed point", net.name);
        // And a second round trip stays fixed.
        assert_eq!(reloaded.to_json(), Design::from_json(&reloaded.to_json()).unwrap().to_json());
    }
}

#[test]
fn design_json_roundtrips_for_non_default_build_inputs() {
    let net = nets::shufflenet_v1();
    let d = Design::builder(&net)
        .platform(Platform::custom("edge", 700 * 1024, 320).with_clock_hz(150.0e6))
        .granularity(Granularity::Factorized)
        .sim_options(SimOptions::baseline())
        .build();
    let json = d.to_json();
    let reloaded = Design::from_json(&json).expect("reload");
    assert_eq!(json, reloaded.to_json());
    assert_eq!(reloaded.platform().name, "edge");
    assert_eq!(reloaded.platform().clock_hz, 150.0e6);
    assert_eq!(reloaded.granularity(), Granularity::Factorized);
    assert_eq!(*reloaded.sim_options(), SimOptions::baseline());
}

#[test]
fn json_is_one_line_with_sorted_keys() {
    let net = nets::mobilenet_v1();
    let d = Design::builder(&net).build();
    for text in [d.to_json(), d.summary_json()] {
        assert!(!text.contains('\n'), "not one line: {text}");
        // Top-level keys appear in sorted order.
        let keys: Vec<usize> = ["\"boundary\"", "\"network\"", "\"platform\"", "\"sram_bytes\""]
            .iter()
            .map(|k| text.find(k).unwrap_or_else(|| panic!("missing {k} in {text}")))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "keys out of order: {text}");
    }
}

#[test]
fn tiny_sram_platform_pushes_boundary_earlier_than_zc706() {
    let net = nets::mobilenet_v2();
    let tiny = Design::builder(&net)
        .platform(Platform::custom("tiny-sram", 256 * 1024, zc706::DSP_BUDGET))
        .build();
    let zc = Design::builder(&net).platform(Platform::zc706()).build();
    // Algorithm 1's second iteration trades spare SRAM for a deeper FRCE
    // region; with almost no SRAM headroom the FRCE/WRCE boundary must sit
    // strictly earlier than the ZC706 design's.
    assert!(
        tiny.ce_plan().boundary < zc.ce_plan().boundary,
        "tiny boundary {} not earlier than zc706 {}",
        tiny.ce_plan().boundary,
        zc.ce_plan().boundary
    );
    // Less on-chip buffering => more off-chip traffic.
    assert!(tiny.dram_bytes() >= zc.dram_bytes());
    assert!(tiny.memory().sram_bytes <= zc.memory().sram_bytes);
}

#[test]
#[allow(deprecated)]
fn deprecated_design_point_shim_matches_builder() {
    for (net, granularity) in [
        (nets::mobilenet_v2(), Granularity::Fgpm),
        (nets::shufflenet_v2(), Granularity::Factorized),
    ] {
        let shim = repro::alloc::design_point(&net, zc706::SRAM_BYTES, zc706::DSP_BUDGET, granularity);
        let d = Design::builder(&net)
            .platform(Platform::zc706())
            .granularity(granularity)
            .build();
        assert_eq!(shim.memory.boundary, d.ce_plan().boundary, "{}", net.name);
        assert_eq!(shim.memory.boundary_min_sram, d.memory().boundary_min_sram);
        assert_eq!(shim.sram_bytes, d.sram_bytes());
        assert_eq!(shim.dram_bytes, d.dram_bytes());
        assert_eq!(shim.parallelism.pes, d.parallelism().pes);
        assert_eq!(shim.parallelism.dsps, d.parallelism().dsps);
        assert_eq!(shim.parallelism.allocs, d.allocs());
        assert_eq!(shim.performance.t_max, d.predicted().t_max);
        assert_eq!(shim.performance.fps, d.predicted().fps);
    }
}

#[test]
fn zcu102_clock_flows_through_prediction() {
    // The catalog's ZCU102 carries a 300 MHz clock; the allocation
    // itself is clock-independent, so against an otherwise identical
    // 200 MHz variant the predicted FPS scales by exactly 3/2 through
    // `throughput::evaluate_at`.
    let net = nets::shufflenet_v2();
    let fast = Design::builder(&net).platform(Platform::zcu102()).build();
    let slow = Design::builder(&net).platform(Platform::zcu102().with_clock_hz(200.0e6)).build();
    assert_eq!(fast.platform().clock_hz, 300.0e6);
    assert_eq!(fast.predicted().t_max, slow.predicted().t_max);
    assert_eq!(fast.allocs(), slow.allocs());
    assert_eq!(fast.ce_plan().boundary, slow.ce_plan().boundary);
    let ratio = fast.predicted().fps / slow.predicted().fps;
    assert!((ratio - 1.5).abs() < 1e-9, "fps ratio {ratio}");
    let ratio = fast.predicted().gops / slow.predicted().gops;
    assert!((ratio - 1.5).abs() < 1e-9, "gops ratio {ratio}");
}

#[test]
fn catalog_platforms_build_and_roundtrip_designs() {
    // Every catalog platform drives the full pipeline and persists: the
    // same (net, platform) matrix the golden baselines pin.
    let net = nets::mobilenet_v2();
    for platform in Platform::list() {
        let d = Design::builder(&net).platform(platform.clone()).build();
        assert_eq!(d.platform(), &platform);
        assert!(d.predicted().fps > 0.0, "{}", platform.name);
        let reloaded = Design::from_json(&d.to_json()).expect("reload");
        assert_eq!(d.to_json(), reloaded.to_json(), "{}", platform.name);
    }
}

#[test]
fn saved_design_file_reloads_and_resimulates() {
    let net = nets::shufflenet_v2();
    let d = Design::builder(&net).platform(Platform::zc706()).build();
    let path = std::env::temp_dir().join("repro_design_roundtrip.json");
    std::fs::write(&path, d.to_json()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let reloaded = Design::from_json(&text).unwrap();
    let a = d.simulate(4).unwrap();
    let b = reloaded.simulate(4).unwrap();
    assert_eq!(a.period_cycles, b.period_cycles);
    assert_eq!(a.total_cycles, b.total_cycles);
}
